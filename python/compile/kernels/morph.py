"""L1 Pallas kernels: 3x3 morphology and the reconstruction step.

The paper's hot-spot GPU kernel is queue-based morphological reconstruction
(their technical report CCI-TR-2012-2): a hierarchical-queue wave propagation.
Queues are intrinsically scalar-irregular and map terribly to a systolic
array, so the TPU formulation used here is the *iterated geodesic dilation*
fixed point:

    marker_{t+1} = min( dilate3x3(marker_t), mask )

Each step is an elementwise 8-neighbour max + clip — pure VPU work on a
VMEM-resident tile — and the fixed-point loop lives at L2 as a
`lax.while_loop` (python/compile/model.py::morph_recon), so the lowered HLO
contains a single `while` whose body is this kernel.  The same dilate/erode
kernels implement Morph. Open (erosion then dilation).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _nbr_reduce(img: jnp.ndarray, op, pad_val: float, connectivity: int) -> jnp.ndarray:
    """Reduce over the 4- or 8-neighbourhood (including centre) with `op`."""
    h, w = img.shape
    padded = jnp.pad(img, 1, mode="constant", constant_values=pad_val)
    if connectivity == 4:
        offsets = ((0, 0), (-1, 0), (1, 0), (0, -1), (0, 1))
    else:
        offsets = tuple((dy, dx) for dy in (-1, 0, 1) for dx in (-1, 0, 1))
    acc = None
    for dy, dx in offsets:
        sl = jax.lax.dynamic_slice(padded, (1 + dy, 1 + dx), (h, w))
        acc = sl if acc is None else op(acc, sl)
    return acc


def _dilate_kernel_factory(connectivity):
    def kernel(img_ref, out_ref):
        out_ref[...] = _nbr_reduce(img_ref[...], jnp.maximum, -jnp.inf, connectivity)

    return kernel


def _erode_kernel_factory(connectivity):
    def kernel(img_ref, out_ref):
        out_ref[...] = _nbr_reduce(img_ref[...], jnp.minimum, jnp.inf, connectivity)

    return kernel


def _dilate_clip_kernel_factory(connectivity):
    """One geodesic dilation step: min(dilate(marker), mask)."""

    def kernel(marker_ref, mask_ref, out_ref):
        d = _nbr_reduce(marker_ref[...], jnp.maximum, -jnp.inf, connectivity)
        out_ref[...] = jnp.minimum(d, mask_ref[...])

    return kernel


def dilate3x3(img: jnp.ndarray, connectivity: int = 8) -> jnp.ndarray:
    """Grayscale dilation by the 3x3 (8-conn) or cross (4-conn) element."""
    return pl.pallas_call(
        _dilate_kernel_factory(connectivity),
        out_shape=jax.ShapeDtypeStruct(img.shape, jnp.float32),
        interpret=True,
    )(img)


def erode3x3(img: jnp.ndarray, connectivity: int = 8) -> jnp.ndarray:
    """Grayscale erosion by the 3x3 (8-conn) or cross (4-conn) element."""
    return pl.pallas_call(
        _erode_kernel_factory(connectivity),
        out_shape=jax.ShapeDtypeStruct(img.shape, jnp.float32),
        interpret=True,
    )(img)


def dilate_clip(marker: jnp.ndarray, mask: jnp.ndarray, connectivity: int = 8) -> jnp.ndarray:
    """Single geodesic dilation step of morphological reconstruction."""
    return pl.pallas_call(
        _dilate_clip_kernel_factory(connectivity),
        out_shape=jax.ShapeDtypeStruct(marker.shape, jnp.float32),
        interpret=True,
    )(marker, mask)
