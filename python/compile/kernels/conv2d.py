"""L1 Pallas kernel: 3x3 stencils (gaussian smoothing, sobel gradients).

The CUDA versions in the paper (Canny / Gradient feature operations) stage a
threadblock-sized tile plus halo into shared memory.  The TPU rethinking: the
whole (H, W) tile is staged into VMEM once (256x256 f32 = 256 KiB, 512x512 =
1 MiB, both << 16 MiB) and the nine taps are shift-adds on the VPU — there is
no per-thread halo logic, the BlockSpec *is* the HBM->VMEM schedule.  For
tiles larger than VMEM the grid splits rows and the one-row halo is
re-materialised from HBM (see `row_block_plan` in DESIGN.md §Perf).

Edges are replicate-padded, matching the rust CPU variant in
`rust/src/imgproc/convolve.rs`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

GAUSSIAN3 = (
    (1.0 / 16, 2.0 / 16, 1.0 / 16),
    (2.0 / 16, 4.0 / 16, 2.0 / 16),
    (1.0 / 16, 2.0 / 16, 1.0 / 16),
)
SOBEL_X = ((-1.0, 0.0, 1.0), (-2.0, 0.0, 2.0), (-1.0, 0.0, 1.0))
SOBEL_Y = ((-1.0, -2.0, -1.0), (0.0, 0.0, 0.0), (1.0, 2.0, 1.0))


def _shift(img: jnp.ndarray, dy: int, dx: int) -> jnp.ndarray:
    """Replicate-padded shift: result[y, x] = img[clamp(y+dy), clamp(x+dx)]."""
    h, w = img.shape
    padded = jnp.pad(img, 1, mode="edge")
    return jax.lax.dynamic_slice(padded, (1 + dy, 1 + dx), (h, w))


def _stencil_kernel_factory(taps):
    taps = tuple(tuple(float(v) for v in row) for row in taps)

    def kernel(img_ref, out_ref):
        img = img_ref[...]
        acc = jnp.zeros_like(img)
        for dy in (-1, 0, 1):
            for dx in (-1, 0, 1):
                t = taps[dy + 1][dx + 1]
                if t != 0.0:
                    acc = acc + t * _shift(img, dy, dx)
        out_ref[...] = acc

    return kernel


def stencil3x3(img: jnp.ndarray, taps) -> jnp.ndarray:
    """Apply a 3x3 stencil with replicate edges to an (H, W) f32 image."""
    return pl.pallas_call(
        _stencil_kernel_factory(taps),
        out_shape=jax.ShapeDtypeStruct(img.shape, jnp.float32),
        interpret=True,
    )(img)


def gaussian3(img: jnp.ndarray) -> jnp.ndarray:
    return stencil3x3(img, GAUSSIAN3)


def _sobel_mag_kernel(img_ref, out_ref):
    """Fused sobel-x, sobel-y and magnitude — one VMEM residency."""
    img = img_ref[...]

    def apply(taps):
        acc = jnp.zeros_like(img)
        for dy in (-1, 0, 1):
            for dx in (-1, 0, 1):
                t = taps[dy + 1][dx + 1]
                if t != 0.0:
                    acc = acc + t * _shift(img, dy, dx)
        return acc

    gx = apply(SOBEL_X)
    gy = apply(SOBEL_Y)
    out_ref[...] = jnp.sqrt(gx * gx + gy * gy)


def sobel_magnitude(img: jnp.ndarray) -> jnp.ndarray:
    """Gradient magnitude sqrt(gx^2 + gy^2) of an (H, W) f32 image."""
    return pl.pallas_call(
        _sobel_mag_kernel,
        out_shape=jax.ShapeDtypeStruct(img.shape, jnp.float32),
        interpret=True,
    )(img)
