"""Pure-jnp oracles for every L1 Pallas kernel.

These are the correctness ground truth: pytest asserts each Pallas kernel
against its oracle (allclose), and the rust integration tests compare the
CPU variants against the AOT artifacts that were themselves validated here.
No pallas imports allowed in this file.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .color_deconv import STAIN_MATRIX
from .stats import HIST_BINS, HIST_RANGE


def stain_inverse_ref(matrix=STAIN_MATRIX) -> jnp.ndarray:
    m = jnp.asarray(matrix, dtype=jnp.float32)
    m = m / jnp.linalg.norm(m, axis=1, keepdims=True)
    return jnp.linalg.inv(m)


def color_deconv_ref(rgb: jnp.ndarray, minv: jnp.ndarray | None = None) -> jnp.ndarray:
    if minv is None:
        minv = stain_inverse_ref()
    od = -jnp.log10((rgb.astype(jnp.float32) + 1.0) / 256.0)
    h, w, _ = rgb.shape
    return (od.reshape(-1, 3) @ minv).reshape(h, w, 3)


def _shift_ref(img: jnp.ndarray, dy: int, dx: int) -> jnp.ndarray:
    h, w = img.shape
    padded = jnp.pad(img, 1, mode="edge")
    return jax.lax.dynamic_slice(padded, (1 + dy, 1 + dx), (h, w))


def stencil3x3_ref(img: jnp.ndarray, taps) -> jnp.ndarray:
    acc = jnp.zeros_like(img, dtype=jnp.float32)
    for dy in (-1, 0, 1):
        for dx in (-1, 0, 1):
            acc = acc + float(taps[dy + 1][dx + 1]) * _shift_ref(img, dy, dx)
    return acc


def sobel_magnitude_ref(img: jnp.ndarray) -> jnp.ndarray:
    from .conv2d import SOBEL_X, SOBEL_Y

    gx = stencil3x3_ref(img, SOBEL_X)
    gy = stencil3x3_ref(img, SOBEL_Y)
    return jnp.sqrt(gx * gx + gy * gy)


def _nbr_reduce_ref(img: jnp.ndarray, op, pad_val: float, connectivity: int) -> jnp.ndarray:
    h, w = img.shape
    padded = jnp.pad(img, 1, mode="constant", constant_values=pad_val)
    if connectivity == 4:
        offsets = ((0, 0), (-1, 0), (1, 0), (0, -1), (0, 1))
    else:
        offsets = tuple((dy, dx) for dy in (-1, 0, 1) for dx in (-1, 0, 1))
    acc = None
    for dy, dx in offsets:
        sl = jax.lax.dynamic_slice(padded, (1 + dy, 1 + dx), (h, w))
        acc = sl if acc is None else op(acc, sl)
    return acc


def dilate3x3_ref(img: jnp.ndarray, connectivity: int = 8) -> jnp.ndarray:
    return _nbr_reduce_ref(img, jnp.maximum, -jnp.inf, connectivity)


def erode3x3_ref(img: jnp.ndarray, connectivity: int = 8) -> jnp.ndarray:
    return _nbr_reduce_ref(img, jnp.minimum, jnp.inf, connectivity)


def dilate_clip_ref(marker: jnp.ndarray, mask: jnp.ndarray, connectivity: int = 8) -> jnp.ndarray:
    return jnp.minimum(dilate3x3_ref(marker, connectivity), mask)


def morph_recon_ref(marker: jnp.ndarray, mask: jnp.ndarray, connectivity: int = 8) -> jnp.ndarray:
    """Fixed-point geodesic dilation, run eagerly (python loop) — oracle only."""
    marker = jnp.minimum(marker, mask)
    while True:
        nxt = dilate_clip_ref(marker, mask, connectivity)
        if bool(jnp.all(nxt == marker)):
            return nxt
        marker = nxt


def tile_stats_ref(img: jnp.ndarray) -> jnp.ndarray:
    flat = img.astype(jnp.float32).reshape(-1)
    width = HIST_RANGE / HIST_BINS
    clipped = jnp.clip(flat, 0.0, HIST_RANGE - 1e-3)
    hist = [
        jnp.sum(jnp.where((clipped >= b * width) & (clipped < (b + 1) * width), 1.0, 0.0))
        for b in range(HIST_BINS)
    ]
    return jnp.stack(
        [jnp.sum(flat), jnp.sum(flat * flat), jnp.min(flat), jnp.max(flat), *hist]
    )
