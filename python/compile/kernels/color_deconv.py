"""L1 Pallas kernel: Ruifrok–Johnston color deconvolution.

The paper's feature-computation stage starts with color deconvolution of the
RGB tile into stain channels (hematoxylin / eosin / residual).  On CUDA the
authors implement it as a per-pixel 3x3 matrix product; on TPU the natural
mapping is a single (H*W, 3) x (3, 3) matmul feeding the MXU, tiled over row
blocks so each block's activation slab fits VMEM.

VMEM/MXU accounting (documented for DESIGN.md §Perf; interpret=True wallclock
is not a TPU proxy):
  block = (BLOCK_ROWS, 3) f32 in + (BLOCK_ROWS, 3) f32 out + (3,3) weights
        = 2 * 8192 * 3 * 4 B  ~= 196 KiB  << 16 MiB VMEM.
The matmul contraction dim is 3, so MXU utilisation is bound by the tiny K;
the win on TPU comes from fusing the -log10 optical-density transform into
the same kernel so the tile is read from HBM exactly once.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default stain matrix (rows: hematoxylin, eosin, residual), Ruifrok & Johnston.
STAIN_MATRIX = (
    (0.650, 0.704, 0.286),
    (0.072, 0.990, 0.105),
    (0.268, 0.570, 0.776),
)

BLOCK_ROWS = 8192


def stain_inverse(matrix=STAIN_MATRIX) -> jnp.ndarray:
    """Normalised, inverted stain matrix used by the deconvolution.

    Computed with *numpy* at trace time so it folds into the HLO as a
    constant: jnp.linalg.inv would lower to a typed-FFI LAPACK custom-call
    that the xla_extension 0.5.1 runtime (rust side) cannot compile.
    """
    import numpy as np

    m = np.asarray(matrix, dtype=np.float64)
    m = m / np.linalg.norm(m, axis=1, keepdims=True)
    return jnp.asarray(np.linalg.inv(m), dtype=jnp.float32)


def _deconv_kernel(rgb_ref, minv_ref, out_ref):
    """One row-block: optical density transform fused with the 3x3 matmul."""
    rgb = rgb_ref[...]
    # Optical density: -log10((I + 1) / 256); +1 avoids log(0) for I = 0.
    od = -jnp.log10((rgb + 1.0) / 256.0)
    out_ref[...] = od @ minv_ref[...]


def color_deconv(rgb: jnp.ndarray, minv: jnp.ndarray | None = None) -> jnp.ndarray:
    """Deconvolve an (H, W, 3) float32 RGB tile (0..255) into stain space.

    Returns an (H, W, 3) float32 array; channel 0 is hematoxylin density.
    """
    if minv is None:
        minv = stain_inverse()
    h, w, _ = rgb.shape
    n = h * w
    flat = rgb.reshape(n, 3)
    block = min(BLOCK_ROWS, n)
    # Grid over row blocks; the stain matrix is broadcast to every block.
    grid = (pl.cdiv(n, block),)
    out = pl.pallas_call(
        _deconv_kernel,
        out_shape=jax.ShapeDtypeStruct((n, 3), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, 3), lambda i: (i, 0)),
            pl.BlockSpec((3, 3), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block, 3), lambda i: (i, 0)),
        interpret=True,  # CPU-PJRT target: Mosaic custom-calls cannot run here.
    )(flat, minv)
    return out.reshape(h, w, 3)
