"""L1 Pallas kernel: per-tile statistics vector.

Computes the pixel-statistics portion of the paper's feature-computation
stage in a single pass over the tile: sum, sum of squares, min, max, and a
16-bin histogram over [0, 256).  Output layout (f32[20]):

    [0] sum   [1] sumsq   [2] min   [3] max   [4..19] histogram

On TPU this is one VMEM residency of the tile with VPU reductions; the
histogram is computed as 16 masked sums (branch-free, vectorises) rather
than a scatter, which the VPU has no efficient primitive for.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

STATS_LEN = 20
HIST_BINS = 16
HIST_RANGE = 256.0


def _stats_kernel(img_ref, out_ref):
    img = img_ref[...]
    flat = img.reshape(-1)
    parts = [
        jnp.sum(flat)[None],
        jnp.sum(flat * flat)[None],
        jnp.min(flat)[None],
        jnp.max(flat)[None],
    ]
    width = HIST_RANGE / HIST_BINS
    clipped = jnp.clip(flat, 0.0, HIST_RANGE - 1e-3)
    for b in range(HIST_BINS):
        lo = b * width
        hi = lo + width
        parts.append(jnp.sum(jnp.where((clipped >= lo) & (clipped < hi), 1.0, 0.0))[None])
    out_ref[...] = jnp.concatenate(parts)


def tile_stats(img: jnp.ndarray) -> jnp.ndarray:
    """f32[20] statistics vector for an (H, W) f32 image in [0, 256)."""
    return pl.pallas_call(
        _stats_kernel,
        out_shape=jax.ShapeDtypeStruct((STATS_LEN,), jnp.float32),
        interpret=True,
    )(img)
