"""L1: Pallas kernels for the paper's compute hot-spots (build-time only)."""

from .color_deconv import color_deconv, stain_inverse, STAIN_MATRIX
from .conv2d import gaussian3, sobel_magnitude, stencil3x3, GAUSSIAN3, SOBEL_X, SOBEL_Y
from .morph import dilate3x3, erode3x3, dilate_clip
from .stats import tile_stats, STATS_LEN, HIST_BINS, HIST_RANGE

__all__ = [
    "color_deconv", "stain_inverse", "STAIN_MATRIX",
    "gaussian3", "sobel_magnitude", "stencil3x3", "GAUSSIAN3", "SOBEL_X", "SOBEL_Y",
    "dilate3x3", "erode3x3", "dilate_clip",
    "tile_stats", "STATS_LEN", "HIST_BINS", "HIST_RANGE",
]
