"""L2: the paper's analysis-pipeline operations as JAX graphs (build-time).

Each function here is the "GPU variant" of one operation in the Fig. 1
segmentation + feature-computation pipeline.  The functions call the L1
Pallas kernels (python/compile/kernels/) so that, when `aot.py` lowers a
graph, the kernel lands in the same HLO module; the rust coordinator then
loads and executes the module via PJRT as the accelerator side of the
operation's *function variant* (paper §III-A).

Algorithm notes (paper Table I parallel):

* ``morph_recon`` — the paper's hot-spot.  Their CUDA kernel is a
  hierarchical-queue wave propagation (CCI-TR-2012-2); queues do not map to
  a systolic array, so here it is the iterated geodesic dilation fixed point
  with the per-step kernel in Pallas and the loop as ``lax.while_loop`` —
  the lowered HLO contains a single ``while``.
* ``bwlabel`` — CPU variant is union-find; this variant is iterative
  max-label propagation (labels are **component-max flat indices + 1**, not
  compacted; the rust side compares components, not raw values).
* ``watershed`` — CPU variant is priority-flood; this variant is an
  iterative marker flood (adopt the min-valued labelled neighbour).  Like
  the paper's OpenCV-vs-Körbes pair, the two variants produce slightly
  different (both valid) tessellations.

Masks are f32 0/1; labels are f32 holding exact small integers (< 2^24) so
the rust Literal bridge only ever moves f32 buffers.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernels

BIG = 1.0e9


# ---------------------------------------------------------------------------
# fixed-point helpers
# ---------------------------------------------------------------------------

def _fixpoint(step, init):
    """Run ``x = step(x)`` to convergence inside a single HLO while loop."""

    def cond(state):
        _, changed = state
        return changed

    def body(state):
        x, _ = state
        nxt = step(x)
        return nxt, jnp.any(nxt != x)

    out, _ = jax.lax.while_loop(cond, body, (init, jnp.array(True)))
    return out


def morph_recon(marker: jnp.ndarray, mask: jnp.ndarray, connectivity: int = 8) -> jnp.ndarray:
    """Grayscale morphological reconstruction of ``mask`` from ``marker``."""
    init = jnp.minimum(marker, mask)
    return _fixpoint(lambda m: kernels.dilate_clip(m, mask, connectivity), init)


# ---------------------------------------------------------------------------
# pipeline operations (one per Fig. 1 / Table I entry)
# ---------------------------------------------------------------------------

def rbc_detect(rgb: jnp.ndarray, ratio: jnp.ndarray) -> jnp.ndarray:
    """Red-blood-cell mask: eosin-dominant pixels, denoised by a 3x3 open."""
    stains = kernels.color_deconv(rgb)
    hema, eosin = stains[..., 0], stains[..., 1]
    raw = jnp.where(eosin > ratio * hema, 1.0, 0.0)
    opened = kernels.dilate3x3(kernels.erode3x3(raw))
    return (opened,)


def morph_open(gray: jnp.ndarray) -> jnp.ndarray:
    """Opening by the radius-2 diamond (two 4-conn erosions then dilations).

    The paper opens with a 19x19 disk on 4Kx4K tiles; scaled to our tile
    sizes a radius-2 element plays the same role (remove small bright
    specks) — documented substitution, matched by the CPU variant.
    """
    e = kernels.erode3x3(kernels.erode3x3(gray, 4), 4)
    return (kernels.dilate3x3(kernels.dilate3x3(e, 4), 4),)


def recon_to_nuclei(gray: jnp.ndarray, h: jnp.ndarray, thresh: jnp.ndarray) -> jnp.ndarray:
    """Nuclei candidate mask via the h-dome transform (recon-based).

    dome = gray - recon(gray - h, gray); candidates are dome > thresh.
    """
    recon = morph_recon(gray - h, gray)
    dome = gray - recon
    return (jnp.where(dome > thresh, 1.0, 0.0),)


def fill_holes(mask: jnp.ndarray) -> jnp.ndarray:
    """Fill holes: background reconstruction seeded from the tile border."""
    comp = 1.0 - mask
    h, w = mask.shape
    border = jnp.zeros((h, w), jnp.float32)
    border = border.at[0, :].set(1.0).at[-1, :].set(1.0)
    border = border.at[:, 0].set(1.0).at[:, -1].set(1.0)
    reachable = morph_recon(comp * border, comp, connectivity=4)
    return (1.0 - reachable,)


def bwlabel(mask: jnp.ndarray) -> jnp.ndarray:
    """Connected components (8-conn) by max-label propagation."""
    hgt, wid = mask.shape
    idx = (jnp.arange(hgt * wid, dtype=jnp.float32) + 1.0).reshape(hgt, wid)
    init = jnp.where(mask > 0.5, idx, 0.0)

    def step(lab):
        d = kernels.dilate3x3(lab)
        return jnp.where(mask > 0.5, jnp.maximum(lab, d), 0.0)

    return (_fixpoint(step, init),)


def _areas_of(labels_f: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    n = labels_f.size
    labels_i = labels_f.astype(jnp.int32).reshape(-1)
    return jnp.zeros((n + 1,), jnp.float32).at[labels_i].add(mask.reshape(-1))


def area_threshold(mask: jnp.ndarray, lo: jnp.ndarray, hi: jnp.ndarray) -> jnp.ndarray:
    """Drop components whose pixel area is outside [lo, hi]."""
    (labels,) = bwlabel(mask)
    areas = _areas_of(labels, mask)
    a = areas[labels.astype(jnp.int32)]
    keep = (mask > 0.5) & (a >= lo) & (a <= hi)
    return (jnp.where(keep, 1.0, 0.0),)


def distance(mask: jnp.ndarray) -> jnp.ndarray:
    """Chessboard distance-to-background by iterated min-plus relaxation."""
    init = jnp.where(mask > 0.5, BIG, 0.0)

    def step(d):
        return jnp.minimum(d, kernels.erode3x3(d) + 1.0)

    return (_fixpoint(step, init),)


def pre_watershed(mask: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Distance transform + markers (regional maxima of the distance map).

    Returns (negated distance — the watershed relief, marker labels).
    """
    (dist,) = distance(mask)
    recon = morph_recon(dist - 1.0, dist)
    maxima = jnp.where((dist - recon > 0.5) & (mask > 0.5), 1.0, 0.0)
    (markers,) = bwlabel(maxima)
    return (-dist, markers)


def watershed(relief: jnp.ndarray, markers: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Iterative marker-based flood of ``relief`` restricted to ``mask``."""
    v = jnp.where(mask > 0.5, relief, BIG)
    hgt, wid = mask.shape

    def shift2(a, fill, dy, dx):
        padded = jnp.pad(a, 1, mode="constant", constant_values=fill)
        return jax.lax.dynamic_slice(padded, (1 + dy, 1 + dx), (hgt, wid))

    offsets = [(dy, dx) for dy in (-1, 0, 1) for dx in (-1, 0, 1) if (dy, dx) != (0, 0)]

    def step(lab):
        best_v = jnp.full_like(v, BIG)
        best_l = jnp.zeros_like(lab)
        for dy, dx in offsets:
            nv = shift2(v, BIG, dy, dx)
            nl = shift2(lab, 0.0, dy, dx)
            cand_v = jnp.where(nl > 0.0, nv, BIG)
            take = cand_v < best_v
            best_v = jnp.where(take, cand_v, best_v)
            best_l = jnp.where(take, nl, best_l)
        adopt = (lab == 0.0) & (mask > 0.5) & (best_v < BIG)
        return jnp.where(adopt, best_l, lab)

    labels = _fixpoint(step, markers * jnp.where(mask > 0.5, 1.0, 0.0))
    return (labels,)


def feature_graph(rgb: jnp.ndarray, edge_thresh: jnp.ndarray):
    """Tile-level feature computation: deconv -> smooth -> gradient -> stats.

    Outputs: (hematoxylin image scaled to [0,256), gradient magnitude,
    edge mask, f32[41] stats vector = stats(hema) ++ stats(grad) ++ [#edges]).
    """
    stains = kernels.color_deconv(rgb)
    hema = jnp.clip(stains[..., 0] * 100.0, 0.0, 255.0)
    smooth = kernels.gaussian3(hema)
    gmag = kernels.sobel_magnitude(smooth)
    edges = jnp.where(gmag > edge_thresh, 1.0, 0.0)
    stats = jnp.concatenate(
        [kernels.tile_stats(hema), kernels.tile_stats(gmag), jnp.sum(edges)[None]]
    )
    return (hema, gmag, edges, stats)


def hema_prep(rgb: jnp.ndarray) -> jnp.ndarray:
    """Hematoxylin channel scaled to [0, 256) — the segmentation stage's
    grayscale input (cheap preprocessing; CPU-only in the rust workflow)."""
    stains = kernels.color_deconv(rgb)
    return (jnp.clip(stains[..., 0] * 100.0, 0.0, 255.0),)


def segment_tile(rgb: jnp.ndarray, h: jnp.ndarray, thresh: jnp.ndarray,
                 lo: jnp.ndarray, hi: jnp.ndarray) -> jnp.ndarray:
    """The whole segmentation stage fused into one module (the
    *non-pipelined* / monolithic variant used by the Fig. 9 comparison).

    Mirrors the pipelined chain exactly (rust/src/app assembles the same
    sequence from individual artifacts): hema -> open -> recon-to-nuclei ->
    fill-holes -> area-threshold -> pre-watershed -> watershed.
    """
    (hema,) = hema_prep(rgb)
    (opened,) = morph_open(hema)
    (cand,) = recon_to_nuclei(opened, h, thresh)
    (filled,) = fill_holes(cand)
    (kept,) = area_threshold(filled, lo, hi)
    relief, markers = pre_watershed(kept)
    (labels,) = watershed(relief, markers, kept)
    return (labels,)


# ---------------------------------------------------------------------------
# AOT registry: name -> (fn, example-arg builder)
# ---------------------------------------------------------------------------

def _img(size):
    return jax.ShapeDtypeStruct((size, size), jnp.float32)


def _rgb(size):
    return jax.ShapeDtypeStruct((size, size, 3), jnp.float32)


def _scalar():
    return jax.ShapeDtypeStruct((), jnp.float32)


GRAPHS = {
    "rbc_detect": (rbc_detect, lambda s: (_rgb(s), _scalar())),
    "morph_open": (morph_open, lambda s: (_img(s),)),
    "recon_to_nuclei": (recon_to_nuclei, lambda s: (_img(s), _scalar(), _scalar())),
    "morph_recon": (lambda m, k: (morph_recon(m, k),), lambda s: (_img(s), _img(s))),
    "fill_holes": (fill_holes, lambda s: (_img(s),)),
    "bwlabel": (bwlabel, lambda s: (_img(s),)),
    "area_threshold": (area_threshold, lambda s: (_img(s), _scalar(), _scalar())),
    "distance": (distance, lambda s: (_img(s),)),
    "pre_watershed": (pre_watershed, lambda s: (_img(s),)),
    "watershed": (watershed, lambda s: (_img(s), _img(s), _img(s))),
    "feature_graph": (feature_graph, lambda s: (_rgb(s), _scalar())),
    "hema_prep": (hema_prep, lambda s: (_rgb(s),)),
    "segment_tile": (segment_tile, lambda s: (_rgb(s), _scalar(), _scalar(), _scalar(), _scalar())),
}
