"""AOT: lower every L2 graph to HLO *text* + write the artifact manifest.

HLO text (NOT ``lowered.compiler_ir("hlo").as_hlo_proto().serialize()``) is
the interchange format: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids, which the xla_extension 0.5.1 used by the rust ``xla``
crate rejects (``proto.id() <= INT_MAX``).  The text parser reassigns ids,
so text round-trips cleanly (see /opt/xla-example/README.md).

Modules are lowered with ``return_tuple=False`` so single-output graphs
yield a plain array root: the rust side can then keep results device-resident
for the data-locality optimisation (multi-output roots are tuples and are
downloaded + decomposed).  Each graph is lowered once per configured
tile size — HLO is shape-specialised, exactly like the paper's
per-resolution CUDA launches.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts [--sizes 64,256]
"""

from __future__ import annotations

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from .model import GRAPHS

DEFAULT_SIZES = (64, 256)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def _spec_desc(spec) -> dict:
    return {"shape": list(spec.shape), "dtype": str(spec.dtype)}


def lower_all(out_dir: str, sizes) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"tile_sizes": list(sizes), "modules": []}
    for name, (fn, arg_builder) in sorted(GRAPHS.items()):
        for size in sizes:
            args = arg_builder(size)
            lowered = jax.jit(fn).lower(*args)
            text = to_hlo_text(lowered)
            fname = f"{name}_{size}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            out_tree = jax.eval_shape(fn, *args)
            outs = list(out_tree) if isinstance(out_tree, (tuple, list)) else [out_tree]
            manifest["modules"].append(
                {
                    "name": name,
                    "size": size,
                    "file": fname,
                    "inputs": [_spec_desc(a) for a in args],
                    "outputs": [_spec_desc(o) for o in outs],
                }
            )
            print(f"lowered {name}@{size}: {len(text)} chars, "
                  f"{len(args)} inputs, {len(outs)} outputs")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--sizes", default=",".join(str(s) for s in DEFAULT_SIZES),
                    help="comma-separated tile sizes to specialise for")
    args = ap.parse_args()
    sizes = tuple(int(s) for s in args.sizes.split(",") if s)
    manifest = lower_all(args.out_dir, sizes)
    # manifest written last: it is the Makefile's freshness stamp.
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(manifest['modules'])} modules + manifest.json to {args.out_dir}")


if __name__ == "__main__":
    main()
