"""pytest: AOT artifact manifest consistency.

Validates the build products the rust runtime consumes: every module in
GRAPHS is present per tile size, files exist and parse as HLO text, and the
declared input/output specs match what jax.eval_shape reports.
"""

import json
import os

import jax
import pytest

from compile.model import GRAPHS

ART = os.path.join(os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__)))), "artifacts")


@pytest.fixture(scope="module")
def manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("run `make artifacts` first")
    with open(path) as f:
        return json.load(f)


def test_every_graph_lowered_at_every_size(manifest):
    names = {(m["name"], m["size"]) for m in manifest["modules"]}
    for g in GRAPHS:
        for s in manifest["tile_sizes"]:
            assert (g, s) in names, f"missing {g}@{s}"


def test_files_exist_and_look_like_hlo(manifest):
    for m in manifest["modules"]:
        path = os.path.join(ART, m["file"])
        assert os.path.exists(path), m["file"]
        head = open(path).read(200)
        assert "HloModule" in head, f"{m['file']} is not HLO text"


def test_specs_match_eval_shape(manifest):
    by_key = {(m["name"], m["size"]): m for m in manifest["modules"]}
    for name, (fn, arg_builder) in GRAPHS.items():
        size = min(manifest["tile_sizes"])
        m = by_key[(name, size)]
        args = arg_builder(size)
        assert len(m["inputs"]) == len(args)
        for spec, arg in zip(m["inputs"], args):
            assert spec["shape"] == list(arg.shape)
        out = jax.eval_shape(fn, *args)
        outs = list(out) if isinstance(out, (tuple, list)) else [out]
        assert len(m["outputs"]) == len(outs)
        for spec, o in zip(m["outputs"], outs):
            assert spec["shape"] == list(o.shape)


def test_no_typed_ffi_custom_calls(manifest):
    # xla_extension 0.5.1 cannot compile API_VERSION_TYPED_FFI custom calls
    # (e.g. from jnp.linalg.inv) — guard against regressions.
    for m in manifest["modules"]:
        text = open(os.path.join(ART, m["file"])).read()
        assert "custom_call_target=\"lapack" not in text.lower(), m["file"]
