"""pytest: L2 graph semantics (shapes, invariants, convergence).

These test the *model* layer: the while-loop fixed points converge, masks
stay binary, labels are consistent components, and the fused segment_tile
agrees with composing the individual stage graphs — the property the
pipelined/non-pipelined comparison (paper Fig. 9) relies on.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def blob_mask(s=24, seed=0):
    rng = np.random.RandomState(seed)
    m = np.zeros((s, s), np.float32)
    for _ in range(rng.randint(1, 5)):
        cy, cx = rng.randint(3, s - 3, 2)
        r = rng.randint(2, 5)
        yy, xx = np.mgrid[0:s, 0:s]
        m[(yy - cy) ** 2 + (xx - cx) ** 2 <= r * r] = 1.0
    return jnp.asarray(m)


class TestMorphRecon:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**16))
    def test_matches_eager_oracle(self, seed):
        rng = np.random.RandomState(seed)
        mask = jnp.asarray(rng.uniform(0, 255, (12, 12)).astype(np.float32))
        marker = mask - jnp.asarray(rng.uniform(0, 60, (12, 12)).astype(np.float32))
        got = jax.jit(model.morph_recon)(marker, mask)
        want = ref.morph_recon_ref(marker, mask)
        np.testing.assert_allclose(got, want, rtol=1e-6)

    def test_recon_leq_mask_and_idempotent(self):
        mask = blob_mask(20, 3) * 200.0
        marker = mask * 0.5
        r1 = jax.jit(model.morph_recon)(marker, mask)
        assert bool(jnp.all(r1 <= mask + 1e-6))
        r2 = jax.jit(model.morph_recon)(r1, mask)
        np.testing.assert_allclose(r1, r2, rtol=1e-6)


class TestBwlabel:
    def test_two_components(self):
        m = jnp.zeros((16, 16), jnp.float32)
        m = m.at[2:5, 2:5].set(1.0).at[10:13, 10:13].set(1.0)
        (lab,) = jax.jit(model.bwlabel)(m)
        lab = np.asarray(lab)
        ids = set(np.unique(lab)) - {0.0}
        assert len(ids) == 2
        # every component has exactly one id
        assert len(set(np.unique(lab[2:5, 2:5]))) == 1

    def test_diagonal_is_connected(self):
        m = jnp.zeros((8, 8), jnp.float32)
        m = m.at[1, 1].set(1.0).at[2, 2].set(1.0).at[3, 3].set(1.0)
        (lab,) = jax.jit(model.bwlabel)(m)
        assert len(set(np.unique(np.asarray(lab))) - {0.0}) == 1

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**16))
    def test_background_stays_zero(self, seed):
        m = blob_mask(seed=seed)
        (lab,) = jax.jit(model.bwlabel)(m)
        assert bool(jnp.all((lab > 0) == (m > 0.5)))


class TestFillHoles:
    def test_fills_a_hole(self):
        m = jnp.ones((10, 10), jnp.float32)
        m = m.at[0, :].set(0).at[-1, :].set(0).at[:, 0].set(0).at[:, -1].set(0)
        m = m.at[5, 5].set(0.0)  # interior hole
        (f,) = jax.jit(model.fill_holes)(m)
        assert float(f[5, 5]) == 1.0
        # border background must remain background
        assert float(f[0, 0]) == 0.0

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**16))
    def test_extensive_and_binary(self, seed):
        m = blob_mask(seed=seed)
        (f,) = jax.jit(model.fill_holes)(m)
        assert bool(jnp.all(f >= m))
        assert set(np.unique(np.asarray(f))) <= {0.0, 1.0}


class TestAreaThreshold:
    def test_drops_small_keeps_large(self):
        m = jnp.zeros((16, 16), jnp.float32)
        m = m.at[1, 1].set(1.0)              # area 1
        m = m.at[8:12, 8:12].set(1.0)        # area 16
        (out,) = jax.jit(model.area_threshold)(m, jnp.float32(4.0), jnp.float32(100.0))
        assert float(out[1, 1]) == 0.0
        assert float(out[8:12, 8:12].min()) == 1.0


class TestDistanceWatershed:
    def test_distance_values(self):
        m = jnp.zeros((9, 9), jnp.float32).at[2:7, 2:7].set(1.0)
        (d,) = jax.jit(model.distance)(m)
        assert float(d[4, 4]) == 3.0  # chessboard distance to background
        assert float(d[2, 2]) == 1.0
        assert float(d[0, 0]) == 0.0

    def test_watershed_separates_two_nuclei(self):
        # two overlapping disks -> one component, watershed must split it
        s = 24
        yy, xx = np.mgrid[0:s, 0:s]
        m = (((yy - 12) ** 2 + (xx - 7) ** 2 <= 25)
             | ((yy - 12) ** 2 + (xx - 17) ** 2 <= 25)).astype(np.float32)
        m = jnp.asarray(m)
        relief, markers = jax.jit(model.pre_watershed)(m)
        n_markers = len(set(np.unique(np.asarray(markers))) - {0.0})
        assert n_markers >= 2
        (lab,) = jax.jit(model.watershed)(relief, markers, m)
        lab = np.asarray(lab)
        assert len(set(np.unique(lab)) - {0.0}) == n_markers
        # full coverage of the mask
        assert bool(((lab > 0) == (np.asarray(m) > 0)).all())
        # the two lobes' centres get different labels
        assert lab[12, 7] != lab[12, 17]


class TestFusedVsComposed:
    def test_segment_tile_matches_stage_composition(self):
        rng = np.random.RandomState(7)
        rgb = jnp.asarray(rng.uniform(0, 255, (24, 24, 3)).astype(np.float32))
        h, t, lo, hi = (jnp.float32(v) for v in (20.0, 5.0, 4.0, 400.0))
        (fused,) = jax.jit(model.segment_tile)(rgb, h, t, lo, hi)

        (hema,) = jax.jit(model.hema_prep)(rgb)
        (opened,) = jax.jit(model.morph_open)(hema)
        (cand,) = jax.jit(model.recon_to_nuclei)(opened, h, t)
        (filled,) = jax.jit(model.fill_holes)(cand)
        (kept,) = jax.jit(model.area_threshold)(filled, lo, hi)
        relief, markers = jax.jit(model.pre_watershed)(kept)
        (lab,) = jax.jit(model.watershed)(relief, markers, kept)
        np.testing.assert_allclose(fused, lab, rtol=1e-5)


class TestFeatureGraph:
    def test_shapes_and_finiteness(self):
        rng = np.random.RandomState(1)
        rgb = jnp.asarray(rng.uniform(0, 255, (16, 16, 3)).astype(np.float32))
        hema, gmag, edges, stats = jax.jit(model.feature_graph)(rgb, jnp.float32(30.0))
        assert hema.shape == (16, 16) and gmag.shape == (16, 16)
        assert stats.shape == (41,)
        assert bool(jnp.isfinite(stats).all())
        assert float(stats[40]) == pytest.approx(float(edges.sum()))
