"""pytest: every L1 Pallas kernel vs its pure-jnp oracle (ref.py).

Hypothesis sweeps shapes and value distributions; this is the core
correctness signal for the compute layer — the rust integration tests
compare against artifacts that these tests validate.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

SHAPES = st.tuples(st.integers(4, 33), st.integers(4, 33))


def img_like(shape, lo=0.0, hi=255.0, seed=0):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.uniform(lo, hi, shape).astype(np.float32))


# ---------------------------------------------------------------------------
# color deconvolution
# ---------------------------------------------------------------------------

class TestColorDeconv:
    def test_matches_ref_fixed(self):
        rgb = img_like((16, 16, 3))
        got = kernels.color_deconv(rgb)
        want = ref.color_deconv_ref(rgb)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    @settings(max_examples=20, deadline=None)
    @given(shape=SHAPES, seed=st.integers(0, 2**16))
    def test_matches_ref_hypothesis(self, shape, seed):
        rgb = img_like((*shape, 3), seed=seed)
        np.testing.assert_allclose(
            kernels.color_deconv(rgb), ref.color_deconv_ref(rgb), rtol=1e-5, atol=1e-5
        )

    def test_block_boundary_shapes(self):
        # Exercise grids that do / do not divide BLOCK_ROWS evenly.
        for h, w in [(64, 128), (91, 7), (1, 1)]:
            rgb = img_like((h, w, 3), seed=h * 131 + w)
            np.testing.assert_allclose(
                kernels.color_deconv(rgb), ref.color_deconv_ref(rgb), rtol=1e-5, atol=1e-5
            )

    def test_white_pixel_near_zero_density(self):
        rgb = jnp.full((4, 4, 3), 255.0, jnp.float32)
        out = kernels.color_deconv(rgb)
        assert float(jnp.abs(out).max()) < 1e-2

    def test_stain_inverse_is_inverse(self):
        m = jnp.asarray(kernels.STAIN_MATRIX, jnp.float32)
        m = m / jnp.linalg.norm(m, axis=1, keepdims=True)
        np.testing.assert_allclose(m @ kernels.stain_inverse(), jnp.eye(3), atol=1e-5)


# ---------------------------------------------------------------------------
# stencils
# ---------------------------------------------------------------------------

class TestStencils:
    @settings(max_examples=20, deadline=None)
    @given(shape=SHAPES, seed=st.integers(0, 2**16))
    def test_gaussian_matches_ref(self, shape, seed):
        img = img_like(shape, seed=seed)
        np.testing.assert_allclose(
            kernels.gaussian3(img), ref.stencil3x3_ref(img, kernels.GAUSSIAN3),
            rtol=1e-5, atol=1e-4,
        )

    @settings(max_examples=20, deadline=None)
    @given(shape=SHAPES, seed=st.integers(0, 2**16))
    def test_sobel_matches_ref(self, shape, seed):
        img = img_like(shape, seed=seed)
        np.testing.assert_allclose(
            kernels.sobel_magnitude(img), ref.sobel_magnitude_ref(img),
            rtol=1e-4, atol=1e-3,
        )

    def test_gaussian_preserves_constant(self):
        img = jnp.full((12, 17), 42.0, jnp.float32)
        np.testing.assert_allclose(kernels.gaussian3(img), img, rtol=1e-6)

    def test_sobel_zero_on_constant(self):
        img = jnp.full((9, 9), 7.0, jnp.float32)
        assert float(kernels.sobel_magnitude(img).max()) < 1e-4

    def test_sobel_detects_vertical_edge(self):
        img = jnp.concatenate(
            [jnp.zeros((8, 4), jnp.float32), jnp.full((8, 4), 100.0, jnp.float32)], axis=1
        )
        mag = kernels.sobel_magnitude(img)
        assert float(mag[:, 3:5].min()) > 100.0
        assert float(mag[:, 0].max()) < 1e-4


# ---------------------------------------------------------------------------
# morphology
# ---------------------------------------------------------------------------

class TestMorph:
    @settings(max_examples=20, deadline=None)
    @given(shape=SHAPES, seed=st.integers(0, 2**16), conn=st.sampled_from([4, 8]))
    def test_dilate_matches_ref(self, shape, seed, conn):
        img = img_like(shape, seed=seed)
        np.testing.assert_allclose(
            kernels.dilate3x3(img, conn), ref.dilate3x3_ref(img, conn), rtol=1e-6
        )

    @settings(max_examples=20, deadline=None)
    @given(shape=SHAPES, seed=st.integers(0, 2**16), conn=st.sampled_from([4, 8]))
    def test_erode_matches_ref(self, shape, seed, conn):
        img = img_like(shape, seed=seed)
        np.testing.assert_allclose(
            kernels.erode3x3(img, conn), ref.erode3x3_ref(img, conn), rtol=1e-6
        )

    @settings(max_examples=20, deadline=None)
    @given(shape=SHAPES, seed=st.integers(0, 2**16))
    def test_dilate_clip_matches_ref(self, shape, seed):
        marker = img_like(shape, seed=seed)
        mask = marker + img_like(shape, 0, 50, seed=seed + 1)
        np.testing.assert_allclose(
            kernels.dilate_clip(marker, mask), ref.dilate_clip_ref(marker, mask), rtol=1e-6
        )

    @settings(max_examples=15, deadline=None)
    @given(shape=SHAPES, seed=st.integers(0, 2**16))
    def test_dilate_geq_erode_leq(self, shape, seed):
        img = img_like(shape, seed=seed)
        assert bool(jnp.all(kernels.dilate3x3(img) >= img))
        assert bool(jnp.all(kernels.erode3x3(img) <= img))

    def test_dilate_extensive_on_point(self):
        img = jnp.zeros((7, 7), jnp.float32).at[3, 3].set(9.0)
        d = kernels.dilate3x3(img)
        assert float(d[2:5, 2:5].min()) == 9.0
        assert float(d[0, 0]) == 0.0


# ---------------------------------------------------------------------------
# stats
# ---------------------------------------------------------------------------

class TestStats:
    @settings(max_examples=20, deadline=None)
    @given(shape=SHAPES, seed=st.integers(0, 2**16))
    def test_matches_ref(self, shape, seed):
        img = img_like(shape, seed=seed)
        np.testing.assert_allclose(
            kernels.tile_stats(img), ref.tile_stats_ref(img), rtol=1e-4, atol=1e-2
        )

    @settings(max_examples=15, deadline=None)
    @given(shape=SHAPES, seed=st.integers(0, 2**16))
    def test_histogram_sums_to_pixel_count(self, shape, seed):
        img = img_like(shape, seed=seed)
        s = kernels.tile_stats(img)
        assert float(jnp.sum(s[4:])) == pytest.approx(img.size)

    def test_constant_image(self):
        img = jnp.full((8, 8), 100.0, jnp.float32)
        s = np.asarray(kernels.tile_stats(img))
        assert s[0] == pytest.approx(6400.0)
        assert s[2] == 100.0 and s[3] == 100.0
        # all mass lands in bin 6 (100 / 16 = 6.25)
        assert s[4 + 6] == 64.0
