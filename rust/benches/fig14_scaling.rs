//! Paper Fig. 14: multi-node strong scaling, 36,848 tiles.
//!
//! Expected shape: near-linear to ~32 nodes, I/O contention degrading
//! efficiency to ~70-80% at 100 nodes while compute-only efficiency stays
//! ~90%+; absolute throughput ~150 tiles/s at 100 nodes.

use htap::bench_util::{f, Table};
use htap::sim::experiments::fig14;

fn main() {
    let rows = fig14(&[8, 16, 32, 50, 75, 100], 36_848);
    let mut t = Table::new(&[
        "nodes",
        "FCFS (s)",
        "PATS+DL+PF (s)",
        "tiles/s",
        "efficiency",
        "compute-only eff.",
    ]);
    for r in &rows {
        t.row(&[
            r.nodes.to_string(),
            f(r.fcfs_secs, 1),
            f(r.pats_all_secs, 1),
            f(r.tiles_per_second, 1),
            f(r.efficiency * 100.0, 1),
            f(r.compute_efficiency * 100.0, 1),
        ]);
    }
    t.print("Fig. 14 — strong scaling, 36,848 4Kx4K-equivalent tiles");
    let last = rows.last().unwrap();
    println!(
        "\n100 nodes: {:.1} tiles/s (paper: ~150), efficiency {:.0}% (paper: ~77%), compute-only {:.0}% (paper: ~93%)",
        last.tiles_per_second,
        last.efficiency * 100.0,
        last.compute_efficiency * 100.0
    );
}
