//! Hot-path microbenchmarks (the §Perf deliverable's measurement tool).
//!
//! Measures the coordinator's per-task overheads — scheduler push/pop,
//! WRM dispatch bookkeeping, tensor<->literal conversion — which must stay
//! well below op execution times for the middleware to claim "overhead-
//! free" fine-grain scheduling.

use htap::bench_util::{f, measure, Table};
use htap::config::Policy;
use htap::coordinator::sched::{make_scheduler, ReadyTask};
use htap::imgproc::convolve::{sobel_magnitude, stencil3x3, SOBEL_X, SOBEL_Y};
use htap::imgproc::reconstruct::{reconstruct, reconstruct_iterative};
use htap::imgproc::{Conn, Gray};
use htap::metrics::DeviceKind;
use htap::runtime::{HostTensor, Value};
use htap::testing::Rng;

fn task(i: u64, speedup: f32) -> ReadyTask {
    ReadyTask {
        key: (i, 0),
        name: String::new(),
        speedup,
        transfer_impact: 0.1,
        seq: i,
        resident_on: if i % 3 == 0 { Some(0) } else { None },
        has_gpu_impl: true,
    }
}

fn main() {
    let mut t = Table::new(&["operation", "mean", "unit"]);

    for (policy, label) in [(Policy::Fcfs, "FCFS"), (Policy::Pats, "PATS")] {
        for n in [16usize, 64, 256] {
            let s = measure(label, 10, 200, || {
                let mut q = make_scheduler(policy);
                let mut rng = Rng::new(7);
                for i in 0..n as u64 {
                    q.push(task(i, rng.f32_range(1.0, 16.0)));
                }
                let mut dev = 0u64;
                while !q.is_empty() {
                    let kind = if dev % 4 == 0 { DeviceKind::Gpu } else { DeviceKind::Cpu };
                    q.pop(kind, 0, true);
                    dev += 1;
                }
            });
            t.row(&[
                format!("{label} push+pop x{n}"),
                f(s.mean.as_nanos() as f64 / n as f64, 0),
                "ns/task".into(),
            ]);
        }
    }

    // tensor <-> literal conversion (the upload/download host cost)
    for size in [64usize, 256] {
        let tensor = HostTensor::new(vec![size, size, 3], vec![1.0; size * size * 3]).unwrap();
        let v = Value::Tensor(tensor);
        let s = measure("to_literal", 5, 50, || {
            let _ = v.to_literal().unwrap();
        });
        t.row(&[format!("tensor->literal {size}x{size}x3"), f(s.mean_ms(), 3), "ms".into()]);
    }

    // payload clone (Arc) — must be O(1)
    let big = Value::Tensor(HostTensor::new(vec![512, 512], vec![0.0; 512 * 512]).unwrap());
    let s = measure("value clone", 10, 1000, || {
        let _ = big.clone();
    });
    t.row(&["value clone 512x512 (Arc)".into(), f(s.mean.as_nanos() as f64, 0), "ns".into()]);

    t.print("hot-path microbenchmarks");

    // §Perf before/after pairs: both implementations ship in the crate, so
    // the optimization log in EXPERIMENTS.md §Perf is reproducible.
    let mut t = Table::new(&["hot path", "before (ms)", "after (ms)", "speedup"]);
    let mut rng = Rng::new(3);
    let size = 128;
    let mask = Gray::new(size, size, rng.image(size, size)).unwrap();
    let marker = Gray {
        h: size,
        w: size,
        px: mask.px.iter().map(|v| (v - 40.0).max(0.0)).collect(),
    };
    let naive = measure("recon naive", 1, 3, || {
        reconstruct_iterative(&marker, &mask, Conn::Eight);
    });
    let fast = measure("recon vincent", 1, 3, || {
        reconstruct(&marker, &mask, Conn::Eight);
    });
    t.row(&[
        format!("morph. reconstruction {size}x{size} (fixed-point -> Vincent hybrid)"),
        f(naive.mean_ms(), 2),
        f(fast.mean_ms(), 2),
        f(naive.mean_ms() / fast.mean_ms(), 1),
    ]);

    let img = Gray::new(size, size, rng.image(size, size)).unwrap();
    let two_pass = measure("sobel 2pass", 2, 10, || {
        let gx = stencil3x3(&img, &SOBEL_X);
        let gy = stencil3x3(&img, &SOBEL_Y);
        let _m: Vec<f32> =
            gx.px.iter().zip(&gy.px).map(|(a, b)| (a * a + b * b).sqrt()).collect();
    });
    let fused = measure("sobel fused", 2, 10, || {
        sobel_magnitude(&img);
    });
    t.row(&[
        format!("sobel magnitude {size}x{size} (two-pass -> fused)"),
        f(two_pass.mean_ms(), 3),
        f(fused.mean_ms(), 3),
        f(two_pass.mean_ms() / fused.mean_ms(), 1),
    ]);
    t.print("§Perf — optimization before/after (see EXPERIMENTS.md)");
}
