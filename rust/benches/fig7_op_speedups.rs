//! Paper Fig. 7: per-operation performance on the accelerator.
//!
//! Unlike the other figure benches (simulated at Keeneland scale), this one
//! *measures* the real function variants on this machine: the rust CPU
//! implementation vs the AOT-compiled XLA executable via PJRT, per pipeline
//! operation, on synthetic tiles.  The PJRT CPU backend is obviously not an
//! M2090 GPU, so the measured "speedups" here characterise this testbed;
//! the paper-calibrated profile (app::profile) is printed alongside.
//!
//! Without `make artifacts` (or under the offline xla shim) the PJRT
//! column degrades to "-" and only the CPU members are measured — the same
//! graceful degradation the WRM applies, so the bench runs everywhere.

use htap::app::{ops, profile};
use htap::bench_util::{f, measure, Table};
use htap::data::{SynthConfig, TileSynthesizer};
use htap::runtime::pjrt::DeviceExecutor;
use htap::runtime::{ArtifactManifest, Value};

const TILE: usize = 64;
const ITERS: usize = 5;

fn main() {
    let manifest = ArtifactManifest::discover_or_empty();
    let mut executor = if manifest.is_empty() {
        eprintln!("fig7: no AOT artifacts (run `make artifacts`); measuring CPU members only");
        None
    } else {
        DeviceExecutor::new(manifest).ok()
    };
    let synth = TileSynthesizer::new(SynthConfig::for_tile_size(TILE, 7));
    let rgb = Value::Tensor(synth.tissue_tile(0).to_tensor());

    // precompute chain inputs with the CPU variants
    let hema = ops::hema_prep(&[rgb.clone()]).unwrap().remove(0);
    let opened = ops::morph_open(&[hema.clone()]).unwrap().remove(0);
    let cand = ops::recon_to_nuclei(&[opened.clone(), Value::Scalar(20.0), Value::Scalar(5.0)])
        .unwrap()
        .remove(0);
    let filled = ops::fill_holes(&[cand.clone()]).unwrap().remove(0);
    let kept = ops::area_threshold(&[filled.clone(), Value::Scalar(5.0), Value::Scalar(500.0)])
        .unwrap();
    let kept = kept[0].clone();
    let pw = ops::pre_watershed(&[kept.clone()]).unwrap();
    let (relief, markers) = (pw[0].clone(), pw[1].clone());

    type CpuCall = Box<dyn Fn() -> ()>;
    let cases: Vec<(&str, Vec<Value>, CpuCall)> = vec![
        ("rbc_detect", vec![rgb.clone(), Value::Scalar(1.2)], {
            let a = [rgb.clone(), Value::Scalar(1.2)];
            Box::new(move || {
                ops::rbc_detect(&a).unwrap();
            })
        }),
        ("morph_open", vec![hema.clone()], {
            let a = [hema.clone()];
            Box::new(move || {
                ops::morph_open(&a).unwrap();
            })
        }),
        ("recon_to_nuclei", vec![opened.clone(), Value::Scalar(20.0), Value::Scalar(5.0)], {
            let a = [opened.clone(), Value::Scalar(20.0), Value::Scalar(5.0)];
            Box::new(move || {
                ops::recon_to_nuclei(&a).unwrap();
            })
        }),
        ("fill_holes", vec![cand.clone()], {
            let a = [cand.clone()];
            Box::new(move || {
                ops::fill_holes(&a).unwrap();
            })
        }),
        ("area_threshold", vec![filled.clone(), Value::Scalar(5.0), Value::Scalar(500.0)], {
            let a = [filled.clone(), Value::Scalar(5.0), Value::Scalar(500.0)];
            Box::new(move || {
                ops::area_threshold(&a).unwrap();
            })
        }),
        ("bwlabel", vec![kept.clone()], {
            let a = [kept.clone()];
            Box::new(move || {
                ops::bwlabel(&a).unwrap();
            })
        }),
        ("pre_watershed", vec![kept.clone()], {
            let a = [kept.clone()];
            Box::new(move || {
                ops::pre_watershed(&a).unwrap();
            })
        }),
        ("watershed", vec![relief.clone(), markers.clone(), kept.clone()], {
            let a = [relief.clone(), markers.clone(), kept.clone()];
            Box::new(move || {
                ops::watershed_op(&a).unwrap();
            })
        }),
        ("feature_graph", vec![rgb.clone(), Value::Scalar(30.0)], {
            let a = [rgb.clone(), Value::Scalar(30.0)];
            Box::new(move || {
                ops::feature_graph(&a).unwrap();
            })
        }),
    ];

    let mut t = Table::new(&[
        "operation",
        "CPU (ms)",
        "PJRT (ms)",
        "measured ratio",
        "paper speedup",
        "paper +transfer",
    ]);
    let mut cpu_total = 0.0;
    for (name, gpu_args, cpu_call) in &cases {
        let cpu = measure(name, 1, ITERS, || cpu_call());
        // probe once; a failed execution (missing artifact, offline shim)
        // leaves the PJRT column unmeasured
        let gpu_ms: Option<f64> = executor.as_mut().and_then(|ex| {
            if ex.run(name, TILE, gpu_args).is_err() {
                return None;
            }
            let s = measure(name, 1, ITERS, || {
                ex.run(name, TILE, gpu_args).unwrap();
            });
            Some(s.mean_ms())
        });
        cpu_total += cpu.mean_ms();
        let e = profile::entry(name).unwrap();
        let (gpu_cell, ratio_cell) = match gpu_ms {
            Some(g) => (f(g, 3), f(cpu.mean_ms() / g, 2)),
            None => ("-".to_string(), "-".to_string()),
        };
        t.row(&[
            name.to_string(),
            f(cpu.mean_ms(), 3),
            gpu_cell,
            ratio_cell,
            f(e.speedup as f64, 1),
            f(e.speedup_with_transfer() as f64, 1),
        ]);
    }
    t.print("Fig. 7 — per-operation CPU variant vs PJRT artifact (this testbed)");
    println!("\nsingle-core total per tile: {:.2} ms ({TILE}x{TILE} synthetic tile)", cpu_total);
    println!("note: PJRT CPU backend stands in for the GPU; the paper-calibrated");
    println!("speedup columns drive PATS and the cluster simulator.");
}
