//! Paper Fig. 11: data-locality conscious assignment (DL) + prefetching.
//!
//! Expected shape: DL improves both policies (paper: 1.1x FCFS, 1.04x
//! PATS); FCFS pipelined+DL >= 1.1x over non-pipelined; prefetch is a small
//! additional effect.  Includes a transferImpact sweep (ablation).

use htap::bench_util::{f, Table};
use htap::sim::experiments::fig11;
use htap::sim::{simulate, SimParams, SimWorkflow};

fn main() {
    let rows = fig11(300);
    let mut t = Table::new(&["configuration", "makespan (s)", "speedup vs 1 core"]);
    for r in &rows {
        t.row(&[r.label.clone(), f(r.makespan, 1), f(r.speedup_vs_1core, 2)]);
    }
    t.print("Fig. 11 — DL and prefetching impact");

    let get = |l: &str| rows.iter().find(|r| r.label == l).unwrap().makespan;
    println!("\nDL gain FCFS = {:.3}x (paper ~1.1x)", get("FCFS pipelined") / get("FCFS pipelined +DL"));
    println!("DL gain PATS = {:.3}x (paper ~1.04x)", get("PATS pipelined") / get("PATS pipelined +DL"));
    println!(
        "prefetch on PATS+DL = {:.3}x (paper ~1.03x)",
        get("PATS pipelined +DL") / get("PATS pipelined +DL +Prefetch")
    );

    // ablation: how the DL decision rule responds to transfer impact
    let mut t = Table::new(&["transferImpact scale", "PATS+DL makespan (s)"]);
    for scale in [0.5f32, 1.0, 2.0] {
        let mut wf = SimWorkflow::pipelined();
        for st in &mut wf.stages {
            for op in &mut st.ops {
                op.transfer_impact = (op.transfer_impact * scale).min(0.9);
            }
        }
        let r = simulate(&SimParams { workflow: wf, n_tiles: 300, ..Default::default() });
        t.row(&[f(scale as f64, 1), f(r.makespan, 1)]);
    }
    t.print("Ablation — transferImpact sweep (DL rule sensitivity)");
}
