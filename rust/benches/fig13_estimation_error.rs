//! Paper Fig. 13: PATS sensitivity to speedup-estimation error.
//!
//! Expected shape: flat until ~40-60% error (order preserved), degrading at
//! 70-100% (adversarial confounded inversion), staying within ~1.35x of
//! FCFS at full inversion.  The random-error column is an ablation beyond
//! the paper showing only the *order* matters.

use htap::bench_util::{f, Table};
use htap::sim::experiments::fig13;

fn main() {
    let (rows, fcfs) = fig13(&[0, 10, 20, 30, 40, 50, 60, 70, 80, 90, 100], 300);
    let mut t = Table::new(&["error %", "PATS confounded (s)", "PATS random (s)"]);
    for r in &rows {
        t.row(&[r.error_pct.to_string(), f(r.pats_secs, 1), f(r.pats_random_secs, 1)]);
    }
    t.print("Fig. 13 — PATS under speedup-estimation error");
    println!("\nFCFS reference = {fcfs:.1}s");
    let e0 = rows[0].pats_secs;
    let e100 = rows.last().unwrap().pats_secs;
    println!("0% error: {:.2}x faster than FCFS", fcfs / e0);
    println!("100% error vs FCFS: {:.2}x (paper: ~1.1x)", e100 / fcfs);
}
