//! Op-dispatch microbench (the zero-copy datapath's measurement tool).
//!
//! Drives the *real* staged Worker path — Manager, staging-cache splice,
//! WRM, device threads (`run_local_staged`) — over a chain of relay ops
//! whose compute cost is ~zero (each returns an `Arc` bump of its input),
//! so wall time is pure coordination: scheduler push/pop, cache fetch +
//! input splice, input gathering, completion bookkeeping, wakeups.
//!
//! Two claims are checked (see docs/perf.md):
//! * per-op dispatch cost is **independent of tile size** — inputs move by
//!   reference, so a 1024² tile dispatches as fast as a 64² one (before
//!   the zero-copy datapath, dispatch scaled with bytes because payloads
//!   were memcpy'd under the WRM mutex);
//! * dispatch throughput scales with `cpu_workers` instead of serialising
//!   behind the lock (tiles/s at 8 threads vs 1).

use htap::bench_util::{f, measure, Table};
use htap::config::{CacheCap, Policy, RunConfig};
use htap::coordinator::{run_local_staged, ChunkId};
use htap::data::staging::ChunkSource;
use htap::dataflow::{OpRegistry, StageKind, Workflow, WorkflowBuilder};
use htap::runtime::calibrate::SharedProfiles;
use htap::runtime::Value;
use htap::Result;
use std::collections::HashMap;
use std::sync::Arc;

/// Relay ops per stage: long enough that coordination dominates setup.
const CHAIN: usize = 8;
const TILES: usize = 48;

/// Every chunk is one shared tile: loads are Arc bumps, so the bench
/// measures the dispatch path, not synthetic data generation.
struct SharedTileSource {
    tile: Value,
    n: usize,
}

impl ChunkSource for SharedTileSource {
    fn n_chunks(&self) -> usize {
        self.n
    }

    fn load(&self, _chunk: ChunkId) -> Result<Vec<Value>> {
        Ok(vec![self.tile.clone()])
    }

    fn describe(&self) -> String {
        "shared-tile".into()
    }
}

fn relay_workflow() -> Arc<Workflow> {
    let mut reg = OpRegistry::new();
    reg.register_cpu("relay", 1, |args: &[Value]| Ok(vec![args[0].clone()]))
        .unwrap();
    let mut wb = WorkflowBuilder::new("dispatch-bench", reg);
    let mut s = wb.stage("chain", StageKind::PerChunk);
    let mut port = s.input_chunk();
    for _ in 0..CHAIN {
        let op = s.add_op("relay", &[port]).unwrap();
        port = op.out();
    }
    s.export(port).unwrap();
    wb.add_stage(s).unwrap();
    Arc::new(wb.build().unwrap())
}

fn main() {
    let workflow = relay_workflow();
    let mut t = Table::new(&["cpus", "tile", "wall (ms)", "ns/op dispatch", "tiles/s"]);
    for cpus in [1usize, 4, 8] {
        for side in [64usize, 1024] {
            let tile = Value::tensor(vec![side, side], vec![1.0; side * side]).unwrap();
            let wf = workflow.clone();
            let cfg = RunConfig {
                tile_size: side,
                n_tiles: TILES,
                cpu_workers: cpus,
                gpu_workers: 0,
                policy: Policy::Pats,
                staging_cap: CacheCap::Chunks(TILES),
                prefetch_depth: 0,
                ..Default::default()
            };
            let s = measure(&format!("dispatch c{cpus} s{side}"), 1, 5, || {
                run_local_staged(
                    wf.clone(),
                    Arc::new(SharedTileSource { tile: tile.clone(), n: TILES }),
                    TILES,
                    cfg.clone(),
                    HashMap::new(),
                    SharedProfiles::fresh(),
                )
                .expect("bench run failed");
            });
            let ops = (TILES * CHAIN) as f64;
            t.row(&[
                format!("{cpus}"),
                format!("{side}x{side}"),
                f(s.mean_ms(), 2),
                f(s.mean.as_nanos() as f64 / ops, 0),
                f(TILES as f64 / s.mean.as_secs_f64(), 0),
            ]);
        }
    }
    t.print("op-dispatch latency & throughput (staged relay chain, zero compute)");
    println!(
        "\nReading this table: within one cpus row, ns/op should be ~flat across tile\n\
         sizes (zero-copy dispatch); tiles/s should grow with cpus (short critical\n\
         section).  See docs/perf.md."
    );
}
