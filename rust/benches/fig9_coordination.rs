//! Paper Fig. 9 + Fig. 10: coordinated CPU+GPU execution.
//!
//! Expected shape: 12-core CPU speedup ~9 (memory-bound sub-linear); 3-GPU
//! near-linear; PATS pipelined ~1.33x over FCFS; non-pipelined PATS ~ FCFS;
//! Fig. 10: low-speedup ops mostly on CPU, high-speedup ops on GPU.

use htap::bench_util::{f, Table};
use htap::sim::experiments::{fig10, fig9};

fn main() {
    let rows = fig9(300);
    let mut t = Table::new(&["configuration", "makespan (s)", "speedup vs 1 core"]);
    for r in &rows {
        t.row(&[r.label.clone(), f(r.makespan, 1), f(r.speedup_vs_1core, 2)]);
    }
    t.print("Fig. 9 — application scalability across device configurations");

    let get = |l: &str| rows.iter().find(|r| r.label == l).unwrap().makespan;
    println!(
        "\nPATS/FCFS (pipelined) = {:.2}x  (paper: ~1.33x)",
        get("3GPU+9CPU FCFS pipelined") / get("3GPU+9CPU PATS pipelined")
    );

    let mut t = Table::new(&["operation", "% on GPU (PATS)"]);
    for (op, frac) in fig10(300) {
        t.row(&[op, f(frac * 100.0, 1)]);
    }
    t.print("Fig. 10 — execution profile per pipeline operation (PATS)");
}
