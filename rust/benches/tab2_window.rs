//! Paper Table II + Fig. 12: demand-driven window size.
//!
//! Expected shape: FCFS flat across 12..19; PATS >= FCFS; at windows below
//! the device count both policies starve (our WRM keeps choice at window =
//! #devices, so PATS's knee sits below the paper's — see EXPERIMENTS.md).

use htap::bench_util::{f, Table};
use htap::sim::experiments::table2;

fn main() {
    let windows = [4, 6, 8, 10, 12, 13, 14, 15, 16, 17, 18, 19, 24, 32];
    let rows = table2(&windows, 300);
    let mut t = Table::new(&["window", "FCFS (s)", "PATS (s)"]);
    for r in &rows {
        t.row(&[r.window.to_string(), f(r.fcfs_secs, 1), f(r.pats_secs, 1)]);
    }
    t.print("Table II — execution time vs demand-driven window size");

    // Fig. 12: per-op GPU share vs window (PATS)
    let ops = ["morph_open", "recon_to_nuclei", "watershed", "feature_graph"];
    let mut t = Table::new(&["window", "morph_open", "recon_to_nuclei", "watershed", "feature_graph"]);
    for r in rows.iter().filter(|r| [4, 8, 12, 16, 19].contains(&r.window)) {
        let mut cells = vec![r.window.to_string()];
        for op in ops {
            let frac = r
                .pats_gpu_fraction
                .iter()
                .find(|(n, _)| n == op)
                .map(|(_, v)| *v)
                .unwrap_or(0.0);
            cells.push(f(frac * 100.0, 1));
        }
        t.row(&cells);
    }
    t.print("Fig. 12 — % of op instances on GPU vs window size (PATS)");
}
