//! Paper Fig. 8: end-to-end multi-GPU speedup, OS vs Closest placement.
//!
//! Expected shape: Closest >= OS at every GPU count, delta growing with
//! GPUs (paper: ~3%, 6%, 8%); multi-GPU scaling near-linear.

use htap::bench_util::{f, Table};
use htap::sim::experiments::fig8;

fn main() {
    let rows = fig8(300);
    let mut t = Table::new(&["GPUs", "placement", "speedup vs 1 core"]);
    for r in &rows {
        t.row(&[r.gpus.to_string(), r.placement.name().into(), f(r.speedup_vs_1core, 2)]);
    }
    t.print("Fig. 8 — multi-GPU end-to-end speedup (includes tile I/O)");
    for g in 1..=3usize {
        let os = rows.iter().find(|r| r.gpus == g && r.placement.name() == "OS").unwrap();
        let cl = rows.iter().find(|r| r.gpus == g && r.placement.name() == "Closest").unwrap();
        println!(
            "gpus={g}: Closest/OS = {:.3} (paper: 1.03 / 1.06 / 1.08)",
            cl.speedup_vs_1core / os.speedup_vs_1core
        );
    }
}
