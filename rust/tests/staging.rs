//! The data-staging subsystem end to end: deferred chunk payloads, the
//! worker staging cache + prefetcher, locality-aware assignment, and the
//! `.tile` directory source — through `run_local_staged` and the real WRM.

use htap::app::{build_workflow, stage_bindings, AppParams};
use htap::config::RunConfig;
use htap::coordinator::{run_local_staged, ChunkId};
use htap::data::staging::ChunkSource;
use htap::data::{DirSource, SynthConfig, TileStore};
use htap::dataflow::{param, OpRegistry, StageKind, Workflow, WorkflowBuilder};
use htap::runtime::calibrate::SharedProfiles;
use htap::runtime::Value;
use htap::Result;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

/// A scalar chunk source with a controllable read latency: chunk `c`
/// loads as `Scalar(c)` after sleeping, standing in for a shared-FS read.
struct ScalarSource {
    n: usize,
    latency: Duration,
}

impl ChunkSource for ScalarSource {
    fn n_chunks(&self) -> usize {
        self.n
    }

    fn load(&self, chunk: ChunkId) -> Result<Vec<Value>> {
        if chunk as usize >= self.n {
            return Err(htap::Error::Config(format!("chunk {chunk} out of range")));
        }
        if !self.latency.is_zero() {
            std::thread::sleep(self.latency);
        }
        Ok(vec![Value::Scalar(chunk as f32)])
    }

    fn describe(&self) -> String {
        format!("scalar({})", self.n)
    }
}

/// Two PerChunk stages that both read the chunk (stage 1 additionally
/// consumes stage 0's output) + a Reduce total, with `op_ms` of compute
/// per op so prefetch has something to hide behind.
fn slow_workflow(op_ms: u64) -> Arc<Workflow> {
    let mut reg = OpRegistry::new();
    reg.register_cpu("slow_add", 1, move |args: &[Value]| {
        if op_ms > 0 {
            std::thread::sleep(Duration::from_millis(op_ms));
        }
        let mut s = 0.0;
        for v in args {
            s += v.as_scalar()?;
        }
        Ok(vec![Value::Scalar(s)])
    })
    .unwrap();
    reg.register_cpu("sum", 1, |args: &[Value]| {
        let mut s = 0.0;
        for v in args {
            s += v.as_scalar()?;
        }
        Ok(vec![Value::Scalar(s)])
    })
    .unwrap();
    let mut wb = WorkflowBuilder::new("staging-test", reg);
    let mut s0 = wb.stage("s0", StageKind::PerChunk);
    let c = s0.input_chunk();
    let op = s0.add_op("slow_add", &[c, param(1.0)]).unwrap();
    s0.export(op.out()).unwrap();
    let s0 = wb.add_stage(s0).unwrap();
    let mut s1 = wb.stage("s1", StageKind::PerChunk);
    let c = s1.input_chunk();
    let up = s1.input_upstream(s0.output(0));
    let op = s1.add_op("slow_add", &[c, up]).unwrap();
    s1.export(op.out()).unwrap();
    let s1 = wb.add_stage(s1).unwrap();
    let mut red = wb.stage("total", StageKind::Reduce);
    red.input_upstream(s1.output(0));
    let op = red.add_reduce_op("sum").unwrap();
    red.export(op.out()).unwrap();
    wb.add_stage(red).unwrap();
    Arc::new(wb.build().unwrap())
}

#[test]
fn staged_run_with_prefetch_hides_read_latency() {
    // 8 chunks, 15 ms simulated read, 40 ms compute per op, window 2:
    // while the two in-flight instances compute, the prefetcher stages
    // the chunks of upcoming assignments (manager hints), so later reads
    // are (at least partially) hidden behind compute.
    let n = 8;
    let wf = slow_workflow(40);
    let source = Arc::new(ScalarSource { n, latency: Duration::from_millis(15) });
    let cfg = RunConfig {
        n_tiles: n,
        cpu_workers: 2,
        gpu_workers: 0,
        window: 2,
        staging_cap: htap::config::CacheCap::Chunks(16),
        prefetch_depth: 4,
        ..Default::default()
    };
    let outcome =
        run_local_staged(wf, source, n, cfg, HashMap::new(), SharedProfiles::fresh()).unwrap();
    let (done, total) = outcome.manager.progress();
    assert_eq!((done, total), (17, 17)); // 8 + 8 + 1 reduce
    // end-to-end values survive the deferred-payload path:
    // s1(c) = c + (c + 1); sum over 0..8 = 2*28 + 8 = 64
    let out = outcome.manager.reduce_outputs("total").unwrap();
    assert_eq!(out[0].as_scalar().unwrap(), 64.0);
    let s = &outcome.metrics.staging;
    // every (stage, chunk) fetch is accounted exactly once
    assert_eq!(s.hits + s.misses, 2 * n as u64, "{s:?}");
    assert!(s.hits > 0, "repeat-stage fetches must hit the cache: {s:?}");
    assert!(s.prefetched > 0, "the prefetcher never staged anything: {s:?}");
    // the acceptance metric: read latency was overlapped with compute
    assert!(s.hidden > Duration::ZERO, "no read latency hidden: {s:?}");
    // single worker: repeat stages land where the chunk is staged
    let (hits, _cold, steals) = outcome.manager.locality_stats();
    assert!(hits >= n as u64, "stage-1 assignments must be locality hits: {hits}");
    assert_eq!(steals, 0);
}

#[test]
fn staged_run_without_prefetcher_still_completes() {
    let n = 4;
    let wf = slow_workflow(0);
    let source = Arc::new(ScalarSource { n, latency: Duration::ZERO });
    let cfg = RunConfig {
        n_tiles: n,
        cpu_workers: 1,
        gpu_workers: 0,
        window: 2,
        staging_cap: htap::config::CacheCap::Chunks(8),
        prefetch_depth: 0, // no prefetcher thread
        chunk_locality: false,
        ..Default::default()
    };
    let outcome =
        run_local_staged(wf, source, n, cfg, HashMap::new(), SharedProfiles::fresh()).unwrap();
    let (done, total) = outcome.manager.progress();
    assert_eq!(done, total);
    assert_eq!(outcome.manager.reduce_outputs("total").unwrap()[0].as_scalar().unwrap(), 16.0);
    let s = &outcome.metrics.staging;
    assert_eq!(s.prefetched, 0);
    assert_eq!(s.hidden, Duration::ZERO);
    // stage-0 fetches demand-load, stage-1 fetches hit the cache
    assert_eq!(s.misses, n as u64);
    assert_eq!(s.hits, n as u64);
    // locality disabled: the policy counters stay silent
    assert_eq!(outcome.manager.locality_stats(), (0, 0, 0));
}

#[test]
fn tight_staging_cap_evicts_and_reloads() {
    let n = 6;
    let wf = slow_workflow(0);
    let source = Arc::new(ScalarSource { n, latency: Duration::ZERO });
    let cfg = RunConfig {
        n_tiles: n,
        cpu_workers: 1,
        gpu_workers: 0,
        window: 4,
        staging_cap: htap::config::CacheCap::Chunks(1), // pathological: at most one staged chunk
        prefetch_depth: 0,
        ..Default::default()
    };
    let outcome =
        run_local_staged(wf, source, n, cfg, HashMap::new(), SharedProfiles::fresh()).unwrap();
    let (done, total) = outcome.manager.progress();
    assert_eq!(done, total, "eviction pressure must not lose work");
    assert_eq!(outcome.manager.reduce_outputs("total").unwrap()[0].as_scalar().unwrap(), 36.0);
    let s = &outcome.metrics.staging;
    assert!(s.evictions > 0, "cap 1 must evict: {s:?}");
}

#[test]
fn tight_cap_with_spill_dir_demotes_and_promotes() {
    // the tentpole acceptance path: a deliberately small --staging-cap
    // with --spill-dir set must report spill_evicted > 0 (evictions
    // demote, not drop) and spill_hits > 0 (misses served from local
    // disk, not the source tier), without losing work or results
    let n = 6;
    let wf = slow_workflow(0);
    let source = Arc::new(ScalarSource { n, latency: Duration::ZERO });
    let spill_dir = std::env::temp_dir()
        .join(format!("htap-staging-spill-it-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&spill_dir);
    let cfg = RunConfig {
        n_tiles: n,
        cpu_workers: 1,
        gpu_workers: 0,
        window: 4,
        staging_cap: htap::config::CacheCap::Chunks(1), // pathological: at most one chunk in memory
        prefetch_depth: 0,
        spill_dir: Some(spill_dir.to_string_lossy().into_owned()),
        spill_cap: htap::config::CacheCap::Chunks(16),
        ..Default::default()
    };
    let outcome =
        run_local_staged(wf, source, n, cfg, HashMap::new(), SharedProfiles::fresh()).unwrap();
    let (done, total) = outcome.manager.progress();
    assert_eq!(done, total, "spill pressure must not lose work");
    assert_eq!(outcome.manager.reduce_outputs("total").unwrap()[0].as_scalar().unwrap(), 36.0);
    let s = &outcome.metrics.staging;
    assert!(s.spill_evicted > 0, "cap 1 must demote to the spill tier: {s:?}");
    assert!(s.spill_hits > 0, "repeat-stage misses must be served from disk: {s:?}");
    assert!(s.promoted > 0, "{s:?}");
    assert_eq!(s.evictions, 0, "nothing may fall off the bounded spill tier: {s:?}");
    // demoted chunks stayed catalogued: stage-1 assignments still route
    // to this worker as locality hits, never as cold re-assignments
    let (hits, _cold, steals) = outcome.manager.locality_stats();
    assert!(hits >= n as u64, "demoted chunks must keep their locality: {hits}");
    assert_eq!(steals, 0);
    let _ = std::fs::remove_dir_all(&spill_dir);
}

#[test]
fn wsi_pipeline_runs_staged_from_a_tile_directory() {
    // export a synthetic dataset as .tile files, then run the real WSI
    // pipeline over the directory source with staging + prefetch
    let dir = std::env::temp_dir().join(format!("htap-staging-it-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let tile = 32;
    let n = 3;
    let store = TileStore::new(SynthConfig::for_tile_size(tile, 99), n);
    assert_eq!(DirSource::export_store(&dir, &store).unwrap(), n);

    let params = AppParams::for_tile_size(tile);
    let wf = Arc::new(build_workflow(&params, false));
    let source = Arc::new(
        DirSource::open(&dir).unwrap().with_read_latency(Duration::from_millis(2)),
    );
    let cfg = RunConfig {
        tile_size: tile,
        n_tiles: n,
        cpu_workers: 2,
        gpu_workers: 0,
        window: 2,
        staging_cap: htap::config::CacheCap::Chunks(8),
        prefetch_depth: 2,
        ..Default::default()
    };
    let outcome =
        run_local_staged(wf, source, n, cfg, stage_bindings(), SharedProfiles::fresh()).unwrap();
    let (done, total) = outcome.manager.progress();
    assert_eq!((done, total), (2 * n, 2 * n));
    let s = &outcome.metrics.staging;
    // both WSI stages read the tile: n fetches per stage
    assert_eq!(s.hits + s.misses, 2 * n as u64);
    assert!(s.hits > 0);
    let _ = std::fs::remove_dir_all(&dir);
}
