//! Service mode: many tenants' workflows multiplexed over one shared
//! worker pool.  Covers the multi-tenant acceptance criteria end to end:
//! concurrent jobs with reduce outputs bit-identical to single-job runs,
//! weighted fair share (deficit round-robin) within tolerance, per-tenant
//! staging-cache quotas that never evict a neighbour, and cancellation
//! that frees the tenant's admission slot without requeueing anything.

use htap::config::{CacheCap, RunConfig};
use htap::coordinator::worker::{run_worker_opts, JobResolver, WorkerOpts};
use htap::coordinator::{AssignPolicy, Assignment, ChunkId, WorkRequest};
use htap::coordinator::WorkerStaging;
use htap::data::{ChunkSource, StagingCache};
use htap::dataflow::{workflow_from_str, OpRegistry};
use htap::metrics::MetricsHub;
use htap::net::{fetch_job_spec, ManagerServer, RemoteManager};
use htap::runtime::{ArtifactManifest, SharedProfiles, Value};
use htap::service::{job_of, Endpoint, JobTable};
use std::collections::HashMap;
use std::sync::Arc;

/// Ops for the test workflows: two distinguishable per-chunk transforms
/// plus an order-sensitive float reduce.
fn reg() -> Arc<OpRegistry> {
    let mut r = OpRegistry::new();
    r.register_cpu("double", 1, |args: &[Value]| {
        Ok(vec![Value::Scalar(args[0].as_scalar()? * 2.0)])
    })
    .unwrap();
    r.register_cpu("triple", 1, |args: &[Value]| {
        Ok(vec![Value::Scalar(args[0].as_scalar()? * 3.0)])
    })
    .unwrap();
    r.register_cpu("sum", 1, |args: &[Value]| {
        let mut s = 0.0f32;
        for a in args {
            s += a.as_scalar()?;
        }
        Ok(vec![Value::Scalar(s)])
    })
    .unwrap();
    Arc::new(r)
}

const DOUBLE_SUM: &str = r#"{
    "name": "double-sum",
    "stages": [
        {
            "name": "double", "kind": "per_chunk", "inputs": ["chunk"],
            "ops": [ { "op": "double", "inputs": [ {"input": 0} ] } ],
            "outputs": [ {"op": "double"} ]
        },
        {
            "name": "total", "kind": "reduce",
            "inputs": [ {"stage": "double", "output": 0} ],
            "ops": [ { "op": "sum", "inputs": "all" } ],
            "outputs": [ {"op": "sum"} ]
        }
    ]
}"#;

const TRIPLE_SUM: &str = r#"{
    "name": "triple-sum",
    "stages": [
        {
            "name": "triple", "kind": "per_chunk", "inputs": ["chunk"],
            "ops": [ { "op": "triple", "inputs": [ {"input": 0} ] } ],
            "outputs": [ {"op": "triple"} ]
        },
        {
            "name": "total", "kind": "reduce",
            "inputs": [ {"stage": "triple", "output": 0} ],
            "ops": [ { "op": "sum", "inputs": "all" } ],
            "outputs": [ {"op": "sum"} ]
        }
    ]
}"#;

/// Per-chunk payload: irrational-ish values so float summation order is
/// observable — bit-identical results mean chunk-order accumulation held.
fn payload(chunk: ChunkId) -> f32 {
    (chunk as f32 + 0.25).sqrt()
}

/// What the reduce must produce: per-chunk outputs summed in chunk order.
fn expected_total(n: usize, factor: f32) -> f32 {
    let mut s = 0.0f32;
    for c in 0..n {
        s += payload(c as ChunkId) * factor;
    }
    s
}

fn bits(vals: &[Value]) -> Vec<u32> {
    vals.iter().map(|v| v.as_scalar().unwrap().to_bits()).collect()
}

/// Complete one assignment the way a worker would: per-chunk stages map
/// `payload(chunk) * factor`, the reduce folds its shipped inputs in
/// order.
fn complete_scalar(table: &JobTable, a: &Assignment, factors: &[(u64, f32)]) {
    let job = job_of(a.instance_id);
    let factor = factors
        .iter()
        .find(|(j, _)| *j == job)
        .map(|(_, f)| *f)
        .unwrap_or_else(|| panic!("assignment for unexpected job {job}"));
    let out = if a.needs_chunk {
        Value::Scalar(payload(a.chunk) * factor)
    } else {
        let mut s = 0.0f32;
        for v in &a.inputs {
            s += v.as_scalar().unwrap();
        }
        Value::Scalar(s)
    };
    Endpoint::complete(table, a.instance_id, vec![out]);
}

fn open_jobs(table: &JobTable) -> usize {
    Endpoint::job_report(table, 0)
        .iter()
        .filter(|s| !matches!(s.state.as_str(), "Done" | "Failed" | "Cancelled"))
        .count()
}

/// Drive the table as one synthetic worker until every job is terminal.
fn drive_all(
    table: &JobTable,
    worker: u64,
    capacity: usize,
    factors: &[(u64, f32)],
    mut seen: impl FnMut(&Assignment),
) {
    loop {
        let req = WorkRequest { capacity, worker, ..Default::default() };
        let batch = Endpoint::request_work(table, &req);
        if batch.assignments.is_empty() {
            if !batch.idle {
                return; // shut down (table stopped)
            }
            if open_jobs(table) == 0 {
                return;
            }
            std::thread::yield_now();
            continue;
        }
        for a in batch.assignments {
            seen(&a);
            complete_scalar(table, &a, factors);
        }
    }
}

#[test]
fn two_tenants_run_concurrently_with_solo_identical_outputs() {
    const N: usize = 8;
    // solo baselines: each workflow as the only job in its own table
    let solo_double = {
        let t = JobTable::new(reg(), N, AssignPolicy::default(), 4, 8);
        let j = Endpoint::submit(&*t, "alice", DOUBLE_SUM, 1).unwrap();
        drive_all(&t, 1, 3, &[(j, 2.0)], |_| {});
        t.reduce_outputs(j, "total").unwrap()
    };
    let solo_triple = {
        let t = JobTable::new(reg(), N, AssignPolicy::default(), 4, 8);
        let j = Endpoint::submit(&*t, "bob", TRIPLE_SUM, 1).unwrap();
        drive_all(&t, 1, 3, &[(j, 3.0)], |_| {});
        t.reduce_outputs(j, "total").unwrap()
    };

    let t = JobTable::new(reg(), N, AssignPolicy::default(), 4, 8);
    let j1 = Endpoint::submit(&*t, "alice", DOUBLE_SUM, 1).unwrap();
    let j2 = Endpoint::submit(&*t, "bob", TRIPLE_SUM, 1).unwrap();
    let mut order = Vec::new();
    drive_all(&t, 1, 3, &[(j1, 2.0), (j2, 3.0)], |a| order.push(job_of(a.instance_id)));

    for s in Endpoint::job_report(&*t, 0) {
        assert_eq!(s.state, "Done", "job {} ended {}", s.job, s.state);
    }
    // DRR no-starvation: with equal weights both tenants get assignments
    // from the very first requests — neither queues behind the other
    let head: Vec<u64> = order.iter().take(4).copied().collect();
    assert!(
        head.contains(&j1) && head.contains(&j2),
        "first assignments served one tenant only: {order:?}"
    );
    // reduce outputs are bit-identical to the single-job runs
    let svc_double = t.reduce_outputs(j1, "total").unwrap();
    let svc_triple = t.reduce_outputs(j2, "total").unwrap();
    assert_eq!(bits(&svc_double), bits(&solo_double));
    assert_eq!(bits(&svc_triple), bits(&solo_triple));
    assert_eq!(bits(&svc_double), vec![expected_total(N, 2.0).to_bits()]);
    assert_eq!(bits(&svc_triple), vec![expected_total(N, 3.0).to_bits()]);
}

#[test]
fn fair_share_respects_weights_within_20_percent() {
    const N: usize = 64;
    let t = JobTable::new(reg(), N, AssignPolicy::default(), 4, 8);
    let j_alice = Endpoint::submit(&*t, "alice", DOUBLE_SUM, 1).unwrap();
    let j_bob = Endpoint::submit(&*t, "bob", TRIPLE_SUM, 4).unwrap();

    // tally per-chunk grants, but only across requests issued while BOTH
    // tenants still had per-chunk backlog — the DRR ratio is only defined
    // while there is contention
    let mut granted: HashMap<u64, u64> = HashMap::new();
    let (mut tally_alice, mut tally_bob) = (0u64, 0u64);
    loop {
        let backlog = |job: u64| granted.get(&job).copied().unwrap_or(0) < N as u64;
        let tallying = backlog(j_alice) && backlog(j_bob);
        let req = WorkRequest { capacity: 10, worker: 1, ..Default::default() };
        let batch = Endpoint::request_work(&*t, &req);
        if batch.assignments.is_empty() {
            if !batch.idle || open_jobs(&t) == 0 {
                break;
            }
            std::thread::yield_now();
            continue;
        }
        for a in batch.assignments {
            let job = job_of(a.instance_id);
            if a.needs_chunk {
                *granted.entry(job).or_insert(0) += 1;
                if tallying {
                    if job == j_alice {
                        tally_alice += 1;
                    } else {
                        tally_bob += 1;
                    }
                }
            }
            complete_scalar(&t, &a, &[(j_alice, 2.0), (j_bob, 3.0)]);
        }
    }

    for s in Endpoint::job_report(&*t, 0) {
        assert_eq!(s.state, "Done", "job {} ended {}", s.job, s.state);
    }
    // weights 1:4 -> the contended-window assignment ratio within 20%
    assert!(tally_alice > 0, "alice starved during contention");
    let ratio = tally_bob as f64 / tally_alice as f64;
    assert!(
        (ratio - 4.0).abs() <= 0.8,
        "fair-share ratio {ratio:.2} (bob {tally_bob} : alice {tally_alice}) \
         outside 4.0 +/- 20%"
    );
    // the table's own fair-share accounting agrees on weights and totals
    let shares: HashMap<String, (u32, u64)> = t
        .tenant_assignments()
        .into_iter()
        .map(|(name, w, n)| (name, (w, n)))
        .collect();
    assert_eq!(shares["alice"].0, 1);
    assert_eq!(shares["bob"].0, 4);
    // every instance (N per-chunk + 1 reduce) was eventually assigned
    assert_eq!(shares["alice"].1, N as u64 + 1);
    assert_eq!(shares["bob"].1, N as u64 + 1);
}

#[test]
fn tenant_quota_evicts_only_the_over_quota_tenant() {
    struct TensorSource {
        n: usize,
    }
    impl ChunkSource for TensorSource {
        fn n_chunks(&self) -> usize {
            self.n
        }
        fn load(&self, chunk: ChunkId) -> htap::Result<Vec<Value>> {
            Ok(vec![Value::tensor(vec![256], vec![chunk as f32; 256])?])
        }
        fn describe(&self) -> String {
            format!("test tensor source ({} chunks)", self.n)
        }
    }

    // global cap far above everything: only the tenant quota can evict
    let cache = StagingCache::new(Arc::new(TensorSource { n: 32 }), CacheCap::Chunks(64), 0);
    cache.get_for("alice", 0).unwrap();
    let per_chunk = cache.tenant_bytes("alice");
    assert!(per_chunk > 0, "tenant attribution recorded no bytes");
    cache.set_tenant_quota(Some(CacheCap::Bytes(2 * per_chunk)));

    // bob stages a two-chunk working set: exactly at quota, never over
    cache.get_for("bob", 10).unwrap();
    cache.get_for("bob", 11).unwrap();
    let bob_bytes = cache.tenant_bytes("bob");
    assert_eq!(bob_bytes, 2 * per_chunk);

    // alice floods: only her own oldest chunks are evicted
    for c in 1..8 {
        cache.get_for("alice", c).unwrap();
    }
    assert!(
        cache.tenant_bytes("alice") <= 2 * per_chunk,
        "alice over quota: {} > {}",
        cache.tenant_bytes("alice"),
        2 * per_chunk
    );
    assert_eq!(
        cache.tenant_bytes("bob"),
        bob_bytes,
        "alice's flood evicted bob's working set"
    );

    // evicted chunks reload correctly (and re-billing stays fenced)
    let v = cache.get_for("alice", 3).unwrap();
    assert_eq!(v[0].as_tensor().unwrap().data()[0], 3.0);
    assert!(cache.tenant_bytes("alice") <= 2 * per_chunk);
    assert_eq!(cache.tenant_bytes("bob"), bob_bytes);
    cache.shutdown();
}

#[test]
fn cancel_mid_run_stops_assignments_and_frees_the_queue_slot() {
    const N: usize = 8;
    // queue depth 1: one non-terminal job per tenant at a time
    let t = JobTable::new(reg(), N, AssignPolicy::default(), 4, 1);
    let j1 = Endpoint::submit(&*t, "alice", DOUBLE_SUM, 1).unwrap();

    // partially run job 1, holding one assignment in flight
    let req = WorkRequest { capacity: 2, worker: 1, ..Default::default() };
    let batch = Endpoint::request_work(&*t, &req);
    assert_eq!(batch.assignments.len(), 2);
    complete_scalar(&t, &batch.assignments[0], &[(j1, 2.0)]);
    let held = &batch.assignments[1];

    // the admission slot is taken ...
    let err = Endpoint::submit(&*t, "alice", TRIPLE_SUM, 1).unwrap_err();
    assert!(err.to_string().contains("already has"), "unexpected error: {err}");

    // ... until cancel frees it
    Endpoint::cancel_job(&*t, j1).unwrap();
    assert_eq!(Endpoint::job_report(&*t, j1)[0].state, "Cancelled");
    let j2 = Endpoint::submit(&*t, "alice", TRIPLE_SUM, 1).unwrap();

    // the in-flight completion from the cancelled job is dropped, not
    // requeued, and cannot resurrect the job
    Endpoint::complete(&*t, held.instance_id, vec![Value::Scalar(0.0)]);
    assert_eq!(Endpoint::job_report(&*t, j1)[0].state, "Cancelled");

    // the replacement job runs to completion; the cancelled job never
    // hands out another assignment
    drive_all(&t, 1, 3, &[(j2, 3.0)], |a| {
        assert_eq!(job_of(a.instance_id), j2, "cancelled job handed out work");
    });
    assert_eq!(Endpoint::job_report(&*t, j2)[0].state, "Done");
    assert_eq!(
        bits(&t.reduce_outputs(j2, "total").unwrap()),
        vec![expected_total(N, 3.0).to_bits()]
    );

    // double-cancel and unknown ids are clean errors
    assert!(Endpoint::cancel_job(&*t, j1).is_err());
    assert!(Endpoint::cancel_job(&*t, 99).is_err());
}

#[test]
fn service_jobs_run_over_tcp_through_real_workers() {
    const N: usize = 6;
    struct ScalarSource {
        n: usize,
    }
    impl ChunkSource for ScalarSource {
        fn n_chunks(&self) -> usize {
            self.n
        }
        fn load(&self, chunk: ChunkId) -> htap::Result<Vec<Value>> {
            Ok(vec![Value::Scalar(payload(chunk))])
        }
        fn describe(&self) -> String {
            format!("scalar source ({} chunks)", self.n)
        }
    }

    let table = JobTable::new(reg(), N, AssignPolicy::default(), 4, 8);
    let server = ManagerServer::bind("127.0.0.1:0", table.clone()).unwrap();
    let addr = server.local_addr();
    let srv = std::thread::spawn(move || server.serve());

    let j1 = Endpoint::submit(&*table, "alice", DOUBLE_SUM, 1).unwrap();
    let j2 = Endpoint::submit(&*table, "bob", TRIPLE_SUM, 2).unwrap();

    // two real workers: full WRM stack, job resolver fetching specs over
    // the wire, staged chunk payloads billed to the submitting tenant
    let mut workers = Vec::new();
    for i in 0..2u64 {
        let addr = addr.clone();
        workers.push(std::thread::spawn(move || {
            let source = Arc::new(RemoteManager::connect(&addr).unwrap());
            let registry = reg();
            let resolver: JobResolver = {
                let addr = addr.clone();
                Arc::new(move |job| {
                    let (tenant, json) = fetch_job_spec(&addr, job)?;
                    let wf = Arc::new(workflow_from_str(&json, registry.clone())?);
                    Ok((tenant, wf))
                })
            };
            let staging = WorkerStaging {
                cache: StagingCache::new(Arc::new(ScalarSource { n: N }), 8, 0),
                worker_id: i + 1,
                prefetch_budget: 0,
            };
            let cfg = RunConfig {
                n_tiles: N,
                cpu_workers: 1,
                gpu_workers: 0,
                window: 2,
                ..Default::default()
            };
            // the default workflow only serves job 0 (legacy single-job
            // mode); every service assignment resolves through the resolver
            let fallback = Arc::new(workflow_from_str(DOUBLE_SUM, reg()).unwrap());
            run_worker_opts(
                source,
                fallback,
                cfg,
                Arc::new(ArtifactManifest::discover_or_empty()),
                Arc::new(MetricsHub::new()),
                HashMap::new(),
                SharedProfiles::fresh(),
                Some(staging),
                WorkerOpts { resolver: Some(resolver), drain: None },
            )
            .unwrap();
        }));
    }

    table.wait_job(j1);
    table.wait_job(j2);
    for s in Endpoint::job_report(&*table, 0) {
        assert_eq!(s.state, "Done", "job {} ended {}", s.job, s.state);
    }
    // outputs match the chunk-order accumulation regardless of which
    // worker ran which instance in which order
    assert_eq!(
        bits(&table.reduce_outputs(j1, "total").unwrap()),
        vec![expected_total(N, 2.0).to_bits()]
    );
    assert_eq!(
        bits(&table.reduce_outputs(j2, "total").unwrap()),
        vec![expected_total(N, 3.0).to_bits()]
    );

    // shutdown: workers see a non-idle empty batch and exit cleanly
    table.shutdown();
    for w in workers {
        w.join().unwrap();
    }
    srv.join().unwrap().unwrap();
}
