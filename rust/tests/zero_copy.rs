//! The zero-copy datapath end to end: Arc-backed tensor values flowing
//! through the staged WRM dispatch path without payload copies, verified
//! against the serial executor as a concurrency/aliasing oracle.
//!
//! Two properties are pinned here:
//! 1. **No copies**: the tensor buffer an op receives is the *same
//!    allocation* the staging cache holds (pointer-witnessed), and
//!    `Value::clone` shares buffers (see also runtime::tensor unit tests).
//! 2. **No aliasing bugs**: a staged run at high `cpu_workers` — where
//!    many op instances concurrently read the same shared buffers —
//!    produces bit-identical stage outputs to `execute_serial`.

use htap::config::{CacheCap, RunConfig};
use htap::coordinator::wrm::execute_serial;
use htap::coordinator::{run_local_staged, ChunkId, ChunkLoader, Manager, WorkSource};
use htap::data::staging::ChunkSource;
use htap::dataflow::{OpRegistry, StageKind, Workflow, WorkflowBuilder};
use htap::runtime::calibrate::SharedProfiles;
use htap::runtime::{HostTensor, Value};
use htap::Result;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

const SIDE: usize = 16;

/// Chunk `c` loads as a deterministic `SIDE x SIDE` tensor.
struct TensorSource {
    n: usize,
}

fn chunk_tensor(c: ChunkId) -> Value {
    let data: Vec<f32> = (0..SIDE * SIDE)
        .map(|i| c as f32 * 0.5 + (i % 17) as f32 * 0.25 - (i % 5) as f32)
        .collect();
    Value::Tensor(HostTensor::new(vec![SIDE, SIDE], data).unwrap())
}

impl ChunkSource for TensorSource {
    fn n_chunks(&self) -> usize {
        self.n
    }

    fn load(&self, chunk: ChunkId) -> Result<Vec<Value>> {
        if chunk as usize >= self.n {
            return Err(htap::Error::Config(format!("chunk {chunk} out of range")));
        }
        Ok(vec![chunk_tensor(chunk)])
    }

    fn describe(&self) -> String {
        format!("tensor({})", self.n)
    }
}

fn elementwise(
    name: &str,
    f: impl Fn(f32, f32) -> f32 + Send + Sync + 'static,
) -> impl Fn(&[Value]) -> Result<Vec<Value>> + Send + Sync + 'static {
    let name = name.to_string();
    move |args: &[Value]| {
        let a = args[0].as_tensor()?;
        let b = args[1].as_tensor()?;
        if a.shape() != b.shape() {
            return Err(htap::Error::Dataflow(format!("{name}: shape mismatch")));
        }
        let data: Vec<f32> = a.data().iter().zip(b.data()).map(|(&x, &y)| f(x, y)).collect();
        Ok(vec![Value::Tensor(HostTensor::new(a.shape().to_vec(), data)?)])
    }
}

/// A tensor workflow with a diamond inside stage 0 (one producer feeds two
/// consumers — the same shared buffer is read concurrently), a second
/// PerChunk stage re-reading the chunk, and a Reduce total.
fn tensor_workflow() -> Arc<Workflow> {
    let mut reg = OpRegistry::new();
    reg.register_cpu("scale2", 1, |args: &[Value]| {
        let t = args[0].as_tensor()?;
        let data: Vec<f32> = t.data().iter().map(|v| v * 2.0).collect();
        Ok(vec![Value::Tensor(HostTensor::new(t.shape().to_vec(), data)?)])
    })
    .unwrap();
    reg.register_cpu("sub", 1, elementwise("sub", |x, y| x - y)).unwrap();
    reg.register_cpu("mix", 1, elementwise("mix", |x, y| 0.75 * x + 0.25 * y)).unwrap();
    reg.register_cpu("sum_all", 1, |args: &[Value]| {
        let mut s = 0.0f32;
        for v in args {
            match v {
                Value::Tensor(t) => {
                    for &x in t.data() {
                        s += x;
                    }
                }
                Value::Scalar(x) => s += x,
            }
        }
        Ok(vec![Value::Scalar(s)])
    })
    .unwrap();
    let mut wb = WorkflowBuilder::new("zero-copy-oracle", reg);
    let mut s0 = wb.stage("s0", StageKind::PerChunk);
    let c = s0.input_chunk();
    let a = s0.add_op("scale2", &[c]).unwrap();
    let b = s0.add_op("scale2", &[a.out()]).unwrap();
    // diamond: `a` is consumed by both `b` and `d` — shared buffer fan-out
    let d = s0.add_op("sub", &[b.out(), a.out()]).unwrap();
    s0.export(d.out()).unwrap();
    s0.export(a.out()).unwrap();
    let s0 = wb.add_stage(s0).unwrap();
    let mut s1 = wb.stage("s1", StageKind::PerChunk);
    let c = s1.input_chunk();
    let up0 = s1.input_upstream(s0.output(0));
    let up1 = s1.input_upstream(s0.output(1));
    let e = s1.add_op("mix", &[c, up0]).unwrap();
    let g = s1.add_op("sub", &[e.out(), up1]).unwrap();
    s1.export(g.out()).unwrap();
    let s1 = wb.add_stage(s1).unwrap();
    let mut red = wb.stage("total", StageKind::Reduce);
    red.input_upstream(s1.output(0));
    let t = red.add_reduce_op("sum_all").unwrap();
    red.export(t.out()).unwrap();
    wb.add_stage(red).unwrap();
    Arc::new(wb.build().unwrap())
}

/// Drive a legacy (payload-shipping) Manager to completion on this thread,
/// executing every assignment with the serial oracle executor.
fn drive_with_serial_oracle(workflow: &Arc<Workflow>, mgr: &Arc<Manager>) {
    loop {
        let batch = mgr.request(4);
        if batch.is_empty() {
            return;
        }
        for a in batch {
            let outs = execute_serial(workflow, &a).unwrap();
            mgr.complete(a.instance_id, outs);
        }
    }
}

#[test]
fn staged_concurrent_run_matches_serial_oracle_bitwise() {
    let n = 32;
    let workflow = tensor_workflow();

    // oracle: every stage instance through execute_serial, one thread
    let loader: ChunkLoader = Arc::new(|c| Ok(vec![chunk_tensor(c)]));
    let serial_mgr = Manager::new(workflow.clone(), loader, n).unwrap();
    drive_with_serial_oracle(&workflow, &serial_mgr);
    let want = serial_mgr.reduce_outputs("total").unwrap();

    // staged run at high cpu_workers, with a tight cache so shared
    // payloads also churn through evict/reload while instances read them
    let cfg = RunConfig {
        n_tiles: n,
        cpu_workers: 8,
        gpu_workers: 0,
        window: 8,
        staging_cap: CacheCap::Chunks(4),
        prefetch_depth: 2,
        ..Default::default()
    };
    let outcome = run_local_staged(
        workflow.clone(),
        Arc::new(TensorSource { n }),
        n,
        cfg,
        HashMap::new(),
        SharedProfiles::fresh(),
    )
    .unwrap();
    let got = outcome.manager.reduce_outputs("total").unwrap();

    assert_eq!(want.len(), got.len());
    for (w, g) in want.iter().zip(&got) {
        assert_eq!(
            w.as_scalar().unwrap().to_bits(),
            g.as_scalar().unwrap().to_bits(),
            "staged concurrent outputs must be byte-identical to execute_serial"
        );
    }
}

#[test]
fn dispatched_ops_see_the_cache_buffer_not_a_copy() {
    // every probe op logs (chunk tag, buffer address); both stages of a
    // chunk must observe the SAME allocation — the staging cache's — or a
    // copy crept back into the datapath
    let n = 4;
    let log: Arc<Mutex<Vec<(u32, usize)>>> = Arc::new(Mutex::new(Vec::new()));
    let mut reg = OpRegistry::new();
    {
        let log = log.clone();
        reg.register_cpu("probe", 1, move |args: &[Value]| {
            let t = args[0].as_tensor()?;
            log.lock().unwrap().push((t.data()[0] as u32, t.data().as_ptr() as usize));
            Ok(vec![Value::Scalar(t.data()[0])])
        })
        .unwrap();
    }
    reg.register_cpu("sum_all", 1, |args: &[Value]| {
        let mut s = 0.0;
        for v in args {
            s += v.as_scalar()?;
        }
        Ok(vec![Value::Scalar(s)])
    })
    .unwrap();
    let mut wb = WorkflowBuilder::new("probe", reg);
    let mut s0 = wb.stage("s0", StageKind::PerChunk);
    let c = s0.input_chunk();
    let p = s0.add_op("probe", &[c]).unwrap();
    s0.export(p.out()).unwrap();
    let s0 = wb.add_stage(s0).unwrap();
    let mut s1 = wb.stage("s1", StageKind::PerChunk);
    let c = s1.input_chunk();
    let up = s1.input_upstream(s0.output(0));
    let p = s1.add_op("probe", &[c]).unwrap();
    let q = s1.add_op("sum_all", &[p.out(), up]).unwrap();
    s1.export(q.out()).unwrap();
    let s1 = wb.add_stage(s1).unwrap();
    let mut red = wb.stage("total", StageKind::Reduce);
    red.input_upstream(s1.output(0));
    let t = red.add_reduce_op("sum_all").unwrap();
    red.export(t.out()).unwrap();
    wb.add_stage(red).unwrap();
    let workflow = Arc::new(wb.build().unwrap());

    /// Chunk `c` loads as a tensor filled with the constant `c` (the tag
    /// the probe reads back).
    struct TaggedSource {
        n: usize,
    }
    impl ChunkSource for TaggedSource {
        fn n_chunks(&self) -> usize {
            self.n
        }
        fn load(&self, chunk: ChunkId) -> Result<Vec<Value>> {
            Ok(vec![Value::Tensor(
                HostTensor::new(vec![SIDE, SIDE], vec![chunk as f32; SIDE * SIDE]).unwrap(),
            )])
        }
        fn describe(&self) -> String {
            "tagged".into()
        }
    }

    // cache big enough that no chunk is evicted and re-read mid-run
    let cfg = RunConfig {
        n_tiles: n,
        cpu_workers: 4,
        gpu_workers: 0,
        window: 4,
        staging_cap: CacheCap::Chunks(64),
        prefetch_depth: 0,
        ..Default::default()
    };
    run_local_staged(
        workflow,
        Arc::new(TaggedSource { n }),
        n,
        cfg,
        HashMap::new(),
        SharedProfiles::fresh(),
    )
    .unwrap();

    let log = log.lock().unwrap();
    let mut by_chunk: HashMap<u32, Vec<usize>> = HashMap::new();
    for &(tag, ptr) in log.iter() {
        by_chunk.entry(tag).or_default().push(ptr);
    }
    assert_eq!(by_chunk.len(), n, "every chunk must be probed");
    for (tag, ptrs) in by_chunk {
        assert_eq!(ptrs.len(), 2, "chunk {tag} probed by both stages");
        assert_eq!(
            ptrs[0], ptrs[1],
            "chunk {tag}: the two stages saw different buffers — a copy crept into the datapath"
        );
    }
}
