//! Distributed mode: the demand-driven window protocol over real sockets —
//! one Manager serving multiple Workers, each running the full WRM with
//! CPU + PJRT device threads.

use htap::app::{build_workflow, stage_bindings, AppParams};
use htap::config::RunConfig;
use htap::coordinator::{
    worker::{run_worker, run_worker_staged},
    AssignPolicy, Manager, WorkSource, WorkerStaging,
};
use htap::data::staging::SpillTier;
use htap::data::{StagingCache, SynthConfig, SynthSource, TileStore};
use htap::metrics::MetricsHub;
use htap::net::{ManagerServer, RemoteManager};
use htap::runtime::calibrate::SharedProfiles;
use htap::runtime::ArtifactManifest;
use std::sync::Arc;
use std::time::Duration;

const TILE: usize = 64;

#[test]
fn two_tcp_workers_complete_the_workflow() {
    let n_tiles = 6;
    let params = AppParams::for_tile_size(TILE);
    let workflow = Arc::new(build_workflow(&params, false));
    let store = Arc::new(TileStore::new(SynthConfig::for_tile_size(TILE, 31), n_tiles));
    let manager = Manager::new(workflow.clone(), store.loader(), n_tiles).unwrap();
    let server = ManagerServer::bind("127.0.0.1:0", manager.clone()).unwrap();
    let addr = server.local_addr();
    let srv = std::thread::spawn(move || server.serve());

    let mut workers = Vec::new();
    for i in 0..2 {
        let addr = addr.clone();
        let workflow = workflow.clone();
        workers.push(std::thread::spawn(move || {
            let source = Arc::new(RemoteManager::connect(&addr).unwrap());
            let metrics = Arc::new(MetricsHub::new());
            let cfg = RunConfig {
                tile_size: TILE,
                n_tiles,
                cpu_workers: 1,
                gpu_workers: i, // worker 0 cpu-only, worker 1 hybrid
                window: 2,
                ..Default::default()
            };
            run_worker(
                source,
                workflow,
                cfg,
                Arc::new(ArtifactManifest::discover_or_empty()),
                metrics.clone(),
                stage_bindings(),
            )
            .unwrap();
            metrics.report().total_executed()
        }));
    }
    let executed: Vec<u64> = workers.into_iter().map(|w| w.join().unwrap()).collect();
    srv.join().unwrap().unwrap();

    assert!(manager.error().is_none(), "{:?}", manager.error());
    let (done, total) = manager.progress();
    assert_eq!(done, total);
    assert_eq!(total, 2 * n_tiles);
    // all fine-grain ops ran somewhere: 9 seg + 3 feat ops per tile
    assert_eq!(executed.iter().sum::<u64>(), (12 * n_tiles) as u64);
    // both workers actually participated (demand-driven balance)
    assert!(executed.iter().all(|&e| e > 0), "a worker starved: {executed:?}");
}

#[test]
fn tensor_payloads_survive_the_wire() {
    // large tile tensors must round-trip through the binary framing
    let n_tiles = 2;
    let params = AppParams::for_tile_size(TILE);
    let workflow = Arc::new(build_workflow(&params, false));
    let store = Arc::new(TileStore::new(SynthConfig::for_tile_size(TILE, 77), n_tiles));
    let manager = Manager::new(workflow.clone(), store.clone().loader(), n_tiles).unwrap();
    let server = ManagerServer::bind("127.0.0.1:0", manager.clone()).unwrap();
    let addr = server.local_addr();
    let srv = std::thread::spawn(move || server.serve());

    let remote = RemoteManager::connect(&addr).unwrap();
    let mut seen_tiles = 0;
    loop {
        let batch = remote.request(4);
        if batch.is_empty() {
            break;
        }
        for a in batch {
            if a.stage_idx == 0 {
                // verify the tile arrived intact
                let got = a.inputs[0].as_tensor().unwrap();
                let want = store.tile(a.chunk).to_tensor();
                assert_eq!(got, &want, "tile {} corrupted in transit", a.chunk);
                seen_tiles += 1;
            }
            let outs =
                htap::dataflow::run_stage_serial(&workflow.stages[a.stage_idx], &a.inputs)
                    .unwrap();
            remote.complete(a.instance_id, outs);
        }
    }
    drop(remote);
    srv.join().unwrap().unwrap();
    assert_eq!(seen_tiles, n_tiles);
    assert!(manager.error().is_none());
}

#[test]
fn staged_tcp_workers_never_ship_tiles_and_hit_locality() {
    // staged protocol: the manager hands out bare chunk ids; each worker
    // stages tiles from its own (identical) synthetic source through a
    // prefetching cache — worker 2 through a deliberately tiny memory
    // tier backed by a local-disk spill tier — and the catalog routes
    // repeat stages back to the worker that staged the tile.
    let n_tiles = 8;
    let seed = 31;
    let params = AppParams::for_tile_size(TILE);
    let workflow = Arc::new(build_workflow(&params, false));
    let manager = Manager::new_staged(workflow.clone(), n_tiles, AssignPolicy::default()).unwrap();
    let server = ManagerServer::bind("127.0.0.1:0", manager.clone()).unwrap();
    let addr = server.local_addr();
    let srv = std::thread::spawn(move || server.serve());

    let spill_root = std::env::temp_dir()
        .join(format!("htap-tcp-spill-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&spill_root);
    let mut workers = Vec::new();
    for i in 0..2u64 {
        let addr = addr.clone();
        let workflow = workflow.clone();
        let spill_root = spill_root.clone();
        workers.push(std::thread::spawn(move || {
            let source = Arc::new(RemoteManager::connect(&addr).unwrap());
            // every worker reconstructs the same dataset locally (the
            // shared-FS stand-in) with a visible read latency
            let chunks = Arc::new(
                SynthSource::new(SynthConfig::for_tile_size(TILE, seed), n_tiles)
                    .with_read_latency(Duration::from_millis(3)),
            );
            let (cap, spill) = if i == 1 {
                let tier = SpillTier::create(spill_root.join("worker-2"), 32).unwrap();
                (1, Some(tier))
            } else {
                (16, None)
            };
            let staging = WorkerStaging {
                cache: StagingCache::new_tiered(chunks, cap, 2, spill),
                worker_id: i + 1,
                prefetch_budget: 2,
            };
            let metrics = Arc::new(MetricsHub::new());
            let cfg = RunConfig {
                tile_size: TILE,
                n_tiles,
                cpu_workers: 1,
                gpu_workers: 0,
                window: 2,
                ..Default::default()
            };
            run_worker_staged(
                source,
                workflow,
                cfg,
                Arc::new(ArtifactManifest::discover_or_empty()),
                metrics.clone(),
                stage_bindings(),
                SharedProfiles::fresh(),
                Some(staging),
            )
            .unwrap();
            metrics.report()
        }));
    }
    let reports: Vec<_> = workers.into_iter().map(|w| w.join().unwrap()).collect();
    srv.join().unwrap().unwrap();

    assert!(manager.error().is_none(), "{:?}", manager.error());
    let (done, total) = manager.progress();
    assert_eq!(done, total);
    assert_eq!(total, 2 * n_tiles);
    // every op instance ran somewhere
    let executed: u64 = reports.iter().map(|r| r.total_executed()).sum();
    assert_eq!(executed, (12 * n_tiles) as u64);
    // every (stage, tile) fetch was staged worker-side, none shipped
    let fetches: u64 = reports.iter().map(|r| r.staging.hits + r.staging.misses).sum();
    assert_eq!(fetches, (2 * n_tiles) as u64);
    // the catalog policy routed repeat stages to the staging worker, and
    // every chunk-bearing assignment is accounted hit, cold or stolen
    let (hits, cold, steals) = manager.locality_stats();
    assert!(hits > 0, "no locality hits across {n_tiles} tiles");
    assert_eq!(hits + cold + steals, (2 * n_tiles) as u64);
    // worker 2's one-chunk memory tier must have demoted to its spill dir
    // (it processes > 1 chunk); demotions travel the v3 wire fields
    let spilled: u64 = reports.iter().map(|r| r.staging.spill_evicted).sum();
    assert!(spilled > 0, "the spill-enabled worker never demoted");
    let _ = std::fs::remove_dir_all(&spill_root);
}

#[test]
fn dead_worker_leases_are_reissued() {
    // A worker takes assignments, then vanishes without completing them; a
    // healthy worker must still finish the whole workflow.
    let n_tiles = 5;
    let params = AppParams::for_tile_size(TILE);
    let workflow = Arc::new(build_workflow(&params, false));
    let store = Arc::new(TileStore::new(SynthConfig::for_tile_size(TILE, 13), n_tiles));
    let manager = Manager::new(workflow.clone(), store.loader(), n_tiles).unwrap();
    let server = ManagerServer::bind("127.0.0.1:0", manager.clone()).unwrap();
    let addr = server.local_addr();
    let srv = std::thread::spawn(move || server.serve());

    // the dying worker: grab 3 leases on its work channel, open the
    // completion channel too (so the server's accept count lines up), die.
    {
        let victim = RemoteManager::connect(&addr).unwrap();
        let batch = victim.request(3);
        assert!(!batch.is_empty());
        // drops both sockets here without completing anything
    }

    // a healthy worker finishes everything, including the re-issued leases
    let workflow2 = workflow.clone();
    let addr2 = addr.clone();
    let healthy = std::thread::spawn(move || {
        let source = Arc::new(RemoteManager::connect(&addr2).unwrap());
        run_worker(
            source,
            workflow2,
            RunConfig {
                tile_size: TILE,
                n_tiles,
                cpu_workers: 2,
                gpu_workers: 0,
                window: 3,
                ..Default::default()
            },
            Arc::new(ArtifactManifest::discover_or_empty()),
            Arc::new(MetricsHub::new()),
            stage_bindings(),
        )
        .unwrap();
    });
    healthy.join().unwrap();
    srv.join().unwrap().unwrap();
    assert!(manager.error().is_none(), "{:?}", manager.error());
    let (done, total) = manager.progress();
    assert_eq!(done, total);
    assert_eq!(total, 2 * n_tiles);
}
