//! Integration coverage for the typed WorkflowBuilder + OpRegistry API and
//! the declarative JSON workflow loader:
//!
//! * a second, non-WSI workflow (generic convolve→threshold→label→stats)
//!   runs end-to-end through `run_local` from its JSON description;
//! * wiring mistakes are rejected eagerly, with both stage kinds
//!   bounds-checked;
//! * JSON descriptions round-trip.

use htap::app::generic::{cell_stats_workflow, generic_registry, CELL_STATS_JSON};
use htap::config::RunConfig;
use htap::coordinator::run_local;
use htap::data::{SynthConfig, TileStore};
use htap::dataflow::{
    param, workflow_from_str, workflow_to_json, PortSpec, StageKind, WorkflowBuilder,
};
use std::collections::HashMap;
use std::sync::Arc;

#[test]
fn generic_json_workflow_runs_end_to_end() {
    let n_tiles = 5;
    let tile_size = 64;
    let wf = Arc::new(cell_stats_workflow().unwrap());
    assert_eq!(wf.name, "cell-stats");
    let store = Arc::new(TileStore::new(SynthConfig::for_tile_size(tile_size, 3), n_tiles));
    let cfg = RunConfig {
        tile_size,
        n_tiles,
        cpu_workers: 2,
        gpu_workers: 0,
        ..Default::default()
    };
    let outcome = run_local(wf, store.loader(), n_tiles, cfg, HashMap::new()).unwrap();
    let (done, total) = outcome.manager.progress();
    assert_eq!(done, total);
    // n per-chunk detect instances + 1 reduce instance
    assert_eq!(total, n_tiles + 1);
    let agg = outcome.manager.reduce_outputs("aggregate").expect("aggregate output");
    let stats = agg[0].as_tensor().unwrap();
    assert_eq!(stats.shape(), &[4]);
    assert!(stats.data()[0] >= 1.0, "mean region count >= 1, got {}", stats.data()[0]);
    assert!(stats.data()[3] > 0.0 && stats.data()[3] < 1.0, "coverage in (0,1)");
}

#[test]
fn generic_workflow_survives_hybrid_device_mix() {
    // All generic ops are CPU-only; a worker with an accelerator thread
    // must still complete (the GPU controller simply finds no eligible
    // tasks, or falls back to CPU members).
    let n_tiles = 3;
    let wf = Arc::new(cell_stats_workflow().unwrap());
    let store = Arc::new(TileStore::new(SynthConfig::for_tile_size(64, 5), n_tiles));
    let cfg = RunConfig { tile_size: 64, n_tiles, cpu_workers: 1, gpu_workers: 1, ..Default::default() };
    let outcome = run_local(wf, store.loader(), n_tiles, cfg, HashMap::new()).unwrap();
    let (done, total) = outcome.manager.progress();
    assert_eq!(done, total);
    assert!(outcome.manager.reduce_outputs("aggregate").is_some());
}

#[test]
fn cell_stats_example_file_matches_embedded_constant() {
    // examples/cell_stats.json (CI's smoke-test workflow) must stay in
    // sync with the CELL_STATS_JSON constant the tests and the
    // generic_pipeline example load — compare semantically so whitespace
    // differs but the workflow cannot.
    let file = include_str!("../../examples/cell_stats.json");
    let a = htap::config::json::Json::parse(file).unwrap();
    let b = htap::config::json::Json::parse(CELL_STATS_JSON).unwrap();
    assert_eq!(
        a, b,
        "examples/cell_stats.json drifted from app::generic::CELL_STATS_JSON"
    );
}

#[test]
fn json_round_trip_preserves_structure_and_behaviour() {
    let reg = Arc::new(generic_registry());
    let wf = workflow_from_str(CELL_STATS_JSON, reg.clone()).unwrap();
    let j = workflow_to_json(&wf).unwrap();
    let wf2 = workflow_from_str(&j.to_string(), reg).unwrap();
    let j2 = workflow_to_json(&wf2).unwrap();
    assert_eq!(j.to_string(), j2.to_string(), "serialise(load(x)) must be a fixpoint");
    assert_eq!(wf2.stages.len(), wf.stages.len());
    assert_eq!(wf2.total_ops(), wf.total_ops());
    // behavioural equivalence on one chunk
    let store = TileStore::new(SynthConfig::for_tile_size(64, 11), 1);
    let tile = htap::runtime::Value::Tensor(store.tile(0).to_tensor());
    let a = htap::dataflow::run_stage_serial(&wf.stages[0], &[tile.clone()]).unwrap();
    let b = htap::dataflow::run_stage_serial(&wf2.stages[0], &[tile]).unwrap();
    assert_eq!(a, b);
}

#[test]
fn wsi_registry_builds_custom_workflows() {
    // The WSI ops compose into new pipelines too: a minimal two-op
    // segmentation front-end, assembled from the same registry as the
    // full app.
    let reg = Arc::new(htap::app::registry());
    let mut wb = WorkflowBuilder::with_shared_registry("mini", reg);
    let mut s = wb.stage("front", StageKind::PerChunk);
    let rgb = s.input_chunk();
    let hema = s.add_op("hema_prep", &[rgb]).unwrap();
    let opened = s.add_op("morph_open", &[hema.out()]).unwrap();
    s.export(opened.out()).unwrap();
    wb.add_stage(s).unwrap();
    let wf = wb.build().unwrap();
    assert_eq!(wf.total_ops(), 2);
    // profile flowed in from the registry
    assert_eq!(
        wf.stages[0].ops[1].speedup,
        htap::app::profile::speedup_of("morph_open")
    );
}

#[test]
fn eager_validation_rejects_wiring_mistakes() {
    let reg = Arc::new(htap::app::registry());
    let wb = WorkflowBuilder::with_shared_registry("bad", reg);
    let mut s = wb.stage("seg", StageKind::PerChunk);
    let rgb = s.input_chunk();
    // unknown registry op
    assert!(s.add_op("sharpen", &[rgb.clone()]).is_err());
    // reference to an op that doesn't exist yet (forward-only by handles;
    // raw indices are bounds-checked)
    assert!(s
        .add_op("morph_open", &[PortSpec::Output { op: 4, output: 0 }])
        .is_err());
    // out-of-range stage input on a PerChunk stage
    assert!(s.add_op("morph_open", &[PortSpec::Input(7)]).is_err());
    // duplicate instance name
    let h = s.add_op("hema_prep", &[rgb.clone()]).unwrap();
    assert!(s.add_op("hema_prep", &[rgb]).is_err());
    // out-of-range output of a real handle
    assert!(s.export(h.output(1)).is_err());
}

#[test]
fn reduce_stages_are_bounds_checked_too() {
    // The historical foot-gun: StageInput bounds were only checked for
    // PerChunk stages.  Both the builder and Workflow::validate now check
    // Reduce stages as well.
    let reg = Arc::new(generic_registry());
    let mut wb = WorkflowBuilder::with_shared_registry("t", reg);
    let mut d = wb.stage("detect", StageKind::PerChunk);
    let c = d.input_chunk();
    let g = d.add_op("grayscale", &[c]).unwrap();
    let r = d.add_op("region_stats", &[g.out()]).unwrap();
    d.export(r.out()).unwrap();
    let d = wb.add_stage(d).unwrap();

    let mut red = wb.stage("agg", StageKind::Reduce);
    red.input_upstream(d.output(0));
    // an explicit out-of-range stage input inside a Reduce stage is an
    // immediate error (not a deferred runtime failure)
    assert!(red.add_op("mean_stats", &[PortSpec::Input(5)]).is_err());
    // in-range explicit input is fine
    let m = red.add_op("mean_stats", &[PortSpec::Input(0)]).unwrap();
    red.export(m.out()).unwrap();
    wb.add_stage(red).unwrap();
    wb.build().unwrap();
}

#[test]
fn cross_builder_stage_handles_cannot_forward_reference() {
    let reg = Arc::new(generic_registry());
    // build a two-stage workflow and keep the *second* stage's handle
    let mut wb1 = WorkflowBuilder::with_shared_registry("w1", reg.clone());
    let mut a = wb1.stage("a", StageKind::PerChunk);
    let c = a.input_chunk();
    let g = a.add_op("grayscale", &[c]).unwrap();
    a.export(g.out()).unwrap();
    let a = wb1.add_stage(a).unwrap();
    let mut b = wb1.stage("b", StageKind::PerChunk);
    let inp = b.input_upstream(a.output(0));
    let i = b.add_op("invert", &[inp]).unwrap();
    b.export(i.out()).unwrap();
    let b_handle = wb1.add_stage(b).unwrap();

    // a fresh builder has no stage 1 yet: the stolen handle is rejected
    let mut wb2 = WorkflowBuilder::with_shared_registry("w2", reg);
    let mut s = wb2.stage("s", StageKind::PerChunk);
    let inp = s.input_upstream(b_handle.output(0));
    let op = s.add_op("grayscale", &[inp]).unwrap();
    s.export(op.out()).unwrap();
    assert!(wb2.add_stage(s).is_err());
}

#[test]
fn scalar_params_wire_through_json_and_builder_identically() {
    let reg = Arc::new(generic_registry());
    // builder version of the detect stage's binarize threshold
    let mut wb = WorkflowBuilder::with_shared_registry("p", reg.clone());
    let mut s = wb.stage("detect", StageKind::PerChunk);
    let c = s.input_chunk();
    let g = s.add_op("grayscale", &[c]).unwrap();
    let inv = s.add_op("invert", &[g.out()]).unwrap();
    let sm = s.add_op("gauss3", &[inv.out()]).unwrap();
    let bin = s.add_op("binarize", &[sm.out(), param(140.0)]).unwrap();
    let lab = s.add_op("cc_label", &[bin.out()]).unwrap();
    let st = s.add_op("region_stats", &[lab.out()]).unwrap();
    s.export(lab.out()).unwrap();
    s.export(st.out()).unwrap();
    wb.add_stage(s).unwrap();
    let built = wb.build().unwrap();

    let loaded = workflow_from_str(CELL_STATS_JSON, reg).unwrap();
    let store = TileStore::new(SynthConfig::for_tile_size(64, 2), 1);
    let tile = htap::runtime::Value::Tensor(store.tile(0).to_tensor());
    let a = htap::dataflow::run_stage_serial(&built.stages[0], &[tile.clone()]).unwrap();
    let b = htap::dataflow::run_stage_serial(&loaded.stages[0], &[tile]).unwrap();
    assert_eq!(a, b, "builder and JSON descriptions define the same computation");
}
