//! End-to-end coordinator tests: Manager + Worker + WRM + device threads
//! executing the real WSI workflow on synthetic tiles, with the accelerator
//! variants running through PJRT.
//!
//! These are the paper's execution modes in miniature: pipelined vs
//! monolithic, FCFS vs PATS, CPU-only vs hybrid, with and without DL.

use htap::app::{build_monolithic, build_workflow, stage_bindings, AppParams};
use htap::config::{Granularity, Placement, Policy, RunConfig};
use htap::coordinator::{run_local, Manager};
use htap::data::{SynthConfig, TileStore};
use htap::dataflow::run_stage_serial;
use htap::imgproc::label::canonical_labels;
use htap::imgproc::Gray;
use htap::runtime::Value;
use std::sync::Arc;

const TILE: usize = 64;
const N_TILES: usize = 6;

fn cfg(policy: Policy, cpu: usize, gpu: usize) -> RunConfig {
    RunConfig {
        tile_size: TILE,
        n_tiles: N_TILES,
        cpu_workers: cpu,
        gpu_workers: gpu,
        policy,
        placement: Placement::Closest,
        granularity: Granularity::Pipelined,
        window: 4,
        data_locality: true,
        prefetch: true,
        seed: 7,
    }
}

fn store() -> Arc<TileStore> {
    Arc::new(TileStore::new(SynthConfig::for_tile_size(TILE, 99), N_TILES))
}

#[test]
fn cpu_only_parallel_matches_serial_oracle() {
    let params = AppParams::for_tile_size(TILE);
    let wf = Arc::new(build_workflow(&params, false));
    let outcome = run_local(
        wf,
        store().loader(),
        N_TILES,
        cfg(Policy::Fcfs, 3, 0),
        stage_bindings(),
    )
    .unwrap();
    // all instances executed: 2 stages x N_TILES
    let (done, total) = outcome.manager.progress();
    assert_eq!(done, total);
    assert_eq!(total, 2 * N_TILES);
    // profile shows every op ran N_TILES times, all on CPU
    let report = outcome.metrics;
    for op in ["recon_to_nuclei", "watershed", "feature_graph", "haralick"] {
        let p = report.op(op).unwrap_or_else(|| panic!("no metrics for {op}"));
        assert_eq!(p.cpu_count + p.gpu_count, N_TILES as u64, "{op}");
        assert_eq!(p.gpu_count, 0, "{op} must stay on CPU in cpu-only mode");
    }
}

#[test]
fn hybrid_pats_execution_completes_and_uses_gpu() {
    let params = AppParams::for_tile_size(TILE);
    let wf = Arc::new(build_workflow(&params, false));
    let outcome = run_local(
        wf,
        store().loader(),
        N_TILES,
        cfg(Policy::Pats, 2, 1),
        stage_bindings(),
    )
    .unwrap();
    let report = outcome.metrics;
    let total: u64 = report.ops.iter().map(|o| o.cpu_count + o.gpu_count).sum();
    assert_eq!(total, (9 + 3) * N_TILES as u64);
    // the GPU must have done something, and feature_graph (highest speedup)
    // should be GPU-heavy under PATS
    let gpu_total: u64 = report.ops.iter().map(|o| o.gpu_count).sum();
    assert!(gpu_total > 0, "accelerator never used");
    let fg = report.op("feature_graph").unwrap();
    let mo = report.op("morph_open").unwrap();
    assert!(
        fg.gpu_fraction() >= mo.gpu_fraction(),
        "PATS should bias high-speedup ops to GPU: fg={} mo={}",
        fg.gpu_fraction(),
        mo.gpu_fraction()
    );
    // CPU-only ops never ran on the accelerator
    assert_eq!(report.op("object_features").unwrap().gpu_count, 0);
}

#[test]
fn classification_reduce_stage_assigns_every_tile() {
    let params = AppParams::for_tile_size(TILE);
    let wf = Arc::new(build_workflow(&params, true));
    let outcome = run_local(
        wf,
        store().loader(),
        N_TILES,
        cfg(Policy::Pats, 2, 1),
        stage_bindings(),
    )
    .unwrap();
    let cls = outcome.manager.reduce_outputs("classification").expect("classification output");
    let assign = cls[0].as_tensor().unwrap();
    assert_eq!(assign.shape(), &[N_TILES]);
    assert!(assign.data().iter().all(|&a| a >= 0.0 && a < 3.0));
}

#[test]
fn fcfs_and_pats_complete_without_errors() {
    let params = AppParams::for_tile_size(TILE);
    let store = store();
    for policy in [Policy::Fcfs, Policy::Pats] {
        let wf = Arc::new(build_workflow(&params, false));
        let manager = Manager::new(wf.clone(), store.clone().loader(), 2).unwrap();
        htap::coordinator::worker::run_worker(
            manager.clone(),
            wf,
            cfg(policy, 2, 0),
            Arc::new(htap::runtime::ArtifactManifest::discover_or_empty()),
            Arc::new(htap::metrics::MetricsHub::new()),
            stage_bindings(),
        )
        .unwrap();
        assert!(manager.error().is_none());
        let (done, total) = manager.progress();
        assert_eq!(done, total);
    }
}

#[test]
fn monolithic_workflow_runs_hybrid() {
    let params = AppParams::for_tile_size(TILE);
    let wf = Arc::new(build_monolithic(&params, false));
    let outcome = run_local(
        wf,
        store().loader(),
        N_TILES,
        RunConfig { granularity: Granularity::NonPipelined, ..cfg(Policy::Pats, 2, 1) },
        stage_bindings(),
    )
    .unwrap();
    let report = outcome.metrics;
    // exactly two monolithic ops per tile
    assert_eq!(report.total_executed(), 2 * N_TILES as u64);
}

#[test]
fn pipelined_and_monolithic_segmentations_agree_serially() {
    // canonical-label equivalence between the two granularities (CPU path)
    let params = AppParams::for_tile_size(TILE);
    let pipe = build_workflow(&params, false);
    let mono = build_monolithic(&params, false);
    let store = store();
    for c in 0..2u64 {
        let tile = Value::Tensor(store.tile(c).to_tensor());
        let a = run_stage_serial(&pipe.stages[0], &[tile.clone()]).unwrap();
        let b = run_stage_serial(&mono.stages[0], &[tile]).unwrap();
        let la = canonical_labels(&Gray::from_tensor(a[0].as_tensor().unwrap()).unwrap());
        let lb = canonical_labels(&Gray::from_tensor(b[0].as_tensor().unwrap()).unwrap());
        assert_eq!(la.px, lb.px, "tile {c} segmentation differs");
    }
}

#[test]
fn window_one_still_completes() {
    let params = AppParams::for_tile_size(TILE);
    let wf = Arc::new(build_workflow(&params, false));
    let mut c = cfg(Policy::Pats, 1, 1);
    c.window = 1;
    c.prefetch = false;
    let outcome = run_local(wf, store().loader(), 3, c, stage_bindings()).unwrap();
    let (done, total) = outcome.manager.progress();
    assert_eq!(done, total);
}

#[test]
fn data_locality_reduces_uploads() {
    // With DL on, chained GPU ops reuse resident data: upload bytes for the
    // whole run must be strictly lower than with DL off.  Requires real
    // accelerator execution: built artifacts AND a PJRT backend that can
    // compile them (not the offline xla shim).
    let can_execute = htap::runtime::ArtifactManifest::discover()
        .ok()
        .filter(|m| m.has("fill_holes", TILE))
        .and_then(|m| htap::runtime::pjrt::DeviceExecutor::new(m).ok())
        .map(|mut ex| {
            let z = Value::Tensor(htap::runtime::HostTensor::zeros(vec![TILE, TILE]));
            ex.run("fill_holes", TILE, &[z]).is_ok()
        })
        .unwrap_or(false);
    if !can_execute {
        eprintln!(
            "skipping data_locality_reduces_uploads: artifacts not built or not executable \
             (run `make artifacts` with a real xla backend)"
        );
        return;
    }
    let params = AppParams::for_tile_size(TILE);
    let mut with_dl = 0u64;
    let mut without_dl = 0u64;
    for (dl, acc) in [(true, &mut with_dl), (false, &mut without_dl)] {
        let wf = Arc::new(build_workflow(&params, false));
        let mut c = cfg(Policy::Pats, 0, 1); // GPU-only: forces chains
        c.data_locality = dl;
        let outcome = run_local(wf, store().loader(), 2, c, stage_bindings()).unwrap();
        *acc = outcome
            .metrics
            .ops
            .iter()
            .map(|o| o.upload_bytes)
            .sum::<u64>();
    }
    assert!(
        with_dl < without_dl,
        "DL should cut uploads: {with_dl} vs {without_dl}"
    );
}
