//! Chaos integration: seeded fault plans over real sockets.
//!
//! Every test follows the same shape — run the WSI workflow clean, run it
//! again under an active fault plan, and assert the chaotic run completes
//! with *bit-identical* reduce outputs while the injection counters show
//! the faults actually fired.  Robustness that only works when nothing
//! goes wrong is not robustness.

use htap::app::{build_workflow, stage_bindings, AppParams};
use htap::config::RunConfig;
use htap::coordinator::{
    checkpoint, worker::run_worker_staged, AssignPolicy, Manager, WorkRequest, WorkSource,
    WorkerStaging,
};
use htap::data::staging::{ChunkSource, FaultySource, SpillTier};
use htap::data::{StagingCache, SynthConfig, SynthSource};
use htap::faults::{FaultPlan, Faults, Site};
use htap::metrics::{MetricsHub, MetricsReport};
use htap::net::{ManagerServer, RemoteManager, RetryPolicy};
use htap::obs::{Registry, Tracer};
use htap::runtime::calibrate::SharedProfiles;
use htap::runtime::{ArtifactManifest, Value};
use std::sync::Arc;

const TILE: usize = 64;
const SEED: u64 = 31;

fn worker_cfg(n_tiles: usize) -> RunConfig {
    RunConfig {
        tile_size: TILE,
        n_tiles,
        cpu_workers: 1,
        gpu_workers: 0,
        window: 2,
        // fast heartbeat: a completion swallowed by a torn-down socket is
        // replayed at the next heartbeat-driven reconnect, so chaos tests
        // recover in tenths of seconds instead of lease terms
        heartbeat_ms: 100,
        lease_ms: 1000,
        ..Default::default()
    }
}

/// Run one full staged TCP worker against `addrs` with `faults` armed on
/// its RPC layer (and optionally on a spill tier), returning its report.
#[allow(clippy::too_many_arguments)]
fn run_chaos_worker(
    addrs: Vec<String>,
    workflow: Arc<htap::dataflow::Workflow>,
    n_tiles: usize,
    worker_id: u64,
    faults: Faults,
    registry: Arc<Registry>,
    spill: Option<SpillTier>,
    cap: usize,
) -> MetricsReport {
    let source = Arc::new(
        RemoteManager::connect_opts(
            &addrs,
            &registry,
            Tracer::disabled(),
            faults,
            RetryPolicy::reconnect(),
        )
        .unwrap(),
    );
    let chunks = Arc::new(SynthSource::new(SynthConfig::for_tile_size(TILE, SEED), n_tiles));
    let staging = WorkerStaging {
        cache: StagingCache::new_tiered(chunks, cap, 2, spill),
        worker_id,
        prefetch_budget: 2,
    };
    let metrics = Arc::new(MetricsHub::new());
    run_worker_staged(
        source,
        workflow,
        worker_cfg(n_tiles),
        Arc::new(ArtifactManifest::discover_or_empty()),
        metrics.clone(),
        stage_bindings(),
        SharedProfiles::fresh(),
        Some(staging),
    )
    .unwrap();
    metrics.report()
}

/// One clean staged run (the fault-free control); returns the reduce
/// outputs every chaotic run must reproduce bit-for-bit.
fn clean_reduce_outputs(n_tiles: usize) -> Vec<Value> {
    let workflow = Arc::new(build_workflow(&AppParams::for_tile_size(TILE), true));
    let manager = Manager::new_staged(workflow.clone(), n_tiles, AssignPolicy::default()).unwrap();
    let server = ManagerServer::bind("127.0.0.1:0", manager.clone()).unwrap();
    let addr = server.local_addr();
    let srv = std::thread::spawn(move || server.serve());
    let registry = Arc::new(Registry::new());
    run_chaos_worker(
        vec![addr],
        workflow,
        n_tiles,
        1,
        Faults::disabled(),
        registry,
        None,
        16,
    );
    srv.join().unwrap().unwrap();
    assert!(manager.error().is_none(), "{:?}", manager.error());
    manager.reduce_outputs("classification").expect("classification ran")
}

/// Run the workflow under `plan` and return (reduce outputs, faults
/// handle, registry, report, manager stale-completion count).
fn chaotic_reduce_outputs(
    n_tiles: usize,
    plan: &str,
    seed: u64,
    spill_dir: Option<&std::path::Path>,
) -> (Vec<Value>, Faults, Arc<Registry>, MetricsReport, u64) {
    let workflow = Arc::new(build_workflow(&AppParams::for_tile_size(TILE), true));
    let manager = Manager::new_staged(workflow.clone(), n_tiles, AssignPolicy::default()).unwrap();
    let server = ManagerServer::bind("127.0.0.1:0", manager.clone()).unwrap();
    let addr = server.local_addr();
    let srv = std::thread::spawn(move || server.serve());
    let registry = Arc::new(Registry::new());
    let faults = Faults::armed(&FaultPlan::parse(plan, seed).unwrap(), &registry);
    let (spill, cap) = match spill_dir {
        Some(dir) => {
            let mut tier = SpillTier::create(dir.join("worker-1"), 32).unwrap();
            tier.set_faults(faults.clone());
            (Some(tier), 1) // one-slot memory tier forces spill traffic
        }
        None => (None, 16),
    };
    let report = run_chaos_worker(
        vec![addr],
        workflow,
        n_tiles,
        1,
        faults.clone(),
        registry.clone(),
        spill,
        cap,
    );
    srv.join().unwrap().unwrap();
    assert!(manager.error().is_none(), "{:?}", manager.error());
    let (done, total) = manager.progress();
    assert_eq!(done, total, "the workflow must complete under plan '{plan}'");
    let outs = manager.reduce_outputs("classification").expect("classification ran");
    (outs, faults, registry, report, manager.stale_completions())
}

#[test]
fn dropped_and_delayed_frames_complete_bit_identically() {
    let n_tiles = 5;
    let baseline = clean_reduce_outputs(n_tiles);
    // the first three data-plane frames drop outright (retried in place),
    // two more stall 5 ms, and the first work request pauses the worker —
    // rate-1 rules with #caps make every injection deterministic
    let plan = "frame-drop=1#3,frame-delay=1@5#2,worker-pause=1@10#1";
    let (outs, faults, registry, _, _) = chaotic_reduce_outputs(n_tiles, plan, 7, None);
    assert_eq!(outs, baseline, "reduce outputs must survive frame chaos bit-for-bit");
    assert_eq!(faults.fired(Site::FrameDrop), 3);
    assert_eq!(faults.fired(Site::FrameDelay), 2);
    assert_eq!(faults.fired(Site::WorkerPause), 1);
    // counters export through the shared registry for operators
    let snap = registry.snapshot();
    assert_eq!(snap.counter("faults.frame-drop.injected"), 3);
    assert_eq!(snap.counter("faults.frame-delay.injected"), 2);
    // dropped frames retry in place on a healthy socket: no reconnect
    assert_eq!(snap.counter("net.reconnects"), 0);
}

#[test]
fn corrupt_frames_tear_down_reconnect_and_still_complete() {
    let n_tiles = 5;
    let baseline = clean_reduce_outputs(n_tiles);
    // two corrupted frames: the server rejects each at decode and drops
    // the connection, so the worker must reconnect, re-identify, and
    // resume — replaying any completion the dead socket swallowed
    let plan = "frame-corrupt=1#2";
    let (outs, faults, registry, _, _) = chaotic_reduce_outputs(n_tiles, plan, 3, None);
    assert_eq!(outs, baseline, "reduce outputs must survive corrupt-frame teardown");
    assert_eq!(faults.fired(Site::FrameCorrupt), 2);
    assert!(
        registry.snapshot().counter("net.reconnects") >= 1,
        "a corrupted frame must force at least one reconnect"
    );
}

#[test]
fn spill_io_faults_degrade_to_plain_eviction_not_death() {
    let n_tiles = 6;
    let baseline = clean_reduce_outputs(n_tiles);
    let dir = std::env::temp_dir().join(format!("htap-faults-spill-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    // the first two spill writes refuse: the tier degrades those
    // evictions to plain drops (re-read from source later) instead of
    // failing the run
    let plan = "spill-io=1#2";
    let (outs, faults, _, report, _) = chaotic_reduce_outputs(n_tiles, plan, 5, Some(&dir));
    assert_eq!(outs, baseline, "reduce outputs must survive spill I/O errors");
    assert_eq!(faults.fired(Site::SpillIo), 2);
    // the one-slot memory tier still demoted once the fault budget drained
    assert!(report.staging.spill_evicted > 0, "spill tier never engaged after degradation");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn faulty_source_surfaces_bounded_read_errors() {
    // SourceIo is the one fatal site (a worker cannot invent tile bytes);
    // the wrapper surfaces it as a plain load error the manager's lease
    // machinery handles, and the #cap bounds the blast radius
    let inner = Arc::new(SynthSource::new(SynthConfig::for_tile_size(TILE, SEED), 4));
    let registry = Registry::new();
    let faults =
        Faults::armed(&FaultPlan::parse("source-io=1#1,source-slow=1@1#1", 9).unwrap(), &registry);
    let src = FaultySource::wrap(inner.clone(), faults.clone());
    assert!(src.load(0).is_err(), "the first read must fail");
    // past the cap the wrapper is transparent (bit-identical payloads)
    assert_eq!(src.load(0).unwrap(), inner.load(0).unwrap());
    assert_eq!(faults.fired(Site::SourceIo), 1);
    assert_eq!(faults.fired(Site::SourceSlow), 1);
    assert_eq!(src.n_chunks(), 4);
    assert!(src.describe().starts_with("faulty("));
}

#[test]
fn duplicate_completions_are_absorbed_idempotently() {
    let n_tiles = 3;
    let workflow = Arc::new(build_workflow(&AppParams::for_tile_size(TILE), true));
    let manager = Manager::new_staged(workflow.clone(), n_tiles, AssignPolicy::default()).unwrap();
    let batch =
        manager.request_work(&WorkRequest { capacity: 1, worker: 1, ..Default::default() });
    assert_eq!(batch.assignments.len(), 1);
    let a = &batch.assignments[0];
    let chunks = Arc::new(SynthSource::new(SynthConfig::for_tile_size(TILE, SEED), n_tiles));
    let payload = chunks.load(a.chunk).unwrap();
    let outs = htap::dataflow::run_stage_serial(&workflow.stages[a.stage_idx], &payload).unwrap();
    // the replay ring can deliver the same completion twice after a
    // reconnect; the manager must count the work exactly once
    manager.complete(a.instance_id, outs.clone());
    let done_once = manager.progress().0;
    manager.complete(a.instance_id, outs.clone());
    manager.complete(a.instance_id, outs);
    assert_eq!(manager.progress().0, done_once, "duplicates must not advance progress");
    assert_eq!(manager.stale_completions(), 2, "both duplicates are counted as stale");
}

#[test]
fn worker_fails_over_to_promoted_standby_without_reexecution() {
    let n_tiles = 4;
    let workflow = Arc::new(build_workflow(&AppParams::for_tile_size(TILE), false));
    let ckpt_dir =
        std::env::temp_dir().join(format!("htap-faults-failover-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&ckpt_dir);

    // the primary: journal on, two completions land, checkpoint, crash
    let primary =
        Manager::new_staged(workflow.clone(), n_tiles, AssignPolicy::default()).unwrap();
    primary.enable_journal();
    let batch =
        primary.request_work(&WorkRequest { capacity: 2, worker: 1, ..Default::default() });
    assert_eq!(batch.assignments.len(), 2);
    let chunks = Arc::new(SynthSource::new(SynthConfig::for_tile_size(TILE, SEED), n_tiles));
    for a in &batch.assignments {
        let payload = chunks.load(a.chunk).unwrap();
        let outs =
            htap::dataflow::run_stage_serial(&workflow.stages[a.stage_idx], &payload).unwrap();
        primary.complete(a.instance_id, outs);
    }
    checkpoint::write_checkpoint(&ckpt_dir, &primary).unwrap();
    drop(primary);

    // a dead address: bind a port, note it, release it — connects now
    // refuse, exactly what a SIGKILLed primary's address does
    let dead_addr = {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().to_string()
    };

    // the promoted standby: restore the snapshot, serve on a fresh port
    let standby =
        Manager::new_staged(workflow.clone(), n_tiles, AssignPolicy::default()).unwrap();
    standby.enable_journal();
    let (journal, catalog) = checkpoint::load_checkpoint(&ckpt_dir).unwrap().expect("snapshot");
    let replayed = standby.restore_from(journal, catalog).unwrap();
    assert_eq!(replayed, 2);
    let server = ManagerServer::bind("127.0.0.1:0", standby.clone()).unwrap();
    let live_addr = server.local_addr();
    let srv = std::thread::spawn(move || server.serve());

    // the worker's failover list leads with the dead primary: the dial
    // must rotate through it onto the standby under the retry policy
    let registry = Arc::new(Registry::new());
    let report = run_chaos_worker(
        vec![dead_addr, live_addr],
        workflow,
        n_tiles,
        1,
        Faults::disabled(),
        registry,
        None,
        16,
    );
    srv.join().unwrap().unwrap();
    assert!(standby.error().is_none(), "{:?}", standby.error());
    let (done, total) = standby.progress();
    assert_eq!(done, total);
    // exact no-reexecution accounting: the worker ran only what the
    // checkpoint had not already journalled — the remaining segmentation
    // instances (9 ops each) plus every features instance (3 ops each)
    assert_eq!(report.total_executed(), (9 * (n_tiles - 2) + 3 * n_tiles) as u64);
    let _ = std::fs::remove_dir_all(&ckpt_dir);
}

#[test]
fn identical_plans_inject_identically_and_seeds_move_the_chaos() {
    // the injection verdict is a pure function of (seed, site, occurrence):
    // two handles armed from the same plan agree call-for-call, and a
    // different seed produces a different (but equally reproducible) trace
    let plan = FaultPlan::parse("frame-drop=0.4", 21).unwrap();
    let r1 = Registry::new();
    let r2 = Registry::new();
    let a = Faults::armed(&plan, &r1);
    let b = Faults::armed(&plan, &r2);
    let trace_a: Vec<bool> = (0..64).map(|_| a.inject(Site::FrameDrop).is_some()).collect();
    let trace_b: Vec<bool> = (0..64).map(|_| b.inject(Site::FrameDrop).is_some()).collect();
    assert_eq!(trace_a, trace_b, "same plan + seed must inject identically");
    assert!(trace_a.iter().any(|&x| x), "a 40% rate over 64 draws must fire");
    assert!(!trace_a.iter().all(|&x| x), "a 40% rate over 64 draws must also pass");
    let other = Faults::armed(&FaultPlan::parse("frame-drop=0.4", 22).unwrap(), &Registry::new());
    let trace_c: Vec<bool> =
        (0..64).map(|_| other.inject(Site::FrameDrop).is_some()).collect();
    assert_ne!(trace_a, trace_c, "a different seed must move the chaos");
}
