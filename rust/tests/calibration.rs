//! Calibration subsystem integration: profiles.json round-trips, measured
//! estimates flow into PATS queue ordering (inverting the static Fig. 7
//! ranking when the measurements say so), the simulator consumes the same
//! store, and the online EWMA path records real executions.

use htap::app::{self, profile};
use htap::config::{Policy, RunConfig};
use htap::coordinator::run_local;
use htap::coordinator::sched::{make_scheduler, OpScheduler, ReadyTask};
use htap::data::{SynthConfig, TileStore};
use htap::metrics::DeviceKind;
use htap::runtime::calibrate::{calibrate_workflows, CalibrationConfig};
use htap::runtime::ProfileStore;
use htap::sim::SimWorkflow;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

fn ms(v: f64) -> Duration {
    Duration::from_secs_f64(v / 1e3)
}

/// A store whose measurements invert the static Fig. 7 ranking of
/// morph_open (static 1.6x -> measured 20x) and feature_graph (static
/// 16x -> measured 1.25x).
fn inverted_store() -> ProfileStore {
    let mut store = ProfileStore::new(64);
    store.record("morph_open", DeviceKind::Cpu, ms(100.0));
    store.record("morph_open", DeviceKind::Gpu, ms(5.0));
    store.record("feature_graph", DeviceKind::Cpu, ms(100.0));
    store.record("feature_graph", DeviceKind::Gpu, ms(80.0));
    store
}

fn temp_path(name: &str) -> String {
    std::env::temp_dir().join(name).to_str().unwrap().to_string()
}

#[test]
fn profiles_json_round_trip_preserves_estimates() {
    let mut store = inverted_store();
    store.record_transfer_impact("morph_open", 0.12);
    let path = temp_path("htap_calibration_roundtrip.json");
    store.save(&path).unwrap();
    let loaded = ProfileStore::load(&path).unwrap();
    assert_eq!(loaded, store, "serialize -> load must preserve the store exactly");
    for op in ["morph_open", "feature_graph"] {
        assert_eq!(loaded.speedup(op), store.speedup(op), "{op}");
        assert_eq!(loaded.cpu_ms(op), store.cpu_ms(op), "{op}");
        assert_eq!(loaded.estimate(op), store.estimate(op), "{op}");
    }
}

/// The acceptance path: a saved+loaded profiles.json, applied to the
/// registry, flips which op PATS hands to an idle GPU first.
#[test]
fn loaded_profiles_invert_pats_dequeue_order() {
    // static ranking: feature_graph (16x) far above morph_open (1.6x)
    assert!(profile::speedup_of("feature_graph") > profile::speedup_of("morph_open"));

    let path = temp_path("htap_calibration_invert.json");
    inverted_store().save(&path).unwrap();
    let loaded = ProfileStore::load(&path).unwrap();

    let push_both = |registry: &htap::dataflow::OpRegistry| {
        let mut q = make_scheduler(Policy::Pats);
        for (i, name) in ["morph_open", "feature_graph"].iter().enumerate() {
            let spec = registry.get(name).unwrap();
            q.push(ReadyTask {
                key: (i as u64, 0),
                name: name.to_string(),
                speedup: spec.speedup,
                transfer_impact: spec.transfer_impact,
                seq: i as u64,
                resident_on: None,
                has_gpu_impl: true,
            });
        }
        q
    };

    // before calibration the GPU takes feature_graph first…
    let static_registry = app::registry();
    let mut q = push_both(&static_registry);
    assert_eq!(q.pop(DeviceKind::Gpu, 0, false).unwrap().name, "feature_graph");

    // …after loading measured profiles it takes morph_open first, and the
    // CPU gets the now-low-speedup feature_graph
    let mut calibrated = app::registry();
    assert_eq!(calibrated.apply_profiles(&loaded), 2);
    let mut q = push_both(&calibrated);
    assert_eq!(
        q.pop(DeviceKind::Gpu, 0, false).unwrap().name,
        "morph_open",
        "measured speedups must override the static Fig. 7 ranking"
    );
    assert_eq!(q.pop(DeviceKind::Cpu, 0, false).unwrap().name, "feature_graph");

    // the estimates also flow into workflows built over the registry
    let wf = app::build_workflow_with(
        Arc::new(calibrated),
        &app::AppParams::for_tile_size(64),
        false,
    )
    .unwrap();
    let op = |name: &str| {
        wf.stages
            .iter()
            .flat_map(|s| s.ops.iter())
            .find(|o| o.name == name)
            .unwrap()
            .speedup
    };
    assert!(op("morph_open") > op("feature_graph"));
}

/// The simulator consumes the same store: measured estimates replace the
/// static table in `SimWorkflow`, unmeasured ops fall back.
#[test]
fn simulator_consumes_the_same_store() {
    let path = temp_path("htap_calibration_sim.json");
    inverted_store().save(&path).unwrap();
    let loaded = ProfileStore::load(&path).unwrap();
    let wf = SimWorkflow::pipelined_profiled(&loaded);
    let est = |name: &str| {
        wf.stages
            .iter()
            .flat_map(|s| s.ops.iter())
            .find(|o| o.name == name)
            .unwrap()
            .speedup_est
    };
    assert!((est("morph_open") - 20.0).abs() < 0.5);
    assert!((est("feature_graph") - 1.25).abs() < 0.1);
    // watershed was never measured: static Fig. 7 fallback
    assert_eq!(est("watershed"), profile::speedup_of("watershed"));
}

/// Offline pass -> profiles.json -> load: the calibrate CLI path in
/// library form, on the quick (CI-sized) configuration.
#[test]
fn quick_offline_pass_round_trips_through_disk() {
    let store = calibrate_workflows(&CalibrationConfig::quick()).unwrap();
    assert!(store.len() >= 16, "expected WSI + generic coverage, got {}", store.len());
    let path = temp_path("htap_calibration_offline.json");
    store.save(&path).unwrap();
    let loaded = ProfileStore::load(&path).unwrap();
    assert_eq!(loaded, store);
    // every measured op has a usable CPU mean
    for op in loaded.op_names() {
        assert!(loaded.cpu_ms(op).unwrap_or(0.0) >= 0.0);
    }
}

/// The online path: a real run folds completion times into the outcome's
/// shared store via the WRM.
#[test]
fn run_local_records_online_cpu_estimates() {
    let n_tiles = 3;
    let wf = Arc::new(app::generic::cell_stats_workflow().unwrap());
    let tiles = Arc::new(TileStore::new(SynthConfig::for_tile_size(64, 17), n_tiles));
    let cfg = RunConfig {
        tile_size: 64,
        n_tiles,
        cpu_workers: 2,
        gpu_workers: 0,
        ..Default::default()
    };
    let outcome = run_local(wf, tiles.loader(), n_tiles, cfg, HashMap::new()).unwrap();
    let snap = outcome.profiles.snapshot();
    for op in ["grayscale", "invert", "gauss3", "binarize", "cc_label", "region_stats"] {
        let cal = snap.get(op).unwrap_or_else(|| panic!("no online samples for {op}"));
        let cpu = cal.cpu.expect("cpu estimate");
        assert_eq!(cpu.samples, n_tiles as u64, "{op} folded once per tile");
        assert!(cpu.mean_ms >= 0.0);
    }
    // the reduce op ran once
    assert_eq!(snap.get("mean_stats").unwrap().cpu.unwrap().samples, 1);
}

/// An EWMA stream that flips two ops' relative speedups reorders a PATS
/// queue fed from the shared store (the WRM's push path in miniature).
#[test]
fn ewma_updates_flip_pats_relative_order() {
    use htap::runtime::SharedProfiles;
    let shared = SharedProfiles::fresh();
    // initial measurements: a=2x, b=10x
    shared.record("a", DeviceKind::Cpu, ms(20.0));
    shared.record("a", DeviceKind::Gpu, ms(10.0));
    shared.record("b", DeviceKind::Cpu, ms(100.0));
    shared.record("b", DeviceKind::Gpu, ms(10.0));
    assert!(shared.estimate("b").unwrap().speedup > shared.estimate("a").unwrap().speedup);

    // the host turns out to run b's accelerator member terribly and a's
    // superbly; EWMA folding must flip the relative order
    for _ in 0..30 {
        shared.record("a", DeviceKind::Gpu, ms(1.0));
        shared.record("b", DeviceKind::Gpu, ms(200.0));
    }
    let (ea, eb) = (shared.estimate("a").unwrap(), shared.estimate("b").unwrap());
    assert!(ea.speedup > eb.speedup, "EWMA must track the drift: a={} b={}", ea.speedup, eb.speedup);

    // and a PATS queue built from the live estimates hands a to the GPU
    let mut q = make_scheduler(Policy::Pats);
    for (i, (name, est)) in [("a", ea), ("b", eb)].into_iter().enumerate() {
        q.push(ReadyTask {
            key: (i as u64, 0),
            name: name.to_string(),
            speedup: est.speedup,
            transfer_impact: est.transfer_impact.unwrap_or(0.1),
            seq: i as u64,
            resident_on: None,
            has_gpu_impl: true,
        });
    }
    assert_eq!(q.pop(DeviceKind::Gpu, 0, false).unwrap().name, "a");
    assert_eq!(q.pop(DeviceKind::Cpu, 0, false).unwrap().name, "b");
}

/// `htap calibrate --read-latency-ms` measures the per-chunk read cost
/// (source read + simulated shared-FS latency) under the CHUNK_READ_OP
/// pseudo-op — the value `htap sim --profiles` feeds into its tile-I/O
/// base so calibrated transfer estimates reflect the same latency.
#[test]
fn calibrate_measures_chunk_read_latency() {
    use htap::runtime::calibrate::CHUNK_READ_OP;
    let mut cfg = CalibrationConfig::quick();
    cfg.read_latency_ms = 5;
    let store = calibrate_workflows(&cfg).unwrap();
    let ms = store.cpu_ms(CHUNK_READ_OP).expect("chunk_read must be calibrated");
    assert!(ms >= 5.0, "chunk_read ({ms:.2} ms) must include the 5 ms simulated latency");
    // a latency-free calibration must NOT record chunk_read: its
    // memory-speed reads would silently collapse the simulator's
    // shared-FS cost model when fed through `htap sim --profiles`
    let store = calibrate_workflows(&CalibrationConfig::quick()).unwrap();
    assert!(store.cpu_ms(CHUNK_READ_OP).is_none(), "0-latency runs must skip chunk_read");
}
