//! Adversarial wire-format decoding: every hostile byte string the
//! Manager/Worker framing can receive must come back as `Err(..)`, never
//! a panic, never a pre-error multi-gigabyte allocation.
//!
//! Three attack families, over the work-cycle [`Message`] kinds plus the
//! v6 observability surface (`TraceBatch` / `StatsQuery` / `StatsReport`):
//!
//! 1. **truncation** — every strict prefix of a valid encoding;
//! 2. **random frames** — deterministic xorshift fuzzing (replayable via
//!    `HTAP_PROPTEST_SEED`), raw and with a valid version/tag header;
//! 3. **hostile counts** — tiny frames whose length prefixes claim 2^32
//!    elements (ids, values, assignments, string bytes, tensor dims,
//!    trace events, utilization rows); these must fail fast on the count
//!    bound, not preallocate.

use htap::coordinator::manager::Assignment;
use htap::net::proto::{decode, encode, read_message, Message, PROTO_VERSION};
use htap::obs::{EventKind, Name, TraceEvent, UtilRow, DEV_GPU};
use htap::runtime::{HostTensor, Value};
use htap::testing::Rng;

// request / assign / complete / fail / trace-batch / stats-query / stats-report
const TAGS: [u8; 7] = [1, 2, 3, 4, 15, 16, 17];

/// One representative (non-trivial) message per wire kind.
fn specimens() -> Vec<Message> {
    let tensor = Value::Tensor(HostTensor::new(vec![2, 3], (0..6).map(|i| i as f32).collect()).unwrap());
    vec![
        Message::Request {
            capacity: 4,
            worker: 0xAB,
            prefetch_budget: 2,
            staged_add: vec![1, 2, 3],
            staged_drop: vec![9],
            demoted: vec![4],
        },
        Message::Assign {
            assignments: vec![Assignment {
                instance_id: 7,
                stage_idx: 1,
                chunk: 3,
                inputs: vec![Value::Scalar(1.5), tensor.clone()],
                needs_chunk: true,
                locality: false,
                replica: true,
            }],
            prefetch: vec![5, 6],
            replicate: vec![3],
        },
        Message::Complete { instance: 7, outputs: vec![tensor, Value::Scalar(-2.0)] },
        Message::Fail { msg: "device lost".into() },
        Message::TraceBatch {
            worker: 3,
            events: vec![
                TraceEvent {
                    ts_us: 1_000,
                    dur_us: 250,
                    device: DEV_GPU,
                    worker: 3,
                    lane: 1,
                    job: 9,
                    stage: 2,
                    chunk: 41,
                    name: Name::new("watershed"),
                    ..TraceEvent::of(EventKind::OpEnd)
                },
                TraceEvent::of(EventKind::StagingMiss),
            ],
        },
        Message::StatsQuery,
        Message::StatsReport {
            rows: vec![UtilRow {
                worker: 3,
                job: 9,
                tenant: "alice".into(),
                ops: 12,
                busy_us: 34_000,
            }],
        },
    ]
}

#[test]
fn every_truncation_of_every_message_errors_cleanly() {
    for msg in specimens() {
        let enc = encode(&msg);
        assert!(decode(&enc).is_ok());
        for cut in 0..enc.len() {
            // catch_unwind would also catch aborts too late: rely on the
            // test harness — any panic here fails the test with the cut
            let r = decode(&enc[..cut]);
            assert!(r.is_err(), "{msg:?} truncated to {cut}/{} bytes decoded Ok", enc.len());
        }
    }
}

#[test]
fn random_frames_error_not_panic() {
    let mut rng = Rng::new(0x5EED);
    for case in 0..2000 {
        let len = rng.below(96);
        let mut frame: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        // half the cases get a valid version (+ sometimes a valid tag) so
        // the fuzz reaches the per-message decoders, not just the header
        if !frame.is_empty() && case % 2 == 0 {
            frame[0] = PROTO_VERSION;
            if frame.len() > 1 && case % 4 == 0 {
                frame[1] = TAGS[case % TAGS.len()];
            }
        }
        let _ = decode(&frame); // must return, Ok or Err — never panic
    }
}

#[test]
fn random_mutations_of_valid_frames_error_or_reparse() {
    let mut rng = Rng::new(0xFACADE);
    let originals = specimens();
    for case in 0..2000 {
        let mut enc = encode(&originals[case % originals.len()]);
        for _ in 0..rng.range(1, 4) {
            let i = rng.below(enc.len());
            enc[i] = rng.next_u64() as u8;
        }
        let _ = decode(&enc); // corrupt frames may still parse; just no panic
    }
}

/// Little-endian u32 helper for hand-built hostile frames.
fn le(v: u32) -> [u8; 4] {
    v.to_le_bytes()
}

fn hostile(tag: u8, body: &[u8]) -> Vec<u8> {
    let mut f = vec![PROTO_VERSION, tag];
    f.extend_from_slice(body);
    f
}

#[test]
fn hostile_count_prefixes_fail_before_preallocation() {
    // Request: header fields, then staged_add count = u32::MAX with no
    // bytes behind it — must die on the count bound
    let mut body = Vec::new();
    body.extend_from_slice(&le(1)); // capacity
    body.extend_from_slice(&0u64.to_le_bytes()); // worker
    body.extend_from_slice(&le(0)); // prefetch_budget
    body.extend_from_slice(&le(u32::MAX)); // staged_add count
    let e = decode(&hostile(1, &body)).unwrap_err();
    assert!(e.to_string().contains("count"), "unexpected error: {e}");

    // Assign: claims 2^32 - 1 assignments in a 4-byte body
    let e = decode(&hostile(2, &le(u32::MAX))).unwrap_err();
    assert!(e.to_string().contains("count"), "unexpected error: {e}");

    // Complete: instance id then a hostile value count
    let mut body = Vec::new();
    body.extend_from_slice(&7u64.to_le_bytes());
    body.extend_from_slice(&le(u32::MAX));
    let e = decode(&hostile(3, &body)).unwrap_err();
    assert!(e.to_string().contains("count"), "unexpected error: {e}");

    // Fail: string length far beyond the frame — take() bounds it
    let e = decode(&hostile(4, &le(u32::MAX))).unwrap_err();
    assert!(e.to_string().contains("truncated"), "unexpected error: {e}");

    // Tensor dims whose product wraps usize: decode error, not a panic or
    // an inconsistent tensor
    let mut body = Vec::new();
    body.extend_from_slice(&7u64.to_le_bytes()); // instance
    body.extend_from_slice(&le(1)); // one output value
    body.push(1); // tensor tag
    body.extend_from_slice(&le(4)); // rank 4
    for _ in 0..4 {
        body.extend_from_slice(&(u64::MAX / 2).to_le_bytes());
    }
    let e = decode(&hostile(3, &body)).unwrap_err();
    assert!(e.to_string().contains("overflow"), "unexpected error: {e}");

    // TraceBatch: worker id then an event count claiming 2^32 - 1 events
    // in an empty body — the 51-byte-per-event bound must reject it
    // before Vec::with_capacity runs
    let mut body = Vec::new();
    body.extend_from_slice(&3u64.to_le_bytes()); // worker
    body.extend_from_slice(&le(u32::MAX)); // event count
    let e = decode(&hostile(15, &body)).unwrap_err();
    assert!(e.to_string().contains("count"), "unexpected error: {e}");

    // StatsReport: hostile utilization-row count, same treatment
    let e = decode(&hostile(17, &le(u32::MAX))).unwrap_err();
    assert!(e.to_string().contains("count"), "unexpected error: {e}");

    // a trace event whose name length byte points past the frame
    let mut body = Vec::new();
    body.extend_from_slice(&3u64.to_le_bytes()); // worker
    body.extend_from_slice(&le(1)); // one event
    body.extend_from_slice(&[0u8; 8]); // ts_us
    body.extend_from_slice(&[0u8; 8]); // dur_us
    body.push(EventKind::OpEnd as u8); // kind
    body.push(0); // device
    body.extend_from_slice(&[0u8; 8]); // worker
    body.extend_from_slice(&le(0)); // lane
    body.extend_from_slice(&[0u8; 8]); // job
    body.extend_from_slice(&le(0)); // stage
    body.extend_from_slice(&[0u8; 8]); // chunk
    body.push(200); // name length: past both NAME_CAP and the frame end
    let e = decode(&hostile(15, &body)).unwrap_err();
    assert!(!e.to_string().is_empty());
}

#[test]
fn framed_reader_rejects_oversized_and_short_frames() {
    // length prefix beyond MAX_FRAME
    let mut buf = Vec::new();
    buf.extend_from_slice(&le(u32::MAX));
    buf.extend_from_slice(&[0; 16]);
    let mut cur = std::io::Cursor::new(buf);
    assert!(read_message(&mut cur).is_err());

    // length prefix promising more bytes than the stream has
    let mut buf = Vec::new();
    buf.extend_from_slice(&le(64));
    buf.extend_from_slice(&[PROTO_VERSION, 1, 2, 3]);
    let mut cur = std::io::Cursor::new(buf);
    assert!(read_message(&mut cur).is_err());

    // clean EOF is the dedicated "eof" error
    let mut cur = std::io::Cursor::new(Vec::<u8>::new());
    let e = read_message(&mut cur).unwrap_err();
    assert!(e.to_string().contains("eof"), "unexpected error: {e}");
}
