//! Property tests on coordinator invariants, driven by random workflows
//! and random device interleavings (the offline proptest substitute —
//! htap::testing).
//!
//! Invariants (DESIGN.md §5):
//! * every operation instance executes exactly once;
//! * dependencies are never violated (an op never runs before its
//!   producers);
//! * the PATS queue always returns the global min (CPU) / max (GPU)
//!   speedup among eligible tasks;
//! * the window protocol never over-assigns and always drains;
//! * random DAG workflows complete under random device mixes.

use htap::config::Policy;
use htap::coordinator::sched::{make_scheduler, OpScheduler, ReadyTask};
use htap::coordinator::{Manager, WorkSource};
use htap::dataflow::{
    OpRegistry, OpSpec, PortRef, PortSpec, StageKind, Workflow, WorkflowBuilder,
};
use htap::metrics::DeviceKind;
use htap::runtime::Value;
use htap::testing::{forall, Rng};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

fn ready(key: u64, speedup: f32, seq: u64, gpu: bool) -> ReadyTask {
    ReadyTask {
        key: (key, 0),
        name: format!("t{key}"),
        speedup,
        transfer_impact: 0.1,
        seq,
        resident_on: None,
        has_gpu_impl: gpu,
    }
}

#[test]
fn prop_pats_pop_is_extremal() {
    forall(
        "pats pop extremal",
        100,
        |r: &mut Rng| {
            let n = r.range(1, 60);
            (0..n)
                .map(|i| (r.f32_range(0.5, 20.0), r.bool()))
                .enumerate()
                .map(|(i, (s, g))| ready(i as u64, s, i as u64, g))
                .collect::<Vec<_>>()
        },
        |tasks| {
            let mut q = make_scheduler(Policy::Pats);
            for t in tasks.clone() {
                q.push(t);
            }
            // CPU pop must be the global minimum
            let min = tasks.iter().map(|t| t.speedup).fold(f32::INFINITY, f32::min);
            let got = q.pop(DeviceKind::Cpu, 0, false).unwrap();
            if (got.speedup - min).abs() > 1e-6 {
                return Err(format!("cpu pop {} != min {min}", got.speedup));
            }
            // GPU pop must be the max among gpu-capable leftovers
            let leftovers: Vec<&ReadyTask> =
                tasks.iter().filter(|t| t.key != got.key && t.has_gpu_impl).collect();
            match q.pop(DeviceKind::Gpu, 0, false) {
                Some(g) => {
                    let max = leftovers.iter().map(|t| t.speedup).fold(f32::NEG_INFINITY, f32::max);
                    if (g.speedup - max).abs() > 1e-6 {
                        return Err(format!("gpu pop {} != max {max}", g.speedup));
                    }
                }
                None => {
                    if !leftovers.is_empty() {
                        return Err("gpu pop empty with eligible tasks".into());
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_schedulers_conserve_tasks() {
    forall(
        "push count == pop count",
        60,
        |r: &mut Rng| {
            let n = r.range(1, 80);
            let policy = if r.bool() { Policy::Pats } else { Policy::Fcfs };
            let tasks: Vec<ReadyTask> = (0..n)
                .map(|i| ready(i as u64, r.f32_range(0.5, 9.0), i as u64, true))
                .collect();
            (policy, tasks)
        },
        |(policy, tasks)| {
            let mut q = make_scheduler(*policy);
            for t in tasks.clone() {
                q.push(t);
            }
            let mut seen = std::collections::HashSet::new();
            let mut rng = Rng::new(9);
            while !q.is_empty() {
                let kind = if rng.bool() { DeviceKind::Cpu } else { DeviceKind::Gpu };
                if let Some(t) = q.pop(kind, rng.below(3), rng.bool()) {
                    if !seen.insert(t.key) {
                        return Err(format!("task {:?} popped twice", t.key));
                    }
                }
            }
            if seen.len() != tasks.len() {
                return Err(format!("{} of {} tasks popped", seen.len(), tasks.len()));
            }
            Ok(())
        },
    );
}

/// Build a random linear-ish DAG workflow (one PerChunk stage) through the
/// typed builder; its ops record execution order into `log`.
fn random_workflow(
    rng: &mut Rng,
    log: Arc<std::sync::Mutex<Vec<(u64, usize, usize)>>>,
    counter: Arc<AtomicUsize>,
) -> Workflow {
    let n_ops = rng.range(1, 7);
    let mut registry = OpRegistry::new();
    for oi in 0..n_ops {
        let log = log.clone();
        let counter = counter.clone();
        registry
            .register(
                OpSpec::cpu(&format!("op{oi}"), 1, move |args: &[Value]| {
                    let chunk = args[0].as_scalar()? as u64;
                    let order = counter.fetch_add(1, Ordering::SeqCst);
                    log.lock().unwrap().push((chunk, oi, order));
                    Ok(vec![Value::Scalar(chunk as f32)])
                })
                .with_profile(rng.f32_range(1.0, 10.0), 0.1, 0.0),
            )
            .unwrap();
    }
    let mut wb = WorkflowBuilder::new("prop", registry);
    let mut stage = wb.stage("rand", StageKind::PerChunk);
    let chunk = stage.input_chunk();
    let mut handles = Vec::with_capacity(n_ops);
    for oi in 0..n_ops {
        // each op depends on a random subset of earlier ops (or the input)
        let mut inputs: Vec<PortSpec> = vec![chunk.clone()];
        for p in 0..oi {
            if rng.bool() {
                inputs.push(handles[p].clone());
            }
        }
        let h = stage.add_op(&format!("op{oi}"), &inputs).unwrap();
        handles.push(h.out());
    }
    let last = handles.last().cloned().unwrap();
    stage.export(last).unwrap();
    wb.add_stage(stage).unwrap();
    wb.build().unwrap()
}

#[test]
fn prop_random_dags_execute_once_in_dependency_order() {
    forall(
        "random dag executes once, deps respected",
        12,
        |r: &mut Rng| (r.next_u64(), r.range(1, 6), r.range(1, 3), r.range(1, 4)),
        |&(seed, n_chunks, cpus, window)| {
            let log = Arc::new(std::sync::Mutex::new(Vec::new()));
            let counter = Arc::new(AtomicUsize::new(0));
            let mut rng = Rng::new(seed);
            let wf = random_workflow(&mut rng, log.clone(), counter.clone());
            let deps: Vec<Vec<usize>> = wf.stages[0]
                .ops
                .iter()
                .map(|o| {
                    o.inputs
                        .iter()
                        .filter_map(|p| match p {
                            PortRef::Op { op, .. } => Some(*op),
                            _ => None,
                        })
                        .collect()
                })
                .collect();
            let n_ops = wf.stages[0].ops.len();
            wf.validate().map_err(|e| e.to_string())?;
            let wf = Arc::new(wf);
            let loader: htap::coordinator::ChunkLoader =
                Arc::new(|c| Ok(vec![Value::Scalar(c as f32)]));
            let mgr = Manager::new(wf.clone(), loader, n_chunks).map_err(|e| e.to_string())?;
            let cfg = htap::config::RunConfig {
                cpu_workers: cpus,
                gpu_workers: 0,
                window,
                n_tiles: n_chunks,
                ..Default::default()
            };
            htap::coordinator::worker::run_worker(
                mgr.clone(),
                wf,
                cfg,
                Arc::new(htap::runtime::ArtifactManifest::discover_or_empty()),
                Arc::new(htap::metrics::MetricsHub::new()),
                Default::default(),
            )
            .map_err(|e| e.to_string())?;
            // every (chunk, op) exactly once
            let log = log.lock().unwrap();
            if log.len() != n_chunks * n_ops {
                return Err(format!("{} executions != {}", log.len(), n_chunks * n_ops));
            }
            let mut order = std::collections::HashMap::new();
            for (chunk, op, ord) in log.iter() {
                if order.insert((*chunk, *op), *ord).is_some() {
                    return Err(format!("({chunk},{op}) executed twice"));
                }
            }
            // dependency order per chunk
            for chunk in 0..n_chunks as u64 {
                for (oi, dep_list) in deps.iter().enumerate() {
                    for &d in dep_list {
                        let me = order[&(chunk, oi)];
                        let dep = order[&(chunk, d)];
                        if dep > me {
                            return Err(format!(
                                "chunk {chunk}: op{oi} (at {me}) ran before dep op{d} (at {dep})"
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_manager_never_exceeds_window() {
    forall(
        "window cap respected",
        20,
        |r: &mut Rng| (r.range(1, 20), r.range(1, 8)),
        |&(n_chunks, window)| {
            let mut registry = OpRegistry::new();
            registry
                .register_cpu("id", 1, |a: &[Value]| Ok(vec![a[0].clone()]))
                .unwrap();
            let mut wb = WorkflowBuilder::new("w", registry);
            let mut s = wb.stage("s", StageKind::PerChunk);
            let chunk = s.input_chunk();
            let op = s.add_op("id", &[chunk]).unwrap();
            s.export(op.out()).unwrap();
            wb.add_stage(s).unwrap();
            let wf = wb.build().map_err(|e| e.to_string())?;
            let loader: htap::coordinator::ChunkLoader =
                Arc::new(|c| Ok(vec![Value::Scalar(c as f32)]));
            let mgr = Manager::new(Arc::new(wf), loader, n_chunks).map_err(|e| e.to_string())?;
            let mut outstanding = 0usize;
            let mut total = 0usize;
            loop {
                let batch = mgr.request(window - outstanding.min(window - 1));
                if batch.is_empty() {
                    break;
                }
                outstanding += batch.len();
                if outstanding > window {
                    return Err(format!("outstanding {outstanding} > window {window}"));
                }
                for a in batch {
                    mgr.complete(a.instance_id, vec![]);
                    outstanding -= 1;
                    total += 1;
                }
            }
            if total != n_chunks {
                return Err(format!("{total} != {n_chunks}"));
            }
            Ok(())
        },
    );
}
