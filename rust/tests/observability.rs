//! The observability subsystem end to end: a staged run with
//! `--trace-out` must produce a trace whose op spans pair up (one
//! begin/end per executed op) and whose per-job rollups reconcile
//! exactly with the run's `MetricsReport` counters; the service job
//! report must join per-tenant rollups from the same merged stream.

use htap::config::RunConfig;
use htap::coordinator::{run_local_staged, AssignPolicy, ChunkId};
use htap::data::staging::ChunkSource;
use htap::dataflow::{param, OpRegistry, StageKind, Workflow, WorkflowBuilder};
use htap::obs::{render_util_table, EventKind, TraceEvent};
use htap::runtime::calibrate::SharedProfiles;
use htap::runtime::Value;
use htap::service::{Endpoint, JobTable};
use htap::Result;
use std::collections::HashMap;
use std::sync::Arc;

/// Chunk `c` loads as `Scalar(c)` — enough to drive the staged path.
struct ScalarSource {
    n: usize,
}

impl ChunkSource for ScalarSource {
    fn n_chunks(&self) -> usize {
        self.n
    }

    fn load(&self, chunk: ChunkId) -> Result<Vec<Value>> {
        Ok(vec![Value::Scalar(chunk as f32)])
    }

    fn describe(&self) -> String {
        format!("scalar({})", self.n)
    }
}

/// Two PerChunk stages (stage 1 consumes stage 0) plus a Reduce total:
/// `2 * n + 1` op executions for `n` chunks.
fn workflow() -> Arc<Workflow> {
    let mut reg = OpRegistry::new();
    reg.register_cpu("add", 1, |args: &[Value]| {
        let mut s = 0.0;
        for v in args {
            s += v.as_scalar()?;
        }
        Ok(vec![Value::Scalar(s)])
    })
    .unwrap();
    reg.register_cpu("sum", 1, |args: &[Value]| {
        let mut s = 0.0;
        for v in args {
            s += v.as_scalar()?;
        }
        Ok(vec![Value::Scalar(s)])
    })
    .unwrap();
    let mut wb = WorkflowBuilder::new("obs-test", reg);
    let mut s0 = wb.stage("s0", StageKind::PerChunk);
    let c = s0.input_chunk();
    let op = s0.add_op("add", &[c, param(1.0)]).unwrap();
    s0.export(op.out()).unwrap();
    let s0 = wb.add_stage(s0).unwrap();
    let mut s1 = wb.stage("s1", StageKind::PerChunk);
    let c = s1.input_chunk();
    let up = s1.input_upstream(s0.output(0));
    let op = s1.add_op("add", &[c, up]).unwrap();
    s1.export(op.out()).unwrap();
    let s1 = wb.add_stage(s1).unwrap();
    let mut red = wb.stage("total", StageKind::Reduce);
    red.input_upstream(s1.output(0));
    let op = red.add_reduce_op("sum").unwrap();
    red.export(op.out()).unwrap();
    wb.add_stage(red).unwrap();
    Arc::new(wb.build().unwrap())
}

#[test]
fn traced_staged_run_reconciles_with_metrics() {
    let n = 6;
    let dir = std::env::temp_dir().join(format!("htap-obs-e2e-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("trace.json").to_string_lossy().to_string();
    let cfg = RunConfig {
        n_tiles: n,
        cpu_workers: 2,
        gpu_workers: 0,
        window: 2,
        staging_cap: htap::config::CacheCap::Chunks(8),
        prefetch_depth: 2,
        trace_out: Some(path.clone()),
        ..Default::default()
    };
    let source = Arc::new(ScalarSource { n });
    let outcome =
        run_local_staged(workflow(), source, n, cfg, HashMap::new(), SharedProfiles::fresh())
            .unwrap();
    let (done, total) = outcome.manager.progress();
    assert_eq!((done, total), (2 * n + 1, 2 * n + 1));
    let executed = outcome.metrics.total_executed();
    assert_eq!(executed, (2 * n + 1) as u64);

    // the worker's final drain ships everything to the manager's
    // collector before the run returns, so the merged stream is complete
    let events = outcome.manager.collector().merged();
    assert!(!events.is_empty(), "traced run produced no events");
    assert_eq!(outcome.manager.collector().dropped(), 0, "bounded rings overflowed");

    // matching begin/end spans per executed op: every OpBegin is closed
    // by an OpEnd with the same (job, stage, chunk, name) identity
    let mut open: HashMap<(u64, u32, u64, String), i64> = HashMap::new();
    let (mut begins, mut ends) = (0u64, 0u64);
    for ev in &events {
        let key = (ev.job, ev.stage, ev.chunk, ev.name.as_str().to_string());
        match ev.kind {
            EventKind::OpBegin => {
                begins += 1;
                *open.entry(key).or_insert(0) += 1;
            }
            EventKind::OpEnd => {
                ends += 1;
                *open.entry(key).or_insert(0) -= 1;
            }
            _ => {}
        }
    }
    assert_eq!(begins, executed, "one OpBegin per executed op");
    assert_eq!(ends, executed, "one OpEnd per executed op");
    for (key, balance) in &open {
        assert_eq!(*balance, 0, "unbalanced span for {key:?}");
    }

    // the rollups the service surfaces reconcile with the metrics report
    let rollups = outcome.manager.collector().job_rollups();
    let rollup_ops: u64 = rollups.iter().map(|r| r.ops).sum();
    assert_eq!(rollup_ops, executed, "rollup ops must sum to MetricsReport total");
    assert!(rollups.iter().all(|r| r.job == 0), "local run is job 0: {rollups:?}");
    assert!(rollups.iter().map(|r| r.busy_us).sum::<u64>() > 0);

    // staging + queue instrumentation rode along
    assert!(events.iter().any(|e| e.kind == EventKind::StagingMiss), "no staging events");
    assert!(events.iter().any(|e| e.kind == EventKind::QueueWait), "no queue-wait events");

    // the export pair landed on disk in the documented shapes
    let doc = std::fs::read_to_string(&path).unwrap();
    assert!(doc.starts_with("{\"traceEvents\":["), "not a Chrome trace: {doc:.40}");
    assert!(doc.contains("\"ph\":\"X\""), "no complete spans in the Chrome view");
    assert!(doc.contains("\"name\":\"add\""), "op names missing from spans");
    let jl = std::fs::read_to_string(format!("{path}.jsonl")).unwrap();
    let jl_ends = jl.lines().filter(|l| l.contains("\"kind\":\"op-end\"")).count() as u64;
    assert_eq!(jl_ends, executed, "jsonl must carry every op span");
    let _ = std::fs::remove_dir_all(&dir);
}

const DOUBLE_SUM: &str = r#"{
    "name": "double-sum",
    "stages": [
        {
            "name": "double", "kind": "per_chunk", "inputs": ["chunk"],
            "ops": [ { "op": "double", "inputs": [ {"input": 0} ] } ],
            "outputs": [ {"op": "double"} ]
        },
        {
            "name": "total", "kind": "reduce",
            "inputs": [ {"stage": "double", "output": 0} ],
            "ops": [ { "op": "sum", "inputs": "all" } ],
            "outputs": [ {"op": "sum"} ]
        }
    ]
}"#;

fn service_reg() -> Arc<OpRegistry> {
    let mut r = OpRegistry::new();
    r.register_cpu("double", 1, |args: &[Value]| {
        Ok(vec![Value::Scalar(args[0].as_scalar()? * 2.0)])
    })
    .unwrap();
    r.register_cpu("sum", 1, |args: &[Value]| {
        let mut s = 0.0f32;
        for a in args {
            s += a.as_scalar()?;
        }
        Ok(vec![Value::Scalar(s)])
    })
    .unwrap();
    Arc::new(r)
}

fn op_end(worker: u64, job: u64, dur_us: u64) -> TraceEvent {
    let mut ev = TraceEvent::of(EventKind::OpEnd);
    ev.ts_us = 1;
    ev.worker = worker;
    ev.job = job;
    ev.dur_us = dur_us;
    ev
}

#[test]
fn service_job_report_joins_per_tenant_rollups() {
    let t = JobTable::new(service_reg(), 4, AssignPolicy::default(), 4, 8);
    let ja = Endpoint::submit(&*t, "alice", DOUBLE_SUM, 1).unwrap();
    let jb = Endpoint::submit(&*t, "bob", DOUBLE_SUM, 1).unwrap();

    // two workers ship heartbeat batches attributing spans to both jobs
    Endpoint::trace_batch(&*t, 1, vec![op_end(1, ja, 100), op_end(1, jb, 40)]);
    Endpoint::trace_batch(&*t, 2, vec![op_end(2, ja, 60)]);

    let rows = Endpoint::job_report(&*t, 0);
    let ra = rows.iter().find(|r| r.job == ja).unwrap();
    let rb = rows.iter().find(|r| r.job == jb).unwrap();
    assert_eq!((ra.ops, ra.busy_us), (2, 160), "{ra:?}");
    assert_eq!((rb.ops, rb.busy_us), (1, 40), "{rb:?}");
    assert_eq!(ra.tenant, "alice");
    assert_eq!(rb.tenant, "bob");

    // the `htap top` feed: per-(worker, job) rows with tenants joined in
    let util = Endpoint::utilization(&*t);
    assert_eq!(util.len(), 3, "{util:?}");
    let w1a = util.iter().find(|r| r.worker == 1 && r.job == ja).unwrap();
    assert_eq!((w1a.ops, w1a.busy_us, w1a.tenant.as_str()), (1, 100, "alice"));
    let table = render_util_table(&util);
    assert!(table.contains("alice") && table.contains("bob"), "{table}");

    // per-tenant rollups sum to everything the collector ingested
    let total_ops: u64 = t.collector().job_rollups().iter().map(|r| r.ops).sum();
    assert_eq!(total_ops, 3);
}
