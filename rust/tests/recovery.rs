//! Crash-recovery integration: the elastic-membership + checkpoint layer
//! over real sockets.
//!
//! * a worker that goes silent mid-run misses its lease; the manager
//!   re-issues its work and the survivors still produce *bit-identical*
//!   reduce outputs;
//! * a restarted worker warm-starts from its surviving spill directory and
//!   serves those chunks from disk instead of re-reading the source;
//! * a manager checkpoint (completion journal + chunk catalog) restores
//!   into a fresh manager which finishes the run without re-executing the
//!   replayed instances.

use htap::app::{build_workflow, stage_bindings, AppParams};
use htap::config::RunConfig;
use htap::coordinator::{
    checkpoint, worker::run_worker_staged, AssignPolicy, Manager, WorkRequest, WorkSource,
    WorkerStaging,
};
use htap::data::staging::{ChunkSource, SpillTier};
use htap::data::{StagingCache, SynthConfig, SynthSource};
use htap::metrics::{MetricsHub, MetricsReport};
use htap::net::{ManagerServer, RemoteManager};
use htap::runtime::calibrate::SharedProfiles;
use htap::runtime::{ArtifactManifest, Value};
use std::sync::Arc;

const TILE: usize = 64;
const SEED: u64 = 31;

fn staged_worker_cfg(n_tiles: usize) -> RunConfig {
    RunConfig {
        tile_size: TILE,
        n_tiles,
        cpu_workers: 1,
        gpu_workers: 0,
        window: 2,
        ..Default::default()
    }
}

/// Spawn a full staged TCP worker and return its metrics report.
fn spawn_staged_worker(
    addr: String,
    workflow: Arc<htap::dataflow::Workflow>,
    n_tiles: usize,
    worker_id: u64,
    spill: Option<SpillTier>,
    cap: usize,
) -> std::thread::JoinHandle<MetricsReport> {
    std::thread::spawn(move || {
        let source = Arc::new(RemoteManager::connect(&addr).unwrap());
        let chunks = Arc::new(SynthSource::new(SynthConfig::for_tile_size(TILE, SEED), n_tiles));
        let staging = WorkerStaging {
            cache: StagingCache::new_tiered(chunks, cap, 2, spill),
            worker_id,
            prefetch_budget: 2,
        };
        let metrics = Arc::new(MetricsHub::new());
        run_worker_staged(
            source,
            workflow,
            staged_worker_cfg(n_tiles),
            Arc::new(ArtifactManifest::discover_or_empty()),
            metrics.clone(),
            stage_bindings(),
            SharedProfiles::fresh(),
            Some(staging),
        )
        .unwrap();
        metrics.report()
    })
}

/// One clean staged run of the WSI workflow (+ classification reduce);
/// returns the reduce outputs.
fn clean_reduce_outputs(n_tiles: usize) -> Vec<Value> {
    let workflow = Arc::new(build_workflow(&AppParams::for_tile_size(TILE), true));
    let manager = Manager::new_staged(workflow.clone(), n_tiles, AssignPolicy::default()).unwrap();
    let server = ManagerServer::bind("127.0.0.1:0", manager.clone()).unwrap();
    let addr = server.local_addr();
    let srv = std::thread::spawn(move || server.serve());
    spawn_staged_worker(addr, workflow, n_tiles, 1, None, 16).join().unwrap();
    srv.join().unwrap().unwrap();
    assert!(manager.error().is_none(), "{:?}", manager.error());
    manager.reduce_outputs("classification").expect("classification ran")
}

#[test]
fn killed_worker_mid_run_still_yields_bit_identical_reduce_outputs() {
    let n_tiles = 5;
    let baseline = clean_reduce_outputs(n_tiles);

    // faulty run: a victim registers with a short lease, grabs work, then
    // goes silent (sockets held open, so only lease expiry can free it)
    let workflow = Arc::new(build_workflow(&AppParams::for_tile_size(TILE), true));
    let manager = Manager::new_staged(workflow.clone(), n_tiles, AssignPolicy::default()).unwrap();
    let server = ManagerServer::bind("127.0.0.1:0", manager.clone()).unwrap();
    let addr = server.local_addr();
    let srv = std::thread::spawn(move || server.serve());

    let victim = RemoteManager::connect(&addr).unwrap();
    victim.register(2, 150);
    let stranded = victim.request_work(&WorkRequest {
        capacity: 3,
        worker: 2,
        ..Default::default()
    });
    assert!(!stranded.assignments.is_empty(), "the victim must strand real leases");

    // a healthy worker (heartbeating on the default lease) finishes the
    // run, including the victim's re-issued instances
    let healthy = spawn_staged_worker(addr, workflow, n_tiles, 1, None, 16);
    let report = healthy.join().unwrap();
    drop(victim); // only now — the server drains open connections on exit
    srv.join().unwrap().unwrap();

    assert!(manager.error().is_none(), "{:?}", manager.error());
    let (done, total) = manager.progress();
    assert_eq!(done, total, "the workflow must complete despite the crash");
    // the victim's membership was reaped by the lease sweeper
    assert_eq!(manager.member_count(), 0);
    assert!(report.total_executed() > 0);
    let outs = manager.reduce_outputs("classification").expect("classification ran");
    assert_eq!(outs, baseline, "reduce outputs must be bit-identical to the no-fault run");
}

#[test]
fn warm_restarted_worker_serves_recovered_chunks_from_its_spill_tier() {
    let n_tiles = 6;
    let spill_root =
        std::env::temp_dir().join(format!("htap-recovery-warm-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&spill_root);
    let workflow = Arc::new(build_workflow(&AppParams::for_tile_size(TILE), false));

    // first incarnation: a one-chunk memory tier forces demotions, so the
    // spill directory ends the run holding most of the dataset
    {
        let manager =
            Manager::new_staged(workflow.clone(), n_tiles, AssignPolicy::default()).unwrap();
        let server = ManagerServer::bind("127.0.0.1:0", manager.clone()).unwrap();
        let addr = server.local_addr();
        let srv = std::thread::spawn(move || server.serve());
        let tier = SpillTier::create(spill_root.join("worker-1"), 32).unwrap();
        let report =
            spawn_staged_worker(addr, workflow.clone(), n_tiles, 1, Some(tier), 1).join().unwrap();
        srv.join().unwrap().unwrap();
        assert!(manager.error().is_none(), "{:?}", manager.error());
        assert!(report.staging.spill_evicted > 0, "nothing demoted; warm restart untestable");
    }
    let recovered =
        SpillTier::recover(spill_root.join("worker-1"), 32).unwrap().resident_chunks();
    assert!(!recovered.is_empty(), "the spill dir must survive the first incarnation");

    // second incarnation ("the worker crashed and restarted"): recover the
    // spill tier instead of clearing it — the recovered chunks are
    // re-advertised to the fresh manager and served from disk, never
    // re-read from the source
    let manager = Manager::new_staged(workflow.clone(), n_tiles, AssignPolicy::default()).unwrap();
    let server = ManagerServer::bind("127.0.0.1:0", manager.clone()).unwrap();
    let addr = server.local_addr();
    let srv = std::thread::spawn(move || server.serve());
    let tier = SpillTier::recover(spill_root.join("worker-1"), 32).unwrap();
    let report = spawn_staged_worker(addr, workflow, n_tiles, 1, Some(tier), 1).join().unwrap();
    srv.join().unwrap().unwrap();
    assert!(manager.error().is_none(), "{:?}", manager.error());
    let (done, total) = manager.progress();
    assert_eq!(done, total);
    assert!(
        report.staging.spill_hits >= recovered.len() as u64,
        "recovered chunks must be promoted from disk, not cold-read: {} < {}",
        report.staging.spill_hits,
        recovered.len()
    );
    let _ = std::fs::remove_dir_all(&spill_root);
}

#[test]
fn manager_checkpoint_restores_into_a_fresh_manager_without_reexecution() {
    let n_tiles = 4;
    let workflow = Arc::new(build_workflow(&AppParams::for_tile_size(TILE), false));
    let ckpt_dir =
        std::env::temp_dir().join(format!("htap-recovery-ckpt-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&ckpt_dir);

    // first manager: journal on, drive part of the run in-process (no TCP
    // needed to make progress), checkpoint, then "crash" (drop it)
    let first = Manager::new_staged(workflow.clone(), n_tiles, AssignPolicy::default()).unwrap();
    first.enable_journal();
    let batch = first.request_work(&WorkRequest { capacity: 2, worker: 1, ..Default::default() });
    assert_eq!(batch.assignments.len(), 2);
    let chunks = Arc::new(SynthSource::new(SynthConfig::for_tile_size(TILE, SEED), n_tiles));
    for a in &batch.assignments {
        let payload = chunks.load(a.chunk).unwrap();
        let outs =
            htap::dataflow::run_stage_serial(&workflow.stages[a.stage_idx], &payload).unwrap();
        first.complete(a.instance_id, outs);
    }
    checkpoint::write_checkpoint(&ckpt_dir, &first).unwrap();
    let (done_before, total) = first.progress();
    assert_eq!(done_before, 2);
    drop(first);

    // second manager: restore the checkpoint, then let a real TCP worker
    // finish the remainder
    let second = Manager::new_staged(workflow.clone(), n_tiles, AssignPolicy::default()).unwrap();
    second.enable_journal();
    let (journal, catalog) = checkpoint::load_checkpoint(&ckpt_dir).unwrap().expect("snapshot");
    let replayed = second.restore_from(journal, catalog).unwrap();
    assert_eq!(replayed, 2);
    assert_eq!(second.progress().0, done_before, "restore must not lose progress");

    let server = ManagerServer::bind("127.0.0.1:0", second.clone()).unwrap();
    let addr = server.local_addr();
    let srv = std::thread::spawn(move || server.serve());
    let report = spawn_staged_worker(addr, workflow, n_tiles, 1, None, 16).join().unwrap();
    srv.join().unwrap().unwrap();

    assert!(second.error().is_none(), "{:?}", second.error());
    let (done, after_total) = second.progress();
    assert_eq!((done, after_total), (total, total));
    // the worker only executed what the checkpoint had not already done:
    // the remaining segmentation instances (9 ops each) plus every
    // features instance (3 ops each) — never the 2 replayed ones
    assert_eq!(report.total_executed(), (9 * (n_tiles - 2) + 3 * n_tiles) as u64);
    let _ = std::fs::remove_dir_all(&ckpt_dir);
}
