//! Deterministic interleaving exploration of the WRM dispatch protocol
//! and the staging cache (`cargo test --features htap-model --test
//! model_wrm`).
//!
//! These tests run the *real* concurrency core — `Wrm::submit` /
//! `cpu_thread` / `gpu_thread` / `wait_completions`, and
//! `StagingCache::prefetch` / `get` — under the virtual scheduler in
//! `htap::runtime::sync::model`, which enumerates bounded thread
//! interleavings (CHESS-style preemption bounding) and reports deadlocks
//! and lost wakeups instead of hanging.  Each scenario asserts:
//!
//! * **no deadlock / no lost wakeup**: `report.deadlocks == 0`;
//! * **exactly-once completion**: every submitted stage instance
//!   completes exactly once, with the expected outputs;
//! * **single-writer `produced` slots**: every fine-grain op executes
//!   exactly once per instance (counted by the op bodies themselves).
//!
//! Scenarios use `Policy::Fcfs` — PATS's EWMA-sorted queue is
//! wall-clock-dependent, which would break schedule replay determinism.

#![cfg(feature = "htap-model")]

use htap::config::{Placement, Policy, RunConfig};
use htap::coordinator::manager::Assignment;
use htap::coordinator::placement::NodeTopology;
use htap::coordinator::wrm::Wrm;
use htap::data::staging::{ChunkSource, StagingCache};
use htap::dataflow::{OpRegistry, StageKind, Workflow, WorkflowBuilder};
use htap::metrics::MetricsHub;
use htap::runtime::calibrate::SharedProfiles;
use htap::runtime::sync::model::{explore, ModelConfig};
use htap::runtime::sync::thread;
use htap::runtime::{ArtifactManifest, Value};
use htap::Result;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Keep the per-test schedule budget modest: every schedule is a full
/// execution with real (virtualised) threads.  The explorer flips the
/// deepest untried branch first, so even a truncated exploration covers
/// the interleavings closest to the initial schedule densely.
fn cfg_model() -> ModelConfig {
    ModelConfig { max_schedules: 250, preemption_bound: 2 }
}

fn run_cfg(cpu: usize, gpu: usize) -> RunConfig {
    RunConfig {
        n_tiles: 2,
        cpu_workers: cpu,
        gpu_workers: gpu,
        policy: Policy::Fcfs,
        window: 2,
        ..Default::default()
    }
}

/// A single-stage workflow `inc(chunk) -> inc -> add(b, a)` whose op
/// bodies count executions into `counts` (single-writer witness).
/// `gpu_artifact` attaches a (deliberately unbuilt) accelerator member to
/// every op so GPU controllers consider them.
fn diamond_workflow(counts: &Arc<[AtomicUsize; 3]>, gpu_artifact: bool) -> Arc<Workflow> {
    let mut reg = OpRegistry::new();
    let register = |reg: &mut OpRegistry, name: &str, idx: usize, two_inputs: bool| {
        let counts = counts.clone();
        let f = move |args: &[Value]| -> Result<Vec<Value>> {
            counts[idx].fetch_add(1, Ordering::Relaxed);
            let a = args[0].as_scalar()?;
            let out = if two_inputs { a + args[1].as_scalar()? } else { a + 1.0 };
            Ok(vec![Value::Scalar(out)])
        };
        if gpu_artifact {
            reg.register(
                htap::dataflow::OpSpec::hybrid(name, 1, f, "missing_artifact")
                    .with_profile(10.0, 0.1, 0.0),
            )
            .unwrap();
        } else {
            reg.register_cpu(name, 1, f).unwrap();
        }
    };
    register(&mut reg, "inc_a", 0, false);
    register(&mut reg, "inc_b", 1, false);
    register(&mut reg, "add_d", 2, true);
    let mut wb = WorkflowBuilder::new("model-diamond", reg);
    let mut s0 = wb.stage("s0", StageKind::PerChunk);
    let c = s0.input_chunk();
    let a = s0.add_op("inc_a", &[c]).unwrap();
    let b = s0.add_op("inc_b", &[a.out()]).unwrap();
    let d = s0.add_op("add_d", &[b.out(), a.out()]).unwrap();
    s0.export(d.out()).unwrap();
    wb.add_stage(s0).unwrap();
    Arc::new(wb.build().unwrap())
}

fn assignment(id: u64, x: f32) -> Assignment {
    Assignment {
        instance_id: id,
        stage_idx: 0,
        chunk: id,
        inputs: vec![Value::Scalar(x)],
        needs_chunk: false,
        locality: false,
        replica: false,
    }
}

fn new_wrm(workflow: Arc<Workflow>, cfg: RunConfig) -> Arc<Wrm> {
    Wrm::new(
        workflow,
        cfg,
        Arc::new(ArtifactManifest::empty()),
        Arc::new(MetricsHub::new()),
        HashMap::new(),
        SharedProfiles::fresh(),
    )
}

/// Drain completions until `want` instances have finished; returns
/// (instance id -> outputs).  Panics (failing the schedule) on errors or
/// duplicate completions.
fn collect_completions(wrm: &Arc<Wrm>, want: usize) -> HashMap<u64, Vec<Value>> {
    let mut done: HashMap<u64, Vec<Value>> = HashMap::new();
    while done.len() < want {
        for (inst, result) in wrm.wait_completions() {
            let outs = result.unwrap_or_else(|e| panic!("instance {inst} failed: {e}"));
            assert!(
                done.insert(inst, outs).is_none(),
                "instance {inst} completed twice"
            );
        }
    }
    done
}

/// x -> a = x+1, b = a+1, d = b+a = 2x+3.
fn expect_diamond(done: &HashMap<u64, Vec<Value>>, id: u64, x: f32) {
    let outs = &done[&id];
    assert_eq!(outs.len(), 1);
    assert_eq!(outs[0].as_scalar().unwrap(), 2.0 * x + 3.0);
}

#[test]
fn two_cpu_threads_and_completer_no_deadlock_exactly_once() {
    let report = explore("wrm-2cpu", cfg_model(), || {
        let counts: Arc<[AtomicUsize; 3]> = Arc::new(Default::default());
        let wrm = new_wrm(diamond_workflow(&counts, false), run_cfg(2, 0));
        let (w1, w2) = (wrm.clone(), wrm.clone());
        let t1 = thread::spawn(move || w1.cpu_thread(0));
        let t2 = thread::spawn(move || w2.cpu_thread(1));
        // submit races against the device threads' startup + wait
        wrm.submit(assignment(1, 1.0));
        wrm.submit(assignment(2, 5.0));
        let done = collect_completions(&wrm, 2);
        expect_diamond(&done, 1, 1.0);
        expect_diamond(&done, 2, 5.0);
        wrm.shutdown();
        t1.join().unwrap();
        t2.join().unwrap();
        // single-writer produced slots: each op ran once per instance
        for (i, c) in counts.iter().enumerate() {
            assert_eq!(c.load(Ordering::Relaxed), 2, "op {i} execution count");
        }
    });
    assert_eq!(report.deadlocks, 0, "{:?}", report.first_deadlock);
    assert!(report.schedules > 1, "explorer drove only one schedule");
}

#[test]
fn gpu_controller_falls_back_to_cpu_member_no_deadlock() {
    // cpu_workers = 0: the controller must take every task; the declared
    // artifact is absent from the (empty) manifest, so each op degrades to
    // its CPU member on the controller thread.
    let report = explore("wrm-gpu-fallback", cfg_model(), || {
        let counts: Arc<[AtomicUsize; 3]> = Arc::new(Default::default());
        let wrm = new_wrm(diamond_workflow(&counts, true), run_cfg(0, 1));
        let topo = NodeTopology::host();
        let w = wrm.clone();
        let t = thread::spawn(move || w.gpu_thread(0, &topo, Placement::Os));
        wrm.submit(assignment(7, 2.0));
        let done = collect_completions(&wrm, 1);
        expect_diamond(&done, 7, 2.0);
        wrm.shutdown();
        t.join().unwrap();
        for (i, c) in counts.iter().enumerate() {
            assert_eq!(c.load(Ordering::Relaxed), 1, "op {i} execution count");
        }
    });
    assert_eq!(report.deadlocks, 0, "{:?}", report.first_deadlock);
}

#[test]
fn poke_and_shutdown_wake_a_blocked_completer() {
    // The completer parks on cv_done with nothing queued; poke() and
    // shutdown() from another thread must always wake it (a lost wakeup
    // here would surface as a deadlock in some schedule).
    let report = explore("wrm-poke", cfg_model(), || {
        let counts: Arc<[AtomicUsize; 3]> = Arc::new(Default::default());
        let wrm = new_wrm(diamond_workflow(&counts, false), run_cfg(1, 0));
        let w = wrm.clone();
        let poker = thread::spawn(move || {
            w.poke();
            w.shutdown();
        });
        // blocks until the poke (or shutdown) lands — a lost wakeup here
        // deadlocks this schedule and the explorer reports it
        let events = wrm.wait_completions();
        assert!(events.is_empty(), "nothing was submitted");
        poker.join().unwrap();
        // shutdown has been called: the drain must return immediately
        assert!(wrm.wait_completions().is_empty());
    });
    assert_eq!(report.deadlocks, 0, "{:?}", report.first_deadlock);
}

/// Scalar chunk source for the cache scenario.
struct ScalarSource;

impl ChunkSource for ScalarSource {
    fn n_chunks(&self) -> usize {
        4
    }
    fn load(&self, chunk: htap::coordinator::ChunkId) -> Result<Vec<Value>> {
        Ok(vec![Value::Scalar(chunk as f32 * 10.0)])
    }
    fn describe(&self) -> String {
        "scalar".into()
    }
}

#[test]
fn cache_prefetch_races_demand_get_without_lost_wakeup() {
    // The prefetcher claims a chunk (Loading), the demand `get` for the
    // same chunk must park and be woken when the payload lands — in every
    // interleaving of the claim / load / record / get steps.
    let report = explore("cache-race", cfg_model(), || {
        let cache = StagingCache::new(Arc::new(ScalarSource), 2usize, 1);
        cache.prefetch(&[1]);
        let g1 = cache.get(1).unwrap();
        assert_eq!(g1[0].as_scalar().unwrap(), 10.0);
        // a second get is a pure hit; a different chunk is a demand load
        // racing the (now idle) prefetcher's queue wait
        let g2 = cache.get(2).unwrap();
        assert_eq!(g2[0].as_scalar().unwrap(), 20.0);
        cache.shutdown();
    });
    assert_eq!(report.deadlocks, 0, "{:?}", report.first_deadlock);
    assert!(report.schedules > 1, "explorer drove only one schedule");
}
