//! Function-variant equivalence: the rust CPU implementations vs the AOT
//! JAX/Pallas artifacts, per pipeline operation, on synthetic tiles.
//!
//! This is the cross-layer correctness contract: the WRM may execute either
//! member of a variant, so the two must agree (exactly for masks and maps;
//! structurally for labelling ops, whose algorithms legitimately differ —
//! see DESIGN.md).

use htap::app::ops;
use htap::data::{SynthConfig, TileSynthesizer};
use htap::imgproc::label::canonical_labels;
use htap::imgproc::Gray;
use htap::runtime::pjrt::DeviceExecutor;
use htap::runtime::{ArtifactManifest, Value};

const TILE: usize = 64;

/// These tests require the AOT artifacts (`make artifacts`) and a real
/// PJRT-backed `xla` crate; without them they skip (pass vacuously) so the
/// CPU-only build stays green.  A probe execution guards against the case
/// where artifacts exist but the offline xla shim (which cannot compile
/// HLO) is in use.
fn executor() -> Option<DeviceExecutor> {
    let m = ArtifactManifest::discover().ok()?;
    if !m.has("fill_holes", TILE) {
        return None;
    }
    {
        let mut probe = DeviceExecutor::new(m.clone()).ok()?;
        let z = Value::Tensor(htap::runtime::HostTensor::zeros(vec![TILE, TILE]));
        if probe.run("fill_holes", TILE, &[z]).is_err() {
            return None;
        }
    }
    Some(DeviceExecutor::new(m).expect("PJRT CPU client"))
}

macro_rules! require_executor {
    () => {
        match executor() {
            Some(ex) => ex,
            None => {
                eprintln!("skipping: AOT artifacts not built (run `make artifacts`)");
                return;
            }
        }
    };
}

fn tile(seed: u64) -> Value {
    let synth = TileSynthesizer::new(SynthConfig::for_tile_size(TILE, 21));
    Value::Tensor(synth.tissue_tile(seed).to_tensor())
}

fn gray(v: &Value) -> Gray {
    Gray::from_tensor(v.as_tensor().unwrap()).unwrap()
}

fn max_diff(a: &Value, b: &Value) -> f32 {
    a.as_tensor().unwrap().max_abs_diff(b.as_tensor().unwrap()).unwrap()
}

#[test]
fn hema_prep_variants_agree() {
    let mut ex = require_executor!();
    for seed in 0..3 {
        let rgb = tile(seed);
        let cpu = ops::hema_prep(&[rgb.clone()]).unwrap();
        let gpu = ex.run("hema_prep", TILE, &[rgb]).unwrap();
        assert!(max_diff(&cpu[0], &gpu[0]) < 0.05, "seed {seed}");
    }
}

#[test]
fn morph_open_variants_agree() {
    let mut ex = require_executor!();
    let rgb = tile(1);
    let hema = ops::hema_prep(&[rgb]).unwrap().remove(0);
    let cpu = ops::morph_open(&[hema.clone()]).unwrap();
    let gpu = ex.run("morph_open", TILE, &[hema]).unwrap();
    assert!(max_diff(&cpu[0], &gpu[0]) < 0.05);
}

#[test]
fn recon_to_nuclei_variants_agree() {
    let mut ex = require_executor!();
    let rgb = tile(2);
    let hema = ops::hema_prep(&[rgb]).unwrap().remove(0);
    let opened = ops::morph_open(&[hema]).unwrap().remove(0);
    let args = [opened, Value::Scalar(20.0), Value::Scalar(5.0)];
    let cpu = ops::recon_to_nuclei(&args).unwrap();
    let gpu = ex.run("recon_to_nuclei", TILE, &args).unwrap();
    // binary masks: tolerate a tiny fringe of pixels where the dome height
    // sits within float rounding of the threshold
    let a = gray(&cpu[0]);
    let b = gray(&gpu[0]);
    let differing = a.px.iter().zip(&b.px).filter(|(x, y)| x != y).count();
    assert!(differing <= (TILE * TILE) / 200, "masks differ in {differing} px");
}

#[test]
fn fill_holes_and_area_threshold_variants_agree() {
    let mut ex = require_executor!();
    let rgb = tile(3);
    let hema = ops::hema_prep(&[rgb]).unwrap().remove(0);
    let opened = ops::morph_open(&[hema]).unwrap().remove(0);
    let cand = ops::recon_to_nuclei(&[opened, Value::Scalar(20.0), Value::Scalar(5.0)])
        .unwrap()
        .remove(0);
    let cpu_fill = ops::fill_holes(&[cand.clone()]).unwrap();
    let gpu_fill = ex.run("fill_holes", TILE, &[cand]).unwrap();
    assert_eq!(max_diff(&cpu_fill[0], &gpu_fill[0]), 0.0, "fill_holes is exact");

    let args = [cpu_fill[0].clone(), Value::Scalar(5.0), Value::Scalar(500.0)];
    let cpu_area = ops::area_threshold(&args).unwrap();
    let gpu_area = ex.run("area_threshold", TILE, &args).unwrap();
    assert_eq!(max_diff(&cpu_area[0], &gpu_area[0]), 0.0, "area_threshold is exact");
}

#[test]
fn bwlabel_variants_same_components() {
    // CPU: compact union-find ids; GPU: max-flat-index propagation.
    // Canonical forms must match exactly.
    let mut ex = require_executor!();
    let rgb = tile(4);
    let hema = ops::hema_prep(&[rgb]).unwrap().remove(0);
    let cand = ops::recon_to_nuclei(&[hema, Value::Scalar(20.0), Value::Scalar(5.0)])
        .unwrap()
        .remove(0);
    let cpu = ops::bwlabel(&[cand.clone()]).unwrap();
    let gpu = ex.run("bwlabel", TILE, &[cand]).unwrap();
    let ca = canonical_labels(&gray(&cpu[0]));
    let cb = canonical_labels(&gray(&gpu[0]));
    assert_eq!(ca.px, cb.px, "same connected components");
}

#[test]
fn distance_variants_agree() {
    let mut ex = require_executor!();
    let rgb = tile(5);
    let hema = ops::hema_prep(&[rgb]).unwrap().remove(0);
    let cand = ops::recon_to_nuclei(&[hema, Value::Scalar(20.0), Value::Scalar(5.0)])
        .unwrap()
        .remove(0);
    let cpu = ops::distance_op(&[cand.clone()]).unwrap();
    let gpu = ex.run("distance", TILE, &[cand]).unwrap();
    assert_eq!(max_diff(&cpu[0], &gpu[0]), 0.0, "chessboard distance is exact");
}

#[test]
fn morph_recon_variants_agree() {
    let mut ex = require_executor!();
    let rgb = tile(6);
    let mask = ops::hema_prep(&[rgb]).unwrap().remove(0);
    let marker = {
        let g = gray(&mask);
        let px = g.px.iter().map(|v| (v - 30.0).max(0.0)).collect();
        Value::Tensor(Gray::new(g.h, g.w, px).unwrap().to_tensor())
    };
    let cpu = ops::morph_recon(&[marker.clone(), mask.clone()]).unwrap();
    let gpu = ex.run("morph_recon", TILE, &[marker, mask]).unwrap();
    assert!(max_diff(&cpu[0], &gpu[0]) < 1e-3, "reconstruction agrees");
}

#[test]
fn watershed_variants_same_region_count_and_coverage() {
    // Priority-flood (CPU) vs synchronous flood (artifact): different
    // algorithms like the paper's OpenCV/Körbes pair — compare structure.
    let mut ex = require_executor!();
    let rgb = tile(7);
    let hema = ops::hema_prep(&[rgb]).unwrap().remove(0);
    let opened = ops::morph_open(&[hema]).unwrap().remove(0);
    let cand = ops::recon_to_nuclei(&[opened, Value::Scalar(20.0), Value::Scalar(5.0)])
        .unwrap()
        .remove(0);
    let filled = ops::fill_holes(&[cand]).unwrap().remove(0);
    let kept = ops::area_threshold(&[filled, Value::Scalar(5.0), Value::Scalar(500.0)])
        .unwrap()
        .remove(0);
    let pw_cpu = ops::pre_watershed(&[kept.clone()]).unwrap();
    let cpu = ops::watershed_op(&[pw_cpu[0].clone(), pw_cpu[1].clone(), kept.clone()]).unwrap();

    let k = ex
        .execute_resident("pre_watershed", TILE, &[htap::runtime::pjrt::ExecInput::Host(&kept)])
        .unwrap();
    let pw_gpu = ex.download(k).unwrap();
    let gpu = ex
        .run("watershed", TILE, &[pw_gpu[0].clone(), pw_gpu[1].clone(), kept.clone()])
        .unwrap();

    let a = gray(&cpu[0]);
    let b = gray(&gpu[0]);
    // identical support
    let support_mismatch =
        a.px.iter().zip(&b.px).filter(|(x, y)| (**x > 0.0) != (**y > 0.0)).count();
    assert_eq!(support_mismatch, 0, "watershed coverage differs");
    // same number of regions
    let count = |g: &Gray| {
        let mut ids: Vec<u32> = g.px.iter().filter(|&&v| v > 0.0).map(|&v| v as u32).collect();
        ids.sort_unstable();
        ids.dedup();
        ids.len()
    };
    assert_eq!(count(&a), count(&b), "watershed region counts differ");
}

#[test]
fn feature_graph_variants_agree() {
    let mut ex = require_executor!();
    let rgb = tile(8);
    let args = [rgb, Value::Scalar(30.0)];
    let cpu = ops::feature_graph(&args).unwrap();
    let gpu = ex.run("feature_graph", TILE, &args).unwrap();
    assert!(max_diff(&cpu[0], &gpu[0]) < 0.05, "hema image");
    assert!(max_diff(&cpu[1], &gpu[1]) < 0.5, "gradient magnitude");
    // stats sum over 4096 px: compare with fp accumulation tolerance
    let a = cpu[3].as_tensor().unwrap();
    let b = gpu[3].as_tensor().unwrap();
    for (i, (x, y)) in a.data().iter().zip(b.data()).enumerate() {
        let tol = (x.abs() * 1e-3).max(2.0);
        assert!((x - y).abs() <= tol, "stats[{i}]: {x} vs {y}");
    }
}

#[test]
fn fused_segment_tile_matches_pipelined_chain() {
    // the monolithic artifact equals composing the per-op artifacts
    let mut ex = require_executor!();
    let rgb = tile(9);
    let (h, t, lo, hi) = (
        Value::Scalar(20.0),
        Value::Scalar(5.0),
        Value::Scalar(5.0),
        Value::Scalar(500.0),
    );
    let fused = ex
        .run("segment_tile", TILE, &[rgb.clone(), h.clone(), t.clone(), lo.clone(), hi.clone()])
        .unwrap();
    let hema = ex.run("hema_prep", TILE, &[rgb]).unwrap().remove(0);
    let opened = ex.run("morph_open", TILE, &[hema]).unwrap().remove(0);
    let cand = ex.run("recon_to_nuclei", TILE, &[opened, h, t]).unwrap().remove(0);
    let filled = ex.run("fill_holes", TILE, &[cand]).unwrap().remove(0);
    let kept = ex.run("area_threshold", TILE, &[filled, lo, hi]).unwrap().remove(0);
    let pw = ex.run("pre_watershed", TILE, &[kept.clone()]).unwrap();
    let labels = ex.run("watershed", TILE, &[pw[0].clone(), pw[1].clone(), kept]).unwrap();
    assert_eq!(max_diff(&fused[0], &labels[0]), 0.0);
}
