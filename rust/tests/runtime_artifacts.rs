//! Integration: load real AOT artifacts through PJRT and sanity-check the
//! numerics + the device-resident (DL) path.
//!
//! Requires `make artifacts` to have run (the Makefile `test` target
//! guarantees this).

use htap::runtime::pjrt::{DeviceExecutor, ExecInput};
use htap::runtime::{ArtifactManifest, HostTensor, Value};

/// These tests require the AOT artifacts (`make artifacts`) and a real
/// PJRT-backed `xla` crate; without them they skip (pass vacuously) so the
/// CPU-only build stays green.  A probe execution guards against the case
/// where artifacts exist but the offline xla shim (which cannot compile
/// HLO) is in use; the probe uses a throwaway executor so stats-sensitive
/// tests (compile/execution counters) start from zero.
fn executor() -> Option<DeviceExecutor> {
    let manifest = ArtifactManifest::discover().ok()?;
    if !manifest.has("fill_holes", 64) {
        return None;
    }
    {
        let mut probe = DeviceExecutor::new(manifest.clone()).ok()?;
        let z = Value::Tensor(HostTensor::zeros(vec![64, 64]));
        if probe.run("fill_holes", 64, &[z]).is_err() {
            return None;
        }
    }
    Some(DeviceExecutor::new(manifest).expect("PJRT CPU client"))
}

macro_rules! require_executor {
    () => {
        match executor() {
            Some(ex) => ex,
            None => {
                eprintln!("skipping: AOT artifacts not built (run `make artifacts`)");
                return;
            }
        }
    };
}

fn blob_mask(s: usize) -> HostTensor {
    // Two rectangular blobs, one containing a hole.
    let mut px = vec![0.0f32; s * s];
    for y in 4..14 {
        for x in 4..14 {
            px[y * s + x] = 1.0;
        }
    }
    px[8 * s + 8] = 0.0; // hole
    for y in 20..28 {
        for x in 30..44 {
            px[y * s + x] = 1.0;
        }
    }
    HostTensor::new(vec![s, s], px).unwrap()
}

#[test]
fn manifest_covers_all_pipeline_ops() {
    let Ok(m) = ArtifactManifest::discover() else {
        eprintln!("skipping: AOT artifacts not built (run `make artifacts`)");
        return;
    };
    for op in [
        "rbc_detect",
        "morph_open",
        "recon_to_nuclei",
        "morph_recon",
        "fill_holes",
        "bwlabel",
        "area_threshold",
        "distance",
        "pre_watershed",
        "watershed",
        "feature_graph",
        "segment_tile",
    ] {
        assert!(m.has(op, 64), "missing artifact {op}@64");
    }
}

#[test]
fn fill_holes_fills_interior_hole() {
    let mut ex = require_executor!();
    let mask = blob_mask(64);
    let out = ex.run("fill_holes", 64, &[Value::Tensor(mask.clone())]).unwrap();
    let filled = out[0].as_tensor().unwrap();
    // the hole at (8, 8) must now be foreground
    assert_eq!(filled.at2(8, 8), 1.0);
    // background far away untouched
    assert_eq!(filled.at2(0, 0), 0.0);
    // extensivity: filled >= mask everywhere
    for (a, b) in filled.data().iter().zip(mask.data()) {
        assert!(a >= b);
    }
}

#[test]
fn bwlabel_finds_two_components() {
    let mut ex = require_executor!();
    let mask = blob_mask(64);
    let out = ex.run("bwlabel", 64, &[Value::Tensor(mask.clone())]).unwrap();
    let labels = out[0].as_tensor().unwrap();
    let mut ids: Vec<u32> = labels
        .data()
        .iter()
        .filter(|&&v| v > 0.0)
        .map(|&v| v as u32)
        .collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), 2, "expected 2 components");
    // label support == mask support
    for (l, m) in labels.data().iter().zip(mask.data()) {
        assert_eq!(*l > 0.0, *m > 0.0);
    }
}

#[test]
fn distance_max_matches_blob_radius() {
    let mut ex = require_executor!();
    let mask = blob_mask(64);
    let out = ex.run("distance", 64, &[Value::Tensor(mask)]).unwrap();
    let d = out[0].as_tensor().unwrap();
    let max = d.data().iter().fold(0.0f32, |a, &b| a.max(b));
    // 10x10 blob would have in-radius 5, but the hole at (8,8) caps the
    // farthest-from-background pixel at chessboard distance 4.
    assert_eq!(max, 4.0);
}

#[test]
fn resident_chaining_avoids_transfers() {
    // fill_holes -> bwlabel chained on-device: the intermediate mask must
    // not cross the host boundary (paper §IV-C data-locality assignment).
    let mut ex = require_executor!();
    let mask = blob_mask(64);
    let v = Value::Tensor(mask);

    let k1 = ex.execute_resident("fill_holes", 64, &[ExecInput::Host(&v)]).unwrap();
    let up_before = ex.stats.uploads;
    let down_before = ex.stats.downloads;
    let k2 = ex.execute_resident("bwlabel", 64, &[ExecInput::Resident(k1)]).unwrap();
    assert_eq!(ex.stats.uploads, up_before, "resident input must not re-upload");
    assert_eq!(ex.stats.downloads, down_before, "chaining must not download");
    assert_eq!(ex.stats.cache_hits, 1);

    let labels = ex.download(k2).unwrap();
    let labels = labels[0].as_tensor().unwrap().clone();
    assert!(labels.data().iter().any(|&v| v > 0.0));
    ex.evict(k1);
    ex.evict(k2);
    assert_eq!(ex.resident_count(), 0);

    // chained result equals unchained result
    let mut ex2 = executor().expect("artifacts verified above");
    let out = ex2.run("fill_holes", 64, &[v.clone()]).unwrap();
    let out = ex2.run("bwlabel", 64, &[out[0].clone()]).unwrap();
    assert_eq!(out[0].as_tensor().unwrap().data(), labels.data());
}

#[test]
fn multi_output_module_downloads_tuple() {
    let mut ex = require_executor!();
    let mask = blob_mask(64);
    let k = ex
        .execute_resident("pre_watershed", 64, &[ExecInput::Host(&Value::Tensor(mask))])
        .unwrap();
    let outs = ex.download(k).unwrap();
    assert_eq!(outs.len(), 2, "pre_watershed returns (relief, markers)");
    let relief = outs[0].as_tensor().unwrap();
    let markers = outs[1].as_tensor().unwrap();
    assert_eq!(relief.shape(), &[64, 64]);
    // relief is negated distance: non-positive everywhere
    assert!(relief.data().iter().all(|&v| v <= 0.0));
    // markers exist inside the blobs
    assert!(markers.data().iter().any(|&v| v > 0.0));
    // tuple payloads cannot feed execute_resident
    let err = ex.execute_resident("bwlabel", 64, &[ExecInput::Resident(k)]);
    assert!(err.is_err());
}

#[test]
fn feature_graph_stats_vector() {
    let mut ex = require_executor!();
    // deterministic pseudo-random rgb tile
    let mut state = 0x1234_5678u64;
    let mut px = Vec::with_capacity(64 * 64 * 3);
    for _ in 0..64 * 64 * 3 {
        state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        px.push(((state >> 33) % 256) as f32);
    }
    let rgb = HostTensor::new(vec![64, 64, 3], px).unwrap();
    let out = ex
        .run("feature_graph", 64, &[Value::Tensor(rgb), Value::Scalar(30.0)])
        .unwrap();
    assert_eq!(out.len(), 4);
    let stats = out[3].as_tensor().unwrap();
    assert_eq!(stats.shape(), &[41]);
    assert!(stats.data().iter().all(|v| v.is_finite()));
    // histogram of hema image sums to pixel count
    let hist_sum: f32 = stats.data()[4..20].iter().sum();
    assert_eq!(hist_sum, (64 * 64) as f32);
    // edge count consistency: stats[40] == sum(edges)
    let edges = out[2].as_tensor().unwrap();
    let edge_sum: f32 = edges.data().iter().sum();
    assert_eq!(stats.data()[40], edge_sum);
}

#[test]
fn executable_cache_compiles_once() {
    let mut ex = require_executor!();
    let mask = blob_mask(64);
    let v = Value::Tensor(mask);
    ex.run("fill_holes", 64, &[v.clone()]).unwrap();
    ex.run("fill_holes", 64, &[v.clone()]).unwrap();
    ex.run("fill_holes", 64, &[v]).unwrap();
    assert_eq!(ex.stats.compile_count, 1);
    assert_eq!(ex.stats.executions, 3);
}
