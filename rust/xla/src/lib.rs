//! Offline shim for the `xla` (xla-rs) bindings.
//!
//! The real crate links `xla_extension` and executes HLO through PJRT.
//! That native bundle is not available in the offline build environment,
//! so this crate provides the *exact API surface* `htap` uses with a null
//! accelerator backend:
//!
//! * host-side types ([`Literal`], [`ArrayShape`], [`PjRtBuffer`]) are fully
//!   functional — they carry f32 data in host memory;
//! * [`PjRtClient::compile`] returns an error, so any attempt to actually
//!   execute an AOT artifact fails with a clear message.  The htap Worker
//!   Resource Manager degrades both *unresolvable* accelerator members and
//!   *failed* accelerator executions to the CPU member of the function
//!   variant (with a one-time warning), so whole-app runs complete even
//!   with artifacts present under this shim.
//!
//! To run the AOT artifacts for real, point the `xla` dependency in
//! `rust/Cargo.toml` at the actual xla-rs crate; no htap source changes
//! are required.

use std::fmt;
use std::path::Path;

/// Error type mirroring `xla::Error`.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Marker trait for element types the shim can move in and out of literals.
/// Only f32 is used by htap (all artifact I/O is f32).
pub trait NativeType: Copy {
    fn from_f32(v: f32) -> Self;
    fn to_f32(self) -> f32;
}

impl NativeType for f32 {
    fn from_f32(v: f32) -> Self {
        v
    }
    fn to_f32(self) -> f32 {
        self
    }
}

/// Dimensions of a (dense, f32) array literal.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// A host-side literal: shape + f32 data (tuples hold element literals).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    data: Vec<f32>,
    tuple: Option<Vec<Literal>>,
}

impl Literal {
    /// Rank-0 scalar literal.
    pub fn scalar(v: f32) -> Literal {
        Literal { dims: vec![], data: vec![v], tuple: None }
    }

    /// Rank-1 literal over a host slice.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal { dims: vec![data.len() as i64], data: data.to_vec(), tuple: None }
    }

    /// Reshape (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(Error(format!(
                "reshape to {:?} ({n} elements) from {} elements",
                dims,
                self.data.len()
            )));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data.clone(), tuple: None })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        if self.tuple.is_some() {
            return Err(Error("literal is a tuple, not an array".into()));
        }
        Ok(ArrayShape { dims: self.dims.clone() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.tuple.is_some() {
            return Err(Error("literal is a tuple, not an array".into()));
        }
        Ok(self.data.iter().map(|&v| T::from_f32(v)).collect())
    }

    /// Split a tuple literal into its elements.
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        match self.tuple.take() {
            Some(parts) => Ok(parts),
            None => Err(Error("literal is not a tuple".into())),
        }
    }
}

/// Parsed HLO module text.  The shim only retains the raw text; it cannot
/// lower or verify it.
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    #[allow(dead_code)]
    text: String,
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| Error(format!("read {}: {e}", path.as_ref().display())))?;
        Ok(HloModuleProto { text })
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    #[allow(dead_code)]
    module: HloModuleProto,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { module: proto.clone() }
    }
}

/// A device-resident buffer.  In the shim, "device" memory is host memory.
#[derive(Debug, Clone)]
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }
}

/// A compiled executable.  Never constructed by the shim (compilation
/// fails), but the type must exist for the caller's executable cache.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error("offline xla shim cannot execute HLO".into()))
    }
}

/// The PJRT client.  `cpu()` succeeds so device controller threads can
/// start; `compile` reports that this build has no accelerator backend.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _private: () })
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error(
            "offline xla shim cannot compile HLO artifacts; swap rust/xla for the real \
             xla-rs crate to enable accelerator execution"
                .into(),
        ))
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        data: &[T],
        dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        let n: usize = dims.iter().product();
        if n != data.len() {
            return Err(Error(format!(
                "host buffer has {} elements, dims {:?} imply {n}",
                data.len(),
                dims
            )));
        }
        let f32s: Vec<f32> = data.iter().map(|&v| v.to_f32()).collect();
        let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
        Ok(PjRtBuffer {
            literal: Literal { dims: dims_i64, data: f32s, tuple: None },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3]).is_err());
    }

    #[test]
    fn scalar_literal() {
        let s = Literal::scalar(7.5);
        assert!(s.array_shape().unwrap().dims().is_empty());
        assert_eq!(s.to_vec::<f32>().unwrap(), vec![7.5]);
    }

    #[test]
    fn tuple_decompose_only_for_tuples() {
        let mut s = Literal::scalar(1.0);
        assert!(s.decompose_tuple().is_err());
    }

    #[test]
    fn client_compiles_nothing() {
        let c = PjRtClient::cpu().unwrap();
        let proto = HloModuleProto { text: String::new() };
        assert!(c.compile(&XlaComputation::from_proto(&proto)).is_err());
        let buf = c.buffer_from_host_buffer::<f32>(&[1.0, 2.0], &[2], None).unwrap();
        assert_eq!(buf.to_literal_sync().unwrap().to_vec::<f32>().unwrap(), vec![1.0, 2.0]);
        assert!(c.buffer_from_host_buffer::<f32>(&[1.0], &[2], None).is_err());
    }
}
