//! `cargo xtask` — repo tooling for htap.
//!
//! Subcommands:
//!
//! * `lint` — run the concurrency-discipline lint pass over `rust/src`
//!   (critical-section deny lists, lock-order, panic policy, proto
//!   round-trip coverage).  Exits non-zero on any violation.  See
//!   docs/analysis.md for the rule catalogue and the annotation language.
//! * `docs` — run the docs drift checks: dead relative links in
//!   `README.md` + `docs/*.md`, and every CLI flag accepted by the
//!   parser must appear in `docs/operations.md` (the knob table).

mod docs;
mod lint;

use std::path::PathBuf;
use std::process::ExitCode;

fn src_root() -> PathBuf {
    // xtask lives at rust/xtask; the tree under analysis is rust/src.
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..").join("src")
}

fn run_lint() -> ExitCode {
    let root = src_root();
    let mut violations = match lint::lint_tree(&root) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("xtask lint: cannot scan {}: {e}", root.display());
            return ExitCode::FAILURE;
        }
    };
    violations.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    if violations.is_empty() {
        println!("xtask lint: clean ({} discipline rules enforced)", lint::RULES.len());
        return ExitCode::SUCCESS;
    }
    for v in &violations {
        println!("{v}");
    }
    println!(
        "xtask lint: {} violation{} — see docs/analysis.md for the rules \
         and the `// lint: allow(rule)` escape hatch",
        violations.len(),
        if violations.len() == 1 { "" } else { "s" }
    );
    ExitCode::FAILURE
}

fn repo_root() -> PathBuf {
    // xtask lives at rust/xtask; docs and README sit at the repo root.
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..").join("..")
}

fn run_docs() -> ExitCode {
    let root = repo_root();
    let mut violations = match docs::check_docs(&root) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("xtask docs: cannot scan {}: {e}", root.display());
            return ExitCode::FAILURE;
        }
    };
    violations.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    if violations.is_empty() {
        println!("xtask docs: clean (links resolve, CLI flag surface documented)");
        return ExitCode::SUCCESS;
    }
    for v in &violations {
        println!("{v}");
    }
    println!(
        "xtask docs: {} violation{} — fix the link or document the flag in docs/operations.md",
        violations.len(),
        if violations.len() == 1 { "" } else { "s" }
    );
    ExitCode::FAILURE
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") => run_lint(),
        Some("docs") => run_docs(),
        Some(other) => {
            eprintln!("xtask: unknown subcommand `{other}`");
            eprintln!("usage: cargo xtask lint|docs");
            ExitCode::FAILURE
        }
        None => {
            eprintln!("usage: cargo xtask lint|docs");
            ExitCode::FAILURE
        }
    }
}
