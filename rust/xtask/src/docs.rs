//! The htap docs checker (`cargo xtask docs`).
//!
//! Two dependency-free checks keep the operator docs from drifting away
//! from the code:
//!
//! 1. **dead-link** — every relative markdown link in `README.md` and
//!    `docs/*.md` must resolve to a file that exists (fragments are
//!    stripped; `http(s)://`, `mailto:` and pure `#anchor` links are
//!    skipped).
//! 2. **flag-docs** — every `--flag` the CLI parser actually accepts
//!    (accessor calls `get("…")` / `get_usize("…")` / `get_flag("…")` in
//!    `rust/src/cli.rs` + `rust/src/main.rs`, plus the `BOOL_FLAGS`
//!    list) must appear as `--flag` in `docs/operations.md`, the
//!    authoritative knob table.  Test modules are excluded, so asserting
//!    on a bogus flag in a unit test does not demand documentation.
//!
//! Like the lint pass, this is lexical by design: no markdown or Rust
//! parser, just enough scanning to catch the drift that actually happens
//! (a renamed doc, a flag added to the parser but not the runbook).

use crate::lint::Violation;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// The markdown files whose links are checked, relative to the repo root
/// (plus everything matching `docs/*.md`).
const LINK_ROOTS: &[&str] = &["README.md"];

/// The flag-accessor call patterns that define the CLI surface.
const FLAG_ACCESSORS: &[&str] = &["get(\"", "get_usize(\"", "get_flag(\""];

/// Files scanned for flag accessors, relative to the repo root.
const FLAG_SOURCES: &[&str] = &["rust/src/cli.rs", "rust/src/main.rs"];

/// The one authoritative knob table, relative to the repo root.
const OPERATIONS_DOC: &str = "docs/operations.md";

/// Run both checks against the repo rooted at `repo_root`.
pub fn check_docs(repo_root: &Path) -> io::Result<Vec<Violation>> {
    let mut out = Vec::new();
    for doc in markdown_files(repo_root)? {
        check_links(repo_root, &doc, &mut out)?;
    }
    check_flag_docs(repo_root, &mut out)?;
    Ok(out)
}

/// README.md + every `docs/*.md`, in a stable order.
fn markdown_files(repo_root: &Path) -> io::Result<Vec<PathBuf>> {
    let mut files: Vec<PathBuf> =
        LINK_ROOTS.iter().map(|f| repo_root.join(f)).filter(|p| p.is_file()).collect();
    let docs = repo_root.join("docs");
    if docs.is_dir() {
        let mut extra: Vec<PathBuf> = fs::read_dir(&docs)?
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| p.extension().map(|x| x == "md").unwrap_or(false))
            .collect();
        extra.sort();
        files.extend(extra);
    }
    Ok(files)
}

/// Extract every markdown link target `](target)` from `text`.
fn link_targets(text: &str) -> Vec<String> {
    let bytes = text.as_bytes();
    let mut out = Vec::new();
    let mut i = 0;
    while i + 1 < bytes.len() {
        if bytes[i] == b']' && bytes[i + 1] == b'(' {
            let start = i + 2;
            if let Some(off) = text[start..].find(')') {
                out.push(text[start..start + off].to_string());
                i = start + off;
                continue;
            }
        }
        i += 1;
    }
    out
}

fn check_links(repo_root: &Path, doc: &Path, out: &mut Vec<Violation>) -> io::Result<()> {
    let text = fs::read_to_string(doc)?;
    let rel_doc = doc.strip_prefix(repo_root).unwrap_or(doc).display().to_string();
    let dir = doc.parent().unwrap_or(repo_root);
    for (ln, line) in text.lines().enumerate() {
        for target in link_targets(line) {
            let target = target.trim();
            if target.is_empty()
                || target.starts_with('#')
                || target.starts_with("http://")
                || target.starts_with("https://")
                || target.starts_with("mailto:")
            {
                continue;
            }
            // strip a #fragment; the file part is what must exist
            let file_part = target.split('#').next().unwrap_or(target);
            if file_part.is_empty() {
                continue;
            }
            if !dir.join(file_part).exists() {
                out.push(Violation {
                    file: rel_doc.clone(),
                    line: ln + 1,
                    rule: "dead-link",
                    msg: format!("link target `{target}` does not exist"),
                });
            }
        }
    }
    Ok(())
}

/// Every flag name the CLI surface accepts, sorted and deduplicated.
pub fn cli_flags(repo_root: &Path) -> io::Result<Vec<String>> {
    let mut flags = Vec::new();
    for src in FLAG_SOURCES {
        let path = repo_root.join(src);
        let text = fs::read_to_string(&path)?;
        // unit tests may probe deliberately-absent flags; stop at the
        // test module so those never demand documentation
        let live = match text.find("#[cfg(test)]") {
            Some(cut) => &text[..cut],
            None => &text[..],
        };
        for pat in FLAG_ACCESSORS {
            let mut rest = live;
            while let Some(hit) = rest.find(pat) {
                let tail = &rest[hit + pat.len()..];
                if let Some(end) = tail.find('"') {
                    flags.push(tail[..end].to_string());
                }
                rest = &rest[hit + pat.len()..];
            }
        }
        // the boolean-flag list is part of the parser surface too
        if let Some(hit) = live.find("BOOL_FLAGS") {
            let tail = &live[hit..];
            if let Some(end) = tail.find(';') {
                let mut rest = &tail[..end];
                while let Some(q) = rest.find('"') {
                    let body = &rest[q + 1..];
                    if let Some(close) = body.find('"') {
                        flags.push(body[..close].to_string());
                        rest = &body[close + 1..];
                    } else {
                        break;
                    }
                }
            }
        }
    }
    flags.retain(|f| !f.is_empty());
    flags.sort();
    flags.dedup();
    Ok(flags)
}

fn check_flag_docs(repo_root: &Path, out: &mut Vec<Violation>) -> io::Result<()> {
    let ops_path = repo_root.join(OPERATIONS_DOC);
    let ops = match fs::read_to_string(&ops_path) {
        Ok(t) => t,
        Err(_) => {
            out.push(Violation {
                file: OPERATIONS_DOC.to_string(),
                line: 1,
                rule: "flag-docs",
                msg: "docs/operations.md is missing — it is the authoritative knob table"
                    .to_string(),
            });
            return Ok(());
        }
    };
    for flag in cli_flags(repo_root)? {
        if !ops.contains(&format!("--{flag}")) {
            out.push(Violation {
                file: OPERATIONS_DOC.to_string(),
                line: 1,
                rule: "flag-docs",
                msg: format!(
                    "the CLI accepts `--{flag}` but docs/operations.md never mentions it"
                ),
            });
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn repo_root() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..").join("..")
    }

    #[test]
    fn live_docs_are_clean() {
        let violations = check_docs(&repo_root()).expect("scan repo docs");
        assert!(
            violations.is_empty(),
            "docs drifted:\n{}",
            violations.iter().map(|v| v.to_string()).collect::<Vec<_>>().join("\n")
        );
    }

    #[test]
    fn link_targets_are_extracted() {
        let t = link_targets(
            "see [a](docs/x.md) and [b](https://e.com/p) plus [c](other.md#frag) ![i](img.png)",
        );
        assert_eq!(t, vec!["docs/x.md", "https://e.com/p", "other.md#frag", "img.png"]);
        assert!(link_targets("no links here (just parens)").is_empty());
    }

    #[test]
    fn dead_links_are_reported() {
        let dir = std::env::temp_dir().join(format!("htap-docstest-{}", std::process::id()));
        let docs = dir.join("docs");
        fs::create_dir_all(&docs).unwrap();
        fs::write(dir.join("README.md"), "[ok](docs/real.md) [bad](docs/ghost.md)\n").unwrap();
        fs::write(docs.join("real.md"), "[up](../README.md) [anchor](#section)\n").unwrap();
        let mut out = Vec::new();
        for doc in markdown_files(&dir).unwrap() {
            check_links(&dir, &doc, &mut out).unwrap();
        }
        let _ = fs::remove_dir_all(&dir);
        assert_eq!(out.len(), 1, "{out:?}");
        assert_eq!(out[0].rule, "dead-link");
        assert!(out[0].msg.contains("ghost.md"));
    }

    #[test]
    fn cli_flag_surface_is_extracted_and_documented() {
        let flags = cli_flags(&repo_root()).expect("scan cli sources");
        // spot-check the surface: long-standing flags and this PR's new ones
        for expected in
            ["tiles", "listen", "connect", "spill-dir", "heartbeat-ms", "lease-ms",
             "checkpoint-dir", "resume", "warm-restart", "kill-worker-at"]
        {
            assert!(flags.iter().any(|f| f == expected), "missing {expected} in {flags:?}");
        }
        // the test-module cut works: cli.rs tests probe an "absent" flag
        assert!(!flags.iter().any(|f| f == "absent"), "test-only flags must not leak");
    }
}
