//! The htap concurrency-discipline linter (`cargo xtask lint`).
//!
//! A deliberately dependency-free, line/brace-level scanner over
//! `rust/src` that machine-checks the WRM lock discipline documented in
//! `docs/analysis.md`:
//!
//! 1. **critical-section** — inside a region marked
//!    `// lint: critical-section`, deny op execution, payload codecs,
//!    payload byte-copies, file/socket I/O and sleeps.  The region spans
//!    from the marker line to the end of its enclosing brace block, or to
//!    an explicit `// lint: end-critical-section`.
//! 2. **lock-order** — the crate-wide order is `wrm` → `cache` →
//!    `catalog`; acquiring a lock while lexically holding a
//!    later-ordered one (or the same one) is denied.
//! 3. **panic** — `.unwrap()` / `.expect(` / `panic!(` / `unreachable!(`
//!    are denied in the runtime modules (`coordinator/`, `data/`, `net/`,
//!    `obs/`, `runtime/`, `service/`), outside `#[cfg(test)]` regions.
//! 4. **proto-coverage** — every `net::proto::Message` variant must be
//!    referenced by the module's round-trip tests.
//!
//! Escapes: a trailing `// lint: allow(rule)` on the offending line, or a
//! standalone `// lint: allow(rule)` on the line immediately above.
//!
//! The scanner strips comments, string/char literals and raw strings
//! before matching, and tracks brace depth for region/scope bookkeeping.
//! It is lexical by design — a call into a denied helper is checked at
//! the helper's own definition site, not at the call site.

use std::fmt;
use std::fs;
use std::path::Path;

/// The discipline rules this pass enforces (reporting only).
pub const RULES: &[&str] = &["critical-section", "lock-order", "panic", "proto-coverage"];

/// Nesting order of the named locks; acquiring index `i` while holding
/// index `j >= i` is a violation.
const LOCK_ORDER: &[&str] = &["wrm", "cache", "catalog"];

/// Deny lists enforced inside `lint: critical-section` regions.
const CS_DENY: &[(&str, &[&str])] = &[
    (
        "op",
        &[
            "run_cpu_member(",
            "execute_resident(",
            "run_stage_serial(",
            "resolve_artifact(",
            ".variant.cpu)",
        ],
    ),
    (
        "codec",
        &[
            "encode_tensor(",
            "decode_tensor(",
            "encode_into(",
            "write_message",
            "read_message",
            "f32s_to_le(",
            "f32s_from_le(",
        ],
    ),
    ("copy", &[".to_vec()", ".to_owned()", ".data().clone()"]),
    (
        "io",
        &[
            "File::",
            "OpenOptions::",
            "std::fs::",
            "read_to_end(",
            "write_all(",
            "read_exact(",
            "sync_all(",
            "TcpStream",
            "UdpSocket",
            "source.load(",
            "spill.put(",
            "spill.get(",
        ],
    ),
    ("sleep", &["thread::sleep"]),
];

/// Panic-family tokens denied in runtime modules.
const PANIC_DENY: &[&str] =
    &[".unwrap()", ".expect(", "panic!(", "unreachable!(", "todo!(", "unimplemented!("];

/// Directories (relative to `src/`) where the panic rule applies.
const PANIC_DIRS: &[&str] =
    &["coordinator/", "data/", "faults/", "net/", "obs/", "runtime/", "service/"];

/// Files exempt from the panic rule.  The model scheduler is test-only
/// machinery compiled under `cfg(htap_model)`; panicking on internal
/// invariant breaks *is* its error-reporting channel.
const PANIC_ALLOW_FILES: &[&str] = &["runtime/sync/model.rs"];

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// One source line with comments/literals stripped: `code` keeps the
/// lexable program text (string bodies replaced by `""`), `comment` the
/// text of any `//` comment (where lint directives live).
struct CleanLine {
    code: String,
    comment: String,
}

/// Strip comments and string/char literals, preserving line structure so
/// violation line numbers match the original file.
fn clean(text: &str) -> Vec<CleanLine> {
    let chars: Vec<char> = text.chars().collect();
    let mut lines = Vec::new();
    let mut code = String::new();
    let mut comment = String::new();
    let mut i = 0;
    let mut block_depth = 0usize; // /* */ nesting
    let mut in_str = false;
    let mut raw_hashes: Option<usize> = None;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            lines.push(CleanLine {
                code: std::mem::take(&mut code),
                comment: std::mem::take(&mut comment),
            });
            i += 1;
            continue;
        }
        if block_depth > 0 {
            if c == '*' && chars.get(i + 1) == Some(&'/') {
                block_depth -= 1;
                i += 2;
            } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                block_depth += 1;
                i += 2;
            } else {
                i += 1;
            }
            continue;
        }
        if let Some(h) = raw_hashes {
            if c == '"' && (0..h).all(|k| chars.get(i + 1 + k) == Some(&'#')) {
                raw_hashes = None;
                code.push('"');
                i += 1 + h;
            } else {
                i += 1;
            }
            continue;
        }
        if in_str {
            match c {
                '\\' => {
                    // a `\`-continued string still ends the physical line:
                    // keep pushing lines so numbering stays accurate
                    if chars.get(i + 1) == Some(&'\n') {
                        lines.push(CleanLine {
                            code: std::mem::take(&mut code),
                            comment: std::mem::take(&mut comment),
                        });
                    }
                    i += 2;
                }
                '"' => {
                    in_str = false;
                    code.push('"');
                    i += 1;
                }
                _ => i += 1,
            }
            continue;
        }
        match c {
            '/' if chars.get(i + 1) == Some(&'/') => {
                let mut j = i + 2;
                while j < chars.len() && chars[j] != '\n' {
                    comment.push(chars[j]);
                    j += 1;
                }
                i = j;
            }
            '/' if chars.get(i + 1) == Some(&'*') => {
                block_depth = 1;
                i += 2;
            }
            '"' => {
                in_str = true;
                code.push('"');
                i += 1;
            }
            'r' if matches!(chars.get(i + 1), Some('"') | Some('#'))
                // only when `r` starts an identifier-free raw string (not
                // the tail of an identifier like `for`)
                && !code.ends_with(|p: char| p.is_alphanumeric() || p == '_') =>
            {
                let mut h = 0;
                let mut j = i + 1;
                while chars.get(j) == Some(&'#') {
                    h += 1;
                    j += 1;
                }
                if chars.get(j) == Some(&'"') {
                    raw_hashes = Some(h);
                    code.push('"');
                    i = j + 1;
                } else {
                    code.push(c);
                    i += 1;
                }
            }
            '\'' => {
                // char literal vs lifetime: 'x' / '\n' are literals,
                // 'ident is a lifetime (keep scanning normally)
                if chars.get(i + 1) == Some(&'\\') {
                    let mut j = i + 2;
                    while j < chars.len() && chars[j] != '\'' && chars[j] != '\n' {
                        j += 1;
                    }
                    code.push_str("' '");
                    i = (j + 1).min(chars.len());
                } else if chars.get(i + 2) == Some(&'\'') {
                    code.push_str("' '");
                    i += 3;
                } else {
                    code.push('\'');
                    i += 1;
                }
            }
            _ => {
                code.push(c);
                i += 1;
            }
        }
    }
    if !code.is_empty() || !comment.is_empty() {
        lines.push(CleanLine { code, comment });
    }
    lines
}

/// Lint directives parsed out of one line's `//` comment text.
#[derive(Default)]
struct Directives {
    critical_section: bool,
    end_critical_section: bool,
    allows: Vec<String>,
}

fn parse_directives(comment: &str) -> Directives {
    let mut d = Directives::default();
    let Some(rest) = comment.trim_start().strip_prefix("lint:") else {
        return d;
    };
    let rest = rest.trim_start();
    if rest.starts_with("end-critical-section") {
        d.end_critical_section = true;
    } else if rest.starts_with("critical-section") {
        d.critical_section = true;
    } else if let Some(arg) = rest.strip_prefix("allow(") {
        if let Some(end) = arg.find(')') {
            d.allows.push(arg[..end].trim().to_string());
        }
    }
    d
}

/// Which named lock (if any) a line of `file` acquires.  `lock_inner()`
/// is the Wrm helper; `self.state` is the Manager's catalog-bearing
/// state; `self.inner` is ambiguous across files and resolved by file
/// name.  Unknown receivers (worker flight tuples, profile stores, net
/// channels, the shim internals) are untracked.
fn acquired_lock(file: &str, code: &str) -> Option<&'static str> {
    let acquires = code.contains(".lock()")
        || code.contains("lock_or_poisoned(")
        || code.contains("lock_clean(")
        || code.contains("lock_inner()");
    if !acquires {
        return None;
    }
    if code.contains("lock_inner") {
        return Some("wrm");
    }
    if code.contains("self.state") {
        return Some("catalog");
    }
    if code.contains("self.inner") {
        if file.ends_with("wrm.rs") {
            return Some("wrm");
        }
        if file.ends_with("cache.rs") {
            return Some("cache");
        }
    }
    None
}

/// Extract the guard variable bound on an acquisition line:
/// `let [Ok(|Some(] [mut] NAME [)] = ...`.  None for expression-position
/// acquisitions (the guard is anonymous; scope tracking still applies).
fn guard_name(code: &str) -> Option<String> {
    let t = code.trim_start();
    let rest = t.strip_prefix("let ")?;
    let rest = rest.trim_start();
    let rest = rest.strip_prefix("Ok(").or_else(|| rest.strip_prefix("Some(")).unwrap_or(rest);
    let rest = rest.trim_start();
    let rest = rest.strip_prefix("mut ").unwrap_or(rest);
    let name: String =
        rest.chars().take_while(|c| c.is_alphanumeric() || *c == '_').collect();
    if name.is_empty() {
        None
    } else {
        Some(name)
    }
}

struct HeldLock {
    var: Option<String>,
    lock: usize, // index into LOCK_ORDER
    depth: usize,
}

fn lock_index(name: &str) -> usize {
    LOCK_ORDER.iter().position(|&l| l == name).unwrap_or(usize::MAX)
}

/// Lint one file's text.  `file` is its path relative to `src/` with
/// forward slashes (drives the panic-rule dirs and lock-name mapping).
pub fn lint_file(file: &str, text: &str) -> Vec<Violation> {
    let lines = clean(text);
    let mut out = Vec::new();
    let panic_applies = PANIC_DIRS.iter().any(|d| file.starts_with(d))
        && !PANIC_ALLOW_FILES.contains(&file);

    let mut depth = 0usize; // brace depth at the start of the current line
    let mut cs: Option<usize> = None; // critical-section region: marker depth
    let mut allow_next: Vec<String> = Vec::new();
    let mut held: Vec<HeldLock> = Vec::new();
    // #[cfg(test)] skipping: pending = attribute seen, waiting for the
    // item; Some(d) = inside a test item whose line started at depth d
    let mut cfg_test_pending = false;
    let mut test_depth: Option<usize> = None;
    // proto-coverage bookkeeping
    let mut test_text = String::new();

    for (idx, line) in lines.iter().enumerate() {
        let lineno = idx + 1;
        let code = line.code.as_str();
        let has_code = !code.trim().is_empty();
        let opens = code.chars().filter(|&c| c == '{').count();
        let closes = code.chars().filter(|&c| c == '}').count();
        let depth_after = (depth + opens).saturating_sub(closes);

        // leave a test region once its item block has closed
        if let Some(d) = test_depth {
            if has_code {
                test_text.push_str(code);
                test_text.push('\n');
            }
            if depth_after <= d {
                test_depth = None;
            }
            depth = depth_after;
            continue;
        }
        if code.contains("#[cfg(test)]") {
            cfg_test_pending = true;
            depth = depth_after;
            continue;
        }
        if cfg_test_pending && has_code {
            cfg_test_pending = false;
            if opens > 0 && depth_after > depth {
                test_depth = Some(depth); // block item: skip until it closes
                test_text.push_str(code);
                test_text.push('\n');
            }
            // single-line item (use/fn-decl ending in `;`): just skip it
            depth = depth_after;
            continue;
        }

        let d = parse_directives(&line.comment);
        if d.critical_section {
            cs = Some(depth);
        }
        if d.end_critical_section {
            cs = None;
        }

        // region/scope maintenance keyed on the depth at line start
        if let Some(cd) = cs {
            if depth < cd {
                cs = None;
            }
        }
        held.retain(|h| depth >= h.depth);
        if has_code {
            // explicit drops release guards early
            let mut kept = Vec::new();
            for h in held.drain(..) {
                let dropped = h
                    .var
                    .as_ref()
                    .map(|v| code.contains(&format!("drop({v})")))
                    .unwrap_or(false);
                if !dropped {
                    kept.push(h);
                }
            }
            held = kept;
        }

        if has_code {
            let mut allowed: Vec<String> = std::mem::take(&mut allow_next);
            allowed.extend(d.allows.iter().cloned());
            let allow = |rule: &str| allowed.iter().any(|a| a.as_str() == rule);

            // rule 1: critical-section deny lists
            if cs.is_some() {
                for &(rule, patterns) in CS_DENY {
                    if allow(rule) {
                        continue;
                    }
                    for &p in patterns {
                        if code.contains(p) {
                            out.push(Violation {
                                file: file.to_string(),
                                line: lineno,
                                rule,
                                msg: format!(
                                    "`{p}` inside a marked critical section \
                                     (move it outside the lock or `lint: allow({rule})`)"
                                ),
                            });
                        }
                    }
                }
            }

            // rule 2: lock order
            if let Some(lock) = acquired_lock(file, code) {
                let li = lock_index(lock);
                for h in &held {
                    if h.lock >= li {
                        out.push(Violation {
                            file: file.to_string(),
                            line: lineno,
                            rule: "lock-order",
                            msg: format!(
                                "acquires `{lock}` while holding `{}` — order is {}",
                                LOCK_ORDER[h.lock],
                                LOCK_ORDER.join(" -> ")
                            ),
                        });
                    }
                }
                held.push(HeldLock { var: guard_name(code), lock: li, depth });
            }

            // rule 3: panic family in runtime modules
            if panic_applies && !allow("panic") {
                for &p in PANIC_DENY {
                    if code.contains(p) {
                        out.push(Violation {
                            file: file.to_string(),
                            line: lineno,
                            rule: "panic",
                            msg: format!(
                                "`{p}` in a runtime module — return an error, or \
                                 justify with `lint: allow(panic)`"
                            ),
                        });
                    }
                }
            }
        } else {
            // a standalone allow applies to the next code line only
            if !d.allows.is_empty() {
                allow_next = d.allows.clone();
            }
        }

        depth = depth_after;
    }

    // rule 4: every proto Message variant exercised by the module's tests
    if file.ends_with("net/proto.rs") {
        for v in message_variants(text) {
            if !test_text.contains(&format!("Message::{v}")) {
                out.push(Violation {
                    file: file.to_string(),
                    line: 1,
                    rule: "proto-coverage",
                    msg: format!(
                        "Message::{v} has no round-trip test in proto.rs's test module"
                    ),
                });
            }
        }
    }
    out
}

/// Variant names of `enum Message` (top-level idents one brace in).
fn message_variants(text: &str) -> Vec<String> {
    let lines = clean(text);
    let mut variants = Vec::new();
    let mut depth = 0usize;
    let mut enum_depth: Option<usize> = None;
    for line in &lines {
        let code = line.code.as_str();
        let opens = code.chars().filter(|&c| c == '{').count();
        let closes = code.chars().filter(|&c| c == '}').count();
        if let Some(d) = enum_depth {
            if depth == d + 1 {
                let t = code.trim_start();
                if t.starts_with(|c: char| c.is_ascii_uppercase()) {
                    let name: String =
                        t.chars().take_while(|c| c.is_alphanumeric() || *c == '_').collect();
                    variants.push(name);
                }
            }
            if (depth + opens).saturating_sub(closes) <= d {
                break;
            }
        } else if code.contains("enum Message") && opens > 0 {
            enum_depth = Some(depth);
        }
        depth = (depth + opens).saturating_sub(closes);
    }
    variants
}

/// Lint every `.rs` file under `src_root`; paths in violations are
/// relative to it.
pub fn lint_tree(src_root: &Path) -> std::io::Result<Vec<Violation>> {
    let mut files = Vec::new();
    collect_rs(src_root, &mut files)?;
    files.sort();
    let mut out = Vec::new();
    for f in files {
        let rel = f
            .strip_prefix(src_root)
            .unwrap_or(&f)
            .to_string_lossy()
            .replace('\\', "/");
        let text = fs::read_to_string(&f)?;
        out.extend(lint_file(&rel, &text));
    }
    Ok(out)
}

fn collect_rs(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> std::io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules(vs: &[Violation]) -> Vec<&'static str> {
        vs.iter().map(|v| v.rule).collect()
    }

    #[test]
    fn clean_tree_is_clean() {
        // the real tree must lint clean; run from the workspace so the
        // fixture-independent acceptance check lives in `cargo test` too
        let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("../src");
        if src.is_dir() {
            let vs = lint_tree(&src).unwrap();
            assert!(vs.is_empty(), "tree has lint violations:\n{}", render(&vs));
        }
    }

    fn render(vs: &[Violation]) -> String {
        vs.iter().map(|v| format!("{v}\n")).collect()
    }

    #[test]
    fn op_call_in_marked_critical_section_is_caught() {
        let src = r#"
impl Wrm {
    fn bad(&self) {
        let Ok(mut inner) = self.lock_inner() else { return };
        // lint: critical-section — seeded violation fixture
        let result = Self::run_cpu_member(op, &vals);
        inner.completions.push_back((0, result));
    }
}
"#;
        let vs = lint_file("coordinator/wrm.rs", src);
        assert_eq!(rules(&vs), vec!["op"], "{}", render(&vs));
        assert_eq!(vs[0].line, 6);
    }

    #[test]
    fn payload_copy_and_io_in_critical_section_are_caught() {
        let src = "
fn f(&self) {
    let mut inner = sync::lock_clean(&self.inner);
    // lint: critical-section
    let bytes = v.data().to_vec();
    let vals = self.source.load(chunk);
}
";
        let vs = lint_file("data/staging/cache.rs", src);
        assert_eq!(rules(&vs), vec!["copy", "io"], "{}", render(&vs));
    }

    #[test]
    fn critical_section_ends_with_its_block() {
        let src = "
fn f(&self) {
    {
        let Ok(mut inner) = self.lock_inner() else { return };
        // lint: critical-section
        inner.queue.pop();
    }
    let r = Self::run_cpu_member(op, &vals); // outside the region
}
";
        assert!(lint_file("coordinator/wrm.rs", src).is_empty());
    }

    #[test]
    fn end_critical_section_reopens_the_unlocked_window() {
        let src = "
fn f(&self) {
    let Ok(mut inner) = self.lock_inner() else { return };
    // lint: critical-section
    drop(inner);
    // lint: end-critical-section
    let loaded = self.source.load(chunk);
}
";
        assert!(lint_file("data/staging/cache.rs", src).is_empty());
    }

    #[test]
    fn out_of_order_lock_nesting_is_caught() {
        // catalog (manager state) is the outermost lock; grabbing the WRM
        // queue lock under it inverts the declared order
        let src = "
fn bad(&self) {
    let mut st = sync::lock_clean(&self.state);
    let Ok(mut inner) = self.lock_inner() else { return };
}
";
        let vs = lint_file("coordinator/wrm.rs", src);
        assert_eq!(rules(&vs), vec!["lock-order"], "{}", render(&vs));
        assert!(vs[0].msg.contains("`wrm` while holding `catalog`"), "{}", vs[0].msg);
    }

    #[test]
    fn in_order_nesting_and_dropped_guards_are_fine() {
        let src = "
fn ok(&self) {
    let Ok(mut inner) = self.lock_inner() else { return };
    drop(inner);
    let mut st = sync::lock_clean(&self.state);
}
";
        assert!(lint_file("coordinator/wrm.rs", src).is_empty());
        // nested in declared order: wrm then catalog
        let src = "
fn ok(&self) {
    let Ok(mut inner) = self.lock_inner() else { return };
    let mut st = sync::lock_clean(&self.state);
}
";
        assert!(lint_file("coordinator/wrm.rs", src).is_empty());
    }

    #[test]
    fn reacquiring_the_same_lock_is_caught() {
        let src = "
fn bad(&self) {
    let Ok(a) = self.lock_inner() else { return };
    let Ok(b) = self.lock_inner() else { return };
}
";
        let vs = lint_file("coordinator/wrm.rs", src);
        assert_eq!(rules(&vs), vec!["lock-order"], "{}", render(&vs));
    }

    #[test]
    fn unwraps_in_runtime_modules_are_caught_and_allowable() {
        let src = "
fn f() {
    let x = maybe().unwrap();
}
";
        let vs = lint_file("coordinator/manager.rs", src);
        assert_eq!(rules(&vs), vec!["panic"], "{}", render(&vs));
        // same-line and standalone allows both escape
        let src = "
fn f() {
    let x = maybe().unwrap(); // lint: allow(panic) — infallible
    // lint: allow(panic) — infallible
    let y = maybe().unwrap();
}
";
        assert!(lint_file("coordinator/manager.rs", src).is_empty());
        // a standalone allow covers only the next line
        let src = "
fn f() {
    // lint: allow(panic)
    let x = maybe().unwrap();
    let y = maybe().unwrap();
}
";
        let vs = lint_file("coordinator/manager.rs", src);
        assert_eq!(vs.len(), 1, "{}", render(&vs));
        assert_eq!(vs[0].line, 5);
    }

    #[test]
    fn test_modules_and_non_runtime_dirs_are_exempt_from_panic() {
        let src = "
fn run() {}
#[cfg(test)]
mod tests {
    fn t() {
        x.unwrap();
    }
}
";
        assert!(lint_file("coordinator/manager.rs", src).is_empty());
        assert!(lint_file("config/mod.rs", "fn f() { x.unwrap(); }").is_empty());
        assert!(lint_file(
            "runtime/sync/model.rs",
            "fn f() { x.unwrap(); }"
        )
        .is_empty());
    }

    #[test]
    fn strings_and_comments_do_not_trip_rules() {
        let src = r#"
fn f(&self) {
    let Ok(mut inner) = self.lock_inner() else { return };
    // lint: critical-section
    let msg = "calls run_cpu_member( and .to_vec() in a string";
    let re = r"thread::sleep";
    /* block comment mentioning File:: and .unwrap() */
    inner.push(msg);
}
"#;
        assert!(lint_file("coordinator/wrm.rs", src).is_empty());
    }

    #[test]
    fn proto_coverage_catches_an_untested_variant() {
        let src = "
pub enum Message {
    Request { capacity: u32 },
    Assign { n: u32 },
}

#[cfg(test)]
mod tests {
    #[test]
    fn roundtrip() {
        let m = Message::Request { capacity: 1 };
    }
}
";
        let vs = lint_file("net/proto.rs", src);
        assert_eq!(rules(&vs), vec!["proto-coverage"], "{}", render(&vs));
        assert!(vs[0].msg.contains("Message::Assign"), "{}", vs[0].msg);
    }

    #[test]
    fn message_variants_parse() {
        let src = "
pub enum Message {
    /// doc
    Request { capacity: u32, nested: Vec<u8> },
    Assign { a: u32 },
    Complete { b: u32 },
    Fail { msg: String },
}
";
        assert_eq!(message_variants(src), vec!["Request", "Assign", "Complete", "Fail"]);
    }
}
