fn main() {
    // `htap_model` is also accepted as a raw cfg (RUSTFLAGS="--cfg htap_model")
    // so the model scheduler can be enabled without cargo features, e.g. from
    // Miri or TSan wrappers; declare it so check-cfg lints stay quiet.
    println!("cargo:rustc-check-cfg=cfg(htap_model)");
}
