//! Measurement harness for `cargo bench` (criterion is not in the offline
//! crate set).  Provides warmup + repeated timing with mean/std, and table
//! printers that emit the same rows the paper's tables/figures report, so
//! every bench target regenerates one paper artifact.

use std::time::{Duration, Instant};

/// Result of measuring one configuration.
#[derive(Debug, Clone)]
pub struct Sample {
    pub name: String,
    pub mean: Duration,
    pub std_dev: Duration,
    pub iters: usize,
}

impl Sample {
    pub fn mean_ms(&self) -> f64 {
        self.mean.as_secs_f64() * 1e3
    }
}

/// Run `f` `warmup` + `iters` times; time only the measured iterations.
pub fn measure<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Sample {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>()
        / times.len().max(1) as f64;
    Sample {
        name: name.to_string(),
        mean: Duration::from_secs_f64(mean),
        std_dev: Duration::from_secs_f64(var.sqrt()),
        iters,
    }
}

/// Measure a function that returns its own metric (e.g. simulated seconds).
pub fn measure_value<F: FnMut() -> f64>(name: &str, reps: usize, mut f: F) -> (String, f64, f64) {
    let vals: Vec<f64> = (0..reps).map(|_| f()).collect();
    let mean = vals.iter().sum::<f64>() / vals.len() as f64;
    let var = vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / vals.len() as f64;
    (name.to_string(), mean, var.sqrt())
}

/// Fixed-width table printer for bench output.
pub struct Table {
    headers: Vec<String>,
    widths: Vec<usize>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            widths: headers.iter().map(|s| s.len().max(8)).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        for (w, c) in self.widths.iter_mut().zip(cells) {
            *w = (*w).max(c.len());
        }
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self, title: &str) {
        println!("\n=== {title} ===");
        let line: Vec<String> = self
            .headers
            .iter()
            .zip(&self.widths)
            .map(|(h, w)| format!("{h:>w$}", w = w))
            .collect();
        println!("{}", line.join("  "));
        println!("{}", "-".repeat(line.join("  ").len()));
        for r in &self.rows {
            let line: Vec<String> = r
                .iter()
                .zip(&self.widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect();
            println!("{}", line.join("  "));
        }
    }
}

/// Format a float with fixed decimals (bench rows).
pub fn f(v: f64, dec: usize) -> String {
    format!("{v:.dec$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_counts_iters() {
        let mut n = 0;
        let s = measure("inc", 2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(s.iters, 5);
    }

    #[test]
    fn measure_value_stats() {
        let mut i = 0.0;
        let (_, mean, sd) = measure_value("seq", 3, || {
            i += 1.0;
            i
        });
        assert!((mean - 2.0).abs() < 1e-9);
        assert!(sd > 0.0);
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn table_checks_columns() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into()]);
    }
}
