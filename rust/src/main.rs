//! `htap` launcher: run / simulate / calibrate / serve / join.

use htap::app::{self, build_workflow_with, stage_bindings, AppParams};
use htap::cli::{Cli, USAGE};
use htap::config::{PartitionMode, Policy, RunConfig};
use htap::coordinator::{
    checkpoint, hub_from_config, run_local_staged, spill_from_config,
    worker::{run_worker_opts, JobResolver, WorkerOpts},
    AssignPolicy, Manager, WorkerStaging,
};
use htap::data::staging::{source_from_spec, ChunkSource, StagingCache};
use htap::data::{DirSource, SynthConfig, TileStore};
use htap::dataflow::{workflow_from_file, workflow_from_str, StageKind, Workflow};
use htap::net::{self, ManagerServer, RemoteManager};
use htap::service::{render_value, JobTable};
use htap::runtime::calibrate::{
    calibrate_workflows, CalibrationConfig, SharedProfiles, CHUNK_READ_OP,
};
use htap::runtime::{ArtifactManifest, ProfileStore};
use htap::sim::{simulate, simulate_traced, SimParams, SimWorkflow};
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cli = match Cli::parse(&args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("{e}\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&cli) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn dispatch(cli: &Cli) -> htap::Result<()> {
    match cli.command.as_str() {
        "run" => cmd_run(cli),
        "sim" => cmd_sim(cli),
        "calibrate" => cmd_calibrate(cli),
        "manager" => cmd_manager(cli),
        "serve" => cmd_serve(cli),
        "submit" => cmd_submit(cli),
        "jobs" => cmd_jobs(cli),
        "cancel" => cmd_cancel(cli),
        "top" => cmd_top(cli),
        "worker" => cmd_worker(cli),
        "export-tiles" => cmd_export_tiles(cli),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => Err(htap::Error::Config(format!("unknown command '{other}'\n{USAGE}"))),
    }
}

/// Load `--profiles` when given (version-checked).  `expected_tile_size`
/// is what this invocation will process; measurements from another tile
/// size still load (relative op costs are better than the static table)
/// but with a visible warning, since op costs scale non-uniformly.
fn load_profiles(cli: &Cli, expected_tile_size: usize) -> htap::Result<Option<ProfileStore>> {
    match cli.get("profiles") {
        Some(path) => {
            let store = ProfileStore::load(path)?;
            println!(
                "loaded measured profiles from {path} ({} ops, tile size {})",
                store.len(),
                store.tile_size
            );
            if store.tile_size != 0 && store.tile_size != expected_tile_size {
                eprintln!(
                    "warning: profiles were calibrated at tile size {} but this run uses {}; \
                     op costs scale non-uniformly with tile size — re-run `htap calibrate \
                     --tile-size {}` for accurate estimates",
                    store.tile_size, expected_tile_size, expected_tile_size
                );
            }
            Ok(Some(store))
        }
        None => Ok(None),
    }
}

/// Resolve the workflow to execute: `--workflow wf.json` loads a
/// declarative workflow over the full op registry (WSI + generic ops) —
/// `run`, `manager` and `worker` all accept it, distributed peers must
/// load the same file; the default is the built-in WSI app.
fn resolve_workflow(
    cli: &Cli,
    cfg: &RunConfig,
    with_classification: bool,
) -> htap::Result<Arc<Workflow>> {
    match cli.get("workflow") {
        Some(path) => {
            let mut registry = app::registry();
            registry.merge(app::generic::generic_registry())?;
            Ok(Arc::new(workflow_from_file(path, Arc::new(registry))?))
        }
        None => {
            let params = AppParams::for_tile_size(cfg.tile_size);
            Ok(Arc::new(build_workflow_with(
                Arc::new(app::registry()),
                &params,
                with_classification,
            )?))
        }
    }
}

/// Resolve `--chunk-source` (default: synthetic tiles matching the run
/// config) and the chunk count to process: an explicit `--tiles` caps a
/// directory source; otherwise the source's full size is used.
fn chunk_source(cli: &Cli, cfg: &RunConfig) -> htap::Result<(Arc<dyn ChunkSource>, usize)> {
    let spec = cli.get("chunk-source").unwrap_or("synth");
    let src = source_from_spec(
        spec,
        cfg.tile_size,
        cfg.seed,
        cfg.n_tiles,
        Duration::from_millis(cfg.read_latency_ms),
    )?;
    let n = if cli.get("tiles").is_some() {
        cfg.n_tiles.min(src.n_chunks())
    } else {
        src.n_chunks()
    };
    Ok((src, n))
}

fn cmd_run(cli: &Cli) -> htap::Result<()> {
    let cfg = cli.run_config()?;
    let store = load_profiles(cli, cfg.tile_size)?;
    // Measured profiles reach PATS through the run's SharedProfiles seed
    // below — the WRM overrides the static OpDef estimates at every task
    // push, so no registry rewrite is needed here.
    let workflow = resolve_workflow(cli, &cfg, true)?;
    let (source, n) = chunk_source(cli, &cfg)?;
    println!(
        "running workflow '{}': {} chunks from {} ({}x{}) with {} ({} cpu + {} gpu threads, \
         window {}, staging cap {}, prefetch depth {}, locality {}, spill {})",
        workflow.name, n, source.describe(), cfg.tile_size, cfg.tile_size, cfg.policy.name(),
        cfg.cpu_workers, cfg.gpu_workers, cfg.window, cfg.staging_cap, cfg.prefetch_depth,
        if cfg.chunk_locality { "on" } else { "off" },
        match &cfg.spill_dir {
            Some(d) => format!("{d} (cap {})", cfg.spill_cap),
            None => "off".to_string(),
        }
    );
    // seed the online store with the offline measurements, so PATS starts
    // from them and the run's EWMA updates refine them
    let profiles = match store {
        Some(s) => SharedProfiles::from_store(s),
        None => SharedProfiles::fresh(),
    };
    let outcome = run_local_staged(workflow.clone(), source, n, cfg, stage_bindings(), profiles)?;
    let report = outcome.metrics;
    println!("\n{}", report.profile_table());
    println!("{}", report.staging.summary());
    println!(
        "wall {:.2}s  ({:.2} tiles/s)",
        report.wall.as_secs_f64(),
        n as f64 / report.wall.as_secs_f64()
    );
    for stage in workflow.stages.iter().filter(|s| s.kind == StageKind::Reduce) {
        if let Some(outs) = outcome.manager.reduce_outputs(&stage.name) {
            println!("reduce stage '{}' produced {} output value(s)", stage.name, outs.len());
        }
    }
    if let Some(path) = cli.get("save-profiles") {
        let snap = outcome.profiles.snapshot();
        snap.save(path)?;
        println!("saved {} measured op profiles to {path}", snap.len());
    }
    Ok(())
}

fn cmd_sim(cli: &Cli) -> htap::Result<()> {
    let nodes = cli.get_usize("nodes", 1)?;
    let tiles = cli.get_usize("tiles", 100)?;
    let policy = match cli.get("policy") {
        Some(p) => Policy::parse(p)?,
        None => Policy::Pats,
    };
    // the simulated pipeline is derived at the 64-px reference tile size
    let store = load_profiles(cli, 64)?;
    let workflow = match &store {
        Some(store) => SimWorkflow::pipelined_profiled(store),
        None => SimWorkflow::pipelined(),
    };
    let chunk_locality = !cli.get_flag("no-locality");
    let replication = !cli.get_flag("no-replication");
    // fault injection: crash the last node at a fraction of the no-fault
    // makespan and let the survivors re-execute its in-flight work
    let kill_worker_at = match cli.get("kill-worker-at") {
        Some(v) => {
            let f: f64 = v
                .parse()
                .map_err(|_| htap::Error::Config("bad --kill-worker-at".into()))?;
            if !(0.0..1.0).contains(&f) {
                return Err(htap::Error::Config(
                    "--kill-worker-at takes a fraction in [0, 1)".into(),
                ));
            }
            if nodes < 2 {
                return Err(htap::Error::Config(
                    "--kill-worker-at needs --nodes >= 2 (someone must survive)".into(),
                ));
            }
            Some(f)
        }
        None => None,
    };
    // --net-fault-rate: the distributed frame-drop plan's analytic mirror
    // (drops delay fetch round-trips behind the live retry backoff)
    let net_fault_rate = match cli.get("net-fault-rate") {
        Some(v) => {
            let f: f64 = v
                .parse()
                .map_err(|_| htap::Error::Config("bad --net-fault-rate".into()))?;
            if !(0.0..=1.0).contains(&f) {
                return Err(htap::Error::Config(
                    "--net-fault-rate takes a fraction in [0, 1]".into(),
                ));
            }
            f
        }
        None => 0.0,
    };
    let fault_seed = cli.get_usize("fault-seed", 0)? as u64;
    let mut p = SimParams {
        workflow,
        n_nodes: nodes,
        n_tiles: tiles,
        policy,
        chunk_locality,
        replication,
        kill_worker_at,
        net_fault_rate,
        fault_seed,
        ..Default::default()
    };
    // a calibrate --read-latency-ms run measured the per-chunk read cost;
    // feed it into the simulated tile-I/O base so transfer estimates
    // reflect the same shared-FS latency
    if let Some(ms) = store.as_ref().and_then(|s| s.cpu_ms(CHUNK_READ_OP)) {
        p.tile_io_base = ms / 1e3;
        println!("calibrated tile I/O base: {ms:.2} ms/chunk (measured {CHUNK_READ_OP})");
    }
    // --trace-out: record the simulated schedule as virtual-time op spans
    // in the same trace_event schema real runs emit
    let trace_out = cli.get("trace-out");
    let (r, trace_events) = match trace_out {
        Some(_) => simulate_traced(&p),
        None => (simulate(&p), Vec::new()),
    };
    if let Some(path) = trace_out {
        htap::obs::write_trace(path, &trace_events)?;
        println!(
            "wrote {} simulated trace events to {path} (+ {path}.jsonl)",
            trace_events.len()
        );
    }
    println!(
        "simulated {} tiles on {} Keeneland nodes ({}, locality {}, replication {}): \
         makespan {:.1}s, {:.1} tiles/s",
        tiles,
        nodes,
        policy.name(),
        if chunk_locality { "on" } else { "off" },
        if replication { "on" } else { "off" },
        r.makespan,
        r.tiles_per_second()
    );
    println!(
        "device busy {:.1}s, transfers {:.1}s, tile I/O {:.1}s, \
         {} steal migrations, {} cold re-reads",
        r.busy_time, r.transfer_time, r.io_time, r.steal_migrations, r.cold_rereads
    );
    if let Some(f) = kill_worker_at {
        println!(
            "fault injection: node {} crashed at {:.0}% of the no-fault makespan; \
             {} stage instances re-executed on the survivors",
            nodes - 1,
            f * 100.0,
            r.reexecuted
        );
    }
    if net_fault_rate > 0.0 {
        println!(
            "net faults: {:.0}% of fetch round-trips dropped a frame; \
             {} frames retried under bounded backoff (seed {fault_seed})",
            net_fault_rate * 100.0,
            r.retried_frames
        );
    }
    // --jobs N: model N identical copies of this run sharing the cluster
    // under weighted fair-share (the service's DRR, analytically)
    let jobs = cli.get_usize("jobs", 1)?;
    if jobs > 1 {
        let weights: Vec<u32> = match cli.get("job-weights") {
            Some(spec) => spec
                .split(',')
                .map(|w| {
                    w.trim()
                        .parse()
                        .map_err(|_| htap::Error::Config(format!("bad --job-weights '{spec}'")))
                })
                .collect::<htap::Result<_>>()?,
            None => vec![1; jobs],
        };
        if weights.len() != jobs {
            return Err(htap::Error::Config(format!(
                "--job-weights lists {} weights but --jobs is {jobs}",
                weights.len()
            )));
        }
        let makespans = htap::sim::fair_share_makespans(r.makespan, &weights);
        println!("fair-share: {jobs} identical jobs over the same {nodes} nodes");
        for (i, (w, m)) in weights.iter().zip(&makespans).enumerate() {
            println!("  job {} (weight {w}): makespan {m:.1}s", i + 1);
        }
    }
    Ok(())
}

fn cmd_calibrate(cli: &Cli) -> htap::Result<()> {
    let mut cfg = if cli.get_flag("quick") {
        CalibrationConfig::quick()
    } else {
        CalibrationConfig::default()
    };
    cfg.tile_size = cli.get_usize("tile-size", cfg.tile_size)?;
    cfg.n_chunks = cli.get_usize("tiles", cfg.n_chunks)?;
    cfg.reps = cli.get_usize("reps", cfg.reps)?.max(1);
    cfg.seed = cli.get_usize("seed", cfg.seed as usize)? as u64;
    cfg.read_latency_ms =
        cli.get_usize("read-latency-ms", cfg.read_latency_ms as usize)? as u64;
    let out = cli.get("out").unwrap_or("profiles.json");
    println!(
        "calibrating registered ops: {} chunks of {}x{}, {} reps (+{} warmup) per op, \
         {} ms simulated read latency",
        cfg.n_chunks, cfg.tile_size, cfg.tile_size, cfg.reps, cfg.warmup, cfg.read_latency_ms
    );
    let store = calibrate_workflows(&cfg)?;
    println!("\n{}", store.summary_table());
    let gpu_measured = store.op_names().filter(|op| store.gpu_ms(op).is_some()).count();
    if gpu_measured == 0 {
        println!(
            "no accelerator measurements on this host (artifacts absent or not executable);\n\
             GPU-side estimates keep the static Fig. 7 defaults until a run records them"
        );
    }
    store.save(out)?;
    println!("wrote {} op profiles to {out}", store.len());
    Ok(())
}

/// How often the manager persists its checkpoint when `--checkpoint-dir`
/// is given.  Sleeps in short steps so the writer thread exits promptly
/// once the run finishes.
const CKPT_INTERVAL_MS: u64 = 1000;

/// How often a standby health-checks its primary.
const PROBE_INTERVAL_MS: u64 = 250;

/// `--standby`: block until the primary goes silent.  A warm standby
/// probes `--primary` every [`PROBE_INTERVAL_MS`]; any successful probe
/// resets the silence clock, so transient hiccups (one dropped probe, a
/// GC-length stall) never trigger a split-brain promotion — only
/// `--promote-after-ms` of *continuous* silence does.  Returns once the
/// caller should promote: restore the newest snapshot under
/// `--checkpoint-dir` and start serving on `--listen`.
fn standby_wait(cli: &Cli) -> htap::Result<()> {
    let primary = cli
        .get("primary")
        .ok_or_else(|| htap::Error::Config("--standby needs --primary HOST:PORT".into()))?;
    if cli.get("checkpoint-dir").is_none() {
        return Err(htap::Error::Config(
            "--standby needs --checkpoint-dir (the promotion state source)".into(),
        ));
    }
    let promote_after = cli.get_usize("promote-after-ms", 3000)? as u64;
    println!(
        "standby: watching primary {primary} (promote after {promote_after} ms of silence)"
    );
    let mut silent_ms = 0u64;
    loop {
        match net::probe(primary) {
            Ok(()) => silent_ms = 0,
            Err(_) => {
                silent_ms += PROBE_INTERVAL_MS;
                if silent_ms >= promote_after {
                    println!(
                        "standby: promoting — primary {primary} silent for {silent_ms} ms"
                    );
                    return Ok(());
                }
            }
        }
        std::thread::sleep(Duration::from_millis(PROBE_INTERVAL_MS));
    }
}

fn cmd_manager(cli: &Cli) -> htap::Result<()> {
    let listen = cli
        .get("listen")
        .ok_or_else(|| htap::Error::Config("manager needs --listen HOST:PORT".into()))?;
    let cfg = cli.run_config()?;
    let workers = cli.get_usize("workers", 1)?;
    let workflow = resolve_workflow(cli, &cfg, false)?;
    // staged protocol: the manager never loads tile payloads — workers
    // stage chunks from their own --chunk-source; the source here only
    // fixes the chunk count (e.g. the .tile count of a shared directory)
    let (source, n) = chunk_source(cli, &cfg)?;
    // --partition init range-assigns cold chunks to worker ids 1..=workers
    // (workers must pass matching --worker-id values)
    let policy = AssignPolicy::from_config(&cfg, (1..=workers as u64).collect());
    let manager = Manager::new_staged(workflow.clone(), n, policy)?;
    // --checkpoint-dir: journal completions and snapshot (journal +
    // catalog) periodically; --resume replays the last snapshot so a
    // restarted manager does not re-execute finished stage instances.
    // The journal goes on *before* the restore so replayed completions
    // land in the new journal and survive the next checkpoint too.
    // --standby: wait out the primary first; a promotion then restores
    // the newest snapshot exactly like --resume would
    let promoted = if cli.get_flag("standby") {
        standby_wait(cli)?;
        true
    } else {
        false
    };
    let ckpt_dir = cli.get("checkpoint-dir").map(std::path::PathBuf::from);
    if let Some(dir) = &ckpt_dir {
        manager.enable_journal();
        if cli.get_flag("resume") || promoted {
            match checkpoint::load_checkpoint(dir)? {
                Some((journal, catalog)) => {
                    let replayed = manager.restore_from(journal, catalog)?;
                    println!("resumed from {}: replayed {replayed} completions", dir.display());
                }
                None => {
                    println!("no checkpoint under {}; starting fresh", dir.display());
                }
            }
        }
    }
    let server = ManagerServer::bind(listen, manager.clone())?;
    println!(
        "manager on {} ({} chunks from {}, expecting {workers} workers, locality {}, \
         replication {}, partition {})",
        server.local_addr(),
        n,
        source.describe(),
        if cfg.chunk_locality { "on" } else { "off" },
        if cfg.replication { "on" } else { "off" },
        cfg.partition.name()
    );
    if cfg.partition == PartitionMode::Init {
        println!("initial partition homes chunks on worker ids 1..={workers}");
    }
    let ckpt_stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let ckpt_writer = ckpt_dir.as_ref().map(|dir| {
        let mgr = manager.clone();
        let dir = dir.clone();
        let stop = ckpt_stop.clone();
        std::thread::spawn(move || {
            let mut since = 0u64;
            while !stop.load(std::sync::atomic::Ordering::Acquire) {
                std::thread::sleep(Duration::from_millis(25));
                since += 25;
                if since >= CKPT_INTERVAL_MS {
                    since = 0;
                    if let Err(e) = checkpoint::write_checkpoint(&dir, &mgr) {
                        eprintln!("htap manager: checkpoint failed: {e}");
                    }
                }
            }
        })
    });
    let served = server.serve();
    ckpt_stop.store(true, std::sync::atomic::Ordering::Release);
    if let Some(h) = ckpt_writer {
        let _ = h.join();
    }
    served?;
    if let Some(dir) = &ckpt_dir {
        // final snapshot so a post-run --resume sees the finished state
        checkpoint::write_checkpoint(dir, &manager)?;
    }
    if let Some(path) = &cfg.trace_out {
        // the cluster-wide stream: every worker's shipped trace batches
        // merged with this manager's membership events
        let events = manager.collector().merged();
        htap::obs::write_trace(path, &events)?;
        println!("wrote {} trace events to {path} (+ {path}.jsonl)", events.len());
    }
    let (done, total) = manager.progress();
    let (hits, cold, steals) = manager.locality_stats();
    println!("workflow complete: {done}/{total}");
    println!(
        "locality: {hits} hits, {cold} cold, {steals} steals, {} replicated",
        manager.replicated()
    );
    for stage in workflow.stages.iter().filter(|s| s.kind == StageKind::Reduce) {
        if let Some(outs) = manager.reduce_outputs(&stage.name) {
            for (i, v) in outs.iter().enumerate() {
                println!("reduce '{}' [{i}] = {}", stage.name, render_value(v));
            }
        }
    }
    Ok(())
}

/// The service op registry: WSI ops + the generic set, the same ops a
/// `--workflow` run resolves against, so any workflow `htap run
/// --workflow` accepts can also be submitted.
fn service_registry() -> htap::Result<Arc<htap::runtime::OpRegistry>> {
    let mut registry = app::registry();
    registry.merge(app::generic::generic_registry())?;
    Ok(Arc::new(registry))
}

fn cmd_serve(cli: &Cli) -> htap::Result<()> {
    let listen = cli
        .get("listen")
        .ok_or_else(|| htap::Error::Config("serve needs --listen HOST:PORT".into()))?;
    let cfg = cli.run_config()?;
    let registry = service_registry()?;
    // like `htap manager`, the service never loads tile payloads; the
    // chunk source only fixes the shared dataset's chunk count
    let (source, n) = chunk_source(cli, &cfg)?;
    let policy = AssignPolicy::from_config(&cfg, Vec::new());
    let table = JobTable::new(registry, n, policy, cfg.max_jobs, cfg.tenant_queue_depth);
    table.set_announce(true);
    // --checkpoint-dir snapshots the whole job table (queued + running
    // jobs, each with its journal and catalog); --resume restores it
    // --standby: wait out the primary first; a promotion then restores
    // the newest job-table snapshot exactly like --resume would
    let promoted = if cli.get_flag("standby") {
        standby_wait(cli)?;
        true
    } else {
        false
    };
    let ckpt_dir = cli.get("checkpoint-dir").map(std::path::PathBuf::from);
    if let Some(dir) = &ckpt_dir {
        table.enable_journal();
        if cli.get_flag("resume") || promoted {
            match checkpoint::load_service_checkpoint(dir)? {
                Some(jobs) => {
                    let restored = table.restore(jobs)?;
                    println!("resumed from {}: restored {restored} job(s)", dir.display());
                }
                None => {
                    println!("no service checkpoint under {}; starting fresh", dir.display());
                }
            }
        }
    }
    let server = ManagerServer::bind(listen, table.clone())?;
    println!(
        "service on {} ({} chunks from {}, max {} concurrent jobs, tenant queue depth {}, \
         tenant quota {})",
        server.local_addr(),
        n,
        source.describe(),
        cfg.max_jobs,
        cfg.tenant_queue_depth,
        match cfg.tenant_quota {
            Some(q) => q.to_string(),
            None => "off".to_string(),
        }
    );
    // --run-for MS bounds the service lifetime (smoke tests); the default
    // runs until the process is killed — safe, because the checkpoint
    // writer below persists the job table every interval
    if let Some(ms) = cli.get("run-for") {
        let ms: u64 = ms.parse().map_err(|_| htap::Error::Config("bad --run-for".into()))?;
        let t = table.clone();
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(ms));
            t.shutdown();
        });
    }
    let ckpt_stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let ckpt_writer = ckpt_dir.as_ref().map(|dir| {
        let tbl = table.clone();
        let dir = dir.clone();
        let stop = ckpt_stop.clone();
        std::thread::spawn(move || {
            let mut since = 0u64;
            while !stop.load(std::sync::atomic::Ordering::Acquire) {
                std::thread::sleep(Duration::from_millis(25));
                since += 25;
                if since >= CKPT_INTERVAL_MS {
                    since = 0;
                    if let Err(e) = checkpoint::write_service_checkpoint(&dir, &tbl.snapshot())
                    {
                        eprintln!("htap serve: checkpoint failed: {e}");
                    }
                }
            }
        })
    });
    let served = server.serve();
    ckpt_stop.store(true, std::sync::atomic::Ordering::Release);
    if let Some(h) = ckpt_writer {
        let _ = h.join();
    }
    served?;
    if let Some(dir) = &ckpt_dir {
        // final snapshot so a post-shutdown --resume sees terminal states
        checkpoint::write_service_checkpoint(dir, &table.snapshot())?;
    }
    if let Some(path) = &cfg.trace_out {
        let events = table.collector().merged();
        htap::obs::write_trace(path, &events)?;
        println!("wrote {} trace events to {path} (+ {path}.jsonl)", events.len());
    }
    let rows = htap::service::Endpoint::job_report(&*table, 0);
    println!("service stopped: {} job(s) on the table", rows.len());
    for r in rows {
        println!(
            "  job {} [{}] {} '{}' {}/{} done (priority {})",
            r.job, r.tenant, r.state, r.workflow, r.done, r.total, r.priority
        );
    }
    Ok(())
}

fn cmd_submit(cli: &Cli) -> htap::Result<()> {
    let addr = cli
        .get("connect")
        .ok_or_else(|| htap::Error::Config("submit needs --connect HOST:PORT".into()))?;
    let path = cli
        .get("workflow")
        .ok_or_else(|| htap::Error::Config("submit needs --workflow wf.json".into()))?;
    let tenant = cli.get("tenant").unwrap_or("default");
    let priority = cli.get_usize("priority", 1)? as u32;
    let json = std::fs::read_to_string(path)?;
    // admission rejections (queue depth, parse errors) come back as Err
    // and exit nonzero through main
    let s = net::submit_job(addr, tenant, &json, priority)?;
    println!(
        "job {} [{}] {} '{}' ({}/{} done, priority {})",
        s.job, s.tenant, s.state, s.workflow, s.done, s.total, s.priority
    );
    Ok(())
}

fn cmd_jobs(cli: &Cli) -> htap::Result<()> {
    let addr = cli
        .get("connect")
        .ok_or_else(|| htap::Error::Config("jobs needs --connect HOST:PORT".into()))?;
    let job = cli.get_usize("job", 0)? as u64;
    let rows = net::job_reports(addr, job)?;
    if rows.is_empty() {
        println!("no jobs");
        return Ok(());
    }
    println!(
        "{:>5}  {:<12} {:<10} {:>11}  {:>8}  {:>6} {:>6} {:>6}  {:>8}  workflow",
        "job", "tenant", "state", "progress", "assigned", "hits", "cold", "steals", "priority"
    );
    for r in rows {
        println!(
            "{:>5}  {:<12} {:<10} {:>5}/{:<5}  {:>8}  {:>6} {:>6} {:>6}  {:>8}  {}",
            r.job,
            r.tenant,
            r.state,
            r.done,
            r.total,
            r.assigned,
            r.hits,
            r.cold,
            r.steals,
            r.priority,
            r.workflow
        );
    }
    Ok(())
}

fn cmd_top(cli: &Cli) -> htap::Result<()> {
    let addr = cli
        .get("connect")
        .ok_or_else(|| htap::Error::Config("top needs --connect HOST:PORT".into()))?;
    let interval = cli.get_usize("interval-ms", 1000)? as u64;
    let iterations = cli.get_usize("iterations", 0)?;
    let mut polls = 0usize;
    loop {
        // one-shot StatsQuery per poll: the daemon answers from its merged
        // trace rollups, so rows only appear once workers run with tracing
        // armed (--trace-out)
        let rows = net::utilization(addr)?;
        println!("{}", htap::obs::render_util_table(&rows));
        polls += 1;
        if iterations > 0 && polls >= iterations {
            break;
        }
        std::thread::sleep(Duration::from_millis(interval.max(1)));
    }
    Ok(())
}

fn cmd_cancel(cli: &Cli) -> htap::Result<()> {
    let addr = cli
        .get("connect")
        .ok_or_else(|| htap::Error::Config("cancel needs --connect HOST:PORT".into()))?;
    let job = cli.get_usize("job", 0)? as u64;
    if job == 0 {
        return Err(htap::Error::Config("cancel needs --job ID".into()));
    }
    let s = net::cancel_job(addr, job)?;
    println!("job {} [{}] {}", s.job, s.tenant, s.state);
    Ok(())
}

/// Build the `--drain-on` trigger: `file:PATH` polls for PATH to appear;
/// `signal` (alias `signal:term`) / `signal:int` arm a SIGTERM / SIGINT
/// handler that only flips an atomic flag (async-signal-safe).
fn parse_drain_trigger(spec: &str) -> htap::Result<Arc<dyn Fn() -> bool + Send + Sync>> {
    if let Some(path) = spec.strip_prefix("file:") {
        if path.is_empty() {
            return Err(htap::Error::Config("--drain-on file: needs a path".into()));
        }
        let path = std::path::PathBuf::from(path);
        return Ok(Arc::new(move || path.exists()));
    }
    let signum = match spec {
        "signal" | "signal:term" => 15, // SIGTERM
        "signal:int" => 2,             // SIGINT
        other => {
            return Err(htap::Error::Config(format!(
                "bad --drain-on '{other}' (want file:PATH or signal[:term|int])"
            )))
        }
    };
    static DRAIN_SIGNALLED: std::sync::atomic::AtomicBool =
        std::sync::atomic::AtomicBool::new(false);
    extern "C" fn on_drain_signal(_sig: i32) {
        DRAIN_SIGNALLED.store(true, std::sync::atomic::Ordering::Release);
    }
    extern "C" {
        // libc's signal(2) registration; std already links libc
        fn signal(signum: i32, handler: usize) -> usize;
    }
    unsafe {
        signal(signum, on_drain_signal as usize);
    }
    Ok(Arc::new(|| DRAIN_SIGNALLED.load(std::sync::atomic::Ordering::Acquire)))
}

fn cmd_worker(cli: &Cli) -> htap::Result<()> {
    let addr = cli
        .get("connect")
        .ok_or_else(|| htap::Error::Config("worker needs --connect HOST:PORT".into()))?;
    // --connect takes a comma-separated failover list (primary first,
    // then standbys); reconnects rotate through it until one answers
    let addrs: Vec<String> = addr
        .split(',')
        .map(|a| a.trim().to_string())
        .filter(|a| !a.is_empty())
        .collect();
    if addrs.is_empty() {
        return Err(htap::Error::Config("worker needs --connect HOST:PORT".into()));
    }
    let cfg = cli.run_config()?;
    // --drain-on parses before anything connects so a bad spec fails fast
    let drain = match cli.get("drain-on") {
        Some(spec) => Some(parse_drain_trigger(spec)?),
        None => None,
    };
    // measured profiles reach PATS through the SharedProfiles seed below
    let store = load_profiles(cli, cfg.tile_size)?;
    let workflow = resolve_workflow(cli, &cfg, false)?;
    let worker_id = cli.get_usize("worker-id", std::process::id() as usize)?.max(1) as u64;
    // --trace-out arms the tracer; events ship to the manager at heartbeat
    // cadence, and net frame counters register alongside the WRM's
    let metrics = hub_from_config(&cfg, worker_id);
    // --fault-plan / HTAP_FAULTS arm seeded chaos at the worker's net and
    // staging fault sites (flag-level already merged into cfg by the CLI)
    let faults = htap::faults::Faults::from_sources(
        None,
        cfg.fault_plan.as_deref(),
        cfg.fault_seed,
        metrics.registry(),
    )?;
    let source = Arc::new(RemoteManager::connect_opts(
        &addrs,
        metrics.registry(),
        metrics.tracer().clone(),
        faults.clone(),
        net::RetryPolicy::reconnect(),
    )?);
    let profiles = match store {
        Some(s) => SharedProfiles::from_store(s),
        None => SharedProfiles::fresh(),
    };
    // chunk payloads come from this worker's own source, staged through a
    // bounded cache whose prefetcher overlaps reads with compute; with
    // --spill-dir, evictions demote to a local-disk tier instead of
    // dropping
    let (chunks, _) = chunk_source(cli, &cfg)?;
    let chunks = if faults.is_armed() {
        htap::data::staging::FaultySource::wrap(chunks, faults.clone())
    } else {
        chunks
    };
    // --warm-restart: keep whatever survived in the spill directory and
    // re-advertise it to the manager as disk-tier chunks (crash recovery);
    // the default cold start clears the directory
    let warm = cli.get_flag("warm-restart");
    let mut spill = spill_from_config(&cfg, worker_id, warm)?;
    if faults.is_armed() {
        if let Some(tier) = spill.as_mut() {
            tier.set_faults(faults.clone());
        }
    }
    if warm {
        if let Some(tier) = &spill {
            println!(
                "warm restart: recovered {} spilled chunk(s) from the previous incarnation",
                tier.resident_chunks().len()
            );
        }
    }
    let staging = WorkerStaging {
        cache: StagingCache::with_obs(
            chunks,
            cfg.staging_cap,
            cfg.prefetch_depth,
            spill,
            metrics.registry(),
            metrics.tracer().clone(),
        ),
        worker_id,
        prefetch_budget: cfg.prefetch_depth,
    };
    // service mode: fence each tenant's share of this worker's cache
    staging.cache.set_tenant_quota(cfg.tenant_quota);
    // service mode: resolve job-tagged assignments by fetching the job's
    // spec over the wire and compiling it against the full registry
    // (single-manager runs tag everything job 0 and never call this)
    let resolver: JobResolver = {
        let addrs = addrs.clone();
        let registry = service_registry()?;
        Arc::new(move |job| {
            let (tenant, json) =
                net::fetch_job_spec_at(&addrs, job, &net::RetryPolicy::reconnect())?;
            let wf = Arc::new(workflow_from_str(&json, registry.clone())?);
            Ok((tenant, wf))
        })
    };
    println!("worker {worker_id} connected to {addr}");
    run_worker_opts(
        source,
        workflow,
        cfg,
        Arc::new(ArtifactManifest::discover_or_empty()),
        metrics.clone(),
        stage_bindings(),
        profiles.clone(),
        Some(staging),
        WorkerOpts { resolver: Some(resolver), drain },
    )?;
    let report = metrics.report();
    println!("{}", report.profile_table());
    println!("{}", report.staging.summary());
    if let Some(line) = faults.summary() {
        // chaos runs end with their blast radius on record
        println!("{line}");
    }
    if let Some(path) = &cfg.trace_out {
        // the worker's events all shipped to the manager (which owns the
        // merged stream); anything still in the rings here means the final
        // shipment was stranded (e.g. the manager went away) — keep it
        let events = metrics.tracer().drain();
        if !events.is_empty() {
            htap::obs::write_trace(path, &events)?;
            println!("wrote {} stranded trace events to {path}", events.len());
        }
    }
    if let Some(path) = cli.get("save-profiles") {
        let snap = profiles.snapshot();
        snap.save(path)?;
        println!("saved {} measured op profiles to {path}", snap.len());
    }
    Ok(())
}

fn cmd_export_tiles(cli: &Cli) -> htap::Result<()> {
    let dir = cli
        .get("dir")
        .ok_or_else(|| htap::Error::Config("export-tiles needs --dir PATH".into()))?;
    let cfg = cli.run_config()?;
    let store =
        TileStore::new(SynthConfig::for_tile_size(cfg.tile_size, cfg.seed), cfg.n_tiles);
    let n = DirSource::export_store(dir, &store)?;
    println!("wrote {n} {s}x{s} tiles to {dir}", s = cfg.tile_size);
    Ok(())
}
