//! `htap` launcher: run / simulate / serve / join.

use htap::app::{self, build_workflow, stage_bindings, AppParams};
use htap::cli::{Cli, USAGE};
use htap::config::Policy;
use htap::coordinator::{run_local, worker::run_worker, Manager};
use htap::data::{SynthConfig, TileStore};
use htap::dataflow::{workflow_from_file, StageKind, Workflow};
use htap::metrics::MetricsHub;
use htap::net::{ManagerServer, RemoteManager};
use htap::runtime::ArtifactManifest;
use htap::sim::{simulate, SimParams};
use std::sync::Arc;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cli = match Cli::parse(&args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("{e}\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&cli) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn dispatch(cli: &Cli) -> htap::Result<()> {
    match cli.command.as_str() {
        "run" => cmd_run(cli),
        "sim" => cmd_sim(cli),
        "manager" => cmd_manager(cli),
        "worker" => cmd_worker(cli),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => Err(htap::Error::Config(format!("unknown command '{other}'\n{USAGE}"))),
    }
}

fn cmd_run(cli: &Cli) -> htap::Result<()> {
    let cfg = cli.run_config()?;
    // `--workflow wf.json` runs any declarative workflow over the full op
    // registry (WSI + generic ops); the default is the built-in WSI app.
    let workflow: Arc<Workflow> = match cli.get("workflow") {
        Some(path) => {
            let mut registry = app::registry();
            registry.merge(app::generic::generic_registry())?;
            Arc::new(workflow_from_file(path, Arc::new(registry))?)
        }
        None => {
            let params = AppParams::for_tile_size(cfg.tile_size);
            Arc::new(build_workflow(&params, true))
        }
    };
    let store = Arc::new(TileStore::new(
        SynthConfig::for_tile_size(cfg.tile_size, cfg.seed),
        cfg.n_tiles,
    ));
    let n = cfg.n_tiles;
    println!(
        "running workflow '{}': {} tiles ({}x{}) with {} ({} cpu + {} gpu threads, window {})",
        workflow.name, n, cfg.tile_size, cfg.tile_size, cfg.policy.name(), cfg.cpu_workers,
        cfg.gpu_workers, cfg.window
    );
    let outcome = run_local(workflow.clone(), store.loader(), n, cfg, stage_bindings())?;
    let report = outcome.metrics;
    println!("\n{}", report.profile_table());
    println!(
        "wall {:.2}s  ({:.2} tiles/s)",
        report.wall.as_secs_f64(),
        n as f64 / report.wall.as_secs_f64()
    );
    for stage in workflow.stages.iter().filter(|s| s.kind == StageKind::Reduce) {
        if let Some(outs) = outcome.manager.reduce_outputs(&stage.name) {
            println!("reduce stage '{}' produced {} output value(s)", stage.name, outs.len());
        }
    }
    Ok(())
}

fn cmd_sim(cli: &Cli) -> htap::Result<()> {
    let nodes = cli.get_usize("nodes", 1)?;
    let tiles = cli.get_usize("tiles", 100)?;
    let policy = match cli.get("policy") {
        Some(p) => Policy::parse(p)?,
        None => Policy::Pats,
    };
    let p = SimParams { n_nodes: nodes, n_tiles: tiles, policy, ..Default::default() };
    let r = simulate(&p);
    println!(
        "simulated {} tiles on {} Keeneland nodes ({}): makespan {:.1}s, {:.1} tiles/s",
        tiles, nodes, policy.name(), r.makespan, r.tiles_per_second()
    );
    println!(
        "device busy {:.1}s, transfers {:.1}s, tile I/O {:.1}s",
        r.busy_time, r.transfer_time, r.io_time
    );
    Ok(())
}

fn cmd_manager(cli: &Cli) -> htap::Result<()> {
    let listen = cli
        .get("listen")
        .ok_or_else(|| htap::Error::Config("manager needs --listen HOST:PORT".into()))?;
    let cfg = cli.run_config()?;
    let workers = cli.get_usize("workers", 1)?;
    let params = AppParams::for_tile_size(cfg.tile_size);
    let workflow = Arc::new(build_workflow(&params, false));
    let store = Arc::new(TileStore::new(
        SynthConfig::for_tile_size(cfg.tile_size, cfg.seed),
        cfg.n_tiles,
    ));
    let manager = Manager::new(workflow, store.loader(), cfg.n_tiles)?;
    let server = ManagerServer::bind(listen, manager.clone())?;
    println!("manager on {} ({} tiles, expecting {workers} workers)", server.local_addr(), cfg.n_tiles);
    server.serve(workers)?;
    let (done, total) = manager.progress();
    println!("workflow complete: {done}/{total}");
    Ok(())
}

fn cmd_worker(cli: &Cli) -> htap::Result<()> {
    let addr = cli
        .get("connect")
        .ok_or_else(|| htap::Error::Config("worker needs --connect HOST:PORT".into()))?;
    let cfg = cli.run_config()?;
    let params = AppParams::for_tile_size(cfg.tile_size);
    let workflow = Arc::new(build_workflow(&params, false));
    let source = Arc::new(RemoteManager::connect(addr)?);
    let metrics = Arc::new(MetricsHub::new());
    println!("worker connected to {addr}");
    run_worker(
        source,
        workflow,
        cfg,
        Arc::new(ArtifactManifest::discover_or_empty()),
        metrics.clone(),
        stage_bindings(),
    )?;
    println!("{}", metrics.report().profile_table());
    Ok(())
}
