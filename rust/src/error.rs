//! Crate-wide error type.

use std::fmt;

/// Unified error for all htap layers.
#[derive(Debug)]
pub enum Error {
    /// Configuration / manifest / JSON problems.
    Config(String),
    /// PJRT runtime (load / compile / execute) failures.
    Runtime(String),
    /// Dataflow graph construction or binding problems.
    Dataflow(String),
    /// Scheduling protocol violations (should never fire in production).
    Scheduler(String),
    /// Image-processing substrate errors (shape mismatches etc.).
    ImgProc(String),
    /// Networking (TCP manager/worker transport) errors.
    Net(String),
    /// Wrapped I/O error.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Dataflow(m) => write!(f, "dataflow error: {m}"),
            Error::Scheduler(m) => write!(f, "scheduler error: {m}"),
            Error::ImgProc(m) => write!(f, "imgproc error: {m}"),
            Error::Net(m) => write!(f, "net error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[macro_export]
macro_rules! bail {
    ($kind:ident, $($arg:tt)*) => {
        return Err($crate::Error::$kind(format!($($arg)*)))
    };
}
