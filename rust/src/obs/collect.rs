//! Cluster-wide trace collection: merge worker batches into one ordered
//! stream and compute per-job / per-worker utilization rollups.
//!
//! Workers drain their rings and ship [`TraceEvent`] batches to the
//! manager piggybacked on the heartbeat cycle (proto v6 `TraceBatch`);
//! the manager ingests them here next to its own locally recorded
//! events.  [`Collector::merged`] is the export stream; the rollup views
//! feed `JobReport` and the `htap top` utilization table.

use std::sync::Mutex;

use super::trace::{EventKind, TraceEvent};

/// Per-job utilization rollup: op executions attributed to one job.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct JobRollup {
    pub job: u64,
    /// Op instances completed.
    pub ops: u64,
    /// Execution time summed over those ops, µs.
    pub busy_us: u64,
}

/// One row of the `htap top` table: a (worker, job) cell.  `tenant` is
/// joined in by the service layer (the collector doesn't know tenants).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct UtilRow {
    pub worker: u64,
    pub job: u64,
    pub tenant: String,
    pub ops: u64,
    pub busy_us: u64,
}

#[derive(Debug, Default)]
struct Inner {
    events: Vec<TraceEvent>,
    dropped: u64,
}

/// Thread-safe merge point for trace batches from every worker plus the
/// local process.
#[derive(Debug, Default)]
pub struct Collector {
    inner: Mutex<Inner>,
}

impl Collector {
    pub fn new() -> Self {
        Self::default()
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        match self.inner.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        }
    }

    /// Ingest a batch shipped by `worker`; events that were recorded
    /// before the worker learned its id (`worker == 0`) get stamped.
    pub fn ingest(&self, worker: u64, mut events: Vec<TraceEvent>) {
        for ev in &mut events {
            if ev.worker == 0 {
                ev.worker = worker;
            }
        }
        self.ingest_local(events);
    }

    /// Ingest locally recorded events as-is.
    pub fn ingest_local(&self, events: Vec<TraceEvent>) {
        let mut inner = self.lock();
        for ev in &events {
            if ev.kind == EventKind::Dropped {
                inner.dropped += ev.chunk;
            }
        }
        inner.events.extend(events);
    }

    pub fn len(&self) -> usize {
        self.lock().events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total events lost to ring overflow across all ingested batches.
    pub fn dropped(&self) -> u64 {
        self.lock().dropped
    }

    /// The merged stream, ordered by timestamp (ties broken by worker
    /// then lane so repeated exports are deterministic).
    pub fn merged(&self) -> Vec<TraceEvent> {
        let mut evs = self.lock().events.clone();
        evs.sort_by_key(|e| (e.ts_us, e.worker, e.lane));
        evs
    }

    /// Per-job rollup over completed op spans, job-sorted.
    pub fn job_rollups(&self) -> Vec<JobRollup> {
        let inner = self.lock();
        let mut rollups: Vec<JobRollup> = Vec::new();
        for ev in inner.events.iter().filter(|e| e.kind == EventKind::OpEnd) {
            match rollups.iter_mut().find(|r| r.job == ev.job) {
                Some(r) => {
                    r.ops += 1;
                    r.busy_us += ev.dur_us;
                }
                None => rollups.push(JobRollup { job: ev.job, ops: 1, busy_us: ev.dur_us }),
            }
        }
        rollups.sort_by_key(|r| r.job);
        rollups
    }

    /// Per-(worker, job) rollup rows for the live utilization table,
    /// sorted by worker then job.  Tenants are left blank here.
    pub fn util_rows(&self) -> Vec<UtilRow> {
        let inner = self.lock();
        let mut rows: Vec<UtilRow> = Vec::new();
        for ev in inner.events.iter().filter(|e| e.kind == EventKind::OpEnd) {
            match rows.iter_mut().find(|r| r.worker == ev.worker && r.job == ev.job) {
                Some(r) => {
                    r.ops += 1;
                    r.busy_us += ev.dur_us;
                }
                None => rows.push(UtilRow {
                    worker: ev.worker,
                    job: ev.job,
                    tenant: String::new(),
                    ops: 1,
                    busy_us: ev.dur_us,
                }),
            }
        }
        rows.sort_by_key(|r| (r.worker, r.job));
        rows
    }
}

/// Render utilization rows as the `htap top` text table.
pub fn render_util_table(rows: &[UtilRow]) -> String {
    let mut out = format!(
        "{:<8} {:<6} {:<12} {:>8} {:>12}\n",
        "worker", "job", "tenant", "ops", "busy(ms)"
    );
    for r in rows {
        let tenant = if r.tenant.is_empty() { "-" } else { r.tenant.as_str() };
        out.push_str(&format!(
            "{:<8} {:<6} {:<12} {:>8} {:>12.1}\n",
            r.worker,
            r.job,
            tenant,
            r.ops,
            r.busy_us as f64 / 1e3
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn op_end(worker: u64, job: u64, dur_us: u64) -> TraceEvent {
        let mut ev = TraceEvent::of(EventKind::OpEnd);
        ev.ts_us = 1;
        ev.worker = worker;
        ev.job = job;
        ev.dur_us = dur_us;
        ev
    }

    #[test]
    fn ingest_stamps_unidentified_workers() {
        let c = Collector::new();
        let mut ev = TraceEvent::of(EventKind::StagingHit);
        ev.ts_us = 5;
        c.ingest(3, vec![ev]);
        assert_eq!(c.merged()[0].worker, 3);
        // pre-stamped events pass through
        let mut ev = TraceEvent::of(EventKind::StagingHit);
        ev.ts_us = 6;
        ev.worker = 9;
        c.ingest(3, vec![ev]);
        assert_eq!(c.merged()[1].worker, 9);
    }

    #[test]
    fn merged_orders_by_timestamp() {
        let c = Collector::new();
        c.ingest(2, vec![op_end(2, 0, 10)]);
        let mut early = op_end(1, 0, 5);
        early.ts_us = 0; // ingest does not stamp ts, only worker
        early.ts_us = 1;
        c.ingest(1, vec![early]);
        let m = c.merged();
        assert_eq!(m.len(), 2);
        assert!(m[0].ts_us <= m[1].ts_us);
    }

    #[test]
    fn rollups_group_by_job_and_worker() {
        let c = Collector::new();
        c.ingest(1, vec![op_end(1, 7, 100), op_end(1, 7, 50), op_end(1, 8, 25)]);
        c.ingest(2, vec![op_end(2, 7, 10)]);
        let jobs = c.job_rollups();
        assert_eq!(jobs.len(), 2);
        assert_eq!(jobs[0], JobRollup { job: 7, ops: 3, busy_us: 160 });
        assert_eq!(jobs[1], JobRollup { job: 8, ops: 1, busy_us: 25 });
        let rows = c.util_rows();
        assert_eq!(rows.len(), 3);
        assert_eq!((rows[0].worker, rows[0].job, rows[0].ops), (1, 7, 2));
        assert_eq!((rows[2].worker, rows[2].job, rows[2].busy_us), (2, 7, 10));
        let table = render_util_table(&rows);
        assert!(table.contains("worker"), "{table}");
        assert!(table.contains("0.2"), "busy ms column: {table}");
    }

    #[test]
    fn dropped_counts_accumulate() {
        let c = Collector::new();
        let mut d = TraceEvent::of(EventKind::Dropped);
        d.ts_us = 1;
        d.chunk = 4;
        c.ingest(1, vec![d]);
        c.ingest(2, vec![d]);
        assert_eq!(c.dropped(), 8);
    }
}
