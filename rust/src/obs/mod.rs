//! Observability: typed metrics registry, structured event tracing, and
//! cluster-wide trace collection/export.
//!
//! The paper's claims are utilization claims — Figs. 8–10 are timelines
//! and per-device busy fractions — so this subsystem makes every run
//! *inspectable* instead of merely summarized:
//!
//! * [`registry`] — counters / gauges / log2-bucket histograms.  Hot
//!   paths hold clonable atomic handles; the staging cache, WRM, net
//!   framing and service layers register named instruments here instead
//!   of hand-rolled `AtomicU64` struct fields.
//! * [`trace`] — fixed-size [`TraceEvent`] records (op exec begin/end
//!   with device + stage + chunk + job, WRM queue wait, staging
//!   hit/miss/promote/demote/prefetch, frame send/recv, membership and
//!   job lifecycle) written to per-thread bounded rings.  Recording
//!   never blocks and never allocates in steady state; overflow is a
//!   counted drop — safe inside `// lint: critical-section` regions.
//! * [`collect`] — workers drain their rings on the heartbeat cadence
//!   and ship batches to the manager (proto v6 `TraceBatch`); the
//!   [`Collector`] merges them with locally recorded events into one
//!   ordered stream with per-job / per-worker rollups.
//! * [`export`] — Chrome `trace_event` JSON (open in perfetto or
//!   chrome://tracing) plus a JSONL event log, written by `--trace-out`;
//!   `htap sim --trace-out` emits the same schema so simulated and real
//!   timelines diff directly, and `htap top` renders the live rollups.
//!
//! See `docs/observability.md` for the schema and workflows.

pub mod collect;
pub mod export;
pub mod registry;
pub mod trace;

pub use collect::{render_util_table, Collector, JobRollup, UtilRow};
pub use export::{chrome_trace_json, jsonl, write_trace};
pub use registry::{
    Counter, Gauge, HistSnapshot, Histogram, Registry, RegistrySnapshot, HIST_BUCKETS,
};
pub use trace::{
    device_name, EventKind, Name, TraceEvent, Tracer, DEFAULT_RING_CAP, DEV_CPU, DEV_GPU,
    DEV_NONE, NAME_CAP,
};
