//! Typed metrics registry: counters, gauges and log2-bucket histograms.
//!
//! Instruments are registered once (by name) and then written lock-free:
//! each handle is a clonable `Arc` around atomics, so the hot paths that
//! used to bump ad-hoc `AtomicU64` struct fields (staging cache, WRM
//! dispatch, net framing, service admission) bump a [`Counter`] instead —
//! same cost, but every instrument is now discoverable through one
//! [`Registry::snapshot`] instead of scattered report structs.  The
//! registry lock is touched only at registration and snapshot time, never
//! on the increment path.

use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

/// Number of histogram buckets.  Bucket 0 counts zero values; bucket
/// `i >= 1` counts values in `[2^(i-1), 2^i)`; the last bucket absorbs
/// everything at or above `2^(HIST_BUCKETS-2)`.
pub const HIST_BUCKETS: usize = 32;

/// Acquire `m`, recovering the guard if poisoned.  Registry state is
/// plain counter lists; the last consistent view is always usable.
fn lock_clean<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}

/// Monotone event counter.  `Clone` shares the underlying cell.
#[derive(Debug, Clone, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Instantaneous level (queue depths, resident bytes).  Signed so
/// transient imbalance in add/sub pairs can't wrap.
#[derive(Debug, Clone, Default)]
pub struct Gauge(Arc<AtomicI64>);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, d: i64) {
        self.0.fetch_add(d, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

#[derive(Debug)]
struct HistCells {
    buckets: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
}

/// Fixed log2-bucket histogram (latencies in µs, sizes in bytes).
/// Observation is three relaxed atomic adds — no lock, no allocation.
#[derive(Debug, Clone)]
pub struct Histogram(Arc<HistCells>);

impl Default for Histogram {
    fn default() -> Self {
        Histogram(Arc::new(HistCells {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }))
    }
}

impl Histogram {
    /// Bucket holding `v`: 0 for zero, else `floor(log2(v)) + 1`, clamped
    /// to the last bucket.
    pub fn bucket_index(v: u64) -> usize {
        if v == 0 {
            0
        } else {
            (64 - v.leading_zeros() as usize).min(HIST_BUCKETS - 1)
        }
    }

    pub fn observe(&self, v: u64) {
        self.0.buckets[Self::bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.0.count.fetch_add(1, Ordering::Relaxed);
        self.0.sum.fetch_add(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }

    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            buckets: self.0.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            count: self.0.count.load(Ordering::Relaxed),
            sum: self.0.sum.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy of one histogram.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct HistSnapshot {
    pub buckets: Vec<u64>,
    pub count: u64,
    pub sum: u64,
}

impl HistSnapshot {
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }
}

#[derive(Debug, Default)]
struct Instruments {
    counters: Vec<(String, Counter)>,
    gauges: Vec<(String, Gauge)>,
    histograms: Vec<(String, Histogram)>,
}

/// Named-instrument registry.  Registration is get-or-create by name, so
/// two subsystems asking for `"staging.hits"` share one cell.
#[derive(Debug, Default)]
pub struct Registry {
    inner: Mutex<Instruments>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn counter(&self, name: &str) -> Counter {
        let mut inner = lock_clean(&self.inner);
        if let Some((_, c)) = inner.counters.iter().find(|(n, _)| n == name) {
            return c.clone();
        }
        let c = Counter::default();
        inner.counters.push((name.to_string(), c.clone()));
        c
    }

    pub fn gauge(&self, name: &str) -> Gauge {
        let mut inner = lock_clean(&self.inner);
        if let Some((_, g)) = inner.gauges.iter().find(|(n, _)| n == name) {
            return g.clone();
        }
        let g = Gauge::default();
        inner.gauges.push((name.to_string(), g.clone()));
        g
    }

    pub fn histogram(&self, name: &str) -> Histogram {
        let mut inner = lock_clean(&self.inner);
        if let Some((_, h)) = inner.histograms.iter().find(|(n, _)| n == name) {
            return h.clone();
        }
        let h = Histogram::default();
        inner.histograms.push((name.to_string(), h.clone()));
        h
    }

    /// Name-sorted copy of every registered instrument's current value.
    pub fn snapshot(&self) -> RegistrySnapshot {
        let inner = lock_clean(&self.inner);
        let mut counters: Vec<(String, u64)> =
            inner.counters.iter().map(|(n, c)| (n.clone(), c.get())).collect();
        let mut gauges: Vec<(String, i64)> =
            inner.gauges.iter().map(|(n, g)| (n.clone(), g.get())).collect();
        let mut histograms: Vec<(String, HistSnapshot)> =
            inner.histograms.iter().map(|(n, h)| (n.clone(), h.snapshot())).collect();
        counters.sort_by(|a, b| a.0.cmp(&b.0));
        gauges.sort_by(|a, b| a.0.cmp(&b.0));
        histograms.sort_by(|a, b| a.0.cmp(&b.0));
        RegistrySnapshot { counters, gauges, histograms }
    }
}

/// Point-in-time copy of a whole registry, name-sorted.
#[derive(Debug, Clone, Default)]
pub struct RegistrySnapshot {
    pub counters: Vec<(String, u64)>,
    pub gauges: Vec<(String, i64)>,
    pub histograms: Vec<(String, HistSnapshot)>,
}

impl RegistrySnapshot {
    /// A counter's value by name (0 if never registered).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.iter().find(|(n, _)| n == name).map(|(_, v)| *v).unwrap_or(0)
    }

    pub fn gauge(&self, name: &str) -> i64 {
        self.gauges.iter().find(|(n, _)| n == name).map(|(_, v)| *v).unwrap_or(0)
    }

    pub fn histogram(&self, name: &str) -> Option<&HistSnapshot> {
        self.histograms.iter().find(|(n, _)| n == name).map(|(_, h)| h)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_share_by_name() {
        let r = Registry::new();
        let a = r.counter("x");
        let b = r.counter("x");
        a.inc();
        b.add(2);
        assert_eq!(a.get(), 3);
        assert_eq!(r.snapshot().counter("x"), 3);
        assert_eq!(r.snapshot().counter("missing"), 0);
    }

    #[test]
    fn gauges_track_levels() {
        let r = Registry::new();
        let g = r.gauge("depth");
        g.set(5);
        g.add(-2);
        assert_eq!(g.get(), 3);
        assert_eq!(r.snapshot().gauge("depth"), 3);
    }

    #[test]
    fn histogram_bucket_edges() {
        // bucket 0: zero; bucket i >= 1: [2^(i-1), 2^i)
        assert_eq!(Histogram::bucket_index(0), 0);
        assert_eq!(Histogram::bucket_index(1), 1);
        assert_eq!(Histogram::bucket_index(2), 2);
        assert_eq!(Histogram::bucket_index(3), 2);
        assert_eq!(Histogram::bucket_index(4), 3);
        assert_eq!(Histogram::bucket_index(7), 3);
        assert_eq!(Histogram::bucket_index(8), 4);
        for k in 0..63 {
            assert_eq!(Histogram::bucket_index(1u64 << k), (k as usize + 1).min(HIST_BUCKETS - 1));
            if k > 0 {
                // top of the bucket: 2^k - 1 lands one lower than 2^k
                assert_eq!(
                    Histogram::bucket_index((1u64 << k) - 1),
                    (k as usize).min(HIST_BUCKETS - 1)
                );
            }
        }
        assert_eq!(Histogram::bucket_index(u64::MAX), HIST_BUCKETS - 1);
    }

    #[test]
    fn histogram_observe_and_snapshot() {
        let h = Histogram::default();
        h.observe(0);
        h.observe(1);
        h.observe(1000);
        let s = h.snapshot();
        assert_eq!(s.count, 3);
        assert_eq!(s.sum, 1001);
        assert_eq!(s.buckets[0], 1);
        assert_eq!(s.buckets[1], 1);
        assert_eq!(s.buckets[Histogram::bucket_index(1000)], 1);
        assert!((s.mean() - 1001.0 / 3.0).abs() < 1e-9);
        assert_eq!(HistSnapshot::default().mean(), 0.0);
    }

    #[test]
    fn concurrent_writers_lose_nothing() {
        let r = std::sync::Arc::new(Registry::new());
        let h = r.histogram("lat");
        let c = r.counter("n");
        let mut threads = Vec::new();
        for t in 0..8 {
            let h = h.clone();
            let c = c.clone();
            threads.push(std::thread::spawn(move || {
                for i in 0..1000u64 {
                    h.observe(t * 1000 + i);
                    c.inc();
                }
            }));
        }
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(c.get(), 8000);
        assert_eq!(h.snapshot().count, 8000);
        assert_eq!(h.snapshot().buckets.iter().sum::<u64>(), 8000);
    }

    #[test]
    fn snapshot_is_name_sorted() {
        let r = Registry::new();
        r.counter("zz");
        r.counter("aa");
        let names: Vec<&str> =
            r.snapshot().counters.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, ["aa", "zz"]);
    }
}
