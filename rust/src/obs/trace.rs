//! Structured event tracing: cheap fixed-size records in per-thread rings.
//!
//! Design constraints (from the PR 5/6 lock discipline):
//!
//! * **Recording never blocks.**  Each thread writes to its own bounded
//!   ring; the only lock taken is the ring's own mutex via `try_lock`,
//!   which can only be contended by a drain in progress — contention is a
//!   *counted drop*, not a wait.
//! * **Recording never allocates in steady state.**  [`TraceEvent`] is
//!   `Copy` (op names live in a fixed [`Name`] buffer) and each ring's
//!   backing `VecDeque` is preallocated to capacity; the only allocations
//!   happen the first time a thread touches a tracer (ring registration).
//! * **Overflow is a counted drop.**  A full ring drops the new event and
//!   bumps a counter that rides along with the next drain, so trace
//!   consumers can see exactly how much they lost.
//!
//! Timestamps are unix-epoch microseconds (an epoch captured at tracer
//! creation plus a monotonic offset), so events recorded by different
//! processes on one machine merge into a sensibly ordered stream.  The
//! simulator stamps virtual time through the same field.

use std::cell::RefCell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, TryLockError};
use std::time::{Instant, SystemTime, UNIX_EPOCH};

/// Fixed capacity of a [`Name`] buffer, bytes.
pub const NAME_CAP: usize = 24;

/// Default per-thread ring capacity, events.
pub const DEFAULT_RING_CAP: usize = 16 * 1024;

/// Device tag on an event: not device-specific.
pub const DEV_NONE: u8 = 0;
/// Device tag on an event: a CPU compute thread.
pub const DEV_CPU: u8 = 1;
/// Device tag on an event: a GPU controller thread.
pub const DEV_GPU: u8 = 2;

/// Human name for a device tag.
pub fn device_name(d: u8) -> &'static str {
    match d {
        DEV_CPU => "cpu",
        DEV_GPU => "gpu",
        _ => "-",
    }
}

/// What happened.  The discriminant is the wire encoding (proto v6), so
/// values are stable: append only.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
#[repr(u8)]
pub enum EventKind {
    /// An op instance started executing (`dur_us` = 0).
    OpBegin = 1,
    /// An op instance finished; `dur_us` is the execution time.
    OpEnd = 2,
    /// Time a ready task waited in the WRM queue before dispatch.
    QueueWait = 3,
    StagingHit = 4,
    StagingMiss = 5,
    StagingPromote = 6,
    StagingDemote = 7,
    StagingPrefetch = 8,
    StagingEvict = 9,
    /// A protocol frame left this endpoint (`chunk` = payload bytes).
    FrameSend = 10,
    /// A protocol frame arrived at this endpoint (`chunk` = payload bytes).
    FrameRecv = 11,
    WorkerJoin = 12,
    WorkerExpire = 13,
    WorkerLeave = 14,
    JobStart = 15,
    JobDone = 16,
    /// Synthesized at drain time: `chunk` events were dropped to ring
    /// overflow or drain contention since the previous drain.
    Dropped = 17,
}

impl EventKind {
    /// Every kind, for round-trip tests.
    pub const ALL: [EventKind; 17] = [
        EventKind::OpBegin,
        EventKind::OpEnd,
        EventKind::QueueWait,
        EventKind::StagingHit,
        EventKind::StagingMiss,
        EventKind::StagingPromote,
        EventKind::StagingDemote,
        EventKind::StagingPrefetch,
        EventKind::StagingEvict,
        EventKind::FrameSend,
        EventKind::FrameRecv,
        EventKind::WorkerJoin,
        EventKind::WorkerExpire,
        EventKind::WorkerLeave,
        EventKind::JobStart,
        EventKind::JobDone,
        EventKind::Dropped,
    ];

    pub fn from_u8(v: u8) -> Option<EventKind> {
        EventKind::ALL.iter().copied().find(|k| *k as u8 == v)
    }

    pub fn name(self) -> &'static str {
        match self {
            EventKind::OpBegin => "op-begin",
            EventKind::OpEnd => "op-end",
            EventKind::QueueWait => "queue-wait",
            EventKind::StagingHit => "staging-hit",
            EventKind::StagingMiss => "staging-miss",
            EventKind::StagingPromote => "staging-promote",
            EventKind::StagingDemote => "staging-demote",
            EventKind::StagingPrefetch => "staging-prefetch",
            EventKind::StagingEvict => "staging-evict",
            EventKind::FrameSend => "frame-send",
            EventKind::FrameRecv => "frame-recv",
            EventKind::WorkerJoin => "worker-join",
            EventKind::WorkerExpire => "worker-expire",
            EventKind::WorkerLeave => "worker-leave",
            EventKind::JobStart => "job-start",
            EventKind::JobDone => "job-done",
            EventKind::Dropped => "dropped",
        }
    }

    /// Chrome-trace category for this kind.
    pub fn category(self) -> &'static str {
        match self {
            EventKind::OpBegin | EventKind::OpEnd => "op",
            EventKind::QueueWait => "wrm",
            EventKind::StagingHit
            | EventKind::StagingMiss
            | EventKind::StagingPromote
            | EventKind::StagingDemote
            | EventKind::StagingPrefetch
            | EventKind::StagingEvict => "staging",
            EventKind::FrameSend | EventKind::FrameRecv => "net",
            EventKind::WorkerJoin | EventKind::WorkerExpire | EventKind::WorkerLeave => {
                "membership"
            }
            EventKind::JobStart | EventKind::JobDone => "service",
            EventKind::Dropped => "obs",
        }
    }
}

/// Inline fixed-capacity string: op/stage names on events without heap
/// allocation.  Construction truncates to the largest prefix that fits on
/// a UTF-8 character boundary.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct Name {
    len: u8,
    bytes: [u8; NAME_CAP],
}

impl Name {
    pub fn new(s: &str) -> Name {
        let mut end = s.len().min(NAME_CAP);
        while end > 0 && !s.is_char_boundary(end) {
            end -= 1;
        }
        let mut bytes = [0u8; NAME_CAP];
        bytes[..end].copy_from_slice(&s.as_bytes()[..end]);
        Name { len: end as u8, bytes }
    }

    pub fn empty() -> Name {
        Name { len: 0, bytes: [0u8; NAME_CAP] }
    }

    /// Rebuild from wire bytes; `None` if too long or not UTF-8.
    pub fn from_bytes(b: &[u8]) -> Option<Name> {
        if b.len() > NAME_CAP || std::str::from_utf8(b).is_err() {
            return None;
        }
        let mut bytes = [0u8; NAME_CAP];
        bytes[..b.len()].copy_from_slice(b);
        Some(Name { len: b.len() as u8, bytes })
    }

    pub fn as_str(&self) -> &str {
        std::str::from_utf8(&self.bytes[..self.len as usize]).unwrap_or("")
    }

    pub fn as_bytes(&self) -> &[u8] {
        &self.bytes[..self.len as usize]
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

impl std::fmt::Debug for Name {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:?}", self.as_str())
    }
}

impl std::fmt::Display for Name {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// One trace record.  `Copy`, fixed size, no heap.
///
/// Field meaning varies slightly by kind (documented on [`EventKind`]):
/// `chunk` carries the chunk id for op/staging events, payload bytes for
/// frame events, and the drop count for [`EventKind::Dropped`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceEvent {
    /// Unix-epoch microseconds (virtual µs in `htap sim` traces).
    /// Zero means "stamp me at record time".
    pub ts_us: u64,
    /// Span duration in µs; 0 for instant events.
    pub dur_us: u64,
    pub kind: EventKind,
    /// [`DEV_NONE`] / [`DEV_CPU`] / [`DEV_GPU`].
    pub device: u8,
    /// Worker id (0 = "stamp with the tracer's worker id").
    pub worker: u64,
    /// Executor lane (WRM device-thread index) or 0.
    pub lane: u32,
    /// Service-mode job id (0 outside service mode).
    pub job: u64,
    /// Workflow stage index.
    pub stage: u32,
    /// Chunk id / payload bytes / drop count, by kind.
    pub chunk: u64,
    /// Op or peer name ("" when the kind says it all).
    pub name: Name,
}

impl TraceEvent {
    /// A zeroed event of `kind`; fill the fields that matter with struct
    /// update syntax and let [`Tracer::record`] stamp `ts_us`/`worker`.
    pub fn of(kind: EventKind) -> TraceEvent {
        TraceEvent {
            ts_us: 0,
            dur_us: 0,
            kind,
            device: DEV_NONE,
            worker: 0,
            lane: 0,
            job: 0,
            stage: 0,
            chunk: 0,
            name: Name::empty(),
        }
    }
}

/// One thread's bounded event ring.
struct Ring {
    slots: Mutex<VecDeque<TraceEvent>>,
    cap: usize,
    /// Events lost to overflow or drain contention.
    dropped: AtomicU64,
}

impl Ring {
    fn new(cap: usize) -> Ring {
        Ring {
            slots: Mutex::new(VecDeque::with_capacity(cap)),
            cap,
            dropped: AtomicU64::new(0),
        }
    }

    /// Non-blocking push: a held lock (drain in progress) or a full ring
    /// both count a drop instead of waiting or growing.
    fn push(&self, ev: TraceEvent) {
        let mut slots = match self.slots.try_lock() {
            Ok(g) => g,
            Err(TryLockError::Poisoned(p)) => p.into_inner(),
            Err(TryLockError::WouldBlock) => {
                self.dropped.fetch_add(1, Ordering::Relaxed);
                return;
            }
        };
        if slots.len() >= self.cap {
            self.dropped.fetch_add(1, Ordering::Relaxed);
        } else {
            slots.push_back(ev);
        }
    }

    fn drain(&self, into: &mut Vec<TraceEvent>) {
        let mut slots = match self.slots.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        into.extend(slots.drain(..));
    }
}

struct Shared {
    /// Distinguishes tracers in thread-local ring lookup (tests run many
    /// tracers on one thread).
    id: u64,
    enabled: AtomicBool,
    ring_cap: usize,
    /// Unix µs at construction; `origin.elapsed()` added on top.
    epoch_us: u64,
    origin: Instant,
    /// Default worker id stamped on events recorded with `worker == 0`.
    worker: AtomicU64,
    rings: Mutex<Vec<Arc<Ring>>>,
}

thread_local! {
    /// This thread's rings, one per tracer it has recorded to.  A short
    /// linear scan — threads touch one or two tracers in practice.
    static THREAD_RINGS: RefCell<Vec<(u64, Arc<Ring>)>> = const { RefCell::new(Vec::new()) };
}

fn next_tracer_id() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

/// Handle to a trace stream.  Cloning shares the stream; a disabled
/// tracer's [`Tracer::record`] is a single relaxed load.
#[derive(Clone)]
pub struct Tracer {
    shared: Arc<Shared>,
}

impl std::fmt::Debug for Tracer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Tracer")
            .field("enabled", &self.is_enabled())
            .field("worker", &self.worker())
            .finish()
    }
}

impl Default for Tracer {
    fn default() -> Self {
        Tracer::disabled()
    }
}

impl Tracer {
    /// An enabled tracer stamping `worker` on its events.
    pub fn new(worker: u64) -> Tracer {
        Tracer::build(worker, DEFAULT_RING_CAP, true)
    }

    /// An enabled tracer with an explicit per-thread ring capacity.
    pub fn with_capacity(worker: u64, ring_cap: usize) -> Tracer {
        Tracer::build(worker, ring_cap.max(1), true)
    }

    /// A no-op tracer: the default everywhere tracing wasn't requested.
    pub fn disabled() -> Tracer {
        Tracer::build(0, 1, false)
    }

    fn build(worker: u64, ring_cap: usize, enabled: bool) -> Tracer {
        let epoch_us = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_micros() as u64)
            .unwrap_or(0);
        Tracer {
            shared: Arc::new(Shared {
                id: next_tracer_id(),
                enabled: AtomicBool::new(enabled),
                ring_cap,
                epoch_us,
                origin: Instant::now(),
                worker: AtomicU64::new(worker),
                rings: Mutex::new(Vec::new()),
            }),
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.shared.enabled.load(Ordering::Relaxed)
    }

    pub fn set_worker(&self, worker: u64) {
        self.shared.worker.store(worker, Ordering::Relaxed);
    }

    pub fn worker(&self) -> u64 {
        self.shared.worker.load(Ordering::Relaxed)
    }

    /// Current timestamp in the trace's clock (unix-epoch µs).
    pub fn now_us(&self) -> u64 {
        self.shared.epoch_us + self.shared.origin.elapsed().as_micros() as u64
    }

    /// Record one event.  Never blocks, never allocates in steady state;
    /// `ts_us == 0` and `worker == 0` are stamped here.
    pub fn record(&self, mut ev: TraceEvent) {
        if !self.shared.enabled.load(Ordering::Relaxed) {
            return;
        }
        if ev.ts_us == 0 {
            ev.ts_us = self.now_us();
        }
        if ev.worker == 0 {
            ev.worker = self.shared.worker.load(Ordering::Relaxed);
        }
        let id = self.shared.id;
        THREAD_RINGS.with(|cell| {
            let mut rings = match cell.try_borrow_mut() {
                Ok(r) => r,
                // unreachable re-entrancy guard: count, don't panic
                Err(_) => return,
            };
            if let Some((_, ring)) = rings.iter().find(|(rid, _)| *rid == id) {
                ring.push(ev);
                return;
            }
            // first record from this thread: register a ring (allocates,
            // once per thread per tracer)
            let ring = Arc::new(Ring::new(self.shared.ring_cap));
            match self.shared.rings.lock() {
                Ok(mut all) => all.push(ring.clone()),
                Err(p) => p.into_inner().push(ring.clone()),
            }
            ring.push(ev);
            rings.push((id, ring));
        });
    }

    /// Shorthand: record an instant event of `kind`.
    pub fn instant(&self, kind: EventKind) {
        self.record(TraceEvent::of(kind));
    }

    /// Drain every thread's ring into one timestamp-sorted batch and
    /// append a [`EventKind::Dropped`] record when events were lost since
    /// the previous drain.  Called off the hot path (heartbeat cadence).
    pub fn drain(&self) -> Vec<TraceEvent> {
        let rings: Vec<Arc<Ring>> = {
            let all = match self.shared.rings.lock() {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
            all.clone()
        };
        let mut out = Vec::new();
        let mut dropped = 0u64;
        for ring in rings {
            ring.drain(&mut out);
            dropped += ring.dropped.swap(0, Ordering::Relaxed);
        }
        out.sort_by_key(|e| (e.ts_us, e.worker, e.lane));
        if dropped > 0 {
            let mut ev = TraceEvent::of(EventKind::Dropped);
            ev.ts_us = self.now_us();
            ev.worker = self.worker();
            ev.chunk = dropped;
            out.push(ev);
        }
        out
    }

    /// Events currently buffered across all rings (diagnostics/tests).
    pub fn pending(&self) -> usize {
        let rings = match self.shared.rings.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        rings
            .iter()
            .map(|r| match r.slots.lock() {
                Ok(s) => s.len(),
                Err(p) => p.into_inner().len(),
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn name_truncates_on_char_boundary() {
        assert_eq!(Name::new("watershed").as_str(), "watershed");
        let long = "a".repeat(NAME_CAP + 10);
        assert_eq!(Name::new(&long).as_str().len(), NAME_CAP);
        // multibyte char straddling the cap is dropped whole
        let tricky = format!("{}é", "a".repeat(NAME_CAP - 1));
        let n = Name::new(&tricky);
        assert_eq!(n.as_str(), "a".repeat(NAME_CAP - 1));
        assert!(Name::from_bytes(&[0xff, 0xfe]).is_none(), "invalid utf-8 rejected");
        assert!(Name::from_bytes(&vec![b'x'; NAME_CAP + 1]).is_none(), "overlong rejected");
        assert_eq!(Name::from_bytes(b"ok").map(|n| n.as_str().to_string()).as_deref(), Some("ok"));
    }

    #[test]
    fn kind_wire_codes_round_trip() {
        for k in EventKind::ALL {
            assert_eq!(EventKind::from_u8(k as u8), Some(k), "{k:?}");
            assert!(!k.name().is_empty());
            assert!(!k.category().is_empty());
        }
        assert_eq!(EventKind::from_u8(0), None);
        assert_eq!(EventKind::from_u8(200), None);
    }

    #[test]
    fn disabled_tracer_records_nothing() {
        let t = Tracer::disabled();
        t.instant(EventKind::StagingHit);
        assert!(t.drain().is_empty());
    }

    #[test]
    fn record_stamps_ts_and_worker() {
        let t = Tracer::new(7);
        t.instant(EventKind::StagingHit);
        let evs = t.drain();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].worker, 7);
        assert!(evs[0].ts_us > 0);
        // explicit fields pass through untouched
        let mut ev = TraceEvent::of(EventKind::OpEnd);
        ev.ts_us = 123;
        ev.worker = 9;
        t.record(ev);
        let evs = t.drain();
        assert_eq!((evs[0].ts_us, evs[0].worker), (123, 9));
    }

    #[test]
    fn overflow_counts_drops() {
        let t = Tracer::with_capacity(1, 4);
        for _ in 0..10 {
            t.instant(EventKind::StagingMiss);
        }
        let evs = t.drain();
        // 4 kept + 1 synthesized Dropped record carrying the count
        assert_eq!(evs.len(), 5);
        let drop_ev = evs.iter().find(|e| e.kind == EventKind::Dropped).unwrap();
        assert_eq!(drop_ev.chunk, 6);
        // after a drain the ring has room again and drops reset
        t.instant(EventKind::StagingMiss);
        let evs = t.drain();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].kind, EventKind::StagingMiss);
    }

    #[test]
    fn concurrent_writers_each_get_a_ring() {
        let t = Tracer::new(1);
        let mut threads = Vec::new();
        for i in 0..4u32 {
            let t = t.clone();
            threads.push(std::thread::spawn(move || {
                for _ in 0..100 {
                    let mut ev = TraceEvent::of(EventKind::OpEnd);
                    ev.lane = i;
                    t.record(ev);
                }
            }));
        }
        for th in threads {
            th.join().unwrap();
        }
        let evs = t.drain();
        assert_eq!(evs.len(), 400);
        for lane in 0..4 {
            assert_eq!(evs.iter().filter(|e| e.lane == lane).count(), 100);
        }
        // drained in timestamp order
        assert!(evs.windows(2).all(|w| w[0].ts_us <= w[1].ts_us));
    }

    #[test]
    fn two_tracers_on_one_thread_stay_separate() {
        let a = Tracer::new(1);
        let b = Tracer::new(2);
        a.instant(EventKind::StagingHit);
        b.instant(EventKind::StagingMiss);
        let ea = a.drain();
        let eb = b.drain();
        assert_eq!(ea.len(), 1);
        assert_eq!(eb.len(), 1);
        assert_eq!(ea[0].kind, EventKind::StagingHit);
        assert_eq!(eb[0].kind, EventKind::StagingMiss);
    }
}
