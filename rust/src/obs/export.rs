//! Trace export: Chrome `trace_event` JSON (perfetto / chrome://tracing)
//! and a line-per-event JSONL log.
//!
//! The Chrome stream renders completed op spans ([`EventKind::OpEnd`],
//! [`EventKind::QueueWait`]) as `"ph":"X"` complete events — `ts` is the
//! span *start*, so a span whose end was stamped at drain time still
//! lands where it began — and every other kind as a thread-scoped
//! instant.  `pid` is the worker id and `tid` the executor lane, so the
//! perfetto track layout reads as "one process per worker, one track per
//! device thread".  `htap sim --trace-out` emits the same schema with
//! virtual timestamps, so simulated and real timelines diff directly.
//!
//! JSON is hand-rolled: events are flat records over a closed field set,
//! and the crate deliberately has no serialization dependency.

use std::io::Write;

use crate::Result;

use super::trace::{device_name, EventKind, TraceEvent};

/// Minimal JSON string escaping (names are short ASCII identifiers in
/// practice, but tenants are user input).
fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn chrome_args(ev: &TraceEvent) -> String {
    format!(
        "{{\"job\":{},\"stage\":{},\"chunk\":{},\"device\":\"{}\"}}",
        ev.job,
        ev.stage,
        ev.chunk,
        device_name(ev.device)
    )
}

fn chrome_record(ev: &TraceEvent) -> Option<String> {
    let name = if ev.name.is_empty() { ev.kind.name() } else { ev.name.as_str() };
    match ev.kind {
        // OpBegin is implied by the X event built from its OpEnd; keeping
        // both would double-draw every span.
        EventKind::OpBegin => None,
        EventKind::OpEnd | EventKind::QueueWait => Some(format!(
            "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\
             \"pid\":{},\"tid\":{},\"args\":{}}}",
            esc(name),
            ev.kind.category(),
            ev.ts_us.saturating_sub(ev.dur_us),
            ev.dur_us,
            ev.worker,
            ev.lane,
            chrome_args(ev)
        )),
        _ => Some(format!(
            "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"i\",\"ts\":{},\"s\":\"t\",\
             \"pid\":{},\"tid\":{},\"args\":{}}}",
            esc(name),
            ev.kind.category(),
            ev.ts_us,
            ev.worker,
            ev.lane,
            chrome_args(ev)
        )),
    }
}

/// The full Chrome-trace document for an event stream.
pub fn chrome_trace_json(events: &[TraceEvent]) -> String {
    let mut out = String::from("{\"traceEvents\":[\n");
    let mut first = true;
    for ev in events {
        if let Some(rec) = chrome_record(ev) {
            if !first {
                out.push_str(",\n");
            }
            out.push_str(&rec);
            first = false;
        }
    }
    out.push_str("\n]}\n");
    out
}

/// One JSON object per event, every field, nothing dropped — the
/// machine-diffable log next to the Chrome view.
pub fn jsonl(events: &[TraceEvent]) -> String {
    let mut out = String::new();
    for ev in events {
        out.push_str(&format!(
            "{{\"ts_us\":{},\"kind\":\"{}\",\"dur_us\":{},\"worker\":{},\
             \"device\":\"{}\",\"lane\":{},\"job\":{},\"stage\":{},\"chunk\":{},\
             \"name\":\"{}\"}}\n",
            ev.ts_us,
            ev.kind.name(),
            ev.dur_us,
            ev.worker,
            device_name(ev.device),
            ev.lane,
            ev.job,
            ev.stage,
            ev.chunk,
            esc(ev.name.as_str())
        ));
    }
    out
}

/// Write the Chrome trace to `path` and the JSONL log to `path.jsonl`.
pub fn write_trace(path: &str, events: &[TraceEvent]) -> Result<()> {
    let mut f = std::fs::File::create(path)?;
    f.write_all(chrome_trace_json(events).as_bytes())?;
    f.sync_all()?;
    let jl = format!("{path}.jsonl");
    let mut f = std::fs::File::create(&jl)?;
    f.write_all(jsonl(events).as_bytes())?;
    f.sync_all()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::{Name, DEV_GPU};

    fn span(ts: u64, dur: u64, name: &str) -> TraceEvent {
        let mut ev = TraceEvent::of(EventKind::OpEnd);
        ev.ts_us = ts;
        ev.dur_us = dur;
        ev.worker = 1;
        ev.lane = 2;
        ev.device = DEV_GPU;
        ev.name = Name::new(name);
        ev
    }

    #[test]
    fn chrome_span_starts_at_begin() {
        let doc = chrome_trace_json(&[span(150, 50, "watershed")]);
        assert!(doc.contains("\"ph\":\"X\""), "{doc}");
        assert!(doc.contains("\"ts\":100"), "{doc}");
        assert!(doc.contains("\"dur\":50"), "{doc}");
        assert!(doc.contains("\"pid\":1"), "{doc}");
        assert!(doc.contains("\"tid\":2"), "{doc}");
        assert!(doc.contains("\"name\":\"watershed\""), "{doc}");
        assert!(doc.contains("\"device\":\"gpu\""), "{doc}");
    }

    #[test]
    fn chrome_skips_op_begin_keeps_instants() {
        let mut begin = TraceEvent::of(EventKind::OpBegin);
        begin.ts_us = 100;
        let mut hit = TraceEvent::of(EventKind::StagingHit);
        hit.ts_us = 120;
        hit.chunk = 9;
        let doc = chrome_trace_json(&[begin, hit]);
        assert!(!doc.contains("op-begin"), "{doc}");
        assert!(doc.contains("\"name\":\"staging-hit\""), "{doc}");
        assert!(doc.contains("\"ph\":\"i\""), "{doc}");
        assert!(doc.contains("\"chunk\":9"), "{doc}");
    }

    #[test]
    fn jsonl_keeps_every_event_and_field() {
        let mut begin = TraceEvent::of(EventKind::OpBegin);
        begin.ts_us = 100;
        let out = jsonl(&[begin, span(150, 50, "canny")]);
        assert_eq!(out.lines().count(), 2);
        assert!(out.contains("\"kind\":\"op-begin\""), "{out}");
        assert!(out.contains("\"kind\":\"op-end\""), "{out}");
        assert!(out.contains("\"name\":\"canny\""), "{out}");
    }

    #[test]
    fn escapes_hostile_names() {
        let doc = chrome_trace_json(&[span(10, 5, "a\"b\\c")]);
        assert!(doc.contains("a\\\"b\\\\c"), "{doc}");
        assert_eq!(esc("tab\there"), "tab\\there");
        assert_eq!(esc("\u{1}"), "\\u0001");
    }

    #[test]
    fn write_trace_emits_both_files() {
        let dir =
            std::env::temp_dir().join(format!("htap-obs-export-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.json").to_string_lossy().to_string();
        write_trace(&path, &[span(10, 5, "op")]).unwrap();
        let doc = std::fs::read_to_string(&path).unwrap();
        assert!(doc.starts_with("{\"traceEvents\":["), "{doc}");
        let jl = std::fs::read_to_string(format!("{path}.jsonl")).unwrap();
        assert!(jl.contains("\"kind\":\"op-end\""), "{jl}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
