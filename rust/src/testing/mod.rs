//! Minimal property-testing harness (proptest is not in the offline crate
//! set).  Deterministic xorshift PRNG + a `forall` runner that reports the
//! failing seed so cases can be replayed.

use std::fmt::Debug;

/// xorshift64* — deterministic, seedable, good enough for test-case generation.
#[derive(Debug, Clone)]
pub struct Rng(u64);

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng(seed.max(1))
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform f32 in [lo, hi).
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.f32() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Random binary mask with given fill probability.
    pub fn mask(&mut self, h: usize, w: usize, p: f32) -> Vec<f32> {
        (0..h * w).map(|_| if self.f32() < p { 1.0 } else { 0.0 }).collect()
    }

    /// Random grayscale image in [0, 256).
    pub fn image(&mut self, h: usize, w: usize) -> Vec<f32> {
        (0..h * w).map(|_| self.f32_range(0.0, 256.0)).collect()
    }

    /// Shuffle a slice in place (Fisher–Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

/// Run `prop` on `cases` generated inputs; panic with the failing seed.
pub fn forall<T: Debug>(
    name: &str,
    cases: usize,
    gen: impl Fn(&mut Rng) -> T,
    prop: impl Fn(&T) -> std::result::Result<(), String>,
) {
    let seed_base = std::env::var("HTAP_PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE_u64);
    for case in 0..cases {
        let seed = seed_base.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed on case {case} (seed {seed:#x}):\n  {msg}\n  input: {input:?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
            let v = r.range(5, 9);
            assert!((5..=9).contains(&v));
            let f = r.f32();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn forall_passes_trivial_property() {
        forall("sum-commutes", 50, |r| (r.below(100), r.below(100)), |&(a, b)| {
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn forall_reports_failure() {
        forall("always-fails", 5, |r| r.below(10), |_| Err("nope".into()));
    }
}
