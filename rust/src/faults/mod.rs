//! Deterministic fault injection (`--fault-plan` / `HTAP_FAULTS`).
//!
//! A [`FaultPlan`] names *sites* in the runtime — protocol framing,
//! connect, spill-tier and chunk-source I/O, the worker request loop —
//! and attaches a seeded injection rule to each.  The plan is decided
//! entirely by a counter-keyed hash of the plan seed, so a given
//! `(seed, site, occurrence)` triple always injects (or not) the same
//! way: a chaos test that failed in CI replays bit-identically from its
//! spec string, and no wall-clock randomness leaks into the model/lint
//! suites.
//!
//! The handle follows the `obs::Tracer` discipline: a disabled
//! [`Faults`] costs one relaxed atomic load per probe and never locks,
//! allocates, or branches further, so production paths keep the
//! instrumentation compiled in.  Armed handles export one
//! `faults.<site>.injected` counter per active site through the
//! [`obs::Registry`], so tests (and `htap top` snapshots) can assert a
//! plan actually fired rather than silently doing nothing.
//!
//! Spec grammar (comma-separated clauses):
//!
//! ```text
//!   site=rate[@delay_ms][#max]
//!   frame-drop=0.25#8,spill-io=0.1,frame-delay=0.5@20,connect=1#2
//! ```
//!
//! `rate` is an injection probability in `[0, 1]` evaluated per
//! occurrence; `@delay_ms` sets the stall length for delay-flavoured
//! sites (default 10 ms); `#max` caps the total injections at that site
//! (unbounded when absent).  The same grammar is accepted from the
//! `--fault-plan` flag, the `fault_plan` config key, and the
//! `HTAP_FAULTS` environment variable (flag > config > env).

use crate::obs;
use crate::{Error, Result};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

/// Environment variable consulted when neither the flag nor the config
/// names a plan.
pub const FAULTS_ENV: &str = "HTAP_FAULTS";

/// Default stall length for delay-flavoured sites without `@delay_ms`.
const DEFAULT_DELAY_MS: u64 = 10;

/// A named injection site.  The discriminant indexes the plan's rule
/// table, so the probe path is one array load — keep the list dense.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(usize)]
pub enum Site {
    /// Drop an outgoing protocol frame (never written to the socket).
    FrameDrop = 0,
    /// Stall before an outgoing protocol frame is written.
    FrameDelay = 1,
    /// Corrupt an outgoing frame's payload (one byte flipped).
    FrameCorrupt = 2,
    /// Refuse a `TcpStream::connect` before it is attempted.
    Connect = 3,
    /// Stall before a protocol read (a slow peer).
    ReadStall = 4,
    /// Stall before a protocol write flush (a slow pipe).
    WriteStall = 5,
    /// Spill-tier put/get fails as an I/O error.
    SpillIo = 6,
    /// Spill-tier read is slow.
    SpillSlow = 7,
    /// Chunk-source `load` fails as an I/O error.
    SourceIo = 8,
    /// Chunk-source `load` is slow.
    SourceSlow = 9,
    /// Worker pauses before issuing a work request.
    WorkerPause = 10,
}

/// Number of sites (rule-table length).
const N_SITES: usize = 11;

/// Every site with its spec-grammar name.
pub const SITES: [(Site, &str); N_SITES] = [
    (Site::FrameDrop, "frame-drop"),
    (Site::FrameDelay, "frame-delay"),
    (Site::FrameCorrupt, "frame-corrupt"),
    (Site::Connect, "connect"),
    (Site::ReadStall, "read-stall"),
    (Site::WriteStall, "write-stall"),
    (Site::SpillIo, "spill-io"),
    (Site::SpillSlow, "spill-slow"),
    (Site::SourceIo, "source-io"),
    (Site::SourceSlow, "source-slow"),
    (Site::WorkerPause, "worker-pause"),
];

impl Site {
    /// The spec-grammar name (`faults.<name>.injected` counter key).
    pub fn name(self) -> &'static str {
        SITES[self as usize].1
    }
}

/// One parsed clause: inject with probability `rate_ppm`/1e6 per
/// occurrence, stalling `delay_ms` on delay sites, at most `max` times
/// (`u64::MAX` = unbounded).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Rule {
    pub rate_ppm: u32,
    pub delay_ms: u64,
    pub max: u64,
}

/// A parsed, seeded fault plan: rules per site.  Immutable once built;
/// arm it into a [`Faults`] handle to start injecting.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultPlan {
    pub seed: u64,
    rules: [Option<Rule>; N_SITES],
}

impl FaultPlan {
    /// An empty plan (no sites armed).
    pub fn empty(seed: u64) -> FaultPlan {
        FaultPlan { seed, rules: [None; N_SITES] }
    }

    /// Parse the spec grammar (see module docs).  An empty spec is the
    /// empty plan.
    pub fn parse(spec: &str, seed: u64) -> Result<FaultPlan> {
        let mut plan = FaultPlan::empty(seed);
        for clause in spec.split(',').map(str::trim).filter(|c| !c.is_empty()) {
            let (name, rest) = clause
                .split_once('=')
                .ok_or_else(|| Error::Config(format!("fault clause '{clause}' needs site=rate")))?;
            let site = SITES
                .iter()
                .find(|(_, n)| *n == name.trim())
                .map(|(s, _)| *s)
                .ok_or_else(|| {
                    Error::Config(format!(
                        "unknown fault site '{}' (want one of: {})",
                        name.trim(),
                        SITES.map(|(_, n)| n).join(", ")
                    ))
                })?;
            // rate[@delay][#max] — suffixes in either order
            let mut rest = rest.trim();
            let mut delay_ms = DEFAULT_DELAY_MS;
            let mut max = u64::MAX;
            loop {
                if let Some((head, tail)) = rest.rsplit_once('#') {
                    if !tail.contains('@') {
                        max = tail.trim().parse().map_err(|_| {
                            Error::Config(format!("bad fault cap '#{tail}' in '{clause}'"))
                        })?;
                        rest = head.trim();
                        continue;
                    }
                }
                if let Some((head, tail)) = rest.rsplit_once('@') {
                    if !tail.contains('#') {
                        delay_ms = tail.trim().parse().map_err(|_| {
                            Error::Config(format!("bad fault delay '@{tail}' in '{clause}'"))
                        })?;
                        rest = head.trim();
                        continue;
                    }
                }
                break;
            }
            let rate: f64 = rest
                .parse()
                .map_err(|_| Error::Config(format!("bad fault rate '{rest}' in '{clause}'")))?;
            if !(0.0..=1.0).contains(&rate) {
                return Err(Error::Config(format!(
                    "fault rate {rate} out of [0, 1] in '{clause}'"
                )));
            }
            plan.rules[site as usize] =
                Some(Rule { rate_ppm: (rate * 1e6) as u32, delay_ms, max });
        }
        Ok(plan)
    }

    /// The rule for `site`, if the plan arms it.
    pub fn rule(&self, site: Site) -> Option<Rule> {
        self.rules[site as usize]
    }

    /// Whether any site is armed.
    pub fn is_empty(&self) -> bool {
        self.rules.iter().all(|r| r.is_none())
    }
}

/// Per-site injection state: the occurrence counter keys the seeded
/// hash; `fired` enforces `#max` and feeds the registry counter.
struct SiteState {
    rule: Rule,
    occurrences: AtomicU64,
    fired: AtomicU64,
    injected: obs::Counter,
}

struct Inner {
    seed: u64,
    sites: [Option<SiteState>; N_SITES],
}

/// Cloneable injection handle.  [`Faults::disabled`] is the production
/// default: probes cost one relaxed load.  Cloning shares state, so a
/// worker's net, spill, and source sites all draw from one plan.
#[derive(Clone)]
pub struct Faults {
    enabled: Arc<AtomicBool>,
    inner: Arc<Inner>,
}

impl std::fmt::Debug for Faults {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Faults")
            .field("armed", &self.is_armed())
            .field("seed", &self.inner.seed)
            .finish()
    }
}

/// What a probe asks the caller to do.  Delay-flavoured sites carry the
/// stall length; error-flavoured sites are unit verdicts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Injection {
    /// Fail / drop / corrupt — the site's error flavour.
    Fault,
    /// Stall this long, then proceed normally.
    Delay(std::time::Duration),
}

impl Faults {
    /// The zero-cost production handle: never injects.
    pub fn disabled() -> Faults {
        Faults {
            enabled: Arc::new(AtomicBool::new(false)),
            inner: Arc::new(Inner { seed: 0, sites: std::array::from_fn(|_| None) }),
        }
    }

    /// Arm `plan`, registering one `faults.<site>.injected` counter per
    /// active site in `registry`.  An empty plan stays disabled.
    pub fn armed(plan: &FaultPlan, registry: &obs::Registry) -> Faults {
        let sites = std::array::from_fn(|i| {
            plan.rules[i].map(|rule| SiteState {
                rule,
                occurrences: AtomicU64::new(0),
                fired: AtomicU64::new(0),
                injected: registry.counter(&format!("faults.{}.injected", SITES[i].1)),
            })
        });
        Faults {
            enabled: Arc::new(AtomicBool::new(!plan.is_empty())),
            inner: Arc::new(Inner { seed: plan.seed, sites }),
        }
    }

    /// Resolve the active plan source (flag > config > env) into a
    /// handle.  `None`/empty everywhere stays disabled.
    pub fn from_sources(
        flag: Option<&str>,
        config: Option<&str>,
        seed: u64,
        registry: &obs::Registry,
    ) -> Result<Faults> {
        let env = std::env::var(FAULTS_ENV).ok();
        let spec = flag.or(config).or(env.as_deref()).unwrap_or("");
        if spec.trim().is_empty() {
            return Ok(Faults::disabled());
        }
        Ok(Faults::armed(&FaultPlan::parse(spec, seed)?, registry))
    }

    /// Whether any site is armed (one relaxed load).
    pub fn is_armed(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Probe `site`: `None` on the overwhelmingly common no-inject path.
    /// The verdict is a pure function of `(seed, site, occurrence)`.
    pub fn inject(&self, site: Site) -> Option<Injection> {
        if !self.enabled.load(Ordering::Relaxed) {
            return None;
        }
        let st = self.inner.sites[site as usize].as_ref()?;
        let n = st.occurrences.fetch_add(1, Ordering::Relaxed);
        let h = splitmix64(self.inner.seed ^ ((site as u64 + 1) << 56) ^ n);
        if (h % 1_000_000) >= st.rule.rate_ppm as u64 {
            return None;
        }
        // #max cap: fetch_update so concurrent probes never overshoot
        if st
            .fired
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |f| {
                (f < st.rule.max).then_some(f + 1)
            })
            .is_err()
        {
            return None;
        }
        st.injected.inc();
        Some(match site {
            Site::FrameDelay
            | Site::ReadStall
            | Site::WriteStall
            | Site::SpillSlow
            | Site::SourceSlow
            | Site::WorkerPause => {
                Injection::Delay(std::time::Duration::from_millis(st.rule.delay_ms))
            }
            _ => Injection::Fault,
        })
    }

    /// Probe a delay-flavoured site and serve the stall inline.  Returns
    /// whether a stall was injected.
    pub fn maybe_stall(&self, site: Site) -> bool {
        match self.inject(site) {
            Some(Injection::Delay(d)) => {
                std::thread::sleep(d);
                true
            }
            Some(Injection::Fault) => true,
            None => false,
        }
    }

    /// Times `site` has actually injected so far.
    pub fn fired(&self, site: Site) -> u64 {
        self.inner.sites[site as usize]
            .as_ref()
            .map(|s| s.fired.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// One-line blast-radius report (`faults: frame-drop=3 spill-io=2`)
    /// for end-of-run logs; `None` when injection is disarmed so quiet
    /// runs stay quiet.
    pub fn summary(&self) -> Option<String> {
        if !self.is_armed() {
            return None;
        }
        let mut out = String::from("faults:");
        let mut any = false;
        for (site, name) in SITES {
            let n = self.fired(site);
            if n > 0 {
                out.push_str(&format!(" {name}={n}"));
                any = true;
            }
        }
        if !any {
            out.push_str(" none fired");
        }
        Some(out)
    }
}

/// SplitMix64: the seeded occurrence hash.  Small, stateless, and
/// well-mixed — the same generator the synth tile source family uses.
/// Public so the simulator's net-fault mirror draws its drop decisions
/// from the same hash the live injector uses.
pub fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_grammar_parses() {
        let p = FaultPlan::parse("frame-drop=0.25#8, spill-io=0.1, frame-delay=0.5@20", 7)
            .unwrap();
        assert_eq!(
            p.rule(Site::FrameDrop),
            Some(Rule { rate_ppm: 250_000, delay_ms: DEFAULT_DELAY_MS, max: 8 })
        );
        assert_eq!(
            p.rule(Site::SpillIo),
            Some(Rule { rate_ppm: 100_000, delay_ms: DEFAULT_DELAY_MS, max: u64::MAX })
        );
        assert_eq!(
            p.rule(Site::FrameDelay),
            Some(Rule { rate_ppm: 500_000, delay_ms: 20, max: u64::MAX })
        );
        assert_eq!(p.rule(Site::Connect), None);
        // suffixes compose in either order
        let p = FaultPlan::parse("source-slow=1@5#3", 7).unwrap();
        assert_eq!(p.rule(Site::SourceSlow), Some(Rule { rate_ppm: 1_000_000, delay_ms: 5, max: 3 }));
        let p = FaultPlan::parse("source-slow=1#3@5", 7).unwrap();
        assert_eq!(p.rule(Site::SourceSlow), Some(Rule { rate_ppm: 1_000_000, delay_ms: 5, max: 3 }));
        // empty spec = empty plan
        assert!(FaultPlan::parse("", 1).unwrap().is_empty());
        assert!(FaultPlan::parse("  ,  ", 1).unwrap().is_empty());
    }

    #[test]
    fn bad_specs_are_rejected() {
        assert!(FaultPlan::parse("bogus-site=0.5", 1).is_err());
        assert!(FaultPlan::parse("frame-drop", 1).is_err());
        assert!(FaultPlan::parse("frame-drop=1.5", 1).is_err());
        assert!(FaultPlan::parse("frame-drop=-0.1", 1).is_err());
        assert!(FaultPlan::parse("frame-drop=0.5@ten", 1).is_err());
        assert!(FaultPlan::parse("frame-drop=0.5#lots", 1).is_err());
    }

    #[test]
    fn disabled_handle_never_injects() {
        let f = Faults::disabled();
        assert!(!f.is_armed());
        for _ in 0..100 {
            assert_eq!(f.inject(Site::FrameDrop), None);
        }
    }

    #[test]
    fn injection_is_seed_deterministic() {
        let plan = FaultPlan::parse("frame-drop=0.3", 42).unwrap();
        let run = |plan: &FaultPlan| {
            let f = Faults::armed(plan, &obs::Registry::new());
            (0..200).map(|_| f.inject(Site::FrameDrop).is_some()).collect::<Vec<_>>()
        };
        let a = run(&plan);
        let b = run(&plan);
        assert_eq!(a, b, "same seed must replay identically");
        assert!(a.iter().any(|&x| x), "rate 0.3 over 200 trials must fire");
        assert!(a.iter().any(|&x| !x), "rate 0.3 over 200 trials must also skip");
        let other = run(&FaultPlan::parse("frame-drop=0.3", 43).unwrap());
        assert_ne!(a, other, "different seeds must differ");
    }

    #[test]
    fn max_cap_bounds_injections_and_counters_export() {
        let reg = obs::Registry::new();
        let plan = FaultPlan::parse("connect=1#3", 9).unwrap();
        let f = Faults::armed(&plan, &reg);
        let hits = (0..50).filter(|_| f.inject(Site::Connect).is_some()).count();
        assert_eq!(hits, 3);
        assert_eq!(f.fired(Site::Connect), 3);
        assert_eq!(reg.snapshot().counter("faults.connect.injected"), 3);
    }

    #[test]
    fn delay_sites_yield_delays_and_error_sites_faults() {
        let reg = obs::Registry::new();
        let plan = FaultPlan::parse("frame-delay=1@7,spill-io=1", 1).unwrap();
        let f = Faults::armed(&plan, &reg);
        assert_eq!(
            f.inject(Site::FrameDelay),
            Some(Injection::Delay(std::time::Duration::from_millis(7)))
        );
        assert_eq!(f.inject(Site::SpillIo), Some(Injection::Fault));
        assert_eq!(f.inject(Site::FrameDrop), None, "unarmed site stays quiet");
    }

    #[test]
    fn source_precedence_flag_config_env() {
        let reg = obs::Registry::new();
        // flag wins over config
        let f =
            Faults::from_sources(Some("connect=1"), Some("frame-drop=1"), 1, &reg).unwrap();
        assert!(f.inject(Site::Connect).is_some());
        assert!(f.inject(Site::FrameDrop).is_none());
        // absent everywhere stays disabled (HTAP_FAULTS unset in tests)
        if std::env::var(FAULTS_ENV).is_err() {
            let f = Faults::from_sources(None, None, 1, &reg).unwrap();
            assert!(!f.is_armed());
        }
    }
}
