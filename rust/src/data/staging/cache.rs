//! The worker-side staging cache: a bounded in-memory chunk store with a
//! background prefetcher (the paper's "data prefetching and asynchronous
//! data copy", lifted from the GPU copy engine to the node's
//! shared-filesystem reads).
//!
//! The Worker's requester warms the cache with the chunks of every queued
//! assignment (plus the Manager's prefetch hints) as soon as a batch
//! arrives; the prefetcher thread then pulls those chunks from the
//! [`ChunkSource`] while the device threads execute the current pipeline
//! instances.  By the time an assignment's inputs are materialised the
//! read has usually already happened — the hidden read latency is counted
//! in [`StagingReport::hidden`].

use super::source::ChunkSource;
use crate::coordinator::ChunkId;
use crate::metrics::StagingReport;
use crate::runtime::Value;
use crate::Result;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

enum Slot {
    /// A read is in flight (prefetcher or another demand load).
    Loading,
    /// Payload staged in memory.
    Ready {
        vals: Arc<Vec<Value>>,
        /// loaded by the prefetcher (not a demand load)
        prefetched: bool,
        /// how long the read took
        load: Duration,
        /// a consumer already claimed it (hidden-latency counted once)
        claimed: bool,
    },
}

struct Inner {
    slots: HashMap<ChunkId, Slot>,
    /// Ready chunk ids in staging order (eviction scan order).
    order: VecDeque<ChunkId>,
    /// Prefetch work queue (callers bound what they offer; the capacity
    /// bound caps what is held staged at once).
    queue: VecDeque<ChunkId>,
    /// Newly staged chunks not yet reported to the manager.
    staged: Vec<ChunkId>,
    /// Evicted chunks not yet reported to the manager.
    evicted: Vec<ChunkId>,
    shutdown: bool,
}

/// Bounded chunk cache + prefetcher; one per worker process.
pub struct StagingCache {
    source: Arc<dyn ChunkSource>,
    /// max staged chunks held in memory
    cap: usize,
    /// 0 = no prefetcher thread (demand loads only); > 0 also serves as
    /// the hint budget the worker requests from the manager
    depth: usize,
    inner: Mutex<Inner>,
    cv: Condvar,
    hits: AtomicU64,
    misses: AtomicU64,
    prefetched: AtomicU64,
    evictions: AtomicU64,
    hidden_ns: AtomicU64,
    stall_ns: AtomicU64,
}

enum Lookup {
    Ready(Arc<Vec<Value>>, Option<(bool, Duration)>),
    Wait,
    Load,
}

impl StagingCache {
    /// Create a cache over `source` holding at most `cap` chunks, with a
    /// background prefetcher when `depth > 0`.  The prefetcher thread is
    /// detached; call [`StagingCache::shutdown`] when the run ends.
    pub fn new(source: Arc<dyn ChunkSource>, cap: usize, depth: usize) -> Arc<Self> {
        let cache = Arc::new(StagingCache {
            source,
            cap: cap.max(1),
            depth,
            inner: Mutex::new(Inner {
                slots: HashMap::new(),
                order: VecDeque::new(),
                queue: VecDeque::new(),
                staged: Vec::new(),
                evicted: Vec::new(),
                shutdown: false,
            }),
            cv: Condvar::new(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            prefetched: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            hidden_ns: AtomicU64::new(0),
            stall_ns: AtomicU64::new(0),
        });
        if depth > 0 {
            let c = cache.clone();
            std::thread::Builder::new()
                .name("htap-prefetch".into())
                .spawn(move || c.prefetch_loop())
                .expect("spawn prefetcher");
        }
        cache
    }

    /// Queue chunks for background staging (first-come order;
    /// already-staged or already-queued ids are skipped).  Every offered
    /// chunk is enqueued — callers bound the list themselves (the
    /// requester passes its window's assignment chunks plus at most
    /// `prefetch_budget` manager hints), and the capacity bound caps how
    /// many staged payloads are held at once.  No-op when the prefetcher
    /// is disabled.
    pub fn prefetch(&self, chunks: &[ChunkId]) {
        if self.depth == 0 {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        for &c in chunks {
            if inner.slots.contains_key(&c) || inner.queue.contains(&c) {
                continue;
            }
            inner.queue.push_back(c);
        }
        drop(inner);
        self.cv.notify_all();
    }

    fn prefetch_loop(&self) {
        loop {
            let chunk = {
                let mut inner = self.inner.lock().unwrap();
                loop {
                    if inner.shutdown {
                        return;
                    }
                    match inner.queue.pop_front() {
                        Some(c) if inner.slots.contains_key(&c) => continue,
                        Some(c) => {
                            inner.slots.insert(c, Slot::Loading);
                            break c;
                        }
                        None => inner = self.cv.wait(inner).unwrap(),
                    }
                }
            };
            let t0 = Instant::now();
            let loaded = self.source.load(chunk);
            let load = t0.elapsed();
            let mut inner = self.inner.lock().unwrap();
            match loaded {
                Ok(vals) => {
                    let slot = Slot::Ready {
                        vals: Arc::new(vals),
                        prefetched: true,
                        load,
                        claimed: false,
                    };
                    inner.slots.insert(chunk, slot);
                    inner.order.push_back(chunk);
                    inner.staged.push(chunk);
                    self.prefetched.fetch_add(1, Ordering::Relaxed);
                    self.evict_excess(&mut inner);
                }
                // drop the slot: the demand path will retry the read and
                // surface the error to the worker
                Err(_) => {
                    inner.slots.remove(&chunk);
                }
            }
            drop(inner);
            self.cv.notify_all();
        }
    }

    /// Fetch one chunk's payload: staged hit, wait on an in-flight
    /// prefetch, or demand-load on this thread.
    pub fn get(&self, chunk: ChunkId) -> Result<Arc<Vec<Value>>> {
        let t_req = Instant::now();
        let mut counted = false;
        let mut inner = self.inner.lock().unwrap();
        loop {
            let lookup = match inner.slots.get_mut(&chunk) {
                Some(Slot::Ready { vals, prefetched, load, claimed }) => {
                    let newly = if *claimed {
                        None
                    } else {
                        *claimed = true;
                        Some((*prefetched, *load))
                    };
                    Lookup::Ready(vals.clone(), newly)
                }
                Some(Slot::Loading) => Lookup::Wait,
                None => Lookup::Load,
            };
            match lookup {
                Lookup::Ready(vals, newly) => {
                    if !counted {
                        self.hits.fetch_add(1, Ordering::Relaxed);
                    }
                    if let Some((true, load)) = newly {
                        // the part of the read that ran before (or while) we
                        // blocked here was hidden behind compute
                        let waited = t_req.elapsed().min(load);
                        let hidden = load.saturating_sub(waited);
                        self.hidden_ns.fetch_add(hidden.as_nanos() as u64, Ordering::Relaxed);
                        self.stall_ns.fetch_add(waited.as_nanos() as u64, Ordering::Relaxed);
                    }
                    // refresh recency for the eviction scan
                    if let Some(pos) = inner.order.iter().position(|&c| c == chunk) {
                        inner.order.remove(pos);
                        inner.order.push_back(chunk);
                    }
                    return Ok(vals);
                }
                Lookup::Wait => {
                    if !counted {
                        // an in-flight prefetch still counts as a hit: part
                        // of the read is overlapped
                        self.hits.fetch_add(1, Ordering::Relaxed);
                        counted = true;
                    }
                    inner = self.cv.wait(inner).unwrap();
                }
                Lookup::Load => {
                    if !counted {
                        self.misses.fetch_add(1, Ordering::Relaxed);
                        counted = true;
                    }
                    inner.slots.insert(chunk, Slot::Loading);
                    drop(inner);
                    let t0 = Instant::now();
                    let loaded = self.source.load(chunk);
                    let load = t0.elapsed();
                    inner = self.inner.lock().unwrap();
                    match loaded {
                        Ok(vals) => {
                            let vals = Arc::new(vals);
                            inner.slots.insert(
                                chunk,
                                Slot::Ready {
                                    vals: vals.clone(),
                                    prefetched: false,
                                    load,
                                    claimed: true,
                                },
                            );
                            inner.order.push_back(chunk);
                            inner.staged.push(chunk);
                            self.stall_ns.fetch_add(load.as_nanos() as u64, Ordering::Relaxed);
                            self.evict_excess(&mut inner);
                            drop(inner);
                            self.cv.notify_all();
                            return Ok(vals);
                        }
                        Err(e) => {
                            inner.slots.remove(&chunk);
                            drop(inner);
                            self.cv.notify_all();
                            return Err(e);
                        }
                    }
                }
            }
        }
    }

    /// Evict beyond capacity: oldest already-consumed entry first, oldest
    /// entry otherwise.  Caller holds the lock.
    fn evict_excess(&self, inner: &mut Inner) {
        while inner.order.len() > self.cap {
            let pos = inner
                .order
                .iter()
                .position(|c| matches!(inner.slots.get(c), Some(Slot::Ready { claimed: true, .. })))
                .unwrap_or(0);
            if let Some(c) = inner.order.remove(pos) {
                inner.slots.remove(&c);
                inner.evicted.push(c);
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Drain the (staged, evicted) chunk-id deltas accumulated since the
    /// last call — piggybacked on the next work request so the Manager's
    /// catalog tracks this worker.
    pub fn take_staged_delta(&self) -> (Vec<ChunkId>, Vec<ChunkId>) {
        let mut inner = self.inner.lock().unwrap();
        (std::mem::take(&mut inner.staged), std::mem::take(&mut inner.evicted))
    }

    /// Whether a chunk is currently staged (Ready) — test/diagnostic hook.
    pub fn is_staged(&self, chunk: ChunkId) -> bool {
        matches!(self.inner.lock().unwrap().slots.get(&chunk), Some(Slot::Ready { .. }))
    }

    /// Stop the prefetcher thread.
    pub fn shutdown(&self) {
        self.inner.lock().unwrap().shutdown = true;
        self.cv.notify_all();
    }

    /// Snapshot of the staging counters.
    pub fn report(&self) -> StagingReport {
        StagingReport {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            prefetched: self.prefetched.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            hidden: Duration::from_nanos(self.hidden_ns.load(Ordering::Relaxed)),
            stall: Duration::from_nanos(self.stall_ns.load(Ordering::Relaxed)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::staging::SynthSource;
    use crate::data::SynthConfig;

    fn source(n: usize, latency_ms: u64) -> Arc<dyn ChunkSource> {
        Arc::new(
            SynthSource::new(SynthConfig::small(), n)
                .with_read_latency(Duration::from_millis(latency_ms)),
        )
    }

    /// Wait (bounded) until `cond` holds.
    fn poll(mut cond: impl FnMut() -> bool) -> bool {
        for _ in 0..500 {
            if cond() {
                return true;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        false
    }

    #[test]
    fn demand_loads_count_misses() {
        let cache = StagingCache::new(source(4, 0), 4, 0);
        let a = cache.get(0).unwrap();
        let b = cache.get(0).unwrap();
        assert_eq!(a, b);
        let r = cache.report();
        assert_eq!((r.misses, r.hits), (1, 1));
        assert_eq!(r.prefetched, 0);
        cache.shutdown();
    }

    #[test]
    fn prefetched_chunks_hide_read_latency() {
        let cache = StagingCache::new(source(4, 10), 4, 4);
        cache.prefetch(&[0, 1]);
        assert!(poll(|| cache.report().prefetched == 2), "prefetcher never completed");
        assert!(cache.is_staged(0) && cache.is_staged(1));
        cache.get(0).unwrap();
        cache.get(1).unwrap();
        let r = cache.report();
        assert_eq!(r.hits, 2);
        assert_eq!(r.misses, 0);
        assert!(r.hidden > Duration::ZERO, "hidden latency not counted: {r:?}");
        // staged delta reports both chunks exactly once
        let (add, dropped) = cache.take_staged_delta();
        assert_eq!(add, vec![0, 1]);
        assert!(dropped.is_empty());
        assert!(cache.take_staged_delta().0.is_empty());
        cache.shutdown();
    }

    #[test]
    fn prefetch_accepts_batches_larger_than_depth() {
        // a window's worth of assignment chunks must all prefetch even
        // when it exceeds the depth knob (depth gates the thread + hint
        // budget, not the queue)
        let cache = StagingCache::new(source(8, 1), 8, 2);
        cache.prefetch(&[0, 1, 2, 3, 4, 5]);
        assert!(poll(|| cache.report().prefetched == 6), "queue was truncated");
        cache.shutdown();
    }

    #[test]
    fn capacity_bound_evicts_and_reports() {
        let cache = StagingCache::new(source(8, 0), 2, 0);
        for c in 0..4u64 {
            cache.get(c).unwrap();
        }
        let r = cache.report();
        assert_eq!(r.evictions, 2);
        let (add, dropped) = cache.take_staged_delta();
        assert_eq!(add.len(), 4);
        assert_eq!(dropped.len(), 2);
        // evicted chunks are no longer staged; a re-get is a miss
        assert!(!cache.is_staged(dropped[0]));
        cache.get(dropped[0]).unwrap();
        assert_eq!(cache.report().misses, 5);
        cache.shutdown();
    }

    #[test]
    fn out_of_range_chunk_errors() {
        let cache = StagingCache::new(source(2, 0), 2, 0);
        assert!(cache.get(9).is_err());
        // the failed load must not leave a stuck Loading slot
        assert!(cache.get(9).is_err());
        cache.shutdown();
    }
}
