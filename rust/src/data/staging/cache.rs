//! The worker-side staging cache: a bounded in-memory chunk store with a
//! background prefetcher (the paper's "data prefetching and asynchronous
//! data copy", lifted from the GPU copy engine to the node's
//! shared-filesystem reads), optionally backed by a local-disk
//! [`SpillTier`] — together the worker's **tiered chunk store**.
//!
//! The Worker's requester warms the cache with the chunks of every queued
//! assignment (plus the Manager's prefetch hints) as soon as a batch
//! arrives; the prefetcher thread then pulls those chunks from the
//! [`ChunkSource`] while the device threads execute the current pipeline
//! instances.  By the time an assignment's inputs are materialised the
//! read has usually already happened — the hidden read latency is counted
//! in [`StagingReport::hidden`].
//!
//! With a spill tier configured (`--spill-dir`), capacity evictions
//! **demote** payloads to local disk instead of dropping them, and a later
//! miss **promotes** from disk before falling back to the source tier.
//! Demoted chunks stay in the Manager's catalog (they are still cheap on
//! this worker — the `demoted` delta only downgrades their tier), so
//! locality-aware assignment keeps routing their repeat stages here.
//!
//! Service mode adds **per-tenant quotas** layered on the global cap:
//! chunks fetched through [`StagingCache::get_for`] are tagged with the
//! consuming tenant (retagged on access when jobs share chunks), and a
//! quota eviction pre-pass pushes an over-quota tenant's own oldest
//! chunks out *first* — one tenant's 36k-tile flood can shrink only its
//! own working set, never another tenant's.  [`StagingCache::demote_all`]
//! is the graceful-drain hook: every memory-tier payload demotes to the
//! spill tier (or is dropped and reported) so a departing worker leaves a
//! warm disk tier behind for `--warm-restart`.

use super::source::ChunkSource;
use super::tiers::SpillTier;
use crate::config::CacheCap;
use crate::coordinator::ChunkId;
use crate::metrics::StagingReport;
use crate::obs::{self, EventKind, TraceEvent, Tracer};
use crate::runtime::sync::{self, Condvar, HoldWatchdog, Mutex};
use crate::runtime::Value;
use crate::{Error, Result};
use std::collections::{HashMap, VecDeque};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Payload footprint of one staged chunk (tensor dims -> bytes).
pub(crate) fn payload_bytes(vals: &[Value]) -> u64 {
    vals.iter().map(|v| v.size_bytes() as u64).sum()
}

enum Slot {
    /// A read is in flight (prefetcher or another demand load).
    Loading,
    /// Payload staged in memory.
    Ready {
        vals: Arc<Vec<Value>>,
        /// loaded by the prefetcher (not a demand load)
        prefetched: bool,
        /// how long the read took
        load: Duration,
        /// a consumer already claimed it (hidden-latency counted once)
        claimed: bool,
        /// promoted from the local-disk spill tier, not the source
        from_spill: bool,
    },
}

struct Inner {
    slots: HashMap<ChunkId, Slot>,
    /// Ready chunk ids in staging order (eviction scan order).
    order: VecDeque<ChunkId>,
    /// Total payload bytes of Ready slots (drives byte-budget caps).
    mem_bytes: u64,
    /// Prefetch work queue (callers bound what they offer; the capacity
    /// bound caps what is held staged at once).
    queue: VecDeque<ChunkId>,
    /// Optional local-disk spill tier (owned under this lock: spill I/O is
    /// cheap local disk, unlike source reads which run unlocked).
    spill: Option<SpillTier>,
    /// Newly staged chunks not yet reported to the manager.
    staged: Vec<ChunkId>,
    /// Evicted chunks not yet reported to the manager.
    evicted: Vec<ChunkId>,
    /// Chunks demoted memory -> disk, not yet reported to the manager.
    demoted: Vec<ChunkId>,
    /// Owner tag per resident chunk (service mode; retagged on access).
    owners: HashMap<ChunkId, String>,
    /// Resident payload bytes attributed to each owner.
    owner_bytes: HashMap<String, u64>,
    /// Per-tenant budget layered on the global cap (None = off).
    tenant_quota: Option<CacheCap>,
    shutdown: bool,
}

/// Bounded chunk cache + prefetcher; one per worker process.
pub struct StagingCache {
    source: Arc<dyn ChunkSource>,
    /// memory-tier budget: max staged chunks, or max payload bytes
    /// (derived from tensor dims) — `--staging-cap N|NMB`
    cap: CacheCap,
    /// 0 = no prefetcher thread (demand loads only); > 0 also serves as
    /// the hint budget the worker requests from the manager
    depth: usize,
    inner: Mutex<Inner>,
    cv: Condvar,
    /// Trace stream for staging events (disabled outside `--trace-out`
    /// runs; recording is then a single atomic load).
    tracer: Tracer,
    // Counters live in the run's obs registry (`staging.*` instruments);
    // these are lock-free handles, same cost as the AtomicU64 fields they
    // replaced.
    hits: obs::Counter,
    misses: obs::Counter,
    prefetched: obs::Counter,
    evictions: obs::Counter,
    spill_hits: obs::Counter,
    spill_evicted: obs::Counter,
    promoted: obs::Counter,
    replicated: obs::Counter,
    hidden_ns: obs::Counter,
    stall_ns: obs::Counter,
}

enum Lookup {
    Ready(Arc<Vec<Value>>, Option<(bool, Duration, bool)>),
    Wait,
    Load,
}

impl StagingCache {
    /// Create a cache over `source` holding at most `cap` chunks, with a
    /// background prefetcher when `depth > 0`.  The prefetcher thread is
    /// detached; call [`StagingCache::shutdown`] when the run ends.
    pub fn new(
        source: Arc<dyn ChunkSource>,
        cap: impl Into<CacheCap>,
        depth: usize,
    ) -> Arc<Self> {
        Self::new_tiered(source, cap, depth, None)
    }

    /// [`StagingCache::new`] with an optional local-disk spill tier:
    /// evictions demote into it and misses promote from it before falling
    /// back to `source`.
    pub fn new_tiered(
        source: Arc<dyn ChunkSource>,
        cap: impl Into<CacheCap>,
        depth: usize,
        spill: Option<SpillTier>,
    ) -> Arc<Self> {
        Self::with_obs(source, cap, depth, spill, &obs::Registry::new(), Tracer::disabled())
    }

    /// [`StagingCache::new_tiered`] wired into the observability layer:
    /// counters register as `staging.*` instruments in `registry` and
    /// cache activity (hit/miss/promote/demote/prefetch/evict) records
    /// trace events through `tracer`.
    pub fn with_obs(
        source: Arc<dyn ChunkSource>,
        cap: impl Into<CacheCap>,
        depth: usize,
        spill: Option<SpillTier>,
        registry: &obs::Registry,
        tracer: Tracer,
    ) -> Arc<Self> {
        let cap = match cap.into() {
            CacheCap::Chunks(n) => CacheCap::Chunks(n.max(1)),
            b => b,
        };
        // warm restart: chunks recovered from a surviving spill dir are
        // announced as *demoted* in the first staged delta, so the
        // Manager's catalog lists the restarted worker as a disk-tier
        // holder again (repeat stages route here, no cold re-read).
        // A freshly created tier is empty and this is a no-op.
        let recovered: Vec<ChunkId> =
            spill.as_ref().map(|s| s.resident_chunks()).unwrap_or_default();
        let cache = Arc::new(StagingCache {
            source,
            cap,
            depth,
            inner: Mutex::new(Inner {
                slots: HashMap::new(),
                order: VecDeque::new(),
                mem_bytes: 0,
                queue: VecDeque::new(),
                spill,
                staged: Vec::new(),
                evicted: Vec::new(),
                demoted: recovered,
                owners: HashMap::new(),
                owner_bytes: HashMap::new(),
                tenant_quota: None,
                shutdown: false,
            }),
            cv: Condvar::new(),
            tracer,
            hits: registry.counter("staging.hits"),
            misses: registry.counter("staging.misses"),
            prefetched: registry.counter("staging.prefetched"),
            evictions: registry.counter("staging.evictions"),
            spill_hits: registry.counter("staging.spill_hits"),
            spill_evicted: registry.counter("staging.spill_evicted"),
            promoted: registry.counter("staging.promoted"),
            replicated: registry.counter("staging.replicated"),
            hidden_ns: registry.counter("staging.hidden_ns"),
            stall_ns: registry.counter("staging.stall_ns"),
        });
        if depth > 0 {
            let c = cache.clone();
            sync::thread::Builder::new()
                .name("htap-prefetch".into())
                .spawn(move || c.prefetch_loop())
                // lint: allow(panic) — failing to spawn at startup is fatal
                .expect("spawn prefetcher");
        }
        cache
    }

    /// Record a per-chunk staging trace event.  Non-blocking and
    /// allocation-free, so it is safe inside the cache's lint-marked
    /// critical sections (a disabled tracer reduces to one atomic load).
    fn trace_chunk(&self, kind: EventKind, chunk: ChunkId) {
        self.tracer.record(TraceEvent { chunk, ..TraceEvent::of(kind) });
    }

    /// Queue chunks for background staging (first-come order;
    /// already-staged or already-queued ids are skipped).  Every offered
    /// chunk is enqueued — callers bound the list themselves (the
    /// requester passes its window's assignment chunks plus at most
    /// `prefetch_budget` manager hints), and the capacity bound caps how
    /// many staged payloads are held at once.  No-op when the prefetcher
    /// is disabled.
    pub fn prefetch(&self, chunks: &[ChunkId]) {
        if self.depth == 0 {
            return;
        }
        // hint path: recover from poisoning, hints are best-effort
        let mut inner = sync::lock_clean(&self.inner);
        // lint: critical-section — queue pushes only
        let hold = HoldWatchdog::new("cache.prefetch_enqueue");
        for &c in chunks {
            if inner.slots.contains_key(&c) || inner.queue.contains(&c) {
                continue;
            }
            inner.queue.push_back(c);
        }
        drop(hold);
        drop(inner);
        self.cv.notify_all();
    }

    /// Queue chunks the Manager flagged as steal replicas (the stolen
    /// chunk is now multi-homed; staging it early keeps this worker a
    /// cheap home).  Counts how many actually enqueue.  No-op when the
    /// prefetcher is disabled.
    pub fn prefetch_replicas(&self, chunks: &[ChunkId]) {
        if self.depth == 0 || chunks.is_empty() {
            return;
        }
        let mut inner = sync::lock_clean(&self.inner);
        // lint: critical-section — queue pushes only
        let mut n = 0u64;
        for &c in chunks {
            if inner.slots.contains_key(&c) || inner.queue.contains(&c) {
                continue;
            }
            inner.queue.push_back(c);
            n += 1;
        }
        drop(inner);
        if n > 0 {
            self.replicated.add(n);
            self.cv.notify_all();
        }
    }

    /// Promote `chunk` from the spill tier into the memory tier, under the
    /// lock.  Returns the payload when the disk copy existed and read back.
    fn try_promote(
        &self,
        inner: &mut Inner,
        chunk: ChunkId,
        prefetched: bool,
        claimed: bool,
    ) -> Option<Arc<Vec<Value>>> {
        // lint: critical-section — caller holds the cache lock
        let spill = inner.spill.as_mut()?;
        // lint: allow(io) — spill promotion reads cheap local disk by design
        let vals = spill.get(chunk)?;
        let vals = Arc::new(vals);
        inner.mem_bytes += payload_bytes(&vals);
        inner.slots.insert(
            chunk,
            Slot::Ready {
                vals: vals.clone(),
                prefetched,
                load: Duration::ZERO,
                claimed,
                from_spill: true,
            },
        );
        inner.order.push_back(chunk);
        // re-announce: the catalog entry tiers back up to memory
        inner.staged.push(chunk);
        self.promoted.inc();
        self.trace_chunk(EventKind::StagingPromote, chunk);
        if claimed {
            // demand-path promotion: the consumer is served from disk now
            self.spill_hits.inc();
        }
        self.evict_excess(inner);
        Some(vals)
    }

    fn prefetch_loop(&self) {
        enum Next {
            Load(ChunkId),
            Promoted,
        }
        loop {
            let next = {
                // poisoned = some critical section panicked; the prefetcher
                // just exits, demand loads still serve the run
                let Ok(mut inner) = sync::lock_or_poisoned(&self.inner) else { return };
                // lint: critical-section — queue pop + spill promotion only
                loop {
                    if inner.shutdown {
                        return;
                    }
                    match inner.queue.pop_front() {
                        Some(c) if inner.slots.contains_key(&c) => continue,
                        Some(c) => {
                            // cheap local-disk promotion before the source
                            if self.try_promote(&mut inner, c, true, false).is_some() {
                                self.prefetched.inc();
                                break Next::Promoted;
                            }
                            inner.slots.insert(c, Slot::Loading);
                            break Next::Load(c);
                        }
                        None => {
                            inner = match self.cv.wait(inner) {
                                Ok(g) => g,
                                Err(_) => return,
                            }
                        }
                    }
                }
            };
            let chunk = match next {
                Next::Promoted => {
                    self.cv.notify_all();
                    continue;
                }
                Next::Load(c) => c,
            };
            let t0 = Instant::now();
            let loaded = self.source.load(chunk);
            let load = t0.elapsed();
            let Ok(mut inner) = sync::lock_or_poisoned(&self.inner) else { return };
            // lint: critical-section — record payload + eviction scan only
            // (spill budget: demotion may write local disk)
            let hold = HoldWatchdog::with_budget_us("cache.prefetch_record", 5_000);
            match loaded {
                Ok(vals) => {
                    inner.mem_bytes += payload_bytes(&vals);
                    let slot = Slot::Ready {
                        vals: Arc::new(vals),
                        prefetched: true,
                        load,
                        claimed: false,
                        from_spill: false,
                    };
                    inner.slots.insert(chunk, slot);
                    inner.order.push_back(chunk);
                    inner.staged.push(chunk);
                    self.prefetched.inc();
                    self.trace_chunk(EventKind::StagingPrefetch, chunk);
                    self.evict_excess(&mut inner);
                }
                // drop the slot: the demand path will retry the read and
                // surface the error to the worker
                Err(_) => {
                    inner.slots.remove(&chunk);
                }
            }
            drop(hold);
            drop(inner);
            self.cv.notify_all();
        }
    }

    /// Fetch one chunk's payload: staged hit, wait on an in-flight
    /// prefetch, or demand-load on this thread.
    pub fn get(&self, chunk: ChunkId) -> Result<Arc<Vec<Value>>> {
        const POISONED: &str = "staging cache poisoned (a critical section panicked)";
        let t_req = Instant::now();
        let mut counted = false;
        let Ok(mut inner) = sync::lock_or_poisoned(&self.inner) else {
            // demand path: surface poisoning as an error so the worker
            // fails the assignment instead of cascading the panic
            return Err(Error::Scheduler(POISONED.into()));
        };
        // lint: critical-section — slot lookup/claim + LRU bump only
        loop {
            let lookup = match inner.slots.get_mut(&chunk) {
                Some(Slot::Ready { vals, prefetched, load, claimed, from_spill }) => {
                    let newly = if *claimed {
                        None
                    } else {
                        *claimed = true;
                        Some((*prefetched, *load, *from_spill))
                    };
                    Lookup::Ready(vals.clone(), newly)
                }
                Some(Slot::Loading) => Lookup::Wait,
                None => Lookup::Load,
            };
            match lookup {
                Lookup::Ready(vals, newly) => {
                    if !counted {
                        self.hits.inc();
                        self.trace_chunk(EventKind::StagingHit, chunk);
                    }
                    if let Some((_, _, true)) = newly {
                        // first consumer of a prefetch-promoted chunk: the
                        // fetch was served by the local-disk tier
                        self.spill_hits.inc();
                    }
                    if let Some((true, load, false)) = newly {
                        // the part of the read that ran before (or while) we
                        // blocked here was hidden behind compute
                        let waited = t_req.elapsed().min(load);
                        let hidden = load.saturating_sub(waited);
                        self.hidden_ns.add(hidden.as_nanos() as u64);
                        self.stall_ns.add(waited.as_nanos() as u64);
                    }
                    // refresh recency for the eviction scan
                    if let Some(pos) = inner.order.iter().position(|&c| c == chunk) {
                        inner.order.remove(pos);
                        inner.order.push_back(chunk);
                    }
                    return Ok(vals);
                }
                Lookup::Wait => {
                    if !counted {
                        // an in-flight prefetch still counts as a hit: part
                        // of the read is overlapped
                        self.hits.inc();
                        self.trace_chunk(EventKind::StagingHit, chunk);
                        counted = true;
                    }
                    inner = match self.cv.wait(inner) {
                        Ok(g) => g,
                        Err(_) => return Err(Error::Scheduler(POISONED.into())),
                    };
                }
                Lookup::Load => {
                    if !counted {
                        self.misses.inc();
                        self.trace_chunk(EventKind::StagingMiss, chunk);
                        counted = true;
                    }
                    // memory miss: the local-disk tier answers before the
                    // (expensive) source tier does
                    if let Some(vals) = self.try_promote(&mut inner, chunk, false, true) {
                        drop(inner);
                        self.cv.notify_all();
                        return Ok(vals);
                    }
                    inner.slots.insert(chunk, Slot::Loading);
                    drop(inner);
                    // lint: end-critical-section — the expensive source
                    // read runs unlocked; compute threads keep hitting
                    let t0 = Instant::now();
                    let loaded = self.source.load(chunk);
                    let load = t0.elapsed();
                    inner = match sync::lock_or_poisoned(&self.inner) {
                        Ok(g) => g,
                        Err(_) => return Err(Error::Scheduler(POISONED.into())),
                    };
                    // lint: critical-section — record payload + eviction
                    // scan only (spill budget: demotion may write disk)
                    let hold = HoldWatchdog::with_budget_us("cache.demand_record", 5_000);
                    match loaded {
                        Ok(vals) => {
                            let vals = Arc::new(vals);
                            inner.mem_bytes += payload_bytes(&vals);
                            inner.slots.insert(
                                chunk,
                                Slot::Ready {
                                    vals: vals.clone(),
                                    prefetched: false,
                                    load,
                                    claimed: true,
                                    from_spill: false,
                                },
                            );
                            inner.order.push_back(chunk);
                            inner.staged.push(chunk);
                            self.stall_ns.add(load.as_nanos() as u64);
                            self.evict_excess(&mut inner);
                            drop(hold);
                            drop(inner);
                            self.cv.notify_all();
                            return Ok(vals);
                        }
                        Err(e) => {
                            inner.slots.remove(&chunk);
                            drop(hold);
                            drop(inner);
                            self.cv.notify_all();
                            return Err(e);
                        }
                    }
                }
            }
        }
    }

    /// [`StagingCache::get`] with tenant attribution (service mode): the
    /// fetched chunk is tagged as `tenant`'s — retagged if another tenant
    /// staged it first, so shared chunks bill whoever touched them last —
    /// and the per-tenant quota pre-pass runs.  An empty tenant (the
    /// single-job path) skips attribution entirely.
    pub fn get_for(&self, tenant: &str, chunk: ChunkId) -> Result<Arc<Vec<Value>>> {
        let vals = self.get(chunk)?;
        if tenant.is_empty() {
            return Ok(vals);
        }
        let mut inner = sync::lock_clean(&self.inner);
        // lint: critical-section — owner retag + quota eviction scan only
        let hold = HoldWatchdog::with_budget_us("cache.retag", 5_000);
        self.retag(&mut inner, chunk, tenant);
        self.evict_over_quota(&mut inner);
        drop(hold);
        drop(inner);
        Ok(vals)
    }

    /// Attribute a resident chunk's bytes to `tenant` (caller holds the
    /// lock).  No-op when the chunk is not Ready or already theirs.
    fn retag(&self, inner: &mut Inner, chunk: ChunkId, tenant: &str) {
        // lint: critical-section — caller holds the cache lock
        let bytes = match inner.slots.get(&chunk) {
            Some(Slot::Ready { vals, .. }) => payload_bytes(vals),
            _ => return,
        };
        if inner.owners.get(&chunk).is_some_and(|o| o == tenant) {
            return;
        }
        let prev = inner.owners.insert(chunk, tenant.to_string());
        if let Some(p) = prev {
            if let Some(b) = inner.owner_bytes.get_mut(&p) {
                *b = b.saturating_sub(bytes);
            }
        }
        *inner.owner_bytes.entry(tenant.to_string()).or_insert(0) += bytes;
    }

    /// Set (or clear) the per-tenant staging quota.  Applies to every
    /// tenant uniformly, layered under the global cap.
    pub fn set_tenant_quota(&self, quota: Option<CacheCap>) {
        let mut inner = sync::lock_clean(&self.inner);
        inner.tenant_quota = quota;
        self.evict_over_quota(&mut inner);
    }

    /// Resident payload bytes currently attributed to `tenant` —
    /// test/diagnostic hook.
    pub fn tenant_bytes(&self, tenant: &str) -> u64 {
        sync::lock_clean(&self.inner).owner_bytes.get(tenant).copied().unwrap_or(0)
    }

    /// Graceful-drain hook: demote every memory-tier payload (to the
    /// spill tier when one exists, else drop + report evicted) so a
    /// departing worker leaves a warm local-disk tier behind for
    /// `--warm-restart`.  Returns how many chunks left the memory tier.
    pub fn demote_all(&self) -> usize {
        let mut inner = sync::lock_clean(&self.inner);
        // lint: critical-section — eviction scan only (spill budget:
        // demotion may write local disk)
        let mut n = 0usize;
        while !inner.order.is_empty() {
            self.evict_at(&mut inner, 0);
            n += 1;
        }
        drop(inner);
        self.cv.notify_all();
        n
    }

    /// Whether the memory tier exceeds its budget (chunk count, or payload
    /// bytes — a single over-budget chunk is always allowed to stay).
    fn over_budget(&self, inner: &Inner) -> bool {
        match self.cap {
            CacheCap::Chunks(cap) => inner.order.len() > cap,
            CacheCap::Bytes(cap) => inner.mem_bytes > cap && inner.order.len() > 1,
        }
    }

    /// Evict (or demote) the chunk at eviction-scan position `pos`.  With
    /// a spill tier, the payload demotes to local disk (the chunk stays
    /// catalogued, just a tier down); without one — or if the disk write
    /// fails — it is dropped and reported evicted.  Caller holds the lock.
    fn evict_at(&self, inner: &mut Inner, pos: usize) {
        // lint: critical-section — caller holds the cache lock
        let Some(c) = inner.order.remove(pos) else { return };
        let vals = match inner.slots.remove(&c) {
            Some(Slot::Ready { vals, .. }) => Some(vals),
            _ => None,
        };
        if let Some(v) = vals.as_ref() {
            let bytes = payload_bytes(v);
            inner.mem_bytes = inner.mem_bytes.saturating_sub(bytes);
            // owner attribution leaves with the payload
            if let Some(owner) = inner.owners.remove(&c) {
                if let Some(b) = inner.owner_bytes.get_mut(&owner) {
                    *b = b.saturating_sub(bytes);
                }
            }
        }
        let mut dropped_from_disk: Vec<ChunkId> = Vec::new();
        let mut demoted = false;
        if let Some(vals) = vals.as_ref() {
            if let Some(spill) = inner.spill.as_mut() {
                // lint: allow(io) — demotion writes cheap local disk by design
                if let Ok(dropped) = spill.put(c, vals) {
                    demoted = true;
                    dropped_from_disk = dropped;
                }
            }
        }
        if demoted {
            self.spill_evicted.inc();
            self.trace_chunk(EventKind::StagingDemote, c);
            inner.demoted.push(c);
            for d in dropped_from_disk {
                // a chunk pushed out of the disk tier is gone from this
                // worker — unless a promoted copy still sits in memory
                if !inner.slots.contains_key(&d) {
                    inner.evicted.push(d);
                    self.evictions.inc();
                    self.trace_chunk(EventKind::StagingEvict, d);
                }
            }
        } else {
            inner.evicted.push(c);
            self.evictions.inc();
            self.trace_chunk(EventKind::StagingEvict, c);
        }
    }

    /// Whether `owner` exceeds the per-tenant quota (chunk count, or
    /// payload bytes — like the global cap, a single over-budget chunk is
    /// always allowed to stay).
    fn owner_over_quota(&self, inner: &Inner, owner: &str) -> bool {
        let Some(quota) = inner.tenant_quota else {
            return false;
        };
        let count = inner.owners.values().filter(|o| o.as_str() == owner).count();
        match quota {
            CacheCap::Chunks(cap) => count > cap,
            CacheCap::Bytes(cap) => {
                inner.owner_bytes.get(owner).copied().unwrap_or(0) > cap && count > 1
            }
        }
    }

    /// Quota pre-pass: evict over-quota tenants' own oldest chunks
    /// (already-consumed entries first), leaving every within-quota
    /// tenant's working set untouched.  Caller holds the lock.
    fn evict_over_quota(&self, inner: &mut Inner) {
        // lint: critical-section — caller holds the cache lock
        if inner.tenant_quota.is_none() {
            return;
        }
        loop {
            let claimed_pos = inner.order.iter().position(|c| {
                matches!(inner.slots.get(c), Some(Slot::Ready { claimed: true, .. }))
                    && inner.owners.get(c).is_some_and(|o| self.owner_over_quota(inner, o))
            });
            let pos = claimed_pos.or_else(|| {
                inner
                    .order
                    .iter()
                    .position(|c| {
                        inner.owners.get(c).is_some_and(|o| self.owner_over_quota(inner, o))
                    })
            });
            let Some(pos) = pos else { return };
            self.evict_at(inner, pos);
        }
    }

    /// Evict beyond capacity: over-quota tenants' chunks first (so one
    /// tenant's flood only shrinks its own working set), then oldest
    /// already-consumed entry, oldest entry otherwise.  Caller holds the
    /// lock.
    fn evict_excess(&self, inner: &mut Inner) {
        // lint: critical-section — caller holds the cache lock
        self.evict_over_quota(inner);
        while self.over_budget(inner) {
            let pos = inner
                .order
                .iter()
                .position(|c| matches!(inner.slots.get(c), Some(Slot::Ready { claimed: true, .. })))
                .unwrap_or(0);
            if inner.order.is_empty() {
                return;
            }
            self.evict_at(inner, pos);
        }
    }

    /// Drain the (staged, evicted, demoted) chunk-id deltas accumulated
    /// since the last call — piggybacked on the next work request so the
    /// Manager's catalog tracks this worker (and each chunk's tier).
    pub fn take_staged_delta(&self) -> (Vec<ChunkId>, Vec<ChunkId>, Vec<ChunkId>) {
        // delta reporting degrades gracefully on poisoning
        let mut inner = sync::lock_clean(&self.inner);
        (
            std::mem::take(&mut inner.staged),
            std::mem::take(&mut inner.evicted),
            std::mem::take(&mut inner.demoted),
        )
    }

    /// Reconnect hook: repopulate the next staged delta with
    /// *everything* this worker holds — every memory-tier (Ready) chunk
    /// as staged, every disk-only spill resident as demoted — so a
    /// freshly promoted manager's checkpoint-stale catalog relearns the
    /// full set on the next `Request`.  Catalog inserts are idempotent,
    /// so re-advertising to the original manager is harmless; pending
    /// eviction deltas are dropped (stale locality hints only cost a
    /// cache miss, never correctness).
    pub fn resync_staged(&self) {
        let mut inner = sync::lock_clean(&self.inner);
        // lint: critical-section — id collection only
        let ready: Vec<ChunkId> = inner
            .order
            .iter()
            .copied()
            .filter(|c| matches!(inner.slots.get(c), Some(Slot::Ready { .. })))
            .collect();
        let spilled: Vec<ChunkId> = inner
            .spill
            .as_ref()
            .map(|s| s.resident_chunks())
            .unwrap_or_default()
            .into_iter()
            // dual residents advertise at the memory tier
            .filter(|c| !matches!(inner.slots.get(c), Some(Slot::Ready { .. })))
            .collect();
        inner.staged = ready;
        inner.evicted.clear();
        inner.demoted = spilled;
    }

    /// Whether a chunk is currently staged (Ready) — test/diagnostic hook.
    pub fn is_staged(&self, chunk: ChunkId) -> bool {
        matches!(sync::lock_clean(&self.inner).slots.get(&chunk), Some(Slot::Ready { .. }))
    }

    /// Whether a chunk currently sits in the spill tier — test hook.
    pub fn is_spilled(&self, chunk: ChunkId) -> bool {
        sync::lock_clean(&self.inner).spill.as_ref().map(|s| s.contains(chunk)).unwrap_or(false)
    }

    /// Stop the prefetcher thread.
    pub fn shutdown(&self) {
        sync::lock_clean(&self.inner).shutdown = true;
        self.cv.notify_all();
    }

    /// Snapshot of the staging counters.  Since the counters are registry
    /// instruments, the same numbers are visible as `staging.*` in the
    /// run's [`obs::Registry`] snapshot; this struct remains the stable
    /// report shape the Manager and `MetricsReport` consume.
    pub fn report(&self) -> StagingReport {
        StagingReport {
            hits: self.hits.get(),
            misses: self.misses.get(),
            prefetched: self.prefetched.get(),
            evictions: self.evictions.get(),
            spill_hits: self.spill_hits.get(),
            spill_evicted: self.spill_evicted.get(),
            promoted: self.promoted.get(),
            replicated: self.replicated.get(),
            hidden: Duration::from_nanos(self.hidden_ns.get()),
            stall: Duration::from_nanos(self.stall_ns.get()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::staging::SynthSource;
    use crate::data::SynthConfig;

    fn source(n: usize, latency_ms: u64) -> Arc<dyn ChunkSource> {
        Arc::new(
            SynthSource::new(SynthConfig::small(), n)
                .with_read_latency(Duration::from_millis(latency_ms)),
        )
    }

    /// Wait (bounded) until `cond` holds.
    fn poll(mut cond: impl FnMut() -> bool) -> bool {
        for _ in 0..500 {
            if cond() {
                return true;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        false
    }

    #[test]
    fn demand_loads_count_misses() {
        let cache = StagingCache::new(source(4, 0), 4, 0);
        let a = cache.get(0).unwrap();
        let b = cache.get(0).unwrap();
        assert_eq!(a, b);
        let r = cache.report();
        assert_eq!((r.misses, r.hits), (1, 1));
        assert_eq!(r.prefetched, 0);
        cache.shutdown();
    }

    #[test]
    fn prefetched_chunks_hide_read_latency() {
        let cache = StagingCache::new(source(4, 10), 4, 4);
        cache.prefetch(&[0, 1]);
        assert!(poll(|| cache.report().prefetched == 2), "prefetcher never completed");
        assert!(cache.is_staged(0) && cache.is_staged(1));
        cache.get(0).unwrap();
        cache.get(1).unwrap();
        let r = cache.report();
        assert_eq!(r.hits, 2);
        assert_eq!(r.misses, 0);
        assert!(r.hidden > Duration::ZERO, "hidden latency not counted: {r:?}");
        // staged delta reports both chunks exactly once
        let (add, dropped, demoted) = cache.take_staged_delta();
        assert_eq!(add, vec![0, 1]);
        assert!(dropped.is_empty());
        assert!(demoted.is_empty());
        assert!(cache.take_staged_delta().0.is_empty());
        cache.shutdown();
    }

    #[test]
    fn prefetch_accepts_batches_larger_than_depth() {
        // a window's worth of assignment chunks must all prefetch even
        // when it exceeds the depth knob (depth gates the thread + hint
        // budget, not the queue)
        let cache = StagingCache::new(source(8, 1), 8, 2);
        cache.prefetch(&[0, 1, 2, 3, 4, 5]);
        assert!(poll(|| cache.report().prefetched == 6), "queue was truncated");
        cache.shutdown();
    }

    #[test]
    fn capacity_bound_evicts_and_reports() {
        let cache = StagingCache::new(source(8, 0), 2, 0);
        for c in 0..4u64 {
            cache.get(c).unwrap();
        }
        let r = cache.report();
        assert_eq!(r.evictions, 2);
        let (add, dropped, demoted) = cache.take_staged_delta();
        assert_eq!(add.len(), 4);
        assert_eq!(dropped.len(), 2);
        assert!(demoted.is_empty(), "no spill tier, nothing demotes");
        // evicted chunks are no longer staged; a re-get is a miss
        assert!(!cache.is_staged(dropped[0]));
        cache.get(dropped[0]).unwrap();
        assert_eq!(cache.report().misses, 5);
        cache.shutdown();
    }

    #[test]
    fn byte_budget_evicts_by_payload_size() {
        // each synthetic chunk has a fixed payload; budget for ~2 of them
        let src = source(8, 0);
        let one = payload_bytes(&src.load(0).unwrap());
        assert!(one > 0);
        let cache = StagingCache::new(src, crate::config::CacheCap::Bytes(2 * one), 0);
        cache.get(0).unwrap();
        cache.get(1).unwrap();
        assert_eq!(cache.report().evictions, 0, "two chunks fit the budget");
        cache.get(2).unwrap(); // third overflows -> oldest claimed evicts
        let r = cache.report();
        assert_eq!(r.evictions, 1, "{r:?}");
        assert!(!cache.is_staged(0));
        assert!(cache.is_staged(1) && cache.is_staged(2));
        // a budget smaller than one chunk still holds exactly one
        let src = source(4, 0);
        let tiny = StagingCache::new(src, crate::config::CacheCap::Bytes(1), 0);
        tiny.get(0).unwrap();
        assert!(tiny.is_staged(0), "a single over-budget chunk must stay");
        tiny.get(1).unwrap();
        assert!(tiny.is_staged(1) && !tiny.is_staged(0));
        tiny.shutdown();
        cache.shutdown();
    }

    #[test]
    fn out_of_range_chunk_errors() {
        let cache = StagingCache::new(source(2, 0), 2, 0);
        assert!(cache.get(9).is_err());
        // the failed load must not leave a stuck Loading slot
        assert!(cache.get(9).is_err());
        cache.shutdown();
    }

    fn spill_dir(name: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir()
            .join(format!("htap-cache-spill-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn eviction_demotes_and_miss_promotes_from_spill() {
        // the acceptance path: cap 1 forces demotion; the re-get is a
        // memory miss served from local disk, not the source tier
        let dir = spill_dir("promote");
        let spill = SpillTier::create(&dir, 8).unwrap();
        let cache = StagingCache::new_tiered(source(4, 0), 1, 0, Some(spill));
        cache.get(0).unwrap();
        cache.get(1).unwrap(); // evicts 0 -> demoted to disk
        assert!(!cache.is_staged(0));
        assert!(cache.is_spilled(0), "eviction must demote, not drop");
        let (_, dropped, demoted) = cache.take_staged_delta();
        assert!(dropped.is_empty(), "demoted chunks stay catalogued");
        assert_eq!(demoted, vec![0]);
        // miss on 0 -> promoted from disk (spill hit, no source read)
        let v = cache.get(0).unwrap();
        assert_eq!(v.len(), 1);
        let r = cache.report();
        assert_eq!(r.spill_evicted, 2, "1 evicted again when 0 promoted back: {r:?}");
        assert_eq!(r.spill_hits, 1, "{r:?}");
        assert_eq!(r.promoted, 1, "{r:?}");
        assert_eq!(r.evictions, 0, "nothing fully dropped: {r:?}");
        // the promotion re-announces chunk 0 at the memory tier
        let (add, dropped, demoted) = cache.take_staged_delta();
        assert!(add.contains(&0));
        assert!(dropped.is_empty());
        assert_eq!(demoted, vec![1]);
        cache.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn spill_payloads_survive_the_round_trip_bitwise() {
        let dir = spill_dir("bits");
        let spill = SpillTier::create(&dir, 8).unwrap();
        let src = source(3, 0);
        let want = src.load(2).unwrap();
        let cache = StagingCache::new_tiered(src, 1, 0, Some(spill));
        cache.get(2).unwrap();
        cache.get(0).unwrap(); // demote 2
        assert!(cache.is_spilled(2));
        let got = cache.get(2).unwrap(); // promote
        assert_eq!(*got, want, "spill round-trip must be bit-identical");
        cache.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn spill_cap_overflow_finally_drops_and_reports() {
        let dir = spill_dir("overflow");
        let spill = SpillTier::create(&dir, 1).unwrap();
        let cache = StagingCache::new_tiered(source(8, 0), 1, 0, Some(spill));
        cache.get(0).unwrap();
        cache.get(1).unwrap(); // 0 demotes
        cache.get(2).unwrap(); // 1 demotes, disk cap drops 0 for good
        let r = cache.report();
        assert_eq!(r.spill_evicted, 2, "{r:?}");
        assert_eq!(r.evictions, 1, "chunk 0 must fall off the disk tier: {r:?}");
        let (_, dropped, demoted) = cache.take_staged_delta();
        assert_eq!(dropped, vec![0]);
        assert_eq!(demoted, vec![0, 1]);
        // a re-get of the fully dropped chunk goes back to the source
        cache.get(0).unwrap();
        let r = cache.report();
        assert_eq!(r.spill_hits, 0, "{r:?}");
        assert_eq!(r.misses, 4, "{r:?}");
        cache.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn prefetcher_promotes_from_spill_and_counts_spill_hit_on_claim() {
        let dir = spill_dir("prefetch");
        let spill = SpillTier::create(&dir, 8).unwrap();
        let cache = StagingCache::new_tiered(source(4, 0), 1, 2, Some(spill));
        cache.get(0).unwrap();
        cache.get(1).unwrap(); // demote 0
        assert!(cache.is_spilled(0));
        cache.prefetch(&[0]);
        assert!(poll(|| cache.report().promoted == 1), "prefetcher never promoted");
        // the consumer's fetch is then served by the disk tier
        cache.get(0).unwrap();
        let r = cache.report();
        assert_eq!(r.spill_hits, 1, "{r:?}");
        cache.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn warm_restart_readvertises_recovered_spill_chunks() {
        let dir = spill_dir("warm");
        // first incarnation demotes chunks 0 and 1 to disk, then "crashes"
        {
            let spill = SpillTier::create(&dir, 8).unwrap();
            let cache = StagingCache::new_tiered(source(4, 0), 1, 0, Some(spill));
            cache.get(0).unwrap();
            cache.get(1).unwrap(); // demotes 0
            cache.get(2).unwrap(); // demotes 1
            assert!(cache.is_spilled(0) && cache.is_spilled(1));
            cache.shutdown();
        }
        // warm restart: the recovered chunks ride the FIRST staged delta
        // as demoted (disk-tier holders), before any get()
        let spill = SpillTier::recover(&dir, 8).unwrap();
        let cache = StagingCache::new_tiered(source(4, 0), 1, 0, Some(spill));
        let (add, dropped, demoted) = cache.take_staged_delta();
        assert!(add.is_empty() && dropped.is_empty());
        assert_eq!(demoted, vec![0, 1], "recovered chunks re-advertise at disk tier");
        // and a consumer fetch is served from local disk, not the source
        cache.get(0).unwrap();
        let r = cache.report();
        assert_eq!(r.spill_hits, 1, "{r:?}");
        assert_eq!(r.promoted, 1, "{r:?}");
        cache.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn tenant_quota_evicts_only_the_over_quota_tenants_chunks() {
        let src = source(8, 0);
        let one = payload_bytes(&src.load(0).unwrap());
        let cache = StagingCache::new(src, 16, 0);
        cache.set_tenant_quota(Some(crate::config::CacheCap::Bytes(2 * one)));
        cache.get_for("alice", 0).unwrap();
        cache.get_for("bob", 1).unwrap();
        cache.get_for("bob", 2).unwrap();
        // bob is at quota; his next chunk pushes out *his* oldest only
        cache.get_for("bob", 3).unwrap();
        assert!(cache.is_staged(0), "alice's chunk must survive bob's flood");
        assert!(!cache.is_staged(1), "bob's oldest chunk is the quota victim");
        assert!(cache.is_staged(2) && cache.is_staged(3));
        assert_eq!(cache.tenant_bytes("alice"), one);
        assert_eq!(cache.tenant_bytes("bob"), 2 * one);
        cache.shutdown();
    }

    #[test]
    fn shared_chunks_retag_to_the_last_toucher() {
        let src = source(4, 0);
        let one = payload_bytes(&src.load(0).unwrap());
        let cache = StagingCache::new(src, 8, 0);
        cache.get_for("alice", 0).unwrap();
        assert_eq!(cache.tenant_bytes("alice"), one);
        // jobs share chunk ids in service mode: the bytes bill whoever
        // touched the chunk last, never both tenants at once
        cache.get_for("bob", 0).unwrap();
        assert_eq!(cache.tenant_bytes("alice"), 0);
        assert_eq!(cache.tenant_bytes("bob"), one);
        // the single-job path (empty tenant) leaves attribution alone
        cache.get_for("", 1).unwrap();
        assert_eq!(cache.tenant_bytes(""), 0);
        cache.shutdown();
    }

    #[test]
    fn demote_all_moves_the_working_set_to_the_spill_tier() {
        let dir = spill_dir("drain");
        let spill = SpillTier::create(&dir, 8).unwrap();
        let cache = StagingCache::new_tiered(source(4, 0), 8, 0, Some(spill));
        cache.get(0).unwrap();
        cache.get(1).unwrap();
        assert_eq!(cache.demote_all(), 2);
        assert!(!cache.is_staged(0) && !cache.is_staged(1));
        assert!(cache.is_spilled(0) && cache.is_spilled(1));
        let (_, dropped, demoted) = cache.take_staged_delta();
        assert!(dropped.is_empty(), "drain demotes, it does not drop");
        assert_eq!(demoted, vec![0, 1]);
        cache.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
        // without a spill tier the payloads drop and report evicted
        let cache = StagingCache::new(source(2, 0), 4, 0);
        cache.get(0).unwrap();
        assert_eq!(cache.demote_all(), 1);
        let (_, dropped, demoted) = cache.take_staged_delta();
        assert_eq!(dropped, vec![0]);
        assert!(demoted.is_empty());
        cache.shutdown();
    }

    #[test]
    fn obs_wiring_mirrors_counters_and_traces_events() {
        let registry = crate::obs::Registry::new();
        let tracer = Tracer::new(1);
        let cache =
            StagingCache::with_obs(source(8, 0), 2, 0, None, &registry, tracer.clone());
        cache.get(0).unwrap(); // miss
        cache.get(0).unwrap(); // hit
        cache.get(1).unwrap(); // miss
        cache.get(2).unwrap(); // miss, evicts 0
        let r = cache.report();
        let snap = registry.snapshot();
        // the registry sees exactly what the report sees
        assert_eq!(snap.counter("staging.hits"), r.hits);
        assert_eq!(snap.counter("staging.misses"), r.misses);
        assert_eq!(snap.counter("staging.evictions"), r.evictions);
        // and the trace stream carries one event per counted fetch
        let evs = tracer.drain();
        let count = |k: EventKind| evs.iter().filter(|e| e.kind == k).count() as u64;
        assert_eq!(count(EventKind::StagingHit), r.hits);
        assert_eq!(count(EventKind::StagingMiss), r.misses);
        assert_eq!(count(EventKind::StagingEvict), r.evictions);
        assert!(evs.iter().all(|e| e.worker == 1));
        cache.shutdown();
    }

    #[test]
    fn resync_readvertises_the_full_tiered_holding_set() {
        let dir = spill_dir("resync");
        let spill = SpillTier::create(&dir, 8).unwrap();
        let cache = StagingCache::new_tiered(source(4, 0), 2, 0, Some(spill));
        cache.get(0).unwrap();
        cache.get(1).unwrap();
        cache.get(2).unwrap(); // demotes 0 to disk
        // deltas already drained: the manager has been told everything
        let _ = cache.take_staged_delta();
        assert!(cache.take_staged_delta().0.is_empty());
        // a reconnect to a promoted standby must re-advertise it all
        cache.resync_staged();
        let (mut add, dropped, demoted) = cache.take_staged_delta();
        add.sort_unstable();
        assert_eq!(add, vec![1, 2], "memory tier re-advertises as staged");
        assert_eq!(demoted, vec![0], "disk tier re-advertises as demoted");
        assert!(dropped.is_empty());
        cache.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn replica_prefetch_counts_replicated() {
        let cache = StagingCache::new(source(4, 0), 4, 2);
        cache.prefetch_replicas(&[2, 3]);
        assert!(poll(|| cache.report().prefetched == 2), "replicas never staged");
        let r = cache.report();
        assert_eq!(r.replicated, 2, "{r:?}");
        // an already-staged chunk does not re-count
        cache.prefetch_replicas(&[2]);
        assert_eq!(cache.report().replicated, 2);
        cache.shutdown();
    }
}
