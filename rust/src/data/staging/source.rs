//! Chunk sources: where stage-input payloads come from.
//!
//! The Manager instantiates workflows over `0..n_chunks`; a
//! [`ChunkSource`] resolves one chunk id to its payload values.  Both the
//! Manager (legacy payload-shipping mode, via [`source_loader`]) and the
//! workers' [`super::StagingCache`] (staged mode) read through this trait,
//! so swapping the synthetic dataset for tiles on disk is one CLI flag.

use crate::coordinator::{ChunkId, ChunkLoader};
use crate::data::{SynthConfig, TileStore};
use crate::runtime::{HostTensor, Value};
use crate::{Error, Result};
use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

/// A dataset addressable by chunk id.
pub trait ChunkSource: Send + Sync {
    /// Number of chunks this source serves (ids `0..n_chunks`).
    fn n_chunks(&self) -> usize;

    /// Load one chunk's payload values (blocking; may include real or
    /// simulated shared-filesystem latency).
    fn load(&self, chunk: ChunkId) -> Result<Vec<Value>>;

    /// Human-readable description for logs.
    fn describe(&self) -> String;
}

/// Bridge a source into the Manager's [`ChunkLoader`] closure (legacy
/// payload-shipping mode and tests).
pub fn source_loader(src: Arc<dyn ChunkSource>) -> ChunkLoader {
    Arc::new(move |chunk| src.load(chunk))
}

/// Chaos wrapper over any [`ChunkSource`]: the `source-io` site fails a
/// read outright (the worker fails that assignment; the manager
/// re-issues it), the `source-slow` site stalls before delegating (a
/// congested shared filesystem).  Wrapping keeps every concrete source
/// fault-free — the injection surface lives in one place.
pub struct FaultySource {
    inner: Arc<dyn ChunkSource>,
    faults: crate::faults::Faults,
}

impl FaultySource {
    /// Wrap `inner`; with a disabled handle the wrapper is a pure
    /// pass-through (one relaxed load per read).
    pub fn wrap(inner: Arc<dyn ChunkSource>, faults: crate::faults::Faults) -> Arc<dyn ChunkSource> {
        Arc::new(FaultySource { inner, faults })
    }
}

impl ChunkSource for FaultySource {
    fn n_chunks(&self) -> usize {
        self.inner.n_chunks()
    }

    fn load(&self, chunk: ChunkId) -> Result<Vec<Value>> {
        use crate::faults::Site;
        if self.faults.inject(Site::SourceIo).is_some() {
            return Err(Error::Config(format!("injected: source read failed (chunk {chunk})")));
        }
        self.faults.maybe_stall(Site::SourceSlow);
        self.inner.load(chunk)
    }

    fn describe(&self) -> String {
        format!("faulty({})", self.inner.describe())
    }
}

/// Deterministic synthetic tiles (wraps [`TileStore`]): every process that
/// constructs a `SynthSource` with the same config serves bit-identical
/// chunks, which is what lets staged distributed runs skip shipping tile
/// payloads over the wire.
pub struct SynthSource {
    store: TileStore,
    read_latency: Duration,
}

impl SynthSource {
    pub fn new(cfg: SynthConfig, n_tiles: usize) -> Self {
        SynthSource { store: TileStore::new(cfg, n_tiles), read_latency: Duration::ZERO }
    }

    /// Add an artificial per-read latency (the Lustre stand-in whose cost
    /// the prefetcher is there to hide).
    pub fn with_read_latency(mut self, lat: Duration) -> Self {
        self.read_latency = lat;
        self
    }
}

impl ChunkSource for SynthSource {
    fn n_chunks(&self) -> usize {
        self.store.len()
    }

    fn load(&self, chunk: ChunkId) -> Result<Vec<Value>> {
        if chunk as usize >= self.store.len() {
            return Err(Error::Config(format!(
                "chunk {chunk} out of range (source has {})",
                self.store.len()
            )));
        }
        if !self.read_latency.is_zero() {
            std::thread::sleep(self.read_latency);
        }
        Ok(vec![Value::Tensor(self.store.tile(chunk).to_tensor())])
    }

    fn describe(&self) -> String {
        format!("synth({} tiles)", self.store.len())
    }
}

/// Magic + format version of the on-disk `.tile` container.
const TILE_MAGIC: &[u8; 4] = b"HTAP";
const TILE_VERSION: u32 = 1;

/// Append one tensor in the `.tile` body layout (rank + dims + raw f32
/// LE).  Shared between the single-tensor `.tile` container and the spill
/// tier's multi-value container ([`super::tiers`]).
pub(crate) fn encode_tensor(buf: &mut Vec<u8>, t: &HostTensor) {
    buf.reserve(4 + t.shape().len() * 8 + t.size_bytes());
    buf.extend_from_slice(&(t.shape().len() as u32).to_le_bytes());
    for &d in t.shape() {
        buf.extend_from_slice(&(d as u64).to_le_bytes());
    }
    // bulk copy straight out of the tensor's shared (Arc-backed) buffer
    crate::runtime::tensor::f32s_to_le(buf, t.data());
}

pub(crate) fn take_bytes<'a>(bytes: &'a [u8], pos: &mut usize, n: usize) -> Result<&'a [u8]> {
    let end = pos
        .checked_add(n)
        .filter(|&e| e <= bytes.len())
        .ok_or_else(|| Error::Config("truncated tensor data".into()))?;
    let s = &bytes[*pos..end];
    *pos = end;
    Ok(s)
}

/// Decode one tensor written by [`encode_tensor`], advancing `pos`.
/// Corrupt input (oversized rank/dims, truncation, element-count
/// overflow) is an `Err`, never a panic — the spill tier relies on that
/// to treat damaged files as cache misses.
pub(crate) fn decode_tensor(bytes: &[u8], pos: &mut usize) -> Result<HostTensor> {
    // lint: allow(panic) — take_bytes guarantees a 4-byte slice
    let rank = u32::from_le_bytes(take_bytes(bytes, pos, 4)?.try_into().unwrap()) as usize;
    if rank > 8 {
        return Err(Error::Config(format!("tensor rank {rank} too large")));
    }
    let mut dims = Vec::with_capacity(rank);
    for _ in 0..rank {
        // lint: allow(panic) — take_bytes guarantees an 8-byte slice
        dims.push(u64::from_le_bytes(take_bytes(bytes, pos, 8)?.try_into().unwrap()) as usize);
    }
    let n = dims
        .iter()
        .try_fold(1usize, |acc, &d| acc.checked_mul(d))
        .and_then(|n| n.checked_mul(4))
        .ok_or_else(|| Error::Config("tensor dims overflow".into()))?;
    let payload = take_bytes(bytes, pos, n)?;
    HostTensor::new(dims, crate::runtime::tensor::f32s_from_le(payload))
}

/// Tiles stored as `.tile` files in a directory (one file per chunk,
/// sorted by file name).  This is the shared-filesystem mode: point the
/// Manager and every worker at the same directory (`--chunk-source
/// dir:PATH`).  `htap export-tiles` writes a synthetic dataset in this
/// format.
pub struct DirSource {
    dir: PathBuf,
    files: Vec<PathBuf>,
    read_latency: Duration,
}

impl DirSource {
    /// Scan `dir` for `*.tile` files (name-sorted; index = chunk id).
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let mut files: Vec<PathBuf> = std::fs::read_dir(&dir)?
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| p.extension().map(|e| e == "tile").unwrap_or(false))
            .collect();
        files.sort();
        if files.is_empty() {
            return Err(Error::Config(format!("no .tile files under {}", dir.display())));
        }
        Ok(DirSource { dir, files, read_latency: Duration::ZERO })
    }

    /// Add an artificial per-read latency on top of the real file read.
    pub fn with_read_latency(mut self, lat: Duration) -> Self {
        self.read_latency = lat;
        self
    }

    /// Write one tensor as a `.tile` file.
    pub fn write_tile(path: impl AsRef<Path>, t: &HostTensor) -> Result<()> {
        let mut buf = Vec::with_capacity(16 + t.data().len() * 4);
        buf.extend_from_slice(TILE_MAGIC);
        buf.extend_from_slice(&TILE_VERSION.to_le_bytes());
        encode_tensor(&mut buf, t);
        let mut f = std::fs::File::create(path)?;
        f.write_all(&buf)?;
        Ok(())
    }

    /// Read one `.tile` file back into a tensor.
    pub fn read_tile(path: impl AsRef<Path>) -> Result<HostTensor> {
        let path = path.as_ref();
        let mut bytes = Vec::new();
        std::fs::File::open(path)?.read_to_end(&mut bytes)?;
        let fail = |m: &str| Error::Config(format!("{}: {m}", path.display()));
        if bytes.len() < 12 || &bytes[..4] != TILE_MAGIC {
            return Err(fail("not an htap .tile file"));
        }
        // lint: allow(panic) — length checked above, fixed 4-byte slice
        let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
        if version != TILE_VERSION {
            return Err(fail(&format!("tile format version {version}, expected {TILE_VERSION}")));
        }
        let mut pos = 8;
        let t = decode_tensor(&bytes, &mut pos).map_err(|e| fail(&e.to_string()))?;
        if pos != bytes.len() {
            return Err(fail("payload size mismatch"));
        }
        Ok(t)
    }

    /// Export every tile of a [`TileStore`] into `dir` (creating it) as
    /// `chunk_NNNNN.tile`; returns how many files were written.
    pub fn export_store(dir: impl AsRef<Path>, store: &TileStore) -> Result<usize> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)?;
        for chunk in 0..store.len() as u64 {
            let t = store.tile(chunk).to_tensor();
            Self::write_tile(dir.join(format!("chunk_{chunk:05}.tile")), &t)?;
        }
        Ok(store.len())
    }
}

impl ChunkSource for DirSource {
    fn n_chunks(&self) -> usize {
        self.files.len()
    }

    fn load(&self, chunk: ChunkId) -> Result<Vec<Value>> {
        let path = self.files.get(chunk as usize).ok_or_else(|| {
            Error::Config(format!("chunk {chunk} out of range (dir has {})", self.files.len()))
        })?;
        if !self.read_latency.is_zero() {
            std::thread::sleep(self.read_latency);
        }
        Ok(vec![Value::Tensor(Self::read_tile(path)?)])
    }

    fn describe(&self) -> String {
        format!("dir:{} ({} tiles)", self.dir.display(), self.files.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("htap-staging-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn synth_source_serves_deterministic_tiles() {
        let src = SynthSource::new(SynthConfig::small(), 3);
        assert_eq!(src.n_chunks(), 3);
        let a = src.load(1).unwrap();
        let b = src.load(1).unwrap();
        assert_eq!(a, b);
        assert!(src.load(3).is_err());
        assert!(src.describe().contains("synth"));
    }

    #[test]
    fn dir_source_round_trips_a_tile_store() {
        let dir = tmp_dir("roundtrip");
        let store = TileStore::new(SynthConfig::small(), 4);
        assert_eq!(DirSource::export_store(&dir, &store).unwrap(), 4);
        let src = DirSource::open(&dir).unwrap();
        assert_eq!(src.n_chunks(), 4);
        for chunk in 0..4u64 {
            let vals = src.load(chunk).unwrap();
            let got = vals[0].as_tensor().unwrap();
            assert_eq!(got, &store.tile(chunk).to_tensor(), "chunk {chunk}");
        }
        assert!(src.load(4).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_tile_files_rejected() {
        let dir = tmp_dir("corrupt");
        std::fs::write(dir.join("a.tile"), b"not a tile").unwrap();
        let src = DirSource::open(&dir).unwrap();
        assert!(src.load(0).is_err());
        // truncated payload
        let t = HostTensor::new(vec![2, 2], vec![1.0; 4]).unwrap();
        DirSource::write_tile(dir.join("b.tile"), &t).unwrap();
        let bytes = std::fs::read(dir.join("b.tile")).unwrap();
        std::fs::write(dir.join("b.tile"), &bytes[..bytes.len() - 4]).unwrap();
        let src = DirSource::open(&dir).unwrap();
        assert!(src.load(1).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn overflowing_dims_error_instead_of_panicking() {
        // a corrupt file whose dims multiply past usize::MAX must come
        // back as Err (the spill tier maps it to a cache miss), not panic
        let dir = tmp_dir("overflow");
        let mut buf = Vec::new();
        buf.extend_from_slice(TILE_MAGIC);
        buf.extend_from_slice(&TILE_VERSION.to_le_bytes());
        buf.extend_from_slice(&2u32.to_le_bytes()); // rank 2
        buf.extend_from_slice(&u64::MAX.to_le_bytes());
        buf.extend_from_slice(&2u64.to_le_bytes());
        std::fs::write(dir.join("a.tile"), &buf).unwrap();
        let src = DirSource::open(&dir).unwrap();
        assert!(src.load(0).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn empty_dir_rejected() {
        let dir = tmp_dir("empty");
        assert!(DirSource::open(&dir).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
