//! Data staging: chunk sources, the worker-side staging cache with
//! asynchronous prefetch, and the manager-side chunk catalog.
//!
//! The paper's cluster-level throughput rests on two optimisations beyond
//! scheduling (§III): *data locality conscious task assignment* and *data
//! prefetching and asynchronous data copy*.  This module is that layer,
//! lifted to the node level:
//!
//! * [`ChunkSource`] abstracts where chunk payloads come from —
//!   [`SynthSource`] (deterministic synthetic tiles, the shared-dataset
//!   stand-in) or [`DirSource`] (`.tile` files on a shared directory, the
//!   Lustre stand-in).  In staged runs the Manager stops shipping tile
//!   payloads over the wire entirely: workers read chunks from their own
//!   source and the `Assign` message carries only upstream values.
//! * [`StagingCache`] is each worker's bounded in-memory chunk cache.  Its
//!   background prefetcher pulls the chunks of queued assignments (and the
//!   Manager's prefetch hints) while the current pipeline instances
//!   execute, so shared-filesystem read latency overlaps with compute —
//!   the hit/miss/hidden-latency counters surface through
//!   [`crate::metrics::StagingReport`].
//! * [`ChunkCatalog`] is the Manager's map of which worker has which
//!   chunks staged (and at which tier), fed by the staged/evicted/demoted
//!   deltas piggybacked on every work request and consumed by the
//!   locality-aware assignment policy in
//!   [`crate::coordinator::Manager::request_work`].
//! * [`SpillTier`] ([`tiers`]) is the optional local-disk rung between the
//!   memory cache and the source: evictions demote instead of dropping,
//!   misses promote from disk before re-reading the shared FS.  Spill
//!   files are crash-consistent (temp-then-rename, per chunk), so a
//!   worker restarted with `--warm-restart` rebuilds the tier's index
//!   from the surviving files ([`SpillTier::recover`]) and re-advertises
//!   those chunks to the Manager as disk-tier holders.

pub mod cache;
pub mod catalog;
pub mod source;
pub mod tiers;

pub use cache::StagingCache;
pub use catalog::{ChunkCatalog, Tier, WorkerId, ANON_WORKER};
pub use source::{source_loader, ChunkSource, DirSource, FaultySource, SynthSource};
pub use tiers::SpillTier;

use crate::data::SynthConfig;
use crate::Result;
use std::sync::Arc;
use std::time::Duration;

/// Build a chunk source from a CLI spec: `"synth"` for deterministic
/// synthetic tiles, or `"dir:PATH"` (or a bare path to an existing
/// directory) for `.tile` files under `PATH`.
pub fn source_from_spec(
    spec: &str,
    tile_size: usize,
    seed: u64,
    n_tiles: usize,
    read_latency: Duration,
) -> Result<Arc<dyn ChunkSource>> {
    if spec == "synth" {
        let src = SynthSource::new(SynthConfig::for_tile_size(tile_size, seed), n_tiles)
            .with_read_latency(read_latency);
        return Ok(Arc::new(src));
    }
    let path = spec.strip_prefix("dir:").unwrap_or(spec);
    if !std::path::Path::new(path).is_dir() {
        return Err(crate::Error::Config(format!(
            "--chunk-source '{spec}' is neither 'synth', 'dir:PATH', nor an existing directory"
        )));
    }
    Ok(Arc::new(DirSource::open(path)?.with_read_latency(read_latency)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synth_spec_parses() {
        let src = source_from_spec("synth", 32, 7, 5, Duration::ZERO).unwrap();
        assert_eq!(src.n_chunks(), 5);
        let vals = src.load(0).unwrap();
        assert_eq!(vals.len(), 1);
    }

    #[test]
    fn bad_spec_rejected() {
        assert!(source_from_spec("/definitely/not/a/dir", 32, 7, 5, Duration::ZERO).is_err());
        assert!(source_from_spec("dir:/nope", 32, 7, 5, Duration::ZERO).is_err());
    }
}
