//! The Manager-side chunk catalog: which worker has which chunks staged,
//! and at which storage tier.
//!
//! Fed by the staged/evicted/demoted deltas piggybacked on every work
//! request (plus an optimistic insert when a chunk-bearing assignment is
//! handed out — the worker must stage the chunk to execute it), and
//! consumed by the locality-aware assignment policy: prefer handing a
//! worker the instances whose chunk it already holds, fall back to cold or
//! stolen chunks so the bag of tasks never stalls.  Tier tracking makes
//! the catalog replication-aware: a chunk held only in workers' spill
//! tiers ([`Tier::Disk`]) is a cheaper steal than a memory-resident one,
//! and a steal leaves the chunk multi-homed unless replication is off.

use crate::coordinator::ChunkId;
use std::collections::{HashMap, HashSet};

/// Stable worker identity carried in work requests.
pub type WorkerId = u64;

/// The anonymous worker id: no staging, no catalog tracking (legacy
/// `request(capacity)` path and non-staged runs).
pub const ANON_WORKER: WorkerId = 0;

/// Storage tier a worker holds a chunk at.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tier {
    /// In the worker's staging cache (memory).
    Mem,
    /// Demoted to the worker's local-disk spill tier.
    Disk,
}

/// Bidirectional worker <-> staged-chunk map with per-entry tiers.
#[derive(Debug, Default)]
pub struct ChunkCatalog {
    by_worker: HashMap<WorkerId, HashMap<ChunkId, Tier>>,
    holders: HashMap<ChunkId, HashSet<WorkerId>>,
}

impl ChunkCatalog {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record that `worker` has `chunk` staged in memory.
    pub fn insert(&mut self, worker: WorkerId, chunk: ChunkId) {
        if worker == ANON_WORKER {
            return;
        }
        self.by_worker.entry(worker).or_default().insert(chunk, Tier::Mem);
        self.holders.entry(chunk).or_default().insert(worker);
    }

    /// Record that `worker` demoted `chunk` to its local-disk tier (still
    /// staged — just a tier down).
    pub fn demote(&mut self, worker: WorkerId, chunk: ChunkId) {
        if worker == ANON_WORKER {
            return;
        }
        self.by_worker.entry(worker).or_default().insert(chunk, Tier::Disk);
        self.holders.entry(chunk).or_default().insert(worker);
    }

    /// Record that `worker` evicted `chunk` entirely.
    pub fn remove(&mut self, worker: WorkerId, chunk: ChunkId) {
        if let Some(map) = self.by_worker.get_mut(&worker) {
            map.remove(&chunk);
            if map.is_empty() {
                self.by_worker.remove(&worker);
            }
        }
        if let Some(set) = self.holders.get_mut(&chunk) {
            set.remove(&worker);
            if set.is_empty() {
                self.holders.remove(&chunk);
            }
        }
    }

    /// Apply one request's staged/evicted/demoted delta.  Demotes apply
    /// before adds: a chunk that was demoted *and* (re-)staged within one
    /// delta window ends at [`Tier::Mem`] — the promote re-announces it in
    /// `staged_add`, and misclassifying a memory-resident chunk as
    /// disk-only would make tier-3 preferentially rob the one worker that
    /// actually has it hot.  Drops apply last (an evict always ends the
    /// window's story for that chunk).
    pub fn update(
        &mut self,
        worker: WorkerId,
        staged_add: &[ChunkId],
        staged_drop: &[ChunkId],
        demoted: &[ChunkId],
    ) {
        for &c in demoted {
            self.demote(worker, c);
        }
        for &c in staged_add {
            self.insert(worker, c);
        }
        for &c in staged_drop {
            self.remove(worker, c);
        }
    }

    /// Forget everything a worker held (it died or disconnected); its
    /// chunks go back to cold so survivors take them in tier 2, not as
    /// steals.  Returns how many chunk entries were dropped.
    pub fn purge_worker(&mut self, worker: WorkerId) -> usize {
        let Some(chunks) = self.by_worker.remove(&worker) else {
            return 0;
        };
        for c in chunks.keys() {
            if let Some(set) = self.holders.get_mut(c) {
                set.remove(&worker);
                if set.is_empty() {
                    self.holders.remove(c);
                }
            }
        }
        chunks.len()
    }

    /// Drop every holder of `chunk` except `keep` (single-owner transfer —
    /// the no-replication policy on a steal).  Returns how many holders
    /// were dropped.
    pub fn remove_other_holders(&mut self, chunk: ChunkId, keep: WorkerId) -> usize {
        let Some(set) = self.holders.get(&chunk) else {
            return 0;
        };
        let others: Vec<WorkerId> = set.iter().copied().filter(|&w| w != keep).collect();
        for w in &others {
            self.remove(*w, chunk);
        }
        others.len()
    }

    /// Whether `worker` currently holds `chunk` (either tier).
    pub fn is_staged(&self, worker: WorkerId, chunk: ChunkId) -> bool {
        self.by_worker.get(&worker).map(|m| m.contains_key(&chunk)).unwrap_or(false)
    }

    /// The tier `worker` holds `chunk` at, if any.
    pub fn tier(&self, worker: WorkerId, chunk: ChunkId) -> Option<Tier> {
        self.by_worker.get(&worker).and_then(|m| m.get(&chunk)).copied()
    }

    /// How many workers hold `chunk` at any tier (0 = cold chunk).
    pub fn holder_count(&self, chunk: ChunkId) -> usize {
        self.holders.get(&chunk).map(|s| s.len()).unwrap_or(0)
    }

    /// How many workers hold `chunk` in memory.  Stealing a chunk that is
    /// memory-resident nowhere forfeits no locality the holders still have.
    pub fn mem_holder_count(&self, chunk: ChunkId) -> usize {
        self.holders
            .get(&chunk)
            .map(|s| {
                s.iter()
                    .filter(|w| {
                        matches!(
                            self.by_worker.get(w).and_then(|m| m.get(&chunk)),
                            Some(Tier::Mem)
                        )
                    })
                    .count()
            })
            .unwrap_or(0)
    }

    /// How many chunks `worker` holds.
    pub fn staged_count(&self, worker: WorkerId) -> usize {
        self.by_worker.get(&worker).map(|m| m.len()).unwrap_or(0)
    }

    /// Number of workers with at least one staged chunk.
    pub fn workers(&self) -> usize {
        self.by_worker.len()
    }

    /// Flat snapshot of every `(worker, chunk, tier)` entry, sorted for
    /// determinism — the manager-checkpoint serializer consumes this.
    pub fn entries(&self) -> Vec<(WorkerId, ChunkId, Tier)> {
        let mut out: Vec<(WorkerId, ChunkId, Tier)> = self
            .by_worker
            .iter()
            .flat_map(|(&w, m)| m.iter().map(move |(&c, &t)| (w, c, t)))
            .collect();
        out.sort_unstable_by_key(|&(w, c, _)| (w, c));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracks_both_directions() {
        let mut cat = ChunkCatalog::new();
        cat.insert(1, 10);
        cat.insert(1, 11);
        cat.insert(2, 10);
        assert!(cat.is_staged(1, 10));
        assert!(cat.is_staged(2, 10));
        assert!(!cat.is_staged(2, 11));
        assert_eq!(cat.holder_count(10), 2);
        assert_eq!(cat.staged_count(1), 2);
        assert_eq!(cat.workers(), 2);
    }

    #[test]
    fn eviction_updates_both_maps() {
        let mut cat = ChunkCatalog::new();
        cat.update(1, &[5, 6], &[], &[]);
        cat.update(1, &[7], &[5], &[]);
        assert!(!cat.is_staged(1, 5));
        assert_eq!(cat.holder_count(5), 0);
        assert_eq!(cat.staged_count(1), 2);
        // removing the last chunk drops the worker entry
        cat.update(1, &[], &[6, 7], &[]);
        assert_eq!(cat.workers(), 0);
    }

    #[test]
    fn demotion_keeps_the_chunk_staged_at_disk_tier() {
        let mut cat = ChunkCatalog::new();
        cat.update(1, &[5], &[], &[]);
        assert_eq!(cat.tier(1, 5), Some(Tier::Mem));
        assert_eq!(cat.mem_holder_count(5), 1);
        cat.update(1, &[], &[], &[5]);
        assert!(cat.is_staged(1, 5), "demoted chunks are still staged");
        assert_eq!(cat.tier(1, 5), Some(Tier::Disk));
        assert_eq!(cat.holder_count(5), 1);
        assert_eq!(cat.mem_holder_count(5), 0);
        // promotion re-announces at memory tier
        cat.update(1, &[5], &[], &[]);
        assert_eq!(cat.tier(1, 5), Some(Tier::Mem));
        // demote-then-promote within ONE delta window ends at Mem: the
        // demote must not shadow the later re-stage
        cat.update(1, &[5], &[], &[5]);
        assert_eq!(cat.tier(1, 5), Some(Tier::Mem));
        assert_eq!(cat.mem_holder_count(5), 1);
    }

    #[test]
    fn purge_clears_a_dead_workers_entries() {
        let mut cat = ChunkCatalog::new();
        cat.update(1, &[5, 6], &[], &[]);
        cat.update(2, &[6], &[], &[]);
        assert_eq!(cat.purge_worker(1), 2);
        assert_eq!(cat.staged_count(1), 0);
        assert_eq!(cat.holder_count(5), 0);
        assert_eq!(cat.holder_count(6), 1, "worker 2 still holds 6");
        assert_eq!(cat.purge_worker(1), 0, "second purge is a no-op");
    }

    #[test]
    fn single_owner_transfer_drops_other_holders() {
        let mut cat = ChunkCatalog::new();
        cat.insert(1, 9);
        cat.insert(2, 9);
        cat.insert(3, 9);
        assert_eq!(cat.remove_other_holders(9, 2), 2);
        assert_eq!(cat.holder_count(9), 1);
        assert!(cat.is_staged(2, 9));
        assert!(!cat.is_staged(1, 9) && !cat.is_staged(3, 9));
        assert_eq!(cat.remove_other_holders(42, 1), 0, "cold chunk: nothing to drop");
    }

    #[test]
    fn entries_snapshot_is_sorted_and_tiered() {
        let mut cat = ChunkCatalog::new();
        cat.insert(2, 7);
        cat.insert(1, 9);
        cat.insert(1, 3);
        cat.demote(1, 9);
        assert_eq!(
            cat.entries(),
            vec![(1, 3, Tier::Mem), (1, 9, Tier::Disk), (2, 7, Tier::Mem)]
        );
    }

    #[test]
    fn anonymous_worker_is_never_tracked() {
        let mut cat = ChunkCatalog::new();
        cat.insert(ANON_WORKER, 3);
        cat.demote(ANON_WORKER, 3);
        assert_eq!(cat.holder_count(3), 0);
        assert_eq!(cat.workers(), 0);
    }
}
