//! The Manager-side chunk catalog: which worker has which chunks staged.
//!
//! Fed by the staged/evicted deltas piggybacked on every work request
//! (plus an optimistic insert when a chunk-bearing assignment is handed
//! out — the worker must stage the chunk to execute it), and consumed by
//! the locality-aware assignment policy: prefer handing a worker the
//! instances whose chunk it already holds, fall back to cold or stolen
//! chunks so the bag of tasks never stalls.

use crate::coordinator::ChunkId;
use std::collections::{HashMap, HashSet};

/// Stable worker identity carried in work requests.
pub type WorkerId = u64;

/// The anonymous worker id: no staging, no catalog tracking (legacy
/// `request(capacity)` path and non-staged runs).
pub const ANON_WORKER: WorkerId = 0;

/// Bidirectional worker <-> staged-chunk map.
#[derive(Debug, Default)]
pub struct ChunkCatalog {
    by_worker: HashMap<WorkerId, HashSet<ChunkId>>,
    holders: HashMap<ChunkId, HashSet<WorkerId>>,
}

impl ChunkCatalog {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record that `worker` has `chunk` staged.
    pub fn insert(&mut self, worker: WorkerId, chunk: ChunkId) {
        if worker == ANON_WORKER {
            return;
        }
        self.by_worker.entry(worker).or_default().insert(chunk);
        self.holders.entry(chunk).or_default().insert(worker);
    }

    /// Record that `worker` evicted `chunk`.
    pub fn remove(&mut self, worker: WorkerId, chunk: ChunkId) {
        if let Some(set) = self.by_worker.get_mut(&worker) {
            set.remove(&chunk);
            if set.is_empty() {
                self.by_worker.remove(&worker);
            }
        }
        if let Some(set) = self.holders.get_mut(&chunk) {
            set.remove(&worker);
            if set.is_empty() {
                self.holders.remove(&chunk);
            }
        }
    }

    /// Apply one request's staged/evicted delta.
    pub fn update(&mut self, worker: WorkerId, staged_add: &[ChunkId], staged_drop: &[ChunkId]) {
        for &c in staged_add {
            self.insert(worker, c);
        }
        for &c in staged_drop {
            self.remove(worker, c);
        }
    }

    /// Forget everything a worker held (it died or disconnected); its
    /// chunks go back to cold so survivors take them in tier 2, not as
    /// steals.  Returns how many chunk entries were dropped.
    pub fn purge_worker(&mut self, worker: WorkerId) -> usize {
        let Some(chunks) = self.by_worker.remove(&worker) else {
            return 0;
        };
        for c in &chunks {
            if let Some(set) = self.holders.get_mut(c) {
                set.remove(&worker);
                if set.is_empty() {
                    self.holders.remove(c);
                }
            }
        }
        chunks.len()
    }

    /// Whether `worker` currently holds `chunk`.
    pub fn is_staged(&self, worker: WorkerId, chunk: ChunkId) -> bool {
        self.by_worker.get(&worker).map(|s| s.contains(&chunk)).unwrap_or(false)
    }

    /// How many workers hold `chunk` (0 = cold chunk).
    pub fn holder_count(&self, chunk: ChunkId) -> usize {
        self.holders.get(&chunk).map(|s| s.len()).unwrap_or(0)
    }

    /// How many chunks `worker` holds.
    pub fn staged_count(&self, worker: WorkerId) -> usize {
        self.by_worker.get(&worker).map(|s| s.len()).unwrap_or(0)
    }

    /// Number of workers with at least one staged chunk.
    pub fn workers(&self) -> usize {
        self.by_worker.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracks_both_directions() {
        let mut cat = ChunkCatalog::new();
        cat.insert(1, 10);
        cat.insert(1, 11);
        cat.insert(2, 10);
        assert!(cat.is_staged(1, 10));
        assert!(cat.is_staged(2, 10));
        assert!(!cat.is_staged(2, 11));
        assert_eq!(cat.holder_count(10), 2);
        assert_eq!(cat.staged_count(1), 2);
        assert_eq!(cat.workers(), 2);
    }

    #[test]
    fn eviction_updates_both_maps() {
        let mut cat = ChunkCatalog::new();
        cat.update(1, &[5, 6], &[]);
        cat.update(1, &[7], &[5]);
        assert!(!cat.is_staged(1, 5));
        assert_eq!(cat.holder_count(5), 0);
        assert_eq!(cat.staged_count(1), 2);
        // removing the last chunk drops the worker entry
        cat.update(1, &[], &[6, 7]);
        assert_eq!(cat.workers(), 0);
    }

    #[test]
    fn purge_clears_a_dead_workers_entries() {
        let mut cat = ChunkCatalog::new();
        cat.update(1, &[5, 6], &[]);
        cat.update(2, &[6], &[]);
        assert_eq!(cat.purge_worker(1), 2);
        assert_eq!(cat.staged_count(1), 0);
        assert_eq!(cat.holder_count(5), 0);
        assert_eq!(cat.holder_count(6), 1, "worker 2 still holds 6");
        assert_eq!(cat.purge_worker(1), 0, "second purge is a no-op");
    }

    #[test]
    fn anonymous_worker_is_never_tracked() {
        let mut cat = ChunkCatalog::new();
        cat.insert(ANON_WORKER, 3);
        assert_eq!(cat.holder_count(3), 0);
        assert_eq!(cat.workers(), 0);
    }
}
