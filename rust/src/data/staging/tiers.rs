//! The local-disk **spill tier** of the worker's tiered chunk store.
//!
//! *Region Templates* (Teodoro et al., arXiv:1405.7958) generalises the
//! paper's staging optimisations into an explicit storage hierarchy
//! spanning memory, local disk, and the shared filesystem.  This module is
//! the middle rung: when the in-memory [`super::StagingCache`] evicts a
//! chunk under capacity pressure it **demotes** the payload here instead
//! of dropping it, and a later miss **promotes** it back from local disk
//! (cheap) before falling back to the shared-FS/source tier (expensive).
//!
//! On-disk format: one `chunk_NNNNNNNN.spill` file per chunk — magic +
//! version + value count, then each [`Value`] as a tag byte followed by a
//! scalar f32 or a `.tile`-style tensor body (the same rank + dims + raw
//! f32 LE layout `DirSource` uses, via the shared codec in
//! [`super::source`]).  The tier is bounded (`--spill-cap` chunks): when
//! full, the least-recently-touched spilled chunk is dropped for good and
//! reported back to the Manager's catalog as evicted.
//!
//! `SpillTier` is not internally synchronised: the owning cache mutates it
//! under its own lock (spill reads/writes are local-disk cheap, unlike the
//! source reads the cache deliberately performs unlocked).

use super::source::{decode_tensor, encode_tensor, take_bytes};
use crate::config::CacheCap;
use crate::coordinator::ChunkId;
use crate::faults::{Faults, Site};
use crate::runtime::Value;
use crate::{Error, Result};
use std::collections::{HashMap, VecDeque};
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

/// Magic + format version of the on-disk `.spill` container.
const SPILL_MAGIC: &[u8; 4] = b"HTSP";
const SPILL_VERSION: u32 = 1;

const TAG_SCALAR: u8 = 0;
const TAG_TENSOR: u8 = 1;

/// Bounded local-disk chunk store; one per worker process.
#[derive(Debug)]
pub struct SpillTier {
    dir: PathBuf,
    /// disk budget: max spilled chunks, or max on-disk bytes
    /// (`--spill-cap N|NMB`)
    cap: CacheCap,
    /// spilled chunk -> its `.spill` file size in bytes
    resident: HashMap<ChunkId, u64>,
    /// total on-disk bytes of resident spill files
    disk_bytes: u64,
    /// spilled chunk ids, least-recently-touched first (eviction order)
    order: VecDeque<ChunkId>,
    /// chaos handle: `spill-io` / `spill-slow` sites (disabled by default)
    faults: Faults,
}

impl SpillTier {
    /// Open (creating) `dir` as a spill directory holding at most `cap`
    /// (chunks or bytes).  Stale `.spill` files from a previous run are
    /// removed — the tier is a cache of the source, never a source of
    /// truth.
    pub fn create(dir: impl AsRef<Path>, cap: impl Into<CacheCap>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)?;
        for entry in std::fs::read_dir(&dir)?.filter_map(|e| e.ok()) {
            let p = entry.path();
            if p.extension().map(|e| e == "spill").unwrap_or(false) {
                let _ = std::fs::remove_file(p);
            }
        }
        let cap = match cap.into() {
            CacheCap::Chunks(n) => CacheCap::Chunks(n.max(1)),
            b => b,
        };
        Ok(SpillTier {
            dir,
            cap,
            resident: HashMap::new(),
            disk_bytes: 0,
            order: VecDeque::new(),
            faults: Faults::disabled(),
        })
    }

    /// Warm restart: reopen `dir` and **keep** the `.spill` files a
    /// previous incarnation of this worker left behind, rebuilding the
    /// resident index from them.  Each surviving file's header is
    /// validated; damaged or foreign files are deleted, not trusted.
    /// Recovered chunks enter the LRU order by ascending chunk id and the
    /// caller (the staging cache) re-advertises them to the Manager as
    /// disk-tier holders, so a restarted worker serves its old working
    /// set from local disk instead of cold shared-FS re-reads.
    pub fn recover(dir: impl AsRef<Path>, cap: impl Into<CacheCap>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)?;
        let cap = match cap.into() {
            CacheCap::Chunks(n) => CacheCap::Chunks(n.max(1)),
            b => b,
        };
        let mut tier = SpillTier {
            dir,
            cap,
            resident: HashMap::new(),
            disk_bytes: 0,
            order: VecDeque::new(),
            faults: Faults::disabled(),
        };
        let mut found: Vec<(ChunkId, u64)> = Vec::new();
        for entry in std::fs::read_dir(&tier.dir)?.filter_map(|e| e.ok()) {
            let p = entry.path();
            if !p.extension().map(|e| e == "spill").unwrap_or(false) {
                continue;
            }
            // chunk id from `chunk_NNNNNNNN.spill`; anything else is stale
            let chunk = p
                .file_stem()
                .and_then(|s| s.to_str())
                .and_then(|s| s.strip_prefix("chunk_"))
                .and_then(|s| s.parse::<ChunkId>().ok());
            let size = entry.metadata().ok().map(|m| m.len());
            match (chunk, size) {
                (Some(c), Some(sz)) if tier.read(c).is_ok() => found.push((c, sz)),
                _ => {
                    let _ = std::fs::remove_file(p);
                }
            }
        }
        found.sort_unstable_by_key(|&(c, _)| c);
        for (c, sz) in found {
            tier.resident.insert(c, sz);
            tier.disk_bytes += sz;
            tier.order.push_back(c);
        }
        // the previous incarnation may have run with a larger budget
        while tier.over_budget() {
            let Some(old) = tier.order.pop_front() else { break };
            if let Some(sz) = tier.resident.remove(&old) {
                tier.disk_bytes = tier.disk_bytes.saturating_sub(sz);
            }
            let _ = std::fs::remove_file(tier.path(old));
        }
        Ok(tier)
    }

    /// Arm the `spill-io` / `spill-slow` chaos sites on this tier.  Call
    /// before handing the tier to the staging cache (which owns it under
    /// its lock afterwards).
    pub fn set_faults(&mut self, faults: Faults) {
        self.faults = faults;
    }

    /// The chunks currently resident on disk, ascending — the warm-restart
    /// hook the staging cache uses to re-advertise recovered chunks.
    pub fn resident_chunks(&self) -> Vec<ChunkId> {
        let mut v: Vec<ChunkId> = self.resident.keys().copied().collect();
        v.sort_unstable();
        v
    }

    fn path(&self, chunk: ChunkId) -> PathBuf {
        self.dir.join(format!("chunk_{chunk:08}.spill"))
    }

    /// Number of chunks currently spilled.
    pub fn len(&self) -> usize {
        self.resident.len()
    }

    pub fn is_empty(&self) -> bool {
        self.resident.is_empty()
    }

    /// Whether `chunk` is currently spilled.
    pub fn contains(&self, chunk: ChunkId) -> bool {
        self.resident.contains_key(&chunk)
    }

    /// Whether the tier exceeds its budget; a single over-budget chunk may
    /// always stay (mirrors the memory tier's rule).
    fn over_budget(&self) -> bool {
        match self.cap {
            CacheCap::Chunks(cap) => self.resident.len() > cap,
            CacheCap::Bytes(cap) => self.disk_bytes > cap && self.resident.len() > 1,
        }
    }

    /// Demote one chunk's payload to disk.  Returns the chunks the
    /// capacity bound dropped from the tier to make room (the caller
    /// reports them to the Manager as fully evicted).  Re-demoting a chunk
    /// whose file survives from an earlier promotion only refreshes its
    /// recency — payloads are immutable.
    pub fn put(&mut self, chunk: ChunkId, vals: &[Value]) -> Result<Vec<ChunkId>> {
        // chaos site: a refused demotion degrades to a plain eviction in
        // the caller (the chunk drops instead of spilling), never a crash
        if self.faults.inject(Site::SpillIo).is_some() {
            return Err(Error::Config("injected: spill write refused".into()));
        }
        if self.contains(chunk) {
            self.touch(chunk);
            return Ok(Vec::new());
        }
        let mut buf = Vec::new();
        buf.extend_from_slice(SPILL_MAGIC);
        buf.extend_from_slice(&SPILL_VERSION.to_le_bytes());
        buf.extend_from_slice(&(vals.len() as u32).to_le_bytes());
        for v in vals {
            match v {
                Value::Scalar(s) => {
                    buf.push(TAG_SCALAR);
                    buf.extend_from_slice(&s.to_le_bytes());
                }
                Value::Tensor(t) => {
                    buf.push(TAG_TENSOR);
                    encode_tensor(&mut buf, t);
                }
            }
        }
        let mut f = std::fs::File::create(self.path(chunk))?;
        f.write_all(&buf)?;
        self.resident.insert(chunk, buf.len() as u64);
        self.disk_bytes += buf.len() as u64;
        self.order.push_back(chunk);
        let mut dropped = Vec::new();
        while self.over_budget() {
            if let Some(old) = self.order.pop_front() {
                if let Some(sz) = self.resident.remove(&old) {
                    self.disk_bytes = self.disk_bytes.saturating_sub(sz);
                }
                let _ = std::fs::remove_file(self.path(old));
                dropped.push(old);
            } else {
                break;
            }
        }
        Ok(dropped)
    }

    /// Read a spilled chunk's payload back (promotion).  The file is kept
    /// — a later re-eviction demotes for free.  A missing or corrupt file
    /// reads as a miss (the entry is dropped and the caller falls back to
    /// the source tier), never an error: this is a cache.
    pub fn get(&mut self, chunk: ChunkId) -> Option<Vec<Value>> {
        if !self.contains(chunk) {
            return None;
        }
        // chaos sites: a slow disk stalls the promotion; a failed read
        // takes the same degraded path as a corrupt file below (drop the
        // entry, fall back to the source tier)
        self.faults.maybe_stall(Site::SpillSlow);
        let read = if self.faults.inject(Site::SpillIo).is_some() {
            Err(Error::Config("injected: spill read failed".into()))
        } else {
            self.read(chunk)
        };
        match read {
            Ok(vals) => {
                self.touch(chunk);
                Some(vals)
            }
            Err(_) => {
                if let Some(sz) = self.resident.remove(&chunk) {
                    self.disk_bytes = self.disk_bytes.saturating_sub(sz);
                }
                if let Some(pos) = self.order.iter().position(|&c| c == chunk) {
                    self.order.remove(pos);
                }
                let _ = std::fs::remove_file(self.path(chunk));
                None
            }
        }
    }

    fn touch(&mut self, chunk: ChunkId) {
        if let Some(pos) = self.order.iter().position(|&c| c == chunk) {
            self.order.remove(pos);
            self.order.push_back(chunk);
        }
    }

    fn read(&self, chunk: ChunkId) -> Result<Vec<Value>> {
        let mut bytes = Vec::new();
        std::fs::File::open(self.path(chunk))?.read_to_end(&mut bytes)?;
        if bytes.len() < 12 || &bytes[..4] != SPILL_MAGIC {
            return Err(Error::Config("not an htap .spill file".into()));
        }
        // lint: allow(panic) — length checked above, fixed 4-byte slice
        let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
        if version != SPILL_VERSION {
            return Err(Error::Config(format!(
                "spill format version {version}, expected {SPILL_VERSION}"
            )));
        }
        // lint: allow(panic) — length checked above, fixed 4-byte slice
        let count = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
        let mut pos = 12;
        // bound the count by the bytes actually present (tag + f32 = 5
        // minimum per value) so a corrupt header can't force a huge
        // preallocation before decoding hits the truncation error
        if count.saturating_mul(5) > bytes.len() - pos {
            return Err(Error::Config(format!(
                "spill value count {count} exceeds file size"
            )));
        }
        let mut vals = Vec::with_capacity(count);
        for _ in 0..count {
            match take_bytes(&bytes, &mut pos, 1)?[0] {
                TAG_SCALAR => {
                    let raw = take_bytes(&bytes, &mut pos, 4)?;
                    // lint: allow(panic) — take_bytes guarantees a 4-byte slice
                    vals.push(Value::Scalar(f32::from_le_bytes(raw.try_into().unwrap())));
                }
                TAG_TENSOR => vals.push(Value::Tensor(decode_tensor(&bytes, &mut pos)?)),
                t => return Err(Error::Config(format!("bad spill value tag {t}"))),
            }
        }
        if pos != bytes.len() {
            return Err(Error::Config("trailing bytes in spill file".into()));
        }
        Ok(vals)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::HostTensor;

    fn tmp_dir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("htap-spill-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn payload(c: u64) -> Vec<Value> {
        vec![
            Value::Scalar(c as f32),
            Value::Tensor(HostTensor::new(vec![2, 2], vec![c as f32; 4]).unwrap()),
        ]
    }

    #[test]
    fn spill_round_trips_mixed_payloads() {
        let dir = tmp_dir("roundtrip");
        let mut tier = SpillTier::create(&dir, 4).unwrap();
        assert!(tier.is_empty());
        tier.put(3, &payload(3)).unwrap();
        tier.put(7, &payload(7)).unwrap();
        assert_eq!(tier.len(), 2);
        assert!(tier.contains(3) && tier.contains(7));
        assert_eq!(tier.get(3).unwrap(), payload(3));
        assert_eq!(tier.get(7).unwrap(), payload(7));
        // promotion keeps the file: a second read still succeeds
        assert_eq!(tier.get(3).unwrap(), payload(3));
        assert!(tier.get(99).is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn capacity_bound_drops_least_recently_touched() {
        let dir = tmp_dir("cap");
        let mut tier = SpillTier::create(&dir, 2).unwrap();
        assert!(tier.put(0, &payload(0)).unwrap().is_empty());
        assert!(tier.put(1, &payload(1)).unwrap().is_empty());
        // touching 0 makes 1 the eviction victim
        tier.get(0).unwrap();
        let dropped = tier.put(2, &payload(2)).unwrap();
        assert_eq!(dropped, vec![1]);
        assert!(!tier.contains(1));
        assert!(tier.get(1).is_none());
        assert!(tier.contains(0) && tier.contains(2));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn redemotion_of_a_kept_file_is_free() {
        let dir = tmp_dir("redemote");
        let mut tier = SpillTier::create(&dir, 2).unwrap();
        tier.put(5, &payload(5)).unwrap();
        // promote, then demote again: no drop, still readable
        tier.get(5).unwrap();
        assert!(tier.put(5, &payload(5)).unwrap().is_empty());
        assert_eq!(tier.len(), 1);
        assert_eq!(tier.get(5).unwrap(), payload(5));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn byte_budget_caps_the_disk_tier() {
        let dir = tmp_dir("bytecap");
        // measure one payload's on-disk size, then budget for ~1.5 files
        let mut probe = SpillTier::create(dir.join("probe"), 8).unwrap();
        probe.put(0, &payload(0)).unwrap();
        let file_sz = probe.disk_bytes;
        assert!(file_sz > 0);
        let mut tier =
            SpillTier::create(&dir, CacheCap::Bytes(file_sz + file_sz / 2)).unwrap();
        assert!(tier.put(1, &payload(1)).unwrap().is_empty());
        // the second put overflows the byte budget: LRU chunk 1 drops
        let dropped = tier.put(2, &payload(2)).unwrap();
        assert_eq!(dropped, vec![1]);
        assert!(tier.contains(2) && !tier.contains(1));
        assert!(tier.disk_bytes <= file_sz + file_sz / 2);
        // a single over-budget chunk is still held (never evict to empty)
        let mut tiny = SpillTier::create(dir.join("tiny"), CacheCap::Bytes(1)).unwrap();
        assert!(tiny.put(7, &payload(7)).unwrap().is_empty());
        assert!(tiny.contains(7));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_spill_file_reads_as_a_miss() {
        let dir = tmp_dir("corrupt");
        let mut tier = SpillTier::create(&dir, 2).unwrap();
        tier.put(1, &payload(1)).unwrap();
        std::fs::write(tier.path(1), b"garbage").unwrap();
        assert!(tier.get(1).is_none(), "corruption must fall back to the source tier");
        assert!(!tier.contains(1));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn injected_spill_faults_degrade_not_die() {
        use crate::faults::{FaultPlan, Faults};
        let dir = tmp_dir("faults");
        let mut tier = SpillTier::create(&dir, 4).unwrap();
        let reg = crate::obs::Registry::new();
        let plan = FaultPlan::parse("spill-io=1#1", 3).unwrap();
        tier.set_faults(Faults::armed(&plan, &reg));
        // the first put eats the injected write error...
        assert!(tier.put(0, &payload(0)).is_err());
        // ...the #1 cap restores service: the retry demotes cleanly and
        // round-trips, and the injection was counted in the registry
        assert!(tier.put(0, &payload(0)).unwrap().is_empty());
        assert_eq!(tier.get(0).unwrap(), payload(0));
        assert_eq!(reg.snapshot().counter("faults.spill-io.injected"), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn recover_rebuilds_the_index_from_surviving_files() {
        let dir = tmp_dir("recover");
        {
            let mut tier = SpillTier::create(&dir, 8).unwrap();
            tier.put(2, &payload(2)).unwrap();
            tier.put(5, &payload(5)).unwrap();
            tier.put(1, &payload(1)).unwrap();
        } // "crash": the tier is dropped, files survive
        let mut warm = SpillTier::recover(&dir, 8).unwrap();
        assert_eq!(warm.resident_chunks(), vec![1, 2, 5]);
        assert_eq!(warm.len(), 3);
        assert!(warm.disk_bytes > 0);
        // recovered payloads read back intact
        assert_eq!(warm.get(5).unwrap(), payload(5));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn recover_deletes_corrupt_and_foreign_files() {
        let dir = tmp_dir("recover-corrupt");
        {
            let mut tier = SpillTier::create(&dir, 8).unwrap();
            tier.put(3, &payload(3)).unwrap();
        }
        std::fs::write(dir.join("chunk_00000009.spill"), b"garbage").unwrap();
        std::fs::write(dir.join("odd-name.spill"), b"not ours").unwrap();
        std::fs::write(dir.join("keep.txt"), b"unrelated").unwrap();
        let warm = SpillTier::recover(&dir, 8).unwrap();
        assert_eq!(warm.resident_chunks(), vec![3], "only the valid file survives");
        assert!(!dir.join("chunk_00000009.spill").exists());
        assert!(!dir.join("odd-name.spill").exists());
        assert!(dir.join("keep.txt").exists(), "non-spill files are untouched");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn recover_respects_a_smaller_budget() {
        let dir = tmp_dir("recover-cap");
        {
            let mut tier = SpillTier::create(&dir, 8).unwrap();
            for c in 0..4 {
                tier.put(c, &payload(c)).unwrap();
            }
        }
        // restart with a smaller cap: oldest (lowest id) recovered chunks
        // are dropped until within budget
        let warm = SpillTier::recover(&dir, 2).unwrap();
        assert_eq!(warm.resident_chunks(), vec![2, 3]);
        assert!(!dir.join("chunk_00000000.spill").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn create_clears_stale_spill_files() {
        let dir = tmp_dir("stale");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("chunk_00000001.spill"), b"old run").unwrap();
        std::fs::write(dir.join("keep.txt"), b"unrelated").unwrap();
        let tier = SpillTier::create(&dir, 2).unwrap();
        assert!(tier.is_empty());
        assert!(!dir.join("chunk_00000001.spill").exists());
        assert!(dir.join("keep.txt").exists(), "only .spill files are cleared");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
