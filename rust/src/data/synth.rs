//! Synthetic H&E tile synthesis.
//!
//! Tiles look enough like stained tissue for the real pipeline to produce
//! meaningful work: nuclei are bluish-purple ellipses (hematoxylin
//! absorbs), stroma is pink (eosin), RBC blobs are saturated red, plus
//! white-ish lumen and per-pixel noise.  Nucleus count/size are
//! configurable so workloads can reproduce the paper's *data-dependent
//! performance variability* (§IV-B: "the same operation may achieve
//! different speedup values with different data chunks").

use crate::imgproc::Rgb;
use crate::testing::Rng;

/// Tile synthesis parameters.
#[derive(Debug, Clone)]
pub struct SynthConfig {
    pub tile_size: usize,
    /// nuclei per tile: uniform in [min, max]
    pub nuclei_min: usize,
    pub nuclei_max: usize,
    /// nucleus radii in pixels
    pub radius_min: f32,
    pub radius_max: f32,
    /// RBC blobs per tile
    pub rbc_count: usize,
    /// per-channel noise amplitude
    pub noise: f32,
    pub seed: u64,
}

impl SynthConfig {
    /// 64-px tiles (matches the test artifact size).
    pub fn small() -> Self {
        SynthConfig {
            tile_size: 32,
            nuclei_min: 2,
            nuclei_max: 5,
            radius_min: 2.5,
            radius_max: 5.0,
            rbc_count: 1,
            noise: 6.0,
            seed: 42,
        }
    }

    /// Tiles matching an artifact size.
    pub fn for_tile_size(tile_size: usize, seed: u64) -> Self {
        let scale = tile_size as f32 / 64.0;
        SynthConfig {
            tile_size,
            nuclei_min: (4.0 * scale * scale).max(2.0) as usize,
            nuclei_max: (10.0 * scale * scale).max(4.0) as usize,
            radius_min: 3.0 * scale.max(1.0),
            radius_max: 6.5 * scale.max(1.0),
            rbc_count: (2.0 * scale).max(1.0) as usize,
            noise: 6.0,
            seed,
        }
    }
}

/// Colours in RGB 0..255 (approximate H&E appearance).
const STROMA: [f32; 3] = [232.0, 180.0, 205.0]; // eosin pink
const NUCLEUS: [f32; 3] = [95.0, 60.0, 150.0]; // hematoxylin blue-purple
const RBC: [f32; 3] = [200.0, 40.0, 40.0]; // saturated red
const BACKGROUND: [f32; 3] = [244.0, 242.0, 245.0]; // glass / lumen

/// Deterministic tile generator.
pub struct TileSynthesizer {
    cfg: SynthConfig,
}

/// A placed ellipse (ground truth for validation tests).
#[derive(Debug, Clone, Copy)]
pub struct Nucleus {
    pub cy: f32,
    pub cx: f32,
    pub ry: f32,
    pub rx: f32,
    pub angle: f32,
}

impl TileSynthesizer {
    pub fn new(cfg: SynthConfig) -> Self {
        TileSynthesizer { cfg }
    }

    fn rng_for(&self, chunk: u64) -> Rng {
        Rng::new(self.cfg.seed ^ chunk.wrapping_mul(0x9E3779B97F4A7C15) ^ 0xABCD_EF01)
    }

    /// Ground-truth nuclei of tile `chunk` (same placement the tile drew).
    pub fn nuclei(&self, chunk: u64) -> Vec<Nucleus> {
        let mut rng = self.rng_for(chunk);
        let s = self.cfg.tile_size as f32;
        let n = rng.range(self.cfg.nuclei_min, self.cfg.nuclei_max);
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let r1 = rng.f32_range(self.cfg.radius_min, self.cfg.radius_max);
            let r2 = rng.f32_range(self.cfg.radius_min, self.cfg.radius_max);
            out.push(Nucleus {
                cy: rng.f32_range(r1 + 1.0, s - r1 - 1.0),
                cx: rng.f32_range(r2 + 1.0, s - r2 - 1.0),
                ry: r1,
                rx: r2,
                angle: rng.f32_range(0.0, std::f32::consts::PI),
            });
        }
        out
    }

    /// Full tissue tile: stroma + nuclei + RBC blobs + noise.
    pub fn tissue_tile(&self, chunk: u64) -> Rgb {
        let s = self.cfg.tile_size;
        let nuclei = self.nuclei(chunk);
        let mut rng = self.rng_for(chunk ^ 0x55AA);
        let mut img = Rgb::filled(s, s, STROMA);
        // lumen patch (white) in ~30% of tiles
        if rng.f32() < 0.3 {
            let ly = rng.below(s);
            let lx = rng.below(s);
            let lr = rng.f32_range(3.0, s as f32 / 4.0);
            paint_ellipse(&mut img, ly as f32, lx as f32, lr, lr, 0.0, BACKGROUND);
        }
        // RBC blobs
        for _ in 0..self.cfg.rbc_count {
            let cy = rng.f32_range(2.0, s as f32 - 2.0);
            let cx = rng.f32_range(2.0, s as f32 - 2.0);
            let r = rng.f32_range(1.5, 3.5);
            paint_ellipse(&mut img, cy, cx, r, r, 0.0, RBC);
        }
        // nuclei on top
        for n in &nuclei {
            paint_ellipse(&mut img, n.cy, n.cx, n.ry, n.rx, n.angle, NUCLEUS);
        }
        // noise
        for v in img.px.iter_mut() {
            *v = (*v + rng.f32_range(-self.cfg.noise, self.cfg.noise)).clamp(0.0, 255.0);
        }
        img
    }

    /// Background-only tile (glass + noise) — discarded by preprocessing.
    pub fn background_tile(&self, chunk: u64) -> Rgb {
        let s = self.cfg.tile_size;
        let mut rng = self.rng_for(chunk ^ 0xBB66);
        let mut img = Rgb::filled(s, s, BACKGROUND);
        for v in img.px.iter_mut() {
            *v = (*v + rng.f32_range(-2.0, 2.0)).clamp(0.0, 255.0);
        }
        img
    }
}

fn paint_ellipse(img: &mut Rgb, cy: f32, cx: f32, ry: f32, rx: f32, angle: f32, color: [f32; 3]) {
    let (sin, cos) = angle.sin_cos();
    let r_max = ry.max(rx).ceil() as isize + 1;
    let y0 = (cy as isize - r_max).max(0);
    let y1 = (cy as isize + r_max).min(img.h as isize - 1);
    let x0 = (cx as isize - r_max).max(0);
    let x1 = (cx as isize + r_max).min(img.w as isize - 1);
    for y in y0..=y1 {
        for x in x0..=x1 {
            let dy = y as f32 - cy;
            let dx = x as f32 - cx;
            let u = cos * dx + sin * dy;
            let v = -sin * dx + cos * dy;
            if (u / rx) * (u / rx) + (v / ry) * (v / ry) <= 1.0 {
                img.set(y as usize, x as usize, color);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::imgproc::color::hema_image;

    #[test]
    fn tissue_tile_has_dark_nuclei_on_hema_channel() {
        let synth = TileSynthesizer::new(SynthConfig::small());
        let tile = synth.tissue_tile(0);
        let hema = hema_image(&tile).unwrap();
        let nuclei = synth.nuclei(0);
        assert!(!nuclei.is_empty());
        // hematoxylin response at a nucleus centre should exceed stroma
        let n = &nuclei[0];
        let at_nucleus = hema.at(n.cy as usize, n.cx as usize);
        // border pixel (very likely stroma)
        let at_corner = hema.at(0, 0);
        assert!(
            at_nucleus > at_corner + 20.0,
            "nucleus {at_nucleus} vs corner {at_corner}"
        );
    }

    #[test]
    fn background_tile_is_bright() {
        let synth = TileSynthesizer::new(SynthConfig::small());
        let tile = synth.background_tile(1);
        let mean: f32 = tile.px.iter().sum::<f32>() / tile.px.len() as f32;
        assert!(mean > 230.0);
    }

    #[test]
    fn nuclei_within_bounds() {
        let cfg = SynthConfig::for_tile_size(64, 9);
        let synth = TileSynthesizer::new(cfg.clone());
        for chunk in 0..10 {
            for n in synth.nuclei(chunk) {
                assert!(n.cy >= 0.0 && n.cy < cfg.tile_size as f32);
                assert!(n.cx >= 0.0 && n.cx < cfg.tile_size as f32);
            }
        }
    }

    #[test]
    fn config_scales_with_tile_size() {
        let small = SynthConfig::for_tile_size(64, 0);
        let big = SynthConfig::for_tile_size(256, 0);
        assert!(big.nuclei_max > small.nuclei_max);
        assert!(big.radius_max > small.radius_max);
    }
}
