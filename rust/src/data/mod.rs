//! Synthetic WSI data: the dataset substrate.
//!
//! The paper processes 340 glioblastoma whole-slide images partitioned into
//! 36,848 4Kx4K tiles stored on Lustre.  We cannot ship those, so this
//! module generates **synthetic H&E-like tiles** with the structure the
//! pipeline cares about: elliptical nuclei (hematoxylin-dark), eosin-pink
//! stroma, red-blood-cell blobs, texture noise, and background-only tiles
//! that get discarded exactly like the paper's preprocessing ("tiles with
//! background only pixels were discarded beforehand").
//!
//! [`TileStore`] serves tiles by chunk id with a configurable artificial
//! read latency, standing in for the shared-filesystem reads whose cost the
//! paper's Figs. 8 and 14 include.  The [`staging`] subsystem builds on it:
//! chunk sources (synthetic or `.tile` directories), the worker-side
//! staging cache with asynchronous prefetch, and the manager-side chunk
//! catalog behind locality-aware assignment.

pub mod staging;
pub mod synth;

pub use staging::{ChunkCatalog, ChunkSource, DirSource, StagingCache, SynthSource};
pub use synth::{SynthConfig, TileSynthesizer};

use crate::coordinator::ChunkLoader;
use crate::imgproc::Rgb;
use crate::runtime::Value;

use std::sync::Arc;
use std::time::Duration;

/// A set of synthetic tiles addressable by chunk id.
pub struct TileStore {
    cfg: SynthConfig,
    n_tiles: usize,
    /// artificial per-read latency (models shared-FS access)
    read_latency: Duration,
    /// chunk ids that simulate "background-only" tiles (pre-discarded)
    background: Vec<bool>,
}

impl TileStore {
    /// Create a store of `n_tiles` tiles; roughly half of raw tiles in the
    /// paper's images were background-only, but those are discarded before
    /// scheduling, so by default every tile here is tissue.
    pub fn new(cfg: SynthConfig, n_tiles: usize) -> Self {
        TileStore { cfg, n_tiles, read_latency: Duration::ZERO, background: vec![false; n_tiles] }
    }

    /// Add an artificial per-read latency (Lustre stand-in).
    pub fn with_read_latency(mut self, lat: Duration) -> Self {
        self.read_latency = lat;
        self
    }

    /// Mark a fraction of tiles as background-only (for discard tests).
    pub fn with_background_fraction(mut self, frac: f32, seed: u64) -> Self {
        let mut rng = crate::testing::Rng::new(seed);
        for b in self.background.iter_mut() {
            *b = rng.f32() < frac;
        }
        self
    }

    pub fn len(&self) -> usize {
        self.n_tiles
    }

    pub fn is_empty(&self) -> bool {
        self.n_tiles == 0
    }

    /// Generate tile `chunk` (deterministic in (seed, chunk)).
    pub fn tile(&self, chunk: u64) -> Rgb {
        let synth = TileSynthesizer::new(self.cfg.clone());
        if self.background.get(chunk as usize).copied().unwrap_or(false) {
            synth.background_tile(chunk)
        } else {
            synth.tissue_tile(chunk)
        }
    }

    /// Chunk ids that survive the background discard.
    pub fn tissue_chunks(&self) -> Vec<u64> {
        (0..self.n_tiles as u64)
            .filter(|&c| !self.background[c as usize])
            .collect()
    }

    /// Adapt to the coordinator's [`ChunkLoader`] interface.
    pub fn loader(self: Arc<Self>) -> ChunkLoader {
        Arc::new(move |chunk| {
            if !self.read_latency.is_zero() {
                std::thread::sleep(self.read_latency);
            }
            let tile = self.tile(chunk);
            Ok(vec![Value::Tensor(tile.to_tensor())])
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_tiles() {
        let store = TileStore::new(SynthConfig::small(), 4);
        let a = store.tile(2);
        let b = store.tile(2);
        assert_eq!(a, b);
        let c = store.tile(3);
        assert_ne!(a, c);
    }

    #[test]
    fn loader_returns_tensor() {
        let store = Arc::new(TileStore::new(SynthConfig::small(), 2));
        let loader = store.loader();
        let vals = loader(0).unwrap();
        assert_eq!(vals.len(), 1);
        let t = vals[0].as_tensor().unwrap();
        assert_eq!(t.shape(), &[32, 32, 3]);
    }

    #[test]
    fn background_fraction_discard() {
        let store = TileStore::new(SynthConfig::small(), 100).with_background_fraction(0.5, 7);
        let tissue = store.tissue_chunks();
        assert!(tissue.len() > 20 && tissue.len() < 80, "got {}", tissue.len());
    }
}
