//! Declarative JSON workflow descriptions.
//!
//! A workflow is data: stages, op instances drawn from an [`OpRegistry`],
//! and wiring — everything except the function bodies, which the registry
//! supplies.  This module loads such a description through the
//! [`WorkflowBuilder`] (so every eager validation applies identically) and
//! serialises a built workflow back to the same format.
//!
//! ```json
//! {
//!   "name": "cell-stats",
//!   "stages": [
//!     {
//!       "name": "detect",
//!       "kind": "per_chunk",
//!       "inputs": ["chunk"],
//!       "ops": [
//!         { "op": "grayscale", "inputs": [ {"input": 0} ] },
//!         { "op": "binarize",  "inputs": [ {"op": "grayscale"}, {"param": 140.0} ] }
//!       ],
//!       "outputs": [ {"op": "binarize"} ]
//!     },
//!     {
//!       "name": "aggregate",
//!       "kind": "reduce",
//!       "inputs": [ {"stage": "detect", "output": 0} ],
//!       "ops": [ { "op": "mean_stats", "inputs": "all" } ],
//!       "outputs": [ {"op": "mean_stats"} ]
//!     }
//!   ]
//! }
//! ```
//!
//! Stage `inputs` entries are the string `"chunk"` (the full chunk
//! payload), `{"chunk": k}` (one value of a multi-value payload), or an
//! upstream reference `{"stage": name, "output": j}`.
//!
//! Reference forms inside `ops[].inputs` / `outputs`:
//! * `{"input": k}` — the stage's k-th declared external input;
//! * `{"op": "<instance>", "output": j}` — output `j` (default 0) of an
//!   earlier op instance in the same stage;
//! * `{"param": <number>}` — a scalar constant;
//! * `{"param": {"dims": [...], "data": [...]}}` — a tensor constant
//!   (row-major f32, `dims` must multiply out to `data.len()`);
//! * the string `"all"` in place of the `inputs` array — the Reduce
//!   consume-all-inputs convention.
//!
//! Op entries take an optional `"as"` instance name so the same registry op
//! can appear repeatedly in one stage.

use super::builder::{OpHandle, OpRegistry, PortSpec, StageHandle, WorkflowBuilder};
use super::{PortRef, StageInput, StageKind, Workflow};
use crate::config::json::Json;
use crate::runtime::Value;
use crate::{Error, Result};
use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;

fn cfg_err(msg: String) -> Error {
    Error::Config(msg)
}

fn stage_kind(s: &str) -> Result<StageKind> {
    match s {
        "per_chunk" => Ok(StageKind::PerChunk),
        "reduce" => Ok(StageKind::Reduce),
        other => Err(cfg_err(format!(
            "unknown stage kind '{other}' (expected 'per_chunk' or 'reduce')"
        ))),
    }
}

fn kind_name(k: StageKind) -> &'static str {
    match k {
        StageKind::PerChunk => "per_chunk",
        StageKind::Reduce => "reduce",
    }
}

/// Parse one `{"input": ..}` / `{"op": ..}` / `{"param": ..}` reference.
fn port_spec(j: &Json, ops: &HashMap<String, OpHandle>, ctx: &str) -> Result<PortSpec> {
    let obj = j
        .as_obj()
        .ok_or_else(|| cfg_err(format!("{ctx}: port reference must be an object")))?;
    if let Some(k) = obj.get("input") {
        let k = k
            .as_usize()
            .ok_or_else(|| cfg_err(format!("{ctx}: 'input' must be a number")))?;
        return Ok(PortSpec::Input(k));
    }
    if let Some(name) = obj.get("op") {
        let name = name
            .as_str()
            .ok_or_else(|| cfg_err(format!("{ctx}: 'op' must be a string")))?;
        let handle = ops.get(name).ok_or_else(|| {
            cfg_err(format!("{ctx}: no earlier op instance named '{name}' in this stage"))
        })?;
        let output = match obj.get("output") {
            None => 0,
            Some(o) => o
                .as_usize()
                .ok_or_else(|| cfg_err(format!("{ctx}: 'output' must be a number")))?,
        };
        return Ok(handle.output(output));
    }
    if let Some(p) = obj.get("param") {
        if let Some(v) = p.as_f64() {
            return Ok(PortSpec::Param(Value::Scalar(v as f32)));
        }
        // tensor constant: {"param": {"dims": [...], "data": [...]}}
        if let Some(t) = p.as_obj() {
            let dims = t
                .get("dims")
                .and_then(|d| d.as_arr())
                .ok_or_else(|| {
                    cfg_err(format!("{ctx}: tensor param needs a 'dims' array"))
                })?
                .iter()
                .map(|d| {
                    d.as_usize()
                        .ok_or_else(|| cfg_err(format!("{ctx}: 'dims' must be numbers")))
                })
                .collect::<Result<Vec<usize>>>()?;
            let data = t
                .get("data")
                .and_then(|d| d.as_arr())
                .ok_or_else(|| {
                    cfg_err(format!("{ctx}: tensor param needs a 'data' array"))
                })?
                .iter()
                .map(|v| {
                    v.as_f64()
                        .map(|f| f as f32)
                        .ok_or_else(|| cfg_err(format!("{ctx}: 'data' must be numbers")))
                })
                .collect::<Result<Vec<f32>>>()?;
            let value = Value::tensor(dims, data)
                .map_err(|e| cfg_err(format!("{ctx}: bad tensor param: {e}")))?;
            return Ok(PortSpec::Param(value));
        }
        return Err(cfg_err(format!(
            "{ctx}: 'param' must be a number or a {{dims, data}} tensor object"
        )));
    }
    Err(cfg_err(format!(
        "{ctx}: port reference needs one of 'input', 'op', 'param'"
    )))
}

/// Load a workflow description against a registry.
pub fn workflow_from_json(root: &Json, registry: Arc<OpRegistry>) -> Result<Workflow> {
    let name = root
        .field("name")?
        .as_str()
        .ok_or_else(|| cfg_err("workflow 'name' must be a string".into()))?;
    let mut wb = WorkflowBuilder::with_shared_registry(name, registry);
    let mut stage_handles: HashMap<String, StageHandle> = HashMap::new();
    let stages = root
        .field("stages")?
        .as_arr()
        .ok_or_else(|| cfg_err("'stages' must be an array".into()))?;
    for sj in stages {
        let sname = sj
            .field("name")?
            .as_str()
            .ok_or_else(|| cfg_err("stage 'name' must be a string".into()))?;
        let kind = stage_kind(
            sj.field("kind")?
                .as_str()
                .ok_or_else(|| cfg_err(format!("stage '{sname}': 'kind' must be a string")))?,
        )?;
        let mut sb = wb.stage(sname, kind);
        for inp in sj
            .field("inputs")?
            .as_arr()
            .ok_or_else(|| cfg_err(format!("stage '{sname}': 'inputs' must be an array")))?
        {
            match inp {
                Json::Str(s) if s == "chunk" => {
                    sb.input_chunk();
                }
                Json::Obj(o) if o.contains_key("chunk") => {
                    // {"chunk": k}: one value of a multi-value chunk payload
                    let k = o.get("chunk").and_then(|v| v.as_usize()).ok_or_else(|| {
                        cfg_err(format!("stage '{sname}': 'chunk' must be a number"))
                    })?;
                    sb.input_chunk_part(k);
                }
                Json::Obj(o) => {
                    let up = o
                        .get("stage")
                        .and_then(|v| v.as_str())
                        .ok_or_else(|| {
                            cfg_err(format!(
                                "stage '{sname}': upstream input needs a 'stage' name"
                            ))
                        })?;
                    let handle = stage_handles.get(up).ok_or_else(|| {
                        cfg_err(format!(
                            "stage '{sname}': upstream stage '{up}' is not defined earlier"
                        ))
                    })?;
                    let output = match o.get("output") {
                        None => 0,
                        Some(v) => v.as_usize().ok_or_else(|| {
                            cfg_err(format!("stage '{sname}': 'output' must be a number"))
                        })?,
                    };
                    sb.input_upstream(handle.output(output));
                }
                other => {
                    return Err(cfg_err(format!(
                        "stage '{sname}': input must be \"chunk\" or an upstream object, \
                         got {other}"
                    )))
                }
            }
        }
        let mut op_handles: HashMap<String, OpHandle> = HashMap::new();
        for oj in sj
            .field("ops")?
            .as_arr()
            .ok_or_else(|| cfg_err(format!("stage '{sname}': 'ops' must be an array")))?
        {
            let op = oj
                .field("op")?
                .as_str()
                .ok_or_else(|| cfg_err(format!("stage '{sname}': op 'op' must be a string")))?;
            let instance = match oj.as_obj().and_then(|o| o.get("as")) {
                None => op.to_string(),
                Some(a) => a
                    .as_str()
                    .ok_or_else(|| cfg_err(format!("stage '{sname}': 'as' must be a string")))?
                    .to_string(),
            };
            let ctx = format!("stage '{sname}' op '{instance}'");
            let inputs = oj.field("inputs").map_err(|_| {
                cfg_err(format!("{ctx}: missing 'inputs' (use \"all\" for consume-all)"))
            })?;
            let handle = match inputs {
                Json::Str(s) if s == "all" => {
                    // add_reduce_op names the instance after the op itself
                    if instance != op {
                        return Err(cfg_err(format!(
                            "{ctx}: \"all\"-input ops cannot be aliased"
                        )));
                    }
                    sb.add_reduce_op(op)?
                }
                Json::Arr(items) => {
                    let mut specs = Vec::with_capacity(items.len());
                    for item in items {
                        specs.push(port_spec(item, &op_handles, &ctx)?);
                    }
                    sb.add_op_as(&instance, op, &specs)?
                }
                other => {
                    return Err(cfg_err(format!(
                        "{ctx}: 'inputs' must be an array or \"all\", got {other}"
                    )))
                }
            };
            op_handles.insert(instance, handle);
        }
        for oj in sj
            .field("outputs")?
            .as_arr()
            .ok_or_else(|| cfg_err(format!("stage '{sname}': 'outputs' must be an array")))?
        {
            let spec = port_spec(oj, &op_handles, &format!("stage '{sname}' output"))?;
            sb.export(spec)?;
        }
        let handle = wb.add_stage(sb)?;
        stage_handles.insert(sname.to_string(), handle);
    }
    wb.build()
}

/// Load a workflow description from JSON text.
pub fn workflow_from_str(text: &str, registry: Arc<OpRegistry>) -> Result<Workflow> {
    workflow_from_json(&Json::parse(text)?, registry)
}

/// Load a workflow description from a file.
pub fn workflow_from_file(path: &str, registry: Arc<OpRegistry>) -> Result<Workflow> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| cfg_err(format!("cannot read workflow file '{path}': {e}")))?;
    workflow_from_str(&text, registry)
}

fn obj(entries: Vec<(&str, Json)>) -> Json {
    let mut m = BTreeMap::new();
    for (k, v) in entries {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

fn port_ref_json(p: &PortRef, stage_ops: &[super::OpDef], ctx: &str) -> Result<Json> {
    match p {
        PortRef::StageInput(k) => Ok(obj(vec![("input", Json::Num(*k as f64))])),
        PortRef::Op { op, output } => {
            let name = stage_ops
                .get(*op)
                .map(|o| o.name.clone())
                .ok_or_else(|| cfg_err(format!("{ctx}: dangling op reference {op}")))?;
            let mut entries = vec![("op", Json::Str(name))];
            if *output != 0 {
                entries.push(("output", Json::Num(*output as f64)));
            }
            Ok(obj(entries))
        }
        PortRef::Param(Value::Scalar(s)) => Ok(obj(vec![("param", Json::Num(*s as f64))])),
        // f32 -> f64 is exact, and Json prints f64 shortest-round-trip,
        // so tensor constants survive a serialise/load cycle bit-for-bit
        PortRef::Param(Value::Tensor(t)) => Ok(obj(vec![(
            "param",
            obj(vec![
                (
                    "dims",
                    Json::Arr(t.shape().iter().map(|&d| Json::Num(d as f64)).collect()),
                ),
                (
                    "data",
                    Json::Arr(t.data().iter().map(|&v| Json::Num(v as f64)).collect()),
                ),
            ]),
        )])),
    }
}

/// Serialise a workflow's structure back to the JSON description format.
/// Function bodies are not serialised — loading requires a registry that
/// provides every `op` name used.
pub fn workflow_to_json(wf: &Workflow) -> Result<Json> {
    let mut stages = Vec::with_capacity(wf.stages.len());
    for stage in &wf.stages {
        let mut inputs = Vec::new();
        for inp in &stage.inputs {
            match inp {
                StageInput::Chunk => inputs.push(Json::Str("chunk".into())),
                StageInput::ChunkPart(k) => {
                    inputs.push(obj(vec![("chunk", Json::Num(*k as f64))]))
                }
                StageInput::Upstream { stage: up, output } => {
                    let up_name = wf
                        .stages
                        .get(*up)
                        .map(|s| s.name.clone())
                        .ok_or_else(|| {
                            cfg_err(format!("stage '{}': dangling upstream {up}", stage.name))
                        })?;
                    inputs.push(obj(vec![
                        ("stage", Json::Str(up_name)),
                        ("output", Json::Num(*output as f64)),
                    ]));
                }
            }
        }
        let mut ops = Vec::new();
        for def in &stage.ops {
            let ctx = format!("stage '{}' op '{}'", stage.name, def.name);
            let inputs_json = if def.inputs.is_empty() {
                Json::Str("all".into())
            } else {
                let mut items = Vec::with_capacity(def.inputs.len());
                for p in &def.inputs {
                    items.push(port_ref_json(p, &stage.ops, &ctx)?);
                }
                Json::Arr(items)
            };
            let mut entries = vec![("op", Json::Str(def.op.clone()))];
            if def.name != def.op {
                entries.push(("as", Json::Str(def.name.clone())));
            }
            entries.push(("inputs", inputs_json));
            ops.push(obj(entries));
        }
        let mut outputs = Vec::new();
        for p in &stage.outputs {
            outputs.push(port_ref_json(p, &stage.ops, &format!("stage '{}'", stage.name))?);
        }
        stages.push(obj(vec![
            ("name", Json::Str(stage.name.clone())),
            ("kind", Json::Str(kind_name(stage.kind).into())),
            ("inputs", Json::Arr(inputs)),
            ("ops", Json::Arr(ops)),
            ("outputs", Json::Arr(outputs)),
        ]));
    }
    Ok(obj(vec![
        ("name", Json::Str(wf.name.clone())),
        ("stages", Json::Arr(stages)),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::builder::OpSpec;

    fn reg() -> Arc<OpRegistry> {
        let mut r = OpRegistry::new();
        r.register_cpu("inc", 1, |args| Ok(vec![Value::Scalar(args[0].as_scalar()? + 1.0)]))
            .unwrap();
        r.register(OpSpec::cpu("fan2", 2, |args| {
            let v = args[0].as_scalar()?;
            Ok(vec![Value::Scalar(v), Value::Scalar(v * 10.0)])
        }))
        .unwrap();
        r.register_cpu("sum", 1, |args| {
            let mut s = 0.0;
            for a in args {
                s += a.as_scalar()?;
            }
            Ok(vec![Value::Scalar(s)])
        })
        .unwrap();
        Arc::new(r)
    }

    const DOC: &str = r#"{
        "name": "demo",
        "stages": [
            {
                "name": "front",
                "kind": "per_chunk",
                "inputs": ["chunk"],
                "ops": [
                    { "op": "inc", "inputs": [ {"input": 0} ] },
                    { "op": "fan2", "inputs": [ {"op": "inc"} ] },
                    { "op": "inc", "as": "inc2", "inputs": [ {"op": "fan2", "output": 1} ] }
                ],
                "outputs": [ {"op": "inc2"}, {"op": "fan2", "output": 0} ]
            },
            {
                "name": "agg",
                "kind": "reduce",
                "inputs": [ {"stage": "front", "output": 0} ],
                "ops": [ { "op": "sum", "inputs": "all" } ],
                "outputs": [ {"op": "sum"} ]
            }
        ]
    }"#;

    #[test]
    fn loads_and_executes() {
        let wf = workflow_from_str(DOC, reg()).unwrap();
        assert_eq!(wf.stages.len(), 2);
        assert_eq!(wf.stages[0].ops.len(), 3);
        assert_eq!(wf.stages[1].kind, StageKind::Reduce);
        // chunk value 2 -> inc = 3 -> fan2 = (3, 30) -> inc2 = 31
        let out = crate::dataflow::run_stage_serial(&wf.stages[0], &[Value::Scalar(2.0)])
            .unwrap();
        assert_eq!(out[0].as_scalar().unwrap(), 31.0);
        assert_eq!(out[1].as_scalar().unwrap(), 3.0);
    }

    #[test]
    fn round_trips_through_json() {
        let wf = workflow_from_str(DOC, reg()).unwrap();
        let json = workflow_to_json(&wf).unwrap();
        let wf2 = workflow_from_json(&json, reg()).unwrap();
        let json2 = workflow_to_json(&wf2).unwrap();
        assert_eq!(json.to_string(), json2.to_string());
        // and the reloaded workflow computes the same thing
        let a = crate::dataflow::run_stage_serial(&wf.stages[0], &[Value::Scalar(5.0)])
            .unwrap();
        let b = crate::dataflow::run_stage_serial(&wf2.stages[0], &[Value::Scalar(5.0)])
            .unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn tensor_params_and_chunk_parts_round_trip() {
        let mut r = OpRegistry::new();
        r.register_cpu("tsum", 2, |args| {
            let t = args[0].as_tensor()?;
            let bias = args[1].as_scalar()?;
            Ok(vec![Value::Scalar(t.data().iter().sum::<f32>() + bias)])
        })
        .unwrap();
        let reg = Arc::new(r);
        let doc = r#"{
            "name": "tensors",
            "stages": [{
                "name": "s", "kind": "per_chunk",
                "inputs": [ {"chunk": 1} ],
                "ops": [ { "op": "tsum", "inputs": [
                    {"param": {"dims": [2, 2], "data": [1.5, 2.0, 3.25, 4.0]}},
                    {"input": 0}
                ] } ],
                "outputs": [ {"op": "tsum"} ]
            }]
        }"#;
        let wf = workflow_from_str(doc, reg.clone()).unwrap();
        assert!(matches!(wf.stages[0].inputs[0], StageInput::ChunkPart(1)));
        // the stage executes against the selected payload part
        let out =
            crate::dataflow::run_stage_serial(&wf.stages[0], &[Value::Scalar(0.25)]).unwrap();
        assert_eq!(out[0].as_scalar().unwrap(), 1.5 + 2.0 + 3.25 + 4.0 + 0.25);
        // serialise -> reload -> serialise is a fixed point (tensor bits
        // and the chunk-part index both survive)
        let json = workflow_to_json(&wf).unwrap();
        let wf2 = workflow_from_json(&json, reg.clone()).unwrap();
        let json2 = workflow_to_json(&wf2).unwrap();
        assert_eq!(json.to_string(), json2.to_string());
        let a = crate::dataflow::run_stage_serial(&wf.stages[0], &[Value::Scalar(1.0)]).unwrap();
        let b = crate::dataflow::run_stage_serial(&wf2.stages[0], &[Value::Scalar(1.0)]).unwrap();
        assert_eq!(a, b);
        // dims/data mismatch is rejected at load with context
        let bad = doc.replace("[2, 2]", "[3, 2]");
        let err = workflow_from_str(&bad, reg.clone()).unwrap_err();
        assert!(err.to_string().contains("bad tensor param"), "{err}");
        // a malformed chunk-part index is rejected
        let bad = doc.replace(r#"{"chunk": 1}"#, r#"{"chunk": "one"}"#);
        let err = workflow_from_str(&bad, reg).unwrap_err();
        assert!(err.to_string().contains("'chunk' must be a number"), "{err}");
    }

    #[test]
    fn unknown_op_instance_reference_rejected() {
        let doc = r#"{
            "name": "bad",
            "stages": [{
                "name": "s", "kind": "per_chunk", "inputs": ["chunk"],
                "ops": [ { "op": "inc", "inputs": [ {"op": "ghost"} ] } ],
                "outputs": [ {"op": "inc"} ]
            }]
        }"#;
        let err = workflow_from_str(doc, reg()).unwrap_err();
        assert!(err.to_string().contains("ghost"), "{err}");
    }

    #[test]
    fn unknown_registry_op_rejected() {
        let doc = r#"{
            "name": "bad",
            "stages": [{
                "name": "s", "kind": "per_chunk", "inputs": ["chunk"],
                "ops": [ { "op": "nope", "inputs": [ {"input": 0} ] } ],
                "outputs": [ {"op": "nope"} ]
            }]
        }"#;
        assert!(workflow_from_str(doc, reg()).is_err());
    }

    #[test]
    fn bad_kind_and_missing_inputs_rejected() {
        let doc = r#"{
            "name": "bad",
            "stages": [{
                "name": "s", "kind": "mapreduce", "inputs": ["chunk"],
                "ops": [], "outputs": []
            }]
        }"#;
        assert!(workflow_from_str(doc, reg()).is_err());
        let doc2 = r#"{
            "name": "bad",
            "stages": [{
                "name": "s", "kind": "per_chunk", "inputs": ["chunk"],
                "ops": [ { "op": "inc" } ],
                "outputs": []
            }]
        }"#;
        assert!(workflow_from_str(doc2, reg()).is_err());
    }

    #[test]
    fn upstream_by_name_resolves_order() {
        // referencing a later stage fails (must be defined earlier)
        let doc = r#"{
            "name": "bad",
            "stages": [{
                "name": "s", "kind": "per_chunk",
                "inputs": [ {"stage": "later", "output": 0} ],
                "ops": [ { "op": "inc", "inputs": [ {"input": 0} ] } ],
                "outputs": [ {"op": "inc"} ]
            }]
        }"#;
        let err = workflow_from_str(doc, reg()).unwrap_err();
        assert!(err.to_string().contains("later"), "{err}");
    }
}
