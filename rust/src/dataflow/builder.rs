//! Typed workflow construction: the [`OpRegistry`] + [`WorkflowBuilder`] API.
//!
//! The raw [`StageDef`]/[`OpDef`] structs wire operations together with
//! bare indices — easy to get wrong, and errors only surface at
//! `Workflow::validate()` (or worse, at runtime).  This module makes
//! workload definition a first-class, *eagerly validated* API:
//!
//! * an [`OpRegistry`] maps operation names to their [`FunctionVariant`]
//!   and performance profile (GPU speedup, transfer impact, CPU cost
//!   share) — one registration per logical operation, shared by every
//!   workflow, the scheduler, and the simulator;
//! * a [`WorkflowBuilder`] assembles stages from registered ops through
//!   typed handles: [`StageBuilder::add_op`] returns an [`OpHandle`],
//!   `handle.output(k)` names one of its outputs, and stages reference
//!   each other through [`StageHandle`]s instead of magic indices.
//!
//! Every wiring mistake — unknown op, duplicate name, out-of-range port,
//! backward reference, a PerChunk stage consuming a Reduce result — is
//! reported at the call that introduces it, with the offending names in
//! the message.  Reduce stages may chain (Reduce -> Reduce): the upstream
//! Reduce contributes a single completed instance to the downstream one.
//!
//! Workflows can also be described as data and loaded against a registry;
//! see [`super::json`].

use super::{FunctionVariant, OpDef, PortRef, StageDef, StageInput, StageKind, Workflow};
use crate::runtime::Value;
use crate::{Error, Result};
use std::collections::BTreeMap;
use std::sync::Arc;

/// Everything the runtime needs to know about one logical operation:
/// its function variant (CPU member + optional accelerator artifact), its
/// output arity, and its calibrated performance profile.
#[derive(Clone)]
pub struct OpSpec {
    pub name: String,
    pub variant: FunctionVariant,
    pub n_outputs: usize,
    /// Estimated GPU-vs-1-CPU-core speedup (paper Fig. 7; drives PATS).
    pub speedup: f32,
    /// Fraction of GPU execution time spent moving data (paper §IV-C).
    pub transfer_impact: f32,
    /// Fraction of single-core per-chunk CPU time this op accounts for
    /// (cost-model calibration; 0.0 when unknown).
    pub cpu_fraction: f64,
}

impl OpSpec {
    /// A CPU-only operation with a neutral profile.
    pub fn cpu(
        name: &str,
        n_outputs: usize,
        f: impl Fn(&[Value]) -> Result<Vec<Value>> + Send + Sync + 'static,
    ) -> Self {
        OpSpec {
            name: name.to_string(),
            variant: FunctionVariant::cpu_only(f),
            n_outputs,
            speedup: 1.0,
            transfer_impact: 0.0,
            cpu_fraction: 0.0,
        }
    }

    /// A CPU + accelerator operation (artifact named in the AOT manifest).
    pub fn hybrid(
        name: &str,
        n_outputs: usize,
        f: impl Fn(&[Value]) -> Result<Vec<Value>> + Send + Sync + 'static,
        artifact: &str,
    ) -> Self {
        OpSpec {
            name: name.to_string(),
            variant: FunctionVariant::hybrid(f, artifact),
            n_outputs,
            speedup: 1.0,
            transfer_impact: 0.0,
            cpu_fraction: 0.0,
        }
    }

    /// Attach the calibrated performance profile.
    pub fn with_profile(mut self, speedup: f32, transfer_impact: f32, cpu_fraction: f64) -> Self {
        self.speedup = speedup;
        self.transfer_impact = transfer_impact;
        self.cpu_fraction = cpu_fraction;
        self
    }
}

impl std::fmt::Debug for OpSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("OpSpec")
            .field("name", &self.name)
            .field("n_outputs", &self.n_outputs)
            .field("speedup", &self.speedup)
            .field("transfer_impact", &self.transfer_impact)
            .field("cpu_fraction", &self.cpu_fraction)
            .finish()
    }
}

/// Central operation registry: op name -> [`OpSpec`].
///
/// The registry is the single source of truth for function variants and
/// performance profiles.  Workflows (hand-built or JSON-loaded) reference
/// operations by name; the builder resolves them here.
#[derive(Clone, Default)]
pub struct OpRegistry {
    ops: BTreeMap<String, OpSpec>,
}

impl OpRegistry {
    pub fn new() -> Self {
        OpRegistry { ops: BTreeMap::new() }
    }

    /// Register an operation.  Duplicate names are rejected.
    pub fn register(&mut self, spec: OpSpec) -> Result<()> {
        if spec.name.is_empty() {
            return Err(Error::Dataflow("op name must be non-empty".into()));
        }
        if spec.n_outputs == 0 {
            return Err(Error::Dataflow(format!(
                "op '{}' must declare at least one output",
                spec.name
            )));
        }
        if self.ops.contains_key(&spec.name) {
            return Err(Error::Dataflow(format!(
                "op '{}' is already registered",
                spec.name
            )));
        }
        self.ops.insert(spec.name.clone(), spec);
        Ok(())
    }

    /// Convenience: register a CPU-only op with a neutral profile.
    pub fn register_cpu(
        &mut self,
        name: &str,
        n_outputs: usize,
        f: impl Fn(&[Value]) -> Result<Vec<Value>> + Send + Sync + 'static,
    ) -> Result<()> {
        self.register(OpSpec::cpu(name, n_outputs, f))
    }

    /// Look up an op, with a helpful error naming close alternatives.
    pub fn get(&self, name: &str) -> Result<&OpSpec> {
        self.ops.get(name).ok_or_else(|| {
            Error::Dataflow(format!(
                "op '{name}' is not registered (registry has: {})",
                self.ops.keys().cloned().collect::<Vec<_>>().join(", ")
            ))
        })
    }

    pub fn contains(&self, name: &str) -> bool {
        self.ops.contains_key(name)
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.ops.keys().map(|s| s.as_str())
    }

    pub fn len(&self) -> usize {
        self.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Fold another registry into this one (duplicate names rejected).
    pub fn merge(&mut self, other: OpRegistry) -> Result<()> {
        for (_, spec) in other.ops {
            self.register(spec)?;
        }
        Ok(())
    }

    /// Override static Fig. 7 profiles with measured estimates from a
    /// calibration [`ProfileStore`](crate::runtime::calibrate::ProfileStore).
    ///
    /// Ops the store has no measurable speedup for keep their static
    /// profile, so partial calibration degrades gracefully.  Returns how
    /// many registered ops were recalibrated.  Workflows built *after*
    /// this call carry the measured estimates into every `OpDef` (and so
    /// into PATS queue ordering and the DL decision rule).
    pub fn apply_profiles(&mut self, store: &crate::runtime::calibrate::ProfileStore) -> usize {
        let mut n = 0;
        for (name, spec) in self.ops.iter_mut() {
            if let Some(e) = store.estimate(name) {
                spec.speedup = e.speedup;
                if let Some(ti) = e.transfer_impact {
                    spec.transfer_impact = ti;
                }
                n += 1;
            }
        }
        n
    }
}

impl std::fmt::Debug for OpRegistry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("OpRegistry").field("ops", &self.ops.keys()).finish()
    }
}

/// A data source for one op input, expressed through typed references
/// instead of raw [`PortRef`] indices.
#[derive(Debug, Clone)]
pub enum PortSpec {
    /// The stage's k-th declared external input (from [`StageBuilder::input_chunk`]
    /// / [`StageBuilder::input_upstream`]).
    Input(usize),
    /// Output `output` of an earlier op in the same stage (from
    /// [`OpHandle::output`]).
    Output { op: usize, output: usize },
    /// A constant parameter baked into the workflow.
    Param(Value),
}

/// Shorthand for a scalar parameter port.
pub fn param(v: f32) -> PortSpec {
    PortSpec::Param(Value::Scalar(v))
}

/// Handle to an op added to a [`StageBuilder`]; names its outputs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OpHandle {
    op: usize,
    n_outputs: usize,
}

impl OpHandle {
    /// Reference this op's k-th output (bounds-checked when the reference
    /// is consumed by `add_op` / `export`).
    pub fn output(self, k: usize) -> PortSpec {
        PortSpec::Output { op: self.op, output: k }
    }

    /// Reference this op's first output.
    pub fn out(self) -> PortSpec {
        self.output(0)
    }

    /// Position of the op inside its stage's pipeline.
    pub fn index(self) -> usize {
        self.op
    }

    pub fn n_outputs(self) -> usize {
        self.n_outputs
    }
}

/// Handle to a stage added to a [`WorkflowBuilder`]; names its outputs for
/// downstream stages.
#[derive(Debug, Clone)]
pub struct StageHandle {
    idx: usize,
    name: String,
    n_outputs: usize,
}

impl StageHandle {
    /// Reference this stage's k-th exported output.
    pub fn output(&self, k: usize) -> UpstreamRef {
        UpstreamRef { stage: self.idx, output: k }
    }

    pub fn index(&self) -> usize {
        self.idx
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn n_outputs(&self) -> usize {
        self.n_outputs
    }
}

/// A reference to one output of an upstream stage.
#[derive(Debug, Clone, Copy)]
pub struct UpstreamRef {
    stage: usize,
    output: usize,
}

/// Builds one stage: declare external inputs, add registered ops wired
/// through handles, export outputs.  Finish with
/// [`WorkflowBuilder::add_stage`].
pub struct StageBuilder {
    name: String,
    kind: StageKind,
    registry: Arc<OpRegistry>,
    inputs: Vec<StageInput>,
    ops: Vec<OpDef>,
    outputs: Vec<PortRef>,
}

impl StageBuilder {
    fn new(name: &str, kind: StageKind, registry: Arc<OpRegistry>) -> Self {
        StageBuilder {
            name: name.to_string(),
            kind,
            registry,
            inputs: Vec::new(),
            ops: Vec::new(),
            outputs: Vec::new(),
        }
    }

    /// Declare a raw-chunk external input; returns the port to wire ops to.
    pub fn input_chunk(&mut self) -> PortSpec {
        self.inputs.push(StageInput::Chunk);
        PortSpec::Input(self.inputs.len() - 1)
    }

    /// Declare an external input drawing only part `part` of the chunk
    /// payload (multi-value chunk sources); the payload width is only
    /// known at run time, so the index is bounds-checked there.
    pub fn input_chunk_part(&mut self, part: usize) -> PortSpec {
        self.inputs.push(StageInput::ChunkPart(part));
        PortSpec::Input(self.inputs.len() - 1)
    }

    /// Declare an external input fed by an upstream stage's output.
    /// (Bounds on the upstream output are checked at `add_stage` time,
    /// when the upstream stage definition is in scope.)
    pub fn input_upstream(&mut self, from: UpstreamRef) -> PortSpec {
        self.inputs
            .push(StageInput::Upstream { stage: from.stage, output: from.output });
        PortSpec::Input(self.inputs.len() - 1)
    }

    fn resolve(&self, port: &PortSpec, ctx: &str) -> Result<PortRef> {
        match port {
            PortSpec::Input(k) => {
                if *k >= self.inputs.len() {
                    return Err(Error::Dataflow(format!(
                        "stage '{}': {ctx} references stage input {k} (stage declares {})",
                        self.name,
                        self.inputs.len()
                    )));
                }
                Ok(PortRef::StageInput(*k))
            }
            PortSpec::Output { op, output } => {
                let def = self.ops.get(*op).ok_or_else(|| {
                    Error::Dataflow(format!(
                        "stage '{}': {ctx} references op {op}, which is not an earlier op \
                         of this stage",
                        self.name
                    ))
                })?;
                if *output >= def.n_outputs {
                    return Err(Error::Dataflow(format!(
                        "stage '{}': {ctx} references output {output} of '{}' (has {})",
                        self.name, def.name, def.n_outputs
                    )));
                }
                Ok(PortRef::Op { op: *op, output: *output })
            }
            PortSpec::Param(v) => Ok(PortRef::Param(v.clone())),
        }
    }

    /// Append a registered op wired to `inputs`; the instance is named
    /// after the op.  Returns a handle for referencing its outputs.
    pub fn add_op(&mut self, op: &str, inputs: &[PortSpec]) -> Result<OpHandle> {
        self.add_op_as(op, op, inputs)
    }

    /// Append a registered op under an explicit instance name (required
    /// when the same op appears more than once in a stage).
    pub fn add_op_as(&mut self, instance: &str, op: &str, inputs: &[PortSpec]) -> Result<OpHandle> {
        let spec = self.registry.get(op)?.clone();
        if self.ops.iter().any(|o| o.name == instance) {
            return Err(Error::Dataflow(format!(
                "stage '{}': duplicate op instance name '{instance}' \
                 (use add_op_as to disambiguate repeated ops)",
                self.name
            )));
        }
        let mut resolved = Vec::with_capacity(inputs.len());
        for p in inputs {
            resolved.push(self.resolve(p, &format!("op '{instance}' input"))?);
        }
        if resolved.is_empty() {
            // The empty port list is the runtime's consume-all-stage-inputs
            // convention; require it to be requested explicitly.
            return Err(Error::Dataflow(format!(
                "stage '{}': op '{instance}' declares no inputs; use add_reduce_op for \
                 the consume-all-inputs convention",
                self.name
            )));
        }
        self.ops.push(OpDef {
            name: instance.to_string(),
            op: op.to_string(),
            variant: spec.variant.clone(),
            inputs: resolved,
            n_outputs: spec.n_outputs,
            speedup: spec.speedup,
            transfer_impact: spec.transfer_impact,
        });
        Ok(OpHandle { op: self.ops.len() - 1, n_outputs: spec.n_outputs })
    }

    /// Append a registered op that consumes *all* stage inputs (the Reduce
    /// convention: a Reduce instance receives one value per upstream chunk
    /// output, so its arity is only known at run time).
    pub fn add_reduce_op(&mut self, op: &str) -> Result<OpHandle> {
        if self.kind != StageKind::Reduce {
            return Err(Error::Dataflow(format!(
                "stage '{}': add_reduce_op (consume-all-inputs) is only valid in Reduce \
                 stages",
                self.name
            )));
        }
        let spec = self.registry.get(op)?.clone();
        if self.ops.iter().any(|o| o.name == op) {
            return Err(Error::Dataflow(format!(
                "stage '{}': duplicate op instance name '{op}'",
                self.name
            )));
        }
        self.ops.push(OpDef {
            name: op.to_string(),
            op: op.to_string(),
            variant: spec.variant.clone(),
            inputs: Vec::new(),
            n_outputs: spec.n_outputs,
            speedup: spec.speedup,
            transfer_impact: spec.transfer_impact,
        });
        Ok(OpHandle { op: self.ops.len() - 1, n_outputs: spec.n_outputs })
    }

    /// Export a port as the stage's next output; returns its output index.
    pub fn export(&mut self, port: PortSpec) -> Result<usize> {
        let r = self.resolve(&port, "stage output")?;
        self.outputs.push(r);
        Ok(self.outputs.len() - 1)
    }

    pub fn name(&self) -> &str {
        &self.name
    }
}

/// Assembles a validated [`Workflow`] from [`StageBuilder`]s.
pub struct WorkflowBuilder {
    name: String,
    registry: Arc<OpRegistry>,
    stages: Vec<StageDef>,
}

impl WorkflowBuilder {
    /// Start a workflow over an owned registry.
    pub fn new(name: &str, registry: OpRegistry) -> Self {
        Self::with_shared_registry(name, Arc::new(registry))
    }

    /// Start a workflow over a shared registry.
    pub fn with_shared_registry(name: &str, registry: Arc<OpRegistry>) -> Self {
        WorkflowBuilder { name: name.to_string(), registry, stages: Vec::new() }
    }

    pub fn registry(&self) -> Arc<OpRegistry> {
        self.registry.clone()
    }

    /// Open a new stage builder (attach it with [`WorkflowBuilder::add_stage`]).
    pub fn stage(&self, name: &str, kind: StageKind) -> StageBuilder {
        StageBuilder::new(name, kind, self.registry.clone())
    }

    /// Validate and append a finished stage; returns its handle.
    pub fn add_stage(&mut self, sb: StageBuilder) -> Result<StageHandle> {
        if self.stages.iter().any(|s| s.name == sb.name) {
            return Err(Error::Dataflow(format!("duplicate stage name '{}'", sb.name)));
        }
        if sb.ops.is_empty() {
            return Err(Error::Dataflow(format!("stage '{}' has no ops", sb.name)));
        }
        let mut has_upstream = false;
        for input in &sb.inputs {
            match input {
                StageInput::Chunk | StageInput::ChunkPart(_) => {
                    if sb.kind == StageKind::Reduce {
                        return Err(Error::Dataflow(format!(
                            "Reduce stage '{}' cannot take raw chunk inputs; it aggregates \
                             upstream outputs",
                            sb.name
                        )));
                    }
                }
                StageInput::Upstream { stage, output } => {
                    has_upstream = true;
                    let up = self.stages.get(*stage).ok_or_else(|| {
                        Error::Dataflow(format!(
                            "stage '{}' references unknown upstream stage {stage}",
                            sb.name
                        ))
                    })?;
                    if *output >= up.outputs.len() {
                        return Err(Error::Dataflow(format!(
                            "stage '{}' references output {output} of stage '{}' (has {})",
                            sb.name,
                            up.name,
                            up.outputs.len()
                        )));
                    }
                    if up.kind == StageKind::Reduce && sb.kind == StageKind::PerChunk {
                        return Err(Error::Dataflow(format!(
                            "PerChunk stage '{}' cannot consume Reduce stage '{}': a Reduce \
                             result is a single instance and per-chunk broadcast of it is \
                             not supported",
                            sb.name, up.name
                        )));
                    }
                }
            }
        }
        if sb.kind == StageKind::Reduce && !has_upstream {
            return Err(Error::Dataflow(format!(
                "Reduce stage '{}' must reference at least one upstream output \
                 (otherwise it would never become ready)",
                sb.name
            )));
        }
        let n_outputs = sb.outputs.len();
        let idx = self.stages.len();
        self.stages.push(StageDef {
            name: sb.name.clone(),
            kind: sb.kind,
            inputs: sb.inputs,
            ops: sb.ops,
            outputs: sb.outputs,
        });
        Ok(StageHandle { idx, name: sb.name, n_outputs })
    }

    /// Finish: run the full graph validation and hand back the workflow.
    pub fn build(self) -> Result<Workflow> {
        let wf = Workflow { name: self.name, stages: self.stages };
        wf.validate()?;
        Ok(wf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn identity(args: &[Value]) -> Result<Vec<Value>> {
        Ok(vec![args[0].clone()])
    }

    fn sum_all(args: &[Value]) -> Result<Vec<Value>> {
        let mut s = 0.0;
        for a in args {
            s += a.as_scalar()?;
        }
        Ok(vec![Value::Scalar(s)])
    }

    fn reg() -> OpRegistry {
        let mut r = OpRegistry::new();
        r.register(OpSpec::cpu("id", 1, identity).with_profile(2.0, 0.1, 0.5)).unwrap();
        r.register_cpu("sum", 1, sum_all).unwrap();
        r.register(OpSpec::cpu("fan2", 2, |args| {
            let v = args[0].as_scalar()?;
            Ok(vec![Value::Scalar(v), Value::Scalar(v * 10.0)])
        }))
        .unwrap();
        r
    }

    #[test]
    fn registry_rejects_duplicates_and_unknowns() {
        let mut r = reg();
        assert!(r.register_cpu("id", 1, identity).is_err());
        assert!(r.get("nope").is_err());
        assert!(r.contains("sum"));
        assert_eq!(r.len(), 3);
    }

    #[test]
    fn apply_profiles_overrides_measured_ops_only() {
        use crate::metrics::DeviceKind;
        use crate::runtime::calibrate::ProfileStore;
        use std::time::Duration;
        let mut r = reg();
        let mut store = ProfileStore::new(64);
        // "id" measured at 8x (vs static 2.0); "sum" left unmeasured
        store.record("id", DeviceKind::Cpu, Duration::from_millis(80));
        store.record("id", DeviceKind::Gpu, Duration::from_millis(10));
        store.record_transfer_impact("id", 0.2);
        assert_eq!(r.apply_profiles(&store), 1);
        assert!((r.get("id").unwrap().speedup - 8.0).abs() < 0.1);
        assert_eq!(r.get("id").unwrap().transfer_impact, 0.2);
        assert_eq!(r.get("sum").unwrap().speedup, 1.0, "unmeasured op keeps static profile");
        // workflows built after calibration carry the measured estimate
        let mut wb = WorkflowBuilder::new("t", r);
        let mut s = wb.stage("s", StageKind::PerChunk);
        let chunk = s.input_chunk();
        let a = s.add_op("id", &[chunk]).unwrap();
        s.export(a.out()).unwrap();
        wb.add_stage(s).unwrap();
        let wf = wb.build().unwrap();
        assert!((wf.stages[0].ops[0].speedup - 8.0).abs() < 0.1);
    }

    #[test]
    fn registry_merge_detects_collisions() {
        let mut a = reg();
        let mut b = OpRegistry::new();
        b.register_cpu("other", 1, identity).unwrap();
        a.merge(b).unwrap();
        assert!(a.contains("other"));
        let mut c = OpRegistry::new();
        c.register_cpu("sum", 1, sum_all).unwrap();
        assert!(a.merge(c).is_err());
    }

    #[test]
    fn builds_linear_stage() {
        let mut wb = WorkflowBuilder::new("t", reg());
        let mut s = wb.stage("s", StageKind::PerChunk);
        let chunk = s.input_chunk();
        let a = s.add_op("id", &[chunk]).unwrap();
        let b = s.add_op("sum", &[a.out(), param(10.0)]).unwrap();
        s.export(b.out()).unwrap();
        let h = wb.add_stage(s).unwrap();
        assert_eq!(h.index(), 0);
        assert_eq!(h.n_outputs(), 1);
        let wf = wb.build().unwrap();
        assert_eq!(wf.total_ops(), 2);
        assert_eq!(wf.stages[0].ops[0].speedup, 2.0);
        let out =
            super::super::run_stage_serial(&wf.stages[0], &[Value::Scalar(5.0)]).unwrap();
        assert_eq!(out[0].as_scalar().unwrap(), 15.0);
    }

    #[test]
    fn unknown_op_rejected_eagerly() {
        let wb = WorkflowBuilder::new("t", reg());
        let mut s = wb.stage("s", StageKind::PerChunk);
        let chunk = s.input_chunk();
        let err = s.add_op("nope", &[chunk]).unwrap_err();
        assert!(err.to_string().contains("not registered"), "{err}");
    }

    #[test]
    fn out_of_range_ports_rejected_eagerly() {
        let wb = WorkflowBuilder::new("t", reg());
        let mut s = wb.stage("s", StageKind::PerChunk);
        let _chunk = s.input_chunk();
        // stage input index out of range
        assert!(s.add_op("id", &[PortSpec::Input(3)]).is_err());
        let a = s.add_op("id", &[PortSpec::Input(0)]).unwrap();
        // op output index out of range
        assert!(s.add_op("id", &[a.output(1)]).is_err());
        // export of a bad port
        assert!(s.export(a.output(2)).is_err());
    }

    #[test]
    fn duplicate_instance_names_rejected() {
        let wb = WorkflowBuilder::new("t", reg());
        let mut s = wb.stage("s", StageKind::PerChunk);
        let chunk = s.input_chunk();
        s.add_op("id", &[chunk.clone()]).unwrap();
        assert!(s.add_op("id", &[chunk.clone()]).is_err());
        // explicit instance naming resolves the collision
        let second = s.add_op_as("id2", "id", &[chunk]).unwrap();
        assert_eq!(second.index(), 1);
    }

    #[test]
    fn duplicate_stage_names_rejected() {
        let mut wb = WorkflowBuilder::new("t", reg());
        let mut s = wb.stage("s", StageKind::PerChunk);
        let chunk = s.input_chunk();
        let a = s.add_op("id", &[chunk]).unwrap();
        s.export(a.out()).unwrap();
        wb.add_stage(s).unwrap();
        let mut s2 = wb.stage("s", StageKind::PerChunk);
        let chunk = s2.input_chunk();
        s2.add_op("id", &[chunk]).unwrap();
        assert!(wb.add_stage(s2).is_err());
    }

    #[test]
    fn upstream_refs_are_bounds_checked() {
        let mut wb = WorkflowBuilder::new("t", reg());
        let mut s = wb.stage("a", StageKind::PerChunk);
        let chunk = s.input_chunk();
        let op = s.add_op("id", &[chunk]).unwrap();
        s.export(op.out()).unwrap();
        let a = wb.add_stage(s).unwrap();
        // referencing output 1 of a 1-output stage fails at add_stage
        let mut s2 = wb.stage("b", StageKind::PerChunk);
        let inp = s2.input_upstream(a.output(1));
        s2.add_op("id", &[inp]).unwrap();
        assert!(wb.add_stage(s2).is_err());
    }

    #[test]
    fn chained_reduce_accepted() {
        // Reduce -> Reduce chains validate (the downstream Reduce aggregates
        // the single upstream Reduce instance); execution is covered by
        // coordinator::manager::tests::chained_reduce_aggregates.
        let mut wb = WorkflowBuilder::new("t", reg());
        let mut s = wb.stage("a", StageKind::PerChunk);
        let chunk = s.input_chunk();
        let op = s.add_op("id", &[chunk]).unwrap();
        s.export(op.out()).unwrap();
        let a = wb.add_stage(s).unwrap();

        let mut r1 = wb.stage("r1", StageKind::Reduce);
        r1.input_upstream(a.output(0));
        let op = r1.add_reduce_op("sum").unwrap();
        r1.export(op.out()).unwrap();
        let r1 = wb.add_stage(r1).unwrap();

        let mut r2 = wb.stage("r2", StageKind::Reduce);
        r2.input_upstream(r1.output(0));
        let op = r2.add_reduce_op("sum").unwrap();
        r2.export(op.out()).unwrap();
        wb.add_stage(r2).unwrap();
        let wf = wb.build().unwrap();
        assert_eq!(wf.stages.len(), 3);
        assert_eq!(wf.stages[2].kind, StageKind::Reduce);
    }

    #[test]
    fn per_chunk_on_reduce_rejected() {
        // broadcasting a Reduce result back out per chunk is not supported;
        // the mistake must surface at add_stage, not hang at run time
        let mut wb = WorkflowBuilder::new("t", reg());
        let mut s = wb.stage("a", StageKind::PerChunk);
        let chunk = s.input_chunk();
        let op = s.add_op("id", &[chunk]).unwrap();
        s.export(op.out()).unwrap();
        let a = wb.add_stage(s).unwrap();

        let mut r1 = wb.stage("r1", StageKind::Reduce);
        r1.input_upstream(a.output(0));
        let op = r1.add_reduce_op("sum").unwrap();
        r1.export(op.out()).unwrap();
        let r1 = wb.add_stage(r1).unwrap();

        let mut pc = wb.stage("broadcast", StageKind::PerChunk);
        let inp = pc.input_upstream(r1.output(0));
        pc.add_op("id", &[inp]).unwrap();
        let err = wb.add_stage(pc).unwrap_err();
        assert!(err.to_string().contains("cannot consume Reduce"), "{err}");
    }

    #[test]
    fn reduce_stage_requires_upstream_and_rejects_chunks() {
        let mut wb = WorkflowBuilder::new("t", reg());
        let mut r = wb.stage("r", StageKind::Reduce);
        r.add_reduce_op("sum").unwrap();
        assert!(wb.add_stage(r).is_err(), "reduce without upstream must fail");

        let mut r = wb.stage("r", StageKind::Reduce);
        r.input_chunk();
        r.add_reduce_op("sum").unwrap();
        assert!(wb.add_stage(r).is_err(), "reduce with chunk input must fail");
    }

    #[test]
    fn reduce_op_only_in_reduce_stages_and_empty_inputs_rejected() {
        let wb = WorkflowBuilder::new("t", reg());
        let mut s = wb.stage("s", StageKind::PerChunk);
        s.input_chunk();
        assert!(s.add_reduce_op("sum").is_err());
        assert!(s.add_op("sum", &[]).is_err(), "empty explicit inputs rejected");
    }

    #[test]
    fn multi_output_wiring() {
        let mut wb = WorkflowBuilder::new("t", reg());
        let mut s = wb.stage("s", StageKind::PerChunk);
        let chunk = s.input_chunk();
        let f = s.add_op("fan2", &[chunk]).unwrap();
        assert_eq!(f.n_outputs(), 2);
        let t = s.add_op("sum", &[f.output(0), f.output(1)]).unwrap();
        s.export(t.out()).unwrap();
        s.export(f.output(1)).unwrap();
        wb.add_stage(s).unwrap();
        let wf = wb.build().unwrap();
        let out =
            super::super::run_stage_serial(&wf.stages[0], &[Value::Scalar(3.0)]).unwrap();
        assert_eq!(out[0].as_scalar().unwrap(), 33.0);
        assert_eq!(out[1].as_scalar().unwrap(), 30.0);
    }
}
