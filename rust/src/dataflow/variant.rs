//! Function variants (paper §III-A): one logical operation, multiple
//! device-specific implementations.

use crate::runtime::Value;
use crate::Result;
use std::sync::Arc;

/// CPU implementation: a pure function over host values.
pub type CpuFn = Arc<dyn Fn(&[Value]) -> Result<Vec<Value>> + Send + Sync>;

/// A function variant: CPU closure + optional accelerator artifact.
///
/// The accelerator member is *named*, not held: PJRT state is per device
/// thread, so the GPU controller resolves the name against its own
/// [`DeviceExecutor`](crate::runtime::DeviceExecutor) at execution time.
/// Names of the form `@stage:<name>` refer to fused whole-stage artifacts
/// (used by monolithic workflows) and are resolved by the executor's
/// binding table.
#[derive(Clone)]
pub struct FunctionVariant {
    pub cpu: CpuFn,
    pub gpu_artifact: Option<String>,
}

impl FunctionVariant {
    /// CPU-only variant.
    pub fn cpu_only(f: impl Fn(&[Value]) -> Result<Vec<Value>> + Send + Sync + 'static) -> Self {
        FunctionVariant { cpu: Arc::new(f), gpu_artifact: None }
    }

    /// CPU + accelerator variant.
    pub fn hybrid(
        f: impl Fn(&[Value]) -> Result<Vec<Value>> + Send + Sync + 'static,
        artifact: &str,
    ) -> Self {
        FunctionVariant { cpu: Arc::new(f), gpu_artifact: Some(artifact.to_string()) }
    }

    pub fn has_gpu(&self) -> bool {
        self.gpu_artifact.is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors() {
        let v = FunctionVariant::cpu_only(|args| Ok(args.to_vec()));
        assert!(!v.has_gpu());
        let h = FunctionVariant::hybrid(|args| Ok(args.to_vec()), "morph_open");
        assert_eq!(h.gpu_artifact.as_deref(), Some("morph_open"));
        let out = (h.cpu)(&[Value::Scalar(1.0)]).unwrap();
        assert_eq!(out.len(), 1);
    }
}
