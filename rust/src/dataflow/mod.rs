//! Hierarchical dataflow model (paper §III-A).
//!
//! An analysis application is an **abstract workflow**: a DAG of
//! coarse-grain *stages* connected by logical streams.  Each stage is
//! itself a pipeline of fine-grain *operations* — the two-level hierarchy
//! of Fig. 2.  Binding a stage to an input data chunk yields a *stage
//! instance* `(chunk, stage)`; instantiating its operations yields
//! *operation instances* `(data, op)` — the units the Worker Resource
//! Manager schedules onto CPU cores and GPUs.
//!
//! Every operation is bound to a **function variant** (paper §III-A,
//! citing Merge/practical predicate dispatch): a CPU closure over host
//! tensors plus, optionally, the name of an AOT artifact executed through
//! PJRT as the accelerator implementation.  The WRM picks the member of
//! the variant that matches the device claiming the task.

pub mod builder;
pub mod json;
pub mod variant;

pub use builder::{
    param, OpHandle, OpRegistry, OpSpec, PortSpec, StageBuilder, StageHandle, UpstreamRef,
    WorkflowBuilder,
};
pub use json::{workflow_from_file, workflow_from_json, workflow_from_str, workflow_to_json};
pub use variant::{CpuFn, FunctionVariant};

use crate::runtime::Value;
use crate::{Error, Result};

/// Where an operation input comes from.
#[derive(Debug, Clone)]
pub enum PortRef {
    /// The stage instance's k-th external input (a chunk payload or an
    /// upstream stage output routed by the Manager).
    StageInput(usize),
    /// Output `output` of fine-grain operation `op` in the same stage.
    Op { op: usize, output: usize },
    /// A constant parameter baked into the workflow (thresholds etc.).
    Param(Value),
}

/// A fine-grain operation inside a stage (second hierarchy level).
///
/// Constructed through [`builder::WorkflowBuilder`] (or internally); the
/// raw struct stays public so the coordinator and simulator can *read*
/// wiring, but consumers should not assemble it by hand.
#[derive(Clone)]
pub struct OpDef {
    /// Instance name, unique within the stage (metrics / diagnostics key).
    pub name: String,
    /// Registry op name this instance was drawn from (equals `name` for
    /// ad-hoc ops); keys profile lookups and JSON serialisation.
    pub op: String,
    pub variant: FunctionVariant,
    pub inputs: Vec<PortRef>,
    pub n_outputs: usize,
    /// Estimated GPU-vs-1-CPU-core speedup (paper Fig. 7; drives PATS).
    pub speedup: f32,
    /// Fraction of GPU execution time spent moving data (paper §IV-C).
    pub transfer_impact: f32,
}

impl std::fmt::Debug for OpDef {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("OpDef")
            .field("name", &self.name)
            .field("inputs", &self.inputs.len())
            .field("n_outputs", &self.n_outputs)
            .field("speedup", &self.speedup)
            .finish()
    }
}

/// How a stage consumes data (paper Fig. 3's two instantiation styles).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StageKind {
    /// One instance per data chunk (bag-of-tasks replication).
    PerChunk,
    /// One instance consuming the outputs of *all* instances of the
    /// upstream stage (the "computation involving intermediary results from
    /// multiple inputs" instantiation — used by the classification stage).
    /// A Reduce may consume another Reduce, which contributes exactly one
    /// upstream instance; what it cannot feed is a PerChunk stage.
    Reduce,
}

/// Where a stage's external inputs come from.
#[derive(Debug, Clone)]
pub enum StageInput {
    /// The raw data chunk (e.g. the RGB tile): every payload value.
    Chunk,
    /// One value of the chunk payload, by index.  Chunk sources may yield
    /// multi-value payloads (e.g. image + mask); a stage can select just
    /// the part it consumes (JSON: `{"chunk": k}`).
    ChunkPart(usize),
    /// Output `output` of upstream stage `stage` (same chunk for PerChunk
    /// stages; concatenated over all chunks for Reduce stages).
    Upstream { stage: usize, output: usize },
}

/// A coarse-grain stage (first hierarchy level).
#[derive(Debug, Clone)]
pub struct StageDef {
    pub name: String,
    pub kind: StageKind,
    pub inputs: Vec<StageInput>,
    /// Fine-grain pipeline (must be in a valid topological order).
    pub ops: Vec<OpDef>,
    /// Which op outputs are exported as the stage's outputs.
    pub outputs: Vec<PortRef>,
}

/// An abstract workflow: the DAG of stages.
#[derive(Debug, Clone, Default)]
pub struct Workflow {
    pub name: String,
    pub stages: Vec<StageDef>,
}

impl Workflow {
    pub fn new(name: &str) -> Self {
        Workflow { name: name.to_string(), stages: Vec::new() }
    }

    /// Append a stage, returning its index.
    pub fn add_stage(&mut self, stage: StageDef) -> usize {
        self.stages.push(stage);
        self.stages.len() - 1
    }

    /// Index of the stage named `name`.
    pub fn stage_index(&self, name: &str) -> Option<usize> {
        self.stages.iter().position(|s| s.name == name)
    }

    /// The stage named `name`.
    pub fn stage_named(&self, name: &str) -> Option<&StageDef> {
        self.stage_index(name).map(|i| &self.stages[i])
    }

    /// Upstream stage indices of stage `s`.
    pub fn upstream_of(&self, s: usize) -> Vec<usize> {
        let mut ups: Vec<usize> = self.stages[s]
            .inputs
            .iter()
            .filter_map(|i| match i {
                StageInput::Upstream { stage, .. } => Some(*stage),
                StageInput::Chunk | StageInput::ChunkPart(_) => None,
            })
            .collect();
        ups.sort_unstable();
        ups.dedup();
        ups
    }

    /// Validate the graph: stage deps acyclic + forward-only, op inputs
    /// reference earlier ops only (pipeline order is topological), port
    /// indices in range.
    pub fn validate(&self) -> Result<()> {
        for (si, stage) in self.stages.iter().enumerate() {
            if self.stages[..si].iter().any(|s| s.name == stage.name) {
                return Err(Error::Dataflow(format!("duplicate stage name '{}'", stage.name)));
            }
            for input in &stage.inputs {
                if let StageInput::Upstream { stage: up, .. } = input {
                    if *up >= si {
                        return Err(Error::Dataflow(format!(
                            "stage '{}' depends on stage {up} which is not earlier",
                            stage.name
                        )));
                    }
                    if self.stages[*up].kind == StageKind::Reduce
                        && stage.kind == StageKind::PerChunk
                    {
                        return Err(Error::Dataflow(format!(
                            "PerChunk stage '{}' cannot consume Reduce stage '{}': \
                             per-chunk broadcast of a Reduce result is not supported",
                            stage.name, self.stages[*up].name
                        )));
                    }
                }
            }
            if stage.ops.is_empty() {
                return Err(Error::Dataflow(format!("stage '{}' has no ops", stage.name)));
            }
            for (oi, op) in stage.ops.iter().enumerate() {
                if op.n_outputs == 0 {
                    return Err(Error::Dataflow(format!(
                        "op '{}' declares zero outputs",
                        op.name
                    )));
                }
                if stage.ops[..oi].iter().any(|o| o.name == op.name) {
                    return Err(Error::Dataflow(format!(
                        "stage '{}': duplicate op name '{}'",
                        stage.name, op.name
                    )));
                }
                for port in &op.inputs {
                    match port {
                        PortRef::Op { op: src, output } => {
                            if *src >= oi {
                                return Err(Error::Dataflow(format!(
                                    "op '{}' input references op {src} not earlier in the pipeline",
                                    op.name
                                )));
                            }
                            if *output >= stage.ops[*src].n_outputs {
                                return Err(Error::Dataflow(format!(
                                    "op '{}' references output {output} of '{}' (has {})",
                                    op.name,
                                    stage.ops[*src].name,
                                    stage.ops[*src].n_outputs
                                )));
                            }
                        }
                        // Both stage kinds are bounds-checked.  A Reduce
                        // instance receives >= one value per declared
                        // upstream ref at run time (n_chunks >= 1), so any
                        // k within the declared inputs is always
                        // resolvable; ops that want the full dynamic input
                        // set use the empty-port-list convention instead.
                        PortRef::StageInput(k) => {
                            if *k >= stage.inputs.len() {
                                return Err(Error::Dataflow(format!(
                                    "op '{}' references stage input {k} (stage has {})",
                                    op.name,
                                    stage.inputs.len()
                                )));
                            }
                        }
                        PortRef::Param(_) => {}
                    }
                }
            }
            for port in &stage.outputs {
                if let PortRef::Op { op, output } = port {
                    if *op >= stage.ops.len() || *output >= stage.ops[*op].n_outputs {
                        return Err(Error::Dataflow(format!(
                            "stage '{}' output references invalid port",
                            stage.name
                        )));
                    }
                }
            }
        }
        Ok(())
    }

    /// Total fine-grain op count across stages (metrics / tests).
    pub fn total_ops(&self) -> usize {
        self.stages.iter().map(|s| s.ops.len()).sum()
    }

    /// Fold a whole stage's pipeline into a single monolithic op — the
    /// *non-pipelined* version of the paper's Fig. 9 comparison.  The
    /// resulting stage has one op that runs the entire chain on one device;
    /// its speedup estimate is the time-weighted blend passed per stage.
    pub fn monolithic(&self, speedups: &[f32]) -> Result<Workflow> {
        if speedups.len() != self.stages.len() {
            return Err(Error::Dataflow("need one blended speedup per stage".into()));
        }
        let mut out = Workflow::new(&format!("{}-monolithic", self.name));
        for (stage, &speedup) in self.stages.iter().zip(speedups) {
            let inner = stage.clone();
            let n_outputs = stage.outputs.len().max(1);
            let n_inputs = stage.inputs.len();
            let cpu_chain: CpuFn = {
                let inner = inner.clone();
                std::sync::Arc::new(move |inputs: &[Value]| run_stage_serial(&inner, inputs))
            };
            // A monolithic stage can only run on the accelerator if a fused
            // artifact exists for the whole chain; the executor resolves the
            // "@stage:<name>" tag against the manifest (e.g. segment_tile).
            let all_gpu = inner.ops.iter().all(|o| o.variant.gpu_artifact.is_some());
            out.add_stage(StageDef {
                name: stage.name.clone(),
                kind: stage.kind,
                inputs: stage.inputs.clone(),
                ops: vec![OpDef {
                    name: format!("{}-monolith", stage.name),
                    op: format!("{}-monolith", stage.name),
                    variant: FunctionVariant {
                        cpu: cpu_chain,
                        gpu_artifact: if all_gpu {
                            Some(format!("@stage:{}", stage.name))
                        } else {
                            None
                        },
                    },
                    inputs: (0..n_inputs).map(PortRef::StageInput).collect(),
                    n_outputs,
                    speedup,
                    transfer_impact: 0.1,
                }],
                outputs: (0..n_outputs).map(|o| PortRef::Op { op: 0, output: o }).collect(),
            });
        }
        Ok(out)
    }
}

/// Assemble one op's argument list from its stage's external inputs and
/// the outputs produced by earlier ops.
///
/// Convention: an op with no declared ports consumes ALL stage inputs
/// (needed by Reduce stages, whose input arity is dynamic).  The serial
/// runner and the calibration microbenchmarks share this helper so the
/// convention cannot drift between them; the WRM implements the same
/// rules over its sparse `Option<Vec<Value>>` storage
/// (`wrm::Wrm::gather_host_inputs`).
pub fn gather_op_inputs(
    op: &OpDef,
    stage_inputs: &[Value],
    produced: &[Vec<Value>],
) -> Result<Vec<Value>> {
    let mut args: Vec<Value> = Vec::with_capacity(op.inputs.len().max(stage_inputs.len()));
    if op.inputs.is_empty() {
        args.extend_from_slice(stage_inputs);
    }
    for port in &op.inputs {
        args.push(resolve_port(port, stage_inputs, produced)?);
    }
    Ok(args)
}

/// Run one stage's fine-grain pipeline serially on the calling thread with
/// the CPU variants.  Used by monolithic stages and as a test oracle for
/// the concurrent WRM execution.
pub fn run_stage_serial(stage: &StageDef, inputs: &[Value]) -> Result<Vec<Value>> {
    let mut produced: Vec<Vec<Value>> = Vec::with_capacity(stage.ops.len());
    for op in &stage.ops {
        let args = gather_op_inputs(op, inputs, &produced)?;
        let outs = (op.variant.cpu)(&args)?;
        if outs.len() != op.n_outputs {
            return Err(Error::Dataflow(format!(
                "op '{}' produced {} outputs, declared {}",
                op.name,
                outs.len(),
                op.n_outputs
            )));
        }
        produced.push(outs);
    }
    stage
        .outputs
        .iter()
        .map(|p| resolve_port(p, inputs, &produced))
        .collect()
}

/// Resolve a port reference against stage inputs + already-produced values.
pub fn resolve_port(
    port: &PortRef,
    stage_inputs: &[Value],
    produced: &[Vec<Value>],
) -> Result<Value> {
    match port {
        PortRef::StageInput(k) => stage_inputs
            .get(*k)
            .cloned()
            .ok_or_else(|| Error::Dataflow(format!("missing stage input {k}"))),
        PortRef::Op { op, output } => produced
            .get(*op)
            .and_then(|outs| outs.get(*output))
            .cloned()
            .ok_or_else(|| Error::Dataflow(format!("missing op output {op}:{output}"))),
        PortRef::Param(v) => Ok(v.clone()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn passthrough(name: &str, inputs: Vec<PortRef>) -> OpDef {
        OpDef {
            name: name.into(),
            op: name.into(),
            variant: FunctionVariant {
                cpu: Arc::new(|args: &[Value]| Ok(vec![args[0].clone()])),
                gpu_artifact: None,
            },
            inputs,
            n_outputs: 1,
            speedup: 1.0,
            transfer_impact: 0.0,
        }
    }

    fn adder(name: &str, inputs: Vec<PortRef>) -> OpDef {
        OpDef {
            name: name.into(),
            op: name.into(),
            variant: FunctionVariant {
                cpu: Arc::new(|args: &[Value]| {
                    let s = args.iter().map(|v| v.as_scalar().unwrap()).sum();
                    Ok(vec![Value::Scalar(s)])
                }),
                gpu_artifact: None,
            },
            inputs,
            n_outputs: 1,
            speedup: 2.0,
            transfer_impact: 0.1,
        }
    }

    fn small_stage() -> StageDef {
        StageDef {
            name: "s".into(),
            kind: StageKind::PerChunk,
            inputs: vec![StageInput::Chunk],
            ops: vec![
                passthrough("a", vec![PortRef::StageInput(0)]),
                adder(
                    "b",
                    vec![PortRef::Op { op: 0, output: 0 }, PortRef::Param(Value::Scalar(10.0))],
                ),
            ],
            outputs: vec![PortRef::Op { op: 1, output: 0 }],
        }
    }

    #[test]
    fn valid_workflow_passes() {
        let mut w = Workflow::new("t");
        w.add_stage(small_stage());
        w.validate().unwrap();
        assert_eq!(w.total_ops(), 2);
    }

    #[test]
    fn forward_reference_rejected() {
        let mut stage = small_stage();
        stage.ops[0].inputs = vec![PortRef::Op { op: 1, output: 0 }];
        let mut w = Workflow::new("t");
        w.add_stage(stage);
        assert!(w.validate().is_err());
    }

    #[test]
    fn bad_output_port_rejected() {
        let mut stage = small_stage();
        stage.outputs = vec![PortRef::Op { op: 1, output: 3 }];
        let mut w = Workflow::new("t");
        w.add_stage(stage);
        assert!(w.validate().is_err());
    }

    #[test]
    fn stage_dependency_must_be_earlier() {
        let mut w = Workflow::new("t");
        let mut s0 = small_stage();
        s0.inputs = vec![StageInput::Upstream { stage: 1, output: 0 }];
        w.add_stage(s0);
        w.add_stage(small_stage());
        assert!(w.validate().is_err());
    }

    #[test]
    fn reduce_stage_input_bounds_checked() {
        // Regression: StageInput bounds used to be checked only for
        // PerChunk stages, so a Reduce stage could reference a nonexistent
        // stage input and fail at runtime instead of validation.
        let mut w = Workflow::new("t");
        w.add_stage(small_stage());
        let mut red = small_stage();
        red.name = "r".into();
        red.kind = StageKind::Reduce;
        red.inputs = vec![StageInput::Upstream { stage: 0, output: 0 }];
        red.ops[0].inputs = vec![PortRef::StageInput(3)];
        w.add_stage(red);
        let err = w.validate().unwrap_err();
        assert!(err.to_string().contains("stage input 3"), "{err}");

        // an in-range reference on a Reduce stage still validates
        let mut w = Workflow::new("t");
        w.add_stage(small_stage());
        let mut red = small_stage();
        red.name = "r".into();
        red.kind = StageKind::Reduce;
        red.inputs = vec![StageInput::Upstream { stage: 0, output: 0 }];
        w.add_stage(red);
        w.validate().unwrap();
    }

    #[test]
    fn reduce_chain_validates_but_broadcast_rejected() {
        // Reduce -> Reduce is a valid chain...
        let mut w = Workflow::new("t");
        w.add_stage(small_stage());
        let mut r1 = small_stage();
        r1.name = "r1".into();
        r1.kind = StageKind::Reduce;
        r1.inputs = vec![StageInput::Upstream { stage: 0, output: 0 }];
        w.add_stage(r1.clone());
        let mut r2 = small_stage();
        r2.name = "r2".into();
        r2.kind = StageKind::Reduce;
        r2.inputs = vec![StageInput::Upstream { stage: 1, output: 0 }];
        w.add_stage(r2);
        w.validate().unwrap();

        // ...but a PerChunk stage consuming a Reduce result is not
        let mut w = Workflow::new("t");
        w.add_stage(small_stage());
        w.add_stage(r1);
        let mut pc = small_stage();
        pc.name = "broadcast".into();
        pc.inputs = vec![StageInput::Upstream { stage: 1, output: 0 }];
        w.add_stage(pc);
        let err = w.validate().unwrap_err();
        assert!(err.to_string().contains("cannot consume Reduce"), "{err}");
    }

    #[test]
    fn duplicate_names_rejected() {
        let mut w = Workflow::new("t");
        w.add_stage(small_stage());
        w.add_stage(small_stage()); // same stage name "s"
        assert!(w.validate().unwrap_err().to_string().contains("duplicate stage"));

        let mut stage = small_stage();
        stage.ops[1].name = "a".into(); // collides with ops[0]
        let mut w = Workflow::new("t");
        w.add_stage(stage);
        assert!(w.validate().unwrap_err().to_string().contains("duplicate op"));
    }

    #[test]
    fn stage_lookup_by_name() {
        let mut w = Workflow::new("t");
        w.add_stage(small_stage());
        assert_eq!(w.stage_index("s"), Some(0));
        assert_eq!(w.stage_index("nope"), None);
        assert_eq!(w.stage_named("s").unwrap().ops.len(), 2);
    }

    #[test]
    fn serial_execution_resolves_ports() {
        let stage = small_stage();
        let out = run_stage_serial(&stage, &[Value::Scalar(5.0)]).unwrap();
        assert_eq!(out[0].as_scalar().unwrap(), 15.0);
    }

    #[test]
    fn upstream_listing() {
        let mut w = Workflow::new("t");
        w.add_stage(small_stage());
        let mut s1 = small_stage();
        s1.inputs = vec![StageInput::Chunk, StageInput::Upstream { stage: 0, output: 0 }];
        let i = w.add_stage(s1);
        assert_eq!(w.upstream_of(i), vec![0]);
        assert_eq!(w.upstream_of(0), Vec::<usize>::new());
    }

    #[test]
    fn monolithic_folds_ops() {
        let mut w = Workflow::new("t");
        w.add_stage(small_stage());
        let m = w.monolithic(&[3.0]).unwrap();
        m.validate().unwrap();
        assert_eq!(m.total_ops(), 1);
        let out = run_stage_serial(&m.stages[0], &[Value::Scalar(1.0)]).unwrap();
        assert_eq!(out[0].as_scalar().unwrap(), 11.0);
        assert_eq!(m.stages[0].ops[0].speedup, 3.0);
    }

    #[test]
    fn wrong_output_arity_detected() {
        let mut stage = small_stage();
        stage.ops[1].n_outputs = 2; // lies about its arity
        let err = run_stage_serial(&stage, &[Value::Scalar(0.0)]);
        assert!(err.is_err());
    }
}
