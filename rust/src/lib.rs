//! # htap — High-Throughput Hierarchical Analysis Pipelines on Hybrid Clusters
//!
//! A reproduction of Teodoro et al., *"High-throughput Execution of
//! Hierarchical Analysis Pipelines on Hybrid Cluster Platforms"* (2012).
//!
//! The crate implements the paper's runtime middleware in three layers:
//!
//! * **Coordinator** ([`coordinator`]) — the paper's contribution: a
//!   Manager/Worker, demand-driven, window-based bag-of-tasks layer combined
//!   with a coarse-grain dataflow layer inside each node (the Worker Resource
//!   Manager), with the PATS / FCFS schedulers, data-locality-conscious
//!   assignment, prefetching and architecture-aware thread placement.
//! * **Dataflow model** ([`dataflow`]) — hierarchical two-level pipelines
//!   (coarse-grain stages made of fine-grain operations), abstract vs
//!   concrete workflows, and *function variants* (CPU + accelerator
//!   implementations of each operation).
//! * **Compute substrate** — [`imgproc`] holds the CPU variants of every
//!   operation in the paper's Fig. 1 WSI pipeline; [`runtime`] loads the
//!   AOT-compiled JAX/Pallas artifacts (`artifacts/*.hlo.txt`) through PJRT
//!   and serves as the "GPU" side of each function variant.
//!
//! Cluster-scale behaviour (the paper's 100-node Keeneland runs) is
//! reproduced by a calibrated discrete-event simulator ([`sim`]) that runs
//! the *same* scheduler implementations against the measured cost model, and
//! by a TCP Manager/Worker transport ([`net`]) standing in for MPI.
//!
//! Chunk payloads flow through the **data-staging subsystem**
//! ([`data::staging`]): pluggable chunk sources, a worker-side staging
//! cache whose prefetcher overlaps shared-filesystem reads with compute,
//! and a manager-side chunk catalog driving locality-aware assignment —
//! the paper's two cluster-level data optimisations (§III).
//!
//! Membership is **elastic and crash-tolerant**: workers join, heartbeat
//! and leave mid-run (`Hello`/`Heartbeat`/`Goodbye`), a lease sweeper
//! expires silent workers and re-issues their in-flight work, the
//! Manager journals completions into a periodic checkpoint
//! (`--checkpoint-dir` / `--resume`), and a restarted worker recovers
//! its local-disk spill tier (`--warm-restart`).  Because chunk sources
//! are deterministic, ops are pure, and Reduce accumulates in chunk
//! order, re-execution after any of these failures is bit-identical.
//! The failure-mode matrix lives in `docs/architecture.md`; operator
//! guidance in `docs/operations.md`.

pub mod app;
pub mod bench_util;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod dataflow;
pub mod error;
pub mod faults;
pub mod imgproc;
pub mod metrics;
pub mod net;
pub mod obs;
pub mod runtime;
pub mod service;
pub mod sim;
pub mod testing;

pub use error::{Error, Result};
