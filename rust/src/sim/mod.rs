//! Calibrated discrete-event simulator of the middleware at cluster scale.
//!
//! The paper's evaluation runs on 120-node Keeneland; this machine has one
//! core.  The simulator replays the *same scheduling code*
//! (`coordinator::sched::OpScheduler` — FCFS/PATS/DL are the production
//! implementations) against a cost model calibrated from the Fig. 7
//! profile (`app::profile`), the Fig. 6 topology
//! (`coordinator::placement::NodeTopology`) and a Lustre contention model,
//! reproducing the shapes of Figs. 8, 9, 10, 11, 12, 13, 14 and Table II.
//!
//! Cost model (per fine-grain op on a tile):
//!
//! * CPU time = `cpu_fraction * t_cpu_tile * jitter(chunk, op) * memory
//!   contention(active cpu threads)` — the contention term reproduces the
//!   paper's sub-linear 12-core speedup (~9x, "high memory bandwidth
//!   requirements").
//! * GPU compute = CPU time / true speedup; GPU transfer = compute *
//!   ti/(1-ti) * link factor(placement).  DL-resident inputs cut the
//!   transfer to its download share; prefetch overlaps transfer with
//!   compute (`max` instead of `+`).
//! * Tile fetch (Lustre) = `tile_io_base * (1 + io_contention*(nodes-1))`,
//!   the shared-filesystem client-scaling penalty the paper blames for the
//!   77% scaling efficiency at 100 nodes.
//! * Chunk-catalog locality (the staging subsystem): with
//!   `chunk_locality` off, a tile's repeat stage lands on an arbitrary
//!   node and pays a cold 2x re-read before it can start — the offline
//!   Fig. 8-style locality-on/off comparison (`htap sim --no-locality`).
//! * Steal replication (the tiered-storage subsystem): even with locality
//!   on, load imbalance steals a fraction of repeat stages
//!   (`steal_rate`).  With `replication` on the Manager's replicate hint
//!   lets the thief prefetch the stolen chunk through its scheduled read
//!   stream (1x contended read); with `--no-replication` the thief pays
//!   the cold unscheduled 2x re-read — `SimResult::cold_rereads` counts
//!   those, the steal-driven re-reads replication is there to remove.

pub mod experiments;

use crate::config::{Placement, Policy};
use crate::coordinator::placement::NodeTopology;
use crate::coordinator::sched::{make_scheduler, OpScheduler, ReadyTask};
use crate::dataflow::OpRegistry;
use crate::metrics::DeviceKind;
use crate::obs::{EventKind, Name, TraceEvent, DEV_CPU, DEV_GPU};
use crate::runtime::calibrate::ProfileStore;
use crate::testing::Rng;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

/// One fine-grain operation of the simulated workflow.
#[derive(Debug, Clone)]
pub struct SimOp {
    pub name: String,
    /// fraction of single-core tile time
    pub cpu_fraction: f64,
    /// true GPU-vs-CPU speedup (cost model)
    pub speedup_true: f32,
    /// estimate visible to the scheduler (Fig. 13 perturbs this)
    pub speedup_est: f32,
    pub transfer_impact: f32,
    /// whether an accelerator implementation exists
    pub has_gpu: bool,
    /// indices of producer ops within the stage
    pub deps: Vec<usize>,
}

/// A simulated stage: a DAG of ops (stage 0 = segmentation, 1 = features).
#[derive(Debug, Clone)]
pub struct SimStage {
    pub name: String,
    pub ops: Vec<SimOp>,
}

/// The simulated two-level workflow.
#[derive(Debug, Clone)]
pub struct SimWorkflow {
    pub stages: Vec<SimStage>,
}

impl SimWorkflow {
    /// Derive a simulated workflow from a real (builder-built) [`Workflow`]:
    /// op wiring comes from the dataflow graph, calibrated costs from the
    /// [`OpRegistry`] the workflow was built against.  `Reduce` stages are
    /// skipped — the simulator models the per-chunk pipeline (the paper's
    /// evaluation predates the MapReduce classification stage).
    pub fn from_workflow(wf: &crate::dataflow::Workflow, registry: &OpRegistry) -> Self {
        Self::from_workflow_inner(wf, registry, None)
    }

    /// Like [`SimWorkflow::from_workflow`], but calibrated from a measured
    /// [`ProfileStore`]: measured speedups/transfer impacts replace the
    /// static Fig. 7 values (both the cost-model truth and the scheduler
    /// estimate — the store describes *this* host), and when every op has
    /// a measured CPU time the per-op cost fractions are renormalised from
    /// those measurements instead of the static table.  This is the same
    /// store `OpRegistry::apply_profiles` and the WRM consume.
    pub fn from_workflow_profiled(
        wf: &crate::dataflow::Workflow,
        registry: &OpRegistry,
        store: &ProfileStore,
    ) -> Self {
        Self::from_workflow_inner(wf, registry, Some(store))
    }

    /// The WSI pipeline calibrated from measured profiles.
    pub fn pipelined_profiled(store: &ProfileStore) -> Self {
        let registry = crate::app::registry();
        let wf = crate::app::build_workflow(&crate::app::AppParams::for_tile_size(64), false);
        Self::from_workflow_profiled(&wf, &registry, store)
    }

    fn from_workflow_inner(
        wf: &crate::dataflow::Workflow,
        registry: &OpRegistry,
        store: Option<&ProfileStore>,
    ) -> Self {
        let stages: Vec<SimStage> = wf
            .stages
            .iter()
            .filter(|s| s.kind == crate::dataflow::StageKind::PerChunk)
            .map(|s| SimStage {
                name: s.name.clone(),
                ops: s
                    .ops
                    .iter()
                    .map(|o| {
                        let cpu_fraction =
                            registry.get(&o.op).map(|spec| spec.cpu_fraction).unwrap_or(0.0);
                        let mut deps: Vec<usize> = o
                            .inputs
                            .iter()
                            .filter_map(|p| match p {
                                crate::dataflow::PortRef::Op { op, .. } => Some(*op),
                                _ => None,
                            })
                            .collect();
                        deps.sort_unstable();
                        deps.dedup();
                        let (speedup, ti) = match store.and_then(|st| st.estimate(&o.op)) {
                            Some(e) => {
                                (e.speedup, e.transfer_impact.unwrap_or(o.transfer_impact))
                            }
                            None => (o.speedup, o.transfer_impact),
                        };
                        SimOp {
                            name: o.name.clone(),
                            cpu_fraction,
                            speedup_true: speedup,
                            speedup_est: speedup,
                            transfer_impact: ti,
                            has_gpu: o.variant.gpu_artifact.is_some(),
                            deps,
                        }
                    })
                    .collect(),
            })
            .collect();
        let mut out = SimWorkflow { stages };
        // measured cost fractions: only when the store covers every op, so
        // a partially-calibrated store never skews the relative mix
        if let Some(st) = store {
            let measured: Vec<Vec<Option<f64>>> = wf
                .stages
                .iter()
                .filter(|s| s.kind == crate::dataflow::StageKind::PerChunk)
                .map(|s| s.ops.iter().map(|o| st.cpu_ms(&o.op)).collect())
                .collect();
            let all = measured.iter().flatten().all(|m| m.is_some());
            let total: f64 = measured.iter().flatten().filter_map(|m| *m).sum();
            if all && total > 0.0 {
                for (stage, ms_row) in out.stages.iter_mut().zip(&measured) {
                    for (op, ms) in stage.ops.iter_mut().zip(ms_row) {
                        op.cpu_fraction = ms.unwrap() / total;
                    }
                }
            }
        }
        out
    }

    /// The WSI pipeline in its *pipelined* form: derived from the same
    /// `app::build_workflow` + `app::registry` the real executor runs.
    pub fn pipelined() -> Self {
        let registry = crate::app::registry();
        let wf = crate::app::build_workflow(&crate::app::AppParams::for_tile_size(64), false);
        Self::from_workflow(&wf, &registry)
    }

    /// The *non-pipelined* (monolithic) form: each stage folded into one op
    /// with the Amdahl-blended speedup (paper Fig. 9 comparison).
    pub fn monolithic() -> Self {
        let p = Self::pipelined();
        SimWorkflow {
            stages: p
                .stages
                .iter()
                .map(|s| {
                    let frac: f64 = s.ops.iter().map(|o| o.cpu_fraction).sum();
                    let gpu: f64 = s
                        .ops
                        .iter()
                        .map(|o| o.cpu_fraction / o.speedup_true.max(0.05) as f64)
                        .sum();
                    let blended = if gpu > 0.0 { (frac / gpu) as f32 } else { 1.0 };
                    SimStage {
                        name: s.name.clone(),
                        ops: vec![SimOp {
                            name: format!("{}-monolith", s.name),
                            cpu_fraction: frac,
                            speedup_true: blended,
                            speedup_est: blended,
                            transfer_impact: 0.1,
                            has_gpu: true,
                            deps: vec![],
                        }],
                    }
                })
                .collect(),
        }
    }

    /// Inject speedup-estimation error (paper §V-G): ops whose true
    /// speedup is below the median get their *estimates* inflated by
    /// `error`, the others deflated — the confounding pattern the paper
    /// uses.  `error = 1.0` reproduces their extreme case (high estimates
    /// zeroed, low ones doubled).
    pub fn with_estimation_error(mut self, error: f32) -> Self {
        let mut speeds: Vec<f32> = self
            .stages
            .iter()
            .flat_map(|s| s.ops.iter())
            .filter(|o| o.has_gpu)
            .map(|o| o.speedup_true)
            .collect();
        speeds.sort_by(|a, b| a.partial_cmp(b).unwrap());
        if speeds.is_empty() {
            return self;
        }
        let median = speeds[speeds.len() / 2];
        for stage in &mut self.stages {
            for op in &mut stage.ops {
                if !op.has_gpu {
                    continue;
                }
                if op.speedup_true < median {
                    op.speedup_est = op.speedup_true * (1.0 + error);
                } else {
                    op.speedup_est = (op.speedup_true * (1.0 - error)).max(0.0);
                }
            }
        }
        self
    }

    /// Inject *random* (unconfounded) estimation error — an ablation the
    /// paper doesn't run; shows PATS only needs the order to survive.
    pub fn with_random_error(mut self, error: f32, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        for stage in &mut self.stages {
            for op in &mut stage.ops {
                if op.has_gpu {
                    let sign = if rng.bool() { 1.0 } else { -1.0 };
                    op.speedup_est = (op.speedup_true * (1.0 + sign * error)).max(0.0);
                }
            }
        }
        self
    }
}

/// Simulation parameters for one run.
#[derive(Debug, Clone)]
pub struct SimParams {
    pub workflow: SimWorkflow,
    pub policy: Policy,
    pub data_locality: bool,
    pub prefetch: bool,
    /// Manager-side chunk-catalog locality (the staging subsystem): with
    /// it on, a tile's next stage runs on the node that staged the tile;
    /// off, repeat stages scatter across nodes and a migrated tile pays a
    /// cold shared-FS re-read before its stage can start (the Fig. 8-style
    /// locality-off control).
    pub chunk_locality: bool,
    /// Replicate-on-steal: a stolen tile was hinted to the thief ahead of
    /// time, so its migrated stage pays one scheduled read instead of a
    /// cold unscheduled 2x re-read (`htap sim --no-replication` control).
    pub replication: bool,
    /// Fraction of repeat stages stolen by another node under load
    /// imbalance, when locality is on and the cluster has > 1 node.
    pub steal_rate: f64,
    pub placement: Placement,
    pub n_nodes: usize,
    pub cpus_per_node: usize,
    pub gpus_per_node: usize,
    pub window: usize,
    pub n_tiles: usize,
    /// single-core seconds to fully process one tile (Fig. 7 basis)
    pub t_cpu_tile: f64,
    /// unloaded per-tile Lustre read seconds
    pub tile_io_base: f64,
    /// I/O slowdown per additional client node
    pub io_contention: f64,
    /// per-(chunk, op) cost jitter amplitude (0 = none)
    pub jitter: f64,
    /// memory-bandwidth contention per extra active CPU thread
    pub mem_contention: f64,
    pub seed: u64,
    /// Fault injection: crash the last node at this fraction (0..1) of the
    /// no-fault makespan.  Its in-flight stage instances are re-issued to
    /// the survivors at cold re-read cost — the simulator mirror of the
    /// manager's lease-expiry requeue path (`htap sim --kill-worker-at`).
    /// Ignored on single-node runs (there are no survivors).
    pub kill_worker_at: Option<f64>,
    /// Net-fault mirror (`htap sim --net-fault-rate`): each tile fetch is
    /// preceded by a manager round-trip, and this fraction of round-trips
    /// drop a frame — retried under the same bounded-backoff schedule real
    /// workers use, delaying the fetch without losing it.  0 = clean wire.
    pub net_fault_rate: f64,
    /// Seed for the mirror's drop decisions (`--fault-seed`): independent
    /// of `seed` so chaos placement can vary while the schedule's cost
    /// jitter stays fixed.
    pub fault_seed: u64,
}

impl Default for SimParams {
    fn default() -> Self {
        SimParams {
            workflow: SimWorkflow::pipelined(),
            policy: Policy::Pats,
            data_locality: true,
            prefetch: true,
            chunk_locality: true,
            replication: true,
            steal_rate: 0.1,
            placement: Placement::Closest,
            n_nodes: 1,
            cpus_per_node: 9,
            gpus_per_node: 3,
            window: 15,
            n_tiles: 100,
            // one 4Kx4K tile ~ 12 s on one Westmere core: calibrated so the
            // single-node 3GPU+9core PATS run of ~100 tiles lands at the
            // paper's Table II ~51 s.
            t_cpu_tile: 12.0,
            tile_io_base: 0.05,
            // calibrated so 100-node strong scaling reaches ~77% efficiency
            // (Fig. 14): reads serialise per node and slow with client count
            io_contention: 0.105,
            jitter: 0.15,
            mem_contention: 0.03,
            seed: 42,
            kill_worker_at: None,
            net_fault_rate: 0.0,
            fault_seed: 0,
        }
    }
}

/// Aggregate result of one simulated run.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// simulated wall-clock seconds
    pub makespan: f64,
    /// op name -> (cpu executions, gpu executions)
    pub profile: HashMap<String, (u64, u64)>,
    /// total simulated seconds devices spent computing
    pub busy_time: f64,
    /// total simulated seconds spent in CPU<->GPU transfers
    pub transfer_time: f64,
    /// total tile-fetch (I/O) seconds
    pub io_time: f64,
    /// repeat stages that migrated off the node that staged their tile
    pub steal_migrations: u64,
    /// migrations that paid a cold unscheduled re-read (locality off, or a
    /// steal without replication)
    pub cold_rereads: u64,
    /// stage instances re-issued to surviving nodes after a fault-injected
    /// crash (`SimParams::kill_worker_at`); 0 on fault-free runs
    pub reexecuted: u64,
    /// manager round-trip frames dropped and retried under the net-fault
    /// mirror (`SimParams::net_fault_rate`); 0 on a clean wire
    pub retried_frames: u64,
    pub tiles: usize,
}

impl SimResult {
    pub fn tiles_per_second(&self) -> f64 {
        self.tiles as f64 / self.makespan
    }

    /// Fraction of instances of `op` that ran on the GPU (Fig. 10/12).
    pub fn gpu_fraction(&self, op: &str) -> f64 {
        match self.profile.get(op) {
            Some(&(c, g)) if c + g > 0 => g as f64 / (c + g) as f64,
            _ => 0.0,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Event {
    /// a tile fetch completed on `node`
    Fetched { node: usize, chunk: u64 },
    /// device finished its op
    OpDone { node: usize, dev: usize },
    /// locality-off: a tile's next stage landed on another node, which
    /// finished re-reading the tile and can now instantiate the stage
    Migrated { node: usize, stage: usize, chunk: u64 },
    /// fault injection: `node` crashes — its in-flight stage instances
    /// re-issue to survivors (the lease-expiry mirror)
    Kill { node: usize },
}

#[derive(Debug, Clone)]
struct Device {
    kind: DeviceKind,
    id: usize,
    busy: bool,
    current: Option<(u64, usize)>, // (inst, op)
}

struct InstState {
    stage: usize,
    chunk: u64,
    remaining_deps: Vec<usize>,
    done: Vec<bool>,
    ops_left: usize,
    /// op -> gpu device id whose memory holds its output
    resident: HashMap<usize, usize>,
}

struct NodeState {
    queue: Box<dyn OpScheduler>,
    devices: Vec<Device>,
    insts: HashMap<u64, InstState>,
    /// stage instances currently assigned (window accounting)
    assigned: usize,
    fetching: usize,
}

/// Run one simulation.
pub fn simulate(params: &SimParams) -> SimResult {
    simulate_impl(params, None)
}

/// [`simulate`], also returning the virtual-time schedule as trace events
/// in the live schema (`htap sim --trace-out`): one begin/end span per
/// dispatched op (`worker` = node index + 1, `lane` = device id, `ts_us` =
/// simulated seconds scaled to microseconds) plus a [`EventKind::StagingMiss`]
/// record per Lustre tile fetch and a [`EventKind::WorkerExpire`] marker at
/// fault injection, so the export opens in Perfetto exactly like a real
/// run's trace.
pub fn simulate_traced(params: &SimParams) -> (SimResult, Vec<TraceEvent>) {
    let mut events = Vec::new();
    let r = simulate_impl(params, Some(&mut events));
    events.sort_by_key(|e| (e.ts_us, e.worker, e.lane));
    (r, events)
}

fn simulate_impl(params: &SimParams, mut trace: Option<&mut Vec<TraceEvent>>) -> SimResult {
    // GPU-only nodes: the controller thread runs CPU-only ops itself (the
    // real WRM's fallback path), at CPU cost and zero transfer.
    let owned_params;
    let params = if params.cpus_per_node == 0 {
        let mut wf = params.workflow.clone();
        for stage in &mut wf.stages {
            for op in &mut stage.ops {
                op.has_gpu = true;
            }
        }
        owned_params = SimParams { workflow: wf, ..params.clone() };
        &owned_params
    } else {
        params
    };
    let topo = NodeTopology::keeneland();
    let n_nodes = params.n_nodes.max(1);
    let mut nodes: Vec<NodeState> = (0..n_nodes)
        .map(|_| {
            let mut devices = Vec::new();
            for c in 0..params.cpus_per_node {
                devices.push(Device { kind: DeviceKind::Cpu, id: c, busy: false, current: None });
            }
            for g in 0..params.gpus_per_node {
                devices.push(Device { kind: DeviceKind::Gpu, id: g, busy: false, current: None });
            }
            NodeState {
                queue: make_scheduler(params.policy),
                devices,
                insts: HashMap::new(),
                assigned: 0,
                fetching: 0,
            }
        })
        .collect();

    let io_time_per_tile =
        params.tile_io_base * (1.0 + params.io_contention * (n_nodes as f64 - 1.0));

    // net-fault mirror: the k-th fetch-issuing round-trip drops its frame
    // while hash(fault_seed, k, attempt) says so, paying the live
    // RetryPolicy's backoff per drop.  The last attempt always lands (the
    // live path surfaces an error past the budget; the mirror keeps the
    // run alive), so faults delay fetches without losing them.
    let net_retry = crate::net::RetryPolicy::rpc();
    let mut rtt_seq = 0u64;
    let mut retried_frames = 0u64;
    let net_delay_of = |rtt: u64| -> (f64, u64) {
        if params.net_fault_rate <= 0.0 {
            return (0.0, 0);
        }
        let threshold = (params.net_fault_rate.min(1.0) * 1e6) as u64;
        let mut delay = 0.0;
        let mut drops = 0u32;
        while (drops + 1) < net_retry.max_attempts.max(1) {
            let h = crate::faults::splitmix64(
                params.fault_seed ^ rtt.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ ((drops as u64) << 48),
            );
            if h % 1_000_000 >= threshold {
                break;
            }
            delay += net_retry.backoff_ms(drops) as f64 / 1e3;
            drops += 1;
        }
        (delay, drops as u64)
    };
    macro_rules! net_delay {
        () => {{
            let (d, n) = net_delay_of(rtt_seq);
            rtt_seq += 1;
            retried_frames += n;
            d
        }};
    }

    let mut heap: BinaryHeap<Reverse<(u64, u64, usize)>> = BinaryHeap::new();
    let mut events: Vec<Event> = Vec::new();
    let mut seq = 0u64;
    let mut now = 0.0f64;
    let mut next_chunk = 0u64;
    let mut next_inst = 0u64;
    let mut task_seq = 0u64;

    let mut profile: HashMap<String, (u64, u64)> = HashMap::new();
    let mut busy_time = 0.0;
    let mut transfer_time = 0.0;
    let mut io_total = 0.0;
    let mut steal_migrations = 0u64;
    let mut cold_rereads = 0u64;
    let mut reexecuted = 0u64;
    let mut tiles_done = 0usize;
    let mut dead = vec![false; n_nodes];

    let to_ns = |t: f64| (t * 1e9) as u64;

    macro_rules! push_event {
        ($t:expr, $e:expr) => {{
            events.push($e);
            heap.push(Reverse((to_ns($t), seq, events.len() - 1)));
            seq += 1;
        }};
    }

    // fault injection: the kill fraction is relative to the *no-fault*
    // makespan, so a no-kill baseline run fixes the absolute crash time
    // (the recursion terminates: the baseline clears kill_worker_at).
    // The victim is the last node; ignored when it has no survivors.
    let victim = n_nodes - 1;
    if n_nodes > 1 {
        if let Some(frac) = params.kill_worker_at {
            let baseline =
                simulate(&SimParams { kill_worker_at: None, ..params.clone() });
            push_event!(frac.max(0.0) * baseline.makespan, Event::Kill { node: victim });
        }
    }
    // deterministic survivor pick for re-issued work (victim is the last
    // node, so survivors are a dense 0..victim prefix)
    let survivor = |chunk: u64| (chunk % victim.max(1) as u64) as usize;

    // initial fetches: one outstanding read per node (a node's Lustre
    // client stream is serial; contention raises its latency)
    for node in 0..n_nodes {
        if nodes[node].assigned + nodes[node].fetching < params.window
            && next_chunk < params.n_tiles as u64
        {
            let chunk = next_chunk;
            next_chunk += 1;
            nodes[node].fetching += 1;
            io_total += io_time_per_tile;
            push_event!(net_delay!() + io_time_per_tile, Event::Fetched { node, chunk });
        }
    }

    // jitter helper: deterministic per (chunk, op)
    let jitter = |chunk: u64, op: usize| -> f64 {
        if params.jitter == 0.0 {
            return 1.0;
        }
        let mut r = Rng::new(params.seed ^ chunk.wrapping_mul(31) ^ (op as u64 + 1) * 0x9E37);
        1.0 + params.jitter * (2.0 * r.f32() as f64 - 1.0)
    };

    // instantiate a stage instance on a node
    fn submit_stage(
        node_state: &mut NodeState,
        wf: &SimWorkflow,
        inst: u64,
        stage: usize,
        chunk: u64,
        task_seq: &mut u64,
    ) {
        let ops = &wf.stages[stage].ops;
        let remaining: Vec<usize> = ops.iter().map(|o| o.deps.len()).collect();
        node_state.insts.insert(
            inst,
            InstState {
                stage,
                chunk,
                remaining_deps: remaining.clone(),
                done: vec![false; ops.len()],
                ops_left: ops.len(),
                resident: HashMap::new(),
            },
        );
        for (oi, op) in ops.iter().enumerate() {
            if remaining[oi] == 0 {
                node_state.queue.push(ReadyTask {
                    key: (inst, oi),
                    name: op.name.clone(),
                    speedup: op.speedup_est,
                    transfer_impact: op.transfer_impact,
                    seq: *task_seq,
                    resident_on: None,
                    has_gpu_impl: op.has_gpu,
                });
                *task_seq += 1;
            }
        }
    }

    // per-node dispatch: fill idle devices from the node queue.  `now` and
    // `node` only feed the optional trace sink: spans are emitted at
    // dispatch time because the whole (compute, transfer, total) cost is
    // known up front in virtual time.
    #[allow(clippy::too_many_arguments)]
    fn dispatch_node(
        node_state: &mut NodeState,
        params: &SimParams,
        topo: &NodeTopology,
        jitter: &dyn Fn(u64, usize) -> f64,
        profile: &mut HashMap<String, (u64, u64)>,
        busy_time: &mut f64,
        transfer_time: &mut f64,
        now: f64,
        node: usize,
        mut trace: Option<&mut Vec<TraceEvent>>,
    ) -> Vec<(usize, f64)> {
        let mut started = Vec::new();
        loop {
            let active_cpus = node_state
                .devices
                .iter()
                .filter(|d| d.kind == DeviceKind::Cpu && d.busy)
                .count();
            let mut any = false;
            for di in 0..node_state.devices.len() {
                if node_state.devices[di].busy {
                    continue;
                }
                let (kind, id) = (node_state.devices[di].kind, node_state.devices[di].id);
                let Some(task) = node_state.queue.pop(kind, id, params.data_locality) else {
                    continue;
                };
                let inst_state = node_state.insts.get(&task.key.0).unwrap();
                let (stage, chunk) = (inst_state.stage, inst_state.chunk);
                let op = &params.workflow.stages[stage].ops[task.key.1];
                let base = op.cpu_fraction * params.t_cpu_tile * jitter(chunk, task.key.1);
                let (compute, transfer) = match kind {
                    DeviceKind::Cpu => {
                        let contention = 1.0 + params.mem_contention * active_cpus as f64;
                        (base * contention, 0.0)
                    }
                    DeviceKind::Gpu => {
                        let compute = base / op.speedup_true.max(0.05) as f64;
                        let ti = op.transfer_impact as f64;
                        let link = topo.expected_links(id, params.placement);
                        let min_link = topo.expected_links(id, Placement::Closest).max(1.0);
                        let mut transfer = compute * ti / (1.0 - ti) * (link / min_link);
                        // DL: resident input -> only the download leg
                        let resident_here =
                            op.deps.iter().any(|d| inst_state.resident.get(d) == Some(&id));
                        if params.data_locality && resident_here {
                            transfer *= 0.3;
                        }
                        (compute, transfer)
                    }
                };
                let total = if kind == DeviceKind::Gpu && params.prefetch {
                    // async copy overlaps; a small serial residue remains
                    compute.max(transfer) + 0.1 * transfer.min(compute)
                } else {
                    compute + transfer
                };
                node_state.devices[di].busy = true;
                node_state.devices[di].current = Some((task.key.0, task.key.1));
                *busy_time += compute;
                *transfer_time += transfer;
                let e = profile.entry(op.name.clone()).or_insert((0, 0));
                match kind {
                    DeviceKind::Cpu => e.0 += 1,
                    DeviceKind::Gpu => e.1 += 1,
                }
                if let Some(tr) = trace.as_deref_mut() {
                    let begin = TraceEvent {
                        ts_us: (now * 1e6) as u64,
                        device: match kind {
                            DeviceKind::Cpu => DEV_CPU,
                            DeviceKind::Gpu => DEV_GPU,
                        },
                        worker: node as u64 + 1,
                        lane: id as u32,
                        stage: stage as u32,
                        chunk,
                        name: Name::new(&op.name),
                        ..TraceEvent::of(EventKind::OpBegin)
                    };
                    tr.push(begin);
                    tr.push(TraceEvent {
                        kind: EventKind::OpEnd,
                        ts_us: ((now + total) * 1e6) as u64,
                        dur_us: (total * 1e6) as u64,
                        ..begin
                    });
                }
                started.push((di, total));
                any = true;
            }
            if !any {
                return started;
            }
        }
    }

    // initial dispatch (nothing queued yet, but keeps the invariant)
    for node in 0..n_nodes {
        for (di, total) in dispatch_node(
            &mut nodes[node],
            params,
            &topo,
            &jitter,
            &mut profile,
            &mut busy_time,
            &mut transfer_time,
            now,
            node,
            trace.as_deref_mut(),
        ) {
            push_event!(now + total, Event::OpDone { node, dev: di });
        }
    }

    // main event loop
    while let Some(Reverse((t_ns, _, eidx))) = heap.pop() {
        now = t_ns as f64 / 1e9;
        let node = match events[eidx] {
            // events landing on a crashed node: re-issue to a survivor.  A
            // completed fetch re-reads on the survivor; a pending OpDone
            // simply evaporates (its instance was already re-issued at kill
            // time); a migration retargets at cold-re-read cost.
            Event::Fetched { node, chunk } if dead[node] => {
                let s = survivor(chunk);
                nodes[s].fetching += 1;
                io_total += io_time_per_tile;
                push_event!(now + net_delay!() + io_time_per_tile, Event::Fetched { node: s, chunk });
                s
            }
            Event::OpDone { node, .. } if dead[node] => node,
            Event::Migrated { node, stage, chunk } if dead[node] => {
                let s = survivor(chunk);
                reexecuted += 1;
                cold_rereads += 1;
                io_total += 2.0 * io_time_per_tile;
                push_event!(
                    now + 2.0 * io_time_per_tile,
                    Event::Migrated { node: s, stage, chunk }
                );
                s
            }
            Event::Kill { node } => {
                dead[node] = true;
                if let Some(tr) = trace.as_deref_mut() {
                    tr.push(TraceEvent {
                        ts_us: (now * 1e6) as u64,
                        worker: node as u64 + 1,
                        ..TraceEvent::of(EventKind::WorkerExpire)
                    });
                }
                // every in-flight stage instance dies with the node; each
                // re-issues to a survivor behind a cold re-read — exactly
                // what the manager's lease-expiry requeue does.  Sorted so
                // the re-issue order (and thus task seq) is deterministic.
                let mut lost: Vec<(usize, u64)> =
                    nodes[node].insts.values().map(|i| (i.stage, i.chunk)).collect();
                lost.sort_unstable();
                nodes[node].insts.clear();
                for d in &mut nodes[node].devices {
                    d.busy = true; // never dispatch onto the corpse
                    d.current = None;
                }
                for (stage, chunk) in lost {
                    reexecuted += 1;
                    cold_rereads += 1;
                    io_total += 2.0 * io_time_per_tile;
                    push_event!(
                        now + 2.0 * io_time_per_tile,
                        Event::Migrated { node: survivor(chunk), stage, chunk }
                    );
                }
                node
            }
            Event::Fetched { node, chunk } => {
                nodes[node].fetching -= 1;
                nodes[node].assigned += 1;
                // every simulated tile read is a cold staging miss: the
                // span covers the contended Lustre fetch that just landed
                if let Some(tr) = trace.as_deref_mut() {
                    tr.push(TraceEvent {
                        ts_us: (now * 1e6) as u64,
                        dur_us: (io_time_per_tile * 1e6) as u64,
                        worker: node as u64 + 1,
                        chunk,
                        name: Name::new("tile-read"),
                        ..TraceEvent::of(EventKind::StagingMiss)
                    });
                }
                let inst = next_inst;
                next_inst += 1;
                submit_stage(&mut nodes[node], &params.workflow, inst, 0, chunk, &mut task_seq);
                // keep the serial read stream busy while the window allows
                if nodes[node].fetching == 0
                    && nodes[node].assigned + nodes[node].fetching < params.window
                    && next_chunk < params.n_tiles as u64
                {
                    let c = next_chunk;
                    next_chunk += 1;
                    nodes[node].fetching += 1;
                    io_total += io_time_per_tile;
                    push_event!(now + net_delay!() + io_time_per_tile, Event::Fetched { node, chunk: c });
                }
                node
            }
            Event::OpDone { node, dev } => {
                let (inst_id, op_idx) = nodes[node].devices[dev].current.take().unwrap();
                nodes[node].devices[dev].busy = false;
                let kind = nodes[node].devices[dev].kind;
                let dev_id = nodes[node].devices[dev].id;
                let wf = &params.workflow;
                let node_state = &mut nodes[node];
                let inst = node_state.insts.get_mut(&inst_id).unwrap();
                inst.done[op_idx] = true;
                inst.ops_left -= 1;
                if kind == DeviceKind::Gpu && params.data_locality {
                    inst.resident.insert(op_idx, dev_id);
                }
                let stage = inst.stage;
                let chunk = inst.chunk;
                // push newly-ready dependents
                let mut pushes: Vec<(usize, Option<usize>)> = Vec::new();
                for (oi, op) in wf.stages[stage].ops.iter().enumerate() {
                    if inst.done[oi] || inst.remaining_deps[oi] == 0 {
                        continue;
                    }
                    if op.deps.contains(&op_idx) {
                        inst.remaining_deps[oi] -= 1;
                        if inst.remaining_deps[oi] == 0 {
                            let resident_on =
                                op.deps.iter().find_map(|d| inst.resident.get(d).copied());
                            pushes.push((oi, resident_on));
                        }
                    }
                }
                let inst_done = inst.ops_left == 0;
                for (oi, resident_on) in pushes {
                    let op = &wf.stages[stage].ops[oi];
                    node_state.queue.push(ReadyTask {
                        key: (inst_id, oi),
                        name: op.name.clone(),
                        speedup: op.speedup_est,
                        transfer_impact: op.transfer_impact,
                        seq: task_seq,
                        resident_on,
                        has_gpu_impl: op.has_gpu,
                    });
                    task_seq += 1;
                }
                if inst_done {
                    node_state.insts.remove(&inst_id);
                    if stage + 1 < wf.stages.len() {
                        // with chunk locality the tile's next stage stays on
                        // the node that staged it (the catalog policy) —
                        // except for the steal fraction the bag hands to an
                        // idle node under load imbalance; without locality
                        // the bag scatters every repeat stage
                        let stolen = params.chunk_locality
                            && n_nodes > 1
                            && params.steal_rate > 0.0
                            && {
                                let mut r = Rng::new(
                                    params.seed
                                        ^ chunk.wrapping_mul(0xC2B2_AE35)
                                        ^ ((stage as u64 + 7) << 24),
                                );
                                (r.f32() as f64) < params.steal_rate
                            };
                        let target = if n_nodes == 1 || (params.chunk_locality && !stolen) {
                            node
                        } else {
                            let mut r = Rng::new(
                                params.seed
                                    ^ chunk.wrapping_mul(0x9E37_79B9)
                                    ^ ((stage as u64 + 1) << 32),
                            );
                            r.below(n_nodes)
                        };
                        if target == node {
                            let next = next_inst;
                            next_inst += 1;
                            submit_stage(node_state, wf, next, stage + 1, chunk, &mut task_seq);
                        } else {
                            // free this node's window slot and keep its
                            // read stream busy
                            node_state.assigned -= 1;
                            if node_state.fetching == 0
                                && node_state.assigned + node_state.fetching < params.window
                                && next_chunk < params.n_tiles as u64
                            {
                                let c = next_chunk;
                                next_chunk += 1;
                                node_state.fetching += 1;
                                io_total += io_time_per_tile;
                                push_event!(
                                    now + net_delay!() + io_time_per_tile,
                                    Event::Fetched { node, chunk: c }
                                );
                            }
                            if stolen {
                                steal_migrations += 1;
                            }
                            // replicated steal: the hint let the thief pull
                            // the tile through its scheduled read stream;
                            // otherwise the migrated stage pays a cold
                            // unscheduled re-read (outside the streaming
                            // window: twice the contended per-tile read)
                            let migrate_io =
                                if stolen && params.chunk_locality && params.replication {
                                    io_time_per_tile
                                } else {
                                    cold_rereads += 1;
                                    2.0 * io_time_per_tile
                                };
                            io_total += migrate_io;
                            push_event!(
                                now + migrate_io,
                                Event::Migrated { node: target, stage: stage + 1, chunk }
                            );
                        }
                    } else {
                        node_state.assigned -= 1;
                        tiles_done += 1;
                        // restart the read stream if the window drained it
                        if node_state.fetching == 0
                            && node_state.assigned < params.window
                            && next_chunk < params.n_tiles as u64
                        {
                            let c = next_chunk;
                            next_chunk += 1;
                            node_state.fetching += 1;
                            io_total += io_time_per_tile;
                            push_event!(now + net_delay!() + io_time_per_tile, Event::Fetched { node, chunk: c });
                        }
                    }
                }
                node
            }
            Event::Migrated { node, stage, chunk } => {
                nodes[node].assigned += 1;
                let inst = next_inst;
                next_inst += 1;
                submit_stage(&mut nodes[node], &params.workflow, inst, stage, chunk, &mut task_seq);
                node
            }
        };
        for (di, total) in dispatch_node(
            &mut nodes[node],
            params,
            &topo,
            &jitter,
            &mut profile,
            &mut busy_time,
            &mut transfer_time,
            now,
            node,
            trace.as_deref_mut(),
        ) {
            push_event!(now + total, Event::OpDone { node, dev: di });
        }
    }

    SimResult {
        makespan: now,
        profile,
        busy_time,
        transfer_time,
        io_time: io_total,
        steal_migrations,
        cold_rereads,
        reexecuted,
        retried_frames,
        tiles: tiles_done,
    }
}

/// Analytic per-job makespans for `weights.len()` identical jobs sharing
/// one cluster under weighted fair-share (`htap sim --jobs/--job-weights`).
///
/// Model: weighted processor sharing with water-filling.  Each job needs
/// `solo_makespan` seconds of the whole cluster; while k jobs are active
/// each gets capacity `w_i / Σ_active w`, so light-weight jobs finish
/// last, and every departure re-divides the freed share among the
/// survivors (a deficit round-robin's long-run behaviour, without
/// simulating per-assignment granularity).  Returns one completion time
/// per input weight, in input order.  Zero weights are clamped to 1, the
/// same floor the service's DRR applies.
pub fn fair_share_makespans(solo_makespan: f64, weights: &[u32]) -> Vec<f64> {
    let mut remaining: Vec<f64> = weights.iter().map(|_| solo_makespan).collect();
    let w: Vec<f64> = weights.iter().map(|&w| f64::from(w.max(1))).collect();
    let mut done = vec![0.0f64; weights.len()];
    let mut active: Vec<usize> = (0..weights.len()).collect();
    let mut now = 0.0f64;
    while !active.is_empty() {
        let wsum: f64 = active.iter().map(|&i| w[i]).sum();
        // time until the next departure at current shares
        let dt = active
            .iter()
            .map(|&i| remaining[i] * wsum / w[i])
            .fold(f64::INFINITY, f64::min);
        now += dt;
        for &i in &active {
            remaining[i] -= dt * w[i] / wsum;
        }
        active.retain(|&i| {
            if remaining[i] <= 1e-12 {
                done[i] = now;
                false
            } else {
                true
            }
        });
    }
    done
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base(n_tiles: usize) -> SimParams {
        SimParams { n_tiles, jitter: 0.1, ..Default::default() }
    }

    #[test]
    fn fair_share_water_filling_matches_hand_arithmetic() {
        // equal weights: both jobs run at half speed and finish together
        let m = fair_share_makespans(100.0, &[1, 1]);
        assert!((m[0] - 200.0).abs() < 1e-9 && (m[1] - 200.0).abs() < 1e-9, "{m:?}");
        // 1:4 — the heavy job gets 4/5 of the cluster and departs at
        // 100 * 5/4 = 125s; the light job then runs alone and finishes at
        // 125 + (100 - 125/5) = 200s (work-conserving: last = n * solo)
        let m = fair_share_makespans(100.0, &[1, 4]);
        assert!((m[1] - 125.0).abs() < 1e-9, "{m:?}");
        assert!((m[0] - 200.0).abs() < 1e-9, "{m:?}");
        // zero weights clamp to 1 (the DRR floor), order is preserved
        let m = fair_share_makespans(10.0, &[0, 3]);
        assert!(m[1] < m[0], "{m:?}");
        assert!((m[0] - 20.0).abs() < 1e-9, "{m:?}");
        // a single job is unaffected by the machinery
        let m = fair_share_makespans(42.0, &[7]);
        assert!((m[0] - 42.0).abs() < 1e-9, "{m:?}");
        assert!(fair_share_makespans(1.0, &[]).is_empty());
    }

    #[test]
    fn sim_workflow_is_derived_from_the_builder_workflow() {
        let p = SimWorkflow::pipelined();
        assert_eq!(p.stages.len(), 2);
        assert_eq!(p.stages[0].ops.len(), 9);
        assert_eq!(p.stages[1].ops.len(), 3);
        // registry cost fractions cover the whole profile
        let total: f64 =
            p.stages.iter().flat_map(|s| s.ops.iter()).map(|o| o.cpu_fraction).sum();
        assert!((total - 1.0).abs() < 1e-9, "total = {total}");
        // wiring came from the dataflow graph: watershed waits on pre_watershed
        let seg = &p.stages[0];
        let ws = seg.ops.iter().position(|o| o.name == "watershed").unwrap();
        let pw = seg.ops.iter().position(|o| o.name == "pre_watershed").unwrap();
        assert!(seg.ops[ws].deps.contains(&pw));
        // CPU-only ops are not GPU-eligible in the model
        assert!(!seg.ops.iter().find(|o| o.name == "hema_prep").unwrap().has_gpu);
    }

    #[test]
    fn profiled_workflow_uses_measured_estimates_and_fractions() {
        use std::time::Duration;
        let mut store = ProfileStore::new(64);
        // measure every WSI pipeline op: 10 ms CPU each except morph_open
        // (40 ms), so measured fractions differ from the static table; give
        // morph_open a large measured speedup and feature_graph a tiny one
        // (the inverse of Fig. 7)
        let p = SimWorkflow::pipelined();
        for stage in &p.stages {
            for op in &stage.ops {
                let cpu = if op.name == "morph_open" { 40.0 } else { 10.0 };
                store.record("ignore_me", DeviceKind::Cpu, Duration::ZERO);
                store.record(&op.name, DeviceKind::Cpu, Duration::from_secs_f64(cpu / 1e3));
            }
        }
        store.record("morph_open", DeviceKind::Gpu, Duration::from_secs_f64(2.0 / 1e3));
        store.record("feature_graph", DeviceKind::Gpu, Duration::from_secs_f64(8.0 / 1e3));
        let wf = SimWorkflow::pipelined_profiled(&store);
        let find = |name: &str| {
            wf.stages
                .iter()
                .flat_map(|s| s.ops.iter())
                .find(|o| o.name == name)
                .unwrap()
                .clone()
        };
        // measured speedups invert the static Fig. 7 ranking
        let mo = find("morph_open");
        let fg = find("feature_graph");
        assert!((mo.speedup_est - 20.0).abs() < 0.5, "morph_open est {}", mo.speedup_est);
        assert!((fg.speedup_est - 1.25).abs() < 0.1, "feature_graph est {}", fg.speedup_est);
        assert!(mo.speedup_est > fg.speedup_est, "measured ranking must invert Fig. 7");
        // unmeasured-speedup ops fall back to static estimates
        let ws = find("watershed");
        assert_eq!(ws.speedup_est, crate::app::profile::speedup_of("watershed"));
        // fractions renormalised from measured CPU times and sum to 1
        let total: f64 =
            wf.stages.iter().flat_map(|s| s.ops.iter()).map(|o| o.cpu_fraction).sum();
        assert!((total - 1.0).abs() < 1e-9, "total = {total}");
        assert!(mo.cpu_fraction > fg.cpu_fraction, "40ms op outweighs 10ms op");
        // the profiled workflow still simulates to completion
        let r = simulate(&SimParams { workflow: wf, n_tiles: 20, ..Default::default() });
        assert_eq!(r.tiles, 20);
    }

    #[test]
    fn all_tiles_complete() {
        let r = simulate(&base(50));
        assert_eq!(r.tiles, 50);
        assert!(r.makespan > 0.0);
        let total_ops: u64 = r.profile.values().map(|(c, g)| c + g).sum();
        assert_eq!(total_ops, 50 * 12);
    }

    #[test]
    fn traced_run_matches_untraced_and_spans_balance() {
        let mut p = base(20);
        p.n_nodes = 2;
        let plain = simulate(&p);
        let (traced, events) = simulate_traced(&p);
        // the sink is write-only: tracing must not perturb the schedule
        assert_eq!(traced.makespan, plain.makespan);
        assert_eq!(traced.tiles, plain.tiles);
        // one begin/end pair per dispatched op, matching the profile
        let total_ops: u64 = traced.profile.values().map(|(c, g)| c + g).sum();
        let begins = events.iter().filter(|e| e.kind == EventKind::OpBegin).count() as u64;
        let ends = events.iter().filter(|e| e.kind == EventKind::OpEnd).count() as u64;
        assert_eq!(begins, total_ops);
        assert_eq!(ends, total_ops);
        // one cold staging miss per contended tile read
        let misses = events.iter().filter(|e| e.kind == EventKind::StagingMiss).count();
        assert!(misses >= traced.tiles, "{misses} misses < {} tiles", traced.tiles);
        // virtual timestamps: sorted, inside the makespan, workers 1-based
        assert!(events.windows(2).all(|w| w[0].ts_us <= w[1].ts_us));
        let end_us = (traced.makespan * 1e6) as u64 + 1;
        assert!(events.iter().all(|e| e.ts_us <= end_us));
        assert!(events.iter().all(|e| (1..=2).contains(&e.worker)));
        assert!(events
            .iter()
            .filter(|e| e.kind == EventKind::OpEnd)
            .all(|e| e.dur_us > 0 && !e.name.is_empty()));
    }

    #[test]
    fn net_faults_delay_but_never_lose_tiles() {
        let mut p = base(30);
        p.n_nodes = 2;
        let clean = simulate(&p);
        assert_eq!(clean.retried_frames, 0);
        p.net_fault_rate = 0.3;
        p.fault_seed = 11;
        let faulty = simulate(&p);
        // every tile still completes — faults delay fetches, never drop them
        assert_eq!(faulty.tiles, 30);
        assert!(faulty.retried_frames > 0, "30% drop rate must retry something");
        assert!(
            faulty.makespan > clean.makespan,
            "retry backoff must cost wall-clock: {} !> {}",
            faulty.makespan,
            clean.makespan
        );
        // the drop pattern is a pure function of the fault seed
        let again = simulate(&p);
        assert_eq!(again.makespan, faulty.makespan);
        assert_eq!(again.retried_frames, faulty.retried_frames);
        // a different fault seed lands the drops elsewhere (same count
        // class, different schedule) without changing completion
        p.fault_seed = 12;
        let other = simulate(&p);
        assert_eq!(other.tiles, 30);
        // tracing must not perturb the faulty schedule either
        p.fault_seed = 11;
        let (traced, _) = simulate_traced(&p);
        assert_eq!(traced.makespan, faulty.makespan);
        assert_eq!(traced.retried_frames, faulty.retried_frames);
    }

    #[test]
    fn pats_beats_fcfs_pipelined() {
        let mut p = base(100);
        p.policy = Policy::Fcfs;
        let fcfs = simulate(&p).makespan;
        p.policy = Policy::Pats;
        let pats = simulate(&p).makespan;
        assert!(pats < fcfs * 0.95, "PATS ({pats:.1}s) should beat FCFS ({fcfs:.1}s)");
    }

    #[test]
    fn monolithic_insensitive_to_policy() {
        let mut p = base(100);
        p.workflow = SimWorkflow::monolithic();
        p.policy = Policy::Fcfs;
        let fcfs = simulate(&p).makespan;
        p.workflow = SimWorkflow::monolithic();
        p.policy = Policy::Pats;
        let pats = simulate(&p).makespan;
        let ratio = fcfs / pats;
        assert!((0.93..1.07).contains(&ratio), "monolithic PATS ~ FCFS, got ratio {ratio:.3}");
    }

    #[test]
    fn pats_gpu_bias_follows_speedup() {
        let r = simulate(&base(100));
        assert!(
            r.gpu_fraction("feature_graph") > r.gpu_fraction("morph_open"),
            "fg {} vs mo {}",
            r.gpu_fraction("feature_graph"),
            r.gpu_fraction("morph_open")
        );
    }

    #[test]
    fn closest_placement_helps() {
        // Fig. 8 setup: GPU-only, no DL/prefetch (those come later in the
        // paper's evaluation), so transfer costs hit fully.
        let mut p = base(100);
        p.cpus_per_node = 0;
        p.gpus_per_node = 3;
        p.data_locality = false;
        p.prefetch = false;
        p.placement = Placement::Closest;
        let closest = simulate(&p).makespan;
        p.placement = Placement::Os;
        let os = simulate(&p).makespan;
        assert!(closest < os, "closest {closest:.2} vs os {os:.2}");
        // the delta is a few percent, like the paper's 3-8%
        assert!(os / closest < 1.25, "delta too large: {:.3}", os / closest);
    }

    #[test]
    fn more_nodes_scale_throughput() {
        let mut p = base(400);
        p.n_nodes = 1;
        let one = simulate(&p);
        p.n_nodes = 8;
        let eight = simulate(&p);
        assert_eq!(eight.tiles, 400);
        let speedup = one.makespan / eight.makespan;
        assert!(speedup > 5.0, "8-node speedup only {speedup:.2}");
        assert!(speedup < 8.5);
    }

    #[test]
    fn estimation_error_degrades_gracefully() {
        let mut p = base(100);
        let perfect = simulate(&p).makespan;
        p.workflow = SimWorkflow::pipelined().with_estimation_error(0.6);
        let e60 = simulate(&p).makespan;
        assert!(e60 >= perfect * 0.98);
        assert!(e60 < perfect * 1.5, "60% error should degrade <50%: {perfect:.1} -> {e60:.1}");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = simulate(&base(30)).makespan;
        let b = simulate(&base(30)).makespan;
        assert_eq!(a, b);
    }

    #[test]
    fn chunk_locality_on_beats_locality_off() {
        // the Fig. 8-style control: without catalog locality, repeat
        // stages migrate across nodes and pay cold tile re-reads
        let mut p = base(120);
        p.n_nodes = 4;
        let on = simulate(&p);
        p.chunk_locality = false;
        let off = simulate(&p);
        assert_eq!(on.tiles, 120);
        assert_eq!(off.tiles, 120);
        assert!(
            off.io_time > on.io_time,
            "migration must add I/O: on {:.2}s off {:.2}s",
            on.io_time,
            off.io_time
        );
        assert!(
            on.makespan < off.makespan,
            "locality on ({:.2}s) must beat locality off ({:.2}s)",
            on.makespan,
            off.makespan
        );
    }

    #[test]
    fn replication_cuts_steal_cold_rereads() {
        // the tiered-storage control: steals happen either way (same seed,
        // same rolls), but only the no-replication run pays cold re-reads
        let mut p = base(120);
        p.n_nodes = 4;
        let on = simulate(&p);
        p.replication = false;
        let off = simulate(&p);
        assert_eq!(on.tiles, 120);
        assert_eq!(off.tiles, 120);
        assert!(on.steal_migrations > 0, "steal pressure must exist for the test to mean anything");
        assert_eq!(on.steal_migrations, off.steal_migrations, "same rolls, same steals");
        assert_eq!(on.cold_rereads, 0, "replicated steals are prefetched, never cold");
        assert!(
            off.cold_rereads >= on.steal_migrations,
            "every unreplicated steal re-reads cold: {} < {}",
            off.cold_rereads,
            off.steal_migrations
        );
        assert!(
            off.io_time > on.io_time,
            "cold re-reads must add I/O: on {:.2}s off {:.2}s",
            on.io_time,
            off.io_time
        );
    }

    #[test]
    fn chunk_locality_irrelevant_on_one_node() {
        let mut p = base(40);
        let on = simulate(&p).makespan;
        p.chunk_locality = false;
        let off = simulate(&p).makespan;
        assert_eq!(on, off, "single node: nothing to migrate");
    }

    #[test]
    fn killed_node_work_reexecutes_and_all_tiles_complete() {
        let mut p = base(120);
        p.n_nodes = 4;
        let clean = simulate(&p);
        p.kill_worker_at = Some(0.5);
        let faulty = simulate(&p);
        // every tile still completes — the survivors re-execute the dead
        // node's in-flight stage instances
        assert_eq!(clean.tiles, 120);
        assert_eq!(faulty.tiles, 120);
        assert!(faulty.reexecuted > 0, "a mid-run crash must strand in-flight work");
        assert_eq!(clean.reexecuted, 0);
        // the recovery is paid for in cold re-reads and lost compute
        assert!(
            faulty.cold_rereads >= clean.cold_rereads + faulty.reexecuted,
            "each re-issue pays a cold re-read: {} vs {} + {}",
            faulty.cold_rereads,
            clean.cold_rereads,
            faulty.reexecuted
        );
        assert!(
            faulty.makespan > clean.makespan,
            "losing a node mid-run cannot speed the run up: {:.2}s vs {:.2}s",
            faulty.makespan,
            clean.makespan
        );
    }

    #[test]
    fn kill_injection_is_deterministic_and_ignored_on_one_node() {
        let mut p = base(60);
        p.n_nodes = 3;
        p.kill_worker_at = Some(0.3);
        let a = simulate(&p);
        let b = simulate(&p);
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.reexecuted, b.reexecuted);
        // single node: no survivors, the injection is a no-op
        let mut solo = base(20);
        solo.kill_worker_at = Some(0.5);
        let r = simulate(&solo);
        assert_eq!(r.tiles, 20);
        assert_eq!(r.reexecuted, 0);
    }

    #[test]
    fn dl_reduces_transfer_time() {
        let mut p = base(100);
        p.data_locality = true;
        let with_dl = simulate(&p).transfer_time;
        p.data_locality = false;
        let without = simulate(&p).transfer_time;
        assert!(with_dl < without, "dl {with_dl:.2} vs none {without:.2}");
    }
}
