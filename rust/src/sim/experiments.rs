//! Experiment drivers: one function per paper table/figure.
//!
//! Each returns structured rows so the `cargo bench` targets (and the
//! cluster_sim example) can print the same series the paper reports.
//! Configurations follow the paper's §V setups exactly: which
//! optimizations are enabled grows section by section (Fig. 8 has no
//! DL/prefetch; Fig. 9 adds PATS; Fig. 11 adds DL then prefetch; ...).

use super::{simulate, SimParams, SimResult, SimWorkflow};
use crate::config::{Placement, Policy};

/// Baseline: 1 CPU core, the reference for all speedup numbers.
pub fn single_core_makespan(n_tiles: usize) -> f64 {
    let p = SimParams {
        cpus_per_node: 1,
        gpus_per_node: 0,
        data_locality: false,
        prefetch: false,
        n_tiles,
        ..Default::default()
    };
    simulate(&p).makespan
}

// ---------------------------------------------------------------------------
// Fig. 8 — multi-GPU end-to-end speedup, OS vs Closest placement
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Fig8Row {
    pub gpus: usize,
    pub placement: Placement,
    pub speedup_vs_1core: f64,
}

pub fn fig8(n_tiles: usize) -> Vec<Fig8Row> {
    let base = single_core_makespan(n_tiles);
    let mut rows = Vec::new();
    for gpus in 1..=3 {
        for placement in [Placement::Os, Placement::Closest] {
            let p = SimParams {
                cpus_per_node: 0,
                gpus_per_node: gpus,
                policy: Policy::Fcfs,
                data_locality: false,
                prefetch: false,
                placement,
                n_tiles,
                ..Default::default()
            };
            let r = simulate(&p);
            rows.push(Fig8Row { gpus, placement, speedup_vs_1core: base / r.makespan });
        }
    }
    rows
}

// ---------------------------------------------------------------------------
// Fig. 9 — CPU/GPU coordination: configs x {policy} x {granularity}
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Fig9Row {
    pub label: String,
    pub makespan: f64,
    pub speedup_vs_1core: f64,
    /// the run (for Fig. 10 profile extraction)
    pub result: SimResult,
}

fn run_cfg(
    label: &str,
    cpus: usize,
    gpus: usize,
    policy: Policy,
    monolithic: bool,
    dl: bool,
    prefetch: bool,
    n_tiles: usize,
) -> Fig9Row {
    let p = SimParams {
        workflow: if monolithic { SimWorkflow::monolithic() } else { SimWorkflow::pipelined() },
        cpus_per_node: cpus,
        gpus_per_node: gpus,
        policy,
        data_locality: dl,
        prefetch,
        n_tiles,
        ..Default::default()
    };
    let r = simulate(&p);
    Fig9Row {
        label: label.to_string(),
        makespan: r.makespan,
        speedup_vs_1core: 0.0, // filled by caller
        result: r,
    }
}

pub fn fig9(n_tiles: usize) -> Vec<Fig9Row> {
    let base = single_core_makespan(n_tiles);
    let mut rows = vec![
        run_cfg("12 CPU cores", 12, 0, Policy::Fcfs, false, false, false, n_tiles),
        run_cfg("3 GPUs", 0, 3, Policy::Fcfs, false, false, false, n_tiles),
        run_cfg("3GPU+9CPU FCFS non-pipelined", 9, 3, Policy::Fcfs, true, false, false, n_tiles),
        run_cfg("3GPU+9CPU PATS non-pipelined", 9, 3, Policy::Pats, true, false, false, n_tiles),
        run_cfg("3GPU+9CPU FCFS pipelined", 9, 3, Policy::Fcfs, false, false, false, n_tiles),
        run_cfg("3GPU+9CPU PATS pipelined", 9, 3, Policy::Pats, false, false, false, n_tiles),
    ];
    for r in &mut rows {
        r.speedup_vs_1core = base / r.makespan;
    }
    rows
}

/// Fig. 10: the per-op CPU/GPU split of the PATS pipelined run.
pub fn fig10(n_tiles: usize) -> Vec<(String, f64)> {
    let row = run_cfg("pats", 9, 3, Policy::Pats, false, false, false, n_tiles);
    let mut profile: Vec<(String, f64)> = row
        .result
        .profile
        .iter()
        .map(|(k, &(c, g))| (k.clone(), if c + g > 0 { g as f64 / (c + g) as f64 } else { 0.0 }))
        .collect();
    profile.sort_by(|a, b| a.0.cmp(&b.0));
    profile
}

// ---------------------------------------------------------------------------
// Fig. 11 — DL + prefetch impact on FCFS and PATS
// ---------------------------------------------------------------------------

pub fn fig11(n_tiles: usize) -> Vec<Fig9Row> {
    let base = single_core_makespan(n_tiles);
    let mut rows = vec![
        run_cfg("FCFS non-pipelined", 9, 3, Policy::Fcfs, true, false, false, n_tiles),
        run_cfg("FCFS pipelined", 9, 3, Policy::Fcfs, false, false, false, n_tiles),
        run_cfg("FCFS pipelined +DL", 9, 3, Policy::Fcfs, false, true, false, n_tiles),
        run_cfg("FCFS pipelined +DL +Prefetch", 9, 3, Policy::Fcfs, false, true, true, n_tiles),
        run_cfg("PATS pipelined", 9, 3, Policy::Pats, false, false, false, n_tiles),
        run_cfg("PATS pipelined +DL", 9, 3, Policy::Pats, false, true, false, n_tiles),
        run_cfg("PATS pipelined +DL +Prefetch", 9, 3, Policy::Pats, false, true, true, n_tiles),
    ];
    for r in &mut rows {
        r.speedup_vs_1core = base / r.makespan;
    }
    rows
}

// ---------------------------------------------------------------------------
// Table II + Fig. 12 — demand-driven window size
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct WindowRow {
    pub window: usize,
    pub fcfs_secs: f64,
    pub pats_secs: f64,
    /// per-op GPU fraction under PATS (Fig. 12 series)
    pub pats_gpu_fraction: Vec<(String, f64)>,
}

pub fn table2(windows: &[usize], n_tiles: usize) -> Vec<WindowRow> {
    windows
        .iter()
        .map(|&window| {
            let mk = |policy: Policy| {
                let p = SimParams {
                    policy,
                    window,
                    n_tiles,
                    data_locality: false,
                    prefetch: false,
                    ..Default::default()
                };
                simulate(&p)
            };
            let fcfs = mk(Policy::Fcfs);
            let pats = mk(Policy::Pats);
            let mut fracs: Vec<(String, f64)> = pats
                .profile
                .iter()
                .map(|(k, &(c, g))| {
                    (k.clone(), if c + g > 0 { g as f64 / (c + g) as f64 } else { 0.0 })
                })
                .collect();
            fracs.sort_by(|a, b| a.0.cmp(&b.0));
            WindowRow {
                window,
                fcfs_secs: fcfs.makespan,
                pats_secs: pats.makespan,
                pats_gpu_fraction: fracs,
            }
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Fig. 13 — sensitivity to speedup-estimation error
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Fig13Row {
    pub error_pct: u32,
    pub pats_secs: f64,
    /// same error applied with random (unconfounded) signs — extension
    pub pats_random_secs: f64,
}

pub fn fig13(errors_pct: &[u32], n_tiles: usize) -> (Vec<Fig13Row>, f64) {
    let fcfs = simulate(&SimParams {
        policy: Policy::Fcfs,
        n_tiles,
        data_locality: false,
        prefetch: false,
        ..Default::default()
    })
    .makespan;
    let rows = errors_pct
        .iter()
        .map(|&pct| {
            let e = pct as f32 / 100.0;
            let run = |wf: SimWorkflow| {
                simulate(&SimParams {
                    workflow: wf,
                    policy: Policy::Pats,
                    n_tiles,
                    data_locality: false,
                    prefetch: false,
                    ..Default::default()
                })
                .makespan
            };
            Fig13Row {
                error_pct: pct,
                pats_secs: run(SimWorkflow::pipelined().with_estimation_error(e)),
                pats_random_secs: run(SimWorkflow::pipelined().with_random_error(e, 17)),
            }
        })
        .collect();
    (rows, fcfs)
}

// ---------------------------------------------------------------------------
// Fig. 14 — multi-node strong scaling
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Fig14Row {
    pub nodes: usize,
    pub fcfs_secs: f64,
    pub pats_all_secs: f64,
    pub tiles_per_second: f64,
    /// efficiency vs linear scaling from the smallest node count
    pub efficiency: f64,
    /// efficiency ignoring I/O (compute-only)
    pub compute_efficiency: f64,
}

pub fn fig14(node_counts: &[usize], n_tiles: usize) -> Vec<Fig14Row> {
    let mut rows: Vec<Fig14Row> = Vec::new();
    let mut base: Option<(usize, f64, f64)> = None; // (nodes, pats_secs, compute_secs)
    for &nodes in node_counts {
        let mk = |policy: Policy, dl: bool, pf: bool| {
            simulate(&SimParams {
                policy,
                data_locality: dl,
                prefetch: pf,
                n_nodes: nodes,
                n_tiles,
                ..Default::default()
            })
        };
        let fcfs = mk(Policy::Fcfs, false, false);
        let pats = mk(Policy::Pats, true, true);
        // compute-only proxy: same run with free I/O
        let compute_only = simulate(&SimParams {
            policy: Policy::Pats,
            data_locality: true,
            prefetch: true,
            n_nodes: nodes,
            n_tiles,
            tile_io_base: 0.0,
            ..Default::default()
        });
        let (b_nodes, b_secs, b_csecs) =
            *base.get_or_insert((nodes, pats.makespan, compute_only.makespan));
        let eff = (b_secs * b_nodes as f64) / (pats.makespan * nodes as f64);
        let ceff = (b_csecs * b_nodes as f64) / (compute_only.makespan * nodes as f64);
        rows.push(Fig14Row {
            nodes,
            fcfs_secs: fcfs.makespan,
            pats_all_secs: pats.makespan,
            tiles_per_second: pats.tiles_per_second(),
            efficiency: eff,
            compute_efficiency: ceff,
        });
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    const TILES: usize = 100;

    #[test]
    fn fig8_closest_wins_and_grows_with_gpus() {
        let rows = fig8(TILES);
        assert_eq!(rows.len(), 6);
        let s = |g: usize, p: Placement| {
            rows.iter().find(|r| r.gpus == g && r.placement == p).unwrap().speedup_vs_1core
        };
        for g in 1..=3 {
            assert!(s(g, Placement::Closest) >= s(g, Placement::Os), "gpu count {g}");
        }
        // multi-GPU scales
        assert!(s(3, Placement::Closest) > 2.0 * s(1, Placement::Closest) * 0.8);
        // 1-GPU end-to-end speedup lands in the paper's ballpark (~5.3x)
        let s1 = s(1, Placement::Closest);
        assert!((3.0..8.0).contains(&s1), "1-GPU speedup {s1}");
    }

    #[test]
    fn fig9_shape_holds() {
        let rows = fig9(TILES);
        let get = |label: &str| {
            rows.iter().find(|r| r.label == label).unwrap_or_else(|| panic!("{label}"))
        };
        // 12-core CPU speedup is sub-linear (paper: ~9)
        let cpu12 = get("12 CPU cores").speedup_vs_1core;
        assert!((7.0..11.0).contains(&cpu12), "12-core speedup {cpu12}");
        // PATS pipelined is the best config and beats FCFS pipelined by
        // roughly the paper's 1.33x
        let pats = get("3GPU+9CPU PATS pipelined").makespan;
        let fcfs = get("3GPU+9CPU FCFS pipelined").makespan;
        let ratio = fcfs / pats;
        assert!((1.1..1.7).contains(&ratio), "PATS/FCFS ratio {ratio:.2}");
        // non-pipelined PATS ~ FCFS (variability not exposed)
        let np_ratio = get("3GPU+9CPU FCFS non-pipelined").makespan
            / get("3GPU+9CPU PATS non-pipelined").makespan;
        assert!((0.92..1.08).contains(&np_ratio), "non-pipelined ratio {np_ratio:.2}");
    }

    #[test]
    fn fig10_low_speedup_ops_stay_on_cpu() {
        let profile = fig10(TILES);
        let frac = |name: &str| profile.iter().find(|(n, _)| n == name).unwrap().1;
        assert!(frac("feature_graph") > 0.75, "feature_graph {}", frac("feature_graph"));
        assert!(frac("morph_open") < 0.5, "morph_open {}", frac("morph_open"));
        assert!(frac("hema_prep") == 0.0);
    }

    #[test]
    fn fig11_dl_and_prefetch_shapes() {
        let rows = fig11(TILES);
        let get = |label: &str| rows.iter().find(|r| r.label == label).unwrap().makespan;
        // DL improves both policies (paper: 1.1x FCFS, 1.04x PATS)
        let fcfs_gain = get("FCFS pipelined") / get("FCFS pipelined +DL");
        let pats_gain = get("PATS pipelined") / get("PATS pipelined +DL");
        assert!(fcfs_gain >= 1.01, "DL should help FCFS: {fcfs_gain:.3}");
        assert!(pats_gain >= 1.01, "DL should help PATS: {pats_gain:.3}");
        // paper's headline for this figure: FCFS pipelined + DL beats the
        // non-pipelined version by >= 1.1x
        let vs_np = get("FCFS non-pipelined") / get("FCFS pipelined +DL");
        assert!(vs_np >= 1.1, "pipelined+DL vs non-pipelined: {vs_np:.3}");
        // PATS dominates FCFS at every optimization level
        for (a, b) in [
            ("PATS pipelined", "FCFS pipelined"),
            ("PATS pipelined +DL", "FCFS pipelined +DL"),
        ] {
            assert!(get(a) <= get(b) * 1.02, "{a} should beat {b}");
        }
        // prefetch is a small effect either way (paper: 1.03x for PATS+DL,
        // nil for FCFS+DL; magnitudes diverge here — see EXPERIMENTS.md)
        let pats_pf = get("PATS pipelined +DL") / get("PATS pipelined +DL +Prefetch");
        assert!((0.9..1.15).contains(&pats_pf), "prefetch effect out of band: {pats_pf:.3}");
    }

    #[test]
    fn table2_fcfs_flat_pats_window_knee() {
        // 300 tiles damps tail noise.  Paper: FCFS flat 12..19; PATS poor at
        // 12 improving to ~15.  Our WRM retains scheduling choice at window
        // = #devices, so PATS's knee sits *below* 12 (divergence documented
        // in EXPERIMENTS.md §TableII); the starved regime shows at window 4.
        let rows = table2(&[4, 12, 19], 300);
        let (w4, w12, w19) = (&rows[0], &rows[1], &rows[2]);
        // FCFS flat across the paper's sweep range
        assert!(
            (w12.fcfs_secs / w19.fcfs_secs - 1.0).abs() < 0.05,
            "FCFS window-sensitive: {:.1} vs {:.1}",
            w12.fcfs_secs,
            w19.fcfs_secs
        );
        // a too-small window starves devices and erases PATS's advantage
        assert!(
            w4.pats_secs > w12.pats_secs * 1.05,
            "window 4 should starve PATS: {:.1} vs {:.1}",
            w4.pats_secs,
            w12.pats_secs
        );
        // in the choice-rich regime PATS beats FCFS by the Fig. 9 margin
        for r in [w12, w19] {
            assert!(
                r.fcfs_secs / r.pats_secs > 1.2,
                "window {}: PATS {:.1} vs FCFS {:.1}",
                r.window,
                r.pats_secs,
                r.fcfs_secs
            );
        }
    }

    #[test]
    fn fig13_confounded_error_degrades_bounded() {
        let (rows, fcfs) = fig13(&[0, 60, 100], 300);
        let e0 = rows[0].pats_secs;
        let e60 = rows[1].pats_secs;
        let e100 = rows[2].pats_secs;
        assert!(e60 / e0 < 1.35, "60% error degraded {:.2}x", e60 / e0);
        assert!(e60 / e0 >= 1.0, "error can't speed things up meaningfully");
        // even full inversion stays within ~1.35x of FCFS (paper saw ~10%
        // worse; our profile has stronger speedup heterogeneity, so the
        // adversarial inversion costs more — see EXPERIMENTS.md §Fig13)
        assert!(e100 / fcfs < 1.35, "100% error vs FCFS: {:.2}", e100 / fcfs);
        // random error is no worse than the adversarial confounded one
        // (PATS only needs relative order — ablation beyond the paper)
        assert!(rows[1].pats_random_secs <= e60 * 1.05);
    }

    #[test]
    fn fig14_efficiency_declines_and_compute_stays_high() {
        let rows = fig14(&[4, 32], 4000);
        assert!((rows[0].efficiency - 1.0).abs() < 1e-9);
        assert!(rows[1].efficiency < 1.0);
        // compute-only efficiency stays higher than end-to-end (I/O is the
        // bottleneck), modulo tail noise
        assert!(rows[1].compute_efficiency >= rows[1].efficiency - 0.03);
    }
}
