//! Configuration: typed run settings + the JSON layer they parse from.
//!
//! `htap` is a framework: the launcher (`rust/src/main.rs`) builds a
//! [`RunConfig`] from CLI flags and/or a JSON config file, and every layer
//! (coordinator, sim, benches) consumes the same struct, so the real
//! executor and the calibrated simulator are always configured identically.

pub mod json;

use crate::{Error, Result};
use json::Json;

/// Scheduling policy for the Worker Resource Manager (paper §IV).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Policy {
    /// First-come-first-served baseline.
    Fcfs,
    /// Performance-Aware Task Scheduling: speedup-sorted queue.
    Pats,
}

impl Policy {
    pub fn parse(s: &str) -> Result<Policy> {
        match s.to_ascii_lowercase().as_str() {
            "fcfs" => Ok(Policy::Fcfs),
            "pats" | "priority" => Ok(Policy::Pats),
            other => Err(Error::Config(format!("unknown policy '{other}'"))),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Policy::Fcfs => "FCFS",
            Policy::Pats => "PATS",
        }
    }
}

/// GPU-controller thread placement strategy (paper §IV-A).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    /// Let the OS scheduler place threads.
    Os,
    /// Bind each GPU controller to the CPU socket closest to that GPU.
    Closest,
}

impl Placement {
    pub fn parse(s: &str) -> Result<Placement> {
        match s.to_ascii_lowercase().as_str() {
            "os" => Ok(Placement::Os),
            "closest" => Ok(Placement::Closest),
            other => Err(Error::Config(format!("unknown placement '{other}'"))),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Placement::Os => "OS",
            Placement::Closest => "Closest",
        }
    }
}

/// How the staged Manager maps cold chunks to workers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PartitionMode {
    /// Purely demand-driven (default): first requester wins a cold chunk.
    Demand,
    /// Catalog-aware initial partitioning: contiguous chunk ranges are
    /// range-assigned to the known workers up front (`htap manager
    /// --partition init` homes chunks on worker ids `1..=--workers`),
    /// demand-driven thereafter.
    Init,
}

impl PartitionMode {
    pub fn parse(s: &str) -> Result<PartitionMode> {
        match s.to_ascii_lowercase().as_str() {
            "demand" => Ok(PartitionMode::Demand),
            "init" | "range" => Ok(PartitionMode::Init),
            other => Err(Error::Config(format!("unknown partition mode '{other}'"))),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            PartitionMode::Demand => "demand",
            PartitionMode::Init => "init",
        }
    }
}

/// A cache-capacity budget for the worker's tiered chunk store: a chunk
/// count (the original knob, back-compat) or a byte budget derived from
/// tensor dims.  Parsed from `N` (chunks) or `NKB`/`NMB`/`NGB` (bytes),
/// e.g. `--staging-cap 64MB`.  Byte budgets make the caps meaningful when
/// chunk sizes vary: 32 chunks of 4K×4K tiles is ~2 GB, of 64×64 tiles
/// ~0.5 MB — same knob value, wildly different memory footprints.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheCap {
    /// At most this many chunks resident.
    Chunks(usize),
    /// At most this many payload bytes resident (always keeps >= 1 chunk
    /// so a single over-budget chunk still caches).
    Bytes(u64),
}

impl CacheCap {
    pub fn parse(s: &str) -> Result<CacheCap> {
        let s = s.trim();
        let lower = s.to_ascii_lowercase();
        let (digits, mult) = if let Some(d) = lower.strip_suffix("kb") {
            (d, 1u64 << 10)
        } else if let Some(d) = lower.strip_suffix("mb") {
            (d, 1u64 << 20)
        } else if let Some(d) = lower.strip_suffix("gb") {
            (d, 1u64 << 30)
        } else {
            let n: usize = lower
                .parse()
                .map_err(|_| Error::Config(format!("bad cache cap '{s}' (want N or NMB)")))?;
            return Ok(CacheCap::Chunks(n));
        };
        let n: u64 = digits
            .trim()
            .parse()
            .map_err(|_| Error::Config(format!("bad cache cap '{s}' (want N or NMB)")))?;
        n.checked_mul(mult)
            .map(CacheCap::Bytes)
            .ok_or_else(|| Error::Config(format!("cache cap '{s}' overflows")))
    }

    /// An empty budget caches nothing — rejected at validation.
    pub fn is_zero(&self) -> bool {
        matches!(self, CacheCap::Chunks(0) | CacheCap::Bytes(0))
    }
}

impl From<usize> for CacheCap {
    fn from(n: usize) -> Self {
        CacheCap::Chunks(n)
    }
}

/// Bare integer literals at `impl Into<CacheCap>` call sites infer as
/// `i32`; accept them so `StagingCache::new(src, 4, 0)` keeps reading
/// naturally (negative counts clamp to the 1-chunk floor downstream).
impl From<i32> for CacheCap {
    fn from(n: i32) -> Self {
        CacheCap::Chunks(n.max(0) as usize)
    }
}

impl std::fmt::Display for CacheCap {
    /// Round-trippable with [`CacheCap::parse`]: byte budgets echo in the
    /// largest suffix that divides them exactly (`2GB`, `512KB`), so the
    /// startup banner prints what the user typed.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CacheCap::Chunks(n) => write!(f, "{n} chunks"),
            CacheCap::Bytes(b) if b % (1 << 30) == 0 => write!(f, "{}GB", b >> 30),
            CacheCap::Bytes(b) if b % (1 << 20) == 0 => write!(f, "{}MB", b >> 20),
            CacheCap::Bytes(b) if b % (1 << 10) == 0 => write!(f, "{}KB", b >> 10),
            CacheCap::Bytes(b) => write!(f, "{}KB (+{} bytes)", b >> 10, b % (1 << 10)),
        }
    }
}

/// Pipeline granularity exposed to the runtime (paper Fig. 9 comparison).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Granularity {
    /// Each stage is a single monolithic task (CPU *or* GPU end-to-end).
    NonPipelined,
    /// Stages decompose into fine-grain operations scheduled individually.
    Pipelined,
}

/// One coherent run description, shared by executor / simulator / benches.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Tile edge in pixels (must match an AOT artifact size).
    pub tile_size: usize,
    /// Number of tiles to process.
    pub n_tiles: usize,
    /// CPU compute threads (paper: cores not running GPU controllers).
    pub cpu_workers: usize,
    /// Accelerator ("GPU") controller threads.
    pub gpu_workers: usize,
    /// WRM scheduling policy.
    pub policy: Policy,
    /// GPU-controller placement strategy.
    pub placement: Placement,
    /// Task granularity.
    pub granularity: Granularity,
    /// Demand-driven window: max stage instances assigned per worker.
    pub window: usize,
    /// Data-locality-conscious assignment (paper §IV-C).
    pub data_locality: bool,
    /// Prefetch + async copy (paper §IV-D).
    pub prefetch: bool,
    /// Staging-cache capacity on each worker (staged runs): chunks, or a
    /// byte budget (`NMB`).
    pub staging_cap: CacheCap,
    /// Background chunk-prefetch depth (0 disables the prefetcher thread).
    pub prefetch_depth: usize,
    /// Manager-side locality-aware (chunk-catalog) assignment.
    pub chunk_locality: bool,
    /// Local-disk spill directory: evictions demote instead of dropping
    /// (None = memory tier only, today's behaviour).
    pub spill_dir: Option<String>,
    /// Spill-tier capacity on each worker's local disk: chunks or bytes.
    pub spill_cap: CacheCap,
    /// Replicate-on-steal: a stolen chunk stays multi-homed in the catalog
    /// and the thief stages it eagerly (off = single-owner transfer).
    pub replication: bool,
    /// Initial cold-chunk partition (demand-driven vs range-assigned).
    pub partition: PartitionMode,
    /// Artificial per-chunk read latency in ms (shared-FS stand-in).
    pub read_latency_ms: u64,
    /// Heartbeat interval for distributed workers: how often an
    /// identified worker renews its lease between completions.
    pub heartbeat_ms: u64,
    /// Lease term a worker promises the manager (elastic membership): if
    /// the manager hears nothing for a full term the worker is presumed
    /// dead — catalog purged, in-flight work re-issued.  0 disables lease
    /// tracking (connection-drop detection still applies).
    pub lease_ms: u64,
    /// Service mode (`htap serve`): max jobs running concurrently; the
    /// rest queue in submission order per tenant.
    pub max_jobs: usize,
    /// Service mode: max queued-or-running jobs per tenant — submissions
    /// beyond this are rejected at admission.
    pub tenant_queue_depth: usize,
    /// Service mode: per-tenant staging-cache budget layered on
    /// `staging_cap` (None = tenants share the global budget unfenced).
    pub tenant_quota: Option<CacheCap>,
    /// Observability: write a Chrome `trace_event` JSON (plus a `.jsonl`
    /// event log) of the run to this path (None = tracing disabled; the
    /// record path is then a single atomic load).
    pub trace_out: Option<String>,
    /// Fault-injection plan (`site=rate[@delay_ms][#max],...`): reproducible
    /// chaos at named sites in the net/staging layers (None = no faults; the
    /// probe path is then a single atomic load).  `HTAP_FAULTS` and
    /// `--fault-plan` override this.
    pub fault_plan: Option<String>,
    /// Seed for the fault plan's injection decisions (independent of the
    /// data seed so chaos placement can vary while inputs stay fixed).
    pub fault_seed: u64,
    /// RNG seed for synthetic data.
    pub seed: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            tile_size: 64,
            n_tiles: 16,
            cpu_workers: 2,
            gpu_workers: 1,
            policy: Policy::Pats,
            placement: Placement::Closest,
            granularity: Granularity::Pipelined,
            window: 15,
            data_locality: true,
            prefetch: true,
            staging_cap: CacheCap::Chunks(32),
            prefetch_depth: 4,
            chunk_locality: true,
            spill_dir: None,
            spill_cap: CacheCap::Chunks(256),
            replication: true,
            partition: PartitionMode::Demand,
            read_latency_ms: 0,
            heartbeat_ms: 500,
            lease_ms: 3000,
            max_jobs: 4,
            tenant_queue_depth: 8,
            tenant_quota: None,
            trace_out: None,
            fault_plan: None,
            fault_seed: 0,
            seed: 42,
        }
    }
}

impl RunConfig {
    /// Merge fields present in a JSON object into this config.
    pub fn apply_json(&mut self, j: &Json) -> Result<()> {
        let obj = j
            .as_obj()
            .ok_or_else(|| Error::Config("config root must be an object".into()))?;
        for (k, v) in obj {
            match k.as_str() {
                "tile_size" => self.tile_size = req_usize(v, k)?,
                "n_tiles" => self.n_tiles = req_usize(v, k)?,
                "cpu_workers" => self.cpu_workers = req_usize(v, k)?,
                "gpu_workers" => self.gpu_workers = req_usize(v, k)?,
                "window" => self.window = req_usize(v, k)?,
                "seed" => self.seed = req_usize(v, k)? as u64,
                "policy" => self.policy = Policy::parse(req_str(v, k)?)?,
                "placement" => self.placement = Placement::parse(req_str(v, k)?)?,
                "granularity" => {
                    self.granularity = match req_str(v, k)? {
                        "pipelined" => Granularity::Pipelined,
                        "non-pipelined" | "monolithic" => Granularity::NonPipelined,
                        other => {
                            return Err(Error::Config(format!("bad granularity '{other}'")))
                        }
                    }
                }
                "data_locality" => {
                    self.data_locality =
                        v.as_bool().ok_or_else(|| Error::Config("bad bool".into()))?
                }
                "prefetch" => {
                    self.prefetch = v.as_bool().ok_or_else(|| Error::Config("bad bool".into()))?
                }
                // a number = chunk count (back-compat); a string = parsed
                // budget spec, e.g. "64MB"
                "staging_cap" => self.staging_cap = req_cap(v, k)?,
                "prefetch_depth" => self.prefetch_depth = req_usize(v, k)?,
                "chunk_locality" => {
                    self.chunk_locality =
                        v.as_bool().ok_or_else(|| Error::Config("bad bool".into()))?
                }
                "spill_dir" => self.spill_dir = Some(req_str(v, k)?.to_string()),
                "spill_cap" => self.spill_cap = req_cap(v, k)?,
                "replication" => {
                    self.replication =
                        v.as_bool().ok_or_else(|| Error::Config("bad bool".into()))?
                }
                "partition" => self.partition = PartitionMode::parse(req_str(v, k)?)?,
                "read_latency_ms" => self.read_latency_ms = req_usize(v, k)? as u64,
                "heartbeat_ms" => self.heartbeat_ms = req_usize(v, k)? as u64,
                "lease_ms" => self.lease_ms = req_usize(v, k)? as u64,
                "max_jobs" => self.max_jobs = req_usize(v, k)?,
                "tenant_queue_depth" => self.tenant_queue_depth = req_usize(v, k)?,
                "tenant_quota" => self.tenant_quota = Some(req_cap(v, k)?),
                "trace_out" => self.trace_out = Some(req_str(v, k)?.to_string()),
                "fault_plan" => self.fault_plan = Some(req_str(v, k)?.to_string()),
                "fault_seed" => self.fault_seed = req_usize(v, k)? as u64,
                other => return Err(Error::Config(format!("unknown config key '{other}'"))),
            }
        }
        Ok(())
    }

    /// Load from a JSON file path.
    pub fn from_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let mut cfg = RunConfig::default();
        cfg.apply_json(&Json::parse(&text)?)?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        if self.cpu_workers + self.gpu_workers == 0 {
            return Err(Error::Config("need at least one worker device".into()));
        }
        if self.window == 0 {
            return Err(Error::Config("window must be >= 1".into()));
        }
        if self.staging_cap.is_zero() {
            return Err(Error::Config("staging_cap must be >= 1 (chunks or bytes)".into()));
        }
        if self.spill_cap.is_zero() {
            return Err(Error::Config("spill_cap must be >= 1 (chunks or bytes)".into()));
        }
        if self.max_jobs == 0 {
            return Err(Error::Config("max_jobs must be >= 1".into()));
        }
        if self.tenant_queue_depth == 0 {
            return Err(Error::Config("tenant_queue_depth must be >= 1".into()));
        }
        if self.tenant_quota.is_some_and(|q| q.is_zero()) {
            return Err(Error::Config("tenant_quota must be >= 1 (chunks or bytes)".into()));
        }
        // a worker that heartbeats slower than its lease term would be
        // declared dead while perfectly healthy
        if self.lease_ms > 0 && self.heartbeat_ms >= self.lease_ms {
            return Err(Error::Config(format!(
                "heartbeat_ms ({}) must be < lease_ms ({})",
                self.heartbeat_ms, self.lease_ms
            )));
        }
        // surface a malformed fault plan at config time, not mid-run
        if let Some(plan) = &self.fault_plan {
            crate::faults::FaultPlan::parse(plan, self.fault_seed)?;
        }
        Ok(())
    }
}

fn req_usize(v: &Json, k: &str) -> Result<usize> {
    v.as_usize()
        .ok_or_else(|| Error::Config(format!("'{k}' must be a number")))
}

fn req_cap(v: &Json, k: &str) -> Result<CacheCap> {
    if let Some(n) = v.as_usize() {
        return Ok(CacheCap::Chunks(n));
    }
    match v.as_str() {
        Some(s) => CacheCap::parse(s),
        None => Err(Error::Config(format!("'{k}' must be a number (chunks) or \"NMB\""))),
    }
}

fn req_str<'a>(v: &'a Json, k: &str) -> Result<&'a str> {
    v.as_str()
        .ok_or_else(|| Error::Config(format!("'{k}' must be a string")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn apply_json_overrides() {
        let mut c = RunConfig::default();
        c.apply_json(
            &Json::parse(
                r#"{"tile_size": 256, "policy": "fcfs", "granularity": "non-pipelined",
                    "window": 12, "data_locality": false, "staging_cap": 8,
                    "prefetch_depth": 2, "chunk_locality": false, "read_latency_ms": 5,
                    "spill_dir": "/tmp/spill", "spill_cap": 64, "replication": false,
                    "partition": "init"}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(c.tile_size, 256);
        assert_eq!(c.policy, Policy::Fcfs);
        assert_eq!(c.granularity, Granularity::NonPipelined);
        assert_eq!(c.window, 12);
        assert!(!c.data_locality);
        assert_eq!(c.staging_cap, CacheCap::Chunks(8));
        assert_eq!(c.prefetch_depth, 2);
        assert!(!c.chunk_locality);
        assert_eq!(c.read_latency_ms, 5);
        assert_eq!(c.spill_dir.as_deref(), Some("/tmp/spill"));
        assert_eq!(c.spill_cap, CacheCap::Chunks(64));
        assert!(!c.replication);
        assert_eq!(c.partition, PartitionMode::Init);
    }

    #[test]
    fn zero_staging_cap_invalid() {
        let mut c = RunConfig::default();
        c.staging_cap = CacheCap::Chunks(0);
        assert!(c.validate().is_err());
        c.staging_cap = CacheCap::Bytes(0);
        assert!(c.validate().is_err());
    }

    #[test]
    fn zero_spill_cap_invalid() {
        let mut c = RunConfig::default();
        c.spill_cap = CacheCap::Chunks(0);
        assert!(c.validate().is_err());
    }

    #[test]
    fn cache_cap_parses_chunks_and_bytes() {
        assert_eq!(CacheCap::parse("32").unwrap(), CacheCap::Chunks(32));
        assert_eq!(CacheCap::parse("64MB").unwrap(), CacheCap::Bytes(64 << 20));
        assert_eq!(CacheCap::parse("64mb").unwrap(), CacheCap::Bytes(64 << 20));
        assert_eq!(CacheCap::parse("512KB").unwrap(), CacheCap::Bytes(512 << 10));
        assert_eq!(CacheCap::parse("2GB").unwrap(), CacheCap::Bytes(2 << 30));
        assert!(CacheCap::parse("lots").is_err());
        assert!(CacheCap::parse("12TB").is_err(), "unknown suffix is an error");
        assert!(CacheCap::parse("-3").is_err());
        assert_eq!(CacheCap::parse("64MB").unwrap().to_string(), "64MB");
        assert_eq!(CacheCap::parse("512KB").unwrap().to_string(), "512KB");
        assert_eq!(CacheCap::parse("2GB").unwrap().to_string(), "2GB");
        assert_eq!(CacheCap::parse("7").unwrap().to_string(), "7 chunks");
    }

    #[test]
    fn cache_cap_edge_cases() {
        // "0MB" parses (it's a well-formed budget) but is_zero() flags it,
        // and validate() rejects it like Chunks(0)
        let zero = CacheCap::parse("0MB").unwrap();
        assert_eq!(zero, CacheCap::Bytes(0));
        assert!(zero.is_zero());
        assert!(CacheCap::parse("0").unwrap().is_zero());
        let mut c = RunConfig::default();
        c.staging_cap = zero;
        assert!(c.validate().is_err());

        // byte budgets near u64::MAX must fail on checked_mul, not wrap
        let e = CacheCap::parse("99999999999GB").unwrap_err();
        assert!(e.to_string().contains("overflows"), "unexpected error: {e}");
        let e = CacheCap::parse("18446744073709551615MB").unwrap_err();
        assert!(e.to_string().contains("overflows"), "unexpected error: {e}");
        // the largest representable budgets still parse
        assert_eq!(CacheCap::parse("17179869183GB").unwrap(), CacheCap::Bytes(17179869183 << 30));

        // garbage suffixes / digits are parse errors with the full input echoed
        // note "+4MB" is NOT here: u64's FromStr accepts a leading '+'
        for bad in ["64MBB", "MB", "1.5MB", "-2MB", "", " ", "0x10MB"] {
            let e = CacheCap::parse(bad).unwrap_err();
            assert!(
                e.to_string().contains("bad cache cap"),
                "'{bad}' gave unexpected error: {e}"
            );
        }
    }

    #[test]
    fn json_caps_accept_numbers_and_budget_strings() {
        let mut c = RunConfig::default();
        c.apply_json(&Json::parse(r#"{"staging_cap": "16MB", "spill_cap": "1GB"}"#).unwrap())
            .unwrap();
        assert_eq!(c.staging_cap, CacheCap::Bytes(16 << 20));
        assert_eq!(c.spill_cap, CacheCap::Bytes(1 << 30));
        assert!(c
            .apply_json(&Json::parse(r#"{"staging_cap": "sixteen"}"#).unwrap())
            .is_err());
    }

    #[test]
    fn partition_mode_parses() {
        assert_eq!(PartitionMode::parse("demand").unwrap(), PartitionMode::Demand);
        assert_eq!(PartitionMode::parse("INIT").unwrap(), PartitionMode::Init);
        assert_eq!(PartitionMode::parse("range").unwrap(), PartitionMode::Init);
        assert!(PartitionMode::parse("static").is_err());
    }

    #[test]
    fn unknown_key_rejected() {
        let mut c = RunConfig::default();
        assert!(c.apply_json(&Json::parse(r#"{"wat": 1}"#).unwrap()).is_err());
    }

    #[test]
    fn lease_knobs_parse_and_validate() {
        let mut c = RunConfig::default();
        c.apply_json(&Json::parse(r#"{"heartbeat_ms": 200, "lease_ms": 1000}"#).unwrap())
            .unwrap();
        assert_eq!((c.heartbeat_ms, c.lease_ms), (200, 1000));
        c.validate().unwrap();
        // a heartbeat slower than the lease always expires: rejected
        c.heartbeat_ms = 1000;
        assert!(c.validate().is_err());
        c.heartbeat_ms = 2000;
        assert!(c.validate().is_err());
        // lease 0 = tracking off; any heartbeat value is then fine
        c.lease_ms = 0;
        c.validate().unwrap();
    }

    #[test]
    fn service_knobs_parse_and_validate() {
        let mut c = RunConfig::default();
        c.apply_json(
            &Json::parse(r#"{"max_jobs": 2, "tenant_queue_depth": 3, "tenant_quota": "8MB"}"#)
                .unwrap(),
        )
        .unwrap();
        assert_eq!(c.max_jobs, 2);
        assert_eq!(c.tenant_queue_depth, 3);
        assert_eq!(c.tenant_quota, Some(CacheCap::Bytes(8 << 20)));
        c.validate().unwrap();
        c.max_jobs = 0;
        assert!(c.validate().is_err());
        c.max_jobs = 1;
        c.tenant_queue_depth = 0;
        assert!(c.validate().is_err());
        c.tenant_queue_depth = 1;
        c.tenant_quota = Some(CacheCap::Chunks(0));
        assert!(c.validate().is_err());
    }

    #[test]
    fn trace_out_parses() {
        let mut c = RunConfig::default();
        assert_eq!(c.trace_out, None);
        c.apply_json(&Json::parse(r#"{"trace_out": "/tmp/trace.json"}"#).unwrap()).unwrap();
        assert_eq!(c.trace_out.as_deref(), Some("/tmp/trace.json"));
        c.validate().unwrap();
        assert!(c.apply_json(&Json::parse(r#"{"trace_out": 3}"#).unwrap()).is_err());
    }

    #[test]
    fn zero_devices_invalid() {
        let mut c = RunConfig::default();
        c.cpu_workers = 0;
        c.gpu_workers = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn fault_knobs_parse_and_validate() {
        let mut c = RunConfig::default();
        c.apply_json(
            &Json::parse(r#"{"fault_plan": "frame-drop=0.05#3,spill-io=1@10", "fault_seed": 7}"#)
                .unwrap(),
        )
        .unwrap();
        assert_eq!(c.fault_plan.as_deref(), Some("frame-drop=0.05#3,spill-io=1@10"));
        assert_eq!(c.fault_seed, 7);
        c.validate().unwrap();
        // malformed plans are a config error, caught before any run starts
        c.fault_plan = Some("no-such-site=1".into());
        assert!(c.validate().is_err());
    }

    #[test]
    fn policy_parse_aliases() {
        assert_eq!(Policy::parse("PRIORITY").unwrap(), Policy::Pats);
        assert_eq!(Policy::parse("fcfs").unwrap(), Policy::Fcfs);
        assert!(Policy::parse("lifo").is_err());
    }
}
