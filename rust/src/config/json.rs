//! Minimal JSON parser (serde is not in the offline crate set).
//!
//! Supports the full JSON grammar minus `\u` surrogate pairs (sufficient for
//! the artifact manifest and config files, which are ASCII).  The parser is
//! recursive-descent over bytes; numbers parse as f64.

use crate::{Error, Result};
use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document from a string.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(Error::Config(format!("trailing JSON at byte {}", p.pos)));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj.get("key")` that threads through as Result with a useful message.
    pub fn field(&self, key: &str) -> Result<&Json> {
        self.as_obj()
            .and_then(|o| o.get(key))
            .ok_or_else(|| Error::Config(format!("missing JSON field '{key}'")))
    }
}

impl fmt::Display for Json {
    /// Serialise back to compact JSON (used by the TCP protocol and configs).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(Error::Config(format!(
                "expected '{}' at byte {} (got {:?})",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(Error::Config(format!("bad JSON literal at byte {}", self.pos)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(Error::Config(format!(
                "unexpected JSON byte {:?} at {}",
                other.map(|c| c as char),
                self.pos
            ))),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(Error::Config("unterminated JSON string".into())),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self
                        .peek()
                        .ok_or_else(|| Error::Config("bad escape".into()))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| Error::Config("bad \\u escape".into()))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| Error::Config("bad \\u escape".into()))?,
                                16,
                            )
                            .map_err(|_| Error::Config("bad \\u escape".into()))?;
                            self.pos += 4;
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| Error::Config("bad codepoint".into()))?,
                            );
                        }
                        _ => return Err(Error::Config("unknown escape".into())),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 code point.
                    let rest = &self.bytes[self.pos..];
                    let s = std::str::from_utf8(rest)
                        .map_err(|_| Error::Config("invalid UTF-8 in JSON".into()))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| Error::Config(format!("bad JSON number '{text}'")))
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(Error::Config(format!("bad array at byte {}", self.pos))),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(Error::Config(format!("bad object at byte {}", self.pos))),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x\n"}], "c": false}"#).unwrap();
        let a = v.field("a").unwrap().as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(1.0));
        assert_eq!(a[2].field("b").unwrap().as_str(), Some("x\n"));
        assert_eq!(v.field("c").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn roundtrips_display() {
        let src = r#"{"a":[1,2.5,"x"],"b":{"c":null,"d":true}}"#;
        let v = Json::parse(src).unwrap();
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("line1\nline2\t\"q\"\\".into());
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn parses_real_manifest() {
        // shape of python/compile/aot.py output
        let src = r#"{"tile_sizes": [64, 256], "modules": [
            {"name": "morph_open", "size": 64, "file": "morph_open_64.hlo.txt",
             "inputs": [{"shape": [64, 64], "dtype": "float32"}],
             "outputs": [{"shape": [64, 64], "dtype": "float32"}]}]}"#;
        let v = Json::parse(src).unwrap();
        let m = &v.field("modules").unwrap().as_arr().unwrap()[0];
        assert_eq!(m.field("name").unwrap().as_str(), Some("morph_open"));
        assert_eq!(
            m.field("inputs").unwrap().as_arr().unwrap()[0]
                .field("shape")
                .unwrap()
                .as_arr()
                .unwrap()
                .len(),
            2
        );
    }
}
