//! Architecture-aware placement of GPU controller threads (paper §IV-A).
//!
//! On a Keeneland node (Fig. 6) two Westmere sockets connect to three GPUs
//! through two I/O hubs: GPU 0 hangs off socket 0's IOH; GPUs 1 and 2 off
//! socket 1's.  The *Closest* strategy pins each GPU controller thread to a
//! core of the socket with the fewest QPI/IOH links to its GPU; *OS* leaves
//! placement to the kernel scheduler.
//!
//! The same [`NodeTopology`] model feeds the simulator's transfer-cost
//! model (extra links -> lower effective PCIe bandwidth, reproducing the
//! 3/6/8% Fig. 8 deltas) and, on the real executor, drives an actual
//! `sched_setaffinity` call.

use crate::config::Placement;

/// CPU-socket / GPU-link topology of one hybrid node.
#[derive(Debug, Clone)]
pub struct NodeTopology {
    /// Core ids per socket.
    pub sockets: Vec<Vec<usize>>,
    /// For each GPU: number of links from each socket (index = socket id).
    /// Lower = closer.
    pub gpu_links: Vec<Vec<u32>>,
}

impl NodeTopology {
    /// The Keeneland node of paper Fig. 6: 2 sockets x 6 cores, 3 GPUs.
    /// GPU 0 is adjacent to socket 0 (1 link) and 2 links from socket 1;
    /// GPUs 1, 2 are adjacent to socket 1.
    pub fn keeneland() -> Self {
        NodeTopology {
            sockets: vec![(0..6).collect(), (6..12).collect()],
            gpu_links: vec![vec![1, 2], vec![2, 1], vec![2, 1]],
        }
    }

    /// A degenerate single-socket topology sized to this machine.
    pub fn host() -> Self {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        NodeTopology { sockets: vec![(0..n).collect()], gpu_links: vec![vec![1]; 3] }
    }

    pub fn n_cores(&self) -> usize {
        self.sockets.iter().map(|s| s.len()).sum()
    }

    /// Socket closest to `gpu` (fewest links).
    pub fn closest_socket(&self, gpu: usize) -> usize {
        let links = &self.gpu_links[gpu % self.gpu_links.len()];
        links
            .iter()
            .enumerate()
            .min_by_key(|(_, &l)| l)
            .map(|(s, _)| s)
            .unwrap_or(0)
    }

    /// Number of links traversed when `gpu`'s controller runs on `socket`.
    pub fn links(&self, gpu: usize, socket: usize) -> u32 {
        self.gpu_links[gpu % self.gpu_links.len()][socket % self.sockets.len()]
    }

    /// Core assignment for GPU controller threads under a strategy.
    ///
    /// * `Closest` — round-robin over the closest socket's cores.
    /// * `Os` — `None`: let the OS place the thread.
    pub fn gpu_controller_core(&self, gpu: usize, strategy: Placement) -> Option<usize> {
        match strategy {
            Placement::Os => None,
            Placement::Closest => {
                let socket = self.closest_socket(gpu);
                let cores = &self.sockets[socket];
                Some(cores[gpu % cores.len()])
            }
        }
    }

    /// Effective number of links for a transfer under a strategy, assuming
    /// the OS scheduler places controllers uniformly at random (expected
    /// value used by the simulator's Fig. 8 model).
    pub fn expected_links(&self, gpu: usize, strategy: Placement) -> f64 {
        match strategy {
            Placement::Closest => {
                self.links(gpu, self.closest_socket(gpu)) as f64
            }
            Placement::Os => {
                let total: u32 = (0..self.sockets.len()).map(|s| self.links(gpu, s)).sum();
                total as f64 / self.sockets.len() as f64
            }
        }
    }
}

/// Pin the calling thread to one core (returns false if the core doesn't
/// exist or the platform doesn't support affinity).
///
/// The `libc` crate is not in the offline dependency set, so the Linux
/// implementation declares `sched_setaffinity` directly against the C
/// library std already links.  `cpu_set_t` is a 1024-bit mask.
#[cfg(target_os = "linux")]
pub fn pin_to_core(core: usize) -> bool {
    const CPU_SETSIZE: usize = 1024;
    extern "C" {
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
    }
    let mut mask = [0u64; CPU_SETSIZE / 64];
    let c = core % CPU_SETSIZE;
    mask[c / 64] |= 1u64 << (c % 64);
    unsafe { sched_setaffinity(0, std::mem::size_of_val(&mask), mask.as_ptr()) == 0 }
}

/// Non-Linux fallback: affinity is not applied; the OS places the thread.
#[cfg(not(target_os = "linux"))]
pub fn pin_to_core(_core: usize) -> bool {
    false
}

/// Apply the placement strategy for one GPU controller thread (call from
/// within the thread).  Returns the pinned core, if any.
pub fn place_gpu_controller(
    topo: &NodeTopology,
    gpu: usize,
    strategy: Placement,
) -> Option<usize> {
    let core = topo.gpu_controller_core(gpu, strategy)?;
    if pin_to_core(core) {
        Some(core)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeneland_shape() {
        let t = NodeTopology::keeneland();
        assert_eq!(t.n_cores(), 12);
        assert_eq!(t.closest_socket(0), 0);
        assert_eq!(t.closest_socket(1), 1);
        assert_eq!(t.closest_socket(2), 1);
    }

    #[test]
    fn closest_assigns_gpu0_to_socket0_cores() {
        let t = NodeTopology::keeneland();
        let c0 = t.gpu_controller_core(0, Placement::Closest).unwrap();
        assert!(t.sockets[0].contains(&c0));
        let c1 = t.gpu_controller_core(1, Placement::Closest).unwrap();
        let c2 = t.gpu_controller_core(2, Placement::Closest).unwrap();
        assert!(t.sockets[1].contains(&c1));
        assert!(t.sockets[1].contains(&c2));
        assert_ne!(c1, c2, "controllers spread over distinct cores");
    }

    #[test]
    fn os_strategy_does_not_pin() {
        let t = NodeTopology::keeneland();
        assert!(t.gpu_controller_core(0, Placement::Os).is_none());
    }

    #[test]
    fn expected_links_closest_beats_os() {
        let t = NodeTopology::keeneland();
        for gpu in 0..3 {
            assert!(t.expected_links(gpu, Placement::Closest) < t.expected_links(gpu, Placement::Os));
        }
        assert_eq!(t.expected_links(0, Placement::Closest), 1.0);
        assert_eq!(t.expected_links(0, Placement::Os), 1.5);
    }

    #[test]
    #[cfg(target_os = "linux")]
    fn pin_to_core_zero_succeeds() {
        // core 0 always exists
        assert!(pin_to_core(0));
    }
}
