//! The Worker (paper §III-B, Fig. 5): a multi-thread process combining the
//! Worker Communication Controller (WCC) with the Worker Resource Manager.
//!
//! The WCC is split into two cooperating threads so that requesting new
//! stage instances overlaps executing the current ones (the paper's "the
//! assignment of a stage instance and the retrieval of necessary input data
//! chunks can be overlapped with the processing of an already assigned
//! stage instance"):
//!
//! * **requester** — keeps up to `window` stage instances in flight by
//!   demand-driven requests to the Manager.  With `prefetch` off it only
//!   refills when the Worker drains (the naive cyclic pattern).
//! * **completer** — drains WRM completions and reports them back.

use super::manager::{Assignment, WorkRequest, WorkSource};
use super::placement::NodeTopology;
use super::wrm::{spawn_device_threads, Wrm};
use crate::config::RunConfig;
use crate::data::staging::StagingCache;
use crate::dataflow::{StageInput, Workflow};
use crate::metrics::MetricsHub;
use crate::runtime::calibrate::SharedProfiles;
use crate::runtime::sync::{self, Condvar, Mutex};
use crate::runtime::ArtifactManifest;
use crate::service::job_of;
use crate::{Error, Result};
use std::collections::HashMap;
use std::sync::Arc;

/// How long an idle service worker sleeps before polling again (the
/// manager answered `Idle`: nothing assignable *right now*).
const IDLE_POLL_MS: u64 = 100;

struct Flight {
    in_flight: usize,
    requester_done: bool,
    failed: Option<String>,
}

/// Resolves a job id to its `(tenant, workflow)` — service-mode workers
/// fetch the spec over the wire (`GetJob`) and compile it locally.
pub type JobResolver = Arc<dyn Fn(u64) -> Result<(String, Arc<Workflow>)> + Send + Sync>;

/// Optional behaviours of a worker run beyond the single-job defaults.
#[derive(Default, Clone)]
pub struct WorkerOpts {
    /// Service mode: resolve the workflow behind a job-tagged assignment.
    /// Resolved specs are cached for the worker's lifetime.  `None` means
    /// every assignment executes against the run's default workflow.
    pub resolver: Option<JobResolver>,
    /// Graceful-drain trigger (`htap worker --drain-on ...`): checked
    /// before each work request and during idle polls.  When it first
    /// returns true the worker stops requesting, finishes its in-flight
    /// stage instances, demotes its memory tier to the spill tier, and
    /// departs with `Goodbye`.
    pub drain: Option<Arc<dyn Fn() -> bool + Send + Sync>>,
}

/// Worker-side staging context for a staged (deferred-chunk) run: the
/// chunk cache + prefetcher, this worker's identity, and how many prefetch
/// hints to ask the Manager for per request.
pub struct WorkerStaging {
    pub cache: Arc<StagingCache>,
    /// stable nonzero worker id (the Manager's catalog key)
    pub worker_id: u64,
    /// prefetch-hint budget per work request
    pub prefetch_budget: usize,
}

/// Splice staged chunk payloads into a deferred assignment: walk the
/// stage's declared inputs, drawing `Chunk` slots from the staging cache
/// and `Upstream` slots from the values the Manager shipped.
fn materialize_inputs(
    workflow: &Workflow,
    a: Assignment,
    staging: Option<&WorkerStaging>,
    tenant: &str,
) -> Result<Assignment> {
    if !a.needs_chunk {
        return Ok(a);
    }
    let Some(stg) = staging else {
        return Err(Error::Scheduler(
            "manager defers chunk payloads but this worker has no chunk source \
             (staging is not configured)"
            .into(),
        ));
    };
    let Assignment { instance_id, stage_idx, chunk, inputs, needs_chunk, locality, replica } = a;
    // tenant attribution (service mode): the fetch bills the submitting
    // tenant's staging quota; an empty tenant is the single-job path
    let payload = stg.cache.get_for(tenant, chunk)?;
    let mut upstream = inputs.into_iter();
    let mut full = Vec::new();
    for input in &workflow.stages[stage_idx].inputs {
        match input {
            // splice by handle: the cache payload is Arc-shared, so every
            // concurrent instance of this chunk reads one buffer
            StageInput::Chunk => full.extend(payload.iter().cloned()),
            StageInput::ChunkPart(k) => full.push(payload.get(*k).cloned().ok_or_else(|| {
                Error::Scheduler(format!(
                    "chunk {chunk} payload has {} value(s), no part {k}",
                    payload.len()
                ))
            })?),
            StageInput::Upstream { .. } => full.push(upstream.next().ok_or_else(|| {
                Error::Scheduler(format!("assignment {instance_id} missing an upstream value"))
            })?),
        }
    }
    Ok(Assignment { instance_id, stage_idx, chunk, inputs: full, needs_chunk, locality, replica })
}

/// Run one Worker against a work source until the workflow completes,
/// recording task completion times into a fresh online profile store.
///
/// Blocks the calling thread; spawns `cpu_workers` + `gpu_workers` device
/// threads plus the requester thread internally.
pub fn run_worker(
    source: Arc<dyn WorkSource>,
    workflow: Arc<Workflow>,
    cfg: RunConfig,
    manifest: Arc<ArtifactManifest>,
    metrics: Arc<MetricsHub>,
    stage_bindings: HashMap<String, String>,
) -> Result<()> {
    run_worker_profiled(
        source,
        workflow,
        cfg,
        manifest,
        metrics,
        stage_bindings,
        SharedProfiles::fresh(),
    )
}

/// [`run_worker`] with a caller-supplied profile store: seed it from a
/// calibrated `profiles.json` and/or read the EWMA estimates back after
/// the run.  Completion times fold into the store as the run progresses,
/// so PATS ready-queue ordering tracks the measured host.
pub fn run_worker_profiled(
    source: Arc<dyn WorkSource>,
    workflow: Arc<Workflow>,
    cfg: RunConfig,
    manifest: Arc<ArtifactManifest>,
    metrics: Arc<MetricsHub>,
    stage_bindings: HashMap<String, String>,
    profiles: Arc<SharedProfiles>,
) -> Result<()> {
    run_worker_staged(source, workflow, cfg, manifest, metrics, stage_bindings, profiles, None)
}

/// [`run_worker_profiled`] with an optional staging context.  With
/// `Some(staging)` the Worker identifies itself to the Manager, reports
/// its staged/evicted chunks, warms the cache with every queued
/// assignment's chunk plus the Manager's prefetch hints (the paper's
/// asynchronous data copy, lifted to node-level shared-FS reads), and
/// splices staged payloads into deferred assignments before submitting
/// them to the WRM.  The cache's counters are folded into `metrics` when
/// the run ends.
#[allow(clippy::too_many_arguments)]
pub fn run_worker_staged(
    source: Arc<dyn WorkSource>,
    workflow: Arc<Workflow>,
    cfg: RunConfig,
    manifest: Arc<ArtifactManifest>,
    metrics: Arc<MetricsHub>,
    stage_bindings: HashMap<String, String>,
    profiles: Arc<SharedProfiles>,
    staging: Option<WorkerStaging>,
) -> Result<()> {
    run_worker_opts(
        source,
        workflow,
        cfg,
        manifest,
        metrics,
        stage_bindings,
        profiles,
        staging,
        WorkerOpts::default(),
    )
}

/// [`run_worker_staged`] with [`WorkerOpts`]: a job resolver (service
/// mode — assignments carry job-tagged instance ids and the worker
/// executes each against its own workflow) and/or a graceful-drain
/// trigger.  Service workers also understand the manager's `Idle` reply:
/// they sleep briefly and poll again instead of treating an empty batch
/// as workflow completion.
#[allow(clippy::too_many_arguments)]
pub fn run_worker_opts(
    source: Arc<dyn WorkSource>,
    workflow: Arc<Workflow>,
    cfg: RunConfig,
    manifest: Arc<ArtifactManifest>,
    metrics: Arc<MetricsHub>,
    stage_bindings: HashMap<String, String>,
    profiles: Arc<SharedProfiles>,
    staging: Option<WorkerStaging>,
    opts: WorkerOpts,
) -> Result<()> {
    cfg.validate()?;
    let topo = NodeTopology::host();
    let wrm = Wrm::new(
        workflow.clone(),
        cfg.clone(),
        manifest,
        metrics.clone(),
        stage_bindings,
        profiles,
    );
    let device_threads = spawn_device_threads(&wrm, &cfg, &topo);

    let flight = Arc::new((Mutex::new(Flight { in_flight: 0, requester_done: false, failed: None }), Condvar::new()));
    let staging = staging.map(Arc::new);

    // elastic membership: an identified (staged) worker announces itself
    // and — when lease tracking is on — keeps its lease warm with a
    // heartbeat thread.  Requests and completions also renew the lease;
    // the heartbeat covers long compute gaps, so an idle-but-alive worker
    // is never presumed dead (`--lease-ms 0` opts out).
    let stop_heartbeat = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let heartbeat = match &staging {
        Some(s) => {
            source.register(s.worker_id, cfg.lease_ms);
            // On reconnect the manager at the far end may be a freshly
            // promoted standby whose catalog is checkpoint-stale: re-stage
            // everything this worker actually holds so the next staged
            // delta re-advertises the full tiered holding set.
            source.set_resync({
                let cache = s.cache.clone();
                Arc::new(move || cache.resync_staged())
            });
            if cfg.lease_ms > 0 {
                let stop = stop_heartbeat.clone();
                let src = source.clone();
                let worker_id = s.worker_id;
                let tracer = metrics.tracer().clone();
                let tick = std::time::Duration::from_millis(cfg.heartbeat_ms.max(1));
                // fine-grained sleep so shutdown never waits a full tick
                let step = std::time::Duration::from_millis(25).min(tick);
                Some(
                    sync::thread::Builder::new()
                        .name("htap-wcc-hb".into())
                        .spawn(move || {
                            let mut since_beat = std::time::Duration::ZERO;
                            while !stop.load(std::sync::atomic::Ordering::Acquire) {
                                std::thread::sleep(step);
                                since_beat += step;
                                if since_beat >= tick {
                                    since_beat = std::time::Duration::ZERO;
                                    src.heartbeat(worker_id);
                                    // trace shipping piggybacks on the
                                    // heartbeat cadence: drain this
                                    // worker's rings and batch them to the
                                    // manager (a no-op when tracing is off)
                                    let events = tracer.drain();
                                    if !events.is_empty() {
                                        src.trace_events(worker_id, events);
                                    }
                                }
                            }
                        })
                        // lint: allow(panic) — failing to spawn at startup is fatal
                        .expect("spawn heartbeater"),
                )
            } else {
                None
            }
        }
        None => None,
    };

    // drain marker: set by the requester when the drain trigger fires, so
    // the clean-exit path knows to demote the memory tier before Goodbye
    let drained = Arc::new(std::sync::atomic::AtomicBool::new(false));

    // requester thread
    let requester = {
        let flight = flight.clone();
        let wrm = wrm.clone();
        let source = source.clone();
        let workflow = workflow.clone();
        let staging = staging.clone();
        let window = cfg.window;
        let prefetch = cfg.prefetch;
        let resolver = opts.resolver.clone();
        let drain = opts.drain.clone();
        let drained = drained.clone();
        sync::thread::Builder::new()
            .name("htap-wcc-req".into())
            .spawn(move || {
                let (lock, cv) = &*flight;
                // service mode: resolved (tenant, workflow) per job id,
                // cached for this worker's lifetime
                let mut jobs: HashMap<u64, (String, Arc<Workflow>)> = HashMap::new();
                loop {
                    // wait for capacity.  The flight record is plain
                    // counters, so poisoning (a panicked holder) recovers
                    // the guard instead of cascading the panic.
                    let capacity = {
                        let mut fl = sync::lock_clean(lock);
                        loop {
                            if fl.failed.is_some() {
                                fl.requester_done = true;
                                cv.notify_all();
                                wrm.poke();
                                return;
                            }
                            let cap = window.saturating_sub(fl.in_flight);
                            let ready = if prefetch { cap > 0 } else { fl.in_flight == 0 };
                            if ready {
                                break cap.max(1);
                            }
                            fl = match cv.wait(fl) {
                                Ok(g) => g,
                                Err(p) => p.into_inner(),
                            };
                        }
                    };
                    // graceful drain: stop asking for work.  In-flight
                    // instances finish normally; the clean-exit path then
                    // demotes the memory tier and departs with Goodbye.
                    if drain.as_ref().is_some_and(|d| d()) {
                        drained.store(true, std::sync::atomic::Ordering::Release);
                        let mut fl = sync::lock_clean(lock);
                        fl.requester_done = true;
                        cv.notify_all();
                        drop(fl);
                        wrm.poke();
                        return;
                    }
                    let req = match &staging {
                        Some(s) => {
                            let (staged_add, staged_drop, demoted) =
                                s.cache.take_staged_delta();
                            WorkRequest {
                                capacity,
                                worker: s.worker_id,
                                staged_add,
                                staged_drop,
                                demoted,
                                prefetch_budget: s.prefetch_budget,
                            }
                        }
                        None => WorkRequest::anonymous(capacity),
                    };
                    let batch = source.request_work(&req);
                    if batch.idle && batch.assignments.is_empty() {
                        // service lull: nothing assignable right now, but
                        // more jobs may arrive — poll again shortly (the
                        // drain trigger stays responsive across polls)
                        std::thread::sleep(std::time::Duration::from_millis(IDLE_POLL_MS));
                        continue;
                    }
                    if batch.assignments.is_empty() {
                        let mut fl = sync::lock_clean(lock);
                        fl.requester_done = true;
                        cv.notify_all();
                        drop(fl);
                        wrm.poke();
                        return;
                    }
                    if let Some(s) = &staging {
                        // steal replicas first (counted), then warm the
                        // cache with this batch's chunks and the manager's
                        // hints; the prefetcher reads them while the
                        // device threads execute the current instances
                        s.cache.prefetch_replicas(&batch.replicate);
                        let mut warm: Vec<u64> = batch
                            .assignments
                            .iter()
                            .filter(|a| a.needs_chunk)
                            .map(|a| a.chunk)
                            .collect();
                        warm.extend(batch.prefetch.iter().copied());
                        s.cache.prefetch(&warm);
                    }
                    {
                        let mut fl = sync::lock_clean(lock);
                        fl.in_flight += batch.assignments.len();
                    }
                    for a in batch.assignments {
                        // service mode tags instance ids with a job id;
                        // job 0 is the single-manager legacy path and runs
                        // against the worker's default workflow
                        let job = job_of(a.instance_id);
                        let resolved = if job == 0 {
                            Ok((String::new(), workflow.clone()))
                        } else if let Some(hit) = jobs.get(&job) {
                            Ok(hit.clone())
                        } else {
                            match &resolver {
                                Some(r) => match r(job) {
                                    Ok(spec) => {
                                        jobs.insert(job, spec.clone());
                                        Ok(spec)
                                    }
                                    Err(e) => Err(e),
                                },
                                None => Err(Error::Scheduler(format!(
                                    "assignment tagged with job {job} but this worker \
                                     has no job resolver"
                                ))),
                            }
                        };
                        let submitted = resolved.and_then(|(tenant, wf)| {
                            materialize_inputs(&wf, a, staging.as_deref(), &tenant)
                                .map(|a| wrm.submit_to(a, wf.clone()))
                        });
                        if let Err(e) = submitted {
                            let mut fl = sync::lock_clean(lock);
                            fl.failed = Some(e.to_string());
                            fl.requester_done = true;
                            cv.notify_all();
                            drop(fl);
                            wrm.poke();
                            return;
                        }
                    }
                }
            })
            // lint: allow(panic) — failing to spawn at startup is fatal
            .expect("spawn requester")
    };

    // fold the staging counters into metrics + stop the prefetcher on exit
    let finish_staging = |staging: &Option<Arc<WorkerStaging>>| {
        if let Some(s) = staging {
            metrics.record_staging(&s.cache.report());
            s.cache.shutdown();
        }
    };

    // stop the heartbeat thread; on a clean exit, say goodbye so the
    // Manager deregisters immediately instead of waiting out the lease
    let finish_membership = |hb: Option<sync::thread::JoinHandle<()>>, clean: bool| {
        stop_heartbeat.store(true, std::sync::atomic::Ordering::Release);
        if let Some(h) = hb {
            let _ = h.join();
        }
        if let Some(s) = &staging {
            // final trace drain: ship whatever the heartbeat cadence
            // hasn't (also the only shipment when leases are off).  Runs
            // on failure exits too — the tail of a failing run is exactly
            // what the merged trace is for.
            let events = metrics.tracer().drain();
            if !events.is_empty() {
                source.trace_events(s.worker_id, events);
            }
            if clean {
                source.goodbye(s.worker_id);
            }
        }
    };

    // completer loop (this thread)
    let (lock, cv) = &*flight;
    loop {
        let events = wrm.wait_completions();
        let mut newly_done = 0usize;
        for (id, result) in events {
            match result {
                Ok(outs) => {
                    source.complete(id, outs);
                    newly_done += 1;
                }
                Err(msg) => {
                    let mut fl = sync::lock_clean(lock);
                    fl.failed = Some(msg);
                    cv.notify_all();
                }
            }
        }
        let mut fl = sync::lock_clean(lock);
        fl.in_flight = fl.in_flight.saturating_sub(newly_done);
        cv.notify_all();
        let finished = fl.in_flight == 0 && fl.requester_done;
        let failed = fl.failed.clone();
        drop(fl);
        if let Some(msg) = failed {
            wrm.shutdown();
            for h in device_threads {
                let _ = h.join();
            }
            let _ = requester.join();
            finish_staging(&staging);
            // no goodbye: the failure already rode back via `fail`, and a
            // clean departure would mask which worker broke the run
            finish_membership(heartbeat, false);
            return Err(Error::Scheduler(format!("worker failed: {msg}")));
        }
        if finished {
            break;
        }
    }
    wrm.shutdown();
    for h in device_threads {
        let _ = h.join();
    }
    let _ = requester.join();
    if drained.load(std::sync::atomic::Ordering::Acquire) {
        // graceful drain: push the memory tier down to the spill tier so a
        // warm restart on this host finds the working set on local disk
        if let Some(s) = &staging {
            let n = s.cache.demote_all();
            if n > 0 {
                eprintln!("htap worker: drained; demoted {n} staged chunks to the spill tier");
            }
        }
    }
    finish_staging(&staging);
    finish_membership(heartbeat, true);
    Ok(())
}
