//! The Worker (paper §III-B, Fig. 5): a multi-thread process combining the
//! Worker Communication Controller (WCC) with the Worker Resource Manager.
//!
//! The WCC is split into two cooperating threads so that requesting new
//! stage instances overlaps executing the current ones (the paper's "the
//! assignment of a stage instance and the retrieval of necessary input data
//! chunks can be overlapped with the processing of an already assigned
//! stage instance"):
//!
//! * **requester** — keeps up to `window` stage instances in flight by
//!   demand-driven requests to the Manager.  With `prefetch` off it only
//!   refills when the Worker drains (the naive cyclic pattern).
//! * **completer** — drains WRM completions and reports them back.

use super::manager::WorkSource;
use super::placement::NodeTopology;
use super::wrm::{spawn_device_threads, Wrm};
use crate::config::RunConfig;
use crate::dataflow::Workflow;
use crate::metrics::MetricsHub;
use crate::runtime::calibrate::SharedProfiles;
use crate::runtime::ArtifactManifest;
use crate::{Error, Result};
use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};

struct Flight {
    in_flight: usize,
    requester_done: bool,
    failed: Option<String>,
}

/// Run one Worker against a work source until the workflow completes,
/// recording task completion times into a fresh online profile store.
///
/// Blocks the calling thread; spawns `cpu_workers` + `gpu_workers` device
/// threads plus the requester thread internally.
pub fn run_worker(
    source: Arc<dyn WorkSource>,
    workflow: Arc<Workflow>,
    cfg: RunConfig,
    manifest: Arc<ArtifactManifest>,
    metrics: Arc<MetricsHub>,
    stage_bindings: HashMap<String, String>,
) -> Result<()> {
    run_worker_profiled(
        source,
        workflow,
        cfg,
        manifest,
        metrics,
        stage_bindings,
        SharedProfiles::fresh(),
    )
}

/// [`run_worker`] with a caller-supplied profile store: seed it from a
/// calibrated `profiles.json` and/or read the EWMA estimates back after
/// the run.  Completion times fold into the store as the run progresses,
/// so PATS ready-queue ordering tracks the measured host.
pub fn run_worker_profiled(
    source: Arc<dyn WorkSource>,
    workflow: Arc<Workflow>,
    cfg: RunConfig,
    manifest: Arc<ArtifactManifest>,
    metrics: Arc<MetricsHub>,
    stage_bindings: HashMap<String, String>,
    profiles: Arc<SharedProfiles>,
) -> Result<()> {
    cfg.validate()?;
    let topo = NodeTopology::host();
    let wrm = Wrm::new(workflow.clone(), cfg.clone(), manifest, metrics, stage_bindings, profiles);
    let device_threads = spawn_device_threads(&wrm, &cfg, &topo);

    let flight = Arc::new((Mutex::new(Flight { in_flight: 0, requester_done: false, failed: None }), Condvar::new()));

    // requester thread
    let requester = {
        let flight = flight.clone();
        let wrm = wrm.clone();
        let source = source.clone();
        let window = cfg.window;
        let prefetch = cfg.prefetch;
        std::thread::Builder::new()
            .name("htap-wcc-req".into())
            .spawn(move || {
                let (lock, cv) = &*flight;
                loop {
                    // wait for capacity
                    let capacity = {
                        let mut fl = lock.lock().unwrap();
                        loop {
                            if fl.failed.is_some() {
                                fl.requester_done = true;
                                cv.notify_all();
                                wrm.poke();
                                return;
                            }
                            let cap = window.saturating_sub(fl.in_flight);
                            let ready = if prefetch { cap > 0 } else { fl.in_flight == 0 };
                            if ready {
                                break cap.max(1);
                            }
                            fl = cv.wait(fl).unwrap();
                        }
                    };
                    let batch = source.request(capacity);
                    if batch.is_empty() {
                        let mut fl = lock.lock().unwrap();
                        fl.requester_done = true;
                        cv.notify_all();
                        drop(fl);
                        wrm.poke();
                        return;
                    }
                    {
                        let mut fl = lock.lock().unwrap();
                        fl.in_flight += batch.len();
                    }
                    for a in batch {
                        wrm.submit(a);
                    }
                }
            })
            .expect("spawn requester")
    };

    // completer loop (this thread)
    let (lock, cv) = &*flight;
    loop {
        let events = wrm.wait_completions();
        let mut newly_done = 0usize;
        for (id, result) in events {
            match result {
                Ok(outs) => {
                    source.complete(id, outs);
                    newly_done += 1;
                }
                Err(msg) => {
                    let mut fl = lock.lock().unwrap();
                    fl.failed = Some(msg);
                    cv.notify_all();
                }
            }
        }
        let mut fl = lock.lock().unwrap();
        fl.in_flight = fl.in_flight.saturating_sub(newly_done);
        cv.notify_all();
        let finished = fl.in_flight == 0 && fl.requester_done;
        let failed = fl.failed.clone();
        drop(fl);
        if let Some(msg) = failed {
            wrm.shutdown();
            for h in device_threads {
                let _ = h.join();
            }
            let _ = requester.join();
            return Err(Error::Scheduler(format!("worker failed: {msg}")));
        }
        if finished {
            break;
        }
    }
    wrm.shutdown();
    for h in device_threads {
        let _ = h.join();
    }
    let _ = requester.join();
    Ok(())
}
