//! Manager checkpointing: periodic serialization of workflow progress
//! (the completion journal) plus the chunk catalog, so `htap manager
//! --resume` picks up a crashed manager's run instead of recomputing.
//!
//! On-disk format (`manager.ckpt`): magic `HTCK` + `u32 LE` version,
//! then the journal (count-prefixed [`CompletionRecord`]s — stage index,
//! chunk id, output values) and the catalog snapshot (count-prefixed
//! `(worker, chunk, tier)` triples).  Values reuse the `.tile`/`.spill`
//! tensor body layout ([`crate::data::staging::source`]), so corrupt or
//! truncated checkpoints decode to `Err`, never a panic — a damaged
//! checkpoint means a cold start, not a crashed restart.
//!
//! Writes go through a temp file + rename so a manager killed mid-write
//! leaves the previous checkpoint intact (the same crash-consistency
//! contract the spill tier makes per chunk file).

use crate::coordinator::manager::{ChunkId, CompletionRecord, Manager};
use crate::data::staging::source::{decode_tensor, encode_tensor, take_bytes};
use crate::data::staging::{Tier, WorkerId};
use crate::runtime::Value;
use crate::{Error, Result};
use std::path::{Path, PathBuf};

/// Magic + format version of the on-disk checkpoint container.
const CKPT_MAGIC: &[u8; 4] = b"HTCK";
const CKPT_VERSION: u32 = 1;

/// File name inside `--checkpoint-dir`.
pub const CHECKPOINT_FILE: &str = "manager.ckpt";

/// Magic + format version of the multi-job *service* checkpoint: a
/// count-prefixed sequence of per-job records, each embedding a complete
/// single-manager checkpoint (journal + catalog) as a length-prefixed
/// blob, plus the job-table metadata (tenant, priority, lifecycle state,
/// workflow JSON) needed to rebuild every in-flight job on
/// `htap serve --resume`.
const SVC_MAGIC: &[u8; 4] = b"HTSV";
const SVC_VERSION: u32 = 1;

/// File name of the service (job-table) checkpoint inside
/// `--checkpoint-dir`.
pub const SERVICE_CHECKPOINT_FILE: &str = "service.ckpt";

/// One job's durable state inside a service checkpoint.
#[derive(Debug, Clone, PartialEq)]
pub struct JobCheckpoint {
    pub job: u64,
    pub tenant: String,
    pub priority: u32,
    /// Lifecycle state name (`Queued`/`Running`/`Done`/`Failed`/
    /// `Cancelled`) — stringly on disk so the codec needs no service
    /// types.
    pub state: String,
    pub workflow_json: String,
    /// Progress at snapshot time, kept so terminal jobs report correctly
    /// after a resume without rebuilding their manager.
    pub done: u64,
    pub total: u64,
    pub journal: Vec<CompletionRecord>,
    pub catalog: Vec<(WorkerId, ChunkId, Tier)>,
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_value(buf: &mut Vec<u8>, v: &Value) {
    match v {
        Value::Scalar(s) => {
            buf.push(0);
            buf.extend_from_slice(&s.to_le_bytes());
        }
        Value::Tensor(t) => {
            buf.push(1);
            encode_tensor(buf, t);
        }
    }
}

fn read_u32(bytes: &[u8], pos: &mut usize) -> Result<u32> {
    // lint: allow(panic) — take_bytes guarantees a 4-byte slice
    Ok(u32::from_le_bytes(take_bytes(bytes, pos, 4)?.try_into().unwrap()))
}

fn read_u64(bytes: &[u8], pos: &mut usize) -> Result<u64> {
    // lint: allow(panic) — take_bytes guarantees an 8-byte slice
    Ok(u64::from_le_bytes(take_bytes(bytes, pos, 8)?.try_into().unwrap()))
}

/// Bound a count prefix by the bytes actually left: a corrupt count must
/// fail before any preallocation (same rule as the wire codec).
fn read_count(bytes: &[u8], pos: &mut usize, min_elem_bytes: usize) -> Result<usize> {
    let n = read_u32(bytes, pos)? as usize;
    let left = bytes.len().saturating_sub(*pos);
    if n.saturating_mul(min_elem_bytes) > left {
        return Err(Error::Config(format!("checkpoint count {n} exceeds file ({left} bytes left)")));
    }
    Ok(n)
}

fn read_value(bytes: &[u8], pos: &mut usize) -> Result<Value> {
    match take_bytes(bytes, pos, 1)?[0] {
        0 => {
            // lint: allow(panic) — take_bytes guarantees a 4-byte slice
            Ok(Value::Scalar(f32::from_le_bytes(take_bytes(bytes, pos, 4)?.try_into().unwrap())))
        }
        1 => Ok(Value::Tensor(decode_tensor(bytes, pos)?)),
        t => Err(Error::Config(format!("checkpoint: bad value tag {t}"))),
    }
}

/// Serialize a checkpoint snapshot to its on-disk byte layout.
pub fn encode(
    journal: &[CompletionRecord],
    catalog: &[(WorkerId, ChunkId, Tier)],
) -> Vec<u8> {
    let mut buf = Vec::new();
    buf.extend_from_slice(CKPT_MAGIC);
    put_u32(&mut buf, CKPT_VERSION);
    put_u32(&mut buf, journal.len() as u32);
    for rec in journal {
        put_u64(&mut buf, rec.stage_idx as u64);
        put_u64(&mut buf, rec.chunk);
        put_u32(&mut buf, rec.outputs.len() as u32);
        for v in &rec.outputs {
            put_value(&mut buf, v);
        }
    }
    put_u32(&mut buf, catalog.len() as u32);
    for &(w, c, tier) in catalog {
        put_u64(&mut buf, w);
        put_u64(&mut buf, c);
        buf.push(match tier {
            Tier::Mem => 0,
            Tier::Disk => 1,
        });
    }
    buf
}

/// Decode a checkpoint written by [`encode`].  Any corruption — bad
/// magic, hostile counts, truncation, trailing bytes — is an `Err`.
pub fn decode(bytes: &[u8]) -> Result<(Vec<CompletionRecord>, Vec<(WorkerId, ChunkId, Tier)>)> {
    let mut pos = 0usize;
    if take_bytes(bytes, &mut pos, 4)? != CKPT_MAGIC {
        return Err(Error::Config("not a checkpoint file (bad magic)".into()));
    }
    let version = read_u32(bytes, &mut pos)?;
    if version != CKPT_VERSION {
        return Err(Error::Config(format!("unsupported checkpoint version {version}")));
    }
    let n_records = read_count(bytes, &mut pos, 20)?; // stage + chunk + count
    let mut journal = Vec::with_capacity(n_records);
    for _ in 0..n_records {
        let stage_idx = read_u64(bytes, &mut pos)? as usize;
        let chunk = read_u64(bytes, &mut pos)?;
        let n_outputs = read_count(bytes, &mut pos, 5)?; // tag + f32 minimum
        let mut outputs = Vec::with_capacity(n_outputs);
        for _ in 0..n_outputs {
            outputs.push(read_value(bytes, &mut pos)?);
        }
        journal.push(CompletionRecord { stage_idx, chunk, outputs });
    }
    let n_entries = read_count(bytes, &mut pos, 17)?; // worker + chunk + tier
    let mut catalog = Vec::with_capacity(n_entries);
    for _ in 0..n_entries {
        let w = read_u64(bytes, &mut pos)?;
        let c = read_u64(bytes, &mut pos)?;
        let tier = match take_bytes(bytes, &mut pos, 1)?[0] {
            0 => Tier::Mem,
            1 => Tier::Disk,
            t => return Err(Error::Config(format!("checkpoint: bad tier tag {t}"))),
        };
        catalog.push((w, c, tier));
    }
    if pos != bytes.len() {
        return Err(Error::Config(format!(
            "checkpoint: {} trailing bytes after decode",
            bytes.len() - pos
        )));
    }
    Ok((journal, catalog))
}

fn checkpoint_path(dir: &Path) -> PathBuf {
    dir.join(CHECKPOINT_FILE)
}

/// Snapshot `mgr` and atomically (temp file + rename) write the
/// checkpoint under `dir`, creating the directory if needed.  The
/// snapshot is taken under the manager lock but encoding and I/O happen
/// outside it — checkpointing never stalls assignment.
pub fn write_checkpoint(dir: &Path, mgr: &Manager) -> Result<()> {
    let (journal, catalog) = mgr.checkpoint_state();
    let bytes = encode(&journal, &catalog);
    std::fs::create_dir_all(dir)?;
    let tmp = dir.join(format!("{CHECKPOINT_FILE}.tmp.{}", std::process::id()));
    std::fs::write(&tmp, &bytes)?;
    std::fs::rename(&tmp, checkpoint_path(dir))?;
    Ok(())
}

/// Load the checkpoint under `dir`, if one exists.  `Ok(None)` means no
/// checkpoint (cold start); a present-but-corrupt file is an `Err` so the
/// operator decides rather than silently recomputing.
pub fn load_checkpoint(
    dir: &Path,
) -> Result<Option<(Vec<CompletionRecord>, Vec<(WorkerId, ChunkId, Tier)>)>> {
    let path = checkpoint_path(dir);
    if !path.exists() {
        return Ok(None);
    }
    let bytes = std::fs::read(&path)?;
    decode(&bytes).map(Some)
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}

fn read_str(bytes: &[u8], pos: &mut usize) -> Result<String> {
    let len = read_u32(bytes, pos)? as usize;
    let raw = take_bytes(bytes, pos, len)?;
    String::from_utf8(raw.to_vec())
        .map_err(|_| Error::Config("checkpoint: non-UTF-8 string".into()))
}

/// Serialize a service (job-table) snapshot to its on-disk byte layout.
/// Each job's journal + catalog are embedded as a length-prefixed
/// single-manager checkpoint blob, so the inner codec is exactly
/// [`encode`]/[`decode`].
pub fn encode_service(jobs: &[JobCheckpoint]) -> Vec<u8> {
    let mut buf = Vec::new();
    buf.extend_from_slice(SVC_MAGIC);
    put_u32(&mut buf, SVC_VERSION);
    put_u32(&mut buf, jobs.len() as u32);
    for j in jobs {
        put_u64(&mut buf, j.job);
        put_str(&mut buf, &j.tenant);
        put_u32(&mut buf, j.priority);
        put_str(&mut buf, &j.state);
        put_str(&mut buf, &j.workflow_json);
        put_u64(&mut buf, j.done);
        put_u64(&mut buf, j.total);
        let inner = encode(&j.journal, &j.catalog);
        put_u32(&mut buf, inner.len() as u32);
        buf.extend_from_slice(&inner);
    }
    buf
}

/// Decode a service checkpoint written by [`encode_service`].  Same
/// corruption contract as [`decode`]: any damage is an `Err`, never a
/// panic.
pub fn decode_service(bytes: &[u8]) -> Result<Vec<JobCheckpoint>> {
    let mut pos = 0usize;
    if take_bytes(bytes, &mut pos, 4)? != SVC_MAGIC {
        return Err(Error::Config("not a service checkpoint file (bad magic)".into()));
    }
    let version = read_u32(bytes, &mut pos)?;
    if version != SVC_VERSION {
        return Err(Error::Config(format!("unsupported service checkpoint version {version}")));
    }
    // job + 3 string lengths + priority + done/total + inner length
    let n_jobs = read_count(bytes, &mut pos, 44)?;
    let mut jobs = Vec::with_capacity(n_jobs);
    for _ in 0..n_jobs {
        let job = read_u64(bytes, &mut pos)?;
        let tenant = read_str(bytes, &mut pos)?;
        let priority = read_u32(bytes, &mut pos)?;
        let state = read_str(bytes, &mut pos)?;
        let workflow_json = read_str(bytes, &mut pos)?;
        let done = read_u64(bytes, &mut pos)?;
        let total = read_u64(bytes, &mut pos)?;
        let inner_len = read_u32(bytes, &mut pos)? as usize;
        let inner = take_bytes(bytes, &mut pos, inner_len)?;
        let (journal, catalog) = decode(inner)?;
        jobs.push(JobCheckpoint {
            job,
            tenant,
            priority,
            state,
            workflow_json,
            done,
            total,
            journal,
            catalog,
        });
    }
    if pos != bytes.len() {
        return Err(Error::Config(format!(
            "service checkpoint: {} trailing bytes after decode",
            bytes.len() - pos
        )));
    }
    Ok(jobs)
}

fn service_checkpoint_path(dir: &Path) -> PathBuf {
    dir.join(SERVICE_CHECKPOINT_FILE)
}

/// Atomically (temp file + rename) write a service checkpoint under
/// `dir`, creating the directory if needed.  The caller (the serve loop)
/// takes the job-table snapshot; encoding and I/O happen here, outside
/// every lock.
pub fn write_service_checkpoint(dir: &Path, jobs: &[JobCheckpoint]) -> Result<()> {
    let bytes = encode_service(jobs);
    std::fs::create_dir_all(dir)?;
    let tmp = dir.join(format!("{SERVICE_CHECKPOINT_FILE}.tmp.{}", std::process::id()));
    std::fs::write(&tmp, &bytes)?;
    std::fs::rename(&tmp, service_checkpoint_path(dir))?;
    Ok(())
}

/// Load the service checkpoint under `dir`, if one exists.  `Ok(None)`
/// means no checkpoint (cold start); a present-but-corrupt file is an
/// `Err` so the operator decides rather than silently dropping jobs.
pub fn load_service_checkpoint(dir: &Path) -> Result<Option<Vec<JobCheckpoint>>> {
    let path = service_checkpoint_path(dir);
    if !path.exists() {
        return Ok(None);
    }
    let bytes = std::fs::read(&path)?;
    decode_service(&bytes).map(Some)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::HostTensor;

    fn sample() -> (Vec<CompletionRecord>, Vec<(WorkerId, ChunkId, Tier)>) {
        let journal = vec![
            CompletionRecord { stage_idx: 0, chunk: 3, outputs: vec![Value::Scalar(1.5)] },
            CompletionRecord {
                stage_idx: 1,
                chunk: 0,
                outputs: vec![
                    Value::Tensor(HostTensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap()),
                    Value::Scalar(-7.0),
                ],
            },
            CompletionRecord { stage_idx: 2, chunk: u64::MAX, outputs: vec![] },
        ];
        let catalog = vec![(1, 0, Tier::Mem), (1, 3, Tier::Disk), (2, 1, Tier::Mem)];
        (journal, catalog)
    }

    #[test]
    fn checkpoint_bytes_roundtrip() {
        let (journal, catalog) = sample();
        let bytes = encode(&journal, &catalog);
        let (j2, c2) = decode(&bytes).unwrap();
        assert_eq!(j2, journal);
        assert_eq!(c2, catalog);
    }

    #[test]
    fn corrupt_checkpoints_are_errors_not_panics() {
        let (journal, catalog) = sample();
        let bytes = encode(&journal, &catalog);
        // bad magic
        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(decode(&bad).is_err());
        // unsupported version
        let mut bad = bytes.clone();
        bad[4] = 99;
        assert!(decode(&bad).is_err());
        // every truncation point must fail cleanly
        for cut in 0..bytes.len() {
            assert!(decode(&bytes[..cut]).is_err(), "truncation at {cut} must not decode");
        }
        // trailing garbage is rejected too
        let mut bad = bytes.clone();
        bad.push(0);
        assert!(decode(&bad).is_err());
        // hostile journal count: claims 2^31 records in a tiny file
        let mut bad = bytes[..8].to_vec();
        bad.extend_from_slice(&0x8000_0000u32.to_le_bytes());
        assert!(decode(&bad).is_err());
    }

    #[test]
    fn checkpoint_file_roundtrip_and_missing_dir() {
        let dir = std::env::temp_dir()
            .join(format!("htap-ckpt-roundtrip-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        assert!(load_checkpoint(&dir).unwrap().is_none(), "no checkpoint = cold start");
        let (journal, catalog) = sample();
        let bytes = encode(&journal, &catalog);
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join(CHECKPOINT_FILE), &bytes).unwrap();
        let (j2, c2) = load_checkpoint(&dir).unwrap().unwrap();
        assert_eq!((j2, c2), (journal, catalog));
        let _ = std::fs::remove_dir_all(&dir);
    }

    fn sample_jobs() -> Vec<JobCheckpoint> {
        let (journal, catalog) = sample();
        vec![
            JobCheckpoint {
                job: 1,
                tenant: "alice".into(),
                priority: 1,
                state: "Running".into(),
                workflow_json: "{\"stages\":[]}".into(),
                done: 2,
                total: 8,
                journal,
                catalog,
            },
            JobCheckpoint {
                job: 2,
                tenant: "bob".into(),
                priority: 4,
                state: "Done".into(),
                workflow_json: String::new(),
                done: 3,
                total: 3,
                journal: vec![],
                catalog: vec![],
            },
        ]
    }

    #[test]
    fn service_checkpoint_roundtrip() {
        let jobs = sample_jobs();
        let bytes = encode_service(&jobs);
        assert_eq!(decode_service(&bytes).unwrap(), jobs);
        // empty table roundtrips too (serve with nothing submitted yet)
        assert_eq!(decode_service(&encode_service(&[])).unwrap(), vec![]);
    }

    #[test]
    fn corrupt_service_checkpoints_are_errors_not_panics() {
        let jobs = sample_jobs();
        let bytes = encode_service(&jobs);
        // single-manager magic is not a service checkpoint
        let (journal, catalog) = sample();
        assert!(decode_service(&encode(&journal, &catalog)).is_err());
        // every truncation point must fail cleanly
        for cut in 0..bytes.len() {
            assert!(decode_service(&bytes[..cut]).is_err(), "truncation at {cut} must not decode");
        }
        // trailing garbage
        let mut bad = bytes.clone();
        bad.push(0);
        assert!(decode_service(&bad).is_err());
        // hostile job count
        let mut bad = bytes[..8].to_vec();
        bad.extend_from_slice(&0x8000_0000u32.to_le_bytes());
        assert!(decode_service(&bad).is_err());
    }

    #[test]
    fn service_checkpoint_file_roundtrip() {
        let dir = std::env::temp_dir()
            .join(format!("htap-svc-ckpt-roundtrip-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        assert!(load_service_checkpoint(&dir).unwrap().is_none(), "no checkpoint = cold start");
        let jobs = sample_jobs();
        write_service_checkpoint(&dir, &jobs).unwrap();
        assert_eq!(load_service_checkpoint(&dir).unwrap().unwrap(), jobs);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
