//! Worker Resource Manager (paper §III-B, Fig. 5): schedules and executes
//! the fine-grain operation instances of the stage instances assigned to a
//! Worker, across CPU-core threads and GPU-controller threads.
//!
//! * one computing thread per CPU core executes the **CPU member** of each
//!   function variant (rust imgproc code);
//! * one controller thread per GPU owns a [`DeviceExecutor`] (PJRT) and
//!   executes the **accelerator member** with explicit upload / process /
//!   download phases; single-output results stay device-resident so the DL
//!   policy can chain dependent operations without re-uploading.
//!
//! The scheduling policy object (`sched::OpScheduler`) is shared with the
//! discrete-event simulator: the decisions benchmarked at cluster scale are
//! made by exactly this code.
//!
//! # Lock discipline (the zero-copy dispatch path)
//!
//! A single mutex guards the scheduler queue and the per-instance
//! dependency tables.  The critical section is **push / pop / bookkeeping
//! only** — tensor payloads are `Arc`-backed ([`Value`]), so everything
//! that happens under the lock is O(ports) pointer bumps:
//!
//! * `gather_host_inputs` / the GPU input-plan snapshot clone *handles*,
//!   never bytes;
//! * op execution, artifact resolution, PJRT transfers and stage-output
//!   resolution all run **outside** the mutex;
//! * wakeups are targeted: device threads wait on per-kind condvars
//!   (`cv_cpu` / `cv_gpu`) and the completer on `cv_done`, so an op
//!   completion that readies one dependent wakes one thread, not the herd.
//!
//! See docs/perf.md for the measured dispatch costs (`make bench` →
//! `bench_dispatch`).
//!
//! The discipline is **machine-checked**, not comment-enforced (see
//! docs/analysis.md):
//!
//! * `cargo xtask lint` denies payload byte-copies, op execution, codec
//!   calls and I/O inside the sections marked `lint: critical-section`
//!   below, and checks the crate-wide lock order `wrm` → `cache` →
//!   `catalog`;
//! * the mutex/condvars come from [`crate::runtime::sync`] — a zero-cost
//!   std re-export in production, a deterministic-interleaving virtual
//!   scheduler under `cfg(htap_model)` — and `tests/model_wrm.rs`
//!   exhaustively explores bounded schedules of this dispatch/wakeup
//!   protocol, asserting no deadlock and no lost wakeup;
//! * in debug builds a [`HoldWatchdog`] times every marked section
//!   against a microsecond budget (`HTAP_LOCK_BUDGET_US`);
//! * mutex poisoning (a panic *inside* a critical section) becomes an
//!   error completion via [`Wrm::lock_inner`], matching the op-panic
//!   policy, instead of cascading unwraps across device threads.

use super::manager::Assignment;
use super::placement::{place_gpu_controller, NodeTopology};
use super::sched::{OpInstKey, OpScheduler, ReadyTask};
use crate::config::{Placement, RunConfig};
use crate::dataflow::{OpDef, PortRef, StageDef, Workflow};
use crate::metrics::{DeviceKind, MetricsHub};
use crate::obs::{EventKind, Name, TraceEvent, DEV_CPU, DEV_GPU};
use crate::runtime::calibrate::SharedProfiles;
use crate::runtime::pjrt::{DeviceExecutor, ExecInput, PayloadKey};
use crate::runtime::{ArtifactManifest, Value};
use crate::{Error, Result};
use crate::runtime::sync::{self, Condvar, HoldWatchdog, Mutex};
use std::collections::{HashMap, VecDeque};
use std::sync::Arc;
use std::time::Instant;

/// A finished stage instance: (instance id, outputs or error message).
pub type Completion = (u64, std::result::Result<Vec<Value>, String>);

struct InstExec {
    /// The workflow this instance executes against.  Single-job workers
    /// share one `Arc` for every instance; service-mode workers run many
    /// tenants' workflows over the same device threads, so the stage/op
    /// tables must travel with the instance, not the WRM.
    workflow: Arc<Workflow>,
    stage_idx: usize,
    /// Stage-external inputs, shared so a dispatch snapshot is one Arc bump.
    inputs: Arc<Vec<Value>>,
    /// Finished op outputs.  Each entry is written once (by its producer)
    /// and shared from then on; consumers snapshot the `Arc`, not the data.
    produced: Vec<Option<Arc<Vec<Value>>>>,
    /// per op: count of distinct producer ops not yet finished
    dep_remaining: Vec<usize>,
    ops_remaining: usize,
    /// Chunk this instance processes, carried from the [`Assignment`] so
    /// op-execution trace spans can be tied back to their pipeline input.
    chunk: u64,
    /// op idx -> (gpu id, resident payload key).
    ///
    /// INVARIANT: only **single-output** op results are ever inserted here
    /// (the GPU thread checks `n_outputs == 1` before keeping a result
    /// resident, and `DeviceExecutor::execute_resident` rejects tuple
    /// payloads as inputs).  Multi-output ops therefore always feed
    /// dependents through host values — by design, not by accident; the
    /// consumer-side lookup debug-asserts this.
    resident: HashMap<usize, (usize, PayloadKey)>,
}

struct WrmInner {
    queue: Box<dyn OpScheduler>,
    insts: HashMap<u64, InstExec>,
    completions: VecDeque<Completion>,
    seq: u64,
    shutdown: bool,
    poked: bool,
    /// Enqueue timestamps for queued tasks, maintained only when tracing
    /// is enabled (the map stays empty otherwise).  Insert-at-push /
    /// remove-at-pop are O(1) hash ops — fine inside the critical
    /// sections; the [`EventKind::QueueWait`] event itself is recorded
    /// outside the lock.
    enqueued: HashMap<OpInstKey, Instant>,
}

/// One port of a GPU dispatch snapshot: a payload resident on this device,
/// or a shared host-value handle (an Arc bump, never a byte copy).
enum PlanSlot {
    Resident(PayloadKey),
    Host(Value),
}

/// Shared WRM state + the device threads' rendezvous.
pub struct Wrm {
    inner: Mutex<WrmInner>,
    /// CPU computing threads wait here for ready tasks.
    cv_cpu: Condvar,
    /// GPU controller threads wait here; only notified for tasks a GPU can
    /// actually take, so CPU-only work never wakes a controller.
    cv_gpu: Condvar,
    /// `wait_completions` callers (the Worker's completer) wait here; op
    /// completions that ready new tasks but finish no stage skip it.
    cv_done: Condvar,
    workflow: Arc<Workflow>,
    manifest: Arc<ArtifactManifest>,
    metrics: Arc<MetricsHub>,
    cfg: RunConfig,
    /// resolution of "@stage:<name>" tags to fused artifact names
    stage_bindings: HashMap<String, String>,
    /// live per-(op, device) EWMA cost estimates; completions fold in here
    /// and ready-task speedups are drawn from here when measured
    profiles: Arc<SharedProfiles>,
}

impl Wrm {
    pub fn new(
        workflow: Arc<Workflow>,
        cfg: RunConfig,
        manifest: Arc<ArtifactManifest>,
        metrics: Arc<MetricsHub>,
        stage_bindings: HashMap<String, String>,
        profiles: Arc<SharedProfiles>,
    ) -> Arc<Self> {
        Arc::new(Wrm {
            inner: Mutex::new(WrmInner {
                queue: super::sched::make_scheduler(cfg.policy),
                insts: HashMap::new(),
                completions: VecDeque::new(),
                seq: 0,
                shutdown: false,
                poked: false,
                enqueued: HashMap::new(),
            }),
            cv_cpu: Condvar::new(),
            cv_gpu: Condvar::new(),
            cv_done: Condvar::new(),
            workflow,
            manifest,
            metrics,
            cfg,
            stage_bindings,
            profiles,
        })
    }

    /// Speedup / transfer-impact estimates for one ready op: the live EWMA
    /// measurement when this run (or a loaded `profiles.json`) has one,
    /// else the op's static Fig. 7 profile.  This is where PATS's input
    /// turns from a constant into a signal.
    fn task_estimates(&self, op: &OpDef) -> (f32, f32) {
        match self.profiles.estimate(&op.op) {
            Some(e) => (e.speedup, e.transfer_impact.unwrap_or(op.transfer_impact)),
            None => (op.speedup, op.transfer_impact),
        }
    }

    /// Whether the scheduler may hand this op to a GPU controller: the op
    /// declares an accelerator member, or the worker has no CPU compute
    /// threads and the controller must run the CPU member itself.  When a
    /// declared artifact is absent from the manifest (e.g. `make artifacts`
    /// hasn't run, or an unbuilt tile size), the controller still takes the
    /// task and degrades to the CPU member (`resolve_artifact` decides at
    /// execution time), so hybrid configurations run everywhere.
    fn gpu_eligible(&self, gpu_artifact: &Option<String>) -> bool {
        self.cfg.cpu_workers == 0 || gpu_artifact.is_some()
    }

    /// Resolve an op's accelerator artifact name (handles `@stage:` tags)
    /// and check it exists at the configured tile size.  Runs on the
    /// device thread *outside* the WRM mutex (string work + manifest
    /// lookups have no business inside the dispatch critical section).
    fn resolve_artifact(&self, gpu_artifact: &Option<String>) -> Option<String> {
        let name = gpu_artifact.as_ref()?;
        let resolved = if let Some(stage) = name.strip_prefix("@stage:") {
            self.stage_bindings.get(stage)?.clone()
        } else {
            name.clone()
        };
        if self.manifest.has(&resolved, self.cfg.tile_size) {
            Some(resolved)
        } else {
            None
        }
    }

    /// Targeted wakeup after pushing `n_new` ready tasks (`any_gpu` = at
    /// least one is GPU-eligible).  The common completion path readies
    /// exactly one dependent → one thread wakes; batch submits fan out.
    fn wake_device_threads(&self, n_new: usize, any_gpu: bool) {
        match n_new {
            0 => {}
            1 => {
                self.cv_cpu.notify_one();
                if any_gpu {
                    self.cv_gpu.notify_one();
                }
            }
            _ => {
                self.cv_cpu.notify_all();
                if any_gpu {
                    self.cv_gpu.notify_all();
                }
            }
        }
    }

    /// Record one op-lifecycle trace event ([`EventKind::QueueWait`] /
    /// [`EventKind::OpBegin`] / [`EventKind::OpEnd`]) against the hub's
    /// tracer.  `job` is decoded from the instance id's service tag (0 for
    /// single-job runs, whose instance ids carry no tag).  Callers sit on
    /// device threads *outside* the dispatch critical sections.
    fn trace_op(
        &self,
        kind: EventKind,
        task: &ReadyTask,
        device: u8,
        lane: u32,
        stage_idx: usize,
        chunk: u64,
        dur_us: u64,
    ) {
        let tracer = self.metrics.tracer();
        if !tracer.is_enabled() {
            return;
        }
        tracer.record(TraceEvent {
            dur_us,
            device,
            lane,
            job: crate::service::job_of(task.key.0),
            stage: stage_idx as u32,
            chunk,
            name: Name::new(&task.name),
            ..TraceEvent::of(kind)
        });
    }

    /// Acquire the WRM mutex, surfacing poisoning (a panic inside some
    /// critical section) to the caller instead of cascading the panic
    /// through every device thread.  Callers convert the error into an
    /// error completion (`wait_completions`) or a clean thread exit.
    fn lock_inner(&self) -> std::result::Result<sync::MutexGuard<'_, WrmInner>, sync::Poisoned> {
        sync::lock_or_poisoned(&self.inner)
    }

    /// Push an error completion and wake the completer (never the device
    /// threads — there is no new work for them in a failure).
    fn push_error(&self, instance: u64, msg: String) {
        let Ok(mut inner) = self.lock_inner() else { return };
        // lint: critical-section — completion push only
        let hold = HoldWatchdog::new("wrm.push_error");
        inner.completions.push_back((instance, Err(msg)));
        drop(hold);
        drop(inner);
        self.cv_done.notify_all();
    }

    /// Enqueue a stage instance against the WRM's default workflow (the
    /// single-job path).
    pub fn submit(&self, a: Assignment) {
        self.submit_to(a, self.workflow.clone());
    }

    /// Enqueue a stage instance of an explicit workflow: instantiate its
    /// fine-grain operations as `(data, op)` tuples and push the
    /// dependency-free ones.  Service-mode workers call this with the
    /// workflow resolved from the assignment's job tag, so one device-
    /// thread pool multiplexes every tenant's pipelines.
    pub fn submit_to(&self, a: Assignment, workflow: Arc<Workflow>) {
        let stage = &workflow.stages[a.stage_idx];
        let n_ops = stage.ops.len();
        let mut dep_remaining = vec![0usize; n_ops];
        for (oi, op) in stage.ops.iter().enumerate() {
            let mut producers: Vec<usize> = op
                .inputs
                .iter()
                .filter_map(|p| match p {
                    PortRef::Op { op, .. } => Some(*op),
                    _ => None,
                })
                .collect();
            producers.sort_unstable();
            producers.dedup();
            dep_remaining[oi] = producers.len();
        }
        let traced = self.metrics.tracer().is_enabled();
        let now = Instant::now();
        let Ok(mut inner) = self.lock_inner() else {
            // poisoned: the run is failing; wait_completions reports it
            return;
        };
        // lint: critical-section — instance insert + ready pushes only
        let hold = HoldWatchdog::new("wrm.submit");
        let exec = InstExec {
            workflow: workflow.clone(),
            stage_idx: a.stage_idx,
            inputs: Arc::new(a.inputs),
            produced: vec![None; n_ops],
            dep_remaining: dep_remaining.clone(),
            ops_remaining: n_ops,
            chunk: a.chunk,
            resident: HashMap::new(),
        };
        inner.insts.insert(a.instance_id, exec);
        let mut n_new = 0usize;
        let mut any_gpu = false;
        for (oi, op) in stage.ops.iter().enumerate() {
            if dep_remaining[oi] == 0 {
                let seq = inner.seq;
                inner.seq += 1;
                let (speedup, transfer_impact) = self.task_estimates(op);
                let has_gpu_impl = self.gpu_eligible(&op.variant.gpu_artifact);
                inner.queue.push(ReadyTask {
                    key: (a.instance_id, oi),
                    name: op.name.clone(),
                    speedup,
                    transfer_impact,
                    seq,
                    resident_on: None,
                    has_gpu_impl,
                });
                if traced {
                    inner.enqueued.insert((a.instance_id, oi), now);
                }
                n_new += 1;
                any_gpu |= has_gpu_impl;
            }
        }
        drop(hold);
        drop(inner);
        self.wake_device_threads(n_new, any_gpu);
    }

    /// Stop all device threads (after the queue drains).
    pub fn shutdown(&self) {
        if let Ok(mut inner) = self.lock_inner() {
            inner.shutdown = true;
        }
        // on poisoning, still wake everyone: blocked waiters observe the
        // poisoned condvar result and exit cleanly
        self.cv_cpu.notify_all();
        self.cv_gpu.notify_all();
        self.cv_done.notify_all();
    }

    /// Wake a `wait_completions` caller even if nothing completed.
    pub fn poke(&self) {
        if let Ok(mut inner) = self.lock_inner() {
            inner.poked = true;
        }
        self.cv_done.notify_all();
    }

    /// Block until at least one completion (or a poke); drain all pending.
    /// A poisoned WRM mutex (a panic inside a critical section) is
    /// reported as an error completion on the `u64::MAX` sentinel
    /// instance — the same channel GPU-init failures use — so the Worker
    /// aborts the run instead of panicking in the completer.
    pub fn wait_completions(&self) -> Vec<Completion> {
        const POISONED: &str = "wrm mutex poisoned (a critical section panicked)";
        let Ok(mut inner) = self.lock_inner() else {
            return vec![(u64::MAX, Err(POISONED.into()))];
        };
        // lint: critical-section — completion drain only
        loop {
            if !inner.completions.is_empty() {
                return inner.completions.drain(..).collect();
            }
            if inner.poked || inner.shutdown {
                inner.poked = false;
                return Vec::new();
            }
            inner = match self.cv_done.wait(inner) {
                Ok(g) => g,
                Err(_) => return vec![(u64::MAX, Err(POISONED.into()))],
            };
        }
    }

    /// Gather host-value *handles* for an op's inputs (caller holds the
    /// lock).  Every push is an `Arc` bump — O(ports) pointer work, no
    /// payload copies inside the critical section.  Also returns the
    /// instance's workflow handle and stage index so the caller needs no
    /// second lock (and resolves ops against the *instance's* workflow,
    /// which in service mode differs per job).  The instance's chunk id
    /// rides along so the caller can label its trace span.
    fn gather_host_inputs(
        inner: &WrmInner,
        key: OpInstKey,
    ) -> std::result::Result<(Vec<Value>, Arc<Workflow>, usize, u64), String> {
        let exec = inner.insts.get(&key.0).ok_or("instance vanished")?;
        let workflow = exec.workflow.clone();
        let stage = &workflow.stages[exec.stage_idx];
        let op = &stage.ops[key.1];
        // empty port list = consume all stage inputs (Reduce convention)
        let mut vals = Vec::with_capacity(op.inputs.len().max(exec.inputs.len()));
        if op.inputs.is_empty() {
            vals.extend_from_slice(&exec.inputs);
        }
        for port in &op.inputs {
            match port {
                PortRef::StageInput(k) => vals.push(
                    exec.inputs.get(*k).cloned().ok_or(format!("missing stage input {k}"))?,
                ),
                PortRef::Op { op: p, output } => {
                    let outs = exec.produced[*p].as_ref().ok_or("dependency not produced")?;
                    vals.push(outs.get(*output).cloned().ok_or("bad output index")?);
                }
                PortRef::Param(v) => vals.push(v.clone()),
            }
        }
        Ok((vals, workflow, exec.stage_idx, exec.chunk))
    }

    /// Resolve a completed instance's stage outputs from its shared
    /// produced/input handles — O(outputs) Arc bumps, no clone of the
    /// produced table.  This mirrors `dataflow::resolve_port` over the
    /// WRM's sparse `Option<Arc<Vec<Value>>>` storage, the same
    /// relationship `gather_host_inputs` has to `gather_op_inputs`
    /// (documented there); keep the two rule sets in sync.
    fn resolve_stage_outputs(
        stage: &StageDef,
        exec: &InstExec,
    ) -> std::result::Result<Vec<Value>, String> {
        stage
            .outputs
            .iter()
            .map(|p| match p {
                PortRef::StageInput(k) => exec
                    .inputs
                    .get(*k)
                    .cloned()
                    .ok_or_else(|| format!("missing stage input {k}")),
                PortRef::Op { op, output } => exec
                    .produced
                    .get(*op)
                    .and_then(|o| o.as_ref())
                    .and_then(|o| o.get(*output))
                    .cloned()
                    .ok_or_else(|| format!("missing op output {op}:{output}")),
                PortRef::Param(v) => Ok(v.clone()),
            })
            .collect()
    }

    /// Record an op's results; push newly-ready dependents; emit the stage
    /// completion if this was the last op.  Returns instance ids that
    /// completed (so GPU threads can evict their resident payloads).
    ///
    /// Everything here is bookkeeping over shared handles — dependency
    /// decrements, queue pushes, and (on the last op) O(outputs) Arc-bump
    /// output resolution — so the whole call is one short lock hold.
    fn finish_op(
        &self,
        key: OpInstKey,
        outs: Vec<Value>,
        resident: Option<(usize, PayloadKey)>,
    ) -> Vec<u64> {
        let mut completed = Vec::new();
        let traced = self.metrics.tracer().is_enabled();
        let now = Instant::now();
        let Ok(mut inner) = self.lock_inner() else {
            // poisoned: drop the result; wait_completions reports the failure
            return completed;
        };
        // lint: critical-section — dependency bookkeeping + queue pushes only
        let hold = HoldWatchdog::new("wrm.finish_op");
        let Some(exec) = inner.insts.get_mut(&key.0) else {
            return completed;
        };
        // Arc bump: ops resolve against the instance's own workflow (the
        // per-job pipeline in service mode), not the WRM default
        let wf = exec.workflow.clone();
        let stage = &wf.stages[exec.stage_idx];
        // single-writer invariant: each produced slot is written exactly
        // once, by the device thread that executed its op (model-checked
        // by tests/model_wrm.rs across interleavings)
        debug_assert!(
            exec.produced[key.1].is_none(),
            "produced slot ({}, {}) written twice",
            key.0,
            key.1
        );
        exec.produced[key.1] = Some(Arc::new(outs));
        if let Some(r) = resident {
            debug_assert_eq!(
                stage.ops[key.1].n_outputs,
                1,
                "resident payloads are single-output by invariant (op '{}')",
                stage.ops[key.1].name
            );
            exec.resident.insert(key.1, r);
        }
        exec.ops_remaining -= 1;
        // decrement dependents
        let mut newly_ready: Vec<usize> = Vec::new();
        for (oi, op) in stage.ops.iter().enumerate() {
            if exec.produced[oi].is_some() || exec.dep_remaining[oi] == 0 {
                continue;
            }
            let depends = op.inputs.iter().any(|p| matches!(p, PortRef::Op { op, .. } if *op == key.1));
            if depends {
                exec.dep_remaining[oi] -= 1;
                if exec.dep_remaining[oi] == 0 {
                    newly_ready.push(oi);
                }
            }
        }
        // residency hints for the new tasks, in the same pass as the
        // dependency bookkeeping (one table lookup, not one per task)
        let hints: Vec<(usize, Option<usize>)> = newly_ready
            .iter()
            .map(|&oi| {
                let hint = stage.ops[oi].inputs.iter().find_map(|p| match p {
                    PortRef::Op { op: prod, .. } => exec.resident.get(prod).map(|(gpu, _)| *gpu),
                    _ => None,
                });
                (oi, hint)
            })
            .collect();
        let stage_done = exec.ops_remaining == 0;
        if stage_done {
            let Some(exec) = inner.insts.remove(&key.0) else {
                // unreachable: get_mut above proved the entry exists
                return completed;
            };
            // resolution is O(outputs) Arc bumps over the removed
            // instance's shared handles — cheap enough to stay under the
            // single lock hold (the old cost, cloning the entire produced
            // table, is what this PR removed)
            let result = Self::resolve_stage_outputs(stage, &exec);
            inner.completions.push_back((key.0, result));
            drop(hold);
            drop(inner);
            self.cv_done.notify_all();
            completed.push(key.0);
        } else {
            // push the newly-ready tasks with their residency hints
            let mut n_new = 0usize;
            let mut any_gpu = false;
            for (oi, hint) in hints {
                let op = &stage.ops[oi];
                let seq = inner.seq;
                inner.seq += 1;
                let (speedup, transfer_impact) = self.task_estimates(op);
                let has_gpu_impl = self.gpu_eligible(&op.variant.gpu_artifact);
                inner.queue.push(ReadyTask {
                    key: (key.0, oi),
                    name: op.name.clone(),
                    speedup,
                    transfer_impact,
                    seq,
                    resident_on: hint,
                    has_gpu_impl,
                });
                if traced {
                    inner.enqueued.insert((key.0, oi), now);
                }
                n_new += 1;
                any_gpu |= has_gpu_impl;
            }
            drop(hold);
            drop(inner);
            self.wake_device_threads(n_new, any_gpu);
        }
        completed
    }

    /// Execute an op's CPU member over shared input handles, converting a
    /// panic into an error so it can never silently kill a device thread.
    /// In debug builds, also asserts the op treated its inputs as
    /// immutable — the aliasing oracle for the zero-copy datapath
    /// (`&[Value]` already prevents safe mutation; this catches
    /// unsafe/interior-mutability escapes).
    fn run_cpu_member(op: &OpDef, vals: &[Value]) -> Result<Vec<Value>> {
        // the aliasing assert runs inside the catch so a tripped oracle
        // surfaces as an error completion, not a hung worker
        std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            #[cfg(debug_assertions)]
            let before: Vec<u64> = vals.iter().map(value_checksum).collect();
            let result = (op.variant.cpu)(vals);
            #[cfg(debug_assertions)]
            for (v, h) in vals.iter().zip(&before) {
                debug_assert_eq!(
                    value_checksum(v),
                    *h,
                    "op '{}' mutated a shared input buffer in place",
                    op.name
                );
            }
            result
        }))
        .unwrap_or_else(|p| {
            let msg = p
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| p.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "op panicked".into());
            Err(Error::Dataflow(format!("op '{}' panicked: {msg}", op.name)))
        })
    }

    /// CPU computing-thread main loop.
    pub fn cpu_thread(self: &Arc<Self>, core: usize) {
        loop {
            // critical section: pop + O(ports) handle gather, nothing else
            let (task, vals, wf, stage_idx, chunk, waited) = {
                let Ok(mut inner) = self.lock_inner() else { return };
                // lint: critical-section — pop + O(ports) handle gather only
                loop {
                    if inner.shutdown {
                        return;
                    }
                    if let Some(task) = inner.queue.pop(DeviceKind::Cpu, 0, false) {
                        let hold = HoldWatchdog::new("wrm.cpu_pop");
                        let waited = inner.enqueued.remove(&task.key);
                        match Self::gather_host_inputs(&inner, task.key) {
                            Ok((vals, wf, stage_idx, chunk)) => {
                                break (task, vals, wf, stage_idx, chunk, waited)
                            }
                            Err(e) => {
                                inner.completions.push_back((task.key.0, Err(e)));
                                self.cv_done.notify_all();
                                drop(hold);
                                continue;
                            }
                        }
                    }
                    inner = match self.cv_cpu.wait(inner) {
                        Ok(g) => g,
                        // poisoned: another thread panicked under the lock;
                        // the completer reports it, this thread just exits
                        Err(_) => return,
                    };
                }
            };
            let lane = core as u32;
            if let Some(t) = waited {
                let dur = t.elapsed().as_micros() as u64;
                self.trace_op(EventKind::QueueWait, &task, DEV_CPU, lane, stage_idx, chunk, dur);
            }
            self.trace_op(EventKind::OpBegin, &task, DEV_CPU, lane, stage_idx, chunk, 0);
            let op = &wf.stages[stage_idx].ops[task.key.1];
            let t0 = Instant::now();
            // run_cpu_member converts a panicking op into an error
            // completion so the Worker aborts cleanly
            let result = Self::run_cpu_member(op, &vals);
            let elapsed = t0.elapsed();
            self.metrics.record_op(&op.name, DeviceKind::Cpu, elapsed);
            self.profiles.record(&op.op, DeviceKind::Cpu, elapsed);
            let dur_us = elapsed.as_micros() as u64;
            self.trace_op(EventKind::OpEnd, &task, DEV_CPU, lane, stage_idx, chunk, dur_us);
            match result {
                Ok(outs) => {
                    self.finish_op(task.key, outs, None);
                }
                Err(e) => self.push_error(task.key.0, e.to_string()),
            }
        }
    }

    /// GPU controller-thread main loop.  Owns the PJRT executor; applies
    /// the placement strategy on entry (paper §IV-A).
    pub fn gpu_thread(self: &Arc<Self>, gpu_id: usize, topo: &NodeTopology, placement: Placement) {
        place_gpu_controller(topo, gpu_id, placement);
        let mut executor = match DeviceExecutor::new((*self.manifest).clone()) {
            Ok(e) => e,
            Err(e) => {
                self.push_error(u64::MAX, format!("gpu {gpu_id}: {e}"));
                return;
            }
        };
        // NOTE on artifact compilation: executables compile lazily on first
        // use and are cached for the worker's lifetime (compile-once /
        // execute-many — verified by runtime_artifacts::executable_cache_
        // compiles_once).  Eager preloading here measurably *hurts* on
        // small hosts: on a single-core machine the preload monopolises the
        // CPU the compute threads need (measured 0.10s -> 1.90s wall for a
        // 48-tile run), so we keep the lazy policy.
        // inst id -> payload keys this GPU holds (for eviction)
        let mut held: HashMap<u64, Vec<PayloadKey>> = HashMap::new();
        // one-time notice when accelerator execution degrades to CPU members
        let mut warned_fallback = false;
        loop {
            // critical section: pop + snapshot the input plan as shared
            // handles (resident keys on THIS gpu, or Arc-bumped host
            // values).  Plan *materialisation* (ExecInput refs, uploads)
            // and artifact resolution happen outside, on this thread.
            let picked = {
                let Ok(mut inner) = self.lock_inner() else { return };
                // lint: critical-section — pop + input-plan snapshot (Arc
                // bumps / resident keys) only; materialisation runs outside
                loop {
                    if inner.shutdown {
                        return;
                    }
                    if let Some(task) =
                        inner.queue.pop(DeviceKind::Gpu, gpu_id, self.cfg.data_locality)
                    {
                        let hold = HoldWatchdog::new("wrm.gpu_pop");
                        let waited = inner.enqueued.remove(&task.key);
                        let Some(exec) = inner.insts.get(&task.key.0) else {
                            drop(hold);
                            continue;
                        };
                        let stage_idx = exec.stage_idx;
                        let chunk = exec.chunk;
                        // Arc bump: the instance's own workflow travels
                        // with the snapshot (per-job pipeline in service
                        // mode)
                        let wf = exec.workflow.clone();
                        let op = &wf.stages[stage_idx].ops[task.key.1];
                        let mut plan: Vec<PlanSlot> =
                            Vec::with_capacity(op.inputs.len().max(exec.inputs.len()));
                        let mut ok = true;
                        if op.inputs.is_empty() {
                            for v in exec.inputs.iter() {
                                plan.push(PlanSlot::Host(v.clone()));
                            }
                        }
                        for port in &op.inputs {
                            match port {
                                PortRef::Op { op: p, output } => {
                                    match exec.resident.get(p) {
                                        Some(&(g, k)) if g == gpu_id => {
                                            // resident ⇒ the producer was
                                            // single-output (see InstExec::
                                            // resident), so the only valid
                                            // port is output 0
                                            debug_assert_eq!(
                                                *output, 0,
                                                "resident payload consumed at output {output}"
                                            );
                                            plan.push(PlanSlot::Resident(k));
                                        }
                                        _ => match exec.produced[*p]
                                            .as_ref()
                                            .and_then(|o| o.get(*output))
                                        {
                                            Some(v) => plan.push(PlanSlot::Host(v.clone())),
                                            None => {
                                                ok = false;
                                                break;
                                            }
                                        },
                                    }
                                }
                                PortRef::StageInput(k) => match exec.inputs.get(*k) {
                                    Some(v) => plan.push(PlanSlot::Host(v.clone())),
                                    None => {
                                        ok = false;
                                        break;
                                    }
                                },
                                PortRef::Param(v) => plan.push(PlanSlot::Host(v.clone())),
                            }
                        }
                        if !ok {
                            inner
                                .completions
                                .push_back((task.key.0, Err("missing op input".into())));
                            self.cv_done.notify_all();
                            drop(hold);
                            continue;
                        }
                        break Some((task, wf, stage_idx, chunk, plan, waited));
                    }
                    inner = match self.cv_gpu.wait(inner) {
                        Ok(g) => g,
                        // poisoned: exit; the completer reports the failure
                        Err(_) => return,
                    };
                }
            };
            let Some((task, wf, stage_idx, chunk, plan, waited)) = picked else { return };
            let lane = gpu_id as u32;
            if let Some(t) = waited {
                let dur = t.elapsed().as_micros() as u64;
                self.trace_op(EventKind::QueueWait, &task, DEV_GPU, lane, stage_idx, chunk, dur);
            }
            let op = &wf.stages[stage_idx].ops[task.key.1];
            // Try the accelerator member first.  A missing artifact or a
            // failed accelerator execution (e.g. the offline xla shim, or a
            // driver error) degrades to the CPU member below rather than
            // failing the stage instance.
            if let Some(artifact) = self.resolve_artifact(&op.variant.gpu_artifact) {
                // upload -> process -> download (paper §IV-D phases)
                self.trace_op(EventKind::OpBegin, &task, DEV_GPU, lane, stage_idx, chunk, 0);
                let t0 = Instant::now();
                let up0 = (executor.stats.bytes_up, executor.stats.bytes_down);
                let inputs: Vec<ExecInput<'_>> = plan
                    .iter()
                    .map(|p| match p {
                        PlanSlot::Resident(k) => ExecInput::Resident(*k),
                        PlanSlot::Host(v) => ExecInput::Host(v),
                    })
                    .collect();
                let exec_result = executor
                    .execute_resident(&artifact, self.cfg.tile_size, &inputs)
                    .and_then(|key| executor.download(key).map(|outs| (key, outs)));
                match exec_result {
                    Ok((key, outs)) => {
                        let n_outputs = outs.len();
                        let elapsed = t0.elapsed();
                        self.metrics.record_op(&op.name, DeviceKind::Gpu, elapsed);
                        // a *real* accelerator execution: fold the
                        // end-to-end (transfer-inclusive) time into the
                        // online GPU estimate; record_accelerator pins the
                        // measured transfer impact to 0 so the DL rule
                        // doesn't discount the transfer cost twice
                        self.profiles.record_accelerator(&op.op, elapsed);
                        let (u1, d1) = (executor.stats.bytes_up, executor.stats.bytes_down);
                        self.metrics.record_transfer(&op.name, u1 - up0.0, d1 - up0.1);
                        let dur_us = elapsed.as_micros() as u64;
                        self.trace_op(
                            EventKind::OpEnd, &task, DEV_GPU, lane, stage_idx, chunk, dur_us,
                        );
                        // keep single-output results resident for DL
                        // chaining; multi-output (tuple) results are
                        // evicted — they cannot feed a dependent execution
                        // without a download (see InstExec::resident)
                        let resident = if self.cfg.data_locality && n_outputs == 1 {
                            held.entry(task.key.0).or_default().push(key);
                            Some((gpu_id, key))
                        } else {
                            executor.evict(key);
                            None
                        };
                        let finished = self.finish_op(task.key, outs, resident);
                        for inst in finished {
                            if let Some(keys) = held.remove(&inst) {
                                for k in keys {
                                    executor.evict(k);
                                }
                            }
                        }
                        // also evict payloads of instances completed elsewhere
                        let live: Vec<u64> = {
                            let Ok(inner) = self.lock_inner() else { return };
                            // lint: critical-section — liveness scan only
                            let hold = HoldWatchdog::new("wrm.gpu_evict_scan");
                            let scan = held
                                .keys()
                                .filter(|k| !inner.insts.contains_key(k))
                                .copied()
                                .collect();
                            drop(hold);
                            scan
                        };
                        for inst in live {
                            if let Some(keys) = held.remove(&inst) {
                                for k in keys {
                                    executor.evict(k);
                                }
                            }
                        }
                        continue;
                    }
                    Err(e) => {
                        // close the degraded attempt's span; the CPU-member
                        // fallback below opens its own
                        let dur_us = t0.elapsed().as_micros() as u64;
                        self.trace_op(
                            EventKind::OpEnd, &task, DEV_GPU, lane, stage_idx, chunk, dur_us,
                        );
                        if !warned_fallback {
                            warned_fallback = true;
                            eprintln!(
                                "htap: gpu {gpu_id}: accelerator execution of '{artifact}' \
                                 failed ({e}); degrading to CPU members"
                            );
                        }
                    }
                }
            }
            // No accelerator member (GPU-only worker fallback, a missing
            // artifact, or a failed accelerator execution): the controller
            // runs the CPU member itself.  Resident inputs are downloaded
            // first.  Execution time is recorded against this controller's
            // device (DeviceKind::Gpu) — the controller *emulates* the
            // accelerator, which keeps the hybrid scheduling paths and the
            // profile table exercised on artifactless hosts.
            let mut vals: Vec<Value> = Vec::with_capacity(plan.len());
            let mut dl_err = None;
            for p in &plan {
                match p {
                    PlanSlot::Host(v) => vals.push(v.clone()),
                    PlanSlot::Resident(k) => match executor.download(*k) {
                        Ok(mut outs) if !outs.is_empty() => vals.push(outs.remove(0)),
                        Ok(_) => dl_err = Some("empty resident payload".to_string()),
                        Err(e) => dl_err = Some(e.to_string()),
                    },
                }
            }
            if let Some(e) = dl_err {
                self.push_error(task.key.0, e);
                continue;
            }
            self.trace_op(EventKind::OpBegin, &task, DEV_GPU, lane, stage_idx, chunk, 0);
            let t0 = Instant::now();
            // same panic discipline as the CPU thread (via run_cpu_member):
            // a panicking op, or a tripped debug aliasing assert, becomes
            // an error completion, not a silently dead controller thread
            let result = Self::run_cpu_member(op, &vals);
            let elapsed = t0.elapsed();
            let fallback_us = elapsed.as_micros() as u64;
            self.trace_op(EventKind::OpEnd, &task, DEV_GPU, lane, stage_idx, chunk, fallback_us);
            match result {
                Ok(outs) => {
                    // metrics attribute this to the controller's device,
                    // but the *profile* records it as a CPU-member sample —
                    // the controller only emulated the accelerator, and a
                    // GPU sample here would drive the measured speedup to
                    // ~1 and corrupt PATS ordering
                    self.metrics.record_op(&op.name, DeviceKind::Gpu, elapsed);
                    self.profiles.record(&op.op, DeviceKind::Cpu, elapsed);
                    self.finish_op(task.key, outs, None);
                }
                Err(e) => self.push_error(task.key.0, e.to_string()),
            }
        }
    }
}

/// Cheap content checksum of a value (debug-build aliasing oracle).
#[cfg(debug_assertions)]
fn value_checksum(v: &Value) -> u64 {
    const PRIME: u64 = 0x100_0000_01b3;
    let fold = |h: u64, bits: u32| (h ^ bits as u64).wrapping_mul(PRIME);
    match v {
        Value::Scalar(s) => fold(0xcbf2_9ce4_8422_2325, s.to_bits()),
        Value::Tensor(t) => {
            let mut h = 0xcbf2_9ce4_8422_2325u64;
            for &d in t.shape() {
                h = fold(h, d as u32);
            }
            for &f in t.data() {
                h = fold(h, f.to_bits());
            }
            h
        }
    }
}

/// Spawn the device threads for a WRM; returns their join handles.
///
/// Threads come from [`crate::runtime::sync::thread`] so that, under
/// `cfg(htap_model)`, the device threads run inside the virtual scheduler
/// and every spawn is an explored interleaving point.
pub fn spawn_device_threads(
    wrm: &Arc<Wrm>,
    cfg: &RunConfig,
    topo: &NodeTopology,
) -> Vec<sync::thread::JoinHandle<()>> {
    let mut handles = Vec::new();
    for c in 0..cfg.cpu_workers {
        let w = wrm.clone();
        handles.push(
            sync::thread::Builder::new()
                .name(format!("htap-cpu-{c}"))
                .spawn(move || w.cpu_thread(c))
                // lint: allow(panic) — failing to spawn at startup is fatal
                .expect("spawn cpu thread"),
        );
    }
    for g in 0..cfg.gpu_workers {
        let w = wrm.clone();
        let topo = topo.clone();
        let placement = cfg.placement;
        handles.push(
            sync::thread::Builder::new()
                .name(format!("htap-gpu-{g}"))
                .spawn(move || w.gpu_thread(g, &topo, placement))
                // lint: allow(panic) — failing to spawn at startup is fatal
                .expect("spawn gpu thread"),
        );
    }
    handles
}

/// Convenience: execute one assignment's stage fully on the current thread
/// with CPU variants (used by tests as the concurrency oracle).
pub fn execute_serial(workflow: &Workflow, a: &Assignment) -> Result<Vec<Value>> {
    let stage: &StageDef = workflow
        .stages
        .get(a.stage_idx)
        .ok_or_else(|| Error::Scheduler("bad stage idx".into()))?;
    crate::dataflow::run_stage_serial(stage, &a.inputs)
}
