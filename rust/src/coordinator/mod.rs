//! The runtime middleware (the paper's contribution): Manager–Worker
//! demand-driven execution + within-node hybrid scheduling.
//!
//! * [`manager`] — workflow instantiation, dependency tracking, windowed
//!   demand-driven assignment (§III-B), plus the elastic-membership layer:
//!   lease-tracked workers, missed-lease expiry (purge + requeue + cold
//!   re-execution) and the replayable completion journal.
//! * [`checkpoint`] — periodic manager checkpoint (journal + catalog) so
//!   `htap manager --resume` survives a manager crash.
//! * [`worker`] — the Worker process: WCC + WRM (§III-B, Fig. 5).
//! * [`wrm`] — fine-grain operation scheduling onto CPU cores and GPUs.
//! * [`sched`] — FCFS / PATS policies with data-locality assignment
//!   (§IV-B, §IV-C); shared with the simulator.
//! * [`placement`] — architecture-aware GPU-controller placement (§IV-A).

pub mod checkpoint;
pub mod manager;
pub mod placement;
pub mod sched;
pub mod worker;
pub mod wrm;

pub use manager::{
    Assignment, AssignPolicy, ChunkId, ChunkLoader, CompletionRecord, Manager, Partition,
    WorkBatch, WorkRequest, WorkSource,
};
pub use placement::NodeTopology;
pub use worker::WorkerStaging;

use crate::config::RunConfig;
use crate::data::staging::{ChunkSource, SpillTier, StagingCache};
use crate::dataflow::Workflow;
use crate::metrics::{MetricsHub, MetricsReport};
use crate::obs::{self, Tracer};
use crate::runtime::calibrate::SharedProfiles;
use crate::runtime::ArtifactManifest;
use crate::Result;
use std::collections::HashMap;
use std::sync::Arc;

/// Result of a local run.
pub struct RunOutcome {
    pub metrics: MetricsReport,
    pub manager: Arc<Manager>,
    /// The run's live profile store: offline seed (if any) + the online
    /// EWMA updates recorded by the WRM.  Snapshot it to persist measured
    /// estimates (`htap run --save-profiles`).
    pub profiles: Arc<SharedProfiles>,
}

/// Execute a workflow on this machine: one in-process Manager + one Worker
/// using the configured device threads.  This is the single-node execution
/// mode (the cluster modes are `net::` for real distribution and `sim::`
/// for calibrated scale).
pub fn run_local(
    workflow: Arc<Workflow>,
    loader: ChunkLoader,
    n_chunks: usize,
    cfg: RunConfig,
    stage_bindings: HashMap<String, String>,
) -> Result<RunOutcome> {
    run_local_profiled(workflow, loader, n_chunks, cfg, stage_bindings, SharedProfiles::fresh())
}

/// [`run_local`] with a caller-supplied profile store (seeded from a
/// calibrated `profiles.json`); completion times fold into it online.
pub fn run_local_profiled(
    workflow: Arc<Workflow>,
    loader: ChunkLoader,
    n_chunks: usize,
    cfg: RunConfig,
    stage_bindings: HashMap<String, String>,
    profiles: Arc<SharedProfiles>,
) -> Result<RunOutcome> {
    let manager = Manager::new(workflow.clone(), loader, n_chunks)?;
    let metrics = hub_from_config(&cfg, 1);
    run_local_inner(workflow, manager, cfg, stage_bindings, profiles, None, metrics)
}

/// Build the run's metrics hub.  With `--trace-out` set, the hub carries a
/// live tracer (events stamped with `worker`) and a shared instrument
/// registry; otherwise tracing is a single relaxed load per call site.
pub fn hub_from_config(cfg: &RunConfig, worker: u64) -> Arc<MetricsHub> {
    if cfg.trace_out.is_some() {
        Arc::new(MetricsHub::with_obs(Arc::new(obs::Registry::new()), Tracer::new(worker)))
    } else {
        Arc::new(MetricsHub::new())
    }
}

/// Build the optional local-disk spill tier for a worker from the run
/// config (`--spill-dir` / `--spill-cap`).  Each worker gets a private
/// `worker-N` subdirectory so co-located processes never collide.
/// `warm` selects warm restart: the tier recovers the chunks that
/// survived in the spill directory (and the staging cache re-advertises
/// them as disk-tier holders) instead of starting from a cleared dir.
pub fn spill_from_config(cfg: &RunConfig, worker_id: u64, warm: bool) -> Result<Option<SpillTier>> {
    match &cfg.spill_dir {
        Some(dir) => {
            let dir = std::path::Path::new(dir).join(format!("worker-{worker_id}"));
            let tier = if warm {
                SpillTier::recover(dir, cfg.spill_cap)?
            } else {
                SpillTier::create(dir, cfg.spill_cap)?
            };
            Ok(Some(tier))
        }
        None => Ok(None),
    }
}

/// [`run_local_profiled`] in **staged** mode: the Manager hands out bare
/// chunk ids, the in-process Worker stages payloads from `source` through
/// a bounded [`StagingCache`] whose prefetcher overlaps reads with compute
/// (`cfg.prefetch_depth`, `cfg.staging_cap`) and whose evictions demote to
/// the local-disk spill tier when one is configured (`cfg.spill_dir`), and
/// assignment follows the locality-aware catalog policy
/// (`cfg.chunk_locality` / `cfg.replication` / `cfg.partition`).  Staging
/// counters land in the returned metrics report.
pub fn run_local_staged(
    workflow: Arc<Workflow>,
    source: Arc<dyn ChunkSource>,
    n_chunks: usize,
    cfg: RunConfig,
    stage_bindings: HashMap<String, String>,
    profiles: Arc<SharedProfiles>,
) -> Result<RunOutcome> {
    let policy = AssignPolicy::from_config(&cfg, vec![1]);
    let manager = Manager::new_staged(workflow.clone(), n_chunks, policy)?;
    let mut spill = spill_from_config(&cfg, 1, false)?;
    let metrics = hub_from_config(&cfg, 1);
    // `cfg.fault_plan` arms the staging-layer fault sites for local runs
    // (the net sites have no wire to fault here); flag-level overrides
    // were already merged into the config by the CLI layer
    let faults = crate::faults::Faults::from_sources(
        None,
        cfg.fault_plan.as_deref(),
        cfg.fault_seed,
        metrics.registry(),
    )?;
    let source = if faults.is_armed() {
        if let Some(tier) = spill.as_mut() {
            tier.set_faults(faults.clone());
        }
        crate::data::staging::FaultySource::wrap(source, faults)
    } else {
        source
    };
    let staging = worker::WorkerStaging {
        cache: StagingCache::with_obs(
            source,
            cfg.staging_cap,
            cfg.prefetch_depth,
            spill,
            metrics.registry(),
            metrics.tracer().clone(),
        ),
        worker_id: 1,
        prefetch_budget: cfg.prefetch_depth,
    };
    run_local_inner(workflow, manager, cfg, stage_bindings, profiles, Some(staging), metrics)
}

/// Shared single-node run harness: one in-process Worker against `manager`.
fn run_local_inner(
    workflow: Arc<Workflow>,
    manager: Arc<Manager>,
    cfg: RunConfig,
    stage_bindings: HashMap<String, String>,
    profiles: Arc<SharedProfiles>,
    staging: Option<worker::WorkerStaging>,
    metrics: Arc<MetricsHub>,
) -> Result<RunOutcome> {
    // No artifacts built => every variant degrades to its CPU member.
    let manifest = Arc::new(ArtifactManifest::discover_or_empty());
    let trace_out = cfg.trace_out.clone();
    metrics.mark_start();
    worker::run_worker_staged(
        manager.clone(),
        workflow,
        cfg,
        manifest,
        metrics.clone(),
        stage_bindings,
        profiles.clone(),
        staging,
    )?;
    metrics.mark_finish();
    if let Some(e) = manager.error() {
        return Err(crate::Error::Scheduler(e));
    }
    if let Some(path) = &trace_out {
        // one stream: events the worker shipped to the manager's collector
        // (plus the manager's own membership events), then whatever is
        // still sitting in the local rings
        let mut events = manager.collector().merged();
        events.extend(metrics.tracer().drain());
        events.sort_by_key(|e| (e.ts_us, e.worker, e.lane));
        obs::write_trace(path, &events)?;
        eprintln!("htap: wrote {} trace events to {path} (+ {path}.jsonl)", events.len());
    }
    Ok(RunOutcome { metrics: metrics.report(), manager, profiles })
}
