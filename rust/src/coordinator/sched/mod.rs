//! WRM scheduling policies (paper §IV): FCFS baseline and PATS, both with
//! optional data-locality-conscious (DL) assignment.
//!
//! The policy implementations are **engine-agnostic**: the real Worker
//! Resource Manager (threads + PJRT) and the discrete-event simulator both
//! drive the same `OpScheduler` objects, so every benchmark exercises the
//! actual production scheduling code.

use crate::metrics::DeviceKind;
use std::collections::VecDeque;

/// Key of an operation instance: (stage instance id, op index).
pub type OpInstKey = (u64, usize);

/// A ready-to-run operation instance, as seen by the scheduler.
#[derive(Debug, Clone)]
pub struct ReadyTask {
    pub key: OpInstKey,
    pub name: String,
    /// Estimated GPU-vs-CPU speedup (paper Fig. 7; possibly perturbed for
    /// the Fig. 13 sensitivity experiments).
    pub speedup: f32,
    /// Fraction of GPU execution spent in data transfer (paper §IV-C).
    pub transfer_impact: f32,
    /// FIFO sequence number (creation order).
    pub seq: u64,
    /// Device id (GPU) whose memory already holds an input of this task.
    pub resident_on: Option<usize>,
    /// Whether the op's function variant has an accelerator member.
    pub has_gpu_impl: bool,
}

/// A scheduling policy over ready operation instances.
pub trait OpScheduler: Send {
    /// Add a newly-ready task.
    fn push(&mut self, task: ReadyTask);

    /// Pick a task for an idle device, honouring data locality if `dl`.
    /// Returns `None` when no *eligible* task exists (e.g. a GPU asking
    /// while only CPU-only tasks are queued).
    fn pop(&mut self, device: DeviceKind, device_id: usize, dl: bool) -> Option<ReadyTask>;

    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn name(&self) -> &'static str;
}

/// First-come-first-served (paper's baseline, §IV intro).
#[derive(Default)]
pub struct Fcfs {
    queue: VecDeque<ReadyTask>,
}

impl Fcfs {
    pub fn new() -> Self {
        Self::default()
    }
}

impl OpScheduler for Fcfs {
    fn push(&mut self, task: ReadyTask) {
        self.queue.push_back(task);
    }

    fn pop(&mut self, device: DeviceKind, device_id: usize, dl: bool) -> Option<ReadyTask> {
        match device {
            DeviceKind::Cpu => self.queue.pop_front(),
            DeviceKind::Gpu => {
                // With DL, prefer the first task whose data is resident here.
                if dl {
                    if let Some(pos) = self
                        .queue
                        .iter()
                        .position(|t| t.has_gpu_impl && t.resident_on == Some(device_id))
                    {
                        return self.queue.remove(pos);
                    }
                }
                let pos = self.queue.iter().position(|t| t.has_gpu_impl)?;
                self.queue.remove(pos)
            }
        }
    }

    fn len(&self) -> usize {
        self.queue.len()
    }

    fn name(&self) -> &'static str {
        "FCFS"
    }
}

/// PATS — Performance-Aware Task Scheduling (paper §IV-B, formerly
/// PRIORITY [36]).  The queue is kept sorted by estimated speedup; an idle
/// CPU takes the minimum-speedup task, an idle GPU the maximum-speedup one.
/// Correct behaviour relies only on the *relative order* of estimates.
///
/// With DL (§IV-C): when a GPU asks and a dependent task's data is already
/// resident there, the dependent is chosen iff
/// `S_d >= S_q * (1 - transferImpact)` where `S_q` is the best-speedup
/// non-resident candidate.
pub struct Pats {
    /// Sorted ascending by (speedup, seq).  Insertion keeps order; windows
    /// are small (paper Table II sweeps 12..19) so O(n) insert is the
    /// right trade-off vs tree overhead.
    queue: Vec<ReadyTask>,
}

impl Pats {
    pub fn new() -> Self {
        Pats { queue: Vec::new() }
    }

    fn insert_sorted(&mut self, task: ReadyTask) {
        let pos = self
            .queue
            .partition_point(|t| (t.speedup, t.seq) <= (task.speedup, task.seq));
        self.queue.insert(pos, task);
    }

    /// Index of the best GPU candidate (max speedup with a GPU impl),
    /// optionally restricted to tasks resident on `device_id`.
    fn best_gpu_idx(&self, resident_on: Option<usize>) -> Option<usize> {
        self.queue
            .iter()
            .enumerate()
            .rev()
            .find(|(_, t)| {
                t.has_gpu_impl
                    && match resident_on {
                        Some(d) => t.resident_on == Some(d),
                        None => true,
                    }
            })
            .map(|(i, _)| i)
    }
}

impl OpScheduler for Pats {
    fn push(&mut self, task: ReadyTask) {
        self.insert_sorted(task);
    }

    fn pop(&mut self, device: DeviceKind, device_id: usize, dl: bool) -> Option<ReadyTask> {
        match device {
            DeviceKind::Cpu => {
                if self.queue.is_empty() {
                    None
                } else {
                    Some(self.queue.remove(0))
                }
            }
            DeviceKind::Gpu => {
                let best_any = self.best_gpu_idx(None)?;
                if dl {
                    if let Some(best_dep) = self.best_gpu_idx(Some(device_id)) {
                        let s_d = self.queue[best_dep].speedup;
                        let q = &self.queue[best_any];
                        // paper §IV-C: reuse data unless a non-resident task
                        // gains enough to pay its transfer penalty.
                        if best_dep == best_any
                            || s_d >= q.speedup * (1.0 - q.transfer_impact)
                        {
                            return Some(self.queue.remove(best_dep));
                        }
                    }
                }
                Some(self.queue.remove(best_any))
            }
        }
    }

    fn len(&self) -> usize {
        self.queue.len()
    }

    fn name(&self) -> &'static str {
        "PATS"
    }
}

/// Build a scheduler from the config policy.
pub fn make_scheduler(policy: crate::config::Policy) -> Box<dyn OpScheduler> {
    match policy {
        crate::config::Policy::Fcfs => Box::new(Fcfs::new()),
        crate::config::Policy::Pats => Box::new(Pats::new()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task(key: u64, speedup: f32, seq: u64) -> ReadyTask {
        ReadyTask {
            key: (key, 0),
            name: format!("op{key}"),
            speedup,
            transfer_impact: 0.1,
            seq,
            resident_on: None,
            has_gpu_impl: true,
        }
    }

    #[test]
    fn fcfs_is_fifo_for_cpu() {
        let mut s = Fcfs::new();
        for i in 0..5 {
            s.push(task(i, (5 - i) as f32, i));
        }
        for i in 0..5 {
            assert_eq!(s.pop(DeviceKind::Cpu, 0, false).unwrap().key.0, i);
        }
        assert!(s.is_empty());
    }

    #[test]
    fn fcfs_gpu_skips_cpu_only_tasks() {
        let mut s = Fcfs::new();
        let mut t0 = task(0, 1.0, 0);
        t0.has_gpu_impl = false;
        s.push(t0);
        s.push(task(1, 2.0, 1));
        assert_eq!(s.pop(DeviceKind::Gpu, 0, false).unwrap().key.0, 1);
        // cpu still sees the cpu-only task
        assert_eq!(s.pop(DeviceKind::Cpu, 0, false).unwrap().key.0, 0);
    }

    #[test]
    fn pats_cpu_takes_min_gpu_takes_max() {
        let mut s = Pats::new();
        s.push(task(0, 3.0, 0));
        s.push(task(1, 30.0, 1));
        s.push(task(2, 1.5, 2));
        assert_eq!(s.pop(DeviceKind::Cpu, 0, false).unwrap().key.0, 2);
        assert_eq!(s.pop(DeviceKind::Gpu, 0, false).unwrap().key.0, 1);
        assert_eq!(s.pop(DeviceKind::Cpu, 0, false).unwrap().key.0, 0);
    }

    #[test]
    fn pats_queue_stays_sorted_under_random_pushes() {
        use crate::testing::{forall, Rng};
        forall(
            "pats sorted",
            30,
            |r: &mut Rng| {
                let n = r.range(1, 40);
                (0..n).map(|i| task(i as u64, r.f32_range(0.5, 50.0), i as u64)).collect::<Vec<_>>()
            },
            |tasks| {
                let mut s = Pats::new();
                for t in tasks.clone() {
                    s.push(t);
                }
                let mut last = f32::NEG_INFINITY;
                for t in &s.queue {
                    if t.speedup < last {
                        return Err("queue out of order".into());
                    }
                    last = t.speedup;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn pats_ties_broken_by_fifo() {
        let mut s = Pats::new();
        s.push(task(0, 2.0, 0));
        s.push(task(1, 2.0, 1));
        assert_eq!(s.pop(DeviceKind::Cpu, 0, false).unwrap().key.0, 0);
    }

    #[test]
    fn pats_dl_prefers_resident_when_close() {
        let mut s = Pats::new();
        let mut dep = task(0, 9.0, 0);
        dep.resident_on = Some(2);
        s.push(dep);
        s.push(task(1, 10.0, 1)); // ti = 0.1 -> threshold 9.0
        // S_d = 9.0 >= 10.0 * 0.9 = 9.0 -> dependent wins
        assert_eq!(s.pop(DeviceKind::Gpu, 2, true).unwrap().key.0, 0);
    }

    #[test]
    fn pats_dl_defers_to_much_faster_task() {
        let mut s = Pats::new();
        let mut dep = task(0, 2.0, 0);
        dep.resident_on = Some(2);
        s.push(dep);
        s.push(task(1, 10.0, 1));
        // S_d = 2.0 < 9.0 -> the faster non-resident task wins
        assert_eq!(s.pop(DeviceKind::Gpu, 2, true).unwrap().key.0, 1);
    }

    #[test]
    fn pats_dl_ignores_other_devices_residency() {
        let mut s = Pats::new();
        let mut dep = task(0, 2.0, 0);
        dep.resident_on = Some(7); // resident on a *different* GPU
        s.push(dep);
        s.push(task(1, 3.0, 1));
        assert_eq!(s.pop(DeviceKind::Gpu, 2, true).unwrap().key.0, 1);
    }

    #[test]
    fn fcfs_dl_prefers_resident() {
        let mut s = Fcfs::new();
        s.push(task(0, 1.0, 0));
        let mut dep = task(1, 1.0, 1);
        dep.resident_on = Some(0);
        s.push(dep);
        assert_eq!(s.pop(DeviceKind::Gpu, 0, true).unwrap().key.0, 1);
        // without DL it would have been FIFO
        let mut s = Fcfs::new();
        s.push(task(0, 1.0, 0));
        let mut dep = task(1, 1.0, 1);
        dep.resident_on = Some(0);
        s.push(dep);
        assert_eq!(s.pop(DeviceKind::Gpu, 0, false).unwrap().key.0, 0);
    }

    #[test]
    fn gpu_returns_none_when_nothing_eligible() {
        let mut s = Pats::new();
        let mut t = task(0, 5.0, 0);
        t.has_gpu_impl = false;
        s.push(t);
        assert!(s.pop(DeviceKind::Gpu, 0, false).is_none());
        assert_eq!(s.len(), 1);
    }
}
