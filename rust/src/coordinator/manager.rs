//! The Manager (paper §III-B): instantiates the abstract workflow over the
//! dataset's chunks, tracks inter-stage dependencies, and hands *stage
//! instances* to Workers with demand-driven, window-limited assignment.
//!
//! Stage instances are assigned **in creation order**; Workers request more
//! as they finish (the window size bounds how many a Worker holds — paper
//! §V-F / Table II).  Both Fig. 3 instantiation styles are supported:
//! per-chunk replication (`StageKind::PerChunk`) and aggregation of
//! intermediary results (`StageKind::Reduce`).

use crate::dataflow::{StageInput, StageKind, Workflow};
use crate::runtime::Value;
use crate::{Error, Result};
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};

/// Identifies a data chunk (e.g. one image tile).
pub type ChunkId = u64;

/// Chunk payload provider (tile loader).  Called once per chunk at
/// instantiation time; the paper's equivalent is the Worker reading tiles
/// from Lustre, and the Fig. 8/14 experiments include this I/O.
pub type ChunkLoader = Arc<dyn Fn(ChunkId) -> Result<Vec<Value>> + Send + Sync>;

/// Sentinel chunk id for Reduce-stage instances.
pub const REDUCE_CHUNK: ChunkId = u64::MAX;

/// One unit of Worker-level work: a `(chunk, stage)` tuple plus its inputs.
#[derive(Debug, Clone, PartialEq)]
pub struct Assignment {
    pub instance_id: u64,
    pub stage_idx: usize,
    pub chunk: ChunkId,
    pub inputs: Vec<Value>,
}

/// Work-source abstraction: the in-process [`Manager`] and the TCP client
/// (`net::RemoteManager`) implement the same demand-driven protocol.
pub trait WorkSource: Send + Sync {
    /// Blocking: wait until up to `capacity` assignments are available.
    /// An empty result means the workflow has fully completed.
    fn request(&self, capacity: usize) -> Vec<Assignment>;

    /// Report a finished stage instance with its outputs.
    fn complete(&self, instance_id: u64, outputs: Vec<Value>);
}

struct MgrState {
    pending: VecDeque<Assignment>,
    next_id: u64,
    /// (stage, chunk) -> remaining upstream completions.
    waiting: HashMap<(usize, ChunkId), usize>,
    /// (stage, chunk) -> that instance's outputs (kept only if consumed
    /// downstream).
    outputs: HashMap<(usize, ChunkId), Vec<Value>>,
    /// leased assignments, kept whole so they can be re-issued if the
    /// holding Worker dies (fault tolerance, cf. the authors' earlier
    /// "reliable scientific workflow system" [13])
    inflight: HashMap<u64, Assignment>,
    /// completions for ids no longer inflight (stale duplicates from
    /// workers presumed dead) — counted, not fatal
    stale_completions: u64,
    /// Reduce stage -> per-chunk upstream outputs (ordered by chunk).
    reduce_acc: HashMap<usize, BTreeMap<ChunkId, Vec<Value>>>,
    reduce_remaining: HashMap<usize, usize>,
    remaining_instances: usize,
    completed_instances: usize,
    error: Option<String>,
}

/// In-process Manager.
pub struct Manager {
    workflow: Arc<Workflow>,
    loader: ChunkLoader,
    n_chunks: usize,
    /// stages that someone downstream consumes (outputs must be retained)
    has_dependents: Vec<bool>,
    state: Mutex<MgrState>,
    cv: Condvar,
}

impl Manager {
    pub fn new(workflow: Arc<Workflow>, loader: ChunkLoader, n_chunks: usize) -> Result<Arc<Self>> {
        workflow.validate()?;
        let n_stages = workflow.stages.len();
        let mut has_dependents = vec![false; n_stages];
        for stage in &workflow.stages {
            for input in &stage.inputs {
                if let StageInput::Upstream { stage: up, .. } = input {
                    has_dependents[*up] = true;
                }
            }
        }
        let mut remaining = 0usize;
        for s in &workflow.stages {
            remaining += match s.kind {
                StageKind::PerChunk => n_chunks,
                StageKind::Reduce => 1,
            };
        }
        let mgr = Arc::new(Manager {
            workflow: workflow.clone(),
            loader,
            n_chunks,
            has_dependents,
            state: Mutex::new(MgrState {
                pending: VecDeque::new(),
                next_id: 0,
                waiting: HashMap::new(),
                outputs: HashMap::new(),
                inflight: HashMap::new(),
                reduce_acc: HashMap::new(),
                reduce_remaining: HashMap::new(),
                remaining_instances: remaining,
                completed_instances: 0,
                stale_completions: 0,
                error: None,
            }),
            cv: Condvar::new(),
        });
        mgr.seed()?;
        Ok(mgr)
    }

    /// Create the initial instances: every PerChunk stage whose inputs are
    /// all `Chunk` (no upstream), chunk-major so tiles flow in order.
    fn seed(&self) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        // initialise waiting counters for dependent stages
        for (si, stage) in self.workflow.stages.iter().enumerate() {
            let ups = self.workflow.upstream_of(si);
            match stage.kind {
                StageKind::PerChunk if !ups.is_empty() => {
                    for c in 0..self.n_chunks {
                        st.waiting.insert((si, c as ChunkId), ups.len());
                    }
                }
                StageKind::Reduce => {
                    // each upstream contributes n_chunks completions
                    st.reduce_remaining.insert(si, ups.len() * self.n_chunks);
                    st.reduce_acc.insert(si, BTreeMap::new());
                }
                _ => {}
            }
        }
        for c in 0..self.n_chunks {
            for (si, stage) in self.workflow.stages.iter().enumerate() {
                if stage.kind == StageKind::PerChunk && self.workflow.upstream_of(si).is_empty() {
                    let inputs = self.assemble_chunk_only_inputs(si, c as ChunkId)?;
                    let id = st.next_id;
                    st.next_id += 1;
                    let a = Assignment {
                        instance_id: id,
                        stage_idx: si,
                        chunk: c as ChunkId,
                        inputs,
                    };
                    st.inflight.insert(id, a.clone());
                    st.pending.push_back(a);
                }
            }
        }
        drop(st);
        self.cv.notify_all();
        Ok(())
    }

    fn assemble_chunk_only_inputs(&self, stage: usize, chunk: ChunkId) -> Result<Vec<Value>> {
        let mut inputs = Vec::new();
        for si in &self.workflow.stages[stage].inputs {
            match si {
                StageInput::Chunk => inputs.extend((self.loader)(chunk)?),
                StageInput::Upstream { .. } => {
                    return Err(Error::Scheduler("stage has upstream inputs".into()))
                }
            }
        }
        Ok(inputs)
    }

    /// Assemble a dependent PerChunk instance's inputs from chunk data +
    /// retained upstream outputs.
    fn assemble_dependent_inputs(
        &self,
        st: &MgrState,
        stage: usize,
        chunk: ChunkId,
    ) -> Result<Vec<Value>> {
        let mut inputs = Vec::new();
        for si in &self.workflow.stages[stage].inputs {
            match si {
                StageInput::Chunk => inputs.extend((self.loader)(chunk)?),
                StageInput::Upstream { stage: up, output } => {
                    let outs = st
                        .outputs
                        .get(&(*up, chunk))
                        .ok_or_else(|| Error::Scheduler(format!("missing outputs of ({up},{chunk})")))?;
                    inputs.push(
                        outs.get(*output)
                            .cloned()
                            .ok_or_else(|| Error::Scheduler("upstream output index".into()))?,
                    );
                }
            }
        }
        Ok(inputs)
    }

    /// Progress counters: (completed, total).
    pub fn progress(&self) -> (usize, usize) {
        let st = self.state.lock().unwrap();
        let total = st.completed_instances + st.remaining_instances;
        (st.completed_instances, total)
    }

    /// First error reported by a worker, if any.
    pub fn error(&self) -> Option<String> {
        self.state.lock().unwrap().error.clone()
    }

    /// Record a fatal worker error; unblocks all requesters.
    pub fn fail(&self, msg: String) {
        let mut st = self.state.lock().unwrap();
        st.error = Some(msg);
        st.remaining_instances = 0;
        st.pending.clear();
        drop(st);
        self.cv.notify_all();
    }

    /// Re-issue the leases a dead Worker held: any of `ids` still inflight
    /// goes back to the front of the pending queue (fault tolerance; the
    /// demand-driven protocol makes this safe — instance ids are stable and
    /// duplicate completions are ignored).  Returns how many were requeued.
    pub fn requeue_stale(&self, ids: &[u64]) -> usize {
        let mut st = self.state.lock().unwrap();
        let mut n = 0;
        for id in ids {
            if let Some(a) = st.inflight.get(id).cloned() {
                // only requeue if not already sitting in pending (a lease is
                // "held" once popped by request(); seeding also pre-inserts)
                if !st.pending.iter().any(|p| p.instance_id == *id) {
                    st.pending.push_front(a);
                    n += 1;
                }
            }
        }
        drop(st);
        if n > 0 {
            self.cv.notify_all();
        }
        n
    }

    /// Number of duplicate/stale completions observed (metrics).
    pub fn stale_completions(&self) -> u64 {
        self.state.lock().unwrap().stale_completions
    }

    /// Outputs of a Reduce stage (after completion), looked up by stage
    /// *name* — e.g. `reduce_outputs("classification")`.  None if no such
    /// stage exists, it hasn't completed, or it isn't a Reduce stage.
    pub fn reduce_outputs(&self, stage: &str) -> Option<Vec<Value>> {
        let idx = self.workflow.stage_index(stage)?;
        let st = self.state.lock().unwrap();
        st.outputs.get(&(idx, REDUCE_CHUNK)).cloned()
    }
}

impl WorkSource for Manager {
    fn request(&self, capacity: usize) -> Vec<Assignment> {
        let mut st = self.state.lock().unwrap();
        loop {
            if !st.pending.is_empty() {
                let n = capacity.min(st.pending.len()).max(1);
                let out: Vec<Assignment> = (0..n).filter_map(|_| st.pending.pop_front()).collect();
                return out;
            }
            if st.remaining_instances == 0 || st.error.is_some() {
                return Vec::new();
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    fn complete(&self, instance_id: u64, outs: Vec<Value>) {
        let mut st = self.state.lock().unwrap();
        let Some(assignment) = st.inflight.remove(&instance_id) else {
            // duplicate completion from a worker presumed dead whose lease
            // was re-issued and already completed — ignore, count it
            st.stale_completions += 1;
            self.cv.notify_all();
            return;
        };
        let (stage, chunk) = (assignment.stage_idx, assignment.chunk);
        st.completed_instances += 1;
        st.remaining_instances = st.remaining_instances.saturating_sub(1);
        // retain outputs consumed downstream; Reduce outputs are final
        // results the caller reads back via `reduce_outputs`.
        if self.has_dependents[stage] || self.workflow.stages[stage].kind == StageKind::Reduce {
            st.outputs.insert((stage, chunk), outs.clone());
        }
        // unblock dependents
        let mut to_create: Vec<(usize, ChunkId)> = Vec::new();
        for (di, dstage) in self.workflow.stages.iter().enumerate() {
            let depends = self
                .workflow
                .upstream_of(di)
                .contains(&stage);
            if !depends {
                continue;
            }
            match dstage.kind {
                StageKind::PerChunk => {
                    if let Some(rem) = st.waiting.get_mut(&(di, chunk)) {
                        *rem -= 1;
                        if *rem == 0 {
                            st.waiting.remove(&(di, chunk));
                            to_create.push((di, chunk));
                        }
                    }
                }
                StageKind::Reduce => {
                    // append only the outputs this Reduce stage's inputs
                    // reference (in input-spec order)
                    let mut picked = Vec::new();
                    for input in &dstage.inputs {
                        if let StageInput::Upstream { stage: s, output } = input {
                            if *s == stage {
                                if let Some(v) = outs.get(*output) {
                                    picked.push(v.clone());
                                }
                            }
                        }
                    }
                    st.reduce_acc
                        .get_mut(&di)
                        .unwrap()
                        .entry(chunk)
                        .or_default()
                        .extend(picked);
                    let rem = st.reduce_remaining.get_mut(&di).unwrap();
                    *rem -= 1;
                    if *rem == 0 {
                        to_create.push((di, REDUCE_CHUNK));
                    }
                }
            }
        }
        for (di, c) in to_create {
            let inputs = if c == REDUCE_CHUNK {
                // concatenate per-chunk outputs in chunk order
                let acc = st.reduce_acc.remove(&di).unwrap_or_default();
                acc.into_values().flatten().collect()
            } else {
                match self.assemble_dependent_inputs(&st, di, c) {
                    Ok(v) => v,
                    Err(e) => {
                        st.error = Some(e.to_string());
                        self.cv.notify_all();
                        return;
                    }
                }
            };
            let id = st.next_id;
            st.next_id += 1;
            let a = Assignment { instance_id: id, stage_idx: di, chunk: c, inputs };
            st.inflight.insert(id, a.clone());
            st.pending.push_back(a);
        }
        // garbage-collect upstream outputs once every dependent of this
        // chunk has been created (simple heuristic: when nothing waits on
        // this (stage, chunk) pair any more and it's not a reduce input).
        drop(st);
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::{param, OpRegistry, OpSpec, StageHandle, WorkflowBuilder};

    /// Scalar test ops: "add" sums its wired inputs (value + param),
    /// "sum" is the Reduce consume-all aggregator, "fan2" produces (v, 10v).
    fn test_registry() -> OpRegistry {
        let mut r = OpRegistry::new();
        r.register_cpu("add", 1, |args: &[Value]| {
            let mut s = 0.0;
            for v in args {
                s += v.as_scalar()?;
            }
            Ok(vec![Value::Scalar(s)])
        })
        .unwrap();
        r.register_cpu("sum", 1, |args: &[Value]| {
            let mut s = 0.0;
            for v in args {
                s += v.as_scalar()?;
            }
            Ok(vec![Value::Scalar(s)])
        })
        .unwrap();
        r.register(OpSpec::cpu("fan2", 2, |args: &[Value]| {
            let v = args[0].as_scalar()?;
            Ok(vec![Value::Scalar(v), Value::Scalar(v * 10.0)])
        }))
        .unwrap();
        r
    }

    /// A linear chain of PerChunk stages s0 -> s1 -> ..., stage i adding
    /// `adds[i]` to its input.
    fn chain_workflow(adds: &[f32]) -> Arc<Workflow> {
        let mut wb = WorkflowBuilder::new("t", test_registry());
        let mut prev: Option<StageHandle> = None;
        for (i, &add) in adds.iter().enumerate() {
            let mut s = wb.stage(&format!("s{i}"), StageKind::PerChunk);
            let inp = match &prev {
                None => s.input_chunk(),
                Some(h) => s.input_upstream(h.output(0)),
            };
            let op = s.add_op("add", &[inp, param(add)]).unwrap();
            s.export(op.out()).unwrap();
            prev = Some(wb.add_stage(s).unwrap());
        }
        Arc::new(wb.build().unwrap())
    }

    fn loader() -> ChunkLoader {
        Arc::new(|c| Ok(vec![Value::Scalar(c as f32)]))
    }

    fn drive_serial(mgr: &Arc<Manager>) -> usize {
        // single synthetic worker that executes instances serially
        let mut executed = 0;
        loop {
            let batch = mgr.request(4);
            if batch.is_empty() {
                return executed;
            }
            for a in batch {
                let stage = &mgr.workflow.stages[a.stage_idx];
                let outs = crate::dataflow::run_stage_serial(stage, &a.inputs).unwrap();
                executed += 1;
                mgr.complete(a.instance_id, outs);
            }
        }
    }

    #[test]
    fn single_stage_bag_of_tasks() {
        let mgr = Manager::new(chain_workflow(&[1.0]), loader(), 5).unwrap();
        assert_eq!(drive_serial(&mgr), 5);
        let (done, total) = mgr.progress();
        assert_eq!((done, total), (5, 5));
    }

    #[test]
    fn two_stage_chain_routes_outputs() {
        let mgr = Manager::new(chain_workflow(&[10.0, 100.0]), loader(), 3).unwrap();
        assert_eq!(drive_serial(&mgr), 6);
    }

    #[test]
    fn reduce_stage_sees_all_chunks() {
        let mut wb = WorkflowBuilder::new("t", test_registry());
        let mut a = wb.stage("a", StageKind::PerChunk);
        let c = a.input_chunk();
        let op = a.add_op("add", &[c, param(0.0)]).unwrap();
        a.export(op.out()).unwrap();
        let a = wb.add_stage(a).unwrap();
        // reduce stage: sums everything it receives (all-inputs convention)
        let mut red = wb.stage("sum", StageKind::Reduce);
        red.input_upstream(a.output(0));
        let s = red.add_reduce_op("sum").unwrap();
        red.export(s.out()).unwrap();
        wb.add_stage(red).unwrap();
        let mgr = Manager::new(Arc::new(wb.build().unwrap()), loader(), 4).unwrap();
        assert_eq!(drive_serial(&mgr), 5);
        let out = mgr.reduce_outputs("sum").unwrap();
        // chunks 0..4 pass through stage a unchanged, reduce sums: 0+1+2+3
        assert_eq!(out[0].as_scalar().unwrap(), 6.0);
        // unknown stage names resolve to None, not a panic
        assert!(mgr.reduce_outputs("nope").is_none());
    }

    #[test]
    fn assignments_created_in_chunk_order() {
        let mgr = Manager::new(chain_workflow(&[0.0]), loader(), 4).unwrap();
        let batch = mgr.request(10);
        let chunks: Vec<ChunkId> = batch.iter().map(|a| a.chunk).collect();
        assert_eq!(chunks, vec![0, 1, 2, 3]);
        for a in batch {
            mgr.complete(a.instance_id, vec![Value::Scalar(0.0)]);
        }
    }

    #[test]
    fn window_capacity_respected() {
        let mgr = Manager::new(chain_workflow(&[0.0]), loader(), 10).unwrap();
        let batch = mgr.request(3);
        assert_eq!(batch.len(), 3);
        for a in batch {
            mgr.complete(a.instance_id, vec![]);
        }
    }

    #[test]
    fn unknown_completion_is_counted_not_fatal() {
        let mgr = Manager::new(chain_workflow(&[0.0]), loader(), 1).unwrap();
        mgr.complete(999, vec![]);
        assert!(mgr.error().is_none());
        assert_eq!(mgr.stale_completions(), 1);
        drive_serial(&mgr);
    }

    #[test]
    fn requeue_reissues_unfinished_leases() {
        let mgr = Manager::new(chain_workflow(&[1.0]), loader(), 3).unwrap();
        // "worker 1" takes two leases and dies
        let batch = mgr.request(2);
        let ids: Vec<u64> = batch.iter().map(|a| a.instance_id).collect();
        assert_eq!(mgr.requeue_stale(&ids), 2);
        // a healthy worker now drains everything exactly once
        assert_eq!(drive_serial(&mgr), 3);
        // the dead worker's late completion is ignored
        mgr.complete(ids[0], vec![Value::Scalar(0.0)]);
        assert!(mgr.error().is_none());
        assert_eq!(mgr.stale_completions(), 1);
    }

    #[test]
    fn reduce_picks_only_referenced_outputs() {
        // upstream produces 2 outputs; the reduce stage references only
        // output 1 — the aggregate must contain exactly those values.
        let mut wb = WorkflowBuilder::new("t", test_registry());
        let mut up = wb.stage("a", StageKind::PerChunk);
        let c = up.input_chunk();
        let f = up.add_op("fan2", &[c]).unwrap();
        up.export(f.output(0)).unwrap();
        up.export(f.output(1)).unwrap();
        let a = wb.add_stage(up).unwrap();
        let mut red = wb.stage("sum", StageKind::Reduce);
        red.input_upstream(a.output(1));
        let s = red.add_reduce_op("sum").unwrap();
        red.export(s.out()).unwrap();
        wb.add_stage(red).unwrap();
        let mgr = Manager::new(Arc::new(wb.build().unwrap()), loader(), 3).unwrap();
        drive_serial(&mgr);
        let out = mgr.reduce_outputs("sum").unwrap();
        // sum of v*10 over chunks 0..3 = (0+1+2)*10 = 30
        assert_eq!(out[0].as_scalar().unwrap(), 30.0);
    }

    #[test]
    fn concurrent_workers_drain_everything() {
        let mgr = Manager::new(chain_workflow(&[1.0, 2.0]), loader(), 20).unwrap();
        let mut handles = Vec::new();
        for _ in 0..4 {
            let m = mgr.clone();
            handles.push(std::thread::spawn(move || drive_serial(&m)));
        }
        let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, 40);
        assert!(mgr.error().is_none());
    }
}
