//! The Manager (paper §III-B): instantiates the abstract workflow over the
//! dataset's chunks, tracks inter-stage dependencies, and hands *stage
//! instances* to Workers with demand-driven, window-limited assignment.
//!
//! Stage instances are assigned **in creation order**, except when the
//! locality-aware policy (staged mode) finds instances whose chunk the
//! requesting Worker already staged — those jump the queue, and chunks
//! staged on *other* workers are stolen only as the last tier, so the bag
//! of tasks never stalls (paper §IV-C, lifted to the cluster level).
//! Workers request more as they finish (the window size bounds how many a
//! Worker holds — paper §V-F / Table II).  Both Fig. 3 instantiation
//! styles are supported: per-chunk replication (`StageKind::PerChunk`) and
//! aggregation of intermediary results (`StageKind::Reduce`, which may
//! chain — an upstream Reduce contributes a single completed instance).

use crate::data::staging::{ChunkCatalog, Tier, WorkerId, ANON_WORKER};
use crate::dataflow::{StageInput, StageKind, Workflow};
use crate::obs::{self, EventKind, TraceEvent, UtilRow};
use crate::runtime::Value;
use crate::{Error, Result};
use crate::runtime::sync::{self, Condvar, Mutex};
use std::collections::{BTreeMap, HashMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Identifies a data chunk (e.g. one image tile).
pub type ChunkId = u64;

/// Chunk payload provider (tile loader).  Called once per chunk at
/// instantiation time; the paper's equivalent is the Worker reading tiles
/// from Lustre, and the Fig. 8/14 experiments include this I/O.
pub type ChunkLoader = Arc<dyn Fn(ChunkId) -> Result<Vec<Value>> + Send + Sync>;

/// Sentinel chunk id for Reduce-stage instances.
pub const REDUCE_CHUNK: ChunkId = u64::MAX;

/// One unit of Worker-level work: a `(chunk, stage)` tuple plus its inputs.
#[derive(Debug, Clone, PartialEq)]
pub struct Assignment {
    pub instance_id: u64,
    pub stage_idx: usize,
    pub chunk: ChunkId,
    pub inputs: Vec<Value>,
    /// Staged mode: the chunk payload was *not* shipped — `inputs` carries
    /// only the upstream values and the worker splices the payload in from
    /// its own chunk source / staging cache.
    pub needs_chunk: bool,
    /// The manager matched this assignment to the requester's staged set
    /// (locality hit; diagnostics only).
    pub locality: bool,
    /// This assignment was a tier-3 steal and replication left the chunk
    /// multi-homed — the worker should keep its staged copy warm.
    pub replica: bool,
}

/// A demand-driven work request (worker -> manager).  The staging fields
/// are what makes locality-aware assignment possible: the worker announces
/// who it is and which chunks it staged/evicted since its last request.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct WorkRequest {
    /// Max assignments to hand out.
    pub capacity: usize,
    /// Stable worker identity ([`ANON_WORKER`] = anonymous, no staging).
    pub worker: WorkerId,
    /// Chunks newly staged in this worker's cache since the last request.
    pub staged_add: Vec<ChunkId>,
    /// Chunks evicted from the cache since the last request.
    pub staged_drop: Vec<ChunkId>,
    /// Chunks demoted to this worker's local-disk spill tier (still
    /// staged, a tier down).
    pub demoted: Vec<ChunkId>,
    /// How many upcoming chunk ids the worker wants as prefetch hints.
    pub prefetch_budget: usize,
}

impl WorkRequest {
    /// A legacy request: no identity, no staging hints.
    pub fn anonymous(capacity: usize) -> Self {
        WorkRequest { capacity, ..Default::default() }
    }
}

/// A work batch (manager -> worker).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct WorkBatch {
    /// Empty = the workflow has fully completed; shut down (unless
    /// [`WorkBatch::idle`] says otherwise).
    pub assignments: Vec<Assignment>,
    /// Upcoming chunk ids the worker should warm its staging cache with
    /// (likely future assignments not yet staged on this worker).
    pub prefetch: Vec<ChunkId>,
    /// Chunks this batch stole from another worker: they are multi-homed
    /// now (replicate hints) and worth staging eagerly.
    pub replicate: Vec<ChunkId>,
    /// Service mode (proto v5 `Idle`): nothing assignable *right now*, but
    /// the manager is long-running and more jobs may arrive — poll again
    /// instead of treating the empty batch as workflow completion.
    pub idle: bool,
}

/// How the Manager maps cold chunks to workers.
#[derive(Debug, Clone, PartialEq)]
pub enum Partition {
    /// Purely demand-driven: first requester wins a cold chunk.
    Demand,
    /// Catalog-aware initial partitioning: contiguous chunk ranges are
    /// range-assigned to the given workers up front (chunk `c` belongs to
    /// `workers[c * W / n_chunks]`); a worker takes another worker's cold
    /// range only as a last resort, demand-driven thereafter.
    Init(Vec<WorkerId>),
}

/// Staged-mode assignment policy: the catalog-driven locality tiers, the
/// replicate-on-steal rule, and the initial chunk partition.
#[derive(Debug, Clone, PartialEq)]
pub struct AssignPolicy {
    /// Locality-aware (chunk-catalog) assignment.
    pub locality: bool,
    /// Replicate on steal: a stolen chunk stays multi-homed in the catalog
    /// and the thief gets a replicate hint.  Off = single-owner transfer.
    pub replication: bool,
    pub partition: Partition,
}

impl Default for AssignPolicy {
    fn default() -> Self {
        AssignPolicy { locality: true, replication: true, partition: Partition::Demand }
    }
}

impl AssignPolicy {
    /// Demand-driven policy with locality on/off (the pre-tiers default).
    pub fn demand(locality: bool) -> Self {
        AssignPolicy { locality, ..Default::default() }
    }

    /// Derive the policy from a run config; `workers` is the identity set
    /// used when `cfg.partition` asks for initial range-assignment.
    pub fn from_config(cfg: &crate::config::RunConfig, workers: Vec<WorkerId>) -> Self {
        let partition = match cfg.partition {
            crate::config::PartitionMode::Demand => Partition::Demand,
            crate::config::PartitionMode::Init => Partition::Init(workers),
        };
        AssignPolicy { locality: cfg.chunk_locality, replication: cfg.replication, partition }
    }
}

/// Work-source abstraction: the in-process [`Manager`] and the TCP client
/// (`net::RemoteManager`) implement the same demand-driven protocol.
pub trait WorkSource: Send + Sync {
    /// Blocking: wait until up to `req.capacity` assignments are
    /// available.  An empty batch means the workflow has fully completed.
    fn request_work(&self, req: &WorkRequest) -> WorkBatch;

    /// Legacy anonymous request (no staging identity, no hints).
    fn request(&self, capacity: usize) -> Vec<Assignment> {
        self.request_work(&WorkRequest::anonymous(capacity)).assignments
    }

    /// Report a finished stage instance with its outputs.
    fn complete(&self, instance_id: u64, outputs: Vec<Value>);

    /// Elastic membership (v4): announce this worker and the lease term it
    /// promises to renew within.  Default no-op so legacy sources (tests,
    /// fixed-pool drivers) keep working unchanged.
    fn register(&self, _worker: WorkerId, _lease_ms: u64) {}

    /// Renew this worker's lease (liveness signal between completions).
    fn heartbeat(&self, _worker: WorkerId) {}

    /// Clean departure: the worker drained its in-flight work and leaves.
    fn goodbye(&self, _worker: WorkerId) {}

    /// Ship a drained batch of trace events to the manager side (proto v6
    /// `TraceBatch`).  Default no-op so legacy sources and untraced runs
    /// cost nothing; the TCP client forwards the batch on the completion
    /// channel, the in-process Manager merges it into its collector.
    fn trace_events(&self, _worker: WorkerId, _events: Vec<TraceEvent>) {}

    /// Install a hook the source fires after reconnecting to a (possibly
    /// different, e.g. freshly promoted) manager, so worker-side state
    /// like the staged-chunk catalog can be re-advertised in full.
    /// Default no-op: in-process sources never lose the manager.
    fn set_resync(&self, _resync: crate::net::ResyncFn) {}
}

/// One replayable completion: which `(stage, chunk)` instance finished and
/// what it produced.  The manager journals these (when checkpointing is
/// enabled) in completion order, so restoring a checkpoint is a replay of
/// the same completions against a freshly seeded manager.
#[derive(Debug, Clone, PartialEq)]
pub struct CompletionRecord {
    pub stage_idx: usize,
    pub chunk: ChunkId,
    pub outputs: Vec<Value>,
}

/// Liveness bookkeeping for one registered worker.
struct Member {
    last_seen: Instant,
    lease: Duration,
}

struct MgrState {
    pending: VecDeque<Assignment>,
    next_id: u64,
    /// (stage, chunk) -> remaining upstream completions.
    waiting: HashMap<(usize, ChunkId), usize>,
    /// (stage, chunk) -> that instance's outputs (kept only if consumed
    /// downstream).
    outputs: HashMap<(usize, ChunkId), Vec<Value>>,
    /// leased assignments, kept whole so they can be re-issued if the
    /// holding Worker dies (fault tolerance, cf. the authors' earlier
    /// "reliable scientific workflow system" [13])
    inflight: HashMap<u64, Assignment>,
    /// completions for ids no longer inflight (stale duplicates from
    /// workers presumed dead) — counted, not fatal
    stale_completions: u64,
    /// Reduce stage -> per-chunk upstream outputs (ordered by chunk).
    reduce_acc: HashMap<usize, BTreeMap<ChunkId, Vec<Value>>>,
    reduce_remaining: HashMap<usize, usize>,
    remaining_instances: usize,
    completed_instances: usize,
    /// which worker has which chunks staged (staged mode)
    catalog: ChunkCatalog,
    /// assignments handed to the worker that already staged the chunk
    locality_hits: u64,
    /// assignments of cold chunks (staged nowhere yet)
    locality_cold: u64,
    /// assignments stolen from chunks staged on *another* worker
    locality_steals: u64,
    /// steals that left the chunk multi-homed (replicate hints emitted)
    replicated: u64,
    /// workers purged from the catalog (crashed or departed): their homed
    /// chunks are treated as unhomed and no hints target them any more
    purged: HashSet<WorkerId>,
    /// instance id -> worker currently holding that lease (identified
    /// requesters only); drives lease-expiry requeue and journal liveness
    lessee: HashMap<u64, WorkerId>,
    /// registered workers with a live lease (heartbeat-tracked membership)
    members: HashMap<WorkerId, Member>,
    /// completion journal (populated only when checkpointing is enabled)
    journal: Vec<CompletionRecord>,
    error: Option<String>,
}

/// In-process Manager.
pub struct Manager {
    workflow: Arc<Workflow>,
    /// `Some` = legacy mode (manager loads chunk payloads and ships them
    /// in assignments); `None` = staged mode (workers stage chunks from
    /// their own [`crate::data::staging::ChunkSource`]).
    loader: Option<ChunkLoader>,
    n_chunks: usize,
    /// stages that someone downstream consumes (outputs must be retained)
    has_dependents: Vec<bool>,
    /// per stage: in staged mode, does an assignment need the chunk payload
    stage_needs_chunk: Vec<bool>,
    /// locality-aware (catalog) assignment policy enabled
    locality: bool,
    /// replicate-on-steal (vs single-owner transfer)
    replication: bool,
    /// initial partition: chunk -> home worker (empty = demand-driven)
    home: HashMap<ChunkId, WorkerId>,
    /// record a [`CompletionRecord`] per completion for checkpointing
    journal_enabled: AtomicBool,
    /// Merge point for trace batches shipped by workers (proto v6) plus
    /// the manager's own membership events.
    collector: Arc<obs::Collector>,
    state: Mutex<MgrState>,
    cv: Condvar,
}

/// Select one value of a loaded chunk payload, bounds-checked — the
/// loader-mode mirror of the worker's staged `ChunkPart` splice.
fn chunk_part(payload: Vec<Value>, chunk: ChunkId, k: usize) -> Result<Value> {
    let n = payload.len();
    payload.into_iter().nth(k).ok_or_else(|| {
        Error::Scheduler(format!("chunk {chunk} payload has {n} value(s), no part {k}"))
    })
}

impl Manager {
    /// Legacy mode: the manager loads every chunk payload itself and ships
    /// it inside assignments.
    pub fn new(workflow: Arc<Workflow>, loader: ChunkLoader, n_chunks: usize) -> Result<Arc<Self>> {
        Self::build(workflow, Some(loader), n_chunks, AssignPolicy::default())
    }

    /// Staged mode: assignments carry bare chunk ids (plus upstream
    /// values); workers stage chunk payloads from their own source.
    /// `policy` selects the catalog-driven assignment tiers, the
    /// replicate-on-steal rule and the initial chunk partition.
    pub fn new_staged(
        workflow: Arc<Workflow>,
        n_chunks: usize,
        policy: AssignPolicy,
    ) -> Result<Arc<Self>> {
        Self::build(workflow, None, n_chunks, policy)
    }

    fn build(
        workflow: Arc<Workflow>,
        loader: Option<ChunkLoader>,
        n_chunks: usize,
        policy: AssignPolicy,
    ) -> Result<Arc<Self>> {
        workflow.validate()?;
        let n_stages = workflow.stages.len();
        let mut has_dependents = vec![false; n_stages];
        for stage in &workflow.stages {
            for input in &stage.inputs {
                if let StageInput::Upstream { stage: up, .. } = input {
                    has_dependents[*up] = true;
                }
            }
        }
        let staged = loader.is_none();
        let stage_needs_chunk: Vec<bool> = workflow
            .stages
            .iter()
            .map(|s| {
                staged
                    && s.inputs
                        .iter()
                        .any(|i| matches!(i, StageInput::Chunk | StageInput::ChunkPart(_)))
            })
            .collect();
        let mut remaining = 0usize;
        for s in &workflow.stages {
            remaining += match s.kind {
                StageKind::PerChunk => n_chunks,
                StageKind::Reduce => 1,
            };
        }
        // catalog-aware initial partitioning: contiguous chunk ranges per
        // known worker, so each worker's first cold pulls are its own range
        let mut home = HashMap::new();
        if let Partition::Init(workers) = &policy.partition {
            let w = workers.len();
            if w > 0 && n_chunks > 0 {
                for c in 0..n_chunks {
                    home.insert(c as ChunkId, workers[c * w / n_chunks]);
                }
            }
        }
        let mgr = Arc::new(Manager {
            workflow: workflow.clone(),
            loader,
            n_chunks,
            has_dependents,
            stage_needs_chunk,
            locality: policy.locality,
            replication: policy.replication,
            home,
            journal_enabled: AtomicBool::new(false),
            collector: Arc::new(obs::Collector::new()),
            state: Mutex::new(MgrState {
                pending: VecDeque::new(),
                next_id: 0,
                waiting: HashMap::new(),
                outputs: HashMap::new(),
                inflight: HashMap::new(),
                reduce_acc: HashMap::new(),
                reduce_remaining: HashMap::new(),
                remaining_instances: remaining,
                completed_instances: 0,
                catalog: ChunkCatalog::new(),
                locality_hits: 0,
                locality_cold: 0,
                locality_steals: 0,
                replicated: 0,
                purged: HashSet::new(),
                lessee: HashMap::new(),
                members: HashMap::new(),
                journal: Vec::new(),
                stale_completions: 0,
                error: None,
            }),
            cv: Condvar::new(),
        });
        mgr.seed()?;
        Ok(mgr)
    }

    /// Create the initial instances: every PerChunk stage whose inputs are
    /// all `Chunk` (no upstream), chunk-major so tiles flow in order.
    fn seed(&self) -> Result<()> {
        // Runs once at startup before any worker contends for the lock, and
        // may invoke the chunk loader (real I/O) — deliberately NOT marked
        // as a lint critical section.
        let mut st = sync::lock_clean(&self.state);
        // initialise waiting counters for dependent stages
        for (si, stage) in self.workflow.stages.iter().enumerate() {
            let ups = self.workflow.upstream_of(si);
            match stage.kind {
                StageKind::PerChunk if !ups.is_empty() => {
                    for c in 0..self.n_chunks {
                        st.waiting.insert((si, c as ChunkId), ups.len());
                    }
                }
                StageKind::Reduce => {
                    // a PerChunk upstream contributes n_chunks completions,
                    // an upstream Reduce exactly one (chained Reduce)
                    let expected: usize = ups
                        .iter()
                        .map(|&u| match self.workflow.stages[u].kind {
                            StageKind::PerChunk => self.n_chunks,
                            StageKind::Reduce => 1,
                        })
                        .sum();
                    st.reduce_remaining.insert(si, expected);
                    st.reduce_acc.insert(si, BTreeMap::new());
                }
                _ => {}
            }
        }
        for c in 0..self.n_chunks {
            for (si, stage) in self.workflow.stages.iter().enumerate() {
                if stage.kind == StageKind::PerChunk && self.workflow.upstream_of(si).is_empty() {
                    let inputs = self.assemble_chunk_only_inputs(si, c as ChunkId)?;
                    let id = st.next_id;
                    st.next_id += 1;
                    let a = Assignment {
                        instance_id: id,
                        stage_idx: si,
                        chunk: c as ChunkId,
                        inputs,
                        needs_chunk: self.stage_needs_chunk[si],
                        locality: false,
                        replica: false,
                    };
                    st.inflight.insert(id, a.clone());
                    st.pending.push_back(a);
                }
            }
        }
        drop(st);
        self.cv.notify_all();
        Ok(())
    }

    fn assemble_chunk_only_inputs(&self, stage: usize, chunk: ChunkId) -> Result<Vec<Value>> {
        let mut inputs = Vec::new();
        for si in &self.workflow.stages[stage].inputs {
            match si {
                // staged mode (loader absent): the worker splices the
                // payload in from its staging cache
                StageInput::Chunk => {
                    if let Some(loader) = &self.loader {
                        inputs.extend(loader(chunk)?);
                    }
                }
                StageInput::ChunkPart(k) => {
                    if let Some(loader) = &self.loader {
                        inputs.push(chunk_part(loader(chunk)?, chunk, *k)?);
                    }
                }
                StageInput::Upstream { .. } => {
                    return Err(Error::Scheduler("stage has upstream inputs".into()))
                }
            }
        }
        Ok(inputs)
    }

    /// Assemble a dependent PerChunk instance's inputs from chunk data +
    /// retained upstream outputs.
    fn assemble_dependent_inputs(
        &self,
        st: &MgrState,
        stage: usize,
        chunk: ChunkId,
    ) -> Result<Vec<Value>> {
        let mut inputs = Vec::new();
        for si in &self.workflow.stages[stage].inputs {
            match si {
                StageInput::Chunk => {
                    if let Some(loader) = &self.loader {
                        inputs.extend(loader(chunk)?);
                    }
                }
                StageInput::ChunkPart(k) => {
                    if let Some(loader) = &self.loader {
                        inputs.push(chunk_part(loader(chunk)?, chunk, *k)?);
                    }
                }
                StageInput::Upstream { stage: up, output } => {
                    let outs = st
                        .outputs
                        .get(&(*up, chunk))
                        .ok_or_else(|| Error::Scheduler(format!("missing outputs of ({up},{chunk})")))?;
                    inputs.push(
                        outs.get(*output)
                            .cloned()
                            .ok_or_else(|| Error::Scheduler("upstream output index".into()))?,
                    );
                }
            }
        }
        Ok(inputs)
    }

    /// Progress counters: (completed, total).
    pub fn progress(&self) -> (usize, usize) {
        let st = sync::lock_clean(&self.state);
        let total = st.completed_instances + st.remaining_instances;
        (st.completed_instances, total)
    }

    /// First error reported by a worker, if any.
    pub fn error(&self) -> Option<String> {
        sync::lock_clean(&self.state).error.clone()
    }

    /// Record a fatal worker error; unblocks all requesters.
    pub fn fail(&self, msg: String) {
        // lint: critical-section — record the failure and flush the queues
        let mut st = sync::lock_clean(&self.state);
        st.error = Some(msg);
        st.remaining_instances = 0;
        st.pending.clear();
        drop(st);
        self.cv.notify_all();
    }

    /// Re-issue the leases a dead Worker held: any of `ids` still inflight
    /// goes back to the front of the pending queue (fault tolerance; the
    /// demand-driven protocol makes this safe — instance ids are stable and
    /// duplicate completions are ignored).  Returns how many were requeued.
    pub fn requeue_stale(&self, ids: &[u64]) -> usize {
        // lint: critical-section — re-issue dead workers' leases
        let mut st = sync::lock_clean(&self.state);
        let mut n = 0;
        for id in ids {
            st.lessee.remove(id);
            if let Some(a) = st.inflight.get(id).cloned() {
                // only requeue if not already sitting in pending (a lease is
                // "held" once popped by request(); seeding also pre-inserts)
                if !st.pending.iter().any(|p| p.instance_id == *id) {
                    st.pending.push_front(a);
                    n += 1;
                }
            }
        }
        drop(st);
        if n > 0 {
            self.cv.notify_all();
        }
        n
    }

    /// Number of duplicate/stale completions observed (metrics).
    pub fn stale_completions(&self) -> u64 {
        sync::lock_clean(&self.state).stale_completions
    }

    /// Locality-policy counters: (hits, cold, steals) — assignments handed
    /// to the worker that staged the chunk / of chunks staged nowhere / of
    /// chunks staged on another worker.
    pub fn locality_stats(&self) -> (u64, u64, u64) {
        let st = sync::lock_clean(&self.state);
        (st.locality_hits, st.locality_cold, st.locality_steals)
    }

    /// Steals that left the chunk multi-homed (replicate hints emitted).
    pub fn replicated(&self) -> u64 {
        sync::lock_clean(&self.state).replicated
    }

    /// How many workers currently hold `chunk` in the catalog (any tier) —
    /// diagnostics/test hook.
    pub fn chunk_holders(&self, chunk: ChunkId) -> usize {
        sync::lock_clean(&self.state).catalog.holder_count(chunk)
    }

    /// Forget a dead/disconnected worker's catalog entries so its chunks
    /// go back to cold and survivors take them in tier 2 instead of as
    /// steals (pairs with [`Manager::requeue_stale`] on the
    /// fault-tolerance path).  The worker is marked purged: its homed
    /// chunks count as unhomed and no prefetch/replicate hints target it
    /// until it re-registers.  Returns how many entries were dropped.
    pub fn purge_worker(&self, worker: WorkerId) -> usize {
        if worker == ANON_WORKER {
            return 0;
        }
        // lint: critical-section — drop the dead worker's catalog entries
        let mut st = sync::lock_clean(&self.state);
        st.purged.insert(worker);
        st.members.remove(&worker);
        st.catalog.purge_worker(worker)
    }

    /// Dynamic membership: a worker announced itself (proto v4 `Hello`).
    /// `lease_ms == 0` opts out of lease tracking (the worker is still
    /// served, but only its TCP connection signals liveness).  A rejoining
    /// worker is un-purged so its home range counts again.
    pub fn register_worker(&self, worker: WorkerId, lease_ms: u64) {
        if worker == ANON_WORKER {
            return;
        }
        self.membership_event(EventKind::WorkerJoin, worker);
        // lint: critical-section — admit a worker to the membership table
        let mut st = sync::lock_clean(&self.state);
        st.purged.remove(&worker);
        if lease_ms > 0 {
            st.members.insert(
                worker,
                Member { last_seen: Instant::now(), lease: Duration::from_millis(lease_ms) },
            );
        }
    }

    /// Renew a registered worker's lease (proto v4 `Heartbeat`).
    pub fn heartbeat_worker(&self, worker: WorkerId) {
        // lint: critical-section — stamp the member's lease
        let mut st = sync::lock_clean(&self.state);
        if let Some(m) = st.members.get_mut(&worker) {
            m.last_seen = Instant::now();
        }
    }

    /// Expel a worker (clean `Goodbye` or a missed lease): requeue every
    /// lease it held, purge its catalog entries, mark it purged.  Returns
    /// how many stage instances were re-issued.
    pub fn expire_worker(&self, worker: WorkerId) -> usize {
        if worker == ANON_WORKER {
            return 0;
        }
        self.membership_event(EventKind::WorkerLeave, worker);
        // lint: critical-section — fold a departed worker out of all state
        let mut st = sync::lock_clean(&self.state);
        st.members.remove(&worker);
        st.purged.insert(worker);
        st.catalog.purge_worker(worker);
        let held: Vec<u64> = st
            .lessee
            .iter()
            .filter(|&(_, &w)| w == worker)
            .map(|(&id, _)| id)
            .collect();
        let mut requeued = 0;
        for id in held {
            st.lessee.remove(&id);
            if let Some(a) = st.inflight.get(&id).cloned() {
                if !st.pending.iter().any(|p| p.instance_id == id) {
                    st.pending.push_front(a);
                    requeued += 1;
                }
            }
        }
        drop(st);
        if requeued > 0 {
            self.cv.notify_all();
        }
        requeued
    }

    /// Sweep the membership table for missed leases and expire every
    /// worker past its term.  Returns `(worker, re-issued instances)` per
    /// expiry — the manager's liveness loop calls this periodically.
    pub fn sweep_leases(&self) -> Vec<(WorkerId, usize)> {
        let now = Instant::now();
        let expired: Vec<WorkerId> = {
            // lint: critical-section — scan lease deadlines
            let st = sync::lock_clean(&self.state);
            st.members
                .iter()
                .filter(|(_, m)| now.duration_since(m.last_seen) > m.lease)
                .map(|(&w, _)| w)
                .collect()
        };
        expired
            .into_iter()
            .map(|w| {
                // a missed lease gets its own event; expire_worker adds the
                // generic WorkerLeave, so a crash reads Expire + Leave while
                // a clean Goodbye reads Leave alone
                self.membership_event(EventKind::WorkerExpire, w);
                (w, self.expire_worker(w))
            })
            .collect()
    }

    /// Registered (lease-tracked) workers — diagnostics/test hook.
    pub fn member_count(&self) -> usize {
        sync::lock_clean(&self.state).members.len()
    }

    /// Record a membership transition into the collector, stamped with
    /// wall-clock µs so it merges cleanly with worker-shipped spans.
    /// Membership changes are rare, so these are collected unconditionally
    /// (no tracer required on the manager side).
    fn membership_event(&self, kind: EventKind, worker: WorkerId) {
        let ts_us = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_micros() as u64)
            .unwrap_or(0);
        self.collector.ingest_local(vec![TraceEvent { ts_us, worker, ..TraceEvent::of(kind) }]);
    }

    /// Merge a worker's drained trace batch (proto v6 `TraceBatch`).
    pub fn ingest_trace(&self, worker: WorkerId, events: Vec<TraceEvent>) {
        self.collector.ingest(worker, events);
    }

    /// The manager-side merge point for cluster-wide traces: every
    /// worker-shipped batch plus local membership events, one ordered
    /// stream for export.
    pub fn collector(&self) -> &Arc<obs::Collector> {
        &self.collector
    }

    /// Live per-(worker, job) utilization rows (proto v6 `StatsQuery`).
    /// Single-job managers leave tenant attribution empty.
    pub fn utilization(&self) -> Vec<UtilRow> {
        self.collector.util_rows()
    }

    /// Block until the workflow completes or a worker reports a fatal
    /// error.  The elastic accept loop uses this to know when to stop
    /// accepting new workers.
    pub fn wait_done(&self) {
        let mut st = sync::lock_clean(&self.state);
        while st.remaining_instances > 0 && st.error.is_none() {
            st = match self.cv.wait(st) {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
        }
    }

    /// Start journaling completions so [`Manager::checkpoint_state`] has a
    /// replayable record.  Call before any worker connects.
    pub fn enable_journal(&self) {
        self.journal_enabled.store(true, Ordering::Release);
    }

    /// Snapshot for a checkpoint: the completion journal so far plus the
    /// chunk catalog (who holds what, at which tier).  Values are
    /// Arc-backed, so the clones are cheap; encoding happens outside the
    /// lock.
    pub fn checkpoint_state(&self) -> (Vec<CompletionRecord>, Vec<(WorkerId, ChunkId, Tier)>) {
        // lint: critical-section — snapshot journal + catalog
        let st = sync::lock_clean(&self.state);
        (st.journal.clone(), st.catalog.entries())
    }

    /// Restore a checkpoint into a freshly built manager by replaying the
    /// journaled completions in order, then re-seeding the catalog.
    /// Returns how many instances were replayed.
    pub fn restore_from(
        &self,
        journal: Vec<CompletionRecord>,
        catalog: Vec<(WorkerId, ChunkId, Tier)>,
    ) -> Result<usize> {
        let mut replayed = 0;
        for rec in journal {
            let id = {
                // lint: critical-section — look up the seeded instance id
                let mut st = sync::lock_clean(&self.state);
                let id = st
                    .inflight
                    .iter()
                    .find(|(_, a)| a.stage_idx == rec.stage_idx && a.chunk == rec.chunk)
                    .map(|(&id, _)| id);
                if let Some(id) = id {
                    // the replayed instance was seeded into the assignment
                    // queue too — drop it there, or the resumed manager
                    // would hand already-completed work out again
                    st.pending.retain(|a| a.instance_id != id);
                }
                id
            };
            let Some(id) = id else {
                return Err(Error::Scheduler(format!(
                    "checkpoint replay: no live instance for stage {} chunk {}",
                    rec.stage_idx, rec.chunk
                )));
            };
            self.complete(id, rec.outputs);
            replayed += 1;
        }
        // lint: critical-section — re-seed catalog holders from the checkpoint
        let mut st = sync::lock_clean(&self.state);
        for (w, c, tier) in catalog {
            st.catalog.insert(w, c);
            if tier == Tier::Disk {
                st.catalog.demote(w, c);
            }
        }
        Ok(replayed)
    }

    /// Outputs of a Reduce stage (after completion), looked up by stage
    /// *name* — e.g. `reduce_outputs("classification")`.  None if no such
    /// stage exists, it hasn't completed, or it isn't a Reduce stage.
    pub fn reduce_outputs(&self, stage: &str) -> Option<Vec<Value>> {
        let idx = self.workflow.stage_index(stage)?;
        let st = sync::lock_clean(&self.state);
        st.outputs.get(&(idx, REDUCE_CHUNK)).cloned()
    }
}

impl WorkSource for Manager {
    /// Demand-driven, locality-aware assignment (paper §IV-C lifted to the
    /// cluster level).  Selection runs in three tiers: (1) instances whose
    /// chunk the requester already staged (memory or spill tier), (2)
    /// instances of cold chunks — honouring the initial partition when one
    /// was configured — or without chunk inputs, (3) *steal* instances
    /// whose chunk another worker staged (chunks memory-resident nowhere
    /// steal first; with replication on, the stolen chunk stays
    /// multi-homed and a replicate hint rides back) — the bag of tasks
    /// never stalls waiting for locality.
    fn request_work(&self, req: &WorkRequest) -> WorkBatch {
        // lint: critical-section — tiered locality selection under the catalog lock
        let mut st = sync::lock_clean(&self.state);
        if req.worker != ANON_WORKER {
            st.catalog.update(req.worker, &req.staged_add, &req.staged_drop, &req.demoted);
            // a work request is as good as a heartbeat
            if let Some(m) = st.members.get_mut(&req.worker) {
                m.last_seen = Instant::now();
            }
        }
        loop {
            if !st.pending.is_empty() {
                return self.select_work(&mut st, req);
            }
            if st.remaining_instances == 0 || st.error.is_some() {
                return WorkBatch::default();
            }
            st = match self.cv.wait(st) {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
        }
    }

    fn complete(&self, instance_id: u64, outs: Vec<Value>) {
        Manager::complete_instance(self, instance_id, outs)
    }

    fn register(&self, worker: WorkerId, lease_ms: u64) {
        self.register_worker(worker, lease_ms);
    }

    fn heartbeat(&self, worker: WorkerId) {
        self.heartbeat_worker(worker);
    }

    fn goodbye(&self, worker: WorkerId) {
        self.expire_worker(worker);
    }

    fn trace_events(&self, worker: WorkerId, events: Vec<TraceEvent>) {
        self.ingest_trace(worker, events);
    }
}

impl Manager {
    /// The tiered locality selection, shared by the blocking
    /// [`WorkSource::request_work`] and the service's non-blocking
    /// [`Manager::try_request_work`].  `st.pending` must be non-empty.
    fn select_work(&self, st: &mut MgrState, req: &WorkRequest) -> WorkBatch {
        {
                let n = req.capacity.min(st.pending.len()).max(1);
                let use_locality = self.locality && req.worker != ANON_WORKER;
                let mut picked: Vec<Assignment> = Vec::with_capacity(n);
                let mut replicate: Vec<ChunkId> = Vec::new();
                if use_locality {
                    // tier 1: chunks already staged on the requester
                    let mut i = 0;
                    while picked.len() < n && i < st.pending.len() {
                        let hit = {
                            let a = &st.pending[i];
                            a.needs_chunk && st.catalog.is_staged(req.worker, a.chunk)
                        };
                        if hit {
                            let Some(mut a) = st.pending.remove(i) else { break };
                            a.locality = true;
                            st.locality_hits += 1;
                            picked.push(a);
                        } else {
                            i += 1;
                        }
                    }
                    // tier 2: cold chunks or chunk-less instances, in
                    // order; with an initial partition, a cold chunk homed
                    // on another worker is left for its owner here
                    let mut i = 0;
                    while picked.len() < n && i < st.pending.len() {
                        let cold = {
                            let a = &st.pending[i];
                            // a chunk homed on a purged worker is unhomed:
                            // any requester may take it in tier 2 instead
                            // of waiting for an owner that will never come
                            !a.needs_chunk
                                || (st.catalog.holder_count(a.chunk) == 0
                                    && self
                                        .home
                                        .get(&a.chunk)
                                        .map(|&w| w == req.worker || st.purged.contains(&w))
                                        .unwrap_or(true))
                        };
                        if cold {
                            let Some(a) = st.pending.remove(i) else { break };
                            if a.needs_chunk {
                                st.locality_cold += 1;
                            }
                            picked.push(a);
                        } else {
                            i += 1;
                        }
                    }
                    // tier 3: last resort — steal chunks staged elsewhere
                    // and take foreign-home cold chunks, so the bag never
                    // stalls.  First pass prefers chunks memory-resident
                    // nowhere (spilled-only holders forfeit no memory
                    // locality when robbed); second pass takes anything.
                    for pass in 0..2 {
                        let mut i = 0;
                        while picked.len() < n && i < st.pending.len() {
                            let take = pass == 1 || {
                                let a = &st.pending[i];
                                st.catalog.mem_holder_count(a.chunk) == 0
                            };
                            if !take {
                                i += 1;
                                continue;
                            }
                            let Some(mut a) = st.pending.remove(i) else { break };
                            if a.needs_chunk {
                                if st.catalog.holder_count(a.chunk) == 0 {
                                    // foreign-home cold chunk: not a steal
                                    st.locality_cold += 1;
                                } else {
                                    st.locality_steals += 1;
                                    if self.replication {
                                        // the chunk becomes multi-homed;
                                        // hint the thief to stage it warm
                                        a.replica = true;
                                        st.replicated += 1;
                                        if !replicate.contains(&a.chunk) {
                                            replicate.push(a.chunk);
                                        }
                                    } else {
                                        // single-owner transfer: the old
                                        // holders lose the catalog entry
                                        st.catalog
                                            .remove_other_holders(a.chunk, req.worker);
                                    }
                                }
                            }
                            picked.push(a);
                        }
                    }
                } else {
                    for _ in 0..n {
                        match st.pending.pop_front() {
                            Some(a) => picked.push(a),
                            None => break,
                        }
                    }
                }
                // the requester must stage these chunks to execute: record
                // them optimistically so follow-up stages route back here
                if req.worker != ANON_WORKER {
                    for a in &picked {
                        if a.needs_chunk {
                            st.catalog.insert(req.worker, a.chunk);
                        }
                        st.lessee.insert(a.instance_id, req.worker);
                    }
                }
                // prefetch hints: upcoming chunks not yet staged here —
                // chunks homed on the requester first, then the rest (the
                // homed pass only exists under an initial partition; a
                // home on a purged worker is no home at all, so those
                // chunks compete in the open pass instead of dangling)
                let mut prefetch: Vec<ChunkId> = Vec::new();
                if req.prefetch_budget > 0 {
                    let first_pass = if self.home.is_empty() { 1 } else { 0 };
                    for pass in first_pass..2 {
                        for a in st.pending.iter() {
                            if prefetch.len() >= req.prefetch_budget {
                                break;
                            }
                            let homed_here = match self.home.get(&a.chunk) {
                                Some(&w) => w == req.worker,
                                None => false,
                            };
                            if pass == 0 && !homed_here {
                                continue;
                            }
                            if a.needs_chunk
                                && !st.catalog.is_staged(req.worker, a.chunk)
                                && !prefetch.contains(&a.chunk)
                            {
                                prefetch.push(a.chunk);
                            }
                        }
                    }
                }
                WorkBatch { assignments: picked, prefetch, replicate, idle: false }
        }
    }

    /// Apply a worker's staging deltas and liveness signal without
    /// requesting work.  Service mode fans one wire request out to many
    /// per-job managers: the (consumed-once) staging deltas must reach
    /// every running job's catalog even though the fair-share scheduler
    /// only asks some of them for assignments.
    pub fn observe_worker(&self, req: &WorkRequest) {
        if req.worker == ANON_WORKER {
            return;
        }
        // lint: critical-section — fold staging deltas into the catalog
        let mut st = sync::lock_clean(&self.state);
        st.catalog.update(req.worker, &req.staged_add, &req.staged_drop, &req.demoted);
        if let Some(m) = st.members.get_mut(&req.worker) {
            m.last_seen = Instant::now();
        }
    }

    /// Non-blocking request: returns an empty batch immediately when no
    /// instance is assignable right now (the deltas in `req` are still
    /// applied).  The service's deficit round-robin multiplexes many
    /// managers per wire request and cannot block on any one of them.
    pub fn try_request_work(&self, req: &WorkRequest) -> WorkBatch {
        // lint: critical-section — tiered locality selection under the catalog lock
        let mut st = sync::lock_clean(&self.state);
        if req.worker != ANON_WORKER {
            st.catalog.update(req.worker, &req.staged_add, &req.staged_drop, &req.demoted);
            if let Some(m) = st.members.get_mut(&req.worker) {
                m.last_seen = Instant::now();
            }
        }
        if st.pending.is_empty() {
            return WorkBatch::default();
        }
        self.select_work(&mut st, req)
    }

    /// Nothing left to hand out: the workflow fully completed or failed.
    pub fn is_done(&self) -> bool {
        let st = sync::lock_clean(&self.state);
        st.remaining_instances == 0 || st.error.is_some()
    }

    /// Whether any instance is ready for assignment right now.
    pub fn has_backlog(&self) -> bool {
        !sync::lock_clean(&self.state).pending.is_empty()
    }

    /// The workflow this manager instantiates (service-mode reporting).
    pub fn workflow(&self) -> Arc<Workflow> {
        self.workflow.clone()
    }

    /// Fold a finished stage instance back into the dependency state —
    /// the body of [`WorkSource::complete`], inherent so the service can
    /// call it on a per-job manager without the trait in scope.
    pub fn complete_instance(&self, instance_id: u64, outs: Vec<Value>) {
        // lint: critical-section — fold a completion into the dependency state
        let mut st = sync::lock_clean(&self.state);
        let Some(assignment) = st.inflight.remove(&instance_id) else {
            // duplicate completion from a worker presumed dead whose lease
            // was re-issued and already completed — ignore, count it
            st.stale_completions += 1;
            self.cv.notify_all();
            return;
        };
        // a completion renews the finishing worker's lease
        if let Some(w) = st.lessee.remove(&instance_id) {
            if let Some(m) = st.members.get_mut(&w) {
                m.last_seen = Instant::now();
            }
        }
        let (stage, chunk) = (assignment.stage_idx, assignment.chunk);
        st.completed_instances += 1;
        st.remaining_instances = st.remaining_instances.saturating_sub(1);
        if self.journal_enabled.load(Ordering::Acquire) {
            st.journal.push(CompletionRecord { stage_idx: stage, chunk, outputs: outs.clone() });
        }
        // retain outputs consumed downstream; Reduce outputs are final
        // results the caller reads back via `reduce_outputs`.
        if self.has_dependents[stage] || self.workflow.stages[stage].kind == StageKind::Reduce {
            st.outputs.insert((stage, chunk), outs.clone());
        }
        // unblock dependents
        let mut to_create: Vec<(usize, ChunkId)> = Vec::new();
        for (di, dstage) in self.workflow.stages.iter().enumerate() {
            let depends = self
                .workflow
                .upstream_of(di)
                .contains(&stage);
            if !depends {
                continue;
            }
            match dstage.kind {
                StageKind::PerChunk => {
                    if let Some(rem) = st.waiting.get_mut(&(di, chunk)) {
                        *rem -= 1;
                        if *rem == 0 {
                            st.waiting.remove(&(di, chunk));
                            to_create.push((di, chunk));
                        }
                    }
                }
                StageKind::Reduce => {
                    // append only the outputs this Reduce stage's inputs
                    // reference (in input-spec order)
                    let mut picked = Vec::new();
                    for input in &dstage.inputs {
                        if let StageInput::Upstream { stage: s, output } = input {
                            if *s == stage {
                                if let Some(v) = outs.get(*output) {
                                    picked.push(v.clone());
                                }
                            }
                        }
                    }
                    if let Some(acc) = st.reduce_acc.get_mut(&di) {
                        acc.entry(chunk).or_default().extend(picked);
                    }
                    let Some(rem) = st.reduce_remaining.get_mut(&di) else { continue };
                    *rem -= 1;
                    if *rem == 0 {
                        to_create.push((di, REDUCE_CHUNK));
                    }
                }
            }
        }
        for (di, c) in to_create {
            let inputs = if c == REDUCE_CHUNK {
                // concatenate per-chunk outputs in chunk order
                let acc = st.reduce_acc.remove(&di).unwrap_or_default();
                acc.into_values().flatten().collect()
            } else {
                match self.assemble_dependent_inputs(&st, di, c) {
                    Ok(v) => v,
                    Err(e) => {
                        st.error = Some(e.to_string());
                        self.cv.notify_all();
                        return;
                    }
                }
            };
            let id = st.next_id;
            st.next_id += 1;
            let a = Assignment {
                instance_id: id,
                stage_idx: di,
                chunk: c,
                inputs,
                needs_chunk: c != REDUCE_CHUNK && self.stage_needs_chunk[di],
                locality: false,
                replica: false,
            };
            st.inflight.insert(id, a.clone());
            st.pending.push_back(a);
        }
        // garbage-collect upstream outputs once every dependent of this
        // chunk has been created (simple heuristic: when nothing waits on
        // this (stage, chunk) pair any more and it's not a reduce input).
        drop(st);
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::{param, OpRegistry, OpSpec, StageHandle, WorkflowBuilder};

    /// Scalar test ops: "add" sums its wired inputs (value + param),
    /// "sum" is the Reduce consume-all aggregator, "fan2" produces (v, 10v).
    fn test_registry() -> OpRegistry {
        let mut r = OpRegistry::new();
        r.register_cpu("add", 1, |args: &[Value]| {
            let mut s = 0.0;
            for v in args {
                s += v.as_scalar()?;
            }
            Ok(vec![Value::Scalar(s)])
        })
        .unwrap();
        r.register_cpu("sum", 1, |args: &[Value]| {
            let mut s = 0.0;
            for v in args {
                s += v.as_scalar()?;
            }
            Ok(vec![Value::Scalar(s)])
        })
        .unwrap();
        r.register(OpSpec::cpu("fan2", 2, |args: &[Value]| {
            let v = args[0].as_scalar()?;
            Ok(vec![Value::Scalar(v), Value::Scalar(v * 10.0)])
        }))
        .unwrap();
        r
    }

    /// A linear chain of PerChunk stages s0 -> s1 -> ..., stage i adding
    /// `adds[i]` to its input.
    fn chain_workflow(adds: &[f32]) -> Arc<Workflow> {
        let mut wb = WorkflowBuilder::new("t", test_registry());
        let mut prev: Option<StageHandle> = None;
        for (i, &add) in adds.iter().enumerate() {
            let mut s = wb.stage(&format!("s{i}"), StageKind::PerChunk);
            let inp = match &prev {
                None => s.input_chunk(),
                Some(h) => s.input_upstream(h.output(0)),
            };
            let op = s.add_op("add", &[inp, param(add)]).unwrap();
            s.export(op.out()).unwrap();
            prev = Some(wb.add_stage(s).unwrap());
        }
        Arc::new(wb.build().unwrap())
    }

    fn loader() -> ChunkLoader {
        Arc::new(|c| Ok(vec![Value::Scalar(c as f32)]))
    }

    fn drive_serial(mgr: &Arc<Manager>) -> usize {
        // single synthetic worker that executes instances serially
        let mut executed = 0;
        loop {
            let batch = mgr.request(4);
            if batch.is_empty() {
                return executed;
            }
            for a in batch {
                let stage = &mgr.workflow.stages[a.stage_idx];
                let outs = crate::dataflow::run_stage_serial(stage, &a.inputs).unwrap();
                executed += 1;
                mgr.complete(a.instance_id, outs);
            }
        }
    }

    #[test]
    fn single_stage_bag_of_tasks() {
        let mgr = Manager::new(chain_workflow(&[1.0]), loader(), 5).unwrap();
        assert_eq!(drive_serial(&mgr), 5);
        let (done, total) = mgr.progress();
        assert_eq!((done, total), (5, 5));
    }

    #[test]
    fn two_stage_chain_routes_outputs() {
        let mgr = Manager::new(chain_workflow(&[10.0, 100.0]), loader(), 3).unwrap();
        assert_eq!(drive_serial(&mgr), 6);
    }

    #[test]
    fn reduce_stage_sees_all_chunks() {
        let mut wb = WorkflowBuilder::new("t", test_registry());
        let mut a = wb.stage("a", StageKind::PerChunk);
        let c = a.input_chunk();
        let op = a.add_op("add", &[c, param(0.0)]).unwrap();
        a.export(op.out()).unwrap();
        let a = wb.add_stage(a).unwrap();
        // reduce stage: sums everything it receives (all-inputs convention)
        let mut red = wb.stage("sum", StageKind::Reduce);
        red.input_upstream(a.output(0));
        let s = red.add_reduce_op("sum").unwrap();
        red.export(s.out()).unwrap();
        wb.add_stage(red).unwrap();
        let mgr = Manager::new(Arc::new(wb.build().unwrap()), loader(), 4).unwrap();
        assert_eq!(drive_serial(&mgr), 5);
        let out = mgr.reduce_outputs("sum").unwrap();
        // chunks 0..4 pass through stage a unchanged, reduce sums: 0+1+2+3
        assert_eq!(out[0].as_scalar().unwrap(), 6.0);
        // unknown stage names resolve to None, not a panic
        assert!(mgr.reduce_outputs("nope").is_none());
    }

    #[test]
    fn assignments_created_in_chunk_order() {
        let mgr = Manager::new(chain_workflow(&[0.0]), loader(), 4).unwrap();
        let batch = mgr.request(10);
        let chunks: Vec<ChunkId> = batch.iter().map(|a| a.chunk).collect();
        assert_eq!(chunks, vec![0, 1, 2, 3]);
        for a in batch {
            mgr.complete(a.instance_id, vec![Value::Scalar(0.0)]);
        }
    }

    #[test]
    fn window_capacity_respected() {
        let mgr = Manager::new(chain_workflow(&[0.0]), loader(), 10).unwrap();
        let batch = mgr.request(3);
        assert_eq!(batch.len(), 3);
        for a in batch {
            mgr.complete(a.instance_id, vec![]);
        }
    }

    #[test]
    fn unknown_completion_is_counted_not_fatal() {
        let mgr = Manager::new(chain_workflow(&[0.0]), loader(), 1).unwrap();
        mgr.complete(999, vec![]);
        assert!(mgr.error().is_none());
        assert_eq!(mgr.stale_completions(), 1);
        drive_serial(&mgr);
    }

    #[test]
    fn requeue_reissues_unfinished_leases() {
        let mgr = Manager::new(chain_workflow(&[1.0]), loader(), 3).unwrap();
        // "worker 1" takes two leases and dies
        let batch = mgr.request(2);
        let ids: Vec<u64> = batch.iter().map(|a| a.instance_id).collect();
        assert_eq!(mgr.requeue_stale(&ids), 2);
        // a healthy worker now drains everything exactly once
        assert_eq!(drive_serial(&mgr), 3);
        // the dead worker's late completion is ignored
        mgr.complete(ids[0], vec![Value::Scalar(0.0)]);
        assert!(mgr.error().is_none());
        assert_eq!(mgr.stale_completions(), 1);
    }

    #[test]
    fn reduce_picks_only_referenced_outputs() {
        // upstream produces 2 outputs; the reduce stage references only
        // output 1 — the aggregate must contain exactly those values.
        let mut wb = WorkflowBuilder::new("t", test_registry());
        let mut up = wb.stage("a", StageKind::PerChunk);
        let c = up.input_chunk();
        let f = up.add_op("fan2", &[c]).unwrap();
        up.export(f.output(0)).unwrap();
        up.export(f.output(1)).unwrap();
        let a = wb.add_stage(up).unwrap();
        let mut red = wb.stage("sum", StageKind::Reduce);
        red.input_upstream(a.output(1));
        let s = red.add_reduce_op("sum").unwrap();
        red.export(s.out()).unwrap();
        wb.add_stage(red).unwrap();
        let mgr = Manager::new(Arc::new(wb.build().unwrap()), loader(), 3).unwrap();
        drive_serial(&mgr);
        let out = mgr.reduce_outputs("sum").unwrap();
        // sum of v*10 over chunks 0..3 = (0+1+2)*10 = 30
        assert_eq!(out[0].as_scalar().unwrap(), 30.0);
    }

    #[test]
    fn chained_reduce_aggregates() {
        // chunks -> a (PerChunk, +0) -> r1 (Reduce sum) -> r2 (Reduce sum):
        // r2 must see exactly r1's single output and complete once.
        let mut wb = WorkflowBuilder::new("t", test_registry());
        let mut a = wb.stage("a", StageKind::PerChunk);
        let c = a.input_chunk();
        let op = a.add_op("add", &[c, param(0.0)]).unwrap();
        a.export(op.out()).unwrap();
        let a = wb.add_stage(a).unwrap();
        let mut r1 = wb.stage("r1", StageKind::Reduce);
        r1.input_upstream(a.output(0));
        let s = r1.add_reduce_op("sum").unwrap();
        r1.export(s.out()).unwrap();
        let r1 = wb.add_stage(r1).unwrap();
        let mut r2 = wb.stage("r2", StageKind::Reduce);
        r2.input_upstream(r1.output(0));
        let s = r2.add_reduce_op("sum").unwrap();
        r2.export(s.out()).unwrap();
        wb.add_stage(r2).unwrap();
        let mgr = Manager::new(Arc::new(wb.build().unwrap()), loader(), 4).unwrap();
        // 4 chunk instances + r1 + r2
        assert_eq!(drive_serial(&mgr), 6);
        let out = mgr.reduce_outputs("r2").unwrap();
        assert_eq!(out[0].as_scalar().unwrap(), 6.0); // 0+1+2+3
        assert_eq!(mgr.reduce_outputs("r1").unwrap()[0].as_scalar().unwrap(), 6.0);
    }

    /// A staged two-stage workflow where both stages read the chunk
    /// (stage 1 additionally consumes stage 0's output) — the shape that
    /// makes repeat-stage locality meaningful.
    fn staged_with_policy(n_chunks: usize, policy: AssignPolicy) -> Arc<Manager> {
        let mut wb = WorkflowBuilder::new("t", test_registry());
        let mut s0 = wb.stage("s0", StageKind::PerChunk);
        let c = s0.input_chunk();
        let op = s0.add_op("add", &[c, param(1.0)]).unwrap();
        s0.export(op.out()).unwrap();
        let s0 = wb.add_stage(s0).unwrap();
        let mut s1 = wb.stage("s1", StageKind::PerChunk);
        let c = s1.input_chunk();
        let up = s1.input_upstream(s0.output(0));
        let op = s1.add_op("add", &[c, up]).unwrap();
        s1.export(op.out()).unwrap();
        wb.add_stage(s1).unwrap();
        Manager::new_staged(Arc::new(wb.build().unwrap()), n_chunks, policy).unwrap()
    }

    fn staged_two_stage(n_chunks: usize, locality: bool) -> Arc<Manager> {
        staged_with_policy(n_chunks, AssignPolicy::demand(locality))
    }

    #[test]
    fn staged_mode_defers_chunk_payloads() {
        let mgr = staged_two_stage(2, true);
        let batch = mgr.request_work(&WorkRequest { capacity: 4, worker: 1, ..Default::default() });
        assert_eq!(batch.assignments.len(), 2);
        for a in &batch.assignments {
            assert!(a.needs_chunk);
            assert!(a.inputs.is_empty(), "stage-0 inputs must not ship the payload");
        }
        // complete stage 0; stage 1 assignments carry ONLY the upstream value
        for a in batch.assignments {
            mgr.complete(a.instance_id, vec![Value::Scalar(a.chunk as f32 + 1.0)]);
        }
        let batch = mgr.request_work(&WorkRequest { capacity: 4, worker: 1, ..Default::default() });
        assert_eq!(batch.assignments.len(), 2);
        for a in &batch.assignments {
            assert!(a.needs_chunk);
            assert_eq!(a.inputs.len(), 1, "only the upstream value ships");
            assert_eq!(a.inputs[0].as_scalar().unwrap(), a.chunk as f32 + 1.0);
            // worker 1 staged both chunks in stage 0 -> locality hits
            assert!(a.locality);
        }
        let (hits, cold, steals) = mgr.locality_stats();
        assert_eq!((hits, cold, steals), (2, 2, 0));
    }

    #[test]
    fn locality_routes_repeat_stages_and_steals_as_last_resort() {
        let mgr = staged_two_stage(4, true);
        let w = |worker, capacity| WorkRequest { capacity, worker, ..Default::default() };
        // worker 1 takes chunks 0,1; worker 2 takes chunks 2,3 (stage 0)
        let b1 = mgr.request_work(&w(1, 2));
        let b2 = mgr.request_work(&w(2, 2));
        assert_eq!(b1.assignments.iter().map(|a| a.chunk).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(b2.assignments.iter().map(|a| a.chunk).collect::<Vec<_>>(), vec![2, 3]);
        // everything completes -> stage-1 instances for chunks 0..4 pend
        for a in b1.assignments.into_iter().chain(b2.assignments) {
            mgr.complete(a.instance_id, vec![Value::Scalar(0.0)]);
        }
        // worker 2 asks for everything: its own chunks 2,3 first (hits),
        // then steals 0,1 (staged on worker 1) so the bag never stalls
        let b = mgr.request_work(&w(2, 4));
        let chunks: Vec<ChunkId> = b.assignments.iter().map(|a| a.chunk).collect();
        assert_eq!(chunks, vec![2, 3, 0, 1]);
        assert!(b.assignments[0].locality && b.assignments[1].locality);
        assert!(!b.assignments[2].locality && !b.assignments[3].locality);
        let (hits, cold, steals) = mgr.locality_stats();
        assert_eq!((hits, cold, steals), (2, 4, 2));
        for a in b.assignments {
            mgr.complete(a.instance_id, vec![Value::Scalar(0.0)]);
        }
        let (done, total) = mgr.progress();
        assert_eq!((done, total), (8, 8));
    }

    #[test]
    fn purged_worker_chunks_go_back_to_cold() {
        let mgr = staged_two_stage(2, true);
        let b1 = mgr.request_work(&WorkRequest { capacity: 2, worker: 1, ..Default::default() });
        for a in b1.assignments {
            mgr.complete(a.instance_id, vec![Value::Scalar(0.0)]);
        }
        // worker 1 dies: without the purge its chunks would stay "held"
        // by a ghost and every repeat stage would count as a steal
        assert_eq!(mgr.purge_worker(1), 2);
        let b = mgr.request_work(&WorkRequest { capacity: 2, worker: 2, ..Default::default() });
        assert_eq!(b.assignments.len(), 2);
        let (hits, cold, steals) = mgr.locality_stats();
        assert_eq!((hits, cold, steals), (0, 4, 0), "repeat stages must be cold, not stolen");
    }

    #[test]
    fn locality_off_preserves_fifo_order() {
        let mgr = staged_two_stage(2, false);
        let b1 = mgr.request_work(&WorkRequest { capacity: 1, worker: 1, ..Default::default() });
        mgr.complete(b1.assignments[0].instance_id, vec![Value::Scalar(0.0)]);
        // pending now: (s0, chunk 1) then (s1, chunk 0); locality off ->
        // FIFO, even though chunk 0 is staged on worker 1
        let b = mgr.request_work(&WorkRequest { capacity: 2, worker: 1, ..Default::default() });
        let got: Vec<(usize, ChunkId)> =
            b.assignments.iter().map(|a| (a.stage_idx, a.chunk)).collect();
        assert_eq!(got, vec![(0, 1), (1, 0)]);
        assert!(b.assignments.iter().all(|a| !a.locality));
        assert_eq!(mgr.locality_stats(), (0, 0, 0));
    }

    #[test]
    fn prefetch_hints_cover_upcoming_unstaged_chunks() {
        let mgr = staged_two_stage(6, true);
        let b = mgr.request_work(&WorkRequest {
            capacity: 2,
            worker: 1,
            prefetch_budget: 3,
            ..Default::default()
        });
        assert_eq!(b.assignments.len(), 2);
        // hints skip the two chunks just handed to (and staged on) worker 1
        assert_eq!(b.prefetch, vec![2, 3, 4]);
        // a worker that reports chunks staged gets no hints for them
        let b2 = mgr.request_work(&WorkRequest {
            capacity: 1,
            worker: 2,
            staged_add: vec![2, 3],
            prefetch_budget: 8,
            ..Default::default()
        });
        // worker 2 is handed its staged chunk first (tier 1 hit)
        assert_eq!(b2.assignments[0].chunk, 2);
        assert!(!b2.prefetch.contains(&3));
    }

    #[test]
    fn steal_with_replication_leaves_the_chunk_multi_homed() {
        let mgr = staged_two_stage(2, true);
        let w = |worker, capacity| WorkRequest { capacity, worker, ..Default::default() };
        // worker 1 runs stage 0 for both chunks
        let b1 = mgr.request_work(&w(1, 2));
        for a in b1.assignments {
            mgr.complete(a.instance_id, vec![Value::Scalar(0.0)]);
        }
        // worker 2 steals both stage-1 instances
        let b2 = mgr.request_work(&w(2, 2));
        assert_eq!(b2.assignments.len(), 2);
        assert!(b2.assignments.iter().all(|a| a.replica), "steals must be marked replicas");
        let mut hinted = b2.replicate.clone();
        hinted.sort_unstable();
        assert_eq!(hinted, vec![0, 1], "replicate hints must name the stolen chunks");
        assert_eq!(mgr.replicated(), 2);
        // both workers now hold both chunks: multi-homed
        assert_eq!(mgr.chunk_holders(0), 2);
        assert_eq!(mgr.chunk_holders(1), 2);
    }

    #[test]
    fn steal_without_replication_transfers_ownership() {
        let mgr = staged_with_policy(
            2,
            AssignPolicy { replication: false, ..Default::default() },
        );
        let w = |worker, capacity| WorkRequest { capacity, worker, ..Default::default() };
        let b1 = mgr.request_work(&w(1, 2));
        for a in b1.assignments {
            mgr.complete(a.instance_id, vec![Value::Scalar(0.0)]);
        }
        let b2 = mgr.request_work(&w(2, 2));
        assert_eq!(b2.assignments.len(), 2);
        assert!(b2.assignments.iter().all(|a| !a.replica));
        assert!(b2.replicate.is_empty(), "no hints without replication");
        assert_eq!(mgr.replicated(), 0);
        // single-owner transfer: only the thief holds the chunks now
        assert_eq!(mgr.chunk_holders(0), 1);
        assert_eq!(mgr.chunk_holders(1), 1);
        let (_, _, steals) = mgr.locality_stats();
        assert_eq!(steals, 2, "the transfer still counts as a steal");
    }

    #[test]
    fn disk_tier_holders_are_stolen_before_memory_holders() {
        let mgr = staged_two_stage(2, true);
        let b1 = mgr.request_work(&WorkRequest { capacity: 2, worker: 1, ..Default::default() });
        for a in b1.assignments {
            mgr.complete(a.instance_id, vec![Value::Scalar(0.0)]);
        }
        // worker 1 demoted chunk 1 to its spill tier
        let _ = mgr.request_work(&WorkRequest {
            capacity: 1,
            worker: 1,
            demoted: vec![1],
            ..Default::default()
        });
        // worker 1 got one of the stage-1 instances (a tier-1 hit); worker
        // 2 steals the other — the disk-tier chunk would have been robbed
        // first had both been pending
        let b2 = mgr.request_work(&WorkRequest { capacity: 2, worker: 2, ..Default::default() });
        assert_eq!(b2.assignments.len(), 1);
        let (hits, _, steals) = mgr.locality_stats();
        assert_eq!(hits, 1);
        assert_eq!(steals, 1);
    }

    #[test]
    fn init_partition_range_assigns_cold_chunks() {
        let mgr = staged_with_policy(
            4,
            AssignPolicy { partition: Partition::Init(vec![1, 2]), ..Default::default() },
        );
        let w = |worker, capacity| WorkRequest { capacity, worker, ..Default::default() };
        // worker 2 asks first: it gets ITS contiguous range (2, 3), not
        // the queue front
        let b2 = mgr.request_work(&w(2, 2));
        assert_eq!(b2.assignments.iter().map(|a| a.chunk).collect::<Vec<_>>(), vec![2, 3]);
        let b1 = mgr.request_work(&w(1, 2));
        assert_eq!(b1.assignments.iter().map(|a| a.chunk).collect::<Vec<_>>(), vec![0, 1]);
        let (hits, cold, steals) = mgr.locality_stats();
        assert_eq!((hits, cold, steals), (0, 4, 0));
        // drain to completion so nothing leaks
        for a in b1.assignments.into_iter().chain(b2.assignments) {
            mgr.complete(a.instance_id, vec![Value::Scalar(0.0)]);
        }
    }

    #[test]
    fn foreign_home_cold_chunks_are_taken_as_last_resort_not_steals() {
        // only worker 2's range is left and worker 1 asks for everything:
        // the bag must not stall, and the takes count cold, not stolen
        let mgr = staged_with_policy(
            2,
            AssignPolicy { partition: Partition::Init(vec![1, 2]), ..Default::default() },
        );
        let b = mgr.request_work(&WorkRequest { capacity: 2, worker: 1, ..Default::default() });
        assert_eq!(b.assignments.len(), 2, "bag of tasks must never stall");
        // chunk 0 is worker 1's home (tier 2), chunk 1 was worker 2's
        assert_eq!(b.assignments.iter().map(|a| a.chunk).collect::<Vec<_>>(), vec![0, 1]);
        let (hits, cold, steals) = mgr.locality_stats();
        assert_eq!((hits, cold, steals), (0, 2, 0));
        assert!(b.replicate.is_empty(), "cold takes are not steals");
    }

    #[test]
    fn init_partition_prefers_homed_prefetch_hints() {
        let mgr = staged_with_policy(
            6,
            AssignPolicy { partition: Partition::Init(vec![1, 2]), ..Default::default() },
        );
        // worker 2 takes one instance; its hints should lead with its own
        // range (4, 5) before worker 1's untouched chunks
        let b = mgr.request_work(&WorkRequest {
            capacity: 1,
            worker: 2,
            prefetch_budget: 3,
            ..Default::default()
        });
        assert_eq!(b.assignments[0].chunk, 3);
        assert_eq!(b.prefetch, vec![4, 5, 0]);
    }

    #[test]
    fn concurrent_workers_drain_everything() {
        let mgr = Manager::new(chain_workflow(&[1.0, 2.0]), loader(), 20).unwrap();
        let mut handles = Vec::new();
        for _ in 0..4 {
            let m = mgr.clone();
            handles.push(std::thread::spawn(move || drive_serial(&m)));
        }
        let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, 40);
        assert!(mgr.error().is_none());
    }

    #[test]
    fn expired_lease_requeues_held_work_and_purges_the_catalog() {
        let mgr = staged_two_stage(3, true);
        mgr.register_worker(1, 1); // 1 ms lease: expires immediately
        mgr.register_worker(2, 60_000);
        assert_eq!(mgr.member_count(), 2);
        // worker 1 takes two leases, stages the chunks, then goes silent
        let b = mgr.request_work(&WorkRequest { capacity: 2, worker: 1, ..Default::default() });
        assert_eq!(b.assignments.len(), 2);
        std::thread::sleep(Duration::from_millis(10));
        let expired = mgr.sweep_leases();
        assert_eq!(expired, vec![(1, 2)], "worker 1's two leases re-issued");
        assert_eq!(mgr.member_count(), 1);
        assert_eq!(mgr.chunk_holders(0), 0, "purged holder is gone from the catalog");
        // a healthy worker drains everything exactly once
        let mut executed = 0;
        loop {
            let b = mgr.request_work(&WorkRequest { capacity: 4, worker: 2, ..Default::default() });
            if b.assignments.is_empty() {
                break;
            }
            for a in b.assignments {
                executed += 1;
                mgr.complete(a.instance_id, vec![Value::Scalar(0.0)]);
            }
        }
        assert_eq!(executed, 6);
        assert!(mgr.error().is_none());
    }

    #[test]
    fn heartbeats_keep_a_short_lease_alive() {
        let mgr = staged_two_stage(1, true);
        mgr.register_worker(1, 60_000);
        mgr.heartbeat_worker(1);
        assert!(mgr.sweep_leases().is_empty());
        // clean goodbye deregisters without requeue noise (no leases held)
        assert_eq!(mgr.expire_worker(1), 0);
        assert_eq!(mgr.member_count(), 0);
    }

    #[test]
    fn rejoining_worker_is_unpurged() {
        let mgr = staged_with_policy(
            4,
            AssignPolicy { partition: Partition::Init(vec![1, 2]), ..Default::default() },
        );
        mgr.purge_worker(2);
        // worker 2's home range is unhomed while purged: worker 1 may take
        // chunk 2 in tier 2 (front of its post-range queue), not last-resort
        let b = mgr.request_work(&WorkRequest { capacity: 3, worker: 1, ..Default::default() });
        assert_eq!(b.assignments.iter().map(|a| a.chunk).collect::<Vec<_>>(), vec![0, 1, 2]);
        let (_, cold, steals) = mgr.locality_stats();
        assert_eq!((cold, steals), (3, 0));
        // worker 2 comes back: its home claim holds again for chunk 3
        mgr.register_worker(2, 60_000);
        let b2 = mgr.request_work(&WorkRequest { capacity: 1, worker: 2, ..Default::default() });
        assert_eq!(b2.assignments[0].chunk, 3);
        for a in b.assignments.into_iter().chain(b2.assignments) {
            mgr.complete(a.instance_id, vec![Value::Scalar(0.0)]);
        }
    }

    #[test]
    fn purged_home_chunks_are_not_deferred_in_hints_or_tier2() {
        let mgr = staged_with_policy(
            6,
            AssignPolicy { partition: Partition::Init(vec![1, 2]), ..Default::default() },
        );
        mgr.purge_worker(1);
        // worker 2 asks: tier 2 starts from the queue front because worker
        // 1's home claim (chunks 0..3) died with it
        let b = mgr.request_work(&WorkRequest {
            capacity: 1,
            worker: 2,
            prefetch_budget: 3,
            ..Default::default()
        });
        assert_eq!(b.assignments[0].chunk, 0);
        // hints: the homed pass leads with worker 2's own range, then the
        // open pass covers the orphaned chunks instead of dangling
        assert_eq!(b.prefetch, vec![3, 4, 5]);
    }

    #[test]
    fn checkpoint_journal_replays_into_a_fresh_manager() {
        // run half the workflow with journaling on, snapshot, then restore
        // into a fresh manager and finish — outputs must match a clean run
        let mgr = staged_two_stage(3, true);
        mgr.enable_journal();
        let b = mgr.request_work(&WorkRequest { capacity: 3, worker: 1, ..Default::default() });
        assert_eq!(b.assignments.len(), 3);
        for a in b.assignments {
            mgr.complete(a.instance_id, vec![Value::Scalar(a.chunk as f32 + 1.0)]);
        }
        let (journal, catalog) = mgr.checkpoint_state();
        assert_eq!(journal.len(), 3);
        assert!(catalog.iter().any(|&(w, _, _)| w == 1));

        let fresh = staged_two_stage(3, true);
        fresh.enable_journal();
        assert_eq!(fresh.restore_from(journal, catalog).unwrap(), 3);
        let (done, total) = fresh.progress();
        assert_eq!((done, total), (3, 6), "stage 0 replayed, stage 1 outstanding");
        // the restored catalog still routes stage-1 work to worker 1 as hits
        let b = fresh.request_work(&WorkRequest { capacity: 3, worker: 1, ..Default::default() });
        assert_eq!(b.assignments.len(), 3);
        assert!(b.assignments.iter().all(|a| a.locality), "restored holders give hits");
        for a in b.assignments {
            // stage 1 sees the replayed upstream value
            assert_eq!(a.inputs[0].as_scalar().unwrap(), a.chunk as f32 + 1.0);
            fresh.complete(a.instance_id, vec![Value::Scalar(0.0)]);
        }
        assert_eq!(fresh.progress(), (6, 6));
    }

    #[test]
    fn restore_rejects_records_for_unknown_instances() {
        let fresh = staged_two_stage(1, true);
        let bogus = vec![CompletionRecord { stage_idx: 7, chunk: 9, outputs: vec![] }];
        assert!(fresh.restore_from(bogus, Vec::new()).is_err());
    }
}
