//! Execution metrics: per-device/per-operation counters and timers.
//!
//! The paper's evaluation reports (a) end-to-end times, (b) the *execution
//! profile* — what fraction of each operation type ran on CPU vs GPU
//! (Figs. 10 and 12) — and (c) data-transfer overheads.  [`MetricsHub`] is a
//! cheap, lock-sharded collector the coordinator threads write into; benches
//! and EXPERIMENTS.md read the aggregated [`MetricsReport`].

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::obs::{self, Tracer};

/// Which kind of device executed an operation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum DeviceKind {
    Cpu,
    Gpu,
}

impl DeviceKind {
    pub fn name(self) -> &'static str {
        match self {
            DeviceKind::Cpu => "CPU",
            DeviceKind::Gpu => "GPU",
        }
    }
}

#[derive(Debug, Default, Clone)]
struct OpRecord {
    cpu_count: u64,
    gpu_count: u64,
    cpu_time: Duration,
    gpu_time: Duration,
    upload_bytes: u64,
    download_bytes: u64,
}

/// Aggregated view handed to benches / reports.
#[derive(Debug, Clone)]
pub struct OpProfile {
    pub op: String,
    pub cpu_count: u64,
    pub gpu_count: u64,
    pub cpu_time: Duration,
    pub gpu_time: Duration,
    pub upload_bytes: u64,
    pub download_bytes: u64,
}

impl OpProfile {
    /// Fraction of instances of this op that ran on the GPU (Fig. 10 metric).
    pub fn gpu_fraction(&self) -> f64 {
        let total = self.cpu_count + self.gpu_count;
        if total == 0 {
            0.0
        } else {
            self.gpu_count as f64 / total as f64
        }
    }
}

/// Counters of the data-staging layer (worker tiered chunk store:
/// in-memory cache + prefetcher + optional local-disk spill tier).
#[derive(Debug, Default, Clone, PartialEq)]
pub struct StagingReport {
    /// chunk fetches served from (or overlapped with) the staging cache
    pub hits: u64,
    /// chunk fetches that demand-loaded from the source
    pub misses: u64,
    /// chunks staged by the background prefetcher
    pub prefetched: u64,
    /// chunks dropped from the worker entirely (no spill tier, or pushed
    /// off the bounded spill tier)
    pub evictions: u64,
    /// chunk fetches served by the local-disk spill tier, not the source
    pub spill_hits: u64,
    /// memory-tier evictions demoted to the spill tier instead of dropped
    pub spill_evicted: u64,
    /// chunks promoted disk -> memory (prefetch or demand)
    pub promoted: u64,
    /// steal-replica chunks staged eagerly off the Manager's hints
    pub replicated: u64,
    /// read latency hidden behind compute by the prefetcher
    pub hidden: Duration,
    /// time spent blocked waiting for chunk payloads
    pub stall: Duration,
}

impl StagingReport {
    /// Fraction of chunk fetches that did not demand-load.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Fold another worker's counters into this one.
    pub fn accumulate(&mut self, other: &StagingReport) {
        self.hits += other.hits;
        self.misses += other.misses;
        self.prefetched += other.prefetched;
        self.evictions += other.evictions;
        self.spill_hits += other.spill_hits;
        self.spill_evicted += other.spill_evicted;
        self.promoted += other.promoted;
        self.replicated += other.replicated;
        self.hidden += other.hidden;
        self.stall += other.stall;
    }

    /// One-line summary for run output (a second "tiers:" line appears
    /// once the spill tier or replication engaged).
    pub fn summary(&self) -> String {
        let mut out = format!(
            "staging: {} hits / {} misses ({:.0}% hit rate), {} prefetched, {} evicted, \
             {:.1} ms read latency hidden, {:.1} ms stalled",
            self.hits,
            self.misses,
            self.hit_rate() * 100.0,
            self.prefetched,
            self.evictions,
            self.hidden.as_secs_f64() * 1e3,
            self.stall.as_secs_f64() * 1e3
        );
        if self.spill_hits + self.spill_evicted + self.promoted + self.replicated > 0 {
            out.push_str(&format!(
                "\ntiers: {} demoted, {} spill hits, {} promoted, {} replica-staged",
                self.spill_evicted, self.spill_hits, self.promoted, self.replicated
            ));
        }
        out
    }
}

/// Thread-safe metrics collector.
///
/// Since the observability subsystem landed, the hub is also the home of
/// the run's typed-instrument [`obs::Registry`] and its [`Tracer`]: the
/// per-op dispatch counts double as registry counters (`wrm.ops_cpu` /
/// `wrm.ops_gpu`, `wrm.op_us` histogram, `wrm.upload_bytes` /
/// `wrm.download_bytes`), and layers that only see the hub (the WRM)
/// reach the trace stream through [`MetricsHub::tracer`].
#[derive(Debug)]
pub struct MetricsHub {
    ops: Mutex<BTreeMap<String, OpRecord>>,
    staging: Mutex<StagingReport>,
    started: Mutex<Option<Instant>>,
    finished: Mutex<Option<Instant>>,
    registry: Arc<obs::Registry>,
    tracer: Tracer,
    ops_cpu: obs::Counter,
    ops_gpu: obs::Counter,
    op_us: obs::Histogram,
    upload_bytes: obs::Counter,
    download_bytes: obs::Counter,
}

impl Default for MetricsHub {
    fn default() -> Self {
        Self::new()
    }
}

impl MetricsHub {
    /// A hub with a private registry and tracing disabled — the default
    /// everywhere observability wasn't explicitly requested.
    pub fn new() -> Self {
        Self::with_obs(Arc::new(obs::Registry::new()), Tracer::disabled())
    }

    /// A hub registering its instruments in a shared `registry` and
    /// recording through `tracer` (enabled by `--trace-out`).
    pub fn with_obs(registry: Arc<obs::Registry>, tracer: Tracer) -> Self {
        MetricsHub {
            ops: Mutex::default(),
            staging: Mutex::default(),
            started: Mutex::default(),
            finished: Mutex::default(),
            ops_cpu: registry.counter("wrm.ops_cpu"),
            ops_gpu: registry.counter("wrm.ops_gpu"),
            op_us: registry.histogram("wrm.op_us"),
            upload_bytes: registry.counter("wrm.upload_bytes"),
            download_bytes: registry.counter("wrm.download_bytes"),
            registry,
            tracer,
        }
    }

    /// The run's instrument registry.
    pub fn registry(&self) -> &Arc<obs::Registry> {
        &self.registry
    }

    /// The run's trace stream (disabled unless `--trace-out` was given).
    pub fn tracer(&self) -> &Tracer {
        &self.tracer
    }

    pub fn mark_start(&self) {
        *self.started.lock().unwrap() = Some(Instant::now());
    }

    pub fn mark_finish(&self) {
        *self.finished.lock().unwrap() = Some(Instant::now());
    }

    /// Record one executed operation instance.
    pub fn record_op(&self, op: &str, device: DeviceKind, elapsed: Duration) {
        match device {
            DeviceKind::Cpu => self.ops_cpu.inc(),
            DeviceKind::Gpu => self.ops_gpu.inc(),
        }
        self.op_us.observe(elapsed.as_micros() as u64);
        let mut map = self.ops.lock().unwrap();
        let rec = map.entry(op.to_string()).or_default();
        match device {
            DeviceKind::Cpu => {
                rec.cpu_count += 1;
                rec.cpu_time += elapsed;
            }
            DeviceKind::Gpu => {
                rec.gpu_count += 1;
                rec.gpu_time += elapsed;
            }
        }
    }

    /// Record bytes moved across the host/device boundary for an op.
    pub fn record_transfer(&self, op: &str, up: u64, down: u64) {
        self.upload_bytes.add(up);
        self.download_bytes.add(down);
        let mut map = self.ops.lock().unwrap();
        let rec = map.entry(op.to_string()).or_default();
        rec.upload_bytes += up;
        rec.download_bytes += down;
    }

    /// Fold a staging-cache snapshot into the run's counters (one call per
    /// worker cache at the end of its run).
    pub fn record_staging(&self, r: &StagingReport) {
        self.staging.lock().unwrap().accumulate(r);
    }

    /// Wall-clock between mark_start and mark_finish (or now).
    pub fn wall_time(&self) -> Duration {
        let s = self.started.lock().unwrap();
        let f = self.finished.lock().unwrap();
        match (*s, *f) {
            (Some(s), Some(f)) => f.duration_since(s),
            (Some(s), None) => s.elapsed(),
            _ => Duration::ZERO,
        }
    }

    pub fn report(&self) -> MetricsReport {
        let ops = self
            .ops
            .lock()
            .unwrap()
            .iter()
            .map(|(k, r)| OpProfile {
                op: k.clone(),
                cpu_count: r.cpu_count,
                gpu_count: r.gpu_count,
                cpu_time: r.cpu_time,
                gpu_time: r.gpu_time,
                upload_bytes: r.upload_bytes,
                download_bytes: r.download_bytes,
            })
            .collect();
        MetricsReport {
            ops,
            staging: self.staging.lock().unwrap().clone(),
            wall: self.wall_time(),
        }
    }
}

/// Immutable aggregate of a run.
#[derive(Debug, Clone)]
pub struct MetricsReport {
    pub ops: Vec<OpProfile>,
    /// data-staging counters (all zeros in non-staged runs)
    pub staging: StagingReport,
    pub wall: Duration,
}

impl MetricsReport {
    pub fn op(&self, name: &str) -> Option<&OpProfile> {
        self.ops.iter().find(|o| o.op == name)
    }

    pub fn total_executed(&self) -> u64 {
        self.ops.iter().map(|o| o.cpu_count + o.gpu_count).sum()
    }

    /// Pretty profile table (Fig. 10-style) as text rows.
    pub fn profile_table(&self) -> String {
        let mut out = format!(
            "{:<20} {:>8} {:>8} {:>7}\n",
            "operation", "CPU", "GPU", "%GPU"
        );
        for o in &self.ops {
            out.push_str(&format!(
                "{:<20} {:>8} {:>8} {:>6.1}%\n",
                o.op,
                o.cpu_count,
                o.gpu_count,
                o.gpu_fraction() * 100.0
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_aggregates() {
        let m = MetricsHub::new();
        m.record_op("watershed", DeviceKind::Cpu, Duration::from_millis(5));
        m.record_op("watershed", DeviceKind::Gpu, Duration::from_millis(2));
        m.record_op("watershed", DeviceKind::Gpu, Duration::from_millis(2));
        m.record_transfer("watershed", 100, 50);
        let r = m.report();
        let p = r.op("watershed").unwrap();
        assert_eq!(p.cpu_count, 1);
        assert_eq!(p.gpu_count, 2);
        assert!((p.gpu_fraction() - 2.0 / 3.0).abs() < 1e-9);
        assert_eq!(p.upload_bytes, 100);
        assert_eq!(r.total_executed(), 3);
    }

    #[test]
    fn op_counts_mirror_into_registry() {
        let reg = Arc::new(obs::Registry::new());
        let m = MetricsHub::with_obs(reg.clone(), Tracer::disabled());
        m.record_op("canny", DeviceKind::Cpu, Duration::from_micros(100));
        m.record_op("canny", DeviceKind::Gpu, Duration::from_micros(40));
        m.record_transfer("canny", 64, 32);
        let snap = reg.snapshot();
        assert_eq!(snap.counter("wrm.ops_cpu"), 1);
        assert_eq!(snap.counter("wrm.ops_gpu"), 1);
        assert_eq!(snap.counter("wrm.upload_bytes"), 64);
        assert_eq!(snap.counter("wrm.download_bytes"), 32);
        let h = snap.histogram("wrm.op_us").unwrap();
        assert_eq!(h.count, 2);
        assert_eq!(h.sum, 140);
        // registry totals agree with the report the run prints
        let r = m.report();
        assert_eq!(
            r.total_executed(),
            snap.counter("wrm.ops_cpu") + snap.counter("wrm.ops_gpu")
        );
    }

    #[test]
    fn wall_time_monotone() {
        let m = MetricsHub::new();
        m.mark_start();
        std::thread::sleep(Duration::from_millis(5));
        m.mark_finish();
        assert!(m.wall_time() >= Duration::from_millis(4));
    }

    #[test]
    fn staging_counters_accumulate_across_workers() {
        let m = MetricsHub::new();
        m.record_staging(&StagingReport {
            hits: 3,
            misses: 1,
            prefetched: 2,
            hidden: Duration::from_millis(10),
            stall: Duration::from_millis(2),
            ..Default::default()
        });
        m.record_staging(&StagingReport { hits: 1, misses: 3, ..Default::default() });
        let s = m.report().staging;
        assert_eq!((s.hits, s.misses, s.prefetched), (4, 4, 2));
        assert!((s.hit_rate() - 0.5).abs() < 1e-9);
        assert_eq!(s.hidden, Duration::from_millis(10));
        assert!(s.summary().contains("50% hit rate"), "{}", s.summary());
        // no tier activity -> no tiers line
        assert!(!s.summary().contains("tiers:"), "{}", s.summary());
    }

    #[test]
    fn tier_counters_accumulate_and_surface() {
        let m = MetricsHub::new();
        m.record_staging(&StagingReport {
            spill_hits: 2,
            spill_evicted: 3,
            promoted: 2,
            replicated: 1,
            ..Default::default()
        });
        m.record_staging(&StagingReport { spill_hits: 1, ..Default::default() });
        let s = m.report().staging;
        assert_eq!((s.spill_hits, s.spill_evicted, s.promoted, s.replicated), (3, 3, 2, 1));
        let sum = s.summary();
        assert!(sum.contains("tiers: 3 demoted, 3 spill hits, 2 promoted, 1 replica-staged"),
            "{sum}");
    }

    #[test]
    fn profile_table_contains_ops() {
        let m = MetricsHub::new();
        m.record_op("canny", DeviceKind::Gpu, Duration::from_millis(1));
        let t = m.report().profile_table();
        assert!(t.contains("canny"));
        assert!(t.contains("100.0%"));
    }
}
