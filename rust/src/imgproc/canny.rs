//! Canny edge detection (CPU variant of the feature stage's edge operation).
//!
//! The AOT feature graph uses a simple `gradient > t` edge mask
//! ([`simple_edges`], identical semantics to `model.feature_graph`); the
//! full Canny (non-maximum suppression + hysteresis) is the richer CPU
//! implementation the paper gets from OpenCV, used by the object feature
//! extractor for edge-density features.

use super::convolve::{gaussian3, stencil3x3, SOBEL_X, SOBEL_Y};
use super::Gray;
use std::collections::VecDeque;

/// Edge mask = sobel magnitude of gaussian-smoothed image > t.
/// Matches the AOT `feature_graph`'s edge output.
pub fn simple_edges(img: &Gray, t: f32) -> Gray {
    let smooth = gaussian3(img);
    let mag = super::convolve::sobel_magnitude(&smooth);
    Gray {
        h: img.h,
        w: img.w,
        px: mag.px.iter().map(|&v| if v > t { 1.0 } else { 0.0 }).collect(),
    }
}

/// Full Canny: gaussian smooth, sobel, NMS along the gradient direction,
/// double threshold + hysteresis linking (8-connected).
pub fn canny(img: &Gray, low: f32, high: f32) -> Gray {
    assert!(low <= high, "canny thresholds must satisfy low <= high");
    let (h, w) = (img.h, img.w);
    let smooth = gaussian3(img);
    let gx = stencil3x3(&smooth, &SOBEL_X);
    let gy = stencil3x3(&smooth, &SOBEL_Y);
    let mut mag = vec![0.0f32; h * w];
    for i in 0..h * w {
        mag[i] = (gx.px[i] * gx.px[i] + gy.px[i] * gy.px[i]).sqrt();
    }
    // non-maximum suppression: quantise direction to 0/45/90/135 degrees
    let mut nms = vec![0.0f32; h * w];
    for y in 0..h {
        for x in 0..w {
            let i = y * w + x;
            if mag[i] == 0.0 {
                continue;
            }
            let angle = gy.px[i].atan2(gx.px[i]);
            let deg = angle.to_degrees();
            let deg = if deg < 0.0 { deg + 180.0 } else { deg };
            let (dy, dx): (isize, isize) = if !(22.5..157.5).contains(&deg) {
                (0, 1) // ~horizontal gradient
            } else if deg < 67.5 {
                (1, 1)
            } else if deg < 112.5 {
                (1, 0)
            } else {
                (1, -1)
            };
            let get = |yy: isize, xx: isize| -> f32 {
                if yy < 0 || xx < 0 || yy >= h as isize || xx >= w as isize {
                    0.0
                } else {
                    mag[yy as usize * w + xx as usize]
                }
            };
            let a = get(y as isize + dy, x as isize + dx);
            let b = get(y as isize - dy, x as isize - dx);
            if mag[i] >= a && mag[i] >= b {
                nms[i] = mag[i];
            }
        }
    }
    // double threshold + hysteresis
    let mut out = vec![0.0f32; h * w];
    let mut queue = VecDeque::new();
    for y in 0..h {
        for x in 0..w {
            let i = y * w + x;
            if nms[i] > high {
                out[i] = 1.0;
                queue.push_back((y, x));
            }
        }
    }
    while let Some((y, x)) = queue.pop_front() {
        for &(dy, dx) in super::Conn::Eight.offsets() {
            let ny = y as isize + dy;
            let nx = x as isize + dx;
            if ny < 0 || nx < 0 || ny >= h as isize || nx >= w as isize {
                continue;
            }
            let q = ny as usize * w + nx as usize;
            if out[q] == 0.0 && nms[q] > low {
                out[q] = 1.0;
                queue.push_back((ny as usize, nx as usize));
            }
        }
    }
    Gray { h, w, px: out }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn step_image(h: usize, w: usize) -> Gray {
        let mut img = Gray::zeros(h, w);
        for y in 0..h {
            for x in w / 2..w {
                img.set(y, x, 200.0);
            }
        }
        img
    }

    #[test]
    fn finds_step_edge() {
        let img = step_image(16, 16);
        let e = canny(&img, 50.0, 150.0);
        // an edge column near the step
        let mid_row = 8;
        let edge_count: f32 = (0..16).map(|x| e.at(mid_row, x)).sum();
        assert!(edge_count >= 1.0, "no edge found on step");
        // edges only near the step (columns 6..10)
        for x in 0..4 {
            assert_eq!(e.at(mid_row, x), 0.0);
        }
        for x in 12..16 {
            assert_eq!(e.at(mid_row, x), 0.0);
        }
    }

    #[test]
    fn nms_thins_edges() {
        let img = step_image(16, 16);
        let e = canny(&img, 50.0, 150.0);
        // per row, at most 2 edge pixels after NMS (vs 3+ for raw threshold)
        for y in 2..14 {
            let row_count: f32 = (0..16).map(|x| e.at(y, x)).sum();
            assert!(row_count <= 2.0, "row {y} has {row_count} edge px");
        }
    }

    #[test]
    fn flat_image_no_edges() {
        let img = Gray::filled(12, 12, 77.0);
        let e = canny(&img, 10.0, 30.0);
        assert!(e.px.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn hysteresis_links_weak_to_strong() {
        // ramp edge whose magnitude varies along the edge: weak segments
        // adjacent to strong ones must be kept.
        let mut img = Gray::zeros(12, 12);
        for y in 0..12 {
            let amp = if y < 6 { 200.0 } else { 80.0 };
            for x in 6..12 {
                img.set(y, x, amp);
            }
        }
        let e = canny(&img, 20.0, 150.0);
        // strong rows present
        assert!((0..6).any(|y| (0..12).any(|x| e.at(y, x) > 0.0)));
        // weak rows linked through hysteresis
        assert!((7..12).any(|y| (0..12).any(|x| e.at(y, x) > 0.0)));
    }

    #[test]
    fn simple_edges_matches_threshold_semantics() {
        let img = step_image(10, 10);
        let e = simple_edges(&img, 100.0);
        assert!(e.px.iter().all(|&v| v == 0.0 || v == 1.0));
        assert!(e.px.iter().any(|&v| v == 1.0));
    }

    #[test]
    #[should_panic(expected = "low <= high")]
    fn rejects_inverted_thresholds() {
        canny(&Gray::zeros(4, 4), 10.0, 5.0);
    }
}
