//! Color deconvolution (Ruifrok–Johnston): CPU variant.
//!
//! Must match `python/compile/kernels/color_deconv.py` bit-for-bit in
//! structure: od = -log10((I + 1) / 256), stains = od @ inv(normalize(M)).
//! The 3x3 inverse is computed here with the adjugate formula in f64 and
//! truncated to f32, which stays within integration-test tolerance of
//! jnp.linalg.inv.

use super::{Gray, Rgb};
use crate::{Error, Result};

/// Default H&E stain matrix (rows: hematoxylin, eosin, residual).
pub const STAIN_MATRIX: [[f64; 3]; 3] = [
    [0.650, 0.704, 0.286],
    [0.072, 0.990, 0.105],
    [0.268, 0.570, 0.776],
];

/// Row-normalise then invert a 3x3 matrix (adjugate / determinant).
pub fn stain_inverse(m: &[[f64; 3]; 3]) -> Result<[[f32; 3]; 3]> {
    let mut n = [[0.0f64; 3]; 3];
    for r in 0..3 {
        let norm = (m[r][0] * m[r][0] + m[r][1] * m[r][1] + m[r][2] * m[r][2]).sqrt();
        if norm == 0.0 {
            return Err(Error::ImgProc("zero row in stain matrix".into()));
        }
        for c in 0..3 {
            n[r][c] = m[r][c] / norm;
        }
    }
    let det = n[0][0] * (n[1][1] * n[2][2] - n[1][2] * n[2][1])
        - n[0][1] * (n[1][0] * n[2][2] - n[1][2] * n[2][0])
        + n[0][2] * (n[1][0] * n[2][1] - n[1][1] * n[2][0]);
    if det.abs() < 1e-12 {
        return Err(Error::ImgProc("singular stain matrix".into()));
    }
    let adj = [
        [
            n[1][1] * n[2][2] - n[1][2] * n[2][1],
            n[0][2] * n[2][1] - n[0][1] * n[2][2],
            n[0][1] * n[1][2] - n[0][2] * n[1][1],
        ],
        [
            n[1][2] * n[2][0] - n[1][0] * n[2][2],
            n[0][0] * n[2][2] - n[0][2] * n[2][0],
            n[0][2] * n[1][0] - n[0][0] * n[1][2],
        ],
        [
            n[1][0] * n[2][1] - n[1][1] * n[2][0],
            n[0][1] * n[2][0] - n[0][0] * n[2][1],
            n[0][0] * n[1][1] - n[0][1] * n[1][0],
        ],
    ];
    let mut out = [[0.0f32; 3]; 3];
    for r in 0..3 {
        for c in 0..3 {
            out[r][c] = (adj[r][c] / det) as f32;
        }
    }
    Ok(out)
}

/// Deconvolved stain channels of an RGB tile.
pub struct Stains {
    pub hematoxylin: Gray,
    pub eosin: Gray,
    pub residual: Gray,
}

/// Deconvolve an RGB tile (values 0..255) into optical-density stain space.
pub fn color_deconv(rgb: &Rgb) -> Result<Stains> {
    let minv = stain_inverse(&STAIN_MATRIX)?;
    color_deconv_with(rgb, &minv)
}

/// Deconvolution with an explicit (already inverted) stain matrix.
pub fn color_deconv_with(rgb: &Rgb, minv: &[[f32; 3]; 3]) -> Result<Stains> {
    let n = rgb.h * rgb.w;
    let mut hema = vec![0.0f32; n];
    let mut eosin = vec![0.0f32; n];
    let mut resid = vec![0.0f32; n];
    const INV_LN10: f32 = std::f32::consts::LOG10_E; // 1/ln(10)
    for i in 0..n {
        // optical density per channel: -log10((I+1)/256)
        let od = [
            -((rgb.px[i * 3] + 1.0) / 256.0).ln() * INV_LN10,
            -((rgb.px[i * 3 + 1] + 1.0) / 256.0).ln() * INV_LN10,
            -((rgb.px[i * 3 + 2] + 1.0) / 256.0).ln() * INV_LN10,
        ];
        hema[i] = od[0] * minv[0][0] + od[1] * minv[1][0] + od[2] * minv[2][0];
        eosin[i] = od[0] * minv[0][1] + od[1] * minv[1][1] + od[2] * minv[2][1];
        resid[i] = od[0] * minv[0][2] + od[1] * minv[1][2] + od[2] * minv[2][2];
    }
    Ok(Stains {
        hematoxylin: Gray::new(rgb.h, rgb.w, hema)?,
        eosin: Gray::new(rgb.h, rgb.w, eosin)?,
        residual: Gray::new(rgb.h, rgb.w, resid)?,
    })
}

/// The hematoxylin channel scaled into [0, 256) image range — the grayscale
/// input of the segmentation stage (matches `model.feature_graph`).
pub fn hema_image(rgb: &Rgb) -> Result<Gray> {
    let stains = color_deconv(rgb)?;
    let mut g = stains.hematoxylin;
    for v in &mut g.px {
        *v = (*v * 100.0).clamp(0.0, 255.0);
    }
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inverse_times_matrix_is_identity() {
        let minv = stain_inverse(&STAIN_MATRIX).unwrap();
        // normalised matrix
        let mut n = [[0.0f64; 3]; 3];
        for r in 0..3 {
            let norm = STAIN_MATRIX[r].iter().map(|v| v * v).sum::<f64>().sqrt();
            for c in 0..3 {
                n[r][c] = STAIN_MATRIX[r][c] / norm;
            }
        }
        for i in 0..3 {
            for j in 0..3 {
                let mut acc = 0.0f64;
                for k in 0..3 {
                    acc += n[i][k] * minv[k][j] as f64;
                }
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!((acc - expect).abs() < 1e-5, "({i},{j}) = {acc}");
            }
        }
    }

    #[test]
    fn white_pixel_has_near_zero_density() {
        let rgb = Rgb::filled(2, 2, [255.0, 255.0, 255.0]);
        let s = color_deconv(&rgb).unwrap();
        assert!(s.hematoxylin.px.iter().all(|v| v.abs() < 1e-2));
        assert!(s.eosin.px.iter().all(|v| v.abs() < 1e-2));
    }

    #[test]
    fn hematoxylin_like_pixel_scores_high_on_h_channel() {
        // A bluish-purple pixel (strong absorption in R, less in B).
        let rgb = Rgb::filled(1, 1, [80.0, 60.0, 160.0]);
        let s = color_deconv(&rgb).unwrap();
        assert!(
            s.hematoxylin.px[0] > s.eosin.px[0],
            "h={} e={}",
            s.hematoxylin.px[0],
            s.eosin.px[0]
        );
    }

    #[test]
    fn singular_matrix_rejected() {
        let m = [[1.0, 0.0, 0.0], [2.0, 0.0, 0.0], [0.0, 0.0, 1.0]];
        assert!(stain_inverse(&m).is_err());
    }

    #[test]
    fn hema_image_in_range() {
        let rgb = Rgb::filled(3, 3, [10.0, 200.0, 30.0]);
        let g = hema_image(&rgb).unwrap();
        assert!(g.px.iter().all(|&v| (0.0..=255.0).contains(&v)));
    }
}
