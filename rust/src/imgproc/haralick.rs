//! Haralick texture features from gray-level co-occurrence matrices (GLCM).
//!
//! The paper's feature-computation stage includes "Haralick features [30]".
//! We quantise to 16 gray levels, accumulate symmetric GLCMs for the four
//! standard directions (0°, 45°, 90°, 135°) at distance 1, and derive the
//! five classic scalar features per direction plus their mean.

use super::Gray;

pub const GLCM_LEVELS: usize = 16;

/// The four standard direction offsets (dy, dx).
pub const DIRECTIONS: [(isize, isize); 4] = [(0, 1), (-1, 1), (-1, 0), (-1, -1)];

/// Scalar Haralick features of one GLCM.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HaralickFeatures {
    pub contrast: f32,
    pub energy: f32,
    pub homogeneity: f32,
    pub entropy: f32,
    pub correlation: f32,
}

impl HaralickFeatures {
    pub fn to_vec(self) -> [f32; 5] {
        [self.contrast, self.energy, self.homogeneity, self.entropy, self.correlation]
    }
}

fn quantise(v: f32) -> usize {
    let clipped = v.clamp(0.0, 255.999);
    (clipped / (256.0 / GLCM_LEVELS as f32)) as usize
}

/// Symmetric, normalised GLCM for one direction, restricted to `mask`
/// (both pixels of a pair must be foreground; pass an all-ones mask for
/// whole-tile texture).
pub fn glcm(img: &Gray, mask: &Gray, dir: (isize, isize)) -> [[f32; GLCM_LEVELS]; GLCM_LEVELS] {
    let (h, w) = (img.h, img.w);
    let mut m = [[0.0f32; GLCM_LEVELS]; GLCM_LEVELS];
    let mut total = 0.0f32;
    for y in 0..h {
        for x in 0..w {
            if mask.at(y, x) <= 0.5 {
                continue;
            }
            let ny = y as isize + dir.0;
            let nx = x as isize + dir.1;
            if ny < 0 || nx < 0 || ny >= h as isize || nx >= w as isize {
                continue;
            }
            if mask.at(ny as usize, nx as usize) <= 0.5 {
                continue;
            }
            let a = quantise(img.at(y, x));
            let b = quantise(img.at(ny as usize, nx as usize));
            m[a][b] += 1.0;
            m[b][a] += 1.0; // symmetric
            total += 2.0;
        }
    }
    if total > 0.0 {
        for row in &mut m {
            for v in row.iter_mut() {
                *v /= total;
            }
        }
    }
    m
}

/// Features of one normalised GLCM.
pub fn glcm_features(m: &[[f32; GLCM_LEVELS]; GLCM_LEVELS]) -> HaralickFeatures {
    let mut contrast = 0.0f32;
    let mut energy = 0.0f32;
    let mut homogeneity = 0.0f32;
    let mut entropy = 0.0f32;
    // marginal stats for correlation
    let mut mean = 0.0f32;
    for (i, row) in m.iter().enumerate() {
        for (j, &p) in row.iter().enumerate() {
            let d = i as f32 - j as f32;
            contrast += p * d * d;
            energy += p * p;
            homogeneity += p / (1.0 + d.abs());
            if p > 0.0 {
                entropy -= p * p.ln();
            }
            mean += i as f32 * p;
        }
    }
    let mut var = 0.0f32;
    for (i, row) in m.iter().enumerate() {
        let pi: f32 = row.iter().sum();
        var += (i as f32 - mean) * (i as f32 - mean) * pi;
    }
    let mut correlation = 0.0f32;
    if var > 1e-12 {
        for (i, row) in m.iter().enumerate() {
            for (j, &p) in row.iter().enumerate() {
                correlation += p * (i as f32 - mean) * (j as f32 - mean);
            }
        }
        correlation /= var;
    }
    HaralickFeatures { contrast, energy, homogeneity, entropy, correlation }
}

/// Mean Haralick features across the four standard directions.
pub fn haralick(img: &Gray, mask: &Gray) -> HaralickFeatures {
    let mut acc = [0.0f32; 5];
    for dir in DIRECTIONS {
        let f = glcm_features(&glcm(img, mask, dir)).to_vec();
        for (a, v) in acc.iter_mut().zip(f) {
            *a += v;
        }
    }
    HaralickFeatures {
        contrast: acc[0] / 4.0,
        energy: acc[1] / 4.0,
        homogeneity: acc[2] / 4.0,
        entropy: acc[3] / 4.0,
        correlation: acc[4] / 4.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::Rng;

    #[test]
    fn constant_image_is_maximally_homogeneous() {
        let img = Gray::filled(16, 16, 100.0);
        let mask = Gray::filled(16, 16, 1.0);
        let f = haralick(&img, &mask);
        assert!(f.contrast.abs() < 1e-6);
        assert!((f.energy - 1.0).abs() < 1e-5);
        assert!((f.homogeneity - 1.0).abs() < 1e-5);
        assert!(f.entropy.abs() < 1e-5);
    }

    #[test]
    fn checkerboard_has_high_contrast() {
        let mut img = Gray::zeros(16, 16);
        for y in 0..16 {
            for x in 0..16 {
                if (y + x) % 2 == 0 {
                    img.set(y, x, 255.0);
                }
            }
        }
        let mask = Gray::filled(16, 16, 1.0);
        let f0 = glcm_features(&glcm(&img, &mask, (0, 1)));
        // horizontal neighbours always differ by 15 levels
        assert!(f0.contrast > 200.0, "contrast = {}", f0.contrast);
        let fc = haralick(&Gray::filled(16, 16, 1.0), &mask);
        assert!(f0.contrast > fc.contrast);
    }

    #[test]
    fn glcm_is_normalised_and_symmetric() {
        let mut r = Rng::new(5);
        let img = Gray::new(12, 12, r.image(12, 12)).unwrap();
        let mask = Gray::filled(12, 12, 1.0);
        let m = glcm(&img, &mask, (0, 1));
        let total: f32 = m.iter().flatten().sum();
        assert!((total - 1.0).abs() < 1e-4);
        for i in 0..GLCM_LEVELS {
            for j in 0..GLCM_LEVELS {
                assert!((m[i][j] - m[j][i]).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn mask_restricts_pairs() {
        let mut img = Gray::zeros(8, 8);
        for y in 0..8 {
            for x in 0..8 {
                img.set(y, x, (x * 32) as f32);
            }
        }
        let empty = Gray::zeros(8, 8);
        let m = glcm(&img, &empty, (0, 1));
        assert!(m.iter().flatten().all(|&v| v == 0.0));
    }

    #[test]
    fn correlation_of_smooth_gradient_is_high() {
        let mut img = Gray::zeros(16, 16);
        for y in 0..16 {
            for x in 0..16 {
                img.set(y, x, (y * 16) as f32);
            }
        }
        let mask = Gray::filled(16, 16, 1.0);
        let f = glcm_features(&glcm(&img, &mask, (0, 1)));
        // horizontal pairs have identical values -> perfect correlation
        assert!(f.correlation > 0.99, "corr = {}", f.correlation);
    }
}
