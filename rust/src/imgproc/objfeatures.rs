//! Per-object morphometry + intensity features.
//!
//! The paper's feature stage computes "pixel statistics, gradient
//! statistics, Haralick features, edge, and morphometry" per segmented
//! nucleus.  This is the morphometry/per-object part: one pass over the
//! label image accumulates geometric moments and intensity sums per label,
//! then derives the feature vector.  Per-object work is irregular and stays
//! on the CPU (in the paper, too, object features are computed from
//! boundaries after the pixel transforms).

use super::{Conn, Gray};

/// Features of one segmented object.
#[derive(Debug, Clone, PartialEq)]
pub struct ObjectFeatures {
    pub label: u32,
    pub area: f32,
    pub centroid: (f32, f32),
    pub bbox: (u32, u32, u32, u32), // (y0, x0, y1, x1) inclusive
    pub perimeter: f32,
    pub eccentricity: f32,
    pub circularity: f32,
    pub mean_intensity: f32,
    pub std_intensity: f32,
    pub mean_gradient: f32,
    pub edge_pixels: f32,
}

impl ObjectFeatures {
    /// Flatten to the fixed-width vector stored per nucleus.
    pub fn to_vec(&self) -> [f32; 12] {
        [
            self.area,
            self.centroid.0,
            self.centroid.1,
            self.bbox.0 as f32,
            self.bbox.1 as f32,
            self.bbox.2 as f32,
            self.bbox.3 as f32,
            self.perimeter,
            self.eccentricity,
            self.circularity,
            self.mean_intensity,
            self.std_intensity,
        ]
    }
}

#[derive(Clone)]
struct Acc {
    area: f64,
    sy: f64,
    sx: f64,
    syy: f64,
    sxx: f64,
    sxy: f64,
    y0: u32,
    x0: u32,
    y1: u32,
    x1: u32,
    perim: f64,
    isum: f64,
    isumsq: f64,
    gsum: f64,
    edges: f64,
}

impl Acc {
    fn new() -> Self {
        Acc {
            area: 0.0,
            sy: 0.0,
            sx: 0.0,
            syy: 0.0,
            sxx: 0.0,
            sxy: 0.0,
            y0: u32::MAX,
            x0: u32::MAX,
            y1: 0,
            x1: 0,
            perim: 0.0,
            isum: 0.0,
            isumsq: 0.0,
            gsum: 0.0,
            edges: 0.0,
        }
    }
}

/// Extract features of every labelled object.
///
/// * `labels` — label image (ids 1..=n_labels, 0 = background)
/// * `intensity` — e.g. hematoxylin channel
/// * `gradient` — gradient magnitude image
/// * `edges` — binary edge mask
pub fn object_features(
    labels: &Gray,
    n_labels: usize,
    intensity: &Gray,
    gradient: &Gray,
    edges: &Gray,
) -> Vec<ObjectFeatures> {
    let (h, w) = (labels.h, labels.w);
    let mut accs = vec![Acc::new(); n_labels + 1];
    for y in 0..h {
        for x in 0..w {
            let id = labels.at(y, x) as usize;
            if id == 0 || id > n_labels {
                continue;
            }
            let a = &mut accs[id];
            let (yf, xf) = (y as f64, x as f64);
            a.area += 1.0;
            a.sy += yf;
            a.sx += xf;
            a.syy += yf * yf;
            a.sxx += xf * xf;
            a.sxy += yf * xf;
            a.y0 = a.y0.min(y as u32);
            a.x0 = a.x0.min(x as u32);
            a.y1 = a.y1.max(y as u32);
            a.x1 = a.x1.max(x as u32);
            a.isum += intensity.at(y, x) as f64;
            a.isumsq += (intensity.at(y, x) as f64).powi(2);
            a.gsum += gradient.at(y, x) as f64;
            a.edges += edges.at(y, x) as f64;
            // boundary pixel: any 4-neighbour outside the object
            let mut boundary = false;
            for &(dy, dx) in Conn::Four.offsets() {
                let ny = y as isize + dy;
                let nx = x as isize + dx;
                if ny < 0 || nx < 0 || ny >= h as isize || nx >= w as isize {
                    boundary = true;
                    break;
                }
                if labels.at(ny as usize, nx as usize) as usize != id {
                    boundary = true;
                    break;
                }
            }
            if boundary {
                a.perim += 1.0;
            }
        }
    }
    let mut out = Vec::new();
    for (id, a) in accs.iter().enumerate().skip(1) {
        if a.area == 0.0 {
            continue;
        }
        let n = a.area;
        let cy = a.sy / n;
        let cx = a.sx / n;
        // central second moments
        let myy = a.syy / n - cy * cy;
        let mxx = a.sxx / n - cx * cx;
        let mxy = a.sxy / n - cx * cy;
        // eigenvalues of the covariance matrix
        let tr = myy + mxx;
        let det = myy * mxx - mxy * mxy;
        let disc = (tr * tr / 4.0 - det).max(0.0).sqrt();
        let l1 = (tr / 2.0 + disc).max(1e-12);
        let l2 = (tr / 2.0 - disc).max(0.0);
        let eccentricity = (1.0 - (l2 / l1)).max(0.0).sqrt();
        let circularity = if a.perim > 0.0 {
            (4.0 * std::f64::consts::PI * n / (a.perim * a.perim)).min(1.5)
        } else {
            1.0
        };
        let mean_i = a.isum / n;
        let var_i = (a.isumsq / n - mean_i * mean_i).max(0.0);
        out.push(ObjectFeatures {
            label: id as u32,
            area: n as f32,
            centroid: (cy as f32, cx as f32),
            bbox: (a.y0, a.x0, a.y1, a.x1),
            perimeter: a.perim as f32,
            eccentricity: eccentricity as f32,
            circularity: circularity as f32,
            mean_intensity: mean_i as f32,
            std_intensity: var_i.sqrt() as f32,
            mean_gradient: (a.gsum / n) as f32,
            edge_pixels: a.edges as f32,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup(mask_fn: impl Fn(usize, usize) -> f32) -> (Gray, Gray, Gray, Gray) {
        let (h, w) = (16, 16);
        let mut labels = Gray::zeros(h, w);
        for y in 0..h {
            for x in 0..w {
                labels.set(y, x, mask_fn(y, x));
            }
        }
        let intensity = Gray::filled(h, w, 50.0);
        let gradient = Gray::filled(h, w, 2.0);
        let edges = Gray::zeros(h, w);
        (labels, intensity, gradient, edges)
    }

    #[test]
    fn square_object_metrics() {
        let (labels, i, g, e) =
            setup(|y, x| if (4..8).contains(&y) && (4..8).contains(&x) { 1.0 } else { 0.0 });
        let f = object_features(&labels, 1, &i, &g, &e);
        assert_eq!(f.len(), 1);
        let o = &f[0];
        assert_eq!(o.area, 16.0);
        assert_eq!(o.centroid, (5.5, 5.5));
        assert_eq!(o.bbox, (4, 4, 7, 7));
        assert_eq!(o.perimeter, 12.0); // 4x4 square boundary
        assert!(o.eccentricity < 1e-3, "square is round: {}", o.eccentricity);
        assert_eq!(o.mean_intensity, 50.0);
        assert!(o.std_intensity < 1e-4);
        assert_eq!(o.mean_gradient, 2.0);
    }

    #[test]
    fn elongated_object_is_eccentric() {
        let (labels, i, g, e) =
            setup(|y, x| if y == 8 && (2..14).contains(&x) { 1.0 } else { 0.0 });
        let f = object_features(&labels, 1, &i, &g, &e);
        assert!(f[0].eccentricity > 0.95, "line ecc = {}", f[0].eccentricity);
    }

    #[test]
    fn multiple_objects_separated() {
        let (labels, i, g, e) = setup(|y, x| {
            if y < 4 && x < 4 {
                1.0
            } else if y > 10 && x > 10 {
                2.0
            } else {
                0.0
            }
        });
        let f = object_features(&labels, 2, &i, &g, &e);
        assert_eq!(f.len(), 2);
        assert_eq!(f[0].label, 1);
        assert_eq!(f[1].label, 2);
        assert_eq!(f[0].area, 16.0);
        assert_eq!(f[1].area, 25.0);
    }

    #[test]
    fn empty_labels_no_features() {
        let (labels, i, g, e) = setup(|_, _| 0.0);
        assert!(object_features(&labels, 0, &i, &g, &e).is_empty());
    }

    #[test]
    fn to_vec_roundtrip_fields() {
        let (labels, i, g, e) =
            setup(|y, x| if (4..8).contains(&y) && (4..8).contains(&x) { 1.0 } else { 0.0 });
        let f = object_features(&labels, 1, &i, &g, &e);
        let v = f[0].to_vec();
        assert_eq!(v[0], 16.0);
        assert_eq!(v[1], 5.5);
    }
}
