//! 3x3 stencils with replicate-edge padding: gaussian smoothing and sobel
//! gradients (CPU variants of `python/compile/kernels/conv2d.py`).

use super::Gray;

pub const GAUSSIAN3: [[f32; 3]; 3] = [
    [1.0 / 16.0, 2.0 / 16.0, 1.0 / 16.0],
    [2.0 / 16.0, 4.0 / 16.0, 2.0 / 16.0],
    [1.0 / 16.0, 2.0 / 16.0, 1.0 / 16.0],
];
pub const SOBEL_X: [[f32; 3]; 3] = [[-1.0, 0.0, 1.0], [-2.0, 0.0, 2.0], [-1.0, 0.0, 1.0]];
pub const SOBEL_Y: [[f32; 3]; 3] = [[-1.0, -2.0, -1.0], [0.0, 0.0, 0.0], [1.0, 2.0, 1.0]];

/// Apply a 3x3 stencil with replicate-edge padding.
///
/// The interior is computed with direct indexing (no clamping) — this is the
/// hot path of the feature stage; only the 1-pixel border pays the clamp.
pub fn stencil3x3(img: &Gray, taps: &[[f32; 3]; 3]) -> Gray {
    let (h, w) = (img.h, img.w);
    let mut out = vec![0.0f32; h * w];
    if h >= 3 && w >= 3 {
        // interior
        for y in 1..h - 1 {
            let row = y * w;
            for x in 1..w - 1 {
                let mut acc = 0.0f32;
                for (dy, taps_row) in taps.iter().enumerate() {
                    let base = row + (dy as isize - 1) as usize * 0; // silence lint
                    let _ = base;
                    let r = (y + dy - 1) * w;
                    acc += taps_row[0] * img.px[r + x - 1]
                        + taps_row[1] * img.px[r + x]
                        + taps_row[2] * img.px[r + x + 1];
                }
                out[row + x] = acc;
            }
        }
    }
    // border (replicate padding)
    let mut do_border = |y: usize, x: usize| {
        let mut acc = 0.0f32;
        for dy in -1isize..=1 {
            for dx in -1isize..=1 {
                acc += taps[(dy + 1) as usize][(dx + 1) as usize]
                    * img.at_clamped(y as isize + dy, x as isize + dx);
            }
        }
        out[y * w + x] = acc;
    };
    for x in 0..w {
        do_border(0, x);
        do_border(h - 1, x);
    }
    for y in 0..h {
        do_border(y, 0);
        do_border(y, w - 1);
    }
    Gray { h, w, px: out }
}

/// 3x3 gaussian blur.
pub fn gaussian3(img: &Gray) -> Gray {
    stencil3x3(img, &GAUSSIAN3)
}

/// Sobel gradient magnitude sqrt(gx^2 + gy^2) (fused single pass).
pub fn sobel_magnitude(img: &Gray) -> Gray {
    let (h, w) = (img.h, img.w);
    let mut out = vec![0.0f32; h * w];
    if h >= 3 && w >= 3 {
        for y in 1..h - 1 {
            let up = (y - 1) * w;
            let mid = y * w;
            let dn = (y + 1) * w;
            for x in 1..w - 1 {
                let (a, b, c) = (img.px[up + x - 1], img.px[up + x], img.px[up + x + 1]);
                let (d, f) = (img.px[mid + x - 1], img.px[mid + x + 1]);
                let (g, hh, i) = (img.px[dn + x - 1], img.px[dn + x], img.px[dn + x + 1]);
                let gx = (c + 2.0 * f + i) - (a + 2.0 * d + g);
                let gy = (g + 2.0 * hh + i) - (a + 2.0 * b + c);
                out[mid + x] = (gx * gx + gy * gy).sqrt();
            }
        }
    }
    let mut do_border = |y: usize, x: usize| {
        let mut gx = 0.0f32;
        let mut gy = 0.0f32;
        for dy in -1isize..=1 {
            for dx in -1isize..=1 {
                let v = img.at_clamped(y as isize + dy, x as isize + dx);
                gx += SOBEL_X[(dy + 1) as usize][(dx + 1) as usize] * v;
                gy += SOBEL_Y[(dy + 1) as usize][(dx + 1) as usize] * v;
            }
        }
        out[y * w + x] = (gx * gx + gy * gy).sqrt();
    };
    for x in 0..w {
        do_border(0, x);
        do_border(h - 1, x);
    }
    for y in 0..h {
        do_border(y, 0);
        do_border(y, w - 1);
    }
    Gray { h, w, px: out }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{forall, Rng};

    #[test]
    fn gaussian_preserves_constant() {
        let img = Gray::filled(8, 11, 42.0);
        let out = gaussian3(&img);
        for v in out.px {
            assert!((v - 42.0).abs() < 1e-4);
        }
    }

    #[test]
    fn sobel_zero_on_constant() {
        let img = Gray::filled(7, 7, 9.0);
        let out = sobel_magnitude(&img);
        assert!(out.px.iter().all(|&v| v.abs() < 1e-4));
    }

    #[test]
    fn sobel_detects_vertical_edge() {
        let mut img = Gray::zeros(8, 8);
        for y in 0..8 {
            for x in 4..8 {
                img.set(y, x, 100.0);
            }
        }
        let mag = sobel_magnitude(&img);
        assert!(mag.at(4, 3) > 100.0 && mag.at(4, 4) > 100.0);
        assert!(mag.at(4, 0) < 1e-4);
    }

    #[test]
    fn fused_sobel_matches_two_pass() {
        forall(
            "sobel-fused == two-pass",
            20,
            |r: &mut Rng| {
                let h = r.range(3, 12);
                let w = r.range(3, 12);
                (h, w, r.image(h, w))
            },
            |(h, w, px)| {
                let img = Gray::new(*h, *w, px.clone()).unwrap();
                let fused = sobel_magnitude(&img);
                let gx = stencil3x3(&img, &SOBEL_X);
                let gy = stencil3x3(&img, &SOBEL_Y);
                for i in 0..px.len() {
                    let want = (gx.px[i] * gx.px[i] + gy.px[i] * gy.px[i]).sqrt();
                    if (fused.px[i] - want).abs() > 1e-3 {
                        return Err(format!("pixel {i}: {} vs {want}", fused.px[i]));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn tiny_images_dont_panic() {
        for (h, w) in [(1, 1), (1, 5), (2, 2), (3, 1)] {
            let img = Gray::filled(h, w, 5.0);
            let g = gaussian3(&img);
            assert_eq!(g.px.len(), h * w);
            let s = sobel_magnitude(&img);
            assert!(s.px.iter().all(|&v| v.abs() < 1e-4));
        }
    }
}
