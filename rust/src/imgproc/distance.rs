//! Chessboard distance transform (two-pass chamfer).
//!
//! `model.distance` computes the same metric as a min-plus fixed point; the
//! chessboard metric is exactly computed by one forward + one backward
//! chamfer pass, which is the O(n) CPU formulation (OpenCV-style, as in the
//! paper's Pre-Watershed).

use super::Gray;

const BIG: f32 = 1.0e9;

/// Distance of each foreground pixel to the nearest background pixel,
/// chessboard metric.  Background pixels get 0.  A mask with no background
/// yields BIG-clamped values (callers always have background in practice).
pub fn distance_chessboard(mask: &Gray) -> Gray {
    let (h, w) = (mask.h, mask.w);
    let mut d: Vec<f32> = mask
        .px
        .iter()
        .map(|&v| if v > 0.5 { BIG } else { 0.0 })
        .collect();
    let idx = |y: usize, x: usize| y * w + x;
    // forward pass: N, NW, NE, W
    for y in 0..h {
        for x in 0..w {
            let mut v = d[idx(y, x)];
            if v == 0.0 {
                continue;
            }
            if y > 0 {
                v = v.min(d[idx(y - 1, x)] + 1.0);
                if x > 0 {
                    v = v.min(d[idx(y - 1, x - 1)] + 1.0);
                }
                if x + 1 < w {
                    v = v.min(d[idx(y - 1, x + 1)] + 1.0);
                }
            }
            if x > 0 {
                v = v.min(d[idx(y, x - 1)] + 1.0);
            }
            d[idx(y, x)] = v;
        }
    }
    // backward pass: S, SE, SW, E
    for y in (0..h).rev() {
        for x in (0..w).rev() {
            let mut v = d[idx(y, x)];
            if v == 0.0 {
                continue;
            }
            if y + 1 < h {
                v = v.min(d[idx(y + 1, x)] + 1.0);
                if x > 0 {
                    v = v.min(d[idx(y + 1, x - 1)] + 1.0);
                }
                if x + 1 < w {
                    v = v.min(d[idx(y + 1, x + 1)] + 1.0);
                }
            }
            if x + 1 < w {
                v = v.min(d[idx(y, x + 1)] + 1.0);
            }
            d[idx(y, x)] = v;
        }
    }
    Gray { h, w, px: d }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{forall, Rng};

    /// Brute-force chessboard distance (O(n^2) oracle).
    fn brute(mask: &Gray) -> Vec<f32> {
        let (h, w) = (mask.h, mask.w);
        let mut out = vec![0.0f32; h * w];
        for y in 0..h {
            for x in 0..w {
                if mask.at(y, x) <= 0.5 {
                    continue;
                }
                let mut best = BIG;
                for by in 0..h {
                    for bx in 0..w {
                        if mask.at(by, bx) <= 0.5 {
                            let dy = (y as isize - by as isize).unsigned_abs();
                            let dx = (x as isize - bx as isize).unsigned_abs();
                            best = best.min(dy.max(dx) as f32);
                        }
                    }
                }
                out[y * w + x] = best;
            }
        }
        out
    }

    #[test]
    fn square_blob_radius() {
        let mut m = Gray::zeros(9, 9);
        for y in 2..7 {
            for x in 2..7 {
                m.set(y, x, 1.0);
            }
        }
        let d = distance_chessboard(&m);
        assert_eq!(d.at(4, 4), 3.0);
        assert_eq!(d.at(2, 2), 1.0);
        assert_eq!(d.at(0, 0), 0.0);
    }

    #[test]
    fn matches_brute_force() {
        forall(
            "chamfer == brute chessboard",
            25,
            |r: &mut Rng| {
                let h = r.range(2, 14);
                let w = r.range(2, 14);
                let mut px = r.mask(h, w, 0.7);
                // guarantee at least one background pixel
                px[r.below(h * w)] = 0.0;
                (h, w, px)
            },
            |(h, w, px)| {
                let m = Gray::new(*h, *w, px.clone()).unwrap();
                let d = distance_chessboard(&m);
                let want = brute(&m);
                for i in 0..px.len() {
                    if (d.px[i] - want[i]).abs() > 1e-6 {
                        return Err(format!("at {i}: {} vs {}", d.px[i], want[i]));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn all_foreground_stays_big() {
        let m = Gray::filled(4, 4, 1.0);
        let d = distance_chessboard(&m);
        assert!(d.px.iter().all(|&v| v >= 4.0), "no background -> huge distances");
    }
}
