//! Grayscale/binary morphology: erosion, dilation, opening, closing,
//! geodesic dilation and hole filling.
//!
//! Padding semantics mirror `python/compile/kernels/morph.py`: dilation pads
//! with -inf, erosion with +inf (i.e. the border does not invent extrema).

use super::reconstruct::reconstruct;
use super::{Conn, Gray};

/// Dilation by the 3x3 square (8-conn) or cross (4-conn) structuring element.
pub fn dilate3x3(img: &Gray, conn: Conn) -> Gray {
    nbr_reduce(img, conn, f32::NEG_INFINITY, f32::max)
}

/// Erosion by the 3x3 square (8-conn) or cross (4-conn) structuring element.
pub fn erode3x3(img: &Gray, conn: Conn) -> Gray {
    nbr_reduce(img, conn, f32::INFINITY, f32::min)
}

fn nbr_reduce(img: &Gray, conn: Conn, pad: f32, op: fn(f32, f32) -> f32) -> Gray {
    let (h, w) = (img.h, img.w);
    let mut out = vec![pad; h * w];
    for y in 0..h {
        for x in 0..w {
            let mut acc = img.at(y, x); // centre always included
            for &(dy, dx) in conn.offsets() {
                let ny = y as isize + dy;
                let nx = x as isize + dx;
                let v = if ny < 0 || nx < 0 || ny >= h as isize || nx >= w as isize {
                    pad
                } else {
                    img.at(ny as usize, nx as usize)
                };
                acc = op(acc, v);
            }
            out[y * w + x] = acc;
        }
    }
    Gray { h, w, px: out }
}

/// Opening by the radius-2 diamond: two 4-conn erosions then two 4-conn
/// dilations.  Matches `model.morph_open` (the paper's 19x19-disk opening,
/// scaled to our tile sizes — see DESIGN.md §Hardware-Adaptation).
pub fn morph_open(img: &Gray) -> Gray {
    let e = erode3x3(&erode3x3(img, Conn::Four), Conn::Four);
    dilate3x3(&dilate3x3(&e, Conn::Four), Conn::Four)
}

/// One geodesic dilation step: min(dilate(marker), mask).
pub fn dilate_clip(marker: &Gray, mask: &Gray, conn: Conn) -> Gray {
    let mut d = dilate3x3(marker, conn);
    for (v, m) in d.px.iter_mut().zip(&mask.px) {
        *v = v.min(*m);
    }
    d
}

/// Fill holes of a binary (0/1) mask: a hole is background not reachable
/// from the tile border (4-connected), matching `model.fill_holes`.
pub fn fill_holes(mask: &Gray) -> Gray {
    let (h, w) = (mask.h, mask.w);
    // complement
    let comp = Gray {
        h,
        w,
        px: mask.px.iter().map(|&v| 1.0 - v).collect(),
    };
    // marker: complement restricted to the border
    let mut marker = Gray::zeros(h, w);
    for x in 0..w {
        marker.set(0, x, comp.at(0, x));
        marker.set(h - 1, x, comp.at(h - 1, x));
    }
    for y in 0..h {
        marker.set(y, 0, comp.at(y, 0));
        marker.set(y, w - 1, comp.at(y, w - 1));
    }
    let reachable = reconstruct(&marker, &comp, Conn::Four);
    Gray {
        h,
        w,
        px: reachable.px.iter().map(|&v| 1.0 - v).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{forall, Rng};

    #[test]
    fn dilate_erode_point() {
        let mut img = Gray::zeros(5, 5);
        img.set(2, 2, 7.0);
        let d8 = dilate3x3(&img, Conn::Eight);
        assert_eq!(d8.at(1, 1), 7.0);
        assert_eq!(d8.at(0, 0), 0.0);
        let d4 = dilate3x3(&img, Conn::Four);
        assert_eq!(d4.at(1, 2), 7.0);
        assert_eq!(d4.at(1, 1), 0.0); // diagonal excluded in 4-conn
        let e = erode3x3(&d8, Conn::Eight);
        assert_eq!(e.at(2, 2), 7.0);
    }

    #[test]
    fn open_removes_specks_keeps_blocks() {
        let mut img = Gray::zeros(16, 16);
        img.set(3, 3, 200.0); // single-pixel speck
        for y in 8..14 {
            for x in 8..14 {
                img.set(y, x, 200.0); // 6x6 block survives radius-2 opening
            }
        }
        let o = morph_open(&img);
        assert_eq!(o.at(3, 3), 0.0, "speck should vanish");
        assert_eq!(o.at(10, 10), 200.0, "block interior should survive");
    }

    #[test]
    fn duality_and_ordering_properties() {
        forall(
            "erode <= img <= dilate; open anti-extensive",
            25,
            |r: &mut Rng| {
                let h = r.range(2, 12);
                let w = r.range(2, 12);
                (h, w, r.image(h, w))
            },
            |(h, w, px)| {
                let img = Gray::new(*h, *w, px.clone()).unwrap();
                let d = dilate3x3(&img, Conn::Eight);
                let e = erode3x3(&img, Conn::Eight);
                let o = morph_open(&img);
                for i in 0..px.len() {
                    if e.px[i] > px[i] + 1e-6 || d.px[i] < px[i] - 1e-6 {
                        return Err(format!("extremes violated at {i}"));
                    }
                    if o.px[i] > px[i] + 1e-6 {
                        return Err(format!("open not anti-extensive at {i}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn fill_holes_basic() {
        // ring with interior hole
        let mut m = Gray::zeros(8, 8);
        for y in 2..6 {
            for x in 2..6 {
                m.set(y, x, 1.0);
            }
        }
        m.set(3, 3, 0.0);
        m.set(4, 4, 0.0);
        let f = fill_holes(&m);
        assert_eq!(f.at(3, 3), 1.0);
        assert_eq!(f.at(4, 4), 1.0);
        assert_eq!(f.at(0, 0), 0.0);
    }

    #[test]
    fn fill_holes_open_bay_not_filled() {
        // a "C" shape: concavity touches outside, must NOT be filled
        let mut m = Gray::zeros(7, 7);
        for y in 1..6 {
            for x in 1..6 {
                m.set(y, x, 1.0);
            }
        }
        for y in 2..5 {
            for x in 3..7 {
                m.set(y, x.min(6), 0.0);
            }
        }
        let f = fill_holes(&m);
        assert_eq!(f.at(3, 4), 0.0, "open bay must stay background");
    }

    #[test]
    fn fill_holes_extensive_property() {
        forall(
            "fill_holes >= mask, binary",
            20,
            |r: &mut Rng| {
                let h = r.range(3, 14);
                let w = r.range(3, 14);
                (h, w, r.mask(h, w, 0.55))
            },
            |(h, w, px)| {
                let m = Gray::new(*h, *w, px.clone()).unwrap();
                let f = fill_holes(&m);
                for i in 0..px.len() {
                    if f.px[i] < px[i] {
                        return Err(format!("not extensive at {i}"));
                    }
                    if f.px[i] != 0.0 && f.px[i] != 1.0 {
                        return Err(format!("non-binary output {}", f.px[i]));
                    }
                }
                Ok(())
            },
        );
    }
}
