//! Thresholding operations: binary threshold and the paper's
//! `AreaThreshold` (drop components outside an area band).

use super::label::{bwlabel, label_areas};
use super::{Conn, Gray};

/// Binary threshold: 1.0 where `img > t`, else 0.0.
pub fn threshold(img: &Gray, t: f32) -> Gray {
    Gray {
        h: img.h,
        w: img.w,
        px: img.px.iter().map(|&v| if v > t { 1.0 } else { 0.0 }).collect(),
    }
}

/// Keep only connected components whose area lies in `[lo, hi]` (inclusive),
/// 8-connected — semantics of `model.area_threshold`.
pub fn area_threshold(mask: &Gray, lo: f32, hi: f32) -> Gray {
    let (labels, k) = bwlabel(mask, Conn::Eight);
    let areas = label_areas(&labels, k);
    let mut out = vec![0.0f32; mask.px.len()];
    for (i, &l) in labels.px.iter().enumerate() {
        let id = l as usize;
        if id > 0 {
            let a = areas[id] as f32;
            if a >= lo && a <= hi {
                out[i] = 1.0;
            }
        }
    }
    Gray { h: mask.h, w: mask.w, px: out }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{forall, Rng};

    #[test]
    fn threshold_strict() {
        let g = Gray::new(1, 3, vec![1.0, 2.0, 3.0]).unwrap();
        let t = threshold(&g, 2.0);
        assert_eq!(t.px, vec![0.0, 0.0, 1.0]);
    }

    #[test]
    fn drops_small_keeps_mid_drops_large() {
        let mut m = Gray::zeros(16, 16);
        m.set(0, 0, 1.0); // area 1
        for y in 3..6 {
            for x in 3..6 {
                m.set(y, x, 1.0); // area 9
            }
        }
        for y in 8..16 {
            for x in 8..16 {
                m.set(y, x, 1.0); // area 64
            }
        }
        let out = area_threshold(&m, 2.0, 20.0);
        assert_eq!(out.at(0, 0), 0.0);
        assert_eq!(out.at(4, 4), 1.0);
        assert_eq!(out.at(12, 12), 0.0);
    }

    #[test]
    fn inclusive_bounds() {
        let mut m = Gray::zeros(4, 8);
        m.set(0, 0, 1.0); // area 1
        m.set(2, 2, 1.0);
        m.set(2, 3, 1.0); // area 2
        let out = area_threshold(&m, 1.0, 1.0);
        assert_eq!(out.at(0, 0), 1.0);
        assert_eq!(out.at(2, 2), 0.0);
    }

    #[test]
    fn area_threshold_is_restriction() {
        forall(
            "area_threshold subset of mask; kept components untouched",
            20,
            |r: &mut Rng| {
                let h = r.range(3, 14);
                let w = r.range(3, 14);
                let lo = r.range(1, 4) as f32;
                let hi = lo + r.range(0, 20) as f32;
                (h, w, r.mask(h, w, 0.45), lo, hi)
            },
            |(h, w, px, lo, hi)| {
                let m = Gray::new(*h, *w, px.clone()).unwrap();
                let out = area_threshold(&m, *lo, *hi);
                for i in 0..px.len() {
                    if out.px[i] > px[i] {
                        return Err("output not subset of input".into());
                    }
                }
                // surviving components must have their whole area intact
                let (lab, k) = bwlabel(&out, Conn::Eight);
                let areas = label_areas(&lab, k);
                for a in &areas[1..] {
                    let a = *a as f32;
                    if a < *lo || a > *hi {
                        return Err(format!("surviving area {a} outside [{lo},{hi}]"));
                    }
                }
                Ok(())
            },
        );
    }
}
