//! Morphological grayscale reconstruction — the paper's compute hot-spot.
//!
//! Two implementations:
//!
//! * [`reconstruct`] — Vincent's **hybrid algorithm** (raster scan,
//!   anti-raster scan, then FIFO-queue wave propagation).  This is the fast
//!   CPU implementation the paper cites ([39] L. Vincent 1993) and uses for
//!   `ReconToNuclei`, `FillHolles` and `Pre-Watershed`.
//! * [`reconstruct_iterative`] — the naive fixed-point of geodesic
//!   dilations.  Semantically identical; used as a cross-check oracle in
//!   tests and as the cost model for the "GPU" variant (which is the same
//!   iteration inside an HLO `while` — see python/compile/model.py).

use super::{Conn, Gray};
use std::collections::VecDeque;

/// Vincent's hybrid grayscale reconstruction of `mask` from `marker`.
///
/// Requires `marker <= mask` pointwise for the usual interpretation; values
/// above the mask are clipped first (same as the JAX variant).
pub fn reconstruct(marker: &Gray, mask: &Gray, conn: Conn) -> Gray {
    assert_eq!(marker.h, mask.h);
    assert_eq!(marker.w, mask.w);
    let (h, w) = (mask.h, mask.w);
    let mut out: Vec<f32> = marker
        .px
        .iter()
        .zip(&mask.px)
        .map(|(&m, &k)| m.min(k))
        .collect();

    let idx = |y: usize, x: usize| y * w + x;

    // N+(p): neighbours visited *before* p in raster order.
    let plus: &[(isize, isize)] = match conn {
        Conn::Four => &[(-1, 0), (0, -1)],
        Conn::Eight => &[(-1, -1), (-1, 0), (-1, 1), (0, -1)],
    };
    // N-(p): neighbours visited before p in anti-raster order.
    let minus: &[(isize, isize)] = match conn {
        Conn::Four => &[(1, 0), (0, 1)],
        Conn::Eight => &[(1, -1), (1, 0), (1, 1), (0, 1)],
    };

    // 1) raster scan
    for y in 0..h {
        for x in 0..w {
            let mut v = out[idx(y, x)];
            for &(dy, dx) in plus {
                let ny = y as isize + dy;
                let nx = x as isize + dx;
                if ny >= 0 && nx >= 0 && nx < w as isize {
                    v = v.max(out[idx(ny as usize, nx as usize)]);
                }
            }
            out[idx(y, x)] = v.min(mask.px[idx(y, x)]);
        }
    }

    // 2) anti-raster scan + queue seeding
    let mut queue: VecDeque<(usize, usize)> = VecDeque::new();
    for y in (0..h).rev() {
        for x in (0..w).rev() {
            let mut v = out[idx(y, x)];
            for &(dy, dx) in minus {
                let ny = y as isize + dy;
                let nx = x as isize + dx;
                if ny < h as isize && nx >= 0 && nx < w as isize {
                    v = v.max(out[idx(ny as usize, nx as usize)]);
                }
            }
            let v = v.min(mask.px[idx(y, x)]);
            out[idx(y, x)] = v;
            // queue p if some anti-raster neighbour could still grow
            for &(dy, dx) in minus {
                let ny = y as isize + dy;
                let nx = x as isize + dx;
                if ny < h as isize && nx >= 0 && nx < w as isize {
                    let q = idx(ny as usize, nx as usize);
                    if out[q] < v && out[q] < mask.px[q] {
                        queue.push_back((y, x));
                        break;
                    }
                }
            }
        }
    }

    // 3) FIFO wave propagation
    while let Some((y, x)) = queue.pop_front() {
        let vp = out[idx(y, x)];
        for &(dy, dx) in conn.offsets() {
            let ny = y as isize + dy;
            let nx = x as isize + dx;
            if ny < 0 || nx < 0 || ny >= h as isize || nx >= w as isize {
                continue;
            }
            let q = idx(ny as usize, nx as usize);
            if out[q] < vp && mask.px[q] != out[q] {
                let nv = vp.min(mask.px[q]);
                if nv > out[q] {
                    out[q] = nv;
                    queue.push_back((ny as usize, nx as usize));
                }
            }
        }
    }

    Gray { h, w, px: out }
}

/// Naive fixed-point reconstruction (oracle; O(iterations * pixels)).
pub fn reconstruct_iterative(marker: &Gray, mask: &Gray, conn: Conn) -> Gray {
    let mut cur = Gray {
        h: marker.h,
        w: marker.w,
        px: marker
            .px
            .iter()
            .zip(&mask.px)
            .map(|(&m, &k)| m.min(k))
            .collect(),
    };
    loop {
        let nxt = super::morphology::dilate_clip(&cur, mask, conn);
        if nxt.px == cur.px {
            return nxt;
        }
        cur = nxt;
    }
}

/// h-dome transform: gray - reconstruct(gray - h, gray).  Bright structures
/// of height > h.  This is the core of `ReconToNuclei`.
pub fn hdome(gray: &Gray, h: f32, conn: Conn) -> Gray {
    let marker = Gray {
        h: gray.h,
        w: gray.w,
        px: gray.px.iter().map(|&v| v - h).collect(),
    };
    let recon = reconstruct(&marker, gray, conn);
    Gray {
        h: gray.h,
        w: gray.w,
        px: gray
            .px
            .iter()
            .zip(&recon.px)
            .map(|(&g, &r)| g - r)
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{forall, Rng};

    fn random_pair(r: &mut Rng) -> (usize, usize, Vec<f32>, Vec<f32>) {
        let h = r.range(2, 14);
        let w = r.range(2, 14);
        let mask = r.image(h, w);
        let marker: Vec<f32> = mask.iter().map(|&v| v - r.f32_range(0.0, 80.0)).collect();
        (h, w, marker, mask)
    }

    #[test]
    fn hybrid_matches_iterative_oracle() {
        for conn in [Conn::Four, Conn::Eight] {
            forall(
                "vincent == fixpoint",
                30,
                random_pair,
                |(h, w, marker, mask)| {
                    let marker = Gray::new(*h, *w, marker.clone()).unwrap();
                    let mask = Gray::new(*h, *w, mask.clone()).unwrap();
                    let fast = reconstruct(&marker, &mask, conn);
                    let slow = reconstruct_iterative(&marker, &mask, conn);
                    if fast.max_abs_diff(&slow) > 1e-6 {
                        return Err(format!("diff {}", fast.max_abs_diff(&slow)));
                    }
                    Ok(())
                },
            );
        }
    }

    #[test]
    fn recon_bounded_by_mask_and_idempotent() {
        forall("recon <= mask; idempotent", 20, random_pair, |(h, w, marker, mask)| {
            let marker = Gray::new(*h, *w, marker.clone()).unwrap();
            let mask = Gray::new(*h, *w, mask.clone()).unwrap();
            let r1 = reconstruct(&marker, &mask, Conn::Eight);
            for i in 0..r1.px.len() {
                if r1.px[i] > mask.px[i] + 1e-6 {
                    return Err(format!("exceeds mask at {i}"));
                }
            }
            let r2 = reconstruct(&r1, &mask, Conn::Eight);
            if r1.max_abs_diff(&r2) > 1e-6 {
                return Err("not idempotent".into());
            }
            Ok(())
        });
    }

    #[test]
    fn plateau_propagates_from_single_seed() {
        // mask: two plateaus (100 and 50) connected by a bridge of 50
        let mut mask = Gray::zeros(5, 9);
        for y in 1..4 {
            for x in 1..4 {
                mask.set(y, x, 100.0);
            }
            for x in 5..8 {
                mask.set(y, x, 50.0);
            }
        }
        mask.set(2, 4, 50.0); // bridge
        let mut marker = Gray::zeros(5, 9);
        marker.set(2, 2, 100.0); // seed inside the tall plateau
        let r = reconstruct(&marker, &mask, Conn::Eight);
        assert_eq!(r.at(1, 1), 100.0);
        assert_eq!(r.at(2, 4), 50.0, "bridge fills to mask level");
        assert_eq!(r.at(2, 6), 50.0, "second plateau reached through bridge");
        assert_eq!(r.at(0, 0), 0.0);
    }

    #[test]
    fn hdome_extracts_peaks() {
        // background ramp 10, one peak of 100, one bump of 15
        let mut g = Gray::filled(7, 7, 10.0);
        g.set(2, 2, 100.0);
        g.set(5, 5, 15.0);
        let d = hdome(&g, 20.0, Conn::Eight);
        assert!((d.at(2, 2) - 20.0).abs() < 1e-5, "peak capped at h");
        assert!(d.at(5, 5) < 20.0, "small bump dome = 5");
        assert!((d.at(5, 5) - 5.0).abs() < 1e-5);
        assert_eq!(d.at(0, 0), 0.0);
    }
}
